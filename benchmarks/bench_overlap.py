"""Fig. 9 analogue: overlap detection, 1D outer-product algorithm vs the 2D
SpGEMM formulation, same inputs.

The 1D variant emulates diBELLA 1D's distributed-hash-table detection: group
k-mer instances by k-mer (the "owner bucket"), emit all read pairs per bucket
(a² per k-mer), then globally deduplicate — an outer-product SpGEMM.  The 2D
variant is our row-expansion SpGEMM on A·Aᵀ.  Also reports the model word
counts (a²m/P vs am/√P, paper §V-B).

``distributions=("local", "shard_map")`` adds the explicit-exchange ring
SUMMA rows (DESIGN.md §2.11): ``overlap[shard_map]/ring_<pr>x<pc>`` with the
measured per-``ppermute`` ``exchange_words_summa`` next to the analytic
``model_words_summa`` (``bench_comm_model.words_summa``) in the derived
field, plus the distributed x-drop row (§2.12):
``align[shard_map]/bucket<b>_P<p>`` with ``exchange_words_align`` vs
``model_words_align`` — ``scripts/check_smoke_comm.py`` asserts both pairs
match exactly."""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from ._timing import timed


def _inputs(genome=10_000):
    from repro.assembly.counter import build_matrices, count_and_select
    from repro.assembly.kmers import extract_kmers
    from repro.assembly.simulate import simulate_genome, simulate_reads

    rng = np.random.default_rng(3)
    g = simulate_genome(rng, genome)
    rs = simulate_reads(g, depth=12, mean_len=900, std_len=120,
                        error_rate=0.03, seed=4)
    km = extract_kmers(jnp.asarray(rs.codes), jnp.asarray(rs.lengths), k=15)
    kc = count_and_select(km, lower=2, upper=24)
    a, at, _, _ = build_matrices(kc, n_reads=rs.n_reads, m_capacity=1 << 14,
                                 read_capacity=128, kmer_capacity=24)
    return a, at, kc, rs


def _outer_product_1d(at, n_reads, cap):
    """Per-k-mer bucket pair expansion (diBELLA-1D-like)."""
    from repro.core.semiring import overlap_semiring as OV
    from repro.core.spmat import from_coo

    m, u = at.cols.shape
    reads = at.cols  # (m, u) read ids per kmer
    pos = at.vals["pos"]
    valid = reads >= 0
    ii = jnp.broadcast_to(reads[:, :, None], (m, u, u)).reshape(-1)
    jj = jnp.broadcast_to(reads[:, None, :], (m, u, u)).reshape(-1)
    pi = jnp.broadcast_to(pos[:, :, None], (m, u, u)).reshape(-1)
    pj = jnp.broadcast_to(pos[:, None, :], (m, u, u)).reshape(-1)
    ok = (jnp.broadcast_to(valid[:, :, None] & valid[:, None, :],
                           (m, u, u)).reshape(-1) & (ii != jj))
    vals = {"cnt": jnp.ones_like(ii, jnp.int32),
            "apos": jnp.stack([pi, jnp.full_like(pi, -1)], -1),
            "bpos": jnp.stack([pj, jnp.full_like(pj, -1)], -1)}
    c, ovf = from_coo(ii, jj, vals, ok, n_rows=n_reads, n_cols=n_reads,
                      capacity=cap, semiring=OV)
    return c


def _ring_rows(a, at, n_reads, cap):
    """Time the explicit-exchange ring SUMMA path and cross-check words.

    Emits one ``overlap[shard_map]/ring_<pr>x<pc>`` row whose derived field
    carries the measured ``exchange_words_summa`` (counted per ``ppermute``
    at trace time) and the analytic ``model_words_summa`` from Table I —
    ``scripts/check_smoke_comm.py`` requires the two to agree.
    """
    from repro.assembly.counter import first_semiring
    from repro.core.semiring import overlap_semiring as OV
    from repro.core.summa import default_summa_mesh, overlap_spgemm_shard_map

    from .bench_comm_model import words_summa

    mesh = default_summa_mesh()
    pr = mesh.shape["data"]
    pc = mesh.shape["model"]

    def call():
        c, ovf, st = overlap_spgemm_shard_map(
            a, at, semiring=OV, operand_semiring=first_semiring,
            capacity=cap, mesh=mesh)
        return c, st

    t = timed(call, out_of=lambda r: r[0].cols)
    (c, st), t_ring = t.result, t.steady_us

    n_pad = -(-n_reads // pr) * pr
    m_rows = at.cols.shape[0]
    m_pad = -(-m_rows // pr) * pr
    # {"pos"} payload: 1 col word + 1 value word per slot.
    wm = words_summa(n_rows=n_pad, a_block_slots=a.capacity,
                     a_words_per_slot=2, m_rows=m_pad,
                     b_block_slots=at.capacity, b_words_per_slot=2,
                     pr=pr, pc=pc)
    derived = (f"exchange_words_summa={st['exchange_words_summa']}"
               f";model_words_summa={wm}"
               f";exchange_rounds_summa={st['exchange_rounds_summa']}"
               f";summa_algorithm={st['summa_algorithm']}"
               f";hbm_round_trips={st.get('spgemm_hbm_round_trips', 0)}"
               f";nnzC={int(c.nnz())}")
    return [(f"overlap[shard_map]/ring_{pr}x{pc}", t_ring, derived,
             t.compile_us, t.peak_hbm_bytes, t.hbm_source)]


def _align_rows(a, at, rs, cap, k=15):
    """Time the distributed x-drop extension and cross-check words.

    Rebuilds the pipeline's pv-valid candidate compaction from the local
    SpGEMM product, then routes the bucket through
    ``core.align_dist.align_bucket_shard_map`` on the default row mesh.
    Emits one ``align[shard_map]/bucket<b>_P<p>`` row whose derived field
    carries the measured ``exchange_words_align`` next to the analytic
    ``model_words_align`` (``bench_comm_model.words_align``) —
    ``scripts/check_smoke_comm.py`` requires the two to agree exactly."""
    from repro.core.align_dist import align_bucket_shard_map
    from repro.core.components_dist import default_row_mesh, infer_row_axes
    from repro.core.semiring import overlap_semiring as OV
    from repro.core.spgemm import spgemm
    from repro.core.spmat import next_pow2

    from .bench_comm_model import words_align

    n = rs.n_reads
    codes = jnp.asarray(rs.codes, jnp.uint8)
    lengths = jnp.asarray(rs.lengths, jnp.int32)
    c, _ = spgemm(a, at, semiring=OV, capacity=cap)

    # the pipeline's candidate compaction (assembly/pipeline.py Alignment)
    pair_i = jnp.broadcast_to(jnp.arange(n)[:, None], (n, cap)).reshape(-1)
    pair_j = c.cols.reshape(-1)
    cnt = c.vals["cnt"].reshape(-1)
    apos = c.vals["apos"][..., 0].reshape(-1)
    bpos = c.vals["bpos"][..., 0].reshape(-1)
    pv = (pair_j > pair_i) & (cnt >= 2)
    pa, ca = apos // 2, apos % 2
    pb, cb = bpos // 2, bpos % 2
    strand = jnp.where(pv, ca ^ cb, 0)
    li = lengths[jnp.where(pv, pair_i, 0)]
    lj = lengths[jnp.where(pv, pair_j, 0)]
    pb_or = jnp.where(strand == 1, lj - k - pb, pb)
    bucket = next_pow2(int(jnp.sum(pv)))
    idx = jnp.nonzero(pv, size=bucket, fill_value=0)[0]
    cand = {
        "i": pair_i[idx], "j": pair_j[idx], "li": li[idx], "lj": lj[idx],
        "pa": jnp.maximum(pa[idx], 0), "pb": jnp.maximum(pb_or[idx], 0),
        "strand": strand[idx],
    }

    mesh = default_row_mesh()
    p = 1
    for ax in infer_row_axes(mesh):
        p *= mesh.shape[ax]

    def call():
        return align_bucket_shard_map(
            codes, cand, k=k, mesh=mesh, backend="reference",
            band=33, max_steps=1024,
        )

    t = timed(call, out_of=lambda r: r[0].score)
    (res, st), t_align = t.result, t.steady_us

    n_pad = -(-n // p) * p
    bucket_pad = -(-bucket // p) * p
    wm = words_align(n_pad=n_pad, row_width=int(codes.shape[1]),
                     bucket_pad=bucket_pad, p=p)
    derived = (f"exchange_words_align={st['exchange_words_align']}"
               f";model_words_align={wm}"
               f";exchange_rounds_align={st['exchange_rounds_align']}"
               f";bucket={bucket}"
               f";n_scored={int(jnp.sum(res.score > 0))}")
    return [(f"align[shard_map]/bucket{bucket_pad}_P{p}", t_align, derived,
             t.compile_us, t.peak_hbm_bytes, t.hbm_source)]


def run(distributions=("local",), genome=10_000):
    from repro.core.semiring import overlap_semiring as OV
    from repro.core.spgemm import spgemm

    a, at, kc, rs = _inputs(genome)
    n = rs.n_reads

    rows = []
    if "shard_map" in distributions:
        rows += _ring_rows(a, at, n, 64)
        rows += _align_rows(a, at, rs, 64)
    if "local" not in distributions:
        return rows

    # repro: noqa[R001] — benchmark: jit built once per measurement.
    f2d = jax.jit(lambda: spgemm(a, at, semiring=OV, capacity=64))
    t2 = timed(f2d, out_of=lambda r: r[0].cols)
    (c2d, _), t_2d = t2.result, t2.steady_us

    # repro: noqa[R001] — benchmark: jit built once per measurement.
    f1d = jax.jit(lambda: _outer_product_1d(at, n, 64))
    t1 = timed(f1d, out_of=lambda r: r.cols)
    c1d, t_1d = t1.result, t1.steady_us

    # same candidate pairs?
    same = int(jnp.sum((c2d.cols >= 0) != (c1d.cols >= 0)))
    # model words at P=1024 (paper Table I)
    m_real = int(kc.m_reliable)
    am = float(a.nnz())
    p = 1024
    w1d = (am / m_real) * am / p if m_real else 0
    w2d = am / (p ** 0.5)
    rows += [
        ("overlap/2d_spgemm", t_2d, f"nnzC={int(c2d.nnz())}",
         t2.compile_us, t2.peak_hbm_bytes, t2.hbm_source),
        ("overlap/1d_outer_product", t_1d,
         f"pattern_mismatches={same};speedup_2d={t_1d / t_2d:.2f}x",
         t1.compile_us, t1.peak_hbm_bytes, t1.hbm_source),
        ("overlap/model_words_P1024", 0.0,
         f"W1D={w1d:.3e};W2D={w2d:.3e}", 0.0),
    ]
    return rows
