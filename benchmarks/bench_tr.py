"""Table VI analogue: transitive-reduction race.

The paper beats SORA (Spark) 10.5–29×; Spark is unavailable here, so the
competing implementations are (a) the sequential Myers algorithm — the
paper's own reference [10] — and (b) a dense min-plus-square reduction.
Ours runs both the paper-faithful semiring loop and the beyond-paper fused
(sampled-square) variant."""

from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp


def _graph(n, avg_deg, seed):
    from repro.core.semiring import minplus_orient_semiring as SR
    from repro.core.spmat import from_coo

    rng = np.random.default_rng(seed)
    e = n * avg_deg
    rows = rng.integers(0, n, e)
    cols = rng.integers(0, n, e)
    combos = rng.integers(0, 4, e)
    suf = rng.integers(1, 500, e).astype(np.float32)
    vals = np.full((e, 4), np.inf, np.float32)
    vals[np.arange(e), combos] = suf
    ok = rows != cols
    mat, _ = from_coo(jnp.asarray(rows), jnp.asarray(cols), jnp.asarray(vals),
                      jnp.asarray(ok), n_rows=n, n_cols=n,
                      capacity=3 * avg_deg, semiring=SR)
    return mat


def _time(f, reps=3):
    f()  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(jax.tree.leaves(f())[0])
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    from repro.core.myers_baseline import (
        dense_square_transitive_reduction, from_ell,
        myers_transitive_reduction,
    )
    from repro.core.transitive_reduction import (
        transitive_reduction, transitive_reduction_fused,
    )

    rows = []
    for n, deg in ((256, 8), (1024, 8), (4096, 8), (16384, 8)):
        r = _graph(n, deg, seed=n)
        edges = from_ell(r)

        t_fused = _time(lambda: transitive_reduction_fused(r, fuzz=100.0)[0])
        t_faith = _time(lambda: transitive_reduction(r, fuzz=100.0)[0])
        t0 = time.perf_counter()
        myers_transitive_reduction(edges, fuzz=100.0)
        t_myers = (time.perf_counter() - t0) * 1e6
        if n <= 256:  # O(n^3) — CPU-feasible only at toy sizes
            t0 = time.perf_counter()
            dense_square_transitive_reduction(edges, n, fuzz=100.0)
            t_dense = (time.perf_counter() - t0) * 1e6
        else:
            t_dense = float("nan")
        rows += [
            (f"tr/n{n}/semiring_fused", t_fused,
             f"speedup_vs_myers={t_myers / t_fused:.1f}x"),
            (f"tr/n{n}/semiring_faithful", t_faith,
             f"speedup_vs_myers={t_myers / t_faith:.1f}x"),
            (f"tr/n{n}/myers_sequential", t_myers, ""),
            (f"tr/n{n}/dense_square", t_dense, ""),
        ]
    return rows
