"""Table VI analogue: transitive-reduction race.

The paper beats SORA (Spark) 10.5–29×; Spark is unavailable here, so the
competing implementations are (a) the sequential Myers algorithm — the
paper's own reference [10] — and (b) a dense min-plus-square reduction.
Ours runs both the paper-faithful semiring loop and the beyond-paper fused
(sampled-square) variant."""

from __future__ import annotations

import time

import numpy as np
import jax.numpy as jnp

from ._timing import timed


def _graph(n, avg_deg, seed):
    from repro.core.semiring import minplus_orient_semiring as SR
    from repro.core.spmat import from_coo

    rng = np.random.default_rng(seed)
    e = n * avg_deg
    rows = rng.integers(0, n, e)
    cols = rng.integers(0, n, e)
    combos = rng.integers(0, 4, e)
    suf = rng.integers(1, 500, e).astype(np.float32)
    vals = np.full((e, 4), np.inf, np.float32)
    vals[np.arange(e), combos] = suf
    ok = rows != cols
    mat, _ = from_coo(jnp.asarray(rows), jnp.asarray(cols), jnp.asarray(vals),
                      jnp.asarray(ok), n_rows=n, n_cols=n,
                      capacity=3 * avg_deg, semiring=SR)
    return mat


def run(sweep=(256, 1024, 4096, 16384), avg_deg=8):
    """One row per (n, variant); semiring rows carry the compile/steady
    split and the HBM watermark via :func:`benchmarks._timing.timed`; the
    host-Python baselines (Myers, dense square) have no XLA compile, so
    their ``compile_us`` is genuinely 0."""
    from repro.core.myers_baseline import (
        dense_square_transitive_reduction, from_ell,
        myers_transitive_reduction,
    )
    from repro.core.transitive_reduction import (
        transitive_reduction, transitive_reduction_fused,
    )
    from repro.obs import watermark

    rows = []
    for n in sweep:
        r = _graph(n, avg_deg, seed=n)
        edges = from_ell(r)

        tf = timed(lambda: transitive_reduction_fused(r, fuzz=100.0)[0],
                   out_of=lambda m: m.cols)
        tt = timed(lambda: transitive_reduction(r, fuzz=100.0)[0],
                   out_of=lambda m: m.cols)
        with watermark() as wm_myers:
            t0 = time.perf_counter()
            myers_transitive_reduction(edges, fuzz=100.0)
            t_myers = (time.perf_counter() - t0) * 1e6
        if n <= 256:  # O(n^3) — CPU-feasible only at toy sizes
            with watermark() as wm_dense:
                t0 = time.perf_counter()
                dense_square_transitive_reduction(edges, n, fuzz=100.0)
                t_dense = (time.perf_counter() - t0) * 1e6
            dense_peak, dense_src = wm_dense.peak_hbm_bytes, wm_dense.source
        else:
            t_dense, dense_peak, dense_src = float("nan"), 0, "live_buffers"
        rows += [
            (f"tr/n{n}/semiring_fused", tf.steady_us,
             f"speedup_vs_myers={t_myers / tf.steady_us:.1f}x",
             tf.compile_us, tf.peak_hbm_bytes, tf.hbm_source),
            (f"tr/n{n}/semiring_faithful", tt.steady_us,
             f"speedup_vs_myers={t_myers / tt.steady_us:.1f}x",
             tt.compile_us, tt.peak_hbm_bytes, tt.hbm_source),
            (f"tr/n{n}/myers_sequential", t_myers, "", 0.0,
             wm_myers.peak_hbm_bytes, wm_myers.source),
            (f"tr/n{n}/dense_square", t_dense, "", 0.0, dense_peak,
             dense_src),
        ]
    return rows
