"""Shared benchmark timing: one untimed warmup + timed steady-state reps.

Every snapshot benchmark used to fold the first (compiling) call into its
reported wall-clock, which made compile-dominated rows — e.g. a per-call
``jax.jit`` rebuild — indistinguishable from genuinely slow steady state.
:func:`timed` separates the two: the first call is measured on its own
(``compile_us``: XLA compile + one execution), then ``reps`` further calls
are averaged for the steady-state figure.  ``benchmarks/run.py`` carries the
pair into the JSON records as ``ms`` / ``compile_ms``, and
``scripts/check_bench_regression.py`` refuses to ratio-compare against
baseline rows that predate the split (no ``compile_ms`` field).
"""

from __future__ import annotations

import time


def timed(f, out_of=lambda r: r, reps: int = 3):
    """Time ``f``: returns ``(result, steady_us, compile_us)``.

    ``out_of`` selects what to device-sync from ``f``'s result (any pytree,
    dataclasses included — synced via :func:`repro.obs.sync`, the same
    block-until-ready path the pipeline's stage spans use).  ``compile_us``
    is the wall-clock of the first call (compile + one execution);
    ``steady_us`` averages ``reps`` subsequent calls."""
    from repro.obs import sync

    t0 = time.perf_counter()
    res = f()
    sync(out_of(res))
    compile_us = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    for _ in range(reps):
        sync(out_of(f()))
    steady_us = (time.perf_counter() - t0) / reps * 1e6
    return res, steady_us, compile_us
