"""Shared benchmark timing: compile/steady split + HBM watermark.

Every snapshot benchmark used to fold the first (compiling) call into its
reported wall-clock, which made compile-dominated rows — e.g. a per-call
``jax.jit`` rebuild — indistinguishable from genuinely slow steady state.
:func:`timed` separates the two: the first call is measured on its own
(``compile_us``: XLA compile + one execution), then ``reps`` further calls
are averaged for the steady-state figure.  The whole window additionally
runs under an ``obs.memory`` watermark, so every row also reports its
device-memory high-water mark (``peak_hbm_bytes``) and the sampling path
that produced it (``hbm_source``) — HBM capacity is the genome-size
ceiling, so "smaller" is tracked next to "faster" in every record.

``benchmarks/run.py`` and ``benchmarks/engine.py`` carry the fields into
the JSON records as ``ms`` / ``compile_ms`` / ``peak_hbm_bytes``, and
``scripts/check_bench_regression.py`` gates on both the time and memory
trajectories.
"""

from __future__ import annotations

import time
from typing import Any, NamedTuple


class Timing(NamedTuple):
    """One :func:`timed` measurement (named so call sites stay readable)."""

    result: Any
    steady_us: float
    compile_us: float
    peak_hbm_bytes: int
    hbm_source: str


def timed(f, out_of=lambda r: r, reps: int = 3) -> Timing:
    """Time ``f`` under a device-memory watermark.

    ``out_of`` selects what to device-sync from ``f``'s result (any pytree,
    dataclasses included — synced via :func:`repro.obs.sync`, the same
    block-until-ready path the pipeline's stage spans use).  ``compile_us``
    is the wall-clock of the first call (compile + one execution);
    ``steady_us`` averages ``reps`` subsequent calls; ``peak_hbm_bytes`` is
    the high-water mark over all ``reps + 1`` calls (``obs.memory``, with
    the live-buffer fallback on backends without ``memory_stats``)."""
    from repro.obs import sample, sync, watermark

    with watermark() as wm:
        t0 = time.perf_counter()
        res = f()
        sync(out_of(res))
        compile_us = (time.perf_counter() - t0) * 1e6
        sample()  # post-call sample point (live-buffer fallback granularity)
        t0 = time.perf_counter()
        for _ in range(reps):
            sync(out_of(f()))
        steady_us = (time.perf_counter() - t0) / reps * 1e6
    return Timing(res, steady_us, compile_us, wm.peak_hbm_bytes, wm.source)
