"""Contigs-stage race: host walk (reference) vs device path (DESIGN.md §2.7),
with a distribution axis (§2.9) and a fused-cc-kernel section.

String graphs are synthesized directly — long unitig chains with their
reverse-complement twins, a sprinkle of branch vertices, and isolated reads —
so the sweep isolates contig generation from the rest of the pipeline.

Rows:
  * ``contigs[backend/distribution]/nN`` — the device path under
    ``distribution="gspmd"`` (auto-sharded) vs ``"shard_map"`` (the full
    explicit-exchange chain stage: branch cut + doubling + ring-bitonic
    ordering, DESIGN.md §2.10); shard_map rows report the per-device
    exchange volume — total, doubling and sort terms — next to the model
    predictions from ``bench_comm_model`` (``words_contig_doubling`` /
    ``words_chain_sort``; the sort pair must match exactly, and the CI
    smoke artifact asserts it via ``scripts/check_smoke_comm.py``).
  * ``cc[backend]/nN`` — the hook/shortcut component rounds through the
    ``cc_labels`` op: jnp oracle (one HBM round trip per round) vs fused
    Pallas kernel (one per 8-round chunk); derived column reports both trip
    counts.

Standalone: ``python -m benchmarks.bench_contigs --backend pallas
--distribution both``.
"""

from __future__ import annotations

import numpy as np

from ._timing import timed


def _string_graph(n, seed):
    """Chain-structured string matrix over n reads: consecutive dovetails
    (plus complements), a branch every 64 reads, and every 16th read left
    fully edge-free so the isolated-singleton path is exercised too."""
    from repro.assembly.contig_gen import string_matrix_from_edges

    def iso(r):
        return r % 16 == 15

    rng = np.random.default_rng(seed)
    edges = []
    for i in range(n - 1):
        suf = int(rng.integers(20, 80))
        if not (iso(i) or iso(i + 1)):
            edges.append((i, i + 1, 0, 0, suf))
            edges.append((i + 1, i, 1, 1, suf + 3))
        if i % 64 == 0 and i + 2 < n and not (iso(i) or iso(i + 2)):
            edges.append((i, i + 2, 0, 0, suf + 1))
            edges.append((i + 2, i, 1, 1, suf + 4))
    return string_matrix_from_edges(n, edges, capacity=8)


def run(backends=("reference", "pallas"), sweep=(256, 1024, 4096),
        distributions=("gspmd",)):
    from repro.assembly.contig_gen import generate_contigs
    from repro.core.components import connected_components, expand_states
    from repro.core.components_dist import default_row_mesh
    from repro.kernels.cc import fused_path_fits, hbm_round_trips

    from .bench_comm_model import words_chain_sort, words_contig_doubling

    mesh = default_row_mesh() if "shard_map" in distributions else None
    rows = []
    for n in sweep:
        s = _string_graph(n, seed=n)
        rng = np.random.default_rng(n + 1)
        codes = rng.integers(0, 4, (n, 256)).astype(np.uint8)
        lengths = rng.integers(150, 250, n).astype(np.int32)
        base = None
        for backend in backends:
            dists = distributions if backend != "reference" else ("gspmd",)
            for dist in dists:
                t = timed(
                    lambda: generate_contigs(
                        s, codes, lengths, backend=backend,
                        distribution=dist, mesh=mesh,
                    ),
                    out_of=lambda c: c.codes,
                )
                cset, us = t.result, t.steady_us
                if backend == "reference":
                    base = us
                derived = f"n_contigs={cset.n_contigs}"
                if base is not None and backend != "reference":
                    derived += f";speedup_vs_reference={base / us:.1f}x"
                if dist == "shard_map":
                    p = len(np.ravel(mesh.devices))
                    model = words_contig_doubling(
                        2 * n, p, cset.stats["exchange_rounds_doubling"]
                    )
                    model_sort = words_chain_sort(2 * n, p)
                    derived += (
                        f";exchange_words={cset.stats['exchange_words']}"
                        f";exchange_words_doubling="
                        f"{cset.stats['exchange_words_doubling']}"
                        f";model_words={model}"
                        f";exchange_words_sort="
                        f"{cset.stats['exchange_words_sort']}"
                        f";model_words_sort={model_sort}"
                    )
                tag = backend if dist == "gspmd" else f"{backend}/{dist}"
                rows.append((f"contigs[{tag}]/n{n}", us, derived,
                             t.compile_us, t.peak_hbm_bytes, t.hbm_source))

        # fused cc kernel vs oracle on the same state graph.  The pallas
        # backend falls back to the oracle above its VMEM budget — then its
        # HBM trips are one per round, not per chunk (fused_path_fits).
        g = expand_states(s)
        fused = bool(fused_path_fits(g.cols))
        for backend in backends:
            t = timed(
                lambda: connected_components(g, backend=backend),
                out_of=lambda r: r[0],
            )
            (labels, iters), us = t.result, t.steady_us
            if backend == "reference" or not fused:
                trips = int(iters)
            else:
                trips = hbm_round_trips(int(iters))
            rows.append((
                f"cc[{backend}]/n{n}", us,
                f"iters={int(iters)};hbm_round_trips={trips}"
                + ("" if backend == "reference" else f";fused={fused}"),
                t.compile_us, t.peak_hbm_bytes, t.hbm_source,
            ))
    return rows


def main() -> None:
    """CLI entry point (CSV on stdout, one row per backend×distribution)."""
    import argparse

    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--backend", default="both",
                   choices=["reference", "pallas", "both"])
    p.add_argument("--distribution", default="gspmd",
                   choices=["gspmd", "shard_map", "both"])
    ns = p.parse_args()
    backends = (("reference", "pallas") if ns.backend == "both"
                else (ns.backend,))
    dists = (("gspmd", "shard_map") if ns.distribution == "both"
             else (ns.distribution,))
    print("name,us_per_call,derived")
    for name, us, derived, *_ in run(backends=backends, distributions=dists):
        print(f"{name},{us:.1f},{derived}", flush=True)


if __name__ == "__main__":
    main()
