"""Contigs-stage race: host walk (reference) vs device path (DESIGN.md §2.7).

String graphs are synthesized directly — long unitig chains with their
reverse-complement twins, a sprinkle of branch vertices, and isolated reads —
so the sweep isolates contig generation from the rest of the pipeline.

Standalone: ``python -m benchmarks.bench_contigs --backend pallas``.
"""

from __future__ import annotations

import time

import numpy as np


def _string_graph(n, seed):
    """Chain-structured string matrix over n reads: consecutive dovetails
    (plus complements), a branch every 64 reads, and every 16th read left
    fully edge-free so the isolated-singleton path is exercised too."""
    from repro.assembly.contig_gen import string_matrix_from_edges

    def iso(r):
        return r % 16 == 15

    rng = np.random.default_rng(seed)
    edges = []
    for i in range(n - 1):
        suf = int(rng.integers(20, 80))
        if not (iso(i) or iso(i + 1)):
            edges.append((i, i + 1, 0, 0, suf))
            edges.append((i + 1, i, 1, 1, suf + 3))
        if i % 64 == 0 and i + 2 < n and not (iso(i) or iso(i + 2)):
            edges.append((i, i + 2, 0, 0, suf + 1))
            edges.append((i + 2, i, 1, 1, suf + 4))
    return string_matrix_from_edges(n, edges, capacity=8)


def run(backends=("reference", "pallas"), sweep=(256, 1024, 4096)):
    import jax

    from repro.assembly.contig_gen import generate_contigs

    rows = []
    for n in sweep:
        s = _string_graph(n, seed=n)
        rng = np.random.default_rng(n + 1)
        codes = rng.integers(0, 4, (n, 256)).astype(np.uint8)
        lengths = rng.integers(150, 250, n).astype(np.int32)
        base = None
        for backend in backends:
            def f():
                return generate_contigs(s, codes, lengths, backend=backend)

            cset = f()  # warm-up / compile
            reps = 3
            t0 = time.perf_counter()
            for _ in range(reps):
                jax.block_until_ready(jax.tree.leaves(f().codes))
            us = (time.perf_counter() - t0) / reps * 1e6
            if backend == "reference":
                base = us
            derived = f"n_contigs={cset.n_contigs}"
            if base is not None and backend != "reference":
                derived += f";speedup_vs_reference={base / us:.1f}x"
            rows.append((f"contigs[{backend}]/n{n}", us, derived))
    return rows


def main() -> None:
    import argparse

    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--backend", default="both",
                   choices=["reference", "pallas", "both"])
    ns = p.parse_args()
    backends = (("reference", "pallas") if ns.backend == "both"
                else (ns.backend,))
    print("name,us_per_call,derived")
    for name, us, derived in run(backends=backends):
        print(f"{name},{us:.1f},{derived}", flush=True)


if __name__ == "__main__":
    main()
