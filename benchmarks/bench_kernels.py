"""Kernel-level microbenchmarks: ELL SpGEMM vs dense min-plus reference
(algorithmic win of sparsity) and the x-drop aligner oracle throughput."""

from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp


def run():
    from repro.core.semiring import minplus_orient_semiring as SR
    from repro.core.spmat import from_coo
    from repro.core.spgemm import spgemm
    from repro.kernels.minplus.ref import minplus_matmul_ref

    rows = []
    n, deg = 1024, 8
    rng = np.random.default_rng(0)
    e = n * deg
    r_ = rng.integers(0, n, e); c_ = rng.integers(0, n, e)
    combos = rng.integers(0, 4, e)
    vals = np.full((e, 4), np.inf, np.float32)
    vals[np.arange(e), combos] = rng.integers(1, 500, e)
    mat, _ = from_coo(jnp.asarray(r_), jnp.asarray(c_), jnp.asarray(vals),
                      jnp.asarray(r_ != c_), n_rows=n, n_cols=n,
                      capacity=3 * deg, semiring=SR)

    f_sp = jax.jit(lambda: spgemm(mat, mat, semiring=SR, capacity=64)[0].cols)
    f_sp().block_until_ready()
    t0 = time.perf_counter()
    for _ in range(3):
        f_sp().block_until_ready()
    t_sp = (time.perf_counter() - t0) / 3 * 1e6

    dense = mat.to_dense(SR)
    f_d = jax.jit(lambda: minplus_matmul_ref(dense, dense))
    f_d().block_until_ready()
    t0 = time.perf_counter()
    f_d().block_until_ready()
    t_d = (time.perf_counter() - t0) * 1e6
    rows.append(("kernels/ell_spgemm_minplus_n1024", t_sp,
                 f"dense_ref={t_d:.0f}us;sparse_speedup={t_d / t_sp:.1f}x"))

    from repro.assembly.alignment import batch_extend

    e2, l = 256, 800
    a = rng.integers(0, 4, (e2, l)).astype(np.uint8)
    b = np.where(rng.random((e2, l)) < 0.05, (a + 1) % 4, a).astype(np.uint8)
    f_al = jax.jit(lambda: batch_extend(
        jnp.asarray(a), jnp.full(e2, l), jnp.asarray(b), jnp.full(e2, l),
        jnp.zeros(e2, jnp.int32), jnp.zeros(e2, jnp.int32), k=15, band=33,
        max_steps=1600,
    ).score)
    f_al().block_until_ready()
    t0 = time.perf_counter()
    f_al().block_until_ready()
    t_al = (time.perf_counter() - t0) * 1e6
    rows.append(("kernels/xdrop_align_256x800bp", t_al,
                 f"pairs_per_s={e2 / (t_al / 1e6):.0f}"))
    return rows
