"""Kernel-level microbenchmarks with a backend axis.

Rows:
  * ELL SpGEMM vs dense min-plus reference (algorithmic win of sparsity);
  * ``minplus_dense`` and ``xdrop_extend`` timed through the backend dispatch
    layer for each requested backend, so the reference-vs-Pallas speedup is
    measured rather than asserted.  On non-TPU hosts the Pallas backend runs
    in interpret mode — parity still exercised, no speedup expected.

Standalone: ``python -m benchmarks.bench_kernels --backend both``.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from ._timing import timed


def _resolve_backends(backend: str):
    from repro.core.backend import resolve_backend

    if backend == "both":
        return ("reference", "pallas")
    return (resolve_backend(backend),)


def run(backend: str = "both"):
    from repro.core.backend import dispatch, resolve_interpret
    from repro.core.semiring import minplus_orient_semiring as SR
    from repro.core.spmat import from_coo
    from repro.core.spgemm import spgemm
    from repro.assembly.alignment import batch_extend

    backends = _resolve_backends(backend)
    rows = []
    rng = np.random.default_rng(0)

    # --- ELL SpGEMM vs dense reference (sparsity win) ---
    n, deg = 1024, 8
    e = n * deg
    r_ = rng.integers(0, n, e); c_ = rng.integers(0, n, e)
    combos = rng.integers(0, 4, e)
    vals = np.full((e, 4), np.inf, np.float32)
    vals[np.arange(e), combos] = rng.integers(1, 500, e)
    mat, _ = from_coo(jnp.asarray(r_), jnp.asarray(c_), jnp.asarray(vals),
                      jnp.asarray(r_ != c_), n_rows=n, n_cols=n,
                      capacity=3 * deg, semiring=SR)
    t_spt = timed(
        # repro: noqa[R001] — benchmark: program built once per bench
        # config; timed() reports compile vs steady-state separately.
        jax.jit(lambda: spgemm(mat, mat, semiring=SR, capacity=64)[0].cols)
    )
    t_sp = t_spt.steady_us
    dense_ref = dispatch("minplus_dense", "reference")
    dense = mat.to_dense(SR)
    # repro: noqa[R001] — benchmark: jit built once per measurement.
    t_dt = timed(jax.jit(lambda: dense_ref(dense, dense)), reps=1)
    t_d = t_dt.steady_us
    rows.append(("kernels/ell_spgemm_minplus_n1024", t_sp,
                 f"dense_ref={t_d:.0f}us;sparse_speedup={t_d / t_sp:.1f}x",
                 t_spt.compile_us, t_spt.peak_hbm_bytes, t_spt.hbm_source))

    # --- minplus_dense backend axis ---
    m = 256
    a = jnp.asarray(np.where(rng.random((m, m, 4)) < 0.35,
                             rng.integers(1, 500, (m, m, 4)), np.inf),
                    jnp.float32)
    mp_times = {}
    for be in backends:
        f = dispatch("minplus_dense", be)
        # repro: noqa[R001] — benchmark: one jit per backend under test.
        t = timed(jax.jit(lambda f=f: f(a, a)))
        mp_times[be] = t.steady_us
        mode = ("interpret" if be == "pallas" and resolve_interpret("auto")
                else "compiled")
        rows.append((f"kernels/minplus_dense_{m}[{be}]", mp_times[be],
                     f"mode={mode}", t.compile_us, t.peak_hbm_bytes,
                     t.hbm_source))
    if len(mp_times) == 2:
        rows.append(("kernels/minplus_dense_speedup", 0.0,
                     f"ref/pallas={mp_times['reference'] / mp_times['pallas']:.2f}x",
                     0.0, t.peak_hbm_bytes, t.hbm_source))

    # --- xdrop_extend backend axis (seed-and-extend via batch_extend) ---
    e2, l = 128, 600
    ac = rng.integers(0, 4, (e2, l)).astype(np.uint8)
    bc = np.where(rng.random((e2, l)) < 0.05, (ac + 1) % 4, ac).astype(np.uint8)
    args = (jnp.asarray(ac), jnp.full(e2, l, jnp.int32), jnp.asarray(bc),
            jnp.full(e2, l, jnp.int32), jnp.zeros(e2, jnp.int32),
            jnp.zeros(e2, jnp.int32))
    xd_times = {}
    for be in backends:
        # repro: noqa[R001] — benchmark: one jit per backend under test.
        f = jax.jit(lambda be=be: batch_extend(
            *args, k=15, band=33, max_steps=1200, backend=be).score)
        t = timed(f)
        xd_times[be] = t.steady_us
        rows.append((f"kernels/xdrop_align_{e2}x{l}bp[{be}]", xd_times[be],
                     f"pairs_per_s={e2 / (xd_times[be] / 1e6):.0f}",
                     t.compile_us, t.peak_hbm_bytes, t.hbm_source))
    if len(xd_times) == 2:
        rows.append(("kernels/xdrop_align_speedup", 0.0,
                     f"ref/pallas={xd_times['reference'] / xd_times['pallas']:.2f}x",
                     0.0, t.peak_hbm_bytes, t.hbm_source))
    return rows


def main() -> None:
    import argparse

    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--backend", default="both",
                   choices=["reference", "pallas", "auto", "both"])
    ns = p.parse_args()
    print("name,us_per_call,derived")
    for name, us, derived, *_ in run(backend=ns.backend):
        print(f"{name},{us:.1f},{derived}", flush=True)


if __name__ == "__main__":
    main()
