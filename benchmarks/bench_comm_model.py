"""Table I reproduction: 1D vs 2D communication cost models.

Evaluates the paper's §V formulas with the measured dataset constants
(Table III/IV) across P = 64..16384 and locates the crossover where the 2D
algorithm wins — the paper's claim is 2D wins for "commonly utilized
concurrencies in the range of 100–10000 processors".
"""

from __future__ import annotations


# Table IV (H. sapiens): n reads, l read length; Table III densities.
DATASETS = {
    "H.sapiens": dict(n=4_421_600, l=7401, d=10, c=1207.7, r=1.3, a=4.0,
                      m=3_000_000_000 // 30),
    "C.elegans": dict(n=420_700, l=11_241, d=40, c=1579.7, r=8.1, a=4.0,
                      m=100_000_000 // 30),
}


def words_1d(ds, p):
    ov = ds["a"] ** 2 * ds["m"] / p  # overlap detection
    rx = ds["c"] * ds["n"] * ds["l"] / p  # read exchange
    return ov + rx


def words_2d(ds, p):
    sp = p ** 0.5
    ov = ds["a"] * ds["m"] / sp
    rx = 2 * ds["n"] * ds["l"] / sp
    tr = ds["r"] * ds["n"] / sp
    return ov + rx + tr


def run():
    rows = []
    for name, ds in DATASETS.items():
        crossover = None
        for p in (64, 128, 256, 512, 1024, 2048, 4096, 8192, 16384):
            w1, w2 = words_1d(ds, p), words_2d(ds, p)
            if w2 < w1 and crossover is None:
                crossover = p
            rows.append((f"comm_model/{name}/P{p}", 0.0,
                         f"W1D={w1:.3e};W2D={w2:.3e};2Dwins={w2 < w1}"))
        rows.append((f"comm_model/{name}/crossover", 0.0,
                     f"2D_wins_below_P={crossover}"))
    return rows
