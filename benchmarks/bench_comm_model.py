"""Table I reproduction: 1D vs 2D communication cost models, plus the
contig-stage doubling model (DESIGN.md §2.9, docs/communication.md).

Evaluates the paper's §V formulas with the measured dataset constants
(Table III/IV) across P = 64..16384 and locates the crossover where the 2D
algorithm wins — the paper's claim is 2D wins for "commonly utilized
concurrencies in the range of 100–10000 processors".

``words_contig_doubling`` is the analytic per-device exchange volume of the
shard_map contig doubling middle (core/components_dist.py): each round ring-
all-gathers 2n-state vectors at ``n·(P−1)/P`` words per vector, with
``rounds ≈ 3·(⌈log₂ 2n⌉+1)`` (one log term per phase: break_cycles,
path_components, chain_rank) and ≈3 gathers per round (the 2/4/2 per-phase
counts of ``components_dist.GATHERS_PER_ROUND``, mean 8/3, rounded up).
bench_contigs and bench_breakdown print the *measured* ``exchange_words``
stat next to this model so the two stay cross-checked.
"""

from __future__ import annotations

import math


# Table IV (H. sapiens): n reads, l read length; Table III densities.
DATASETS = {
    "H.sapiens": dict(n=4_421_600, l=7401, d=10, c=1207.7, r=1.3, a=4.0,
                      m=3_000_000_000 // 30),
    "C.elegans": dict(n=420_700, l=11_241, d=40, c=1579.7, r=8.1, a=4.0,
                      m=100_000_000 // 30),
}


def words_1d(ds, p):
    ov = ds["a"] ** 2 * ds["m"] / p  # overlap detection
    rx = ds["c"] * ds["n"] * ds["l"] / p  # read exchange
    return ov + rx


def words_2d(ds, p):
    sp = p ** 0.5
    ov = ds["a"] * ds["m"] / sp
    rx = 2 * ds["n"] * ds["l"] / sp
    tr = ds["r"] * ds["n"] / sp
    return ov + rx + tr


def words_contig_doubling(n_states, p, rounds=None):
    """Per-device words exchanged by the shard_map doubling middle: one ring
    all-gather (``n·(P−1)/P`` words) per gather-round.  ``rounds`` defaults
    to the analytic O(log n) total over the three doubling phases (the
    measured counterpart is ``ContigSet.stats['exchange_rounds']``)."""
    if rounds is None:
        log_rounds = max(1, math.ceil(math.log2(max(n_states, 2)))) + 1
        rounds = 3 * log_rounds  # break_cycles + path_components + chain_rank
    # gathers per round averaged over phases ≈ 3 (2 bc / 4 pc / 2 cr, see
    # components_dist.GATHERS_PER_ROUND — the model rounds the 8/3 mean up)
    return 3 * rounds * (n_states * (p - 1) // max(p, 1))


def run():
    rows = []
    for name, ds in DATASETS.items():
        for p in (4, 16, 64, 256):
            w = words_contig_doubling(2 * ds["n"], p)
            rows.append((f"comm_model/{name}/contig_doubling/P{p}", 0.0,
                         f"Wdoubling={w:.3e};scaling=(P-1)/P·log2n"))
        crossover = None
        for p in (64, 128, 256, 512, 1024, 2048, 4096, 8192, 16384):
            w1, w2 = words_1d(ds, p), words_2d(ds, p)
            if w2 < w1 and crossover is None:
                crossover = p
            rows.append((f"comm_model/{name}/P{p}", 0.0,
                         f"W1D={w1:.3e};W2D={w2:.3e};2Dwins={w2 < w1}"))
        rows.append((f"comm_model/{name}/crossover", 0.0,
                     f"2D_wins_below_P={crossover}"))
    return rows
