"""Table I reproduction: 1D vs 2D communication cost models, plus the
contig-stage exchange models (DESIGN.md §2.9/§2.10, docs/communication.md).

Evaluates the paper's §V formulas with the measured dataset constants
(Table III/IV) across P = 64..16384 and locates the crossover where the 2D
algorithm wins — the paper's claim is 2D wins for "commonly utilized
concurrencies in the range of 100–10000 processors".

``words_contig_doubling`` is the analytic per-device exchange volume of the
shard_map contig doubling middle (core/components_dist.py): each round ring-
all-gathers 2n-state vectors at ``n·(P−1)/P`` words per vector, with
``rounds ≈ 3·(⌈log₂ 2n⌉+1)`` (one log term per phase: break_cycles,
path_components, chain_rank) and ≈3 gathers per round (the 2/4/2 per-phase
counts of ``components_dist.GATHERS_PER_ROUND``, mean 8/3, rounded up).

``words_graph_cut`` and ``words_chain_sort`` model the two sub-stages PR 5
moved into the same shard_map region: the branch cut's single psum round (3
full-vector ring allreduces) and the ring-bitonic chain ordering (one
eligibility all-gather + ``log₂P·(log₂P+1)/2`` merge-split hops of the
3-word (labkey, rank, idx) sort triple).  Both are *data-independent* —
fixed by (n, P) alone — so the measured ``exchange_words_cut`` /
``exchange_words_sort`` stats must match these formulas exactly; the
formulas are deliberately re-derived here (not imported from
``components_dist``) so the benchmark cross-check is an independent model,
not an identity.  bench_contigs and bench_breakdown print the *measured*
stats next to these models, and the CI smoke artifact asserts the sort-term
agreement (``scripts/check_smoke_comm.py``).
"""

from __future__ import annotations

import math


# Table IV (H. sapiens): n reads, l read length; Table III densities.
DATASETS = {
    "H.sapiens": dict(n=4_421_600, l=7401, d=10, c=1207.7, r=1.3, a=4.0,
                      m=3_000_000_000 // 30),
    "C.elegans": dict(n=420_700, l=11_241, d=40, c=1579.7, r=8.1, a=4.0,
                      m=100_000_000 // 30),
}


def words_1d(ds, p):
    ov = ds["a"] ** 2 * ds["m"] / p  # overlap detection
    rx = ds["c"] * ds["n"] * ds["l"] / p  # read exchange
    return ov + rx


def words_2d(ds, p):
    sp = p ** 0.5
    ov = ds["a"] * ds["m"] / sp
    rx = 2 * ds["n"] * ds["l"] / sp
    tr = ds["r"] * ds["n"] / sp
    return ov + rx + tr


def words_contig_doubling(n_states, p, rounds=None):
    """Per-device words exchanged by the shard_map doubling middle: one ring
    all-gather (``n·(P−1)/P`` words) per gather-round.  ``rounds`` defaults
    to the analytic O(log n) total over the three doubling phases (the
    measured counterpart is ``ContigSet.stats['exchange_rounds']``)."""
    if rounds is None:
        log_rounds = max(1, math.ceil(math.log2(max(n_states, 2)))) + 1
        rounds = 3 * log_rounds  # break_cycles + path_components + chain_rank
    # gathers per round averaged over phases ≈ 3 (2 bc / 4 pc / 2 cr, see
    # components_dist.GATHERS_PER_ROUND — the model rounds the 8/3 mean up)
    return 3 * rounds * (n_states * (p - 1) // max(p, 1))


def _states_per_device(n_states, p):
    """Padded local state count: reads are padded to a multiple of P before
    sharding (core/components_dist.contig_stage_shard_map), so every device
    holds an even number of states — 2·⌈(n/2)/P⌉."""
    return 2 * (-(-(n_states // 2) // p))


def words_graph_cut(n_states, p):
    """Per-device words of the distributed branch cut's single psum round:
    3 full-vector ring allreduces (in-degree tally, pred scatter, in-suffix
    scatter), each a reduce-scatter + all-gather of ``n·(P−1)/P`` words."""
    if p <= 1:
        return 0
    return 3 * 2 * (_states_per_device(n_states, p) * (p - 1))


def words_chain_sort(n_states, p):
    """Per-device words of the ring-bitonic distributed chain ordering
    (DESIGN.md §2.10): one out-degree ring all-gather (``n·(P−1)/P`` words,
    chain-head eligibility) plus one merge-split hop per comparator stage of
    the sort network — ``log₂P·(log₂P+1)/2`` stages for power-of-two P
    (bitonic), ``P`` stages otherwise (odd-even transposition) — each
    shipping the local 3-word (labkey, rank, idx) block, ``3·n/P`` words.
    Data-independent: the network is fixed by P, so the measured
    ``exchange_words_sort`` stat must equal this exactly."""
    if p <= 1:
        return 0
    if p & (p - 1) == 0:
        lg = int(math.log2(p))
        stages = lg * (lg + 1) // 2
    else:
        stages = p
    n_loc = _states_per_device(n_states, p)
    return n_loc * (p - 1) + 3 * n_loc * stages


def words_summa(*, n_rows, a_block_slots, a_words_per_slot,
                m_rows, b_block_slots, b_words_per_slot, pr, pc):
    """Per-device words of the explicit-exchange ring SUMMA
    (``core.summa.summa_ring``): pc−1 rotations, each shipping the device's
    whole A panel (``n/pr`` rows × block slots) plus its whole B panel
    (``m/pr`` rows × block slots); a slot is the int32 column id + the value
    leaves behind it (``core.summa._slot_words``).  This is the paper's
    Table-I W = a·m/√P term with the dense ELL panel standing in for a·m/P
    per device and √P−1 ≈ √P stages.  Data-independent (the panels travel
    whole, occupied or not), so the measured ``exchange_words_summa`` stat
    must equal this exactly — ``scripts/check_smoke_comm.py`` asserts it."""
    if pc <= 1:
        return 0
    wa = (n_rows // pr) * a_block_slots * a_words_per_slot
    wb = (m_rows // pr) * b_block_slots * b_words_per_slot
    return (pc - 1) * (wa + wb)


def words_align(*, n_pad, row_width, bucket_pad, p):
    """Per-device words of the distributed x-drop extension
    (``core.align_dist.align_bucket_shard_map``): one ring all-gather of the
    padded read-code matrix (``(n/P)·(P−1)`` rows of ``row_width`` words —
    nested row axes telescope to the same total) plus one allreduce of the
    five stacked int32 ``PairAlignment`` outputs over the padded bucket
    (reduce-scatter + all-gather = ``2·(5·bucket/P)·(P−1)`` words).
    Data-independent — fixed by (n, L, bucket, P) alone — so the measured
    ``exchange_words_align`` stat must equal this exactly
    (``scripts/check_smoke_comm.py`` asserts it)."""
    if p <= 1:
        return 0
    return (row_width * (n_pad // p) * (p - 1)
            + 2 * 5 * (bucket_pad // p) * (p - 1))


def run():
    rows = []
    for name, ds in DATASETS.items():
        for p in (4, 16, 64, 256):
            w = words_contig_doubling(2 * ds["n"], p)
            rows.append((f"comm_model/{name}/contig_doubling/P{p}", 0.0,
                         f"Wdoubling={w:.3e};scaling=(P-1)/P·log2n"))
            wc = words_graph_cut(2 * ds["n"], p)
            ws = words_chain_sort(2 * ds["n"], p)
            rows.append((f"comm_model/{name}/chain_sort/P{p}", 0.0,
                         f"Wcut={wc:.3e};Wsort={ws:.3e};"
                         f"scaling=(P-1)/P+3·log2P·(log2P+1)/2/P"))
        crossover = None
        for p in (64, 128, 256, 512, 1024, 2048, 4096, 8192, 16384):
            w1, w2 = words_1d(ds, p), words_2d(ds, p)
            if w2 < w1 and crossover is None:
                crossover = p
            rows.append((f"comm_model/{name}/P{p}", 0.0,
                         f"W1D={w1:.3e};W2D={w2:.3e};2Dwins={w2 < w1}"))
        rows.append((f"comm_model/{name}/crossover", 0.0,
                     f"2D_wins_below_P={crossover}"))
    return rows
