"""Benchmark driver: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV (per the harness contract)."""

import sys


def main() -> None:
    from . import (
        bench_breakdown, bench_comm_model, bench_contigs, bench_kernels,
        bench_overlap, bench_scaling, bench_sparsity, bench_tr,
    )

    mods = [
        ("comm_model[TableI]", bench_comm_model),
        ("sparsity[TableIII]", bench_sparsity),
        ("tr[TableVI]", bench_tr),
        ("scaling[Fig4]", bench_scaling),
        ("breakdown[Fig5-8]", bench_breakdown),
        ("overlap[Fig9]", bench_overlap),
        ("kernels", bench_kernels),
        ("contigs", bench_contigs),
    ]
    print("name,us_per_call,derived")
    for label, mod in mods:
        try:
            for name, us, derived in mod.run():
                print(f"{name},{us:.1f},{derived}", flush=True)
        except Exception as exc:  # pragma: no cover
            print(f"{label}/ERROR,nan,{type(exc).__name__}:{exc}", flush=True)
            raise


if __name__ == "__main__":
    main()
