"""Benchmark driver: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (per the harness contract) and, with
``--json PATH``, also emits machine-readable per-benchmark records
``{name, op, backend, shape, ms, compile_ms, derived}`` so the perf
trajectory can be tracked across commits (CI uploads a smoke-size artifact
per run).  ``ms`` is steady-state wall-clock (post-warmup average,
``benchmarks/_timing.timed``); ``compile_ms`` is the separately measured
first call (compile + one execution) — rows from modules that have not
adopted the split omit the field.

``--snapshot`` is the legacy committed-artifact mode: it implies
``--smoke``, restricts to the snapshot module set (``_SNAPSHOT_ONLY``),
and writes ``BENCH_<n>.json`` at the repo root.  The per-PR snapshot
convention is superseded by the persistent experiment engine
(``benchmarks/engine.py`` + ``bench/trajectory.jsonl`` — README
"Experiment engine and the perf trajectory"); the committed ``BENCH_*``
files remain readable history for ``scripts/check_bench_regression.py``.

``--trace-dir DIR`` additionally runs one small traced pipeline
(``PipelineConfig.trace=True``, shard_map distribution) and writes the
Chrome-trace JSON to ``DIR/assemble_trace.json`` — open it in Perfetto /
``chrome://tracing``, or let ``scripts/check_trace.py`` assert its stage →
phase nesting (the CI smoke job uploads it as an artifact).

    python -m benchmarks.run [--only contigs,consensus] [--smoke]
                             [--json BENCH.json] [--snapshot]
                             [--trace-dir DIR]
"""

import argparse
import inspect
import json
import os
import re
import sys

# row names look like "op[backend]/shape"; backend and shape are optional
_NAME_RE = re.compile(r"^(?P<op>[^\[/]+)(?:\[(?P<backend>[^\]]+)\])?"
                      r"(?:/(?P<shape>.*))?$")

# reduced-size kwargs per module for the CI smoke run (only passed when the
# module's run() accepts them).  contigs keeps both distribution rows so the
# uploaded artifact tracks the gspmd-vs-shard_map trajectory (§2.9).
_SMOKE = {
    "contigs": {"sweep": (256,), "distributions": ("gspmd", "shard_map")},
    "consensus": {"sweep": (256,)},
    "scaling": {"sweep": (256,)},
    # ring-SUMMA rows only: the local Fig-9 variants are too slow for CI, and
    # check_smoke_comm.py needs the measured-vs-model exchange_words_summa row.
    "overlap": {"distributions": ("shard_map",), "genome": 4_000},
}

# module keys included in a --snapshot run (per-op wall-clock + exchange
# words at smoke size; the rest of the suite is full-size only)
_SNAPSHOT_ONLY = ("contigs", "consensus", "overlap")

# committed snapshot artifact for this PR sequence (bumped per perf PR)
_SNAPSHOT_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_7.json")


def _modules():
    from . import (
        bench_breakdown, bench_comm_model, bench_consensus, bench_contigs,
        bench_kernels, bench_overlap, bench_scaling, bench_sparsity,
        bench_tr,
    )

    return [
        ("comm_model[TableI]", bench_comm_model),
        ("sparsity[TableIII]", bench_sparsity),
        ("tr[TableVI]", bench_tr),
        ("scaling[Fig4]", bench_scaling),
        ("breakdown[Fig5-8]", bench_breakdown),
        ("overlap[Fig9]", bench_overlap),
        ("kernels", bench_kernels),
        ("contigs", bench_contigs),
        ("consensus", bench_consensus),
    ]


def _record(name, us, derived, compile_us=None, peak_hbm_bytes=None,
            hbm_source=None):
    m = _NAME_RE.match(name)
    rec = {
        "name": name,
        "op": m.group("op") if m else name,
        "backend": m.group("backend") if m else None,
        "shape": m.group("shape") if m else None,
        "ms": us / 1e3,
        "derived": str(derived),
    }
    if compile_us is not None:
        rec["compile_ms"] = compile_us / 1e3
    if peak_hbm_bytes is not None:
        rec["peak_hbm_bytes"] = int(peak_hbm_bytes)
    if hbm_source is not None:
        rec["hbm_source"] = hbm_source
    return rec


def _write_trace(trace_dir: str) -> str:
    """Run one small traced pipeline and export its Chrome trace.

    Uses the shard_map distribution so the trace exercises the explicit-
    exchange phases (ring SUMMA stages, contig chain stage) — the nesting
    ``scripts/check_trace.py`` asserts.  Prints the span tree to stderr
    (``bench_breakdown.render_span_tree``) and returns the JSON path."""
    import numpy as np

    from repro.assembly.pipeline import PipelineConfig, assemble
    from repro.assembly.simulate import simulate_genome, simulate_reads
    from repro.obs import write_chrome_trace

    from .bench_breakdown import render_span_tree

    rng = np.random.default_rng(9)
    g = simulate_genome(rng, 4_000)
    rs = simulate_reads(g, depth=10, mean_len=600, std_len=80,
                        error_rate=0.03, seed=10)
    # backend="pallas" is load-bearing: "auto" resolves to the reference
    # backend off-TPU, whose contig path is the host walk — no shard_map
    # chain stage, so the cut/doubling/sort phase spans check_trace.py
    # asserts would never be traced
    cfg = PipelineConfig(m_capacity=1 << 16, upper=48, read_capacity=128,
                         overlap_capacity=48, r_capacity=32, band=33,
                         max_steps=2048, align_chunk=8192,
                         backend="pallas", distribution="shard_map",
                         trace=True)
    res = assemble(rs.codes, rs.lengths, cfg)
    os.makedirs(trace_dir, exist_ok=True)
    path = os.path.join(trace_dir, "assemble_trace.json")
    write_chrome_trace(res.trace, path)
    print(render_span_tree(res.trace), file=sys.stderr)
    print(f"# wrote Chrome trace to {path}", file=sys.stderr)
    return path


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write per-benchmark JSON records to PATH")
    ap.add_argument("--only", default=None,
                    help="comma-separated module keys (e.g. contigs,consensus)")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sizes for CI (see _SMOKE)")
    ap.add_argument("--snapshot", action="store_true",
                    help="write the committed smoke snapshot "
                         f"({os.path.basename(_SNAPSHOT_PATH)}); implies "
                         "--smoke and restricts to " + ",".join(_SNAPSHOT_ONLY))
    ap.add_argument("--trace-dir", default=None, metavar="DIR",
                    help="also run one traced pipeline and write its Chrome "
                         "trace JSON to DIR/assemble_trace.json")
    ns = ap.parse_args(argv)
    if ns.snapshot:
        ns.smoke = True
        if ns.only is None:
            ns.only = ",".join(_SNAPSHOT_ONLY)
        if ns.json is None:
            ns.json = _SNAPSHOT_PATH
    mods = _modules()
    only = set(ns.only.split(",")) if ns.only else None
    if only is not None:
        known = {label.split("[")[0] for label, _ in mods}
        unknown = only - known
        if unknown:
            ap.error(f"unknown --only keys {sorted(unknown)}; "
                     f"known: {sorted(known)}")

    records = []
    print("name,us_per_call,derived")
    try:
        for label, mod in mods:
            key = label.split("[")[0]
            if only is not None and key not in only:
                continue
            kwargs = {}
            if ns.smoke:
                accepted = inspect.signature(mod.run).parameters
                kwargs = {k: v for k, v in _SMOKE.get(key, {}).items()
                          if k in accepted}
            try:
                # per-module watermark backfills rows from modules that do
                # not time through _timing.timed (analytic tables, the
                # breakdown driver) so every record carries peak_hbm_bytes
                from repro.obs import watermark

                module_records = []
                with watermark() as wm:
                    for name, us, derived, *extra in mod.run(**kwargs):
                        print(f"{name},{us:.1f},{derived}", flush=True)
                        module_records.append(_record(
                            name, us, derived,
                            compile_us=extra[0] if extra else None,
                            peak_hbm_bytes=(extra[1] if len(extra) > 1
                                            else None),
                            hbm_source=(extra[2] if len(extra) > 2
                                        else None),
                        ))
                for rec in module_records:
                    rec.setdefault("peak_hbm_bytes", wm.peak_hbm_bytes)
                    rec.setdefault("hbm_source", wm.source)
                records.extend(module_records)
            except Exception as exc:  # pragma: no cover
                print(f"{label}/ERROR,nan,{type(exc).__name__}:{exc}",
                      flush=True)
                raise
        if ns.trace_dir:
            _write_trace(ns.trace_dir)
    finally:
        # keep the partial trajectory even when a late module dies
        if ns.json:
            with open(ns.json, "w") as f:
                json.dump(records, f, indent=1)
            print(f"# wrote {len(records)} records to {ns.json}",
                  file=sys.stderr)


if __name__ == "__main__":
    main()
