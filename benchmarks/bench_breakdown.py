"""Fig. 5-8 analogue: per-stage runtime breakdown of the pipeline
(CountKmer / CreateSpMat / SpGEMM / Alignment / BuildR / TrReduction /
Contigs / Consensus), with a backend axis: the reference row set uses the jnp oracles
and the host contig walk, the pallas row set routes the hot ops (x-drop
extension, min-plus squares) through the Pallas kernels via the dispatch
layer (compiled on TPU, interpret elsewhere) and runs the device contig
path (DESIGN.md §2.7).

With ``--distribution shard_map`` (or ``both``) an extra pipeline run uses
the explicit-exchange contig doubling (§2.9) and emits a ``contig_comm``
row: measured per-device/per-round exchange volume next to the analytic
model from ``bench_comm_model.words_contig_doubling`` — plus an
``align_comm`` row for the distributed x-drop extension (§2.12): measured
``exchange_words_align`` next to ``bench_comm_model.words_align``.

Standalone: ``python -m benchmarks.bench_breakdown --backend pallas
--distribution both``.
"""

from __future__ import annotations

import numpy as np


def render_span_tree(tracer, max_depth: int = 4) -> str:
    """Render an ``obs.Tracer``'s span forest as an indented text tree.

    One line per span — ``name [attrs] ms`` — children indented under their
    parent, depth-capped at ``max_depth``.  This is the human-readable twin
    of the Chrome trace export (``obs.write_chrome_trace``): the breakdown
    benchmark prints it so a ``--trace`` run shows the stage → shard_map
    phase → kernel-launch nesting without opening Perfetto."""
    lines = []

    def _fmt(sp, depth):
        if depth > max_depth:
            return
        attrs = {k: v for k, v in sp.attrs.items() if k != "kind"}
        att = (" [" + ", ".join(f"{k}={v}" for k, v in attrs.items()) + "]"
               if attrs else "")
        lines.append(f"{'  ' * depth}{sp.name}{att} {sp.duration_ms:.2f}ms")
        for child in sp.children:
            _fmt(child, depth + 1)

    for root in tracer.roots:
        _fmt(root, 0)
    return "\n".join(lines)


def run(backends=("reference", "pallas"), distributions=("gspmd",)):
    from repro.assembly.pipeline import PipelineConfig, assemble
    from repro.assembly.simulate import simulate_genome, simulate_reads

    rng = np.random.default_rng(9)
    g = simulate_genome(rng, 10_000)
    rs = simulate_reads(g, depth=12, mean_len=900, std_len=120,
                        error_rate=0.03, seed=10)
    rows = []
    for backend in backends:
        cfg = PipelineConfig(m_capacity=1 << 16, upper=48, read_capacity=128,
                             overlap_capacity=48, r_capacity=32, band=33,
                             max_steps=2048, align_chunk=8192, backend=backend)
        res = assemble(rs.codes, rs.lengths, cfg)
        total = sum(res.timings.values())
        live = res.stats["n_aligned"]
        cand = res.stats["align_candidates"]
        rows.extend(
            (f"breakdown[{backend}]/{k}", v * 1e6,
             f"frac={v / total:.3f};live_pairs={live}/{cand}")
            for k, v in res.timings.items()
        )
        rows.append(
            (f"breakdown[{backend}]/tr_stats",
             res.timings["TrReduction"] * 1e6,
             # tr_backend is the kernel path that actually ran — the fused
             # TR downgrades pallas→reference above TR_DENSE_MAX_ROWS, and
             # this row is where that must stay visible
             f"iters={res.stats['tr_iterations']};"
             f"tr_backend={res.stats['tr_backend']};"
             f"n_overflow={res.stats['tr_overflow']};"
             f"nnz_S={res.stats['nnz_S']}")
        )
        cs = res.stats["contigs"]
        rows.append(
            (f"breakdown[{backend}]/contig_stats",
             res.timings["Contigs"] * 1e6,
             f"n={cs['n_contigs']};n50={cs['n50']};l50={cs['l50']};"
             f"mean={cs['mean_length']:.0f};"
             f"branch_cut={res.stats['n_branch_cut']};"
             f"cc_iters={res.stats['cc_iterations']}")
        )
        rows.append(
            (f"breakdown[{backend}]/consensus_stats",
             res.timings["Consensus"] * 1e6,
             f"depth_mean={res.stats['consensus_depth_mean']:.2f};"
             f"identity_est={res.stats['identity_estimate']:.4f};"
             f"qv_est={res.stats['qv_estimate']:.1f};"
             f"changed={res.stats['consensus_changed']};"
             f"junction_shifts={res.stats['n_junction_shifted']}")
        )

    if "shard_map" in distributions:
        # §2.9 communication check: explicit-exchange contig doubling,
        # measured per-device exchange volume vs the analytic model
        import jax

        from .bench_comm_model import (
            words_align, words_chain_sort, words_contig_doubling,
            words_graph_cut,
        )

        cfg = PipelineConfig(m_capacity=1 << 16, upper=48, read_capacity=128,
                             overlap_capacity=48, r_capacity=32, band=33,
                             max_steps=2048, align_chunk=8192,
                             backend="pallas", distribution="shard_map")
        res = assemble(rs.codes, rs.lengths, cfg)
        p = len(jax.devices())
        n_states = 2 * res.stats["n_reads"]
        measured = res.stats["exchange_words"]
        rounds = res.stats["exchange_rounds"]
        dbl_rounds = res.stats["exchange_rounds_doubling"]
        model = words_contig_doubling(n_states, p, dbl_rounds)
        per_round = measured // max(rounds, 1)
        rows.append(
            (f"breakdown[pallas/shard_map]/contig_comm",
             res.timings["Contigs"] * 1e6,
             f"P={p};rounds={rounds};exchange_words={measured};"
             f"words_per_round={per_round};model_words={model};"
             f"model_words_logn={words_contig_doubling(n_states, p)};"
             f"exchange_words_cut={res.stats['exchange_words_cut']};"
             f"model_words_cut={words_graph_cut(n_states, p)};"
             f"exchange_words_sort={res.stats['exchange_words_sort']};"
             f"model_words_sort={words_chain_sort(n_states, p)}")
        )
        # §2.12 communication check: distributed x-drop extension, measured
        # per-device gather/scatter volume vs the analytic model (the
        # pipeline ran it on the default 1D row mesh over all devices)
        n_reads = res.stats["n_reads"]
        bucket = res.stats["align_bucket"]
        n_pad = -(-n_reads // p) * p
        bucket_pad = -(-bucket // p) * p
        wm_align = words_align(n_pad=n_pad, row_width=rs.codes.shape[1],
                               bucket_pad=bucket_pad, p=p)
        rows.append(
            (f"breakdown[pallas/shard_map]/align_comm",
             res.timings["Alignment"] * 1e6,
             f"P={p};bucket={bucket};"
             f"exchange_words_align={res.stats['exchange_words_align']};"
             f"model_words_align={wm_align};"
             f"exchange_rounds_align={res.stats['exchange_rounds_align']};"
             f"n_passed={res.stats['n_passed']}")
        )
    return rows


def main() -> None:
    """CLI entry point (CSV on stdout)."""
    import argparse

    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--backend", default="both",
                   choices=["reference", "pallas", "both"])
    p.add_argument("--distribution", default="gspmd",
                   choices=["gspmd", "shard_map", "both"])
    p.add_argument("--trace", action="store_true",
                   help="run one traced pipeline and print its span tree "
                        "(stage -> phase -> kernel) to stderr")
    ns = p.parse_args()
    backends = (("reference", "pallas") if ns.backend == "both"
                else (ns.backend,))
    dists = (("gspmd", "shard_map") if ns.distribution == "both"
             else (ns.distribution,))
    print("name,us_per_call,derived")
    for name, us, derived in run(backends=backends, distributions=dists):
        print(f"{name},{us:.1f},{derived}", flush=True)
    if ns.trace:
        import sys

        from repro.assembly.pipeline import PipelineConfig, assemble
        from repro.assembly.simulate import simulate_genome, simulate_reads

        rng = np.random.default_rng(9)
        g = simulate_genome(rng, 10_000)
        rs = simulate_reads(g, depth=12, mean_len=900, std_len=120,
                            error_rate=0.03, seed=10)
        cfg = PipelineConfig(m_capacity=1 << 16, upper=48, read_capacity=128,
                             overlap_capacity=48, r_capacity=32, band=33,
                             max_steps=2048, align_chunk=8192,
                             backend="pallas", distribution="shard_map",
                             trace=True)
        res = assemble(rs.codes, rs.lengths, cfg)
        print(render_span_tree(res.trace), file=sys.stderr)


if __name__ == "__main__":
    main()
