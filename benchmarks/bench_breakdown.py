"""Fig. 5-8 analogue: per-stage runtime breakdown of the pipeline
(CountKmer / CreateSpMat / SpGEMM / Alignment / BuildR / TrReduction)."""

from __future__ import annotations

import numpy as np


def run():
    from repro.assembly.pipeline import PipelineConfig, assemble
    from repro.assembly.simulate import simulate_genome, simulate_reads

    rng = np.random.default_rng(9)
    g = simulate_genome(rng, 10_000)
    rs = simulate_reads(g, depth=12, mean_len=900, std_len=120,
                        error_rate=0.03, seed=10)
    cfg = PipelineConfig(m_capacity=1 << 16, upper=48, read_capacity=128,
                         overlap_capacity=48, r_capacity=32, band=33,
                         max_steps=2048, align_chunk=8192)
    res = assemble(rs.codes, rs.lengths, cfg)
    total = sum(res.timings.values())
    return [
        (f"breakdown/{k}", v * 1e6, f"frac={v / total:.3f}")
        for k, v in res.timings.items()
    ]
