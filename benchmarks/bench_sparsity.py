"""Table III analogue: measured sparsity statistics (c, r, s densities and
the c/2d overlapper-inefficiency factor) on a simulated dataset."""

from __future__ import annotations

import numpy as np

from ._timing import timed


def run(genome=9_000, depth=14):
    """One end-to-end assemble (timed via :func:`benchmarks._timing.timed`
    with ``reps=1`` — the pipeline is the unit here, not a kernel) plus
    derived density rows; the timing row carries the compile/steady split
    and the HBM watermark like every other record."""
    from repro.assembly.pipeline import PipelineConfig, assemble
    from repro.assembly.simulate import simulate_genome, simulate_reads

    rng = np.random.default_rng(5)
    g = simulate_genome(rng, genome)
    rs = simulate_reads(g, depth=depth, mean_len=1000, std_len=150,
                        error_rate=0.04, seed=6)
    cfg = PipelineConfig(m_capacity=1 << 16, upper=56, read_capacity=128,
                         overlap_capacity=64, r_capacity=32, band=33,
                         max_steps=2048, align_chunk=8192)
    t = timed(lambda: assemble(rs.codes, rs.lengths, cfg),
              out_of=lambda r: r.s_graph.cols, reps=1)
    res = t.result
    s = res.stats
    d = rs.depth
    # derived-statistic rows time nothing themselves (us == 0.0): their
    # compile is 0 by construction, but they share the run's watermark
    mem = (0.0, t.peak_hbm_bytes, t.hbm_source)
    rows = [
        ("sparsity/c_density", t.steady_us, f"{s['c_density']:.2f}",
         t.compile_us, t.peak_hbm_bytes, t.hbm_source),
        ("sparsity/r_density", 0.0, f"{s['r_density']:.3f}", *mem),
        ("sparsity/s_density", 0.0, f"{s['s_density']:.3f}", *mem),
        ("sparsity/inefficiency_c_over_2d", 0.0,
         f"{s['c_density'] / (2 * d):.3f}", *mem),
        ("sparsity/contained_frac", 0.0,
         f"{s['n_contained'] / s['n_reads']:.3f}", *mem),
    ]
    return rows
