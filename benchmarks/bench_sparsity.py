"""Table III analogue: measured sparsity statistics (c, r, s densities and
the c/2d overlapper-inefficiency factor) on a simulated dataset."""

from __future__ import annotations

import time

import numpy as np


def run():
    from repro.assembly.pipeline import PipelineConfig, assemble
    from repro.assembly.simulate import simulate_genome, simulate_reads

    rng = np.random.default_rng(5)
    g = simulate_genome(rng, 9_000)
    rs = simulate_reads(g, depth=14, mean_len=1000, std_len=150,
                        error_rate=0.04, seed=6)
    cfg = PipelineConfig(m_capacity=1 << 16, upper=56, read_capacity=128,
                         overlap_capacity=64, r_capacity=32, band=33,
                         max_steps=2048, align_chunk=8192)
    t0 = time.perf_counter()
    res = assemble(rs.codes, rs.lengths, cfg)
    dt = (time.perf_counter() - t0) * 1e6
    s = res.stats
    d = rs.depth
    rows = [
        ("sparsity/c_density", dt, f"{s['c_density']:.2f}"),
        ("sparsity/r_density", 0.0, f"{s['r_density']:.3f}"),
        ("sparsity/s_density", 0.0, f"{s['s_density']:.3f}"),
        ("sparsity/inefficiency_c_over_2d", 0.0,
         f"{s['c_density'] / (2 * d):.3f}"),
        ("sparsity/contained_frac", 0.0,
         f"{s['n_contained'] / s['n_reads']:.3f}"),
    ]
    return rows
