"""Consensus-stage race: jnp scatter-add oracle (reference) vs banded Pallas
pileup kernel (DESIGN.md §2.8), timed through the dispatch layer.

Inputs are synthesized through the device contig path on chain-structured
string graphs whose reads are *genome-consistent* (each read really is a
slice of one synthetic genome, plus 2% substitution errors), so overlapping
reads pass the vote-coherence gate and the sweep exercises the full pileup
depth, not just the writer's self-vote.

Standalone: ``python -m benchmarks.bench_consensus --backend pallas``.
"""

from __future__ import annotations

import numpy as np

from ._timing import timed


def run(backends=("reference", "pallas"), sweep=(256, 1024, 4096)):
    from repro.assembly.consensus import polish_contig_set
    from repro.assembly.contig_gen import (
        consistent_chain_graph, generate_contigs,
    )

    rows = []
    for n in sweep:
        s, codes, lengths, _ = consistent_chain_graph(
            n, seed=n, err=0.02, break_every=64
        )
        cset = generate_contigs(s, codes, lengths, backend="pallas")
        base = None
        for backend in backends:
            def f():
                return polish_contig_set(
                    cset, codes, lengths, backend=backend, min_depth=2
                )

            t = timed(f, out_of=lambda r: r.codes)
            cres, us = t.result, t.steady_us
            if backend == "reference":
                base = us
            derived = (
                f"n_contigs={cres.n_contigs};"
                f"depth_mean={cres.stats['consensus_depth_mean']:.2f};"
                f"identity_est={cres.stats['identity_estimate']:.4f}"
            )
            if base is not None and backend != "reference":
                derived += f";speedup_vs_reference={base / us:.1f}x"
            rows.append((f"consensus[{backend}]/n{n}", us, derived,
                         t.compile_us, t.peak_hbm_bytes, t.hbm_source))
    return rows


def main() -> None:
    import argparse

    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--backend", default="both",
                   choices=["reference", "pallas", "both"])
    ns = p.parse_args()
    backends = (("reference", "pallas") if ns.backend == "both"
                else (ns.backend,))
    print("name,us_per_call,derived")
    for name, us, derived, *_ in run(backends=backends):
        print(f"{name},{us:.1f},{derived}", flush=True)


if __name__ == "__main__":
    main()
