"""Fig. 4 analogue: strong scaling of the distributed TR across host-device
counts (subprocess per device count — jax locks the device count at init).
A CPU-host proxy for the paper's node scaling; the roofline table in
EXPERIMENTS.md §Roofline carries the production-mesh story.  Each
subprocess reports the compile/steady split and its HBM watermark, so the
scaling rows carry the same record fields as every other module."""

from __future__ import annotations

import os
import subprocess
import sys

_SNIPPET = """
import time
import numpy as np, jax, jax.numpy as jnp
from repro.core.semiring import minplus_orient_semiring as SR
from repro.core.spmat import from_coo
from repro.core.summa import distribute_ell, dist_transitive_reduction
from repro.launch.mesh import make_test_mesh
from repro.obs import watermark

shape = {mesh_shape}
mesh = make_test_mesh(shape)
rng = np.random.default_rng(0)
n, deg = {n}, 8
e = n * deg
rows = rng.integers(0, n, e); cols = rng.integers(0, n, e)
combos = rng.integers(0, 4, e)
suf = rng.integers(1, 500, e).astype(np.float32)
vals = np.full((e, 4), np.inf, np.float32)
vals[np.arange(e), combos] = suf
ok = rows != cols
Rd, _ = distribute_ell(jnp.asarray(rows), jnp.asarray(cols),
                       jnp.asarray(vals), jnp.asarray(ok), n_rows=n,
                       n_cols=n, block_capacity=3 * deg, semiring=SR,
                       mesh=mesh)
with watermark() as wm:
    t0 = time.perf_counter()
    out, it, nnz = dist_transitive_reduction(Rd, fuzz=100.0, fused=True)
    nnz.block_until_ready()
    compile_us = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    for _ in range(3):
        out, it, nnz = dist_transitive_reduction(Rd, fuzz=100.0, fused=True)
        nnz.block_until_ready()
    steady_us = (time.perf_counter() - t0) / 3 * 1e6
print(f"{{steady_us}} {{compile_us}} {{wm.peak_hbm_bytes}} {{wm.source}}")
"""


def run(shapes=((1, 1), (2, 1), (2, 2)), n=4096):
    """One subprocess per mesh shape; rows report steady-state wall-clock,
    parallel efficiency vs the P=1 base, and the per-subprocess compile
    time + HBM watermark parsed from the child's stdout."""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    rows = []
    base = None
    for shape in shapes:
        nd = shape[0] * shape[1]
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={nd}"
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        r = subprocess.run(
            [sys.executable, "-c", _SNIPPET.format(mesh_shape=shape, n=n)],
            capture_output=True, text=True, env=env, timeout=560,
        )
        if r.returncode != 0:
            rows.append((f"scaling/P{nd}", float("nan"), "FAILED", 0.0, 0,
                         "live_buffers"))
            continue
        parts = r.stdout.strip().splitlines()[-1].split()
        us, compile_us = float(parts[0]), float(parts[1])
        peak, source = int(parts[2]), parts[3]
        if base is None:
            base = us
        rows.append((f"scaling/P{nd}", us,
                     f"efficiency={base / (us * nd):.2f}", compile_us,
                     peak, source))
    return rows
