"""Persistent experiment engine CLI: cached runs + perf/memory trajectory.

The incremental benchmark driver over the declarative registry in
:mod:`repro.obs.experiments` (rtl-experiments ``framework.py`` style).
Experiment ids fold in a **code fingerprint** (``benchmarks/`` +
``src/repro`` sources, jax version, device count), so an untouched tree
re-runs for free from ``.bench_cache/`` and any relevant edit invalidates
exactly the affected entries.  Every fresh run appends its records to the
append-only trajectory store ``bench/trajectory.jsonl`` (one line per
experiment row per code snapshot — the successor of the one-file-per-PR
``BENCH_<n>.json`` convention; old snapshots stay readable as history).
All records carry ``ms``, ``compile_ms`` and ``peak_hbm_bytes``.

Verbs::

    python benchmarks/engine.py todo  [--smoke] [--check-empty]
    python benchmarks/engine.py run   [--smoke] [--only contigs,tr] [--force]
                                      [--json ALL.json] [--delta FRESH.json]
    python benchmarks/engine.py report
    python benchmarks/engine.py csv

``todo`` lists pending (uncached-at-this-fingerprint) experiments;
``--check-empty`` exits 1 when any are pending — the CI cache-hit gate runs
it immediately after ``run`` and requires zero.  ``run`` executes only the
pending set (cache hits are served instantly), so a second ``run --smoke``
in an unchanged tree is pure cache reads.  ``report`` summarizes cache
state per experiment; ``csv`` dumps every cached record.
"""

from __future__ import annotations

import argparse
import csv as _csv
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

if __package__ in (None, ""):  # `python benchmarks/engine.py ...`
    sys.path.insert(0, _ROOT)
    sys.path.insert(0, os.path.join(_ROOT, "src"))

try:
    import repro  # noqa: F401  (PYTHONPATH=src already set)
except ImportError:  # pragma: no cover - module-form without PYTHONPATH
    sys.path.insert(0, os.path.join(_ROOT, "src"))

from repro.obs.experiments import (  # noqa: E402
    Experiment,
    ExperimentEngine,
    code_fingerprint,
)

#: sources the experiment ids depend on: any edit here re-runs the suite.
_FINGERPRINT_PATHS = (
    os.path.join(_ROOT, "benchmarks"),
    os.path.join(_ROOT, "src", "repro"),
)

_CACHE_DIR = os.path.join(_ROOT, ".bench_cache")
_TRAJECTORY = os.path.join(_ROOT, "bench", "trajectory.jsonl")


def experiments(smoke: bool) -> list:
    """The declarative experiment registry (one entry per module × axis).

    The smoke set is the CI grid: reduced sizes, both backends, both
    distributions where the axis exists.  The full set mirrors the paper
    table/figure sizes of ``benchmarks/run.py``."""
    if smoke:
        return [
            Experiment("contigs",
                       {"sweep": (256,),
                        "backends": ("reference", "pallas"),
                        "distributions": ("gspmd",)},
                       {"distribution": "gspmd"}),
            Experiment("contigs",
                       {"sweep": (256,), "backends": ("pallas",),
                        "distributions": ("shard_map",)},
                       {"distribution": "shard_map"}),
            Experiment("consensus", {"sweep": (256,)}, {}),
            Experiment("tr", {"sweep": (256,)}, {}),
            Experiment("kernels", {"backend": "both"},
                       {"backend": "both"}),
            Experiment("overlap",
                       {"distributions": ("shard_map",), "genome": 4_000},
                       {"distribution": "shard_map"}),
        ]
    return [
        Experiment("contigs",
                   {"sweep": (256, 1024, 4096),
                    "backends": ("reference", "pallas"),
                    "distributions": ("gspmd", "shard_map")},
                   {"distribution": "both"}),
        Experiment("consensus", {"sweep": (256, 1024, 4096)}, {}),
        Experiment("tr", {}, {}),
        Experiment("kernels", {"backend": "both"}, {"backend": "both"}),
        Experiment("sparsity", {}, {}),
        Experiment("overlap",
                   {"distributions": ("local", "shard_map")},
                   {"distribution": "both"}),
        Experiment("scaling", {}, {}),
    ]


def _run_experiment(exp: Experiment) -> list:
    """Runner: execute one bench module and normalize its rows to records.

    Reuses ``benchmarks.run._record`` (same name/op/backend/shape parsing
    as the legacy snapshot path) and backfills memory columns from a
    module-level watermark for rows that do not time through
    ``_timing.timed`` — a record without ``compile_ms`` still fails
    validation loudly in the engine."""
    import importlib

    from repro.obs import watermark

    from benchmarks.run import _record

    mod = importlib.import_module(f"benchmarks.bench_{exp.module}")
    records = []
    with watermark() as wm:
        for name, us, derived, *extra in mod.run(**dict(exp.kwargs)):
            records.append(_record(
                name, us, derived,
                compile_us=extra[0] if extra else None,
                peak_hbm_bytes=extra[1] if len(extra) > 1 else None,
                hbm_source=extra[2] if len(extra) > 2 else None,
            ))
    for rec in records:
        rec.setdefault("peak_hbm_bytes", wm.peak_hbm_bytes)
        rec.setdefault("hbm_source", wm.source)
        rec["experiment"] = exp.label
    return records


def make_engine(smoke: bool, *, cache_dir: str = _CACHE_DIR,
                trajectory: str = _TRAJECTORY) -> ExperimentEngine:
    """Build the engine over the registry at the current code fingerprint
    (sources + jax version + device count — topology changes the shard_map
    rows, so it is part of the cache key)."""
    import jax

    # paths hash relative to the repo root, so the fingerprint (and with it
    # every experiment id and trajectory dedup key) agrees across checkouts
    fp = code_fingerprint(_FINGERPRINT_PATHS, root=_ROOT)
    fingerprint = f"{fp}-jax{jax.__version__}-d{jax.device_count()}"
    return ExperimentEngine(
        experiments(smoke), _run_experiment,
        cache_dir=cache_dir, trajectory_path=trajectory,
        fingerprint=fingerprint,
    )


def main(argv=None) -> int:
    """Dispatch one engine verb; returns the process exit status."""
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("verb", choices=["todo", "run", "report", "csv"])
    ap.add_argument("--smoke", action="store_true",
                    help="CI-size experiment set (reduced sweeps)")
    ap.add_argument("--only", default=None,
                    help="comma-separated module keys (run verb)")
    ap.add_argument("--force", action="store_true",
                    help="re-run even on cache hits (run verb)")
    ap.add_argument("--check-empty", action="store_true",
                    help="todo: exit 1 when any experiment is pending "
                         "(the CI cache-hit gate)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="run: write ALL records of this invocation "
                         "(cache hits included) to PATH")
    ap.add_argument("--delta", default=None, metavar="PATH",
                    help="run: write only the freshly-run records to PATH "
                         "(the trajectory delta artifact)")
    ap.add_argument("--cache-dir", default=_CACHE_DIR)
    ap.add_argument("--trajectory", default=_TRAJECTORY)
    ns = ap.parse_args(argv)

    eng = make_engine(ns.smoke, cache_dir=ns.cache_dir,
                      trajectory=ns.trajectory)

    if ns.verb == "todo":
        pending = eng.todo()
        for exp in pending:
            print(f"pending {exp.label} ({eng.id_of(exp)})")
        print(f"{len(pending)} pending experiment(s) "
              f"[fingerprint {eng.fingerprint}]")
        return 1 if (ns.check_empty and pending) else 0

    if ns.verb == "run":
        import json

        only = set(ns.only.split(",")) if ns.only else None
        if only is not None:
            known = {e.module for e in eng.experiments}
            unknown = only - known
            if unknown:
                ap.error(f"unknown --only keys {sorted(unknown)}; "
                         f"known: {sorted(known)}")
        out = eng.run(only=only, force=ns.force,
                      log=lambda msg: print(msg, flush=True))
        print("name,ms,compile_ms,peak_hbm_bytes,derived")
        for rec in out["records"]:
            print(f"{rec['name']},{rec['ms']:.3f},{rec['compile_ms']:.1f},"
                  f"{rec['peak_hbm_bytes']},{rec['derived']}", flush=True)
        print(f"# {len(out['ran'])} run, {len(out['hits'])} cache hit(s), "
              f"{out['wall_s']:.1f}s wall", file=sys.stderr)
        if ns.json:
            with open(ns.json, "w") as f:
                json.dump(out["records"], f, indent=1)
        if ns.delta:
            with open(ns.delta, "w") as f:
                json.dump(out["fresh_records"], f, indent=1)
        return 0

    if ns.verb == "report":
        for row in eng.report_rows():
            wall = "-" if row["wall_s"] is None else f"{row['wall_s']:.1f}s"
            print(f"{row['state']:8s} {row['experiment']:40s} "
                  f"{row['records']:3d} record(s)  {wall}  {row['id']}")
        pending = len(eng.todo())
        print(f"# {len(eng.experiments) - pending} cached, "
              f"{pending} pending", file=sys.stderr)
        return 0

    if ns.verb == "csv":
        w = _csv.writer(sys.stdout)
        for row in eng.csv_rows():
            w.writerow(row)
        return 0

    return 2  # pragma: no cover - argparse restricts the verbs


if __name__ == "__main__":
    sys.exit(main())
