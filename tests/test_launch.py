"""Launch-layer units: HLO collective parser, roofline math, train resume."""

import numpy as np
import pytest

from repro.launch.hlo_analysis import collective_bytes
from repro.launch.roofline import RooflineTerms, model_flops, roofline_fraction


HLO_SAMPLE = """
ENTRY %main () -> f32[] {
  %ag = f32[16,1024]{1,0} all-gather(%x), channel_id=1
  %ar = bf16[8,8]{1,0} all-reduce(%y), metadata={op_name="jit(f)/while/body/foo"}
  %cp-start = f32[4]{0} collective-permute-start(%z), channel_id=3
  %cp-done = f32[4]{0} collective-permute-done(%cp-start)
  %rs = f32[2,2]{1,0} reduce-scatter(%w), channel_id=4
}
"""


def test_collective_parser():
    r = collective_bytes(HLO_SAMPLE, default_loop_trips=10)
    assert r["by_op"]["all-gather"] == 16 * 1024 * 4
    assert r["by_op"]["all-reduce"] == 8 * 8 * 2 * 10  # inside while → ×10
    assert r["by_op"]["collective-permute"] == 16  # -start counted, -done not
    assert r["by_op"]["reduce-scatter"] == 16
    assert r["static_bytes"] == 16 * 1024 * 4 + 128 + 16 + 16
    assert r["total_bytes_tpu_estimate"] <= r["total_bytes"]


def test_roofline_terms():
    t = RooflineTerms(
        arch="x", shape="train_4k", mesh="single", chips=256,
        flops_per_device=197e12,  # exactly 1 second of compute
        bytes_per_device=819e9,  # exactly 1 second of HBM
        collective_bytes_per_device=50e9 * 4 * 2,  # 2 s of ICI
        model_flops_global=197e12 * 256,
    ).finalize()
    assert abs(t.compute_s - 1.0) < 1e-9
    assert abs(t.memory_s - 1.0) < 1e-9
    assert abs(t.collective_s - 2.0) < 1e-9
    assert t.bottleneck == "collective"
    assert abs(roofline_fraction(t) - 0.5) < 1e-9


def test_model_flops():
    class C:
        def active_param_count(self):
            return 1_000_000

    assert model_flops(C(), "train", 10, 2) == 6e6 * 20
    assert model_flops(C(), "decode", 9999, 4) == 2e6 * 4


@pytest.mark.slow  # two train runs + checkpoint restore: ~10s
def test_train_resume_determinism(tmp_path):
    """Restart-from-checkpoint reproduces the uninterrupted run exactly
    (deterministic data pipeline + checkpointed state)."""
    from repro.launch.train import main

    full = main([
        "--arch", "qwen3-4b", "--reduced", "--steps", "8", "--batch", "4",
        "--seq", "32", "--log-every", "100",
    ])
    part1 = main([
        "--arch", "qwen3-4b", "--reduced", "--steps", "5", "--batch", "4",
        "--seq", "32", "--ckpt-dir", str(tmp_path), "--ckpt-every", "5",
        "--log-every", "100",
    ])
    part2 = main([
        "--arch", "qwen3-4b", "--reduced", "--steps", "8", "--batch", "4",
        "--seq", "32", "--ckpt-dir", str(tmp_path), "--resume",
        "--log-every", "100",
    ])
    np.testing.assert_allclose(part2[-1], full[-1], rtol=1e-4)
