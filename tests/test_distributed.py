"""Distributed (multi-device) tests: SUMMA vs local, distributed TR, elastic
resharding.  Each runs in a subprocess with fake host devices (jax locks the
device count at first init)."""

import pytest

from _dist_helpers import run_with_devices

pytestmark = pytest.mark.dist  # deselect quickly with -m "not dist"

SETUP = """
import numpy as np, jax, jax.numpy as jnp
from repro.core.semiring import minplus_orient_semiring as SR
from repro.core.spmat import from_coo
from repro.core.spgemm import spgemm
from repro.core.summa import (
    distribute_ell, summa_allgather, summa_ring, collect,
    dist_transitive_reduction,
)
from repro.core.transitive_reduction import transitive_reduction
from repro.core.myers_baseline import from_ell, graphs_equal
from repro.launch.mesh import make_test_mesh

mesh = make_test_mesh((2, 2))
rng = np.random.default_rng(0)
n, E = 16, 60
rows = rng.integers(0, n, E); cols = rng.integers(0, n, E)
ok = rows != cols
combos = rng.integers(0, 4, E); suf = rng.integers(1, 100, E).astype(np.float32)
vals = np.full((E, 4), np.inf, np.float32)
vals[np.arange(E), combos] = suf
args = tuple(map(jnp.asarray, (rows, cols, vals, ok)))
R, _ = from_coo(*args, n_rows=n, n_cols=n, capacity=8, semiring=SR)
Rd, ovfd = distribute_ell(*args, n_rows=n, n_cols=n, block_capacity=8,
                          semiring=SR, mesh=mesh)
assert int(ovfd) == 0
"""


def test_summa_allgather_matches_local():
    run_with_devices(SETUP + """
Cr, _ = spgemm(R, R, semiring=SR, capacity=32)
Cd, _ = summa_allgather(Rd, Rd, semiring=SR, out_block_capacity=16)
assert graphs_equal(from_ell(collect(Cd)), from_ell(Cr))
print("OK")
""")


def test_summa_ring_matches_local():
    run_with_devices(SETUP + """
Cr, _ = spgemm(R, R, semiring=SR, capacity=32)
Cd, _, st = summa_ring(Rd, Rd, semiring=SR, out_block_capacity=16)
assert graphs_equal(from_ell(collect(Cd)), from_ell(Cr))
assert st["summa_algorithm"] == "ring"
assert st["exchange_words_summa"] > 0
print("OK")
""")


@pytest.mark.parametrize("fused", [False, True])
def test_dist_tr_matches_local(fused):
    run_with_devices(SETUP + f"""
S, st = transitive_reduction(R, fuzz=50.0, n_capacity=64)
Sd, iters, nnzf = dist_transitive_reduction(Rd, fuzz=50.0, fused={fused})
assert graphs_equal(from_ell(collect(Sd)), from_ell(S))
assert int(nnzf) == int(S.nnz())
print("OK")
""")


def test_multipod_row_axes():
    """(pod, data, model) mesh: grid rows span pod×data."""
    run_with_devices("""
import numpy as np, jax, jax.numpy as jnp
from repro.core.semiring import minplus_orient_semiring as SR
from repro.core.spmat import from_coo
from repro.core.spgemm import spgemm
from repro.core.summa import distribute_ell, summa_allgather, collect
from repro.core.myers_baseline import from_ell, graphs_equal
from repro.launch.mesh import make_test_mesh

mesh = make_test_mesh((2, 2, 2), ("pod", "data", "model"))
rng = np.random.default_rng(1)
n, E = 16, 50
rows = rng.integers(0, n, E); cols = rng.integers(0, n, E)
ok = rows != cols
combos = rng.integers(0, 4, E); suf = rng.integers(1, 100, E).astype(np.float32)
vals = np.full((E, 4), np.inf, np.float32)
vals[np.arange(E), combos] = suf
args = tuple(map(jnp.asarray, (rows, cols, vals, ok)))
R, _ = from_coo(*args, n_rows=n, n_cols=n, capacity=8, semiring=SR)
Rd, _ = distribute_ell(*args, n_rows=n, n_cols=n, block_capacity=8,
                       semiring=SR, mesh=mesh, row_axes=("pod", "data"))
Cr, _ = spgemm(R, R, semiring=SR, capacity=32)
Cd, _ = summa_allgather(Rd, Rd, semiring=SR, out_block_capacity=16)
assert graphs_equal(from_ell(collect(Cd)), from_ell(Cr))
print("OK")
""", n_devices=8)


def test_contigs_generated_on_mesh():
    """Device-side contig generation without leaving the mesh: the string
    matrix (and read tensors) stay sharded over a 2×2 mesh while the jitted
    components/chain/gather stages run SPMD; results must equal the host
    walk on the gathered matrix."""
    run_with_devices("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.assembly.contig_gen import (
    generate_contigs, string_matrix_from_edges,
)
from repro.core.spmat import EllMatrix
from repro.launch.mesh import make_test_mesh

mesh = make_test_mesh((2, 2))
n = 16
edges = []
for i in range(n - 1):
    edges.append((i, i + 1, 0, 0, 30))
    edges.append((i + 1, i, 1, 1, 30))
edges += [(3, 9, 0, 0, 12), (12, 5, 1, 0, 11)]  # branches
S = string_matrix_from_edges(n, edges)
rng = np.random.default_rng(0)
codes = jnp.asarray(rng.integers(0, 4, (n, 128)), jnp.uint8)
lengths = jnp.full((n,), 100, jnp.int32)

ref = generate_contigs(S, codes, lengths, backend="reference")

row = NamedSharding(mesh, P("data"))
Sd = EllMatrix(
    cols=jax.device_put(S.cols, row),
    vals=jax.device_put(S.vals, row),
    n_cols=S.n_cols,
)
dev = generate_contigs(
    Sd, jax.device_put(codes, row), jax.device_put(lengths, row),
    backend="pallas",
)
rc, dc = ref.to_contigs(), dev.to_contigs()
assert ref.n_contigs == dev.n_contigs
for a, b in zip(rc, dc):
    assert a.reads == b.reads and a.length == b.length
    assert np.array_equal(a.codes, b.codes)
assert ref.stats["n_branch_cut"] == dev.stats["n_branch_cut"]
print("OK", dev.n_contigs)
""")


def test_contigs_shard_map_matches_gspmd_and_reference():
    """Distribution-axis parity (DESIGN.md §2.9): on mesh-sharded inputs the
    shard_map doubling middle (explicit ppermute/psum exchanges) must produce
    a bit-identical ContigSet to the GSPMD auto-sharded path — same padded
    tensors, same path_components iteration count — and both must match the
    host-walk reference contig-by-contig.  Also checks the exchange
    accounting is live (nonzero words on a P>1 row axis)."""
    run_with_devices("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.assembly.contig_gen import (
    generate_contigs, string_matrix_from_edges,
)
from repro.core.spmat import EllMatrix
from repro.launch.mesh import make_test_mesh

mesh = make_test_mesh((2, 2))
n = 24
edges = []
for i in range(n - 1):
    if i % 9 != 8:  # several chains
        edges.append((i, i + 1, 0, 0, 30))
        edges.append((i + 1, i, 1, 1, 30))
edges += [(3, 9, 0, 0, 12), (12, 5, 1, 0, 11)]   # branches
edges += [(21, 18, 0, 0, 7), (18, 21, 1, 1, 7)]  # extra cycle edges
S = string_matrix_from_edges(n, edges)
rng = np.random.default_rng(0)
codes = jnp.asarray(rng.integers(0, 4, (n, 128)), jnp.uint8)
lengths = jnp.full((n,), 100, jnp.int32)

ref = generate_contigs(S, codes, lengths, backend="reference")

row = NamedSharding(mesh, P("data"))
Sd = EllMatrix(
    cols=jax.device_put(S.cols, row),
    vals=jax.device_put(S.vals, row),
    n_cols=S.n_cols,
)
cd, ld = jax.device_put(codes, row), jax.device_put(lengths, row)
gs = generate_contigs(Sd, cd, ld, backend="pallas", distribution="gspmd")
sm = generate_contigs(Sd, cd, ld, backend="pallas",
                      distribution="shard_map", mesh=mesh)

# bit-identical ContigSet tensors across the distribution axis
for k in ("codes", "lengths", "states", "offsets", "widths"):
    assert np.array_equal(np.asarray(getattr(gs, k)),
                          np.asarray(getattr(sm, k))), k
assert gs.n_contigs == sm.n_contigs
assert gs.stats["n_branch_cut"] == sm.stats["n_branch_cut"]
assert gs.stats["cc_iterations"] == sm.stats["cc_iterations"]
assert sm.stats["exchange_words"] > 0 and sm.stats["exchange_rounds"] > 0

# ...and contig-by-contig parity with the host walk
rc, dc = ref.to_contigs(), sm.to_contigs()
assert ref.n_contigs == sm.n_contigs
for a, b in zip(rc, dc):
    assert a.reads == b.reads and a.length == b.length
    assert np.array_equal(a.codes, b.codes)
print("OK", sm.n_contigs, sm.stats["exchange_words"])
""")


def test_doubling_shard_map_matches_local_on_multipod_axes():
    """The doubling middle itself on a (pod, data, model) mesh: labels,
    heads, ranks and the cycle-cut pointers must equal the local
    implementations for row_axes spanning pod×data (the runtime/sharding.py
    grid-row convention), including an odd length that forces padding."""
    run_with_devices("""
import numpy as np, jax, jax.numpy as jnp
from repro.core.components import break_cycles, chain_rank, path_components
from repro.core.components_dist import doubling_shard_map, infer_row_axes
from repro.launch.mesh import make_test_mesh

mesh = make_test_mesh((2, 2, 2), ("pod", "data", "model"))
assert infer_row_axes(mesh) == ("pod", "data")
rng = np.random.default_rng(3)
n = 53  # odd: exercises the pad-to-multiple-of-P path
perm = rng.permutation(n)
succ = np.full(n, -1, np.int32); pred = np.full(n, -1, np.int32)
for i in range(n - 1):
    if i % 11 == 10:
        continue  # chain break
    succ[perm[i]] = perm[i + 1]; pred[perm[i + 1]] = perm[i]
# close a cycle over the last chain segment
succ[perm[n - 1]] = perm[44]; pred[perm[44]] = perm[n - 1]
succ_j, pred_j = jnp.asarray(succ), jnp.asarray(pred)

s2, p2, n_cut = break_cycles(succ_j, pred_j)
labels, cc_iters = path_components(s2, p2)
head, rank, _ = chain_rank(p2)

d = doubling_shard_map(succ_j, pred_j, mesh=mesh)
assert np.array_equal(np.asarray(d["succ"]), np.asarray(s2))
assert np.array_equal(np.asarray(d["pred"]), np.asarray(p2))
assert np.array_equal(np.asarray(d["labels"]), np.asarray(labels))
assert np.array_equal(np.asarray(d["head"]), np.asarray(head))
assert np.array_equal(np.asarray(d["rank"]), np.asarray(rank))
assert int(d["n_cut"]) == int(n_cut)
assert int(d["cc_iterations"]) == int(cc_iters)
assert d["exchange_words"] > 0
print("OK", int(d["cc_iterations"]), d["exchange_words"])
""", n_devices=8)


def test_elastic_reshard():
    """Train state saved on a 2×2 mesh restores and resharding onto 4×1."""
    run_with_devices("""
import numpy as np, jax, jax.numpy as jnp
from repro.configs import reduced_config
from repro.models.model import init_params
from repro.optim import AdamW
from repro.runtime.elastic import reshard_state
from repro.runtime.sharding import apply_sharding_rules
from repro.launch.mesh import make_test_mesh

cfg = reduced_config("qwen3-4b")
m1 = make_test_mesh((2, 2))
params = init_params(cfg, jax.random.PRNGKey(0))
params = jax.device_put(params, apply_sharding_rules(params, m1))
opt = AdamW()
state = (params, opt.init(params), jnp.int32(7))
m2 = make_test_mesh((4, 1))
state2 = reshard_state(state, m2)
for a, b in zip(jax.tree.leaves(state[0]), jax.tree.leaves(state2[0])):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
assert int(state2[2]) == 7
print("OK")
""")


@pytest.mark.slow  # heavyweight mesh parametrization (MoE dispatch): ~6s on top of dist
def test_moe_shardmap_matches_gspmd_dispatch():
    run_with_devices("""
import numpy as np, jax, jax.numpy as jnp, dataclasses
from repro.configs import reduced_config
from repro.models.model import init_params, loss_fn
from repro.launch.mesh import make_test_mesh

mesh = make_test_mesh((2, 2))
cfg = reduced_config("granite-moe-1b-a400m")
params = init_params(cfg, jax.random.PRNGKey(0))
b = {"tokens": jnp.arange(2 * 32).reshape(2, 32) % 100 + 1,
     "labels": jnp.ones((2, 32), jnp.int32)}
l_sm = float(loss_fn(params, b, dataclasses.replace(cfg, moe_impl="shardmap"),
                     mesh=mesh))
l_gs = float(loss_fn(params, b, dataclasses.replace(cfg, moe_impl="gspmd"),
                     mesh=None))
# capacity dropping is implementation-defined: local (per-shard)
# vs global dispatch order drop different overflow tokens
assert abs(l_sm - l_gs) < 0.2, (l_sm, l_gs)
print("OK", l_sm, l_gs)
""")


def test_contig_stage_shard_map_end_to_end_parity():
    """End-to-end shard_map contig stage (DESIGN.md §2.10): branch cut,
    doubling and ring-bitonic chain ordering all inside one shard_map region
    must produce a bit-identical ContigSet to the GSPMD path — including
    odd-n read padding — and match the host walk contig-by-contig.  The
    per-phase exchange accounting must be live (cut/doubling/sort all
    nonzero on a P>1 row axis), sum to the total, and the data-independent
    sort term must equal the analytic model exactly."""
    import os

    root = os.path.join(os.path.dirname(__file__), "..")
    run_with_devices(f"""
import sys
sys.path.insert(0, {root!r})
import numpy as np, jax, jax.numpy as jnp
from repro.assembly.contig_gen import (
    generate_contigs, string_matrix_from_edges,
)
from repro.core.components_dist import infer_row_axes
from repro.launch.mesh import make_test_mesh
from benchmarks.bench_comm_model import words_chain_sort, words_graph_cut

mesh = make_test_mesh((2, 2))
n = 23  # odd: forces the pad-to-multiple-of-P read path
rng = np.random.default_rng(0)
edges = []
for i in range(n - 1):
    if i % 7 != 6:  # several chains
        edges.append((i, i + 1, 0, 0, 30))
        edges.append((i + 1, i, 1, 1, 30))
edges += [(3, 9, 0, 0, 12), (12, 5, 1, 0, 11)]   # branches
edges += [(21, 18, 0, 0, 7), (18, 21, 1, 1, 7)]  # extra cycle edges
S = string_matrix_from_edges(n, edges)
codes = jnp.asarray(rng.integers(0, 4, (n, 128)), jnp.uint8)
lengths = jnp.asarray(rng.integers(80, 120, n), jnp.int32)

ref = generate_contigs(S, codes, lengths, backend="reference")
gs = generate_contigs(S, codes, lengths, backend="pallas",
                      distribution="gspmd")
sm = generate_contigs(S, codes, lengths, backend="pallas",
                      distribution="shard_map", mesh=mesh)

for k in ("codes", "lengths", "states", "offsets", "widths"):
    assert np.array_equal(np.asarray(getattr(gs, k)),
                          np.asarray(getattr(sm, k))), k
assert gs.n_contigs == sm.n_contigs
assert gs.stats["n_branch_cut"] == sm.stats["n_branch_cut"]
assert gs.stats["cc_iterations"] == sm.stats["cc_iterations"]

# per-phase exchange accounting: live, additive, and the data-independent
# terms equal the independent analytic model
st = sm.stats
assert st["exchange_words_cut"] > 0
assert st["exchange_words_doubling"] > 0
assert st["exchange_words_sort"] > 0
assert st["exchange_words"] == (st["exchange_words_cut"]
                                + st["exchange_words_doubling"]
                                + st["exchange_words_sort"])
p = 1
for a in infer_row_axes(mesh):
    p *= mesh.shape[a]
assert st["exchange_words_sort"] == words_chain_sort(2 * n, p)
assert st["exchange_words_cut"] == words_graph_cut(2 * n, p)
# the gspmd path reports the same keys, present-and-zero
for k, v in gs.stats.items():
    if k.startswith("exchange_"):
        assert v == 0, (k, v)

rc, dc = ref.to_contigs(), sm.to_contigs()
assert ref.n_contigs == sm.n_contigs
for a, b in zip(rc, dc):
    assert a.reads == b.reads and a.length == b.length
    assert np.array_equal(a.codes, b.codes)
print("OK", sm.n_contigs, st["exchange_words"])
""")


def test_contig_stage_matches_doubling_composition_on_multipod():
    """Golden parity of the single-region contig stage against the PR 4
    composition (GSPMD graph cut → shard_map doubling middle → GSPMD chain
    ordering) on a (pod, data, model) mesh with row_axes spanning pod×data:
    every chain-state array — sorted state permutation, eligibility, ranks,
    chain indices, suffix/edge vectors — must be bit-identical, odd n
    included."""
    run_with_devices("""
import numpy as np, jax, jax.numpy as jnp
from repro.assembly.contig_gen import (
    _graph_cut, _order_chains, string_matrix_from_edges,
)
from repro.core.components_dist import (
    contig_stage_shard_map, doubling_shard_map, infer_row_axes,
)
from repro.launch.mesh import make_test_mesh

mesh = make_test_mesh((2, 2, 2), ("pod", "data", "model"))
assert infer_row_axes(mesh) == ("pod", "data")
n = 53  # odd: pad path on a P=4 row grid
rng = np.random.default_rng(3)
edges = []
for i in range(n - 1):
    if i % 11 != 10:
        edges.append((i, i + 1, 0, 0, 25))
        edges.append((i + 1, i, 1, 1, 25))
edges += [(5, 20, 0, 0, 9), (33, 12, 1, 0, 8)]   # branches
edges += [(50, 44, 0, 0, 6), (44, 50, 1, 1, 6)]  # cycle edges
S = string_matrix_from_edges(n, edges)

# PR 4 composition: GSPMD cut -> shard_map doubling -> GSPMD ordering
cut = _graph_cut(S)
d = doubling_shard_map(cut["succ0"], cut["pred0"], mesh=mesh)
dbl = {k: d[k] for k in ("labels", "head", "rank")}
dbl["cc_iterations"] = d["cc_iterations"]
st_old = _order_chains(cut, dbl)

# PR 5: everything in one shard_map region
st_new, xstats = contig_stage_shard_map(S, mesh=mesh)

for k in ("state_s", "elig_s", "rank_s", "chain_idx_s", "new_chain",
          "insuf", "has_edge"):
    assert np.array_equal(np.asarray(st_old[k]), np.asarray(st_new[k])), k
for k in ("n_chains", "max_chain", "n_branch_cut", "cc_iterations"):
    assert int(st_old[k]) == int(st_new[k]), k
assert xstats["exchange_words_sort"] > 0
print("OK", int(st_new["n_chains"]), xstats["exchange_words"])
""", n_devices=8)
