"""Algorithm 2 vs the sequential Myers oracle + structural properties."""

import numpy as np
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st

from repro.core.semiring import minplus_orient_semiring as SR
from repro.core.spmat import from_coo
from repro.core.myers_baseline import (
    dense_square_transitive_reduction,
    from_ell,
    graphs_equal,
    myers_transitive_reduction,
)
from repro.core.transitive_reduction import (
    transitive_reduction,
    transitive_reduction_fused,
)


def _rand_graph(seed, n=20, e=80, symmetric=True):
    rng = np.random.default_rng(seed)
    rows = rng.integers(0, n, e)
    cols = rng.integers(0, n, e)
    combos = rng.integers(0, 4, e)
    suf = rng.integers(1, 200, e).astype(np.float32)
    if symmetric:
        # complement edges (paper §II: both strands walkable)
        r2 = cols.copy(); c2 = rows.copy()
        cb2 = 2 * (1 - combos % 2) + (1 - combos // 2)
        s2 = rng.integers(1, 200, e).astype(np.float32)
        rows = np.concatenate([rows, r2]); cols = np.concatenate([cols, c2])
        combos = np.concatenate([combos, cb2]); suf = np.concatenate([suf, s2])
    ok = rows != cols
    e2 = len(rows)
    vals = np.full((e2, 4), np.inf, np.float32)
    vals[np.arange(e2), combos] = suf
    mat, _ = from_coo(
        jnp.asarray(rows), jnp.asarray(cols), jnp.asarray(vals),
        jnp.asarray(ok), n_rows=n, n_cols=n, capacity=2 * e // n + 8,
        semiring=SR,
    )
    return mat, n


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([20.0, 100.0]))
def test_tr_matches_myers_oracle(seed, fuzz):
    r, n = _rand_graph(seed)
    s, stats = transitive_reduction(r, fuzz=fuzz, n_capacity=r.capacity ** 2)
    oracle, _ = myers_transitive_reduction(from_ell(r), fuzz=fuzz)
    assert graphs_equal(from_ell(s), oracle)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_fused_equals_faithful(seed):
    r, n = _rand_graph(seed)
    s1, _ = transitive_reduction(r, fuzz=50.0, n_capacity=r.capacity ** 2)
    s2, _ = transitive_reduction_fused(r, fuzz=50.0)
    assert graphs_equal(from_ell(s1), from_ell(s2))


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000))
def test_dense_square_baseline_agrees(seed):
    r, n = _rand_graph(seed, n=14, e=40)
    s, _ = transitive_reduction(r, fuzz=50.0, n_capacity=r.capacity ** 2)
    dense, _ = dense_square_transitive_reduction(from_ell(r), n, fuzz=50.0)
    assert graphs_equal(from_ell(s), dense)


def test_chain_graph_is_fixed_point():
    # a linear chain has no transitive edges: TR must not remove anything
    n = 10
    rows, cols, vals = [], [], []
    for i in range(n - 1):
        rows += [i, i + 1]
        cols += [i + 1, i]
        v1 = np.full(4, np.inf, np.float32); v1[0] = 50
        v2 = np.full(4, np.inf, np.float32); v2[3] = 50
        vals += [v1, v2]
    mat, _ = from_coo(
        jnp.asarray(rows), jnp.asarray(cols), jnp.asarray(np.stack(vals)),
        jnp.ones(len(rows), bool), n_rows=n, n_cols=n, capacity=4,
        semiring=SR,
    )
    s, stats = transitive_reduction_fused(mat, fuzz=10.0)
    assert int(s.nnz()) == int(mat.nnz())


def test_triangle_removes_long_edge():
    # 0→1 (5), 1→2 (7), 0→2 (12): 0→2 is transitive
    def mp(s_, a, b):
        v = np.full(4, np.inf, np.float32); v[2 * a + b] = s_; return v
    rows = jnp.asarray([0, 1, 0]); cols = jnp.asarray([1, 2, 2])
    vals = jnp.asarray(np.stack([mp(5, 0, 0), mp(7, 0, 0), mp(12, 0, 0)]))
    mat, _ = from_coo(rows, cols, vals, jnp.ones(3, bool), n_rows=3,
                      n_cols=3, capacity=4, semiring=SR)
    s, stats = transitive_reduction_fused(mat, fuzz=1.0)
    assert int(s.nnz()) == 2
    assert from_ell(s).keys() == {(0, 1), (1, 2)}


def test_orientation_blocks_reduction():
    # middle-node strands inconsistent: 0→2 must SURVIVE
    def mp(s_, a, b):
        v = np.full(4, np.inf, np.float32); v[2 * a + b] = s_; return v
    rows = jnp.asarray([0, 1, 0]); cols = jnp.asarray([1, 2, 2])
    vals = jnp.asarray(np.stack([mp(5, 0, 0), mp(7, 1, 0), mp(12, 0, 0)]))
    mat, _ = from_coo(rows, cols, vals, jnp.ones(3, bool), n_rows=3,
                      n_cols=3, capacity=4, semiring=SR)
    s, _ = transitive_reduction_fused(mat, fuzz=1.0)
    assert (0, 2) in from_ell(s)


def test_faithful_overflow_reported_when_fused_diverges():
    """Bugfix guard (PR 5): when the faithful path's N = R² capacity
    overflows it can lose min-candidates and diverge from the fused/sampled
    square — that divergence must be *reported* via ``TRStats.n_overflow``,
    never silent.  The fused path cannot overflow by construction."""
    r, _ = _rand_graph(0)
    s_faith, st_faith = transitive_reduction(r, fuzz=50.0, n_capacity=2)
    s_fused, st_fused = transitive_reduction_fused(r, fuzz=50.0)
    assert not graphs_equal(from_ell(s_faith), from_ell(s_fused))
    assert int(st_faith.n_overflow) > 0  # the divergence is accounted for
    assert int(st_fused.n_overflow) == 0
    # ...and with enough capacity the two agree and nothing overflows
    s_ok, st_ok = transitive_reduction(r, fuzz=50.0,
                                       n_capacity=r.capacity ** 2)
    assert int(st_ok.n_overflow) == 0
    assert graphs_equal(from_ell(s_ok), from_ell(s_fused))


def test_fused_records_backend_actually_used():
    """Bugfix guard (PR 5): ``transitive_reduction_fused`` silently
    downgrades ``backend="pallas"`` to the sampled ELL square when
    ``n > TR_DENSE_MAX_ROWS``; ``TRStats.backend`` must record the path
    that actually ran so benchmark rows cannot mislabel the kernel path."""
    from repro.core.transitive_reduction import TR_DENSE_MAX_ROWS

    r_small, _ = _rand_graph(1)
    _, st_small = transitive_reduction_fused(r_small, fuzz=50.0,
                                             backend="pallas")
    assert st_small.backend == "pallas"
    _, st_ref = transitive_reduction_fused(r_small, fuzz=50.0,
                                           backend="reference")
    assert st_ref.backend == "reference"

    n_big = TR_DENSE_MAX_ROWS + 4
    rows = jnp.arange(8, dtype=jnp.int32)
    cols = rows + 1
    vals = np.full((8, 4), np.inf, np.float32)
    vals[:, 0] = 10.0
    r_big, _ = from_coo(rows, cols, jnp.asarray(vals),
                        jnp.ones(8, bool), n_rows=n_big, n_cols=n_big,
                        capacity=4, semiring=SR)
    _, st_big = transitive_reduction_fused(r_big, fuzz=50.0,
                                           backend="pallas")
    assert st_big.backend == "reference"  # downgrade recorded, not silent
    # the faithful path ignores the knob by contract and says so
    _, st_faith = transitive_reduction(r_small, fuzz=50.0,
                                       backend="pallas")
    assert st_faith.backend == "reference"
