"""Bloom filter: no false negatives (the invariant that matters)."""

import numpy as np
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st

from repro.assembly.bloom import BloomFilter


@settings(max_examples=20, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 2**30 - 1), st.integers(0, 2**30 - 1)),
                min_size=1, max_size=100))
def test_no_false_negatives(items):
    bf = BloomFilter.create(4096, n_hashes=3)
    hi = jnp.asarray([x[0] for x in items], jnp.int32)
    lo = jnp.asarray([x[1] for x in items], jnp.int32)
    bf = bf.insert(hi, lo, jnp.ones(len(items), bool))
    assert bool(jnp.all(bf.query(hi, lo)))


def test_false_positive_rate_sane(rng):
    bf = BloomFilter.create(1 << 14, n_hashes=3)
    n = 500
    hi = jnp.asarray(rng.integers(0, 2**30, n), jnp.int32)
    lo = jnp.asarray(rng.integers(0, 2**30, n), jnp.int32)
    bf = bf.insert(hi, lo, jnp.ones(n, bool))
    other_hi = jnp.asarray(rng.integers(0, 2**30, 2000), jnp.int32)
    other_lo = jnp.asarray(rng.integers(0, 2**30, 2000) + 2**30, jnp.int32)
    fp = float(jnp.mean(bf.query(other_hi, other_lo)))
    assert fp < 0.15


def test_invalid_not_inserted():
    bf = BloomFilter.create(256, 2)
    bf = bf.insert(jnp.asarray([5]), jnp.asarray([7]), jnp.asarray([False]))
    assert not bool(bf.query(jnp.asarray([5]), jnp.asarray([7]))[0])
