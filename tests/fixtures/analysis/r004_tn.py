"""R004 true negatives: registered keys and declared groups only.

``exchange_words_summa`` and the ``summa_exchange`` group are declared in
``obs/schema.py``; dynamic (non-literal) keys are out of static scope by
design.  No findings expected.
"""


def report(metrics, n, dynamic_key):
    """Emit only registered names."""
    metrics.emit("exchange_words_summa", n)
    metrics.emit_many({"exchange_rounds_summa": 1})
    metrics.seed_zero("summa_exchange")
    metrics.emit(dynamic_key, n)  # dynamic: validated at run time instead
