"""R006 true negatives: the sanctioned span usage.

``sp.set_output(...)`` is the span's own sync-on-exit path; host reads
belong after the block; non-phase spans (kind="op") have no async
schedule to protect.  No findings expected.
"""

import numpy as np

from repro.obs.trace import span


def ring_phase(run, out):
    """Phase body that defers every host read to the span exit."""
    with span("SpGEMM", kind="phase", phase="ring_stage") as sp:
        out = run(out)
        sp.set_output(out)
    return np.asarray(out)


def kernel_launch(run, x):
    """op spans measure a synchronous launch: host reads are fine."""
    with span("spgemm", kind="op"):
        y = run(x)
        y.block_until_ready()
    return y
