"""R003 true negatives: both sanctioned accounting conventions.

The trace-time ``acct`` increment next to the collective (the
``summa._ring_program`` convention) and the analytic
``exchange_words_*`` model call in the enclosing scope (the
``components_dist`` convention).  No findings expected.
"""

import jax


def exchange_words_fixture(n, p):
    """Analytic model helper: words per device for the fixture schedule."""
    return n * (p - 1) // p


def rotate_counted(x, axis, perm, acct, words):
    """The acct-dict convention: count next to the ppermute."""
    acct["words"] += words
    acct["rounds"] += 1
    return jax.lax.ppermute(x, axis, perm)


def gather_modeled(x, axis, perm, n, p, stats):
    """The analytic convention: the model call covers the schedule."""
    stats["exchange_words_fixture"] = exchange_words_fixture(n, p)
    return jax.lax.ppermute(x, axis, perm)
