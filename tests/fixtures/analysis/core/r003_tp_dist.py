"""R003 true positive: a collective with no exchange accounting.

A ``lax.ppermute`` in an explicit-exchange module (``core/*_dist.py``
scope) whose enclosing function chain neither increments an ``acct``
accumulator nor calls an analytic ``exchange_words_*`` model.  One
finding expected, anchored at the ppermute.
"""

import jax


def rotate_unaccounted(x, axis, perm):
    """Move a panel without telling the comm model."""
    return jax.lax.ppermute(x, axis, perm)
