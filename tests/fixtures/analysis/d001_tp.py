class Widget:
    def resize(self, n):
        return n


def frob(x):
    return x
