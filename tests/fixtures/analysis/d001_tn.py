"""D001 true negative: fully documented public surface.

Private names and nested defs are out of scope.  No findings expected.
"""


class Widget:
    """A documented class."""

    def resize(self, n):
        """A documented method."""
        return n

    def _internal(self):
        return None


def frob(x):
    """A documented function with an undocumented nested def."""
    def helper(y):
        return y
    return helper(x)


def _private(x):
    return x
