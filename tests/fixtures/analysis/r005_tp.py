"""R005 true positives: host entropy baked into a traced program.

A clock call and a set iteration inside functions that are traced
(``@jax.jit`` decoration; passed by name to ``shard_map``).  Three
findings expected: the clock, the random draw, and the set-literal loop.
"""

import random
import time

import jax


@jax.jit
def stamped_step(x):
    """Bakes one arbitrary host timestamp into the compiled program."""
    started = time.time()
    jitter = random.random()
    return x + started + jitter


def build(mesh, spec):
    """Hands ``f`` to shard_map: its body runs at trace time."""

    def f(x):
        total = x
        for axis in {"rows", "cols"}:  # trace order varies per hash seed
            total = jax.lax.psum(total, axis)
        return total

    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=(spec,), out_specs=spec)
