"""R001 true positive: the PR 7 summa_ring retrace bug, minimized.

``jax.jit(shard_map(f))`` is rebuilt on every call, so the fresh closure
identity defeats jit's cache and each call re-traces the whole ring.
Exactly one finding is expected: the composite is reported once, at the
outer ``jit`` call.
"""

import jax
from jax.experimental.shard_map import shard_map


def summa_ring_buggy(mesh, spec, f, a, b):
    """Multiply one panel pair — rebuilding the program per call."""
    fm = jax.jit(
        shard_map(f, mesh=mesh, in_specs=(spec, spec), out_specs=spec)
    )
    return fm(a, b)
