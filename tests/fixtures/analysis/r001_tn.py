"""R001 true negatives: every sanctioned way to build a jitted program.

Module-level construction, an ``@lru_cache`` program builder (the
``_ring_program`` pattern), a ``make_*``-prefixed one-shot builder, AOT
``.lower()``, and a ``shard_map`` consumed at trace time of an enclosing
jitted step.  No findings expected.
"""

from functools import lru_cache

import jax
from jax.experimental.shard_map import shard_map

module_level = jax.jit(lambda x: x + 1)


@lru_cache(maxsize=None)
def _cached_program(mesh, spec, f):
    """The _ring_program pattern: one build per (mesh, spec, f) key."""
    return jax.jit(
        shard_map(f, mesh=mesh, in_specs=(spec,), out_specs=spec)
    )


def make_step(f):
    """One-shot builder by naming convention: the caller caches."""
    return jax.jit(f, donate_argnums=(0,))


def dry_run_cost(f, x):
    """AOT lowering pays compilation deliberately."""
    return jax.jit(f).lower(x).compile().cost_analysis()


def fused_phase(mesh, spec, f, x):
    """shard_map invoked in the same expression: traced into the
    enclosing jitted program, no per-call cache identity."""
    return shard_map(f, mesh=mesh, in_specs=(spec,), out_specs=spec)(x)
