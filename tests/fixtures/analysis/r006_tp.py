"""R006 true positives: device→host syncs inside a phase span.

``.block_until_ready()``, ``np.asarray`` and ``float(...)`` force extra
blocking round-trips mid-phase, so the span stops measuring the async
schedule.  Three findings expected.
"""

import numpy as np

from repro.obs.trace import span


def ring_phase(run, out, tally):
    """Phase body that drains the dispatch pipeline three ways."""
    with span("SpGEMM", kind="phase", phase="ring_stage") as sp:
        out = run(out)
        out.block_until_ready()
        host = np.asarray(out)
        tally += float(out[0])
        sp.set_output(host)
    return tally
