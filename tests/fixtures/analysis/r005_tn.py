"""R005 true negatives: entropy outside traces, ordered iteration inside.

Clock calls in plain host functions are fine (benchmark timers live
there), and a traced function may iterate a *sorted* set.  No findings
expected.
"""

import time

import jax


def timed(f):
    """Host-side timing helper: clocks are fine outside a trace."""
    t0 = time.perf_counter()
    out = f()
    return out, time.perf_counter() - t0


@jax.jit
def ordered_step(x):
    """Deterministic iteration: sorted() fixes the trace order."""
    total = x
    for axis in sorted({"rows", "cols"}):
        total = jax.lax.psum(total, axis)
    return total
