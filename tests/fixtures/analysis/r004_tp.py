"""R004 true positive: emitting stats keys the schema never declared.

An ``emit`` with a typo'd key, an ``emit_many`` dict with an unregistered
key, and a ``seed_zero`` naming an undeclared present-and-zero group.
Three findings expected.
"""


def report(metrics, n):
    """Emit under names obs/schema.py does not know."""
    metrics.emit("exchnage_words_summa", n)  # typo'd key
    metrics.emit_many({"totally_unregistered_key": n})
    metrics.seed_zero("not_a_zero_group")
