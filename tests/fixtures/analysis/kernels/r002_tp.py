"""R002 true positive: the PR 6 pallas_call captured-constant bug, minimized.

``NO_COL`` is a module-level ``jnp`` scalar — a concrete device array —
captured inside a Pallas kernel body.  One finding expected on the load
inside ``merge_kernel``.
"""

import jax.numpy as jnp
from jax.experimental import pallas as pl

NO_COL = jnp.int32(-1)


def merge_kernel(x_ref, o_ref):
    """Kernel body capturing the module-level device constant."""
    o_ref[...] = jnp.where(x_ref[...] == NO_COL, 0, x_ref[...])


def run(x):
    """Launch the kernel."""
    return pl.pallas_call(merge_kernel, out_shape=x)(x)
