"""R002 true negatives: the sanctioned constant patterns.

A plain Python literal inside a kernel (the ``kernels/cc/cc.py``
``_BIG = 2**30`` fix), and a module-level ``jnp`` constant used only
*outside* kernel bodies (host-side oracles may hold device values).
No findings expected.
"""

import jax.numpy as jnp
from jax.experimental import pallas as pl

_BIG = 2**30  # plain python int: safe to capture
_HOST_ONLY = jnp.int32(-1)


def clamp_kernel(x_ref, o_ref):
    """Kernel body using only the plain-literal constant."""
    o_ref[...] = jnp.minimum(x_ref[...], _BIG)


def run(x):
    """Launch the kernel."""
    return pl.pallas_call(clamp_kernel, out_shape=x)(x)


def host_reference(x):
    """Host-side oracle: free to use the device constant."""
    return jnp.where(x == _HOST_ONLY, 0, x)
