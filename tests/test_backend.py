"""Backend dispatch layer: resolution, registry, and the golden-assembly
parity guarantee — ``assemble()`` must produce identical (EllMatrix-equal)
R and S graphs and contig stats under ``backend="reference"`` and
``backend="pallas"`` (interpret mode on CPU)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.assembly.pipeline import PipelineConfig, assemble
from repro.assembly.simulate import simulate_genome, simulate_reads
from repro.core.backend import (
    available_backends,
    dispatch,
    resolve_backend,
    resolve_interpret,
)
from repro.core.semiring import minplus_orient_semiring as SR
from repro.core.spmat import ell_equal, from_coo
from repro.core.transitive_reduction import transitive_reduction_fused


def _sim():
    rng = np.random.default_rng(3)
    g = simulate_genome(rng, 3000)
    return simulate_reads(g, depth=8, mean_len=400, std_len=60,
                          error_rate=0.02, seed=4)


def _cfg(backend):
    return PipelineConfig(
        m_capacity=1 << 15, upper=48, read_capacity=64, overlap_capacity=32,
        r_capacity=24, band=17, max_steps=512, align_chunk=1024, xdrop=25,
        backend=backend,
    )


@pytest.fixture(scope="module")
def both_results():
    rs = _sim()
    return (
        assemble(rs.codes, rs.lengths, _cfg("reference")),
        assemble(rs.codes, rs.lengths, _cfg("pallas")),
    )


def test_resolution_and_registry():
    assert resolve_backend("reference") == "reference"
    assert resolve_backend("pallas") == "pallas"
    expected = "pallas" if jax.default_backend() == "tpu" else "reference"
    assert resolve_backend("auto") == expected
    assert resolve_interpret("auto") == (jax.default_backend() != "tpu")
    assert resolve_interpret(False) is False
    with pytest.raises(ValueError):
        resolve_backend("cuda")
    for op in ("xdrop_extend", "minplus_dense", "contig_gen", "consensus"):
        assert available_backends(op) == ("pallas", "reference")
        assert callable(dispatch(op, "reference"))
        assert callable(dispatch(op, "pallas"))
    with pytest.raises(KeyError):
        dispatch("no_such_op", "reference")


def test_golden_assembly_backend_parity(both_results):
    res_ref, res_pal = both_results
    assert res_ref.stats["backend"] == "reference"
    assert res_pal.stats["backend"] == "pallas"
    assert ell_equal(res_ref.r_graph, res_pal.r_graph)
    assert ell_equal(res_ref.s_graph, res_pal.s_graph)
    assert res_ref.stats["contigs"] == res_pal.stats["contigs"]
    for key in ("n_aligned", "n_passed", "nnz_R", "nnz_S", "tr_iterations"):
        assert res_ref.stats[key] == res_pal.stats[key], key
    # the consensus stage rides the same parity contract (DESIGN.md §2.8):
    # identical polished tensors and quality stats per backend
    for key in ("consensus_depth_mean", "identity_estimate",
                "consensus_changed", "n_junction_shifted"):
        assert res_ref.stats[key] == res_pal.stats[key], key
    a, b = res_ref.consensus, res_pal.consensus
    n = a.n_contigs
    assert n == b.n_contigs
    # contig-tensor padding differs per backend (exact vs pow2 staging);
    # the live rows must agree exactly
    assert np.array_equal(
        np.asarray(a.lengths)[:n], np.asarray(b.lengths)[:n]
    )
    pc_ref, pc_pal = a.to_contigs(), b.to_contigs()
    assert len(pc_ref) == len(pc_pal)
    for x, y in zip(pc_ref, pc_pal):
        assert x.reads == y.reads
        assert x.length == y.length
        assert np.array_equal(x.codes, y.codes)


def test_alignment_candidates_compacted(both_results):
    """The alignment stage must evaluate the compacted bucket, not all
    n × overlap_capacity ELL slots."""
    for res in both_results:
        total = res.stats["align_candidates"]
        bucket = res.stats["align_bucket"]
        live = res.stats["n_aligned"]
        assert total == res.stats["n_reads"] * 32  # n × overlap_capacity
        assert bucket < total
        assert live <= bucket < 2 * max(live, 1)  # next pow2 of live count


def test_tr_backend_parity_on_random_graph():
    rng = np.random.default_rng(11)
    n, e = 24, 90
    rows = rng.integers(0, n, e)
    cols = rng.integers(0, n, e)
    ok = rows != cols
    combos = rng.integers(0, 4, e)
    vals = np.full((e, 4), np.inf, np.float32)
    vals[np.arange(e), combos] = rng.integers(1, 120, e)
    r, _ = from_coo(
        jnp.asarray(rows), jnp.asarray(cols), jnp.asarray(vals),
        jnp.asarray(ok), n_rows=n, n_cols=n, capacity=12, semiring=SR,
    )
    s_ref, st_ref = transitive_reduction_fused(r, fuzz=60.0, backend="reference")
    s_pal, st_pal = transitive_reduction_fused(r, fuzz=60.0, backend="pallas")
    assert ell_equal(s_ref, s_pal)
    assert int(st_ref.iterations) == int(st_pal.iterations)
    assert int(st_ref.nnz_final) == int(st_pal.nnz_final)
