"""Observability layer (src/repro/obs/): span tracing, the typed metric
schema, the dict-shape compatibility shim, and Chrome-trace export.

The schema-coverage tests parametrize over the emission paths (host walk,
gspmd device path, shard_map explicit exchange) and assert the contract the
scattered per-test key tuples used to check piecemeal: every emitted stats
key is registered in ``obs/schema.py`` with a kind-compatible value, and
every present-and-zero group key exists on every path."""

import dataclasses
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.obs import (
    Metrics,
    MetricsError,
    Tracer,
    schema,
    span,
    sync,
    to_chrome_trace,
    tracing,
    validated,
)


# ---------------------------------------------------------------------------
# spans + tracer
# ---------------------------------------------------------------------------


def test_span_nesting_builds_tree():
    tr = Tracer()
    with tracing(tr):
        with span("Stage", kind="stage"):
            with span("Phase", kind="phase", phase="ring_stage"):
                with span("kernel_launch", kind="kernel"):
                    pass
            with span("Phase", kind="phase", phase="merge"):
                pass
        with span("Other", kind="stage"):
            pass
    assert [r.name for r in tr.roots] == ["Stage", "Other"]
    stage = tr.roots[0]
    assert [c.attrs["phase"] for c in stage.children] == ["ring_stage",
                                                          "merge"]
    assert stage.children[0].children[0].name == "kernel_launch"
    assert all(sp.duration_s >= 0 for sp in tr.spans())
    assert len(tr.find("Phase")) == 2


def test_span_works_without_tracer():
    with span("lonely") as sp:
        sp.set_output(jnp.arange(4))
    assert sp.duration_s >= 0
    assert sp.t1 is not None


def test_tracing_restores_previous_tracer():
    outer, inner = Tracer(), Tracer()
    with tracing(outer):
        with tracing(inner):
            with span("in-inner"):
                pass
        with span("in-outer"):
            pass
    assert [r.name for r in inner.roots] == ["in-inner"]
    assert [r.name for r in outer.roots] == ["in-outer"]


def test_sync_descends_plain_dataclasses():
    @dataclasses.dataclass
    class Box:
        arr: object
        nested: object = None

    b = Box(arr=jnp.arange(8), nested=Box(arr=jnp.ones(3)))
    out = sync([b, {"k": jnp.zeros(2)}, 5, "s"])
    assert out[0] is b  # returns its argument


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_metrics_rejects_unregistered_key():
    m = Metrics(context="t")
    with pytest.raises(MetricsError, match="unregistered"):
        m.emit("definitely_not_a_metric", 1)


def test_metrics_rejects_wrong_kind():
    m = Metrics(context="t")
    with pytest.raises(MetricsError, match="counter"):
        m.emit("nnz_A", 1.5)  # counter must be integral
    with pytest.raises(MetricsError, match="counter"):
        m.emit("nnz_A", True)  # bools are not counters
    with pytest.raises(MetricsError, match="label"):
        m.emit("backend", 3)


def test_metrics_seed_zero_keeps_measured_values():
    m = Metrics(context="t")
    m.emit("exchange_words_summa", 42)
    m.seed_zero("summa_exchange")
    d = m.as_dict()
    assert d["exchange_words_summa"] == 42  # setdefault, not overwrite
    assert d["exchange_rounds_summa"] == 0
    assert set(schema.group_keys("summa_exchange")) <= set(d)


def test_validated_reports_missing_group_keys():
    with pytest.raises(MetricsError, match="present-and-zero"):
        validated({"exchange_words": 0}, context="t",
                  require_groups=("contig_exchange",))


def test_zero_groups_declared():
    assert set(schema.ZERO_GROUPS) == {
        "contig_exchange", "summa_exchange", "align_exchange",
    }
    assert len(schema.group_keys("contig_exchange")) == 7
    assert len(schema.group_keys("summa_exchange")) == 2
    assert len(schema.group_keys("align_exchange")) == 2


# ---------------------------------------------------------------------------
# schema coverage of the real emission paths (replaces the per-test key
# tuples that used to live in test_contigs / test_summa_dist)
# ---------------------------------------------------------------------------


def _string_graph(n=24):
    from repro.assembly.contig_gen import string_matrix_from_edges

    edges = []
    for i in range(n - 1):
        edges.append((i, i + 1, 0, 0, 30))
        edges.append((i + 1, i, 1, 1, 33))
    return string_matrix_from_edges(n, edges)


@pytest.mark.parametrize("backend,distribution,expect", [
    ("reference", "gspmd", "host"),
    ("pallas", "gspmd", "gspmd"),
    ("pallas", "shard_map", "shard_map"),
])
def test_contig_stats_schema_coverage(backend, distribution, expect):
    """Every ContigSet.stats key of every contig path is registered, kind-
    valid, and carries the full contig_exchange present-and-zero group."""
    from repro.assembly.contig_gen import generate_contigs

    n = 24
    rng = np.random.default_rng(0)
    codes = rng.integers(0, 4, (n, 64)).astype(np.uint8)
    lengths = np.full(n, 64, np.int32)
    cset = generate_contigs(_string_graph(n), codes, lengths,
                            backend=backend, distribution=distribution)
    assert cset.stats["distribution"] == expect
    problems = schema.validate_stats(
        cset.stats, context=f"{backend}/{distribution}",
        require_groups=("contig_exchange",),
    )
    assert problems == []
    if expect != "shard_map":
        for key in schema.group_keys("contig_exchange"):
            assert cset.stats[key] == 0, key


def test_summa_stats_schema_coverage():
    """The ring-SUMMA stats dict (exchange_*_summa, spgemm_hbm_round_trips,
    summa_* labels) is fully registered and group-complete."""
    from repro.assembly.counter import first_semiring
    from repro.core.semiring import overlap_semiring
    from repro.core.spmat import from_coo
    from repro.core.summa import default_summa_mesh, overlap_spgemm_shard_map

    n, m = 12, 16
    rng = np.random.default_rng(1)
    rows = jnp.asarray(rng.integers(0, n, 40))
    cols = jnp.asarray(rng.integers(0, m, 40))
    vals = {"pos": jnp.asarray(rng.integers(0, 50, 40), jnp.int32)}
    ok = jnp.ones(40, bool)
    a, _ = from_coo(rows, cols, vals, ok, n_rows=n, n_cols=m, capacity=8,
                    semiring=first_semiring)
    at, _ = from_coo(cols, rows, vals, ok, n_rows=m, n_cols=n, capacity=8,
                     semiring=first_semiring)
    _, _, st = overlap_spgemm_shard_map(
        a, at, semiring=overlap_semiring, operand_semiring=first_semiring,
        capacity=16, mesh=default_summa_mesh(),
    )
    problems = schema.validate_stats(
        st, context="summa_ring", require_groups=("summa_exchange",)
    )
    assert problems == []
    assert "spgemm_hbm_round_trips" in st
    assert "spgemm_hbm_round_trips_reference" in st


def test_tr_stats_keys_registered():
    """The flattened TRStats surface (tr_iterations / tr_backend /
    tr_overflow) the pipeline emits is registered with correct kinds."""
    for key, value in (("tr_iterations", 3), ("tr_backend", "reference"),
                       ("tr_overflow", 0)):
        s = schema.spec(key)
        assert schema._kind_ok(s.kind, value), (key, s.kind)


def test_pipeline_stats_validate_and_trace_tree():
    """End-to-end: a tiny traced assemble's stats dict passes the registry
    with both zero groups required, and the span forest's roots are the
    Algorithm 1 stages in order."""
    from repro.assembly.pipeline import PipelineConfig, assemble
    from repro.assembly.simulate import simulate_genome, simulate_reads

    rng = np.random.default_rng(7)
    g = simulate_genome(rng, 1500)
    rs = simulate_reads(g, depth=6, mean_len=300, std_len=30, min_len=200,
                        seed=8)
    cfg = PipelineConfig(backend="reference", trace=True)
    res = assemble(rs.codes, rs.lengths, cfg)
    problems = schema.validate_stats(
        res.stats, context="assemble",
        require_groups=("contig_exchange", "summa_exchange"),
    )
    assert problems == []
    roots = [sp.name for sp in res.trace.roots]
    assert roots == ["CountKmer", "CreateSpMat", "SpGEMM", "Alignment",
                     "BuildR", "TrReduction", "Contigs", "Consensus"]
    # timings mirror the stage spans (one timing code path)
    for name in roots:
        (sp,) = res.trace.find(name)
        assert res.timings[name] == pytest.approx(sp.duration_s)


def test_untraced_assemble_has_no_tracer():
    from repro.assembly.pipeline import PipelineConfig, assemble
    from repro.assembly.simulate import simulate_genome, simulate_reads

    rng = np.random.default_rng(7)
    g = simulate_genome(rng, 1200)
    rs = simulate_reads(g, depth=5, mean_len=300, std_len=30, min_len=200,
                        seed=9)
    res = assemble(rs.codes, rs.lengths,
                   PipelineConfig(backend="reference", polish=False))
    assert res.trace is None


# ---------------------------------------------------------------------------
# HBM watermark telemetry
# ---------------------------------------------------------------------------


def test_watermark_measures_allocations():
    from repro.obs import sample, watermark

    with watermark() as wm:
        x = jnp.ones((256, 256), jnp.float32)
        sync(x)
        sample()
    assert wm.source in ("device_stats", "live_buffers")
    assert wm.peak_hbm_bytes >= 256 * 256 * 4
    assert wm.hbm_bytes_in_use >= 0
    del x


def test_watermark_outer_absorbs_nested_samples():
    """An inner window's sample points fold into every open outer window,
    so an allocation freed before the outer exit still shows in its peak."""
    from repro.obs import sample, watermark

    with watermark() as outer:
        with watermark() as inner:
            x = jnp.ones((128, 128), jnp.float32)
            sync(x)
            sample()
            del x
    assert inner.peak_hbm_bytes >= 128 * 128 * 4
    assert outer.peak_hbm_bytes >= inner.peak_hbm_bytes
    assert outer.delta_bytes == (outer.exit.bytes_in_use
                                 - outer.enter.bytes_in_use)


def test_watermark_window_closes_on_error():
    from repro.obs import memory, watermark

    with pytest.raises(RuntimeError):
        with watermark():
            raise RuntimeError("boom")
    assert memory._open_watermarks() == []


def test_watermark_windows_are_thread_local():
    """A sample taken on another thread folds into that thread's windows
    only — concurrent pipelines never pollute each other's peaks."""
    import threading

    from repro.obs import memory, watermark

    with watermark() as wm:
        before = wm.peak_hbm_bytes
        t = threading.Thread(target=memory.sample)
        t.start()
        t.join()
        assert wm.peak_hbm_bytes == before


def test_span_survives_enter_sample_failure(monkeypatch):
    """A failing enter sample must not leak its watermark into the open
    registry (every later sample would fold into it forever) nor kill the
    span: the span records without memory attribution instead."""
    from repro.obs import memory

    def boom():
        raise RuntimeError("sampling failed")

    monkeypatch.setattr(memory, "sample", boom)
    tr = Tracer()
    with tracing(tr):
        with span("Stage", kind="stage") as sp:
            pass
    assert memory._open_watermarks() == []
    assert tr.roots == [sp]
    assert "peak_hbm_bytes" not in sp.attrs


def test_span_memory_attribution():
    """Spans under a memory-enabled tracer carry the HBM attrs the trace
    export and check_trace.py's stage assertion consume."""
    tr = Tracer()
    with tracing(tr):
        with span("Stage", kind="stage"):
            x = jnp.ones((64, 64), jnp.float32)
            sync(x)
    sp = tr.roots[0]
    for key in ("peak_hbm_bytes", "hbm_bytes_in_use", "hbm_delta_bytes",
                "hbm_source"):
        assert key in sp.attrs, key
    assert sp.attrs["peak_hbm_bytes"] >= sp.attrs["hbm_delta_bytes"]
    del x


def test_tracer_memory_opt_out():
    tr = Tracer(memory=False)
    with tracing(tr):
        with span("Stage", kind="stage"):
            pass
    assert "peak_hbm_bytes" not in tr.roots[0].attrs


def test_timed_returns_compile_split_and_watermark():
    import jax

    from benchmarks._timing import timed

    t = timed(jax.jit(lambda: jnp.ones((64, 64)) * 2),
              out_of=lambda r: r, reps=2)
    assert t.steady_us >= 0 and t.compile_us > 0
    assert t.peak_hbm_bytes >= 64 * 64 * 4
    assert t.hbm_source in ("device_stats", "live_buffers")


# ---------------------------------------------------------------------------
# export
# ---------------------------------------------------------------------------


def test_chrome_trace_export(tmp_path):
    from repro.obs import write_chrome_trace

    tr = Tracer()
    with tracing(tr):
        with span("Stage", kind="stage"):
            with span("Phase", kind="phase", phase="ring_stage", s=0):
                pass
    path = write_chrome_trace(tr, str(tmp_path / "t.json"))
    doc = json.loads(open(path).read())
    events = doc["traceEvents"]
    assert [e["name"] for e in events] == ["Stage", "Phase"]
    outer, inner = events
    assert outer["ph"] == "X" and inner["ph"] == "X"
    # nesting == ts/dur containment (Perfetto's stacking rule)
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-6
    assert inner["args"]["phase"] == "ring_stage"
    tree = doc["spanTree"]
    assert tree[0]["name"] == "Stage"
    assert tree[0]["children"][0]["attrs"]["phase"] == "ring_stage"
