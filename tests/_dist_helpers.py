"""Run a snippet in a subprocess with N fake host devices (first import of
jax locks the device count, so multi-device tests must be isolated)."""

import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_with_devices(code: str, n_devices: int = 4, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=timeout, env=env,
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout
