"""EllMatrix construction / merge / prune invariants."""

import numpy as np
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st

from repro.core.semiring import count_semiring as CS
from repro.core.spmat import EllMatrix, from_coo, prune


@settings(max_examples=30, deadline=None)
@given(
    st.lists(
        st.tuples(st.integers(0, 7), st.integers(0, 9), st.integers(1, 5)),
        min_size=1, max_size=60,
    )
)
def test_from_coo_matches_dense_accumulation(triples):
    rows = jnp.asarray([t[0] for t in triples])
    cols = jnp.asarray([t[1] for t in triples])
    vals = jnp.asarray([t[2] for t in triples], jnp.int32)
    ok = jnp.ones(len(triples), bool)
    m, ovf = from_coo(rows, cols, vals, ok, n_rows=8, n_cols=10,
                      capacity=10, semiring=CS)
    assert int(ovf) == 0
    dense = np.zeros((8, 10), np.int64)
    for r, c, v in triples:
        dense[r, c] += v
    got = np.asarray(m.to_dense(CS))
    np.testing.assert_array_equal(got, dense)
    # rows sorted by col, invalid at the end
    cols_np = np.asarray(m.cols)
    for r in range(8):
        valid = cols_np[r][cols_np[r] >= 0]
        assert (np.diff(valid) > 0).all()
        assert (cols_np[r][len(valid):] == -1).all()


def test_overflow_counted_not_dropped_silently():
    rows = jnp.zeros(10, jnp.int32)
    cols = jnp.arange(10)
    vals = jnp.ones(10, jnp.int32)
    m, ovf = from_coo(rows, cols, vals, jnp.ones(10, bool), n_rows=2,
                      n_cols=16, capacity=4, semiring=CS)
    assert int(ovf) == 6
    assert m.cols[0].tolist() == [0, 1, 2, 3]


def test_prune_recompacts():
    rows = jnp.asarray([0, 0, 0])
    cols = jnp.asarray([2, 5, 7])
    vals = jnp.asarray([1, 2, 3], jnp.int32)
    m, _ = from_coo(rows, cols, vals, jnp.ones(3, bool), n_rows=1, n_cols=8,
                    capacity=4, semiring=CS)
    drop = jnp.asarray([[False, True, False, False]])
    m2 = prune(m, drop, CS)
    assert m2.cols[0].tolist() == [2, 7, -1, -1]
    assert m2.vals[0].tolist()[:2] == [1, 3]


def test_lookup():
    rows = jnp.asarray([0, 0, 1])
    cols = jnp.asarray([2, 5, 3])
    vals = jnp.asarray([10, 20, 30], jnp.int32)
    m, _ = from_coo(rows, cols, vals, jnp.ones(3, bool), n_rows=2, n_cols=8,
                    capacity=4, semiring=CS)
    got, found = m.lookup(CS, jnp.asarray([[5, 2, 7], [3, -1, 0]]))
    assert found.tolist() == [[True, True, False], [True, False, False]]
    assert got.tolist()[0][:2] == [20, 10]
