"""EllMatrix construction / merge / prune invariants, plus 0-1-principle
style edge cases for ``merge_sorted_rows`` — the per-row candidate merge is
load-bearing for both the local SpGEMM and the ring-SUMMA stage merge
(``core/summa.py``), so its duplicate-combine / pad / overflow semantics are
pinned directly here rather than only through end-to-end parity."""

from collections import Counter

import numpy as np
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st

from repro.core.semiring import count_semiring as CS
from repro.core.spmat import EllMatrix, from_coo, merge_sorted_rows, prune


@settings(max_examples=30, deadline=None)
@given(
    st.lists(
        st.tuples(st.integers(0, 7), st.integers(0, 9), st.integers(1, 5)),
        min_size=1, max_size=60,
    )
)
def test_from_coo_matches_dense_accumulation(triples):
    rows = jnp.asarray([t[0] for t in triples])
    cols = jnp.asarray([t[1] for t in triples])
    vals = jnp.asarray([t[2] for t in triples], jnp.int32)
    ok = jnp.ones(len(triples), bool)
    m, ovf = from_coo(rows, cols, vals, ok, n_rows=8, n_cols=10,
                      capacity=10, semiring=CS)
    assert int(ovf) == 0
    dense = np.zeros((8, 10), np.int64)
    for r, c, v in triples:
        dense[r, c] += v
    got = np.asarray(m.to_dense(CS))
    np.testing.assert_array_equal(got, dense)
    # rows sorted by col, invalid at the end
    cols_np = np.asarray(m.cols)
    for r in range(8):
        valid = cols_np[r][cols_np[r] >= 0]
        assert (np.diff(valid) > 0).all()
        assert (cols_np[r][len(valid):] == -1).all()


def test_overflow_counted_not_dropped_silently():
    rows = jnp.zeros(10, jnp.int32)
    cols = jnp.arange(10)
    vals = jnp.ones(10, jnp.int32)
    m, ovf = from_coo(rows, cols, vals, jnp.ones(10, bool), n_rows=2,
                      n_cols=16, capacity=4, semiring=CS)
    assert int(ovf) == 6
    assert m.cols[0].tolist() == [0, 1, 2, 3]


def test_prune_recompacts():
    rows = jnp.asarray([0, 0, 0])
    cols = jnp.asarray([2, 5, 7])
    vals = jnp.asarray([1, 2, 3], jnp.int32)
    m, _ = from_coo(rows, cols, vals, jnp.ones(3, bool), n_rows=1, n_cols=8,
                    capacity=4, semiring=CS)
    drop = jnp.asarray([[False, True, False, False]])
    m2 = prune(m, drop, CS)
    assert m2.cols[0].tolist() == [2, 7, -1, -1]
    assert m2.vals[0].tolist()[:2] == [1, 3]


def test_lookup():
    rows = jnp.asarray([0, 0, 1])
    cols = jnp.asarray([2, 5, 3])
    vals = jnp.asarray([10, 20, 30], jnp.int32)
    m, _ = from_coo(rows, cols, vals, jnp.ones(3, bool), n_rows=2, n_cols=8,
                    capacity=4, semiring=CS)
    got, found = m.lookup(CS, jnp.asarray([[5, 2, 7], [3, -1, 0]]))
    assert found.tolist() == [[True, True, False], [True, False, False]]
    assert got.tolist()[0][:2] == [20, 10]


# ---------------------------------------------------------------------------
# merge_sorted_rows edge cases
# ---------------------------------------------------------------------------


def _merge(cols_rows, capacity):
    cand = jnp.asarray(cols_rows, jnp.int32)
    vals = jnp.ones(cand.shape, jnp.int32)
    return merge_sorted_rows(cand, vals, capacity=capacity, semiring=CS)


def test_merge_sorted_rows_duplicate_columns_at_capacity():
    # every column appears twice and the post-combine count exactly fills
    # the capacity: duplicates must combine (not spill) and overflow stays 0
    cols, vals, ovf = _merge([[9, 3, 5, 3, 7, 9, 5, 7]], capacity=4)
    assert cols.tolist() == [[3, 5, 7, 9]]
    assert vals.tolist() == [[2, 2, 2, 2]]
    assert int(ovf) == 0


def test_merge_sorted_rows_all_pad_rows():
    cols, vals, ovf = _merge([[-1] * 6, [-1] * 6], capacity=3)
    assert cols.tolist() == [[-1, -1, -1]] * 2
    assert vals.tolist() == [[0, 0, 0]] * 2
    assert int(ovf) == 0


def test_merge_sorted_rows_overflow_count_exact():
    # 6 distinct columns into capacity 4 → exactly 2 overflow; the kept
    # slots are the 4 smallest columns.  Duplicates combine BEFORE the
    # capacity cut, so a second row with 6 slots over 3 distinct columns
    # adds nothing to the overflow.
    cols, vals, ovf = _merge(
        [[11, 2, 7, 5, 13, 3], [4, 4, 6, 6, 8, 8]], capacity=4
    )
    assert cols.tolist()[0] == [2, 3, 5, 7]
    assert cols.tolist()[1] == [4, 6, 8, -1]
    assert vals.tolist()[1] == [2, 2, 2, 0]
    assert int(ovf) == 2


@settings(max_examples=30, deadline=None)
@given(
    st.lists(st.integers(-1, 7), min_size=1, max_size=12),
    st.integers(1, 8),
)
def test_merge_sorted_rows_matches_dedup_oracle(cols_list, capacity):
    # 0-1-principle spirit: unit counts, arbitrary column patterns — the
    # merge must equal the sorted-distinct-prefix oracle on every input.
    # capacity ≤ Q is the callers' invariant (Q is always a multiple of the
    # output capacity in both SpGEMM paths), so the draw is clamped.
    capacity = min(capacity, len(cols_list))
    cols, vals, ovf = _merge([cols_list], capacity)
    counts = Counter(c for c in cols_list if c >= 0)
    distinct = sorted(counts)
    exp_cols = distinct[:capacity] + [-1] * (capacity - len(distinct[:capacity]))
    exp_vals = [counts[c] for c in distinct[:capacity]]
    exp_vals += [0] * (capacity - len(exp_vals))
    assert cols.tolist() == [exp_cols]
    assert vals.tolist() == [exp_vals]
    assert int(ovf) == max(len(distinct) - capacity, 0)
