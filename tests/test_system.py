"""End-to-end behaviour tests: the full Algorithm-1 pipeline on simulated
genomes (the paper's system-level claims at laptop scale)."""

import numpy as np
import pytest

from repro.assembly.pipeline import PipelineConfig, assemble
from repro.assembly.simulate import simulate_genome, simulate_reads


@pytest.fixture(scope="module")
def small_result():
    rng = np.random.default_rng(7)
    g = simulate_genome(rng, 8000)
    rs = simulate_reads(g, depth=12, mean_len=900, std_len=120,
                        error_rate=0.03, seed=11)
    cfg = PipelineConfig(
        m_capacity=1 << 15, upper=48, read_capacity=128,
        overlap_capacity=48, r_capacity=32, align_chunk=8192,
        band=33, max_steps=2048, xdrop=25,
    )
    return g, rs, assemble(rs.codes, rs.lengths, cfg)


def test_assembles_single_contig(small_result):
    g, rs, res = small_result
    stats = res.stats["contigs"]
    assert stats["n_contigs"] <= 3
    assert abs(stats["longest"] - len(g)) < 0.05 * len(g)


def test_sparsity_statistics_match_paper_model(small_result):
    """Ellis et al.: c ≈ 2d for a perfect overlapper (paper §V-C)."""
    g, rs, res = small_result
    d = rs.depth
    c = res.stats["c_density"]
    assert 1.0 * d < c < 4.0 * d
    # r ≤ c (alignment prunes candidates)
    assert res.stats["r_density"] <= c


def test_tr_converges_quickly(small_result):
    """Paper §V-D: 'the number of iterations is often a small constant'."""
    _, _, res = small_result
    assert res.stats["tr_iterations"] <= 4
    assert res.stats["nnz_S"] < res.stats["nnz_R"]


def test_string_graph_mostly_linear(small_result):
    """After TR of a linear genome, surviving degree ≈ 2 per strand-state."""
    _, _, res = small_result
    n_active = res.stats["n_reads"] - res.stats["n_contained"]
    assert res.stats["s_density"] <= 4.0


def test_contig_sequence_matches_genome(small_result):
    g, rs, res = small_result
    longest = max(res.contigs, key=lambda c: c.length)
    contig = longest.codes
    # exact subsequence check is too strict with 3% errors; check k-mer
    # recall instead.  The contig is a concatenation of raw (error-bearing)
    # reads — no consensus step — so exact-15-mer survival is bounded by
    # (1−e)^15 ≈ 0.63 at e=3%; genome set sampled at stride 1 so offsets
    # align, contig at stride 3.
    k = 15

    def kmers(x, stride):
        return {tuple(x[i : i + k]) for i in range(0, len(x) - k + 1, stride)}

    def rc(x):
        return (3 - x)[::-1]

    gk = kmers(g, 1) | kmers(rc(g), 1)
    ck = kmers(contig, 3)
    recall = len(ck & gk) / max(1, len(ck))
    assert recall > 0.45, f"contig k-mer recall {recall:.3f}"
