"""SpGEMM vs dense brute force (property-based) + masked/chunked variants."""

import numpy as np
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st

from repro.core.semiring import (
    count_semiring as CS,
    minplus_orient_semiring as SR,
)
from repro.core.spmat import from_coo
from repro.core.spgemm import spgemm, spgemm_masked, transpose
from repro.core.myers_baseline import from_ell, graphs_equal
from repro.kernels.minplus.ref import minplus_matmul_ref


def _rand_count_mat(rng, n, m, density, cap):
    mask = rng.random((n, m)) < density
    vals = rng.integers(1, 4, (n, m)) * mask
    rows, cols = np.nonzero(mask)
    mat, ovf = from_coo(
        jnp.asarray(rows), jnp.asarray(cols),
        jnp.asarray(vals[rows, cols], jnp.int32),
        jnp.ones(len(rows), bool), n_rows=n, n_cols=m, capacity=cap,
        semiring=CS,
    )
    assert int(ovf) == 0
    return mat, vals


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_spgemm_count_semiring_matches_dense(seed):
    rng = np.random.default_rng(seed)
    a, da = _rand_count_mat(rng, 12, 9, 0.3, 9)
    b, db = _rand_count_mat(rng, 9, 11, 0.3, 11)
    c, ovf = spgemm(a, b, semiring=CS, capacity=11)
    assert int(ovf) == 0
    np.testing.assert_array_equal(np.asarray(c.to_dense(CS)), da @ db)


def _rand_mp_mat(rng, n, density, cap):
    e = int(n * n * density)
    rows = rng.integers(0, n, e)
    cols = rng.integers(0, n, e)
    combos = rng.integers(0, 4, e)
    suf = rng.integers(1, 100, e).astype(np.float32)
    vals = np.full((e, 4), np.inf, np.float32)
    vals[np.arange(e), combos] = suf
    ok = rows != cols
    mat, _ = from_coo(
        jnp.asarray(rows), jnp.asarray(cols), jnp.asarray(vals),
        jnp.asarray(ok), n_rows=n, n_cols=n, capacity=cap, semiring=SR,
    )
    return mat


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_spgemm_minplus_matches_dense_kernel_ref(seed):
    rng = np.random.default_rng(seed)
    r = _rand_mp_mat(rng, 14, 0.25, 14)
    n_sp, ovf = spgemm(r, r, semiring=SR, capacity=14 * 14)
    dense_r = np.asarray(r.to_dense(SR))
    dense_n = np.asarray(
        minplus_matmul_ref(jnp.asarray(dense_r), jnp.asarray(dense_r))
    )
    got = np.asarray(n_sp.to_dense(SR))
    np.testing.assert_allclose(got, dense_n)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_masked_equals_full_at_mask(seed):
    rng = np.random.default_rng(seed)
    r = _rand_mp_mat(rng, 14, 0.25, 14)
    full, _ = spgemm(r, r, semiring=SR, capacity=14 * 14)
    msk = spgemm_masked(r, r, r, semiring=SR)
    at_r, found = full.lookup(SR, r.cols)
    m_mask = np.asarray(r.mask)
    np.testing.assert_allclose(
        np.asarray(msk.vals)[m_mask],
        np.where(np.asarray(found)[m_mask][:, None],
                 np.asarray(at_r)[m_mask], np.inf),
    )


def test_transpose_roundtrip(rng):
    a, da = _rand_count_mat(rng, 10, 8, 0.3, 8)
    at, ovf = transpose(a, capacity=10, semiring=CS)
    assert int(ovf) == 0
    np.testing.assert_array_equal(np.asarray(at.to_dense(CS)), da.T)


def test_row_chunked_equivalence(rng):
    r = _rand_mp_mat(rng, 30, 0.2, 20)
    c1, _ = spgemm(r, r, semiring=SR, capacity=40)
    c2, _ = spgemm(r, r, semiring=SR, capacity=40, row_chunk=7)
    assert graphs_equal(from_ell(c1), from_ell(c2))
    m1 = spgemm_masked(r, r, r, semiring=SR)
    m2 = spgemm_masked(r, r, r, semiring=SR, row_chunk=11)
    assert graphs_equal(from_ell(m1), from_ell(m2))
