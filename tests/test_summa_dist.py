"""Explicit-exchange ring SUMMA (DESIGN.md §2.11): golden parity of the ring
vs the all-gather variant vs the local SpGEMM — bit-identical ELL output and
overflow counts — plus the exchange-accounting contract (measured words equal
the analytic ``words_summa`` model exactly; present-and-zero on paths without
explicit exchanges) and the loud non-square / multi-row-axis fallback."""

import os

import pytest

from _dist_helpers import run_with_devices

pytestmark = pytest.mark.dist  # deselect quickly with -m "not dist"

_ROOT = os.path.join(os.path.dirname(__file__), "..")

SETUP = f"""
import sys
sys.path.insert(0, {_ROOT!r})
import numpy as np, jax, jax.numpy as jnp
from repro.core.semiring import (
    minplus_orient_semiring as SR, overlap_semiring)
from repro.assembly.counter import first_semiring
from repro.core.spmat import ell_equal, from_coo
from repro.core.spgemm import spgemm
from repro.core.summa import (
    collect, distribute_ell, distribute_ell_blocks, overlap_spgemm_shard_map,
    summa_allgather, summa_ring,
)
from repro.launch.mesh import make_test_mesh
from benchmarks.bench_comm_model import words_summa

def mpsr_mat(n, m, cap, e, seed):
    rng = np.random.default_rng(seed)
    rows = rng.integers(0, n, e); cols = rng.integers(0, m, e)
    ok = np.ones(e, bool)
    combos = rng.integers(0, 4, e)
    suf = rng.integers(1, 100, e).astype(np.float32)
    vals = np.full((e, 4), np.inf, np.float32)
    vals[np.arange(e), combos] = suf
    args = tuple(map(jnp.asarray, (rows, cols, vals, ok)))
    mat, _ = from_coo(*args, n_rows=n, n_cols=m, capacity=cap, semiring=SR)
    return mat, args

def pos_mat(rows, cols, n, m, cap, seed):
    rng = np.random.default_rng(seed)
    vals = {{"pos": jnp.asarray(rng.integers(0, 60, len(rows)), jnp.int32)}}
    ok = jnp.ones(len(rows), bool)
    mat, ovf = from_coo(jnp.asarray(rows), jnp.asarray(cols), vals, ok,
                        n_rows=n, n_cols=m, capacity=cap,
                        semiring=first_semiring)
    assert int(ovf) == 0
    return mat
"""


def test_ring_allgather_local_parity_2x2_exact_words():
    """2×2 grid, MinPlus semiring: the three paths agree bit-for-bit (cols,
    vals, overflow), the ring's measured exchange words equal the analytic
    model exactly, and the stat keys carry the round-trip evidence."""
    run_with_devices(SETUP + """
mesh = make_test_mesh((2, 2))
n = 16
R, args = mpsr_mat(n, n, 8, 60, 0)
Rd, ovfd = distribute_ell(*args, n_rows=n, n_cols=n, block_capacity=8,
                          semiring=SR, mesh=mesh)
assert int(ovfd) == 0

C_ag, ovf_ag = summa_allgather(Rd, Rd, semiring=SR, out_block_capacity=16)
C_rg, ovf_rg, st = summa_ring(Rd, Rd, semiring=SR, out_block_capacity=16)
assert ell_equal(collect(C_ag), collect(C_rg))
assert int(ovf_ag) == int(ovf_rg)

# host-level parity against the local product (collect + canonical merge)
C_host, ovf_host, st2 = overlap_spgemm_shard_map(
    R, R, semiring=SR, operand_semiring=SR, capacity=16, mesh=mesh)
C_loc, ovf_loc = spgemm(R, R, semiring=SR, capacity=16)
assert ell_equal(C_host, C_loc)
assert int(ovf_host) == int(ovf_loc)

# every stats key registered + summa_exchange group complete (the key-set
# contract itself lives in repro.obs.schema; values asserted below)
from repro.obs import schema
assert schema.validate_stats(st, context="summa_ring",
                             require_groups=("summa_exchange",)) == []

# measured == model, exactly (5 words/slot: col id + (4,) f32 suffixes)
assert st["summa_algorithm"] == "ring"
assert st["summa_stages"] == 2
assert st["exchange_rounds_summa"] == 1
assert st["exchange_words_summa"] == words_summa(
    n_rows=n, a_block_slots=8, a_words_per_slot=5,
    m_rows=n, b_block_slots=8, b_words_per_slot=5, pr=2, pc=2)
assert st["spgemm_hbm_round_trips_reference"] == 2
assert st["spgemm_hbm_round_trips"] <= 2
print("OK", st["exchange_words_summa"])
""")


def test_overlap_semiring_parity_with_padding_and_shared_kmers():
    """Overlap semiring (order-dependent ⊕) with read pairs sharing > 2
    k-mers — the canonical k-order reorder is what keeps the position pairs
    bit-identical — on an odd read count (exercises the row padding)."""
    run_with_devices(SETUP + """
mesh = make_test_mesh((2, 2))
n_reads, m = 15, 32  # odd reads: pad-to-multiple-of-pr path
rng = np.random.default_rng(5)
rows = list(rng.integers(0, n_reads, 50))
cols = list(rng.integers(0, m, 50))
# force pairs with >2 shared k-mers (cnt beyond NUM_POS_PAIRS): reads 1 and 2
# share k-mers 3,4,5,6 — the kept pair subset depends on merge order
for km in (3, 4, 5, 6):
    rows += [1, 2]; cols += [km, km]
A = pos_mat(np.array(rows), np.array(cols), n_reads, m, 12, 1)
At = pos_mat(np.array(cols), np.array(rows), m, n_reads, 12, 2)

C_loc, ovf_loc = spgemm(A, At, semiring=overlap_semiring, capacity=16)
C_dist, ovf_dist, st = overlap_spgemm_shard_map(
    A, At, semiring=overlap_semiring, operand_semiring=first_semiring,
    capacity=16, mesh=mesh)
assert ell_equal(C_dist, C_loc)
assert int(ovf_dist) == int(ovf_loc)
assert int(C_loc.vals["cnt"].max()) > 2  # the >NUM_POS_PAIRS case is live
assert st["summa_algorithm"] == "ring"
# measured == model on the padded row count (16 = 15 padded to pr=2)
assert st["exchange_words_summa"] == words_summa(
    n_rows=16, a_block_slots=12, a_words_per_slot=2,
    m_rows=32, b_block_slots=12, b_words_per_slot=2, pr=2, pc=2)
print("OK", int(C_loc.vals["cnt"].max()))
""")


def test_odd_block_capacity():
    """Odd (non-power-of-two) block capacities through distribution, ring and
    merge — no alignment assumption anywhere in the path."""
    run_with_devices(SETUP + """
mesh = make_test_mesh((2, 2))
n = 16
R, args = mpsr_mat(n, n, 7, 70, 3)  # odd operand capacity
Rd, _ = distribute_ell(*args, n_rows=n, n_cols=n, block_capacity=7,
                       semiring=SR, mesh=mesh)
C_ag, ovf_ag = summa_allgather(Rd, Rd, semiring=SR, out_block_capacity=13)
C_rg, ovf_rg, st = summa_ring(Rd, Rd, semiring=SR, out_block_capacity=13)
assert ell_equal(collect(C_ag), collect(C_rg))
assert int(ovf_ag) == int(ovf_rg)
assert st["exchange_words_summa"] == words_summa(
    n_rows=n, a_block_slots=7, a_words_per_slot=5,
    m_rows=n, b_block_slots=7, b_words_per_slot=5, pr=2, pc=2)
print("OK")
""")


def test_non_square_grid_falls_back_loudly():
    """(4,1) and (1,4) grids cannot form the Cannon ring: the result must
    still be correct (routed through summa_allgather), the stats must record
    the fallback + reason, the exchange stats must be present-and-zero, and
    strict=True must raise instead."""
    run_with_devices(SETUP + """
n = 16
R, args = mpsr_mat(n, n, 8, 60, 0)
C_loc, _ = spgemm(R, R, semiring=SR, capacity=16)
for shape in ((4, 1), (1, 4)):
    mesh = make_test_mesh(shape)
    Rd, _ = distribute_ell(*args, n_rows=n, n_cols=n, block_capacity=8,
                           semiring=SR, mesh=mesh)
    Cd, ovf, st = summa_ring(Rd, Rd, semiring=SR, out_block_capacity=16)
    assert st["summa_algorithm"] == "allgather_fallback"
    assert "non-square" in st["summa_fallback_reason"]
    assert st["exchange_words_summa"] == 0
    assert st["exchange_rounds_summa"] == 0
    g = collect(Cd)
    from repro.core.myers_baseline import from_ell, graphs_equal
    assert graphs_equal(from_ell(g), from_ell(C_loc))
    try:
        summa_ring(Rd, Rd, semiring=SR, out_block_capacity=16, strict=True)
        raise AssertionError("strict=True should have raised")
    except ValueError as e:
        assert "square" in str(e)
print("OK")
""")


def test_multipod_mesh_ring_and_fallback():
    """(pod, data, model) mesh: row_axes=("data",) leaves a square 2×2
    subgrid — the ring runs; row_axes=("pod", "data") is a multi-axis grid —
    the recorded all-gather fallback routes, same results either way."""
    run_with_devices(SETUP + """
mesh = make_test_mesh((2, 2, 2), ("pod", "data", "model"))
n = 16
R, args = mpsr_mat(n, n, 8, 50, 1)
C_loc, _ = spgemm(R, R, semiring=SR, capacity=16)
from repro.core.myers_baseline import from_ell, graphs_equal

Rd_sq, _ = distribute_ell(*args, n_rows=n, n_cols=n, block_capacity=8,
                          semiring=SR, mesh=mesh, row_axes=("data",))
C_sq, _, st_sq = summa_ring(Rd_sq, Rd_sq, semiring=SR, out_block_capacity=16)
assert st_sq["summa_algorithm"] == "ring"
assert st_sq["exchange_words_summa"] == words_summa(
    n_rows=n, a_block_slots=8, a_words_per_slot=5,
    m_rows=n, b_block_slots=8, b_words_per_slot=5, pr=2, pc=2)
assert graphs_equal(from_ell(collect(C_sq)), from_ell(C_loc))

Rd_mp, _ = distribute_ell(*args, n_rows=n, n_cols=n, block_capacity=8,
                          semiring=SR, mesh=mesh, row_axes=("pod", "data"))
C_mp, _, st_mp = summa_ring(Rd_mp, Rd_mp, semiring=SR, out_block_capacity=16)
assert st_mp["summa_algorithm"] == "allgather_fallback"
assert "multi-axis" in st_mp["summa_fallback_reason"]
assert st_mp["exchange_words_summa"] == 0
assert graphs_equal(from_ell(collect(C_mp)), from_ell(C_loc))
print("OK")
""", n_devices=8)


def test_distribute_ell_blocks_roundtrip_and_overflow():
    """The semiring-free block distribution: bit-identical to the COO-based
    distribute_ell on the same matrix, and the overflow counter fires when
    block_capacity is too small for one (row, block)."""
    run_with_devices(SETUP + """
mesh = make_test_mesh((2, 2))
n = 16
R, args = mpsr_mat(n, n, 8, 60, 0)
Rd_coo, _ = distribute_ell(*args, n_rows=n, n_cols=n, block_capacity=8,
                           semiring=SR, mesh=mesh)
Rd_blk, ovf = distribute_ell_blocks(R, block_capacity=8, semiring=SR,
                                    mesh=mesh)
assert int(ovf) == 0
assert ell_equal(collect(Rd_coo), collect(Rd_blk))
# tight capacity: must surface (not drop silently) the spill
_, ovf_tight = distribute_ell_blocks(R, block_capacity=1, semiring=SR,
                                     mesh=mesh)
assert int(ovf_tight) > 0
# indivisible rows fail loudly
try:
    bad, _ = mpsr_mat(15, n, 8, 40, 9)
    distribute_ell_blocks(bad, block_capacity=8, semiring=SR, mesh=mesh)
    raise AssertionError("should have raised on 15 rows / pr=2")
except ValueError as e:
    assert "divisible" in str(e)
print("OK")
""")


def test_dist_tr_ring_matches_allgather_and_local():
    """Transitive reduction with the N = R² square on the ring: same S graph
    as the all-gather variant and the local Algorithm 2, with live exchange
    accounting accumulated across iterations."""
    run_with_devices(SETUP + """
from repro.core.summa import (
    dist_transitive_reduction, dist_transitive_reduction_ring)
from repro.core.transitive_reduction import transitive_reduction
from repro.core.myers_baseline import from_ell, graphs_equal

mesh = make_test_mesh((2, 2))
n = 16
R, args = mpsr_mat(n, n, 8, 60, 0)
Rd, _ = distribute_ell(*args, n_rows=n, n_cols=n, block_capacity=8,
                       semiring=SR, mesh=mesh)
S, _ = transitive_reduction(R, fuzz=50.0, n_capacity=64)
Sd_ag, it_ag, nnz_ag = dist_transitive_reduction(Rd, fuzz=50.0)
Sd_rg, it_rg, nnz_rg, st = dist_transitive_reduction_ring(Rd, fuzz=50.0)
assert graphs_equal(from_ell(collect(Sd_rg)), from_ell(S))
assert graphs_equal(from_ell(collect(Sd_rg)), from_ell(collect(Sd_ag)))
assert int(nnz_rg) == int(nnz_ag) == int(S.nnz())
assert st["summa_algorithm"] == "ring"
assert st["exchange_rounds_summa"] == it_rg  # one rotation per pass on 2x2
assert st["exchange_words_summa"] > 0
# the summa= knob on the public entry point routes to the same result
Sd_kn, it_kn, nnz_kn = dist_transitive_reduction(Rd, fuzz=50.0, summa="ring")
assert graphs_equal(from_ell(collect(Sd_kn)), from_ell(collect(Sd_rg)))
print("OK", int(it_rg), st["exchange_words_summa"])
""")
