"""Golden parity suite for the Contigs stage (DESIGN.md §2.7): the host-walk
``reference`` backend and the device ``pallas`` backend must produce
*identical* contigs — same (read, strand) chains, same lengths, same base
sequences, same stats — on every string-graph shape: linear chains, branches,
cycles, contained reads, isolated singletons, strand flips, and full
simulated-genome pipelines (linear and circular)."""

import numpy as np
import pytest

from repro.assembly.contig_gen import (
    ContigSet,
    generate_contigs,
    string_matrix_from_edges,
)
from repro.assembly.contigs import Contig, ContigStats, contig_stats
from repro.assembly.pipeline import PipelineConfig, assemble
from repro.assembly.simulate import simulate_genome, simulate_reads


def _sym(edges):
    """Add the structural complement (j→i at flipped strands) per edge, the
    way build_overlap_graph does for proper dovetails."""
    out = list(edges)
    for (i, j, a, b, suf) in edges:
        out.append((j, i, 1 - b, 1 - a, suf + 7))
    return out


def _reads(n, seed=1, lmax=150):
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, 4, (n, lmax)).astype(np.uint8)
    lengths = rng.integers(80, lmax - 10, n).astype(np.int32)
    return codes, lengths


def _assert_parity(s_mat, codes, lengths, contained=None):
    ref = generate_contigs(s_mat, codes, lengths, contained,
                           backend="reference")
    dev = generate_contigs(s_mat, codes, lengths, contained, backend="pallas")
    rc, dc = ref.to_contigs(), dev.to_contigs()
    assert ref.n_contigs == dev.n_contigs
    for a, b in zip(rc, dc):
        assert a.reads == b.reads
        assert a.length == b.length
        assert np.array_equal(a.codes, b.codes)
    assert contig_stats(rc) == contig_stats(dc)
    assert ref.stats["n_branch_cut"] == dev.stats["n_branch_cut"]
    _assert_provenance_parity(ref, dev)
    return rc, dev


def _assert_provenance_parity(ref, dev):
    """Per-piece (offset, width) provenance — consumed by the consensus stage
    (DESIGN.md §2.8) — must agree piece-by-piece across backends, and pieces
    must tile each contig exactly (offset = running sum of widths, total =
    contig length)."""
    rs, ds = np.asarray(ref.states), np.asarray(dev.states)
    ro, do_ = np.asarray(ref.offsets), np.asarray(dev.offsets)
    rw, dw = np.asarray(ref.widths), np.asarray(dev.widths)
    for i in range(ref.n_contigs):
        k = int((rs[i] >= 0).sum())
        assert np.array_equal(ro[i, :k], do_[i, :k])
        assert np.array_equal(rw[i, :k], dw[i, :k])
        assert np.array_equal(ro[i, :k], np.cumsum(rw[i, :k]) - rw[i, :k])
        assert int(rw[i, :k].sum()) == int(np.asarray(ref.lengths)[i])


SCENARIOS = {
    "linear": (5, _sym([(i, i + 1, 0, 0, 30) for i in range(4)])),
    "branch": (4, _sym([(0, 1, 0, 0, 30), (0, 2, 0, 0, 25),
                        (2, 3, 0, 0, 20)])),
    "in_branch": (4, _sym([(1, 0, 0, 0, 30), (2, 0, 1, 0, 25),
                           (3, 1, 0, 0, 10)])),
    "cycle": (3, _sym([(0, 1, 0, 0, 30), (1, 2, 0, 0, 30),
                       (2, 0, 0, 0, 30)])),
    "strand_mix": (4, _sym([(0, 1, 0, 1, 30), (1, 2, 1, 1, 25),
                            (2, 3, 1, 0, 20)])),
    "asymmetric": (4, [(0, 1, 0, 0, 30), (1, 2, 0, 0, 25),
                       (2, 3, 0, 0, 20)]),
    "zero_suffix": (3, _sym([(0, 1, 0, 0, 0), (1, 2, 0, 0, 15)])),
    "empty": (3, []),
}


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_parity_handcrafted(name):
    n, edges = SCENARIOS[name]
    codes, lengths = _reads(n)
    _assert_parity(string_matrix_from_edges(n, edges), codes, lengths)


@pytest.mark.parametrize("seed", range(4))
def test_parity_random_graphs(seed):
    rng = np.random.default_rng(seed)
    n, e = 16, 40
    edges = [
        (int(i), int(j), int(a), int(b), int(s))
        for i, j, a, b, s in zip(
            rng.integers(0, n, e), rng.integers(0, n, e),
            rng.integers(0, 2, e), rng.integers(0, 2, e),
            rng.integers(1, 60, e),
        )
        if i != j
    ]
    codes, lengths = _reads(n, seed=seed)
    _assert_parity(string_matrix_from_edges(n, edges), codes, lengths)


def test_parity_contained_and_isolated():
    n = 5
    s = string_matrix_from_edges(n, _sym([(0, 1, 0, 0, 30)]))
    codes, lengths = _reads(n)
    contained = np.zeros(n, bool)
    contained[4] = True
    rc, _ = _assert_parity(s, codes, lengths, contained)
    # reads 2, 3 isolated singletons; read 4 contained → suppressed
    singleton_reads = {c.reads[0][0] for c in rc if len(c.reads) == 1}
    assert {2, 3} <= singleton_reads
    assert 4 not in {r for c in rc for r, _ in c.reads}


def test_parity_simulated_linear_genome():
    rng = np.random.default_rng(3)
    g = simulate_genome(rng, 3000)
    rs = simulate_reads(g, depth=8, mean_len=400, std_len=60,
                        error_rate=0.02, seed=4)
    cfg = PipelineConfig(
        m_capacity=1 << 15, upper=48, read_capacity=64, overlap_capacity=32,
        r_capacity=24, band=17, max_steps=512, align_chunk=1024, xdrop=25,
        backend="reference",
    )
    res = assemble(rs.codes, rs.lengths, cfg)
    _assert_parity(res.s_graph, rs.codes, rs.lengths, res.contained)


@pytest.mark.slow  # simulated circular genome through the pipeline: ~14s
def test_parity_simulated_circular_genome():
    """Circular genome → the string graph closes into a cycle; the canonical
    cut at the minimum state must agree between backends."""
    rng = np.random.default_rng(5)
    g = simulate_genome(rng, 2500)
    rs = simulate_reads(g, depth=9, mean_len=400, std_len=50,
                        error_rate=0.0, seed=6, circular=True)
    cfg = PipelineConfig(
        m_capacity=1 << 15, upper=48, read_capacity=64, overlap_capacity=32,
        r_capacity=24, band=17, max_steps=512, align_chunk=1024, xdrop=25,
        backend="reference",
    )
    res = assemble(rs.codes, rs.lengths, cfg)
    rc, _ = _assert_parity(res.s_graph, rs.codes, rs.lengths, res.contained)
    assert len(rc) >= 1


def test_parity_long_permuted_unitig():
    """One 128-read unitig whose read ids are shuffled along the chain —
    regression for the label-propagation iteration cap that used to split
    long permuted chains on the device path."""
    n = 128
    rng = np.random.default_rng(9)
    perm = rng.permutation(n)
    edges = []
    for i in range(n - 1):
        a, b = int(perm[i]), int(perm[i + 1])
        edges.append((a, b, 0, 0, 30))
        edges.append((b, a, 1, 1, 33))
    codes, lengths = _reads(n)
    rc, _ = _assert_parity(
        string_matrix_from_edges(n, edges, capacity=4), codes, lengths
    )
    assert max(len(c.reads) for c in rc) == n


def test_rc_twins_emitted_once():
    n = 3
    s = string_matrix_from_edges(n, _sym([(0, 1, 0, 0, 30), (1, 2, 0, 0, 25)]))
    codes, lengths = _reads(n)
    rc, _ = _assert_parity(s, codes, lengths)
    # one chain covering all three reads, emitted once (not once per strand)
    chains = [c for c in rc if len(c.reads) == 3]
    assert len(chains) == 1
    # the kept representative is the lexicographically smaller orientation
    states = [2 * r + st for r, st in chains[0].reads]
    twin = [s ^ 1 for s in reversed(states)]
    assert states < twin


def test_dedup_keys_on_chain_not_read_set():
    """Two distinct chains visiting the same read set in different orders are
    both contigs; the old ``frozenset(read ids)`` key collapsed them."""
    # chain A: (0,0)→(1,0)→(2,0);  chain B: (2,1)→(0,1)→(1,1).
    # Both visit reads {0,1,2}; B is NOT the reverse-complement of A
    # (twin(A) = (2,1)→(1,1)→(0,1)).
    edges = [
        (0, 1, 0, 0, 30), (1, 2, 0, 0, 25),
        (2, 0, 1, 1, 20), (0, 1, 1, 1, 15),
    ]
    n = 3
    s = string_matrix_from_edges(n, edges)
    codes, lengths = _reads(n)
    rc, _ = _assert_parity(s, codes, lengths)
    chains = sorted(c.reads for c in rc if len(c.reads) == 3)
    assert chains == [
        [(0, 0), (1, 0), (2, 0)],
        [(2, 1), (0, 1), (1, 1)],
    ]


def test_parity_suffix_exceeding_read_length():
    """Degenerate suffix > read length: both backends clamp to appending at
    most the whole read (no negative host slices, no device index clipping
    artifacts)."""
    n = 2
    s = string_matrix_from_edges(n, [(0, 1, 0, 0, 90)])
    codes, lengths = _reads(n)
    lengths[:] = 50
    rc, _ = _assert_parity(s, codes, lengths)
    chain = next(c for c in rc if len(c.reads) == 2)
    assert chain.length == 100  # 50 (head) + min(90, 50)


def test_contig_set_materialization_roundtrip():
    n = 4
    s = string_matrix_from_edges(n, _sym([(i, i + 1, 0, 0, 20)
                                          for i in range(3)]))
    codes, lengths = _reads(n)
    dev = generate_contigs(s, codes, lengths, backend="pallas")
    assert isinstance(dev, ContigSet)
    contigs = dev.to_contigs()
    assert len(contigs) == dev.n_contigs
    lens = np.asarray(dev.lengths)
    for i, c in enumerate(contigs):
        assert c.length == len(c.codes)
        assert int(lens[i]) == c.length


# ---------------------------------------------------------------------------
# ContigStats extensions (l50, mean_length, degenerate guards).
# ---------------------------------------------------------------------------


def _fake(lengths):
    return [Contig(reads=[(0, 0)], length=l, codes=np.zeros(l, np.uint8))
            for l in lengths]


def test_contig_stats_n50_l50_mean():
    cs = contig_stats(_fake([100, 80, 40, 20]))
    assert cs == ContigStats(
        n_contigs=4, total_length=240, n50=80, longest=100, l50=2,
        mean_length=60.0,
    )


def test_contig_stats_single():
    cs = contig_stats(_fake([50]))
    assert (cs.n50, cs.l50, cs.longest, cs.mean_length) == (50, 1, 50, 50.0)


def test_contig_stats_empty_list():
    assert contig_stats([]) == ContigStats(0, 0, 0, 0, 0, 0.0)


def test_contig_stats_all_zero_lengths():
    cs = contig_stats(_fake([0, 0, 0]))
    assert cs == ContigStats(
        n_contigs=3, total_length=0, n50=0, longest=0, l50=0, mean_length=0.0,
    )


@pytest.mark.slow  # second full pipeline run purely for stats plumbing: ~15s
def test_pipeline_stats_carry_contig_gen_counters():
    rng = np.random.default_rng(7)
    g = simulate_genome(rng, 2000)
    rs = simulate_reads(g, depth=7, mean_len=350, std_len=40,
                        error_rate=0.0, seed=8)
    cfg = PipelineConfig(
        m_capacity=1 << 15, upper=48, read_capacity=64, overlap_capacity=32,
        r_capacity=24, band=17, max_steps=512, align_chunk=1024, xdrop=25,
        backend="pallas",
    )
    res = assemble(rs.codes, rs.lengths, cfg)
    assert "n_branch_cut" in res.stats and res.stats["n_branch_cut"] >= 0
    assert res.stats["cc_iterations"] >= 1
    cs = res.stats["contigs"]
    assert set(cs) == {"n_contigs", "total_length", "n50", "longest", "l50",
                       "mean_length"}


def test_exchange_stats_present_and_zero_without_explicit_exchange():
    """Bugfix guard (PR 5): the exchange accounting keys are part of the
    ``ContigSet.stats`` contract on *every* path — present-and-zero on the
    gspmd device path and the host walk (rather than absent), so
    distribution-axis benchmark rows compare without key-existence
    checks."""
    n, edges = SCENARIOS["linear"]
    codes, lengths = _reads(n)
    s = string_matrix_from_edges(n, edges)
    from repro.obs import schema

    keys = schema.group_keys("contig_exchange")
    ref = generate_contigs(s, codes, lengths, backend="reference")
    dev = generate_contigs(s, codes, lengths, backend="pallas",
                           distribution="gspmd")
    for cset, dist in ((ref, "host"), (dev, "gspmd")):
        assert cset.stats["distribution"] == dist
        for k in keys:
            assert cset.stats[k] == 0, (dist, k)
    # ...and the shard_map path on a single device: keys live, ring
    # degenerate, so the words are *measured* zero while rounds still count
    sm = generate_contigs(s, codes, lengths, backend="pallas",
                          distribution="shard_map")
    assert sm.stats["distribution"] == "shard_map"
    assert sm.stats["exchange_words"] == 0  # P == 1: (P-1)/P = 0
    assert sm.stats["exchange_rounds"] > 0
