"""The ``repro.analysis`` static-analysis suite against its fixtures.

Every rule is pinned in both directions: the true-positive fixture under
``tests/fixtures/analysis/`` (R001/R002 are the PR 7 retrace and PR 6
captured-constant bugs, minimized) must produce findings, the true-negative
fixture must not.  The engine's suppression (``# repro: noqa[RULE]``),
baseline round-trip, CLI exit codes, and the single-source contracts tables
shared with the gate scripts are covered here too.  The whole module is
import-light by design: ``repro.analysis`` is stdlib-only and the fixtures
are parsed, never imported.
"""

import importlib.util
import json
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
FIXTURES = REPO / "tests" / "fixtures" / "analysis"

from repro.analysis import (  # noqa: E402
    contracts,
    engine,
    load_baseline,
    load_rules,
    run,
    write_baseline,
)
from repro.analysis.rules import ALL_RULES  # noqa: E402

# (rule, true-positive fixture, expected TP findings, true-negative fixture)
CASES = [
    ("R001", FIXTURES / "r001_tp.py", 1, FIXTURES / "r001_tn.py"),
    ("R002", FIXTURES / "kernels" / "r002_tp.py", 1,
     FIXTURES / "kernels" / "r002_tn.py"),
    ("R003", FIXTURES / "core" / "r003_tp_dist.py", 1,
     FIXTURES / "core" / "r003_tn_dist.py"),
    ("R004", FIXTURES / "r004_tp.py", 3, FIXTURES / "r004_tn.py"),
    ("R005", FIXTURES / "r005_tp.py", 3, FIXTURES / "r005_tn.py"),
    ("R006", FIXTURES / "r006_tp.py", 3, FIXTURES / "r006_tn.py"),
    ("D001", FIXTURES / "d001_tp.py", 4, FIXTURES / "d001_tn.py"),
    ("D002", FIXTURES / "d002_tp.md", 1, FIXTURES / "d002_tn.md"),
]


# ---------------------------------------------------------------------------
# per-rule fixtures: every rule has a TP and a TN
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rule,tp,n_expected,_tn", CASES,
                         ids=[c[0] for c in CASES])
def test_rule_true_positive(rule, tp, n_expected, _tn):
    res = run([tp], rules=[rule])
    assert len(res.findings) == n_expected, \
        f"{rule} on {tp.name}: {[f.render() for f in res.findings]}"
    for f in res.findings:
        assert f.rule == rule
        assert f.line > 0 and f.hint and f.message
        assert f.path.endswith(tp.name)


@pytest.mark.parametrize("rule,_tp,_n,tn", CASES, ids=[c[0] for c in CASES])
def test_rule_true_negative(rule, _tp, _n, tn):
    res = run([tn], rules=[rule])
    assert res.findings == [], [f.render() for f in res.findings]


def test_r001_composite_reported_once():
    # jax.jit(shard_map(f)) is one hazard, not two: the inner shard_map
    # builder-argument is folded into the outer jit finding
    res = run([FIXTURES / "r001_tp.py"], rules=["R001"])
    assert len(res.findings) == 1
    assert "jit(...)" in res.findings[0].message


def test_r002_names_the_kernel_and_constant():
    res = run([FIXTURES / "kernels" / "r002_tp.py"], rules=["R002"])
    (f,) = res.findings
    assert "merge_kernel" in f.message and "NO_COL" in f.message
    assert f.context == "merge_kernel"


def test_r004_registry_parses_real_schema():
    names, groups = ALL_RULES[3].load_registry(REPO)
    assert "exchange_words_summa" in names
    assert "summa_exchange" in groups


def test_d001_scoped_files_only_unless_explicit(tmp_path):
    # the same undocumented module: flagged when named explicitly, skipped
    # when swept up by a directory walk (D001 scopes to its curated list)
    src = (FIXTURES / "d001_tp.py").read_text()
    sub = tmp_path / "swept"
    sub.mkdir()
    (sub / "undocumented.py").write_text(src)
    assert run([sub / "undocumented.py"], rules=["D001"]).findings
    assert run([sub], rules=["D001"]).findings == []


# ---------------------------------------------------------------------------
# engine: suppression, baseline, walking
# ---------------------------------------------------------------------------


def test_noqa_suppresses_on_line_and_lead_comment(tmp_path):
    core = tmp_path / "core"
    core.mkdir()
    f = core / "noqa_demo_dist.py"
    f.write_text(
        '"""Fixture."""\n'
        "import jax\n\n\n"
        "def a(x, axis, perm):\n"
        '    """Trailing suppression."""\n'
        "    return jax.lax.ppermute(x, axis, perm)  # repro: noqa[R003]\n"
        "\n\n"
        "def b(x, axis, perm):\n"
        '    """Lead-comment suppression."""\n'
        "    # repro: noqa[R003] — fixture: justified in the comment block\n"
        "    # directly above the collective.\n"
        "    return jax.lax.ppermute(x, axis, perm)\n"
    )
    res = run([f], rules=["R003"])
    assert res.findings == [] and res.suppressed == 2


def test_noqa_other_rule_does_not_suppress(tmp_path):
    core = tmp_path / "core"
    core.mkdir()
    f = core / "wrong_noqa_dist.py"
    f.write_text(
        '"""Fixture."""\n'
        "import jax\n\n\n"
        "def a(x, axis, perm):\n"
        '    """Suppressing the wrong rule changes nothing."""\n'
        "    return jax.lax.ppermute(x, axis, perm)  # repro: noqa[R001]\n"
    )
    res = run([f], rules=["R003"])
    assert len(res.findings) == 1 and res.suppressed == 0


def test_baseline_round_trip(tmp_path):
    res = run([FIXTURES / "r001_tp.py"], rules=["R001"])
    assert res.findings
    bl = tmp_path / "baseline.json"
    write_baseline(bl, res.findings)
    again = run([FIXTURES / "r001_tp.py"], rules=["R001"], baseline=bl)
    assert again.findings == [] and again.baselined == len(res.findings)
    # keys are line-number-free: entries carry no "line"
    for entry in json.loads(bl.read_text())["findings"]:
        assert "line" not in entry


def test_baseline_version_and_missing_file(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text('{"version": 99, "findings": []}')
    with pytest.raises(ValueError):
        load_baseline(bad)
    with pytest.raises(FileNotFoundError):
        load_baseline(tmp_path / "nope.json")


def test_committed_baseline_is_empty():
    # the repo ships a clean tree: the committed baseline must stay empty
    # (fix or noqa new findings; never park them in the baseline silently)
    assert load_baseline(REPO / "analysis_baseline.json") == frozenset()


def test_load_rules_unknown_id():
    with pytest.raises(ValueError, match="R999"):
        load_rules(["R999"])


def test_walk_skips_pycache(tmp_path):
    core = tmp_path / "core"
    (core / "__pycache__").mkdir(parents=True)
    (core / "__pycache__" / "junk_dist.py").write_text("import jax\n")
    (core / "ok_dist.py").write_text('"""Fixture."""\n')
    files = engine.walk_targets([tmp_path], {".py"})
    assert [f.name for f in files] == ["ok_dist.py"]


# ---------------------------------------------------------------------------
# contracts: one source of truth shared with the gate scripts
# ---------------------------------------------------------------------------


def _load_script(name):
    spec = importlib.util.spec_from_file_location(
        name, REPO / "scripts" / f"{name}.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_contracts_single_source():
    assert _load_script("check_trace").STAGES is contracts.STAGES
    assert _load_script("check_smoke_comm")._CONTRACTS \
        is contracts.COMM_CONTRACTS
    # every phase-contract stage is a real Algorithm 1 stage, and every
    # comm contract pairs an exchange field with a model field
    assert set(contracts.STAGE_PHASES) <= set(contracts.STAGES)
    for _op, measured, model in contracts.COMM_CONTRACTS:
        assert measured.startswith("exchange_words_")
        assert model.startswith("model_words_")


def test_comm_contract_fields_are_registered_metrics():
    names, _groups = ALL_RULES[3].load_registry(REPO)
    for _op, measured, _model in contracts.COMM_CONTRACTS:
        assert measured in names, f"{measured} missing from obs/schema.py"


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def _cli(*args, cwd=REPO):
    env = {"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"}
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True, timeout=300, cwd=cwd, env=env,
    )


def test_cli_list_rules():
    r = _cli("check", "--list-rules")
    assert r.returncode == 0
    for mod in ALL_RULES:
        assert mod.RULE_ID in r.stdout


def test_cli_exit_codes_and_json(tmp_path):
    art = tmp_path / "findings.json"
    bad = _cli("check", str(FIXTURES / "r001_tp.py"), "--rule", "R001",
               "--json", str(art))
    assert bad.returncode == 1
    assert "R001" in bad.stdout and "hint:" in bad.stdout
    doc = json.loads(art.read_text())
    assert doc["rules"] == ["R001"] and len(doc["findings"]) == 1

    good = _cli("check", str(FIXTURES / "r001_tn.py"), "--rule", "R001")
    assert good.returncode == 0 and "analysis clean" in good.stdout

    usage = _cli("check", "--rule", "R999", str(FIXTURES / "r001_tn.py"))
    assert usage.returncode == 2 and "unknown rule" in usage.stderr


def test_cli_write_baseline(tmp_path):
    bl = tmp_path / "bl.json"
    r = _cli("check", str(FIXTURES / "r001_tp.py"), "--rule", "R001",
             "--write-baseline", str(bl))
    assert r.returncode == 0 and "wrote 1 finding(s)" in r.stdout
    r2 = _cli("check", str(FIXTURES / "r001_tp.py"), "--rule", "R001",
              "--baseline", str(bl))
    assert r2.returncode == 0 and "1 baselined" in r2.stdout


def test_real_tree_is_clean():
    # the acceptance gate: the shipped tree has no live findings (the same
    # invocation CI's docs job runs, minus the baseline indirection)
    r = _cli("check", "src", "benchmarks", "scripts")
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "analysis clean" in r.stdout
