"""Per-arch smoke tests (brief requirement): REDUCED config of each family,
one forward/train step on CPU, asserting output shapes + no NaNs; plus
decode-vs-forward consistency for each cache type."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ALL_NAMES, reduced_config
from repro.models.model import (
    init_cache, init_params, loss_fn, make_prefill_step, make_serve_step,
    forward,
)

ARCHS = [n for n in ALL_NAMES if n != "dibella"]


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=32):
    if cfg.frontend == "token":
        t = jnp.arange(b * s).reshape(b, s) % (cfg.vocab_size - 1) + 1
        return {"tokens": t.astype(jnp.int32),
                "labels": jnp.roll(t, -1, 1).astype(jnp.int32)}
    e = jnp.ones((b, s, cfg.d_model), jnp.bfloat16) * 0.01
    return {"embeddings": e,
            "labels": jnp.ones((b, s), jnp.int32)}


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch, key):
    cfg = reduced_config(arch)
    params = init_params(cfg, key)
    batch = _batch(cfg)
    x, _ = forward(params, batch, cfg)
    assert x.shape == (2, 32, cfg.d_model)
    assert np.isfinite(np.asarray(x, np.float32)).all()
    loss, grads = jax.value_and_grad(lambda p: loss_fn(p, batch, cfg))(params)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(g.astype(jnp.float32) ** 2))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", [
    "qwen3-4b", "mamba2-1.3b", "hymba-1.5b", "gemma3-4b", "phi3-mini-3.8b",
])
def test_decode_consistency(arch, key):
    """prefill(S) + decode(1) logits == forward(S+1) last logits."""
    cfg = reduced_config(arch)
    params = init_params(cfg, key)
    b, s = 2, 16
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (b, s + 1)), jnp.int32)
    # full forward on S+1 tokens
    x_full, _ = forward(params, {"tokens": toks}, cfg)
    logits_full = jnp.einsum(
        "bd,dv->bv", x_full[:, -1].astype(jnp.float32),
        params["unembed"].astype(jnp.float32))
    # prefill S then decode 1
    caches = init_cache(cfg, b, s + 4)
    prefill = make_prefill_step(cfg)
    step = make_serve_step(cfg)
    _, caches = prefill(params, caches, {"tokens": toks[:, :s]})
    logits_dec, _ = step(params, caches, {"tokens": toks[:, s : s + 1]},
                         jnp.int32(s))
    np.testing.assert_allclose(
        np.asarray(logits_dec, np.float32),
        np.asarray(logits_full, np.float32),
        rtol=0.15, atol=0.15,  # bf16 cache + different reduction orders
    )
    # ranking agreement is the functional requirement
    agree = np.mean(
        np.argmax(np.asarray(logits_dec), -1)
        == np.argmax(np.asarray(logits_full), -1)
    )
    assert agree == 1.0


def test_param_counts_match_public_configs():
    from repro.configs import get_config

    expected_b = {
        "yi-9b": (8.5, 9.3), "qwen3-4b": (3.9, 4.6),
        "phi3-mini-3.8b": (3.5, 4.1), "qwen2-moe-a2.7b": (13.5, 14.9),
        "gemma3-4b": (4.0, 5.0), "mamba2-1.3b": (1.2, 1.6),
        "hymba-1.5b": (1.4, 1.8), "granite-moe-1b-a400m": (1.1, 1.6),
        "musicgen-large": (2.1, 2.7), "internvl2-26b": (19.0, 21.0),
    }
    for arch, (lo, hi) in expected_b.items():
        n = get_config(arch).param_count() / 1e9
        assert lo <= n <= hi, f"{arch}: {n:.2f}B not in [{lo},{hi}]"
