"""Sort-based counter vs python Counter; A/Aᵀ consistency."""

from collections import Counter

import numpy as np
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st

from repro.assembly.counter import build_matrices, count_and_select
from repro.assembly.kmers import encode_seq, extract_kmers


def _py_counts(seqs, k):
    comp = {"A": "T", "C": "G", "G": "C", "T": "A"}
    cnt = Counter()
    for s in seqs:
        for i in range(len(s) - k + 1):
            km = s[i : i + k]
            rc = "".join(comp[c] for c in reversed(km))
            cnt[min(km, rc)] += 1
    return cnt


@settings(max_examples=20, deadline=None)
@given(
    st.lists(st.text(alphabet="ACGT", min_size=10, max_size=30),
             min_size=2, max_size=8),
    st.sampled_from([5, 9]),
)
def test_counts_match_python(seqs, k):
    lmax = max(len(s) for s in seqs)
    codes = np.zeros((len(seqs), lmax), np.uint8)
    lens = np.zeros(len(seqs), np.int32)
    for i, s in enumerate(seqs):
        codes[i, : len(s)] = np.asarray(encode_seq(s))
        lens[i] = len(s)
    km = extract_kmers(jnp.asarray(codes), jnp.asarray(lens), k=k)
    kc = count_and_select(km, lower=1, upper=10**6)
    ref = _py_counts(seqs, k)
    assert int(kc.n_unique) == len(ref)
    # per-instance counts: group by count histogram
    got_hist = Counter()
    cnts = np.asarray(kc.count).reshape(-1)
    valid = np.asarray(km["valid"]).reshape(-1)
    # count each unique kmer once: via col_id first occurrence
    cols = np.asarray(kc.col_id)
    seen = {}
    for i in range(len(cols)):
        if valid[i] and cols[i] >= 0 and cols[i] not in seen:
            seen[cols[i]] = cnts[i]
    assert Counter(seen.values()) == Counter(ref.values())


def test_reliable_selection_and_matrices():
    seqs = ["ACGTACGTACGT", "ACGTACGTACGT", "TTTTTTTTTTTT"]
    lmax = max(len(s) for s in seqs)
    codes = np.zeros((len(seqs), lmax), np.uint8)
    lens = np.asarray([len(s) for s in seqs], np.int32)
    for i, s in enumerate(seqs):
        codes[i, : len(s)] = np.asarray(encode_seq(s))
    km = extract_kmers(jnp.asarray(codes), jnp.asarray(lens), k=5)
    kc = count_and_select(km, lower=2, upper=50)
    a, at, ovf_a, ovf_at = build_matrices(
        kc, n_reads=3, m_capacity=64, read_capacity=16, kmer_capacity=50
    )
    # A row nnz equals reliable instances deduped per (read, kmer)
    assert int(a.nnz()) > 0
    # Aᵀ consistency: every A entry appears in Aᵀ
    acols = np.asarray(a.cols)
    atcols = np.asarray(at.cols)
    for r in range(3):
        for q in acols[r][acols[r] >= 0]:
            assert r in atcols[q][atcols[q] >= 0]
