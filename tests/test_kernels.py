"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)
plus a property-based x-drop parity layer (``_hypothesis_compat``)."""

import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.minplus import minplus_matmul, minplus_matmul_ref
from repro.kernels.xdrop import xdrop_extend_batch, xdrop_extend_batch_ref


@pytest.mark.parametrize("m,k,n,bm,bn,bk", [
    (8, 8, 8, 8, 8, 8),
    (32, 16, 24, 16, 16, 16),
    (65, 33, 47, 32, 32, 32),   # non-divisible → padding path
    (128, 128, 128, 64, 64, 64),
])
def test_minplus_kernel_shapes(m, k, n, bm, bn, bk):
    rng = np.random.default_rng(m * 1000 + n)
    a = np.where(rng.random((m, k, 4)) < 0.35,
                 rng.integers(1, 500, (m, k, 4)).astype(np.float32), np.inf)
    b = np.where(rng.random((k, n, 4)) < 0.35,
                 rng.integers(1, 500, (k, n, 4)).astype(np.float32), np.inf)
    got = np.asarray(minplus_matmul(jnp.asarray(a), jnp.asarray(b),
                                    block_m=bm, block_n=bn, block_k=bk))
    ref = np.asarray(minplus_matmul_ref(jnp.asarray(a), jnp.asarray(b)))
    assert np.array_equal(np.isinf(got), np.isinf(ref))
    np.testing.assert_allclose(got[np.isfinite(got)], ref[np.isfinite(ref)])


@pytest.mark.parametrize("e,la,lb,band,pairs_per_block", [
    (4, 40, 40, 9, 2),
    (17, 64, 80, 17, 8),
    (9, 100, 60, 33, 4),
])
@pytest.mark.parametrize("direction", [1, -1])
def test_xdrop_kernel_sweep(e, la, lb, band, pairs_per_block, direction):
    rng = np.random.default_rng(e * 100 + la + direction)
    a = rng.integers(0, 4, (e, la)).astype(np.uint8)
    b = np.zeros((e, lb), np.uint8)
    n = min(la, lb)
    b[:, :n] = a[:, :n]
    noise = rng.random((e, lb)) < 0.07
    b = np.where(noise, (b + 1) % 4, b).astype(np.uint8)
    if direction == 1:
        base_a = np.zeros(e, np.int32); len_a = np.full(e, la, np.int32)
        base_b = np.zeros(e, np.int32); len_b = np.full(e, lb, np.int32)
    else:
        base_a = np.full(e, la - 1, np.int32); len_a = np.full(e, la, np.int32)
        base_b = np.full(e, lb - 1, np.int32); len_b = np.full(e, lb, np.int32)
    step = np.full(e, direction, np.int32)
    args = [jnp.asarray(x) for x in
            (a, base_a, step, len_a, b, base_b, step, len_b)]
    kw = dict(band=band, max_steps=la + lb)
    s1, i1, j1 = xdrop_extend_batch(*args, pairs_per_block=pairs_per_block, **kw)
    s2, i2, j2 = xdrop_extend_batch_ref(*args, **kw)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(j1), np.asarray(j2))


@settings(max_examples=10, deadline=None)
@given(
    st.integers(0, 2**31 - 1),
    st.floats(0.0, 0.3),
    st.sampled_from([5, 15, 40]),
    st.sampled_from([(1, -1, -1), (2, -3, -2)]),
    st.sampled_from([9, 33]),
    st.sampled_from([1, -1]),
)
def test_xdrop_kernel_property_parity(seed, err, xd, scoring, band, direction):
    """Kernel-level property: for random sequences, error rates, x-drop
    thresholds, scoring triples, bands and walk directions the Pallas kernel
    is bit-identical to the reference wavefront on all three outputs.
    Shapes are fixed so the jit/interpret caches persist across examples."""
    e, la, lb = 4, 72, 72
    match, mismatch, gap = scoring
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 4, (e, la)).astype(np.uint8)
    b = a.copy()
    noise = rng.random((e, lb)) < err
    b = np.where(noise, (b + rng.integers(1, 4, (e, lb))) % 4, b)
    b = b.astype(np.uint8)
    len_a = rng.integers(1, la + 1, e).astype(np.int32)
    len_b = rng.integers(1, lb + 1, e).astype(np.int32)
    if direction == 1:
        base_a = np.zeros(e, np.int32)
        base_b = np.zeros(e, np.int32)
    else:
        base_a = (len_a - 1).astype(np.int32)
        base_b = (len_b - 1).astype(np.int32)
    step = np.full(e, direction, np.int32)
    args = [jnp.asarray(x) for x in
            (a, base_a, step, len_a, b, base_b, step, len_b)]
    kw = dict(xdrop=xd, match=match, mismatch=mismatch, gap=gap, band=band,
              max_steps=la + lb)
    pal = xdrop_extend_batch(*args, pairs_per_block=2, **kw)
    ref = xdrop_extend_batch_ref(*args, **kw)
    for name, x, y in zip(("score", "ai", "bj"), pal, ref):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=name)


def _tree_equal(x, y):
    import jax

    return all(
        np.array_equal(np.asarray(a), np.asarray(b), equal_nan=True)
        for a, b in zip(jax.tree.leaves(x), jax.tree.leaves(y))
    )


def _stacked_panels(builder, stages, seed0):
    import jax

    mats = [builder(seed0 + s) for s in range(stages)]
    cols = jnp.stack([m.cols for m in mats])
    vals = jax.tree.map(lambda *xs: jnp.stack(xs), *[m.vals for m in mats])
    return cols, vals


@pytest.mark.parametrize("stages,n,nb,ka,kb,cap", [
    (1, 8, 8, 4, 4, 8),
    (3, 8, 6, 4, 4, 8),
    (4, 16, 12, 7, 5, 13),  # odd capacities: no alignment assumption
])
@pytest.mark.parametrize("kind", ["mpsr", "overlap"])
def test_spgemm_ring_stages_parity(stages, n, nb, ka, kb, cap, kind):
    """The fused stage-batch kernel is bit-identical to the per-stage oracle
    — per-stage ELL buffers and the summed overflow — for both the MinPlus
    and the (order-dependent ⊕) overlap semiring."""
    from repro.assembly.counter import first_semiring
    from repro.core.semiring import (
        minplus_orient_semiring as MPSR, overlap_semiring)
    from repro.core.spmat import from_coo
    from repro.kernels.spgemm.ref import spgemm_ring_stages_ref
    from repro.kernels.spgemm.spgemm import spgemm_ring_stages_pallas

    m_tot = stages * nb  # stage s covers B rows [s·nb, (s+1)·nb)
    n_cols_out = 32

    def build_a(seed):
        rng = np.random.default_rng(seed)
        e = 3 * n
        rows = jnp.asarray(rng.integers(0, n, e))
        cols = jnp.asarray(rng.integers(0, m_tot, e))
        if kind == "mpsr":
            combos = rng.integers(0, 4, e)
            v = np.full((e, 4), np.inf, np.float32)
            v[np.arange(e), combos] = rng.integers(1, 90, e)
            vals = jnp.asarray(v)
            sr = MPSR
        else:
            vals = {"pos": jnp.asarray(rng.integers(0, 50, e), jnp.int32)}
            sr = first_semiring
        m, _ = from_coo(rows, cols, vals, jnp.ones(e, bool), n_rows=n,
                        n_cols=m_tot, capacity=ka, semiring=sr)
        return m

    def build_b(seed):
        rng = np.random.default_rng(seed)
        e = 3 * nb
        rows = jnp.asarray(rng.integers(0, nb, e))
        cols = jnp.asarray(rng.integers(0, n_cols_out, e))
        if kind == "mpsr":
            combos = rng.integers(0, 4, e)
            v = np.full((e, 4), np.inf, np.float32)
            v[np.arange(e), combos] = rng.integers(1, 90, e)
            vals = jnp.asarray(v)
            sr = MPSR
        else:
            vals = {"pos": jnp.asarray(rng.integers(0, 50, e), jnp.int32)}
            sr = first_semiring
        m, _ = from_coo(rows, cols, vals, jnp.ones(e, bool), n_rows=nb,
                        n_cols=n_cols_out, capacity=kb, semiring=sr)
        return m

    semiring = MPSR if kind == "mpsr" else overlap_semiring
    a_cols, a_vals = _stacked_panels(build_a, stages, 100 * stages)
    b_cols, b_vals = _stacked_panels(build_b, stages, 200 * stages)
    offsets = jnp.arange(stages, dtype=jnp.int32) * nb

    ref = spgemm_ring_stages_ref(
        offsets, a_cols, a_vals, b_cols, b_vals, semiring=semiring,
        capacity=cap, n_cols_out=n_cols_out)
    pal = spgemm_ring_stages_pallas(
        offsets, a_cols, a_vals, b_cols, b_vals, semiring=semiring,
        capacity=cap, n_cols_out=n_cols_out, interpret=True)
    assert np.array_equal(np.asarray(ref[0]), np.asarray(pal[0]))
    assert _tree_equal(ref[1], pal[1])
    assert int(ref[2]) == int(pal[2])


def test_spgemm_hbm_round_trips_fewer_than_reference():
    """The evidence stat of the fusion: for any multi-stage ring the fused
    path pays strictly fewer HBM round trips than the per-stage reference
    (which pays one per stage), and the VMEM-budget gate reports honestly."""
    from repro.core.semiring import minplus_orient_semiring as MPSR
    from repro.kernels.spgemm.ops import (
        VMEM_BUDGET_BYTES, fused_path_fits, hbm_round_trips)
    import jax

    for stages, g in [(2, 4), (4, 4), (8, 4), (16, 2), (7, 3)]:
        if stages > g:
            assert hbm_round_trips(stages, g) < stages
        assert hbm_round_trips(stages, g) == -(-stages // g)
    # the gate: a small batch fits, a huge one reports False (falls back)
    sds = jax.ShapeDtypeStruct
    small = dict(
        a_cols=sds((4, 16, 8), jnp.int32), a_vals=sds((4, 16, 8, 4), jnp.float32),
        b_cols=sds((4, 16, 8), jnp.int32), b_vals=sds((4, 16, 8, 4), jnp.float32))
    huge = dict(
        a_cols=sds((4, 1 << 14, 64), jnp.int32),
        a_vals=sds((4, 1 << 14, 64, 4), jnp.float32),
        b_cols=sds((4, 1 << 14, 64), jnp.int32),
        b_vals=sds((4, 1 << 14, 64, 4), jnp.float32))
    assert fused_path_fits(**small, capacity=16, semiring=MPSR)
    assert not fused_path_fits(**huge, capacity=64, semiring=MPSR)
    assert VMEM_BUDGET_BYTES > 0
