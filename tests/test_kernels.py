"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels.minplus import minplus_matmul, minplus_matmul_ref
from repro.kernels.xdrop import xdrop_extend_batch, xdrop_extend_batch_ref


@pytest.mark.parametrize("m,k,n,bm,bn,bk", [
    (8, 8, 8, 8, 8, 8),
    (32, 16, 24, 16, 16, 16),
    (65, 33, 47, 32, 32, 32),   # non-divisible → padding path
    (128, 128, 128, 64, 64, 64),
])
def test_minplus_kernel_shapes(m, k, n, bm, bn, bk):
    rng = np.random.default_rng(m * 1000 + n)
    a = np.where(rng.random((m, k, 4)) < 0.35,
                 rng.integers(1, 500, (m, k, 4)).astype(np.float32), np.inf)
    b = np.where(rng.random((k, n, 4)) < 0.35,
                 rng.integers(1, 500, (k, n, 4)).astype(np.float32), np.inf)
    got = np.asarray(minplus_matmul(jnp.asarray(a), jnp.asarray(b),
                                    block_m=bm, block_n=bn, block_k=bk))
    ref = np.asarray(minplus_matmul_ref(jnp.asarray(a), jnp.asarray(b)))
    assert np.array_equal(np.isinf(got), np.isinf(ref))
    np.testing.assert_allclose(got[np.isfinite(got)], ref[np.isfinite(ref)])


@pytest.mark.parametrize("e,la,lb,band,pairs_per_block", [
    (4, 40, 40, 9, 2),
    (17, 64, 80, 17, 8),
    (9, 100, 60, 33, 4),
])
@pytest.mark.parametrize("direction", [1, -1])
def test_xdrop_kernel_sweep(e, la, lb, band, pairs_per_block, direction):
    rng = np.random.default_rng(e * 100 + la + direction)
    a = rng.integers(0, 4, (e, la)).astype(np.uint8)
    b = np.zeros((e, lb), np.uint8)
    n = min(la, lb)
    b[:, :n] = a[:, :n]
    noise = rng.random((e, lb)) < 0.07
    b = np.where(noise, (b + 1) % 4, b).astype(np.uint8)
    if direction == 1:
        base_a = np.zeros(e, np.int32); len_a = np.full(e, la, np.int32)
        base_b = np.zeros(e, np.int32); len_b = np.full(e, lb, np.int32)
    else:
        base_a = np.full(e, la - 1, np.int32); len_a = np.full(e, la, np.int32)
        base_b = np.full(e, lb - 1, np.int32); len_b = np.full(e, lb, np.int32)
    step = np.full(e, direction, np.int32)
    args = [jnp.asarray(x) for x in
            (a, base_a, step, len_a, b, base_b, step, len_b)]
    kw = dict(band=band, max_steps=la + lb)
    s1, i1, j1 = xdrop_extend_batch(*args, pairs_per_block=pairs_per_block, **kw)
    s2, i2, j2 = xdrop_extend_batch_ref(*args, **kw)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(j1), np.asarray(j2))
