"""K-mer encoding / canonicalization properties."""

import numpy as np
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st

from repro.assembly.kmers import (
    decode_seq, encode_seq, extract_kmers, revcomp,
)

seqs = st.text(alphabet="ACGT", min_size=8, max_size=40)


@settings(max_examples=40, deadline=None)
@given(seqs)
def test_encode_decode_roundtrip(s):
    assert decode_seq(encode_seq(s)) == s


@settings(max_examples=40, deadline=None)
@given(seqs)
def test_revcomp_involution(s):
    codes = encode_seq(s)[None, :]
    lens = jnp.asarray([len(s)])
    rc = revcomp(codes, lens)
    rcrc = revcomp(rc, lens)
    np.testing.assert_array_equal(np.asarray(rcrc), np.asarray(codes))


def _py_canonical(s, k):
    comp = {"A": "T", "C": "G", "G": "C", "T": "A"}
    out = []
    for i in range(len(s) - k + 1):
        km = s[i : i + k]
        rc = "".join(comp[c] for c in reversed(km))
        out.append(min(km, rc))
    return out


@settings(max_examples=30, deadline=None)
@given(seqs, st.sampled_from([5, 9, 15]))
def test_extraction_matches_python(s, k):
    if len(s) < k:
        return
    codes = encode_seq(s)[None, :]
    km = extract_kmers(codes, jnp.asarray([len(s)]), k=k)
    got = []
    p = len(s) - k + 1
    for i in range(p):
        assert bool(km["valid"][0, i])
        hi, lo = int(km["hi"][0, i]), int(km["lo"][0, i])
        got.append((hi, lo))
    ref = _py_canonical(s, k)
    # same packed value ⇔ same canonical string; check ordering consistency
    packed_ref = {}
    for g, r in zip(got, ref):
        packed_ref.setdefault(r, set()).add(g)
    for r, gs in packed_ref.items():
        assert len(gs) == 1, f"canonical {r} mapped to {gs}"
    # strand bit: canonical == forward iff strand == 0
    for i in range(p):
        fwd = s[i : i + k]
        assert (ref[i] == fwd) == (int(km["strand"][0, i]) == 0)


@settings(max_examples=30, deadline=None)
@given(seqs, st.sampled_from([7, 15]))
def test_canonical_invariant_under_rc(s, k):
    """The canonical k-mer multiset of a read equals its RC's."""
    if len(s) < k:
        return
    codes = encode_seq(s)[None, :]
    lens = jnp.asarray([len(s)])
    km1 = extract_kmers(codes, lens, k=k)
    km2 = extract_kmers(revcomp(codes, lens), lens, k=k)
    p = len(s) - k + 1
    set1 = sorted((int(km1["hi"][0, i]), int(km1["lo"][0, i])) for i in range(p))
    set2 = sorted((int(km2["hi"][0, i]), int(km2["lo"][0, i])) for i in range(p))
    assert set1 == set2
