"""Consensus stage (DESIGN.md §2.8): vote semantics, error-free round trip,
majority-vote recovery at 5% error, and the golden parity contract — the
``reference`` (jnp scatter-add oracle) and ``pallas`` (banded kernel,
interpret mode on CPU) backends of the ``consensus`` op must agree
bit-for-bit, and both must match the host dict-and-loop walk."""

import numpy as np
import pytest

from repro.assembly.consensus import polish_contig_set
from repro.assembly.contig_gen import (
    consistent_chain_graph,
    generate_contigs,
)
from repro.assembly.contigs import pileup_polish_host
from repro.assembly.metrics import assembly_identity
from repro.assembly.pipeline import PipelineConfig, assemble
from repro.assembly.simulate import simulate_genome, simulate_reads
from repro.core.backend import available_backends, dispatch


def test_registry():
    assert available_backends("consensus") == ("pallas", "reference")
    assert callable(dispatch("consensus", "reference"))
    assert callable(dispatch("consensus", "pallas"))


# ---------------------------------------------------------------------------
# op-level vote semantics (no pipeline)
# ---------------------------------------------------------------------------


def _op_inputs(seed=0, depth=5, l=400, err=0.05):
    """One contig, ``depth`` full-length reads stacked at offset 0."""
    rng = np.random.default_rng(seed)
    truth = rng.integers(0, 4, l).astype(np.uint8)
    pieces = np.broadcast_to(truth, (1, depth, l)).copy()
    flip = rng.random((1, depth, l)) < err
    pieces = np.where(
        flip, (pieces + rng.integers(1, 4, (1, depth, l))) % 4, pieces
    ).astype(np.uint8)
    draft = pieces[0, 0].copy()[None, :]  # draft = first (error-bearing) read
    start = np.zeros((1, depth), np.int32)
    plen = np.full((1, depth), l, np.int32)
    return truth, draft, pieces, start, plen


@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_vote_recovers_substitutions(backend):
    truth, draft, pieces, start, plen = _op_inputs()
    pol, dep, agr = dispatch("consensus", backend)(
        draft, pieces, start, plen, min_depth=2, band=128
    )
    pol = np.asarray(pol)
    assert np.asarray(dep).max() == pieces.shape[1]
    # the draft carries ~5% errors; the vote recovers essentially all of them
    assert (draft[0] != truth).sum() > 10
    assert (pol[0] != truth).mean() < 0.005


@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_drifted_votes_abstain(backend):
    """Misplaced reads (the indel-drift failure mode) must fail the
    coherence gate and leave the draft untouched, not outvote it."""
    rng = np.random.default_rng(1)
    l, depth = 400, 5
    truth = rng.integers(0, 4, l).astype(np.uint8)
    draft = truth.copy()[None, :]
    pieces = np.zeros((1, depth, l), np.uint8)
    start = np.zeros((1, depth), np.int32)
    plen = np.full((1, depth), l, np.int32)
    for t in range(depth):
        d = t + 1  # every piece drifted by a distinct 1..5 columns
        pieces[0, t, : l - d] = truth[d:]
    pol, dep, agr = dispatch("consensus", backend)(
        draft, pieces, start, plen, min_depth=2, band=128
    )
    # drifted votes are suppressed (only coincidental local matches leak
    # through), so the draft survives essentially untouched instead of
    # being outvoted by correlated-drift noise
    assert (np.asarray(pol) != draft).mean() < 0.01
    assert np.asarray(dep).sum() < 0.05 * depth * l


def test_op_backend_parity_random():
    """Adversarial op-level parity: random drafts/pieces/starts (negative
    and out-of-range included), several shapes and min_depths."""
    rng = np.random.default_rng(2)
    for case in range(3):
        c, m, lr = int(rng.integers(1, 6)), int(rng.integers(1, 9)), 64
        l = int(rng.integers(20, 300))
        draft = rng.integers(0, 4, (c, l)).astype(np.uint8)
        pieces = rng.integers(0, 4, (c, m, lr)).astype(np.uint8)
        start = rng.integers(-30, l + 10, (c, m)).astype(np.int32)
        plen = rng.integers(0, lr + 1, (c, m)).astype(np.int32)
        for md in (1, 3):
            ref = dispatch("consensus", "reference")(
                draft, pieces, start, plen, min_depth=md
            )
            pal = dispatch("consensus", "pallas")(
                draft, pieces, start, plen, min_depth=md, band=64
            )
            for x, y in zip(ref, pal):
                assert np.array_equal(np.asarray(x), np.asarray(y)), (
                    case, md
                )


# ---------------------------------------------------------------------------
# stage-level: ContigSet in, polished contigs out
# ---------------------------------------------------------------------------


def test_stage_parity_and_host_walk():
    """Full-stage parity on a genome-consistent chain: reference vs pallas
    op backends bit-for-bit (through junction refinement), and the raw op
    agrees with the host dict-and-loop walk on the unrefined layout."""
    s, codes, lengths, _ = consistent_chain_graph(24, seed=5, err=0.03)
    for cb in ("reference", "pallas"):
        cset = generate_contigs(s, codes, lengths, backend=cb)
        ref = polish_contig_set(cset, codes, lengths, backend="reference")
        pal = polish_contig_set(cset, codes, lengths, backend="pallas")
        for a, b in (
            (ref.codes, pal.codes), (ref.depth, pal.depth),
            (ref.agree, pal.agree), (ref.lengths, pal.lengths),
        ):
            assert np.array_equal(np.asarray(a), np.asarray(b))
        assert ref.stats == pal.stats
        # independent host oracle on the nominal (radius-0) layout
        nor = polish_contig_set(
            cset, codes, lengths, backend="pallas", junction_radius=0
        )
        hp, hd, ha = pileup_polish_host(
            cset.codes, cset.lengths, cset.states, cset.offsets,
            cset.widths, codes, lengths, min_depth=2,
        )
        assert np.array_equal(np.asarray(nor.codes), hp)
        assert np.array_equal(np.asarray(nor.depth), hd)
        assert np.array_equal(np.asarray(nor.agree), ha)


def test_error_free_round_trip_synthetic():
    """Polishing is the identity on error-free, exactly-laid-out chains."""
    s, codes, lengths, _ = consistent_chain_graph(16, seed=6)
    cset = generate_contigs(s, codes, lengths, backend="pallas")
    for backend in ("reference", "pallas"):
        cres = polish_contig_set(cset, codes, lengths, backend=backend)
        # result capacity is the max contig length (data-dependent), the
        # draft tensor keeps its pow2 padding — compare the live columns
        l_op = np.asarray(cres.codes).shape[1]
        assert l_op == int(np.asarray(cset.lengths).max())
        assert np.array_equal(
            np.asarray(cres.codes), np.asarray(cset.codes)[:, :l_op]
        )
        assert np.array_equal(
            np.asarray(cres.lengths), np.asarray(cset.lengths)
        )
        assert cres.stats["n_changed"] == 0
        assert cres.stats["n_junction_shifted"] == 0
        assert cres.stats["identity_estimate"] == pytest.approx(1.0)


def test_refinement_grows_past_draft_capacity():
    """Nominal suffixes that *understate* the junction offsets make the
    draft too short; refinement must grow the contig past the draft
    tensor's exact (reference-backend) column capacity instead of silently
    truncating, and both op backends must agree on the grown tensor."""
    from repro.assembly.contig_gen import string_matrix_from_edges

    rng = np.random.default_rng(9)
    n, ln, ov = 6, 200, 100
    lengths = np.full(n, ln, np.int32)
    pos = np.arange(n) * (ln - ov)
    genome = rng.integers(0, 4, int(pos[-1]) + ln, dtype=np.uint8)
    codes = np.zeros((n, ln), np.uint8)
    for i in range(n):
        codes[i] = genome[pos[i] : pos[i] + ln]
    edges = []
    for i in range(n - 1):
        suf = ln - ov - 4  # understate every junction by 4 bases
        edges.append((i, i + 1, 0, 0, suf))
        edges.append((i + 1, i, 1, 1, suf))
    s = string_matrix_from_edges(n, edges)
    cset = generate_contigs(s, codes, lengths, backend="reference")
    ref = polish_contig_set(cset, codes, lengths, backend="reference")
    pal = polish_contig_set(cset, codes, lengths, backend="pallas")
    assert np.array_equal(np.asarray(ref.codes), np.asarray(pal.codes))
    assert np.array_equal(np.asarray(ref.lengths), np.asarray(pal.lengths))
    assert int(np.asarray(ref.lengths).max()) > int(
        np.asarray(cset.lengths).max()
    )
    # the re-anchored, polished contig is exactly the genome
    pc = max(ref.to_contigs(), key=lambda c: c.length)
    assert pc.length == len(genome)
    assert np.array_equal(pc.codes, genome)


# ---------------------------------------------------------------------------
# pipeline-level: the ISSUE acceptance criterion
# ---------------------------------------------------------------------------


def _pipeline_cfg():
    return PipelineConfig(
        m_capacity=1 << 16, upper=64, read_capacity=96, overlap_capacity=48,
        r_capacity=32, band=17, max_steps=2048, align_chunk=4096, xdrop=25,
        backend="reference",
    )


@pytest.mark.slow  # full pipeline at depth: ~14s, over the tier-1 budget
def test_error_free_pipeline_round_trip():
    rng = np.random.default_rng(3)
    g = simulate_genome(rng, 3000)
    rs = simulate_reads(g, depth=8, mean_len=400, std_len=60,
                        error_rate=0.0, seed=4)
    res = assemble(rs.codes, rs.lengths, _pipeline_cfg())
    assert res.consensus is not None
    assert res.stats["consensus_changed"] == 0
    assert res.stats["identity_estimate"] == pytest.approx(1.0)
    for a, b in zip(res.contigs, res.polished_contigs):
        assert a.length == b.length
        assert np.array_equal(a.codes, b.codes)


@pytest.mark.slow  # 5%-error pipeline + polish: ~16s, heaviest consensus case
def test_majority_vote_recovery_5pct():
    """Acceptance criterion: at 5% read error and ≥10× depth, polishing
    lifts measured per-base identity vs the simulated genome to ≥ 0.99
    while the raw concatenation sits ≤ 0.96."""
    rng = np.random.default_rng(7)
    g = simulate_genome(rng, 8000)
    rs = simulate_reads(g, depth=12, mean_len=700, std_len=100,
                        error_rate=0.05, indel_frac=0.0, seed=10)
    assert rs.depth >= 10.0
    res = assemble(rs.codes, rs.lengths, _pipeline_cfg())
    draft_id, nbases = assembly_identity(res.contigs, rs, min_reads=2)
    pol_id, _ = assembly_identity(res.polished_contigs, rs, min_reads=2)
    assert nbases > 5000  # the chains cover most of the genome
    assert draft_id <= 0.96
    assert pol_id >= 0.99
    assert res.stats["consensus_depth_mean"] >= 2.0
    # the on-device estimate is informative (same side of the draft)
    assert res.stats["identity_estimate"] > 0.9
