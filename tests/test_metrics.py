"""Truth-based quality metrics (assembly/metrics.py): banded edit distance
against a full-DP oracle, and contig-to-genome interval mapping."""

import numpy as np

from repro.assembly.contigs import Contig
from repro.assembly.metrics import (
    assembly_identity,
    banded_edit_distance,
    contig_identity_vs_truth,
    contig_truth_interval,
    identity,
)
from repro.assembly.simulate import simulate_genome, simulate_reads


def _full_edit(a, b):
    la, lb = len(a), len(b)
    dp = np.zeros((la + 1, lb + 1), int)
    dp[:, 0] = np.arange(la + 1)
    dp[0, :] = np.arange(lb + 1)
    for i in range(1, la + 1):
        for j in range(1, lb + 1):
            dp[i, j] = min(
                dp[i - 1, j] + 1, dp[i, j - 1] + 1,
                dp[i - 1, j - 1] + (a[i - 1] != b[j - 1]),
            )
    return dp[la, lb]


def test_banded_matches_full_dp():
    rng = np.random.default_rng(0)
    for _ in range(25):
        a = rng.integers(0, 4, int(rng.integers(0, 80)))
        b = rng.integers(0, 4, int(rng.integers(0, 80)))
        assert banded_edit_distance(a, b, band=96) == _full_edit(a, b)


def test_banded_exact_on_mutated_copy():
    rng = np.random.default_rng(1)
    a = rng.integers(0, 4, 400)
    b = list(a)
    for _ in range(16):
        p = int(rng.integers(0, len(b)))
        r = rng.random()
        if r < 0.5:
            b[p] = (b[p] + 1) % 4
        elif r < 0.75:
            del b[p]
        else:
            b.insert(p, int(rng.integers(0, 4)))
    b = np.asarray(b)
    assert banded_edit_distance(a, b, band=32) == _full_edit(a, b)
    assert identity(a, a) == 1.0
    assert identity(a, b) < 1.0


def test_contig_truth_mapping():
    rng = np.random.default_rng(2)
    g = simulate_genome(rng, 2000)
    rs = simulate_reads(g, depth=6, mean_len=300, std_len=40,
                        error_rate=0.0, seed=3)
    # a perfect "contig": an exact slice of the genome spanning two reads
    r0, r1 = 0, 1
    lo = int(min(rs.truth_start[r0], rs.truth_start[r1]))
    hi = int(max(rs.truth_end[r0], rs.truth_end[r1]))
    c = Contig(
        reads=[(r0, int(rs.truth_strand[r0])), (r1, int(rs.truth_strand[r1]))],
        length=hi - lo,
        codes=g[lo:hi].copy(),
    )
    assert contig_truth_interval(c, rs)[:2] == (lo, hi)
    assert contig_identity_vs_truth(c, rs) == 1.0
    ident, nbases = assembly_identity([c], rs)
    assert ident == 1.0 and nbases == hi - lo
