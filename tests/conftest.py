# NOTE: no XLA_FLAGS here — smoke tests and benches must see 1 device.
# Distributed tests spawn subprocesses that set
# --xla_force_host_platform_device_count themselves.
import os

import numpy as np
import pytest

# Per-test wall-clock budget (seconds) for the call phase.  The tier-1 suite
# must stay under a ~5-minute total CPU budget; any single unmarked test
# burning more than this is a regression we want CI to *fail on*, not absorb
# (pytest-timeout is not in the baked image, so the assert lives here).
# `slow`/`dist`-marked tests are exempt; REPRO_TEST_BUDGET_S overrides, 0
# (or any value ≤ 0) disables; an unparseable value falls back to the
# default instead of erroring the whole collection.


def _budget_from_env(default: float = 60.0) -> float:
    raw = os.environ.get("REPRO_TEST_BUDGET_S")
    if raw is None or not raw.strip():
        return default
    try:
        return float(raw)
    except ValueError:
        return default


TEST_BUDGET_S = _budget_from_env()


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_makereport(item, call):
    """Fail any unmarked test whose call phase exceeds ``TEST_BUDGET_S``."""
    outcome = yield
    rep = outcome.get_result()
    if (
        rep.when == "call"
        and rep.passed
        and TEST_BUDGET_S > 0
        and rep.duration > TEST_BUDGET_S
        and item.get_closest_marker("slow") is None
        and item.get_closest_marker("dist") is None
    ):
        rep.outcome = "failed"
        rep.longrepr = (
            f"{item.nodeid}: call took {rep.duration:.1f}s, over the "
            f"{TEST_BUDGET_S:.0f}s per-test budget (tier-1 must stay under "
            f"the 5-minute suite budget).  Mark it `slow` (excluded from "
            f"the default run) or `dist`, shrink it, or override with "
            f"REPRO_TEST_BUDGET_S."
        )


@pytest.fixture
def rng():
    return np.random.default_rng(0)
