"""Overlap classification and bidirected graph construction."""

import numpy as np
import jax.numpy as jnp

from repro.core.string_graph import (
    build_overlap_graph, classify_overlaps, drop_contained,
)
from repro.core.myers_baseline import from_ell


def _cls(bi, ei, li, bj, ej, lj, s, fuzz=3):
    arr = lambda x: jnp.asarray([x], jnp.int32)
    return classify_overlaps(
        arr(bi), arr(ei), arr(li), arr(bj), arr(ej), arr(lj), arr(s),
        end_fuzz=fuzz,
    )


def test_suffix_prefix_dovetail():
    c = _cls(40, 100, 100, 0, 60, 90, 0)
    assert bool(c.fwd_ij[0]) and not bool(c.fwd_ji[0])
    assert int(c.suf_ij[0]) == 30  # lj - ej
    assert int(c.suf_ij_comp[0]) == 40  # bi
    assert c.strands_ij[0].tolist() == [0, 0]


def test_prefix_suffix_dovetail():
    c = _cls(0, 60, 100, 30, 90, 90, 1)
    assert bool(c.fwd_ji[0]) and not bool(c.fwd_ij[0])
    assert int(c.suf_ji[0]) == 40  # li - ei
    assert c.strands_ji[0].tolist() == [1, 0]


def test_contained_detected():
    c = _cls(2, 98, 100, 20, 116, 200, 0)
    assert bool(c.contained_i[0]) and not bool(c.contained_j[0])
    assert not bool(c.fwd_ij[0]) and not bool(c.fwd_ji[0])


def test_internal_match_dropped():
    c = _cls(20, 60, 100, 30, 70, 120, 0)
    assert not bool(c.fwd_ij[0]) and not bool(c.fwd_ji[0])


def test_graph_has_complement_edges():
    c = _cls(40, 100, 100, 0, 60, 90, 1)
    r, contained, ovf = build_overlap_graph(
        jnp.asarray([0]), jnp.asarray([1]), c, jnp.asarray([True]),
        n_reads=2, capacity=4,
    )
    edges = from_ell(r)
    assert (0, 1) in edges and (1, 0) in edges
    # i→j at (0, s=1): combo 1; complement j→i at (1−1, 1−0) = (0, 1): combo 1
    assert np.isfinite(edges[(0, 1)][1])
    assert np.isfinite(edges[(1, 0)][1])
    assert edges[(0, 1)][1] == 30.0  # overhang of oriented j
    assert edges[(1, 0)][1] == 40.0  # overhang of i on reverse walk


def test_drop_contained_removes_incident_edges():
    c = _cls(40, 100, 100, 0, 60, 90, 0)
    r, _, _ = build_overlap_graph(
        jnp.asarray([0]), jnp.asarray([1]), c, jnp.asarray([True]),
        n_reads=3, capacity=4,
    )
    r2 = drop_contained(r, jnp.asarray([False, True, False]))
    assert int(r2.nnz()) == 0
