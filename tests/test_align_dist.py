"""Property-based tests for the alignment stack plus the distributed x-drop
extension (DESIGN.md §2.12).

Three layers, matching the stack:

* ``assembly.alignment.batch_extend`` — property tests through
  ``_hypothesis_compat``: reference↔pallas bit parity on random
  sequences/error profiles/scoring params, and the per-pair independence
  invariants (candidate-pair permutation and pad-slot count) that make the
  candidate-axis block split bit-safe in the first place;
* ``core.align_dist.align_bucket_shard_map`` on a degenerate P=1 mesh —
  in-process parity against a local ``batch_extend`` with the
  ``align_exchange`` metric group present-and-zero;
* subprocess multi-device parity (2×2 and multipod (2,2,2) meshes, and the
  full ``assemble()`` gspmd↔shard_map path on 4 devices), with the measured
  ``exchange_words_align`` asserted EXACTLY equal to the analytic
  ``bench_comm_model.words_align`` — the same contract
  ``scripts/check_smoke_comm.py`` enforces on CI artifacts.

Seeded determinism (the run-to-run half of the parity story) lives here too:
``assemble()`` at a fixed seed must be byte-identical across two runs and
across ``backend="reference"|"pallas"``.
"""

import os

import numpy as np
import jax.numpy as jnp
import pytest
from _dist_helpers import run_with_devices
from _hypothesis_compat import given, settings, st

from repro.assembly import alignment as al

_K = 7
_E = 4  # pairs per drawn example — fixed so jit caches persist across draws
_L = 96  # fixed code-row width, same reason


def _pair_batch(seed, err, e=_E, length=_L):
    """``e`` read pairs sharing a planted exact ``_K``-mer seed at
    (pa, pb), with the overlapping suffix of ``a`` copied into ``b`` (so the
    extension has signal) and substitution noise at rate ``err`` everywhere
    except the seed window."""
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 4, (e, length)).astype(np.uint8)
    b = rng.integers(0, 4, (e, length)).astype(np.uint8)
    la = rng.integers(_K + 8, length + 1, e).astype(np.int32)
    lb = rng.integers(_K + 8, length + 1, e).astype(np.int32)
    pa = (rng.integers(0, 1 << 30, e) % (la - _K)).astype(np.int32)
    pb = (rng.integers(0, 1 << 30, e) % (lb - _K)).astype(np.int32)
    for t in range(e):
        n_fwd = min(la[t] - pa[t], lb[t] - pb[t])
        b[t, pb[t]:pb[t] + n_fwd] = a[t, pa[t]:pa[t] + n_fwd]
        n_bwd = min(pa[t], pb[t])
        b[t, pb[t] - n_bwd:pb[t]] = a[t, pa[t] - n_bwd:pa[t]]
    noise = rng.random((e, length)) < err
    for t in range(e):
        noise[t, pb[t]:pb[t] + _K] = False  # keep the seed exact
    b = np.where(noise, (b + rng.integers(1, 4, (e, length))) % 4, b)
    return a.astype(np.uint8), la, b.astype(np.uint8), lb, pa, pb


def _extend(a, la, b, lb, pa, pb, backend="reference", band=17, **kw):
    return al.batch_extend(
        jnp.asarray(a), jnp.asarray(la), jnp.asarray(b), jnp.asarray(lb),
        jnp.asarray(pa), jnp.asarray(pb), k=_K, backend=backend, band=band,
        max_steps=128, **kw,
    )


# ---------------------------------------------------------------------------
# property layer: batch_extend
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(
    st.integers(0, 2**31 - 1),
    st.floats(0.0, 0.25),
    st.sampled_from([5, 20, 40]),
    st.sampled_from([(1, -1, -1), (2, -3, -2)]),
    st.sampled_from([17, 33]),
)
def test_batch_extend_ref_pallas_bit_parity(seed, err, xd, scoring, band):
    """The alignment-stack parity contract as a property: for random
    sequences, error rates, x-drop thresholds, scoring triples and bands the
    reference and pallas backends must agree on every PairAlignment field
    bit-for-bit (both extensions, both directions)."""
    match, mismatch, gap = scoring
    batch = _pair_batch(seed, err)
    kw = dict(xdrop=xd, match=match, mismatch=mismatch, gap=gap, band=band)
    ref = _extend(*batch, backend="reference", **kw)
    pal = _extend(*batch, backend="pallas", **kw)
    for name, x, y in zip(ref._fields, ref, pal):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=name)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(0, 3))
def test_batch_extend_permutation_and_pad_invariance(seed, n_pad):
    """Per-pair independence — the property that makes the candidate-axis
    block split of ``core/align_dist.py`` bit-safe: permuting the candidate
    pairs permutes the outputs identically, and appending zero pad slots
    never perturbs the live entries."""
    a, la, b, lb, pa, pb = _pair_batch(seed, 0.08)
    base = _extend(a, la, b, lb, pa, pb)

    perm = np.random.default_rng(seed ^ 0xA5A5).permutation(_E)
    permuted = _extend(a[perm], la[perm], b[perm], lb[perm], pa[perm],
                       pb[perm])
    for name, x, y in zip(base._fields, base, permuted):
        np.testing.assert_array_equal(np.asarray(x)[perm], np.asarray(y),
                                      err_msg=name)

    if n_pad:
        def _pad(x):
            z = np.zeros((n_pad,) + x.shape[1:], x.dtype)
            return np.concatenate([x, z])

        padded = _extend(*(_pad(x) for x in (a, la, b, lb, pa, pb)))
        for name, x, y in zip(base._fields, base, padded):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y)[:_E],
                                          err_msg=name)


# ---------------------------------------------------------------------------
# align_bucket_shard_map, degenerate P=1 mesh (in-process single device)
# ---------------------------------------------------------------------------


def test_align_bucket_single_device_matches_batch_extend():
    from repro.core.align_dist import align_bucket_shard_map

    a, la, b, lb, pa, pb = _pair_batch(11, 0.1, e=6)
    codes = np.concatenate([a, b], 0)  # reads 0..5 = a side, 6..11 = b side
    cand = {
        "i": np.arange(6), "j": 6 + np.arange(6), "li": la, "lj": lb,
        "pa": pa, "pb": pb, "strand": np.zeros(6, np.int32),
    }
    cand = {key: jnp.asarray(v, jnp.int32) for key, v in cand.items()}
    res, stats = align_bucket_shard_map(
        jnp.asarray(codes), cand, k=_K, backend="reference", band=17,
        max_steps=128,
    )
    exp = _extend(a, la, b, lb, pa, pb)
    for name, x, y in zip(exp._fields, exp, res):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=name)
    # present-and-zero on a single-device mesh: the align_exchange group is
    # emitted (schema contract) but no words move
    assert stats["exchange_words_align"] == 0
    assert stats["exchange_rounds_align"] == 0


# ---------------------------------------------------------------------------
# multi-device parity + exact exchange accounting (subprocess)
# ---------------------------------------------------------------------------

_ROOT = os.path.join(os.path.dirname(__file__), "..")

# bucket (10) deliberately NOT a multiple of P → exercises the pad path;
# strand-1 pairs exercise the in-region revcomp orientation.
_DIST_CODE = """
import sys
sys.path.insert(0, __ROOT__)
import numpy as np, jax.numpy as jnp
from repro.assembly import alignment as al
from repro.assembly.kmers import revcomp
from repro.core.align_dist import align_bucket_shard_map
from repro.core.components_dist import infer_row_axes
from repro.launch.mesh import make_test_mesh
from benchmarks.bench_comm_model import words_align

K = 7
E, L = 10, 96
rng = np.random.default_rng(42)
a = rng.integers(0, 4, (E, L)).astype(np.uint8)
b = rng.integers(0, 4, (E, L)).astype(np.uint8)
la = rng.integers(K + 8, L + 1, E).astype(np.int32)
lb = rng.integers(K + 8, L + 1, E).astype(np.int32)
pa = (rng.integers(0, 1 << 30, E) % (la - K)).astype(np.int32)
pb = (rng.integers(0, 1 << 30, E) % (lb - K)).astype(np.int32)
for t in range(E):
    n = min(la[t] - pa[t], lb[t] - pb[t])
    b[t, pb[t]:pb[t] + n] = a[t, pa[t]:pa[t] + n]
noise = rng.random((E, L)) < 0.08
for t in range(E):
    noise[t, pb[t]:pb[t] + K] = False
b = np.where(noise, (b + rng.integers(1, 4, (E, L))) % 4, b).astype(np.uint8)
strand = (np.arange(E) % 2).astype(np.int32)  # odd pairs arrive strand-1

# the stored partner row is the reverse complement of the oriented b the
# local oracle aligns; align_bucket_shard_map re-orients in-region
stored_b = np.asarray(revcomp(jnp.asarray(b), jnp.asarray(lb)))
stored_b = np.where((strand == 1)[:, None], stored_b, b).astype(np.uint8)

codes = np.concatenate([a, stored_b], 0)
cand = dict(i=np.arange(E), j=E + np.arange(E), li=la, lj=lb, pa=pa, pb=pb,
            strand=strand)
cand = {k: jnp.asarray(v, jnp.int32) for k, v in cand.items()}

kw = dict(k=K, backend="reference", band=17, max_steps=128)
exp = al.batch_extend(jnp.asarray(a), jnp.asarray(la), jnp.asarray(b),
                      jnp.asarray(lb), jnp.asarray(pa), jnp.asarray(pb), **kw)

mesh = make_test_mesh(__SHAPE__, __AXES__)
res, stats = align_bucket_shard_map(jnp.asarray(codes), cand, mesh=mesh, **kw)
for name, x, y in zip(exp._fields, exp, res):
    assert np.array_equal(np.asarray(x), np.asarray(y)), name

row_axes = infer_row_axes(mesh)
p = 1
for ax in row_axes:
    p *= mesh.shape[ax]
assert p == __P__, (row_axes, p)
n_pad = -(-codes.shape[0] // p) * p
bucket_pad = -(-E // p) * p
wm = words_align(n_pad=n_pad, row_width=L, bucket_pad=bucket_pad, p=p)
assert stats["exchange_words_align"] == wm, (dict(stats), wm)
hops = sum(mesh.shape[ax] - 1 for ax in row_axes)
assert stats["exchange_rounds_align"] == hops + 1, dict(stats)
print("OK", p, stats["exchange_words_align"])
"""


def _dist_code(shape, axes, p):
    return (
        _DIST_CODE
        .replace("__ROOT__", repr(_ROOT))
        .replace("__SHAPE__", repr(shape))
        .replace("__AXES__", repr(axes))
        .replace("__P__", repr(p))
    )


@pytest.mark.dist
def test_align_bucket_matches_local_on_2x2_mesh():
    """2×2 ("data", "model") mesh: the candidate axis splits over the one
    grid-row axis (P=2); scores/coords bit-identical to the local path and
    the measured words exactly equal to the analytic model."""
    out = run_with_devices(_dist_code((2, 2), ("data", "model"), 2),
                           n_devices=4)
    assert "OK 2" in out


@pytest.mark.dist
def test_align_bucket_matches_local_on_multipod_mesh():
    """Multipod (2,2,2) ("pod","data","model") mesh: the row split nests two
    axes (P=4) and the telescoped ring-gather accounting must still equal
    ``words_align`` exactly."""
    out = run_with_devices(
        _dist_code((2, 2, 2), ("pod", "data", "model"), 4), n_devices=8,
    )
    assert "OK 4" in out


@pytest.mark.dist
def test_assemble_shard_map_alignment_matches_gspmd():
    """Full-pipeline acceptance: ``distribution="shard_map"`` routes the
    alignment stage through ``align_bucket_shard_map`` and must reproduce
    the gspmd run bit-for-bit — R/S graphs, accepted-pair count, contig
    stats — while reporting live alignment exchange words that match
    ``words_align`` exactly (the gspmd run reports the same keys
    present-and-zero)."""
    run_with_devices(f"""
import sys
sys.path.insert(0, {_ROOT!r})
import numpy as np, jax
from repro.assembly.pipeline import PipelineConfig, assemble
from repro.assembly.simulate import simulate_genome, simulate_reads
from repro.core.spmat import ell_equal
from benchmarks.bench_comm_model import words_align

rng = np.random.default_rng(3)
g = simulate_genome(rng, 3000)
rs = simulate_reads(g, depth=8, mean_len=400, std_len=60, error_rate=0.02,
                    seed=4)
kw = dict(m_capacity=1 << 15, upper=48, read_capacity=64,
          overlap_capacity=32, r_capacity=24, band=17, max_steps=512,
          align_chunk=1024, xdrop=25, polish=False)
gs = assemble(rs.codes, rs.lengths, PipelineConfig(distribution="gspmd", **kw))
sm = assemble(rs.codes, rs.lengths,
              PipelineConfig(distribution="shard_map", **kw))

assert ell_equal(gs.r_graph, sm.r_graph)
assert ell_equal(gs.s_graph, sm.s_graph)
assert gs.stats["contigs"] == sm.stats["contigs"]
for key in ("n_aligned", "n_passed", "nnz_R", "nnz_S", "tr_iterations"):
    assert gs.stats[key] == sm.stats[key], key

assert gs.stats["align_distribution"] == "gspmd"
assert sm.stats["align_distribution"] == "shard_map"
assert gs.stats["exchange_words_align"] == 0  # present-and-zero
assert gs.stats["exchange_rounds_align"] == 0
p = len(jax.devices())
n_pad = -(-sm.stats["n_reads"] // p) * p
bucket_pad = -(-sm.stats["align_bucket"] // p) * p
wm = words_align(n_pad=n_pad, row_width=rs.codes.shape[1],
                 bucket_pad=bucket_pad, p=p)
assert sm.stats["exchange_words_align"] == wm, (
    sm.stats["exchange_words_align"], wm)
assert sm.stats["exchange_rounds_align"] == p
print("OK", sm.stats["exchange_words_align"])
""", n_devices=4)


# ---------------------------------------------------------------------------
# seeded determinism (two runs byte-identical; reference ≡ pallas)
# ---------------------------------------------------------------------------

# stats keys whose values are allowed to differ between byte-identical runs
# (memory sampling) or that *name* the path that ran (backend labels)
_MEM_KEYS = ("peak_hbm_bytes", "hbm_bytes_in_use", "hbm_source")
# labels naming the path that ran, plus counters measuring the path rather
# than the result (the host contig walk reports cc_iterations=0; the device
# pointer-doubling path reports the round count)
_PATH_KEYS = ("backend", "summa_backend", "tr_backend", "distribution",
              "cc_iterations")


def _stats_sans(stats, drop):
    return {k: v for k, v in stats.items() if k not in drop}


@pytest.fixture(scope="module")
def determinism_runs():
    from repro.assembly.pipeline import PipelineConfig, assemble
    from repro.assembly.simulate import simulate_genome, simulate_reads

    rng = np.random.default_rng(3)
    g = simulate_genome(rng, 3000)
    rs = simulate_reads(g, depth=8, mean_len=400, std_len=60,
                        error_rate=0.02, seed=4)

    def _cfg(backend):
        return PipelineConfig(
            m_capacity=1 << 15, upper=48, read_capacity=64,
            overlap_capacity=32, r_capacity=24, band=17, max_steps=512,
            align_chunk=1024, xdrop=25, backend=backend,
        )

    return (
        assemble(rs.codes, rs.lengths, _cfg("reference")),
        assemble(rs.codes, rs.lengths, _cfg("reference")),
        assemble(rs.codes, rs.lengths, _cfg("pallas")),
    )


def test_assemble_seeded_run_to_run_determinism(determinism_runs):
    """Two ``assemble()`` calls at a fixed seed must be byte-identical:
    every graph tensor, the contig/consensus tensors, and every stats entry
    except the memory-sampling gauges."""
    r1, r2, _ = determinism_runs
    for attr in ("r_graph", "s_graph"):
        m1, m2 = getattr(r1, attr), getattr(r2, attr)
        np.testing.assert_array_equal(np.asarray(m1.cols), np.asarray(m2.cols))
        np.testing.assert_array_equal(np.asarray(m1.vals), np.asarray(m2.vals))
    np.testing.assert_array_equal(np.asarray(r1.contained),
                                  np.asarray(r2.contained))
    assert _stats_sans(r1.stats, _MEM_KEYS) == _stats_sans(r2.stats, _MEM_KEYS)
    c1, c2 = r1.consensus, r2.consensus
    assert c1.n_contigs == c2.n_contigs
    for field in ("codes", "lengths", "states", "depth", "agree"):
        np.testing.assert_array_equal(
            np.asarray(getattr(c1, field)), np.asarray(getattr(c2, field)),
            err_msg=field,
        )
    for x, y in zip(r1.polished_contigs, r2.polished_contigs):
        assert x.reads == y.reads and x.length == y.length
        np.testing.assert_array_equal(np.asarray(x.codes), np.asarray(y.codes))


def test_assemble_seeded_backend_determinism(determinism_runs):
    """At the same fixed seed, ``backend="pallas"`` must agree with the
    reference run on the full numeric stats dict (only the path labels and
    memory gauges may differ) and on the polished contig bytes."""
    r1, _, r3 = determinism_runs
    assert r3.stats["backend"] == "pallas"
    drop = _MEM_KEYS + _PATH_KEYS
    assert _stats_sans(r1.stats, drop) == _stats_sans(r3.stats, drop)
    assert len(r1.polished_contigs) == len(r3.polished_contigs)
    for x, y in zip(r1.polished_contigs, r3.polished_contigs):
        assert x.reads == y.reads and x.length == y.length
        np.testing.assert_array_equal(np.asarray(x.codes), np.asarray(y.codes))
