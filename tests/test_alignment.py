"""X-drop alignment semantics (the jnp oracle itself)."""

import numpy as np
import jax.numpy as jnp

from repro.assembly.alignment import extend_pair, xdrop_extend
from repro.assembly.kmers import encode_seq


def _ext(a, b, **kw):
    ac = jnp.asarray(np.asarray(encode_seq(a)))
    bc = jnp.asarray(np.asarray(encode_seq(b)))
    return xdrop_extend(
        ac, 0, 1, len(a), bc, 0, 1, len(b),
        **{"band": 17, "max_steps": 128, **kw},
    )


def test_perfect_match():
    e = _ext("ACGTACGTAC", "ACGTACGTAC")
    assert int(e.score) == 10 and int(e.ai) == 10 and int(e.bj) == 10


def test_mismatch_tail_dropped():
    # first 8 match, then garbage: x-drop stops, reports the matched prefix
    e = _ext("ACGTACGT" + "AAAA", "ACGTACGT" + "TTTT", xdrop=3)
    assert int(e.score) == 8 and int(e.ai) == 8


def test_single_gap_recovered():
    a = "ACGTACGTACGT"
    b = "ACGTACGACGT" + "A"  # deletion of one T
    e = _ext(a, b, xdrop=10)
    assert int(e.score) >= 8  # 11 matches − gap penalties


def test_seed_extension_coordinates():
    genome = "ACGTTGCAAGGCTTACCGGATTACGCAT"
    a = genome[2:20]
    b = genome[8:28]
    # shared 6-mer at a[6:12] == b[0:6]
    al = extend_pair(
        jnp.asarray(np.asarray(encode_seq(a))), len(a),
        jnp.asarray(np.asarray(encode_seq(b))), len(b),
        jnp.int32(6), jnp.int32(0), k=6, band=17, max_steps=128,
    )
    # overlap spans a[6:18] vs b[0:12]: 12 exact matches (6 seed + 6 ext)
    assert int(al.score) == len(a) - 6
    assert int(al.bi) == 6 and int(al.ei) == len(a)
    assert int(al.bj) == 0 and int(al.ej) == len(a) - 6
