"""FASTA I/O + sharded reading protocol."""

import numpy as np

from repro.assembly.io_fasta import (
    pack_reads, parse_fasta, read_fasta_sharded, write_fasta,
)


def test_roundtrip(tmp_path):
    names = ["r1", "r2 extra info", "r3"]
    seqs = ["ACGT" * 30, "TTTGGG", "A"]
    codes, lens = pack_reads(seqs)
    path = str(tmp_path / "x.fasta")
    write_fasta(path, names, codes, lens)
    n2, c2, l2 = read_fasta_sharded(path)
    assert n2 == names
    np.testing.assert_array_equal(l2, lens)
    np.testing.assert_array_equal(c2[:, : c2.shape[1]], codes[:, : c2.shape[1]])


def test_sharded_reading_partitions_records(tmp_path):
    names = [f"read{i}" for i in range(20)]
    seqs = [("ACGT" * (i + 3))[: 7 + 3 * i] for i in range(20)]
    codes, lens = pack_reads(seqs)
    path = str(tmp_path / "y.fasta")
    write_fasta(path, names, codes, lens)
    got = []
    for shard in range(4):
        n, c, l = read_fasta_sharded(path, shard, 4)
        got.extend(n)
    assert got == names  # every record exactly once, in order


def test_component_grouped_contigs(tmp_path):
    """write_contig_fasta groups records by string-graph component and
    carries per-component stats (and optional consensus evidence) in every
    header; read_components labels the graph's connected pieces."""
    from repro.assembly.contig_gen import string_matrix_from_edges
    from repro.assembly.contigs import (
        Contig, contig_components, read_components,
    )
    from repro.assembly.io_fasta import write_contig_fasta

    # two disjoint chains: reads {0,1,2} and {3,4}
    s = string_matrix_from_edges(
        5, [(0, 1, 0, 0, 10), (1, 2, 0, 0, 10), (3, 4, 0, 0, 10)]
    )
    comp = read_components(s)
    assert list(comp) == [0, 0, 0, 3, 3]

    rng = np.random.default_rng(0)
    contigs = [
        Contig(reads=[(0, 0), (1, 0), (2, 0)], length=40,
               codes=rng.integers(0, 4, 40).astype(np.uint8)),
        Contig(reads=[(3, 0), (4, 0)], length=25,
               codes=rng.integers(0, 4, 25).astype(np.uint8)),
        Contig(reads=[(2, 1)], length=12,
               codes=rng.integers(0, 4, 12).astype(np.uint8)),
    ]
    labels = contig_components(contigs, comp)
    assert labels == [0, 3, 0]
    path = str(tmp_path / "c.fasta")
    n = write_contig_fasta(path, contigs, labels,
                           identity=[0.99, 0.98, 1.0], depth=[4.0, 2.0, 1.0])
    assert n == 3
    names, c2, l2 = read_fasta_sharded(path)
    assert len(names) == 3
    # component 0's two contigs are adjacent, component 3's record follows
    assert [h.split()[0] for h in names] == [
        "contig_0_0", "contig_0_1", "contig_1_0"
    ]
    assert "comp_contigs=2" in names[0] and "comp_total=52" in names[0]
    assert "comp_contigs=1" in names[2] and "comp_n50=25" in names[2]
    assert "identity=0.9900" in names[0] and "depth=4.0" in names[0]
    # sequences survive the round trip grouped-order permutation
    np.testing.assert_array_equal(c2[0][: l2[0]], contigs[0].codes)
    np.testing.assert_array_equal(c2[2][: l2[2]], contigs[1].codes)
