"""FASTA I/O + sharded reading protocol."""

import numpy as np

from repro.assembly.io_fasta import (
    pack_reads, parse_fasta, read_fasta_sharded, write_fasta,
)


def test_roundtrip(tmp_path):
    names = ["r1", "r2 extra info", "r3"]
    seqs = ["ACGT" * 30, "TTTGGG", "A"]
    codes, lens = pack_reads(seqs)
    path = str(tmp_path / "x.fasta")
    write_fasta(path, names, codes, lens)
    n2, c2, l2 = read_fasta_sharded(path)
    assert n2 == names
    np.testing.assert_array_equal(l2, lens)
    np.testing.assert_array_equal(c2[:, : c2.shape[1]], codes[:, : c2.shape[1]])


def test_sharded_reading_partitions_records(tmp_path):
    names = [f"read{i}" for i in range(20)]
    seqs = [("ACGT" * (i + 3))[: 7 + 3 * i] for i in range(20)]
    codes, lens = pack_reads(seqs)
    path = str(tmp_path / "y.fasta")
    write_fasta(path, names, codes, lens)
    got = []
    for shard in range(4):
        n, c, l = read_fasta_sharded(path, shard, 4)
        got.extend(n)
    assert got == names  # every record exactly once, in order
