"""Experiment engine (``repro.obs.experiments``): content-addressed ids,
cached runs, the append-only trajectory store, and legacy snapshot history.

The engine is exercised against a stub runner (no benchmarks executed) so
the tests pin the *caching contract*: same code + spec never re-runs, any
fingerprint or spec change invalidates exactly the affected entries, a
record that drops a required field fails loudly instead of caching thin,
and trajectory rows deduplicate on ``(experiment_id, name)``."""

import json
import os

import pytest

from repro.obs.experiments import (
    REQUIRED_RECORD_FIELDS,
    Experiment,
    ExperimentEngine,
    code_fingerprint,
    experiment_id,
    load_bench_snapshots,
    validate_records,
)


def _rec(name, ms=1.0, peak=1024):
    return {"name": name, "ms": ms, "compile_ms": 2.0,
            "peak_hbm_bytes": peak, "derived": ""}


def _engine(tmp_path, runner, fingerprint="fp0", experiments=None):
    if experiments is None:
        experiments = [Experiment("alpha", {"n": 1}, {"backend": "ref"}),
                       Experiment("beta", {}, {})]
    return ExperimentEngine(
        experiments, runner,
        cache_dir=str(tmp_path / "cache"),
        trajectory_path=str(tmp_path / "traj.jsonl"),
        fingerprint=fingerprint,
    )


# ---------------------------------------------------------------------------
# ids + fingerprint
# ---------------------------------------------------------------------------


def test_experiment_id_stable_and_spec_sensitive():
    a = Experiment("tr", {"sweep": (256,)}, {"backend": "ref"})
    b = Experiment("tr", {"sweep": (256,)}, {"backend": "ref"})
    assert experiment_id(a, "fp") == experiment_id(b, "fp")
    assert experiment_id(a, "fp") != experiment_id(a, "fp2")
    c = Experiment("tr", {"sweep": (512,)}, {"backend": "ref"})
    d = Experiment("tr", {"sweep": (256,)}, {"backend": "pallas"})
    ids = {experiment_id(e, "fp") for e in (a, c, d)}
    assert len(ids) == 3


def test_experiment_label():
    assert Experiment("tr").label == "tr"
    e = Experiment("contigs", {}, {"distribution": "shard_map"})
    assert e.label == "contigs[distribution=shard_map]"


def test_code_fingerprint_tracks_py_edits(tmp_path):
    (tmp_path / "a.py").write_text("x = 1\n")
    (tmp_path / "notes.txt").write_text("ignored\n")
    fp1 = code_fingerprint([str(tmp_path)])
    assert fp1 == code_fingerprint([str(tmp_path)])  # deterministic
    (tmp_path / "notes.txt").write_text("still ignored\n")
    assert code_fingerprint([str(tmp_path)]) == fp1  # non-.py files inert
    (tmp_path / "a.py").write_text("x = 2\n")
    assert code_fingerprint([str(tmp_path)]) != fp1


def test_code_fingerprint_checkout_location_invariant(tmp_path):
    """Two checkouts of the same tree at different absolute paths agree on
    the fingerprint (paths hash relative to the tree root), so cache
    entries and trajectory dedup keys survive across machines."""
    for co in ("checkout_a", "deeper/checkout_b"):
        d = tmp_path / co
        d.mkdir(parents=True)
        (d / "a.py").write_text("x = 1\n")
        (d / "sub").mkdir()
        (d / "sub" / "b.py").write_text("y = 2\n")
    fp_a = code_fingerprint([str(tmp_path / "checkout_a")])
    fp_b = code_fingerprint([str(tmp_path / "deeper" / "checkout_b")])
    assert fp_a == fp_b
    # an explicit root (the engine passes the repo root) matches the
    # default common-parent behaviour for a single-tree path list
    assert code_fingerprint([str(tmp_path / "checkout_a")],
                            root=str(tmp_path / "checkout_a")) == fp_a
    # ... but file *names* still matter: renaming changes the fingerprint
    os.rename(str(tmp_path / "checkout_a" / "a.py"),
              str(tmp_path / "checkout_a" / "a2.py"))
    assert code_fingerprint([str(tmp_path / "checkout_a")]) != fp_a


def test_validate_records_reports_each_missing_field():
    rec = {"name": "r"}
    problems = validate_records([rec], "ctx")
    assert len(problems) == len(REQUIRED_RECORD_FIELDS) - 1
    assert all("ctx" in p for p in problems)
    assert validate_records([_rec("ok")], "ctx") == []


# ---------------------------------------------------------------------------
# cached runs
# ---------------------------------------------------------------------------


def test_run_caches_and_todo_empties(tmp_path):
    calls = []

    def runner(exp):
        calls.append(exp.module)
        return [_rec(f"{exp.module}/row")]

    eng = _engine(tmp_path, runner)
    assert len(eng.todo()) == 2
    out = eng.run()
    assert sorted(calls) == ["alpha", "beta"]
    assert len(out["records"]) == 2
    ids = {eng.id_of(e) for e in eng.experiments}
    for rec in out["records"]:  # provenance stamped into the records
        assert rec["fingerprint"] == "fp0"
        assert rec["experiment_id"] in ids
    assert out["fresh_records"] == out["records"]
    assert out["hits"] == []
    assert eng.todo() == []  # the CI cache-hit gate
    # second run: pure cache reads, runner untouched
    out2 = eng.run()
    assert sorted(calls) == ["alpha", "beta"]
    assert len(out2["records"]) == 2
    assert out2["fresh_records"] == []
    assert len(out2["hits"]) == 2 and out2["ran"] == []


def test_force_and_only_filters(tmp_path):
    calls = []

    def runner(exp):
        calls.append(exp.module)
        return [_rec(f"{exp.module}/row")]

    eng = _engine(tmp_path, runner)
    eng.run(only={"alpha"})
    assert calls == ["alpha"]
    assert [e.module for e in eng.todo()] == ["beta"]
    eng.run(only={"alpha"}, force=True)
    assert calls == ["alpha", "alpha"]


def test_fingerprint_change_invalidates_cache(tmp_path):
    runner = lambda exp: [_rec(f"{exp.module}/row")]  # noqa: E731
    _engine(tmp_path, runner, fingerprint="fp0").run()
    stale = _engine(tmp_path, runner, fingerprint="fp1")
    assert len(stale.todo()) == 2  # every entry is fingerprint-fresh


def test_thin_record_fails_loudly_and_does_not_cache(tmp_path):
    def runner(exp):
        return [{"name": f"{exp.module}/row", "ms": 1.0}]  # no compile/peak

    eng = _engine(tmp_path, runner)
    with pytest.raises(ValueError, match="compile_ms"):
        eng.run(only={"alpha"})
    assert any(e.module == "alpha" for e in eng.todo())  # still pending


# ---------------------------------------------------------------------------
# trajectory store
# ---------------------------------------------------------------------------


def test_trajectory_rows_annotated_and_deduplicated(tmp_path):
    runner = lambda exp: [_rec(f"{exp.module}/row")]  # noqa: E731
    eng = _engine(tmp_path, runner)
    eng.run()
    rows = eng.load_trajectory()
    assert len(rows) == 2
    for row in rows:
        assert row["experiment_id"] in {eng.id_of(e) for e in eng.experiments}
        assert row["fingerprint"] == "fp0"
        assert "ts" in row
        for field in REQUIRED_RECORD_FIELDS:
            assert field in row
    # force re-run at the same fingerprint: same (id, name) pairs, no growth
    eng.run(force=True)
    assert len(eng.load_trajectory()) == 2
    # a new fingerprint is a new snapshot: rows append, history preserved
    _engine(tmp_path, runner, fingerprint="fp1").run()
    assert len(eng.load_trajectory()) == 4


def test_report_and_csv_rows(tmp_path):
    runner = lambda exp: [_rec(f"{exp.module}/row")]  # noqa: E731
    eng = _engine(tmp_path, runner)
    eng.run(only={"alpha"})
    states = {r["experiment"]: r["state"] for r in eng.report_rows()}
    assert states == {"alpha[backend=ref]": "cached", "beta": "pending"}
    rows = eng.csv_rows()
    assert rows[0][:4] == ["experiment", "name", "ms", "compile_ms"]
    assert [r[1] for r in rows[1:]] == ["alpha/row"]


def test_load_bench_snapshots_reads_legacy_history(tmp_path):
    (tmp_path / "BENCH_1.json").write_text(json.dumps(
        [{"name": "a", "ms": 1.0}]))
    (tmp_path / "BENCH_2.json").write_text(json.dumps(
        [{"name": "a", "ms": 2.0, "compile_ms": 1.0}, {"no_name": True}]))
    (tmp_path / "BENCH_bad.json").write_text("not json")
    rows = load_bench_snapshots(str(tmp_path))
    assert [(r["snapshot"], r["ms"]) for r in rows] == [
        ("BENCH_1", 1.0), ("BENCH_2", 2.0)]
    assert load_bench_snapshots(str(tmp_path / "nowhere")) == []
