"""Optimizer, checkpointing, compression, straggler, elastic, data."""

import os

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager, restore_latest
from repro.data import SyntheticLMData, TokenPacker
from repro.optim import AdamW, cosine_schedule
from repro.runtime import CompressedAllReduce, StragglerMonitor


def test_adamw_converges_on_quadratic():
    opt = AdamW(learning_rate=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    for step in range(200):
        grads = {"w": 2 * params["w"]}
        upd, state = opt.update(grads, state, params, jnp.int32(step))
        params = jax.tree.map(lambda p, u: p + u, params, upd)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, 10, 100, floor_frac=0.1)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1.0) < 1e-6
    assert abs(float(lr(100)) - 0.1) < 1e-6
    assert float(lr(55)) < float(lr(20))


def test_checkpoint_roundtrip_and_keep_policy(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    tree = {"a": jnp.arange(10, dtype=jnp.float32), "b": {"c": jnp.ones(3)}}
    for s in (1, 2, 3, 4):
        mgr.save(s, jax.tree.map(lambda x: x * s, tree))
    assert mgr.all_steps() == [3, 4]
    like = jax.tree.map(jnp.zeros_like, tree)
    got, step = restore_latest(str(tmp_path), like)
    assert step == 4
    np.testing.assert_allclose(np.asarray(got["a"]), np.arange(10) * 4)


def test_checkpoint_atomicity(tmp_path):
    # a torn .tmp dir is never picked up by restore_latest
    os.makedirs(tmp_path / "step_00000007.tmp")
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    tree = {"x": jnp.zeros(2)}
    mgr.save(3, tree)
    got, step = restore_latest(str(tmp_path), tree)
    assert step == 3


def test_compression_error_feedback_unbiased():
    comp = CompressedAllReduce(mode="int8")
    g_true = {"w": jnp.asarray(np.linspace(-1, 1, 128), jnp.float32)}
    err = comp.init_error(g_true)
    acc = jnp.zeros(128)
    n = 50
    for _ in range(n):
        dec, err = comp.compress_ef(g_true, err)
        acc = acc + dec["w"]
    # error feedback: mean of compressed grads → true grad
    np.testing.assert_allclose(np.asarray(acc / n),
                               np.asarray(g_true["w"]), atol=2e-3)


def test_int8_roundtrip_bounded():
    from repro.runtime.compression import int8_compress, int8_decompress

    x = jnp.asarray(np.random.default_rng(0).normal(0, 3, 1000), jnp.float32)
    q, s = int8_compress(x)
    err = np.abs(np.asarray(int8_decompress(q, s) - x))
    assert err.max() <= float(s) * 0.5 + 1e-6


def test_straggler_detection_and_recovery():
    mon = StragglerMonitor(n_hosts=4, threshold=1.5, patience=2)
    flagged_at = None
    for step in range(10):
        for h in range(4):
            t = 1.0 if h != 2 else (3.0 if step >= 3 else 1.0)
            mon.report(h, t)
        new = mon.evaluate()
        if new and flagged_at is None:
            flagged_at = step
            assert new == [2]
    assert flagged_at is not None and flagged_at >= 4
    # recovery: host 2 speeds back up → unflagged
    for step in range(8):
        for h in range(4):
            mon.report(h, 1.0)
        mon.evaluate()
    assert 2 not in mon.flagged


def test_data_determinism_and_sharding():
    d = SyntheticLMData(vocab_size=100, batch_size=8, seq_len=16, seed=3)
    b1 = d.batch_at(5, shard=0, n_shards=2)
    b2 = d.batch_at(5, shard=0, n_shards=2)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = d.batch_at(5, shard=1, n_shards=2)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    assert b1["tokens"].shape == (4, 16)
    # labels are next-token
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])


def test_token_packer():
    p = TokenPacker(seq_len=8, sep_token=0)
    docs = [np.asarray([1, 2, 3]), np.asarray([4, 5]), np.asarray([6] * 10)]
    rows = p.pack(docs)
    assert rows.shape[1] == 8
    flat = rows.reshape(-1)
    for tok in (1, 2, 3, 4, 5):
        assert tok in flat
    assert (flat == 6).sum() == 10
