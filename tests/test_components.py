"""Unit tests for core/components.py: state-graph expansion, pointer-doubling
connected components, cycle breaking, and chain ranking — plus golden parity
of the fused cc kernel (kernels/cc/, DESIGN.md §2.9) against the jnp
oracle."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.assembly.contig_gen import string_matrix_from_edges
from repro.core.components import (
    break_cycles,
    chain_rank,
    connected_components,
    degrees,
    expand_states,
    path_components,
)
from repro.core.spmat import EllMatrix
from repro.kernels.cc import hbm_round_trips, transpose_ell


def _adj(n, pairs, capacity=4):
    """Directed ELL adjacency from (u, v) pairs."""
    cols = np.full((n, capacity), -1, np.int32)
    fill = np.zeros(n, int)
    for u, v in sorted(pairs):
        cols[u, fill[u]] = v
        fill[u] += 1
    return EllMatrix(
        cols=jnp.asarray(cols),
        vals=jnp.zeros((n, capacity), jnp.float32),
        n_cols=n,
    )


def test_expand_states_maps_combos_to_state_edges():
    # edge 0→1 at strands (0,1) suffix 30 → state edge 0 → 3
    # edge 1→2 at strands (1,0) suffix 20 → state edge 3 → 4
    s = string_matrix_from_edges(3, [(0, 1, 0, 1, 30), (1, 2, 1, 0, 20)])
    g = expand_states(s)
    assert g.n_cols == 6 and g.n_rows == 6
    cols = np.asarray(g.cols)
    vals = np.asarray(g.vals)
    edges = {
        (u, int(cols[u, q])): float(vals[u, q])
        for u in range(6)
        for q in range(cols.shape[1])
        if cols[u, q] >= 0
    }
    assert edges == {(0, 3): 30.0, (3, 4): 20.0}
    out_deg, in_deg = degrees(g)
    assert out_deg.tolist() == [1, 0, 0, 1, 0, 0]
    assert in_deg.tolist() == [0, 0, 0, 1, 1, 0]


def test_expand_states_rows_sorted():
    s = string_matrix_from_edges(
        4, [(2, 3, 1, 1, 5), (2, 0, 1, 0, 9), (2, 1, 1, 1, 7)]
    )
    g = expand_states(s)
    row = np.asarray(g.cols[5])  # state (2, strand 1)
    live = row[row >= 0]
    assert list(live) == sorted(live)
    assert set(live) == {0, 3, 7}


def test_connected_components_labels_and_isolated():
    # components {0,1,2}, {3,4} (edge given one direction only), {5} isolated
    adj = _adj(6, [(0, 1), (1, 2), (4, 3)])
    labels, iters = connected_components(adj)
    assert labels.tolist() == [0, 0, 0, 3, 3, 5]
    assert int(iters) >= 1


def test_connected_components_long_path_converges_logarithmically():
    n = 256
    adj = _adj(n, [(i, i + 1) for i in range(n - 1)], capacity=1)
    labels, iters = connected_components(adj)
    assert labels.tolist() == [0] * n
    assert int(iters) <= 2 * int(np.ceil(np.log2(n))) + 4


def test_path_components_permuted_path_is_logarithmic():
    """A single chain whose vertex ids are randomly permuted along it: the
    doubling labeler must find the mid-chain minimum in O(log n) rounds
    (min-label propagation needs Θ(n) hook rounds here)."""
    n = 257
    rng = np.random.default_rng(2)
    perm = rng.permutation(n)
    succ = np.full(n, -1, np.int32)
    pred = np.full(n, -1, np.int32)
    for i in range(n - 1):
        succ[perm[i]] = perm[i + 1]
        pred[perm[i + 1]] = perm[i]
    labels, iters = path_components(jnp.asarray(succ), jnp.asarray(pred))
    assert labels.tolist() == [0] * n
    assert int(iters) <= int(np.ceil(np.log2(n))) + 1


def test_path_components_multiple_chains_and_isolated():
    # chains 4→2→0 and 1→3; 5 isolated
    succ = jnp.asarray([-1, 3, 0, -1, 2, -1], jnp.int32)
    pred = jnp.asarray([2, -1, 4, 1, -1, -1], jnp.int32)
    labels, _ = path_components(succ, pred)
    assert labels.tolist() == [0, 1, 0, 1, 0, 5]


def test_chain_rank_on_paths():
    #  0→1→2→3  and 4→5; pred pointers, -1 at heads
    pred = jnp.asarray([-1, 0, 1, 2, -1, 4], jnp.int32)
    head, rank, iters = chain_rank(pred)
    assert head.tolist() == [0, 0, 0, 0, 4, 4]
    assert rank.tolist() == [0, 1, 2, 3, 0, 1]
    assert int(iters) <= int(np.ceil(np.log2(6))) + 1


def test_break_cycles_cuts_at_minimum():
    # cycle 1→4→2→1 plus path 0→3
    succ = jnp.asarray([3, 4, 1, -1, 2, -1], jnp.int32)
    pred = jnp.asarray([-1, 2, 4, 0, 1, -1], jnp.int32)
    s2, p2, n_cut = break_cycles(succ, pred)
    assert int(n_cut) == 1
    assert s2.tolist() == [3, 4, -1, -1, 2, -1]  # edge 2→1 cut (1 = cycle min)
    assert p2.tolist() == [-1, -1, 4, 0, 1, -1]
    # the cut graph is pure paths: chain_rank converges with head=1 for cycle
    head, rank, _ = chain_rank(p2)
    assert head.tolist() == [0, 1, 1, 0, 1, 5]
    assert rank.tolist() == [0, 0, 2, 1, 1, 0]


def test_break_cycles_self_loop():
    succ = jnp.asarray([0, -1], jnp.int32)
    pred = jnp.asarray([0, -1], jnp.int32)
    s2, p2, n_cut = break_cycles(succ, pred)
    assert int(n_cut) == 1
    assert s2.tolist() == [-1, -1] and p2.tolist() == [-1, -1]


# ---------------------------------------------------------------------------
# Fused cc kernel: golden parity vs the jnp oracle (DESIGN.md §2.9).
# ---------------------------------------------------------------------------


def _permuted_chain_adj(n, seed, capacity=2):
    """A single path whose vertex ids are randomly permuted along it — the
    adversarial Θ(n)-round case for min-label propagation."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    return _adj(
        n, [(int(perm[i]), int(perm[i + 1])) for i in range(n - 1)],
        capacity=capacity,
    )


def _cycle_heavy_adj(n, cycle, seed, capacity=4):
    """n/cycle disjoint directed cycles with shuffled vertex order."""
    rng = np.random.default_rng(seed)
    pairs = []
    for c0 in range(0, n, cycle):
        cyc = [c0 + t for t in range(cycle)]
        rng.shuffle(cyc)
        pairs += [(cyc[t], cyc[(t + 1) % cycle]) for t in range(cycle)]
    return _adj(n, pairs, capacity=capacity)


@pytest.mark.parametrize("make_adj", [
    lambda: _permuted_chain_adj(257, seed=2),
    lambda: _cycle_heavy_adj(320, cycle=10, seed=3),
    lambda: _cycle_heavy_adj(96, cycle=3, seed=4),
])
def test_cc_kernel_golden_parity(make_adj):
    """Fused kernel labels must equal the oracle's bit-for-bit.  HBM
    round-trip accounting: the oracle pays one trip per round, the fused
    path one per chunk of 8 rounds (+1 confirming chunk), so it never pays
    more than the oracle's ceil-to-chunks and wins strictly whenever
    convergence is slower than one chunk."""
    adj = make_adj()
    lr, ir = connected_components(adj, backend="reference")
    lp, ip = connected_components(adj, backend="pallas")
    assert np.array_equal(np.asarray(lr), np.asarray(lp))
    # oracle: one HBM round trip per round; fused: one per chunk of 8
    trips = hbm_round_trips(int(ip))
    assert trips <= hbm_round_trips(int(ir)) + 1
    if int(ir) > 8:
        assert trips < int(ir)


def test_cc_kernel_parity_on_random_graphs():
    rng = np.random.default_rng(7)
    for trial in range(3):
        n = int(rng.integers(40, 200))
        e = int(rng.integers(n // 2, 2 * n))
        pairs = {(int(rng.integers(n)), int(rng.integers(n)))
                 for _ in range(e)}
        cap = max(sum(1 for u, _ in pairs if u == r) for r in range(n))
        adj = _adj(n, sorted(pairs), capacity=max(cap, 1))
        lr, _ = connected_components(adj, backend="reference")
        lp, _ = connected_components(adj, backend="pallas")
        assert np.array_equal(np.asarray(lr), np.asarray(lp)), trial


def test_transpose_ell_lists_in_neighbours():
    adj = _adj(5, [(0, 2), (1, 2), (3, 2), (4, 0)], capacity=2)
    t = np.asarray(transpose_ell(adj.cols))
    ins = {r: sorted(int(c) for c in t[r] if c >= 0) for r in range(5)}
    assert ins == {0: [4], 1: [], 2: [0, 1, 3], 3: [], 4: []}


@pytest.mark.parametrize("p", [1, 2, 3, 4, 5, 8])
def test_sort_network_is_valid_sorting_network(p):
    """The cross-shard comparator schedule (DESIGN.md §2.10) must be a valid
    sorting network on P wires — checked exhaustively by the 0-1 principle —
    and, by the sorted-block adaptation theorem, its merge-split form must
    sort blocks: verified directly on random blocks."""
    from repro.core.components_dist import n_sort_stages, sort_network

    stages = sort_network(p)
    assert len(stages) == n_sort_stages(p)
    # 0-1 principle: a comparator network sorts everything iff it sorts
    # every 0-1 input
    for bits in range(2 ** p):
        v = [(bits >> i) & 1 for i in range(p)]
        for st_pairs in stages:
            for lo, hi in st_pairs:
                if v[lo] > v[hi]:
                    v[lo], v[hi] = v[hi], v[lo]
        assert v == sorted(v), (p, bits)
    # merge-split on sorted blocks (the form the shard_map region runs)
    rng = np.random.default_rng(p)
    blocks = [sorted(rng.integers(0, 50, 6).tolist()) for _ in range(p)]
    for st_pairs in stages:
        for lo, hi in st_pairs:
            merged = sorted(blocks[lo] + blocks[hi])
            blocks[lo], blocks[hi] = merged[:6], merged[6:]
    flat = [x for b in blocks for x in b]
    assert flat == sorted(flat)
