"""Hypothesis with a deterministic fallback.

The property-test modules import ``given``/``settings``/``st`` from here.
When the real ``hypothesis`` package is installed it is used unchanged; when
it is not (minimal CI images), a small deterministic strategy engine stands
in so the property tests still *run* instead of erroring at collection.

The fallback covers exactly the strategy surface these tests use —
``integers``, ``floats``, ``just``, ``sampled_from``, ``lists``, ``tuples``,
``text``, ``one_of`` (``|``) and ``.map`` — draws a fixed number of examples
from a per-test seeded RNG (so failures reproduce), and always tries the
minimal example first (empty lists, lower bounds) the way hypothesis's
shrinking would surface it.
"""

from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    import os as _os

    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True

    # CI property leg: a fixed derandomized profile so the hypothesis run
    # is reproducible across jobs — select with REPRO_HYPOTHESIS_PROFILE=ci
    # (the fallback engine below is already deterministic, so the variable
    # is only meaningful when the real package is installed).
    settings.register_profile(
        "ci", settings(derandomize=True, max_examples=50, deadline=None))
    _profile = _os.environ.get("REPRO_HYPOTHESIS_PROFILE")
    if _profile:
        settings.load_profile(_profile)
except ImportError:
    HAVE_HYPOTHESIS = False

    import functools
    import types
    import zlib

    import numpy as np

    _DEFAULT_MAX_EXAMPLES = 25

    class _Strategy:
        def example(self, rng):
            raise NotImplementedError

        def minimal(self):
            raise NotImplementedError

        def map(self, f):
            return _Mapped(self, f)

        def __or__(self, other):
            return _OneOf(self, other)

    class _Mapped(_Strategy):
        def __init__(self, base, f):
            self.base, self.f = base, f

        def example(self, rng):
            return self.f(self.base.example(rng))

        def minimal(self):
            return self.f(self.base.minimal())

    class _OneOf(_Strategy):
        def __init__(self, *opts):
            self.opts = []
            for o in opts:  # flatten nested (a | b) | c
                self.opts.extend(o.opts if isinstance(o, _OneOf) else [o])

        def example(self, rng):
            return self.opts[int(rng.integers(len(self.opts)))].example(rng)

        def minimal(self):
            return self.opts[0].minimal()

    class _Integers(_Strategy):
        def __init__(self, min_value=0, max_value=2**31 - 1):
            self.lo, self.hi = int(min_value), int(max_value)

        def example(self, rng):
            return int(rng.integers(self.lo, self.hi + 1))

        def minimal(self):
            return self.lo

    class _Floats(_Strategy):
        def __init__(self, min_value=0.0, max_value=1.0):
            self.lo, self.hi = float(min_value), float(max_value)

        def example(self, rng):
            return float(rng.uniform(self.lo, self.hi))

        def minimal(self):
            return self.lo

    class _Just(_Strategy):
        def __init__(self, value):
            self.value = value

        def example(self, rng):
            return self.value

        def minimal(self):
            return self.value

    class _SampledFrom(_Strategy):
        def __init__(self, elements):
            self.elements = list(elements)

        def example(self, rng):
            return self.elements[int(rng.integers(len(self.elements)))]

        def minimal(self):
            return self.elements[0]

    class _Lists(_Strategy):
        def __init__(self, elem, min_size=0, max_size=None):
            self.elem = elem
            self.lo = int(min_size)
            self.hi = int(max_size) if max_size is not None else self.lo + 10

        def example(self, rng):
            n = int(rng.integers(self.lo, self.hi + 1))
            return [self.elem.example(rng) for _ in range(n)]

        def minimal(self):
            return [self.elem.minimal() for _ in range(self.lo)]

    class _Tuples(_Strategy):
        def __init__(self, *elems):
            self.elems = elems

        def example(self, rng):
            return tuple(e.example(rng) for e in self.elems)

        def minimal(self):
            return tuple(e.minimal() for e in self.elems)

    class _Text(_Strategy):
        def __init__(self, alphabet="abc", min_size=0, max_size=None):
            self.alphabet = list(alphabet)
            self.lo = int(min_size)
            self.hi = int(max_size) if max_size is not None else self.lo + 10

        def example(self, rng):
            n = int(rng.integers(self.lo, self.hi + 1))
            return "".join(
                self.alphabet[int(i)]
                for i in rng.integers(0, len(self.alphabet), n)
            )

        def minimal(self):
            return self.alphabet[0] * self.lo

    st = types.SimpleNamespace(
        integers=_Integers,
        floats=_Floats,
        just=_Just,
        sampled_from=_SampledFrom,
        lists=_Lists,
        tuples=_Tuples,
        text=_Text,
        one_of=lambda *opts: _OneOf(*opts),
    )

    def given(*strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper():
                n = getattr(wrapper, "_max_examples", _DEFAULT_MAX_EXAMPLES)
                rng = np.random.default_rng(zlib.crc32(fn.__name__.encode()))
                for i in range(n):
                    if i == 0:
                        args = tuple(s.minimal() for s in strategies)
                    else:
                        args = tuple(s.example(rng) for s in strategies)
                    try:
                        fn(*args)
                    except Exception as exc:
                        raise AssertionError(
                            f"falsifying example #{i}: {fn.__name__}{args!r}"
                        ) from exc

            # pytest resolves fixtures through __wrapped__'s signature; the
            # drawn arguments are not fixtures, so hide the original.
            del wrapper.__wrapped__
            return wrapper

        return deco

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco
