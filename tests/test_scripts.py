"""CI gate scripts (``scripts/check_bench_regression.py``,
``scripts/check_trace.py`` and ``scripts/check_smoke_comm.py``) against
pass/fail fixtures.

The scripts are stdlib-only and loaded by file path (``scripts/`` is not a
package); the fixtures pin both directions of each gate — a clean run
exits 0 and each contract violation (gross slowdown, watermark growth,
dropped row, broken span nesting, missing memory attribution) produces a
targeted failure instead of a silent pass."""

import importlib.util
import json
import os

import pytest

_SCRIPTS = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "scripts")


def _load_script(name):
    spec = importlib.util.spec_from_file_location(
        name, os.path.join(_SCRIPTS, name + ".py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.fixture(scope="module")
def cbr():
    return _load_script("check_bench_regression")


@pytest.fixture(scope="module")
def ctr():
    return _load_script("check_trace")


# ---------------------------------------------------------------------------
# check_bench_regression
# ---------------------------------------------------------------------------


def _row(name, ms=10.0, peak=4 << 20, split=True, experiment=None):
    rec = {"name": name, "ms": ms, "peak_hbm_bytes": peak}
    if split:
        rec["compile_ms"] = 1.0
    if experiment is not None:
        rec["experiment"] = experiment
    return rec


def _write(path, rows):
    if str(path).endswith(".jsonl"):
        path.write_text("".join(json.dumps(r) + "\n" for r in rows))
    else:
        path.write_text(json.dumps(rows))
    return str(path)


def test_cbr_clean_run_passes(tmp_path, cbr, capsys):
    fresh = _write(tmp_path / "fresh.json", [_row("a"), _row("b")])
    prev = _write(tmp_path / "traj.jsonl",
                  [_row("a", ms=9.0), _row("b", ms=11.0)])
    assert cbr.main([fresh, prev]) == 0
    assert "no gross perf/memory regression" in capsys.readouterr().out


def test_cbr_time_regression_fails(tmp_path, cbr, capsys):
    fresh = _write(tmp_path / "fresh.json", [_row("a", ms=100.0)])
    prev = _write(tmp_path / "prev.json", [_row("a", ms=10.0)])
    assert cbr.main([fresh, prev]) == 1
    assert "previous best 10.0 ms" in capsys.readouterr().out


def test_cbr_memory_regression_fails(tmp_path, cbr, capsys):
    fresh = _write(tmp_path / "fresh.json",
                   [_row("a", peak=40 << 20)])
    prev = _write(tmp_path / "prev.json", [_row("a", peak=4 << 20)])
    assert cbr.main([fresh, prev]) == 1
    assert "watermark grew" in capsys.readouterr().out


def test_cbr_small_pools_skip_memory_gate(tmp_path, cbr):
    # both sides under MIN_BYTES: allocator noise, not working-set growth
    fresh = _write(tmp_path / "fresh.json", [_row("a", peak=900_000)])
    prev = _write(tmp_path / "prev.json", [_row("a", peak=1_000)])
    assert cbr.main([fresh, prev]) == 0


def test_cbr_missing_memory_baseline_skips_gate(tmp_path, cbr):
    fresh = _write(tmp_path / "fresh.json", [_row("a", peak=1 << 30)])
    prev = _write(tmp_path / "prev.json",
                  [{"name": "a", "ms": 10.0, "compile_ms": 1.0}])
    assert cbr.main([fresh, prev]) == 0


def test_cbr_dropped_row_fails_coverage(tmp_path, cbr, capsys):
    fresh = _write(tmp_path / "fresh.json", [_row("a")])
    prev = _write(tmp_path / "prev.json", [_row("a"), _row("gone")])
    assert cbr.main([fresh, prev]) == 1
    assert "missing from fresh records" in capsys.readouterr().out


def test_cbr_out_of_scope_experiment_is_not_a_drop(tmp_path, cbr):
    # trajectory holds a full-size experiment the smoke run never executes:
    # out of scope, not a dropped benchmark
    fresh = _write(tmp_path / "fresh.json",
                   [_row("a", experiment="tr")])
    prev = _write(tmp_path / "traj.jsonl",
                  [_row("a", experiment="tr"),
                   _row("big/row", experiment="sparsity")])
    assert cbr.main([fresh, prev]) == 0


def test_cbr_pre_split_baseline_skipped_with_notice(tmp_path, cbr, capsys):
    fresh = _write(tmp_path / "fresh.json", [_row("a", ms=1000.0, peak=None)])
    prev = _write(tmp_path / "prev.json",
                  [{"name": "a", "ms": 1.0}])  # pre-split era
    assert cbr.main([fresh, prev]) == 0
    assert "skipped, not compared" in capsys.readouterr().out


def test_cbr_best_within_one_file_wins(tmp_path, cbr, capsys):
    # a .jsonl trajectory holds one row per code snapshot: the baseline is
    # the best ever recorded, not merely the most recent row (otherwise
    # each PR may regress 5x vs the previous PR — ratchet creep)
    fresh = _write(tmp_path / "fresh.json", [_row("a", ms=30.0)])
    prev = _write(tmp_path / "traj.jsonl",
                  [_row("a", ms=2.0), _row("a", ms=29.0)])
    assert cbr.main([fresh, prev]) == 1  # 30 > 5 x 2, not vs 29
    assert "previous best 2.0 ms" in capsys.readouterr().out


def test_cbr_within_file_smallest_watermark_wins(tmp_path, cbr, capsys):
    fresh = _write(tmp_path / "fresh.json", [_row("a", peak=40 << 20)])
    prev = _write(tmp_path / "traj.jsonl",
                  [_row("a", peak=4 << 20), _row("a", peak=39 << 20)])
    assert cbr.main([fresh, prev]) == 1
    assert "watermark grew" in capsys.readouterr().out


def test_cbr_later_pre_split_row_keeps_split_baseline(tmp_path, cbr, capsys):
    # a pre-split row appended after a split one must not displace it
    fresh = _write(tmp_path / "fresh.json", [_row("a", ms=100.0)])
    prev = _write(tmp_path / "traj.jsonl",
                  [_row("a", ms=10.0), {"name": "a", "ms": 0.5}])
    assert cbr.main([fresh, prev]) == 1
    assert "previous best 10.0 ms" in capsys.readouterr().out


def test_cbr_fresh_fingerprint_rows_excluded_from_baseline(
        tmp_path, cbr, capsys):
    # CI: the engine appends fresh rows to the trajectory before the gate
    # runs; rows stamped with the fresh run's fingerprint must not serve
    # as baseline or the ratio gates compare a measurement to itself
    fresh = _write(tmp_path / "fresh.json",
                   [dict(_row("a", ms=100.0), fingerprint="fpNEW")])
    prev = _write(tmp_path / "traj.jsonl",
                  [dict(_row("a", ms=10.0), fingerprint="fpOLD"),
                   dict(_row("a", ms=100.0), fingerprint="fpNEW")])
    assert cbr.main([fresh, prev]) == 1
    assert "previous best 10.0 ms" in capsys.readouterr().out
    # a store holding only the self-snapshot means the trajectory starts
    # here: zero shared rows, clean pass — not a silent self-comparison
    only_self = _write(tmp_path / "self.jsonl",
                       [dict(_row("a", ms=100.0), fingerprint="fpNEW")])
    assert cbr.main([fresh, only_self]) == 0
    assert "0 shared row(s)" in capsys.readouterr().out


def test_cbr_unlabelled_fresh_keeps_full_coverage(tmp_path, cbr, capsys):
    # legacy benchmarks/run.py output carries no experiment labels: every
    # labelled baseline row stays in scope, so a dropped row still fails
    # instead of being skipped as "out of scope"
    fresh = _write(tmp_path / "fresh.json", [_row("a")])
    prev = _write(tmp_path / "traj.jsonl",
                  [_row("a"), _row("gone", experiment="sparsity")])
    assert cbr.main([fresh, prev]) == 1
    assert "missing from fresh records" in capsys.readouterr().out


def test_cbr_best_previous_wins_across_baselines(tmp_path, cbr):
    fresh = _write(tmp_path / "fresh.json", [_row("a", ms=30.0)])
    slow = _write(tmp_path / "p1.json", [_row("a", ms=29.0)])
    fast = _write(tmp_path / "p2.jsonl", [_row("a", ms=2.0)])
    assert cbr.main([fresh, slow]) == 0
    assert cbr.main([fresh, slow, fast]) == 1  # 30 > 5 x 2


def test_cbr_usage_and_no_baseline(tmp_path, cbr, monkeypatch):
    assert cbr.main([]) == 2
    # no baselines anywhere: trajectory starts here
    monkeypatch.setattr(cbr, "_default_baselines", lambda fresh: [])
    fresh = _write(tmp_path / "fresh.json", [_row("a")])
    assert cbr.main([fresh]) == 0


def test_cbr_default_baselines_prefer_trajectory(tmp_path, cbr):
    root = str(tmp_path)
    assert cbr._default_baselines("fresh.json", root=root) == []
    _write(tmp_path / "BENCH_2.json", [_row("a", ms=1.0)])
    _write(tmp_path / "BENCH_10.json", [_row("a", ms=50.0)])
    found = cbr._default_baselines("fresh.json", root=root)
    assert [os.path.basename(p) for p in found] == \
        ["BENCH_10.json"]  # numeric, not lexicographic, latest
    # the fresh file itself never serves as its own baseline
    fresh = str(tmp_path / "BENCH_10.json")
    found = cbr._default_baselines(fresh, root=root)
    assert [os.path.basename(p) for p in found] == ["BENCH_2.json"]
    (tmp_path / "bench").mkdir()
    _write(tmp_path / "bench" / "trajectory.jsonl", [_row("a")])
    found = cbr._default_baselines("fresh.json", root=root)
    assert [os.path.basename(p) for p in found] == ["trajectory.jsonl"]


# ---------------------------------------------------------------------------
# check_trace
# ---------------------------------------------------------------------------


_MEM = {"peak_hbm_bytes": 1024, "hbm_bytes_in_use": 512,
        "hbm_source": "live_buffers"}


def _node(name, kind, children=(), **attrs):
    return {"name": name, "attrs": {"kind": kind, **attrs},
            "children": list(children)}


def _valid_tree(ctr):
    def stage(name, children=()):
        return _node(name, "stage", children, **_MEM)

    def phase(ph, children=()):
        return _node(ph, "phase", children, phase=ph)

    spgemm_children = [
        phase("skew"),
        phase("ring", [phase("ring_stage",
                             [_node("op", "op",
                                    [_node("k", "kernel", kernel="mp")])])]),
        phase("collect_merge"),
    ]
    contig_children = [phase("chain_stage",
                             [phase("cut"), phase("doubling"),
                              phase("sort")])]
    align_children = [phase("pair_exchange",
                            [phase("gather_reads"),
                             phase("extend",
                                   [_node("op", "op",
                                          [_node("k", "kernel",
                                                 kernel="xdrop")])]),
                             phase("scatter_scores")])]
    tree = []
    for name in ctr.STAGES:
        kids = ({"SpGEMM": spgemm_children,
                 "Contigs": contig_children,
                 "Alignment": align_children}.get(name, ()))
        tree.append(stage(name, kids))
    return tree


def test_ctr_valid_tree_passes(ctr):
    assert ctr.check(_valid_tree(ctr)) == []


def test_ctr_missing_stage_and_order(ctr):
    tree = _valid_tree(ctr)
    tree[0], tree[1] = tree[1], tree[0]
    assert any("out of Algorithm 1 order" in m for m in ctr.check(tree))
    assert any("missing stage root" in m for m in ctr.check(tree[1:]))


def test_ctr_missing_memory_attribution_fails(ctr):
    tree = _valid_tree(ctr)
    del tree[3]["attrs"]["peak_hbm_bytes"]  # Alignment
    msgs = ctr.check(tree)
    assert any("memory attribution" in m and "Alignment" in m for m in msgs)


def test_ctr_missing_ring_or_chain_phase_fails(ctr):
    tree = _valid_tree(ctr)
    spgemm = next(n for n in tree if n["name"] == "SpGEMM")
    spgemm["children"] = [c for c in spgemm["children"]
                          if c["name"] != "ring"]
    msgs = ctr.check(tree)
    assert any("ring_stage" in m for m in msgs)
    tree2 = _valid_tree(ctr)
    contigs = next(n for n in tree2 if n["name"] == "Contigs")
    contigs["children"] = []
    assert any("chain_stage" in m for m in ctr.check(tree2))


def test_ctr_missing_align_phase_fails(ctr):
    tree = _valid_tree(ctr)
    align = next(n for n in tree if n["name"] == "Alignment")
    align["children"] = []
    msgs = ctr.check(tree)
    for ph in ("pair_exchange", "gather_reads", "extend", "scatter_scores"):
        assert any(f"phase={ph!r}" in m and "Alignment" in m for m in msgs)


def test_ctr_kernel_outside_op_fails(ctr):
    tree = _valid_tree(ctr)
    tree[0]["children"] = [_node("stray", "kernel", kernel="x")]
    msgs = ctr.check(tree)
    assert any("bypassed the dispatch layer" in m for m in msgs)


def test_ctr_main_exit_codes(tmp_path, ctr, capsys):
    good = tmp_path / "good.json"
    good.write_text(json.dumps({"traceEvents": [],
                                "spanTree": _valid_tree(ctr)}))
    assert ctr.main([str(good)]) == 0
    assert "span-tree structure ok" in capsys.readouterr().out
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"traceEvents": []}))
    assert ctr.main([str(bad)]) == 1
    assert ctr.main([]) == 2


# ---------------------------------------------------------------------------
# check_smoke_comm
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def csc():
    return _load_script("check_smoke_comm")


def _comm_row(op, shape, derived):
    return {"name": f"{op}[shard_map]/{shape}", "op": op,
            "backend": "shard_map", "shape": shape, "ms": 1.0,
            "derived": derived}


def _valid_artifact():
    return [
        _comm_row("contigs", "n256",
                  "exchange_words_sort=100;model_words_sort=100"),
        _comm_row("overlap", "ring_2x2",
                  "exchange_words_summa=200;model_words_summa=200"),
        _comm_row("align", "bucket512_P4",
                  "exchange_words_align=300;model_words_align=300"),
    ]


def test_csc_valid_artifact_passes(tmp_path, csc, capsys):
    path = _write(tmp_path / "bench.json", _valid_artifact())
    assert csc.main([path]) == 0
    out = capsys.readouterr().out
    assert "comm-model cross-check ok" in out
    assert "1 align" in out


def test_csc_missing_align_row_fails(tmp_path, csc, capsys):
    # a smoke artifact without the distributed-alignment row means the
    # distribution axis was silently dropped — CI must fail, not pass
    records = [r for r in _valid_artifact() if r["op"] != "align"]
    path = _write(tmp_path / "bench.json", records)
    assert csc.main([path]) == 1
    assert "no align[*/shard_map] rows found" in capsys.readouterr().out


def test_csc_align_word_mismatch_fails(tmp_path, csc, capsys):
    records = _valid_artifact()
    records[-1]["derived"] = \
        "exchange_words_align=300;model_words_align=600"
    path = _write(tmp_path / "bench.json", records)
    assert csc.main([path]) == 1
    assert "exchange_words_align=300" in capsys.readouterr().out


def test_csc_missing_align_fields_fails(tmp_path, csc, capsys):
    records = _valid_artifact()
    records[-1]["derived"] = "bucket=512"
    path = _write(tmp_path / "bench.json", records)
    assert csc.main([path]) == 1
    assert "missing exchange_words_align" in capsys.readouterr().out


def test_csc_degenerate_p1_rows_pass(tmp_path, csc):
    # P == 1: every exchange degenerates, both sides exactly 0
    records = [
        _comm_row("contigs", "n256",
                  "exchange_words_sort=0;model_words_sort=0"),
        _comm_row("overlap", "ring_1x1",
                  "exchange_words_summa=0;model_words_summa=0"),
        _comm_row("align", "bucket512_P1",
                  "exchange_words_align=0;model_words_align=0"),
    ]
    path = _write(tmp_path / "bench.json", records)
    assert csc.main([path]) == 0
