"""Semiring laws (property-based) — correctness of Algorithm 3's algebra."""

import numpy as np
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st

from repro.core.semiring import (
    minplus_orient_semiring as SR,
    overlap_semiring as OV,
    mp_value,
)


def mp_vals(draw_inf=True):
    elem = st.floats(1, 1e5) | (st.just(np.inf) if draw_inf else st.floats(1, 1e5))
    return st.lists(elem, min_size=4, max_size=4).map(
        lambda v: jnp.asarray(v, jnp.float32)
    )


@settings(max_examples=50, deadline=None)
@given(mp_vals(), mp_vals(), mp_vals())
def test_minplus_add_assoc_comm(a, b, c):
    add = SR.add
    x = add(add(a, b), c)
    y = add(a, add(b, c))
    np.testing.assert_allclose(np.asarray(x), np.asarray(y))
    np.testing.assert_allclose(np.asarray(add(a, b)), np.asarray(add(b, a)))


@settings(max_examples=50, deadline=None)
@given(mp_vals(), mp_vals(), mp_vals())
def test_minplus_mul_distributes_over_add(a, b, c):
    # a ⊗ (b ⊕ c) == (a ⊗ b) ⊕ (a ⊗ c)  — required for SpGEMM correctness
    lhs = SR.mul(a, SR.add(b, c))
    rhs = SR.add(SR.mul(a, b), SR.mul(a, c))
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs))


@settings(max_examples=50, deadline=None)
@given(mp_vals(), mp_vals())
def test_minplus_zero_absorbs(a, b):
    zero = SR.zero(())
    assert bool(SR.is_zero(SR.mul(a, zero)))
    np.testing.assert_allclose(np.asarray(SR.add(a, zero)), np.asarray(a))


def test_minplus_mul_is_oriented_2x2_matmul():
    # edge i→k strands (0,1) suffix 5; edge k→j strands (1,0) suffix 7:
    # consistent middle strand (1 == 1) → combo (0,0) value 12
    a = mp_value(5.0, 0, 1)
    b = mp_value(7.0, 1, 0)
    out = np.asarray(SR.mul(a, b))
    assert out[0] == 12.0 and np.isinf(out[1:]).all()
    # inconsistent middle: k used in strand 1 by left, strand 0 expected
    b2 = mp_value(7.0, 0, 0)
    assert np.isinf(np.asarray(SR.mul(a, b2))).all()


def test_overlap_semiring_counts_and_pairs():
    a = {"pos": jnp.int32(10)}
    b = {"pos": jnp.int32(20)}
    one = OV.mul(a, b)
    assert int(one["cnt"]) == 1
    two = OV.add(one, OV.mul({"pos": jnp.int32(30)}, {"pos": jnp.int32(40)}))
    assert int(two["cnt"]) == 2
    assert two["apos"].tolist() == [10, 30]
    three = OV.add(two, OV.mul({"pos": jnp.int32(50)}, {"pos": jnp.int32(60)}))
    assert int(three["cnt"]) == 3
    assert three["apos"].tolist() == [10, 30]  # capped at NUM_POS_PAIRS


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, 1000), min_size=1, max_size=6))
def test_overlap_add_associative_in_count(posns):
    vals = [OV.mul({"pos": jnp.int32(p)}, {"pos": jnp.int32(p + 1)})
            for p in posns]
    left = vals[0]
    for v in vals[1:]:
        left = OV.add(left, v)
    right = vals[-1]
    for v in reversed(vals[:-1]):
        right = OV.add(v, right)
    assert int(left["cnt"]) == int(right["cnt"]) == len(posns)
    assert left["apos"].tolist() == right["apos"].tolist()
