"""Docs-surface checks in tier-1: markdown links resolve and the public-API
docstring lint passes (the same scripts the CI docs job runs, so a broken
README link or an undocumented public function fails locally first)."""

import os
import subprocess
import sys

REPO = os.path.join(os.path.dirname(__file__), "..")


def _run(script):
    r = subprocess.run(
        [sys.executable, os.path.join(REPO, "scripts", script)],
        capture_output=True, text=True, timeout=120,
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_docs_links_resolve():
    out = _run("check_docs_links.py")
    assert "links ok" in out


def test_public_api_docstrings():
    out = _run("lint_docstrings.py")
    assert "docstring lint clean" in out


def test_readme_exists_with_required_sections():
    with open(os.path.join(REPO, "README.md")) as f:
        readme = f.read()
    # the satellite contract: quickstart, tier-1 command, the matrix, DESIGN
    assert "pytest -x -q" in readme
    assert "examples/quickstart.py" in readme
    assert "distribution" in readme and "backend" in readme
    assert "DESIGN.md" in readme
    assert os.path.exists(os.path.join(REPO, "docs", "communication.md"))
