"""Fault-tolerant checkpointing: atomic, shard-aware, async, elastic.

Design (DESIGN.md §3):
  * a checkpoint is a directory ``step_<n>/`` holding one ``.npz`` per host
    plus a ``meta.json`` (step, tree structure, mesh shape, config hash);
  * writes go to ``step_<n>.tmp`` and are renamed atomically — a crash
    mid-write never corrupts the latest checkpoint (restart-safety);
  * arrays are stored by *logical* (global) value, so restoring onto a
    different mesh/process count just re-shards at device_put — this is the
    elastic-scaling path (tested in tests/test_checkpoint.py);
  * ``CheckpointManager`` keeps the most recent ``keep`` checkpoints, can
    write asynchronously on a background thread, and ``restore_latest``
    scans for the newest complete checkpoint (skipping torn ``.tmp`` dirs).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(p) for p in path)
        out[key] = np.asarray(jax.device_get(leaf))
    return out, treedef


def save_pytree(path: str, tree: Any, *, meta: Optional[dict] = None) -> None:
    """Atomic save of a pytree to ``path`` (a directory)."""
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    arrs, _ = _flatten_with_paths(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrs)
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(
            {"meta": meta or {}, "keys": sorted(arrs.keys()),
             "time": time.time()},
            f,
        )
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)


def load_pytree(path: str, like: Any, *, shardings: Any = None) -> Any:
    """Load into the structure of ``like``; optionally device_put with the
    given shardings (elastic restore onto any mesh)."""
    data = np.load(os.path.join(path, "arrays.npz"))
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, leaf in flat:
        key = "/".join(str(x) for x in p)
        arr = data[key]
        leaves.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree


class CheckpointManager:
    """Keep-policy + optional async writer."""

    def __init__(self, directory: str, *, keep: int = 3, async_write: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:08d}")

    def all_steps(self):
        out = []
        for d in os.listdir(self.directory):
            if d.startswith("step_") and not d.endswith(".tmp"):
                try:
                    out.append(int(d.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree: Any, *, meta: Optional[dict] = None):
        # snapshot to host before handing to the writer thread
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def _write():
            save_pytree(self._step_dir(step), host_tree, meta=meta)
            self._gc()

        self.wait()
        if self.async_write:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    def restore(self, step: int, like: Any, *, shardings: Any = None):
        return load_pytree(self._step_dir(step), like, shardings=shardings)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None


def restore_latest(directory: str, like: Any, *, shardings: Any = None):
    """Returns (tree, step) from the newest complete checkpoint, or
    (None, None)."""
    mgr = CheckpointManager(directory, async_write=False)
    step = mgr.latest_step()
    if step is None:
        return None, None
    return mgr.restore(step, like, shardings=shardings), step
