from .checkpoint import CheckpointManager, restore_latest, save_pytree, load_pytree  # noqa: F401
