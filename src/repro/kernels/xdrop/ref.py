"""Oracle for the banded x-drop extension kernel: the pure-jnp wavefront DP
from assembly/alignment.py, vmapped over pairs."""

from __future__ import annotations

from functools import partial

import jax

from ...assembly.alignment import xdrop_extend


def xdrop_extend_batch_ref(
    a, base_a, step_a, len_a, b, base_b, step_b, len_b, *,
    xdrop=15, match=1, mismatch=-1, gap=-1, band=33, max_steps=256,
):
    f = partial(
        xdrop_extend, xdrop=xdrop, match=match, mismatch=mismatch, gap=gap,
        band=band, max_steps=max_steps,
    )
    ext = jax.vmap(f)(a, base_a, step_a, len_a, b, base_b, step_b, len_b)
    return ext.score, ext.ai, ext.bj
