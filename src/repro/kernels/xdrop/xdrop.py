"""Pallas TPU kernel: banded x-drop seed-extension wavefront.

Hardware adaptation (DESIGN.md §2): SeqAn's SSE anti-diagonal vectorization
becomes a (PAIRS_PER_BLOCK, BAND) wavefront living in VMEM/VREGs — the band
fills the 128-wide lane dimension and a block of pairs fills the sublane
dimension, so every VPU op advances BAND cells of PB alignments at once.
The DP state is two wavefronts + running best (score, ai, bj); sequences are
staged into VMEM by the BlockSpec.  Fixed trip count (max_steps) with
x-drop retirement masking — identical semantics to the oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...core.backend import resolve_interpret

NEG = -(10**9) // 2  # plain int: Pallas kernels cannot capture traced consts


def _xdrop_kernel(
    a_ref, ba_ref, sa_ref, la_ref, b_ref, bb_ref, sb_ref, lb_ref,
    score_ref, ai_ref, bj_ref,
    *, band: int, max_steps: int, xdrop: int, match: int, mismatch: int,
    gap: int,
):
    pb = a_ref.shape[0]
    w = band
    c = w // 2
    offs = jnp.arange(w) - c  # (W,)
    a = a_ref[...].astype(jnp.int32)  # (PB, LA)
    b = b_ref[...].astype(jnp.int32)
    ba = ba_ref[...].astype(jnp.int32)[:, None]  # (PB, 1)
    sa = sa_ref[...].astype(jnp.int32)[:, None]
    la = la_ref[...].astype(jnp.int32)[:, None]
    bb = bb_ref[...].astype(jnp.int32)[:, None]
    sb = sb_ref[...].astype(jnp.int32)[:, None]
    lb = lb_ref[...].astype(jnp.int32)[:, None]
    lmax_a = a.shape[1]
    lmax_b = b.shape[1]

    def fetch(seq, base, step, t, lim, lmax):
        idx = base + step * t  # (PB, W)
        safe = jnp.clip(idx, 0, lmax - 1)
        v = jnp.take_along_axis(seq, safe, axis=1)
        return v, (t >= 0) & (t < lim)

    def step_fn(s, carry):
        h1, h2, best, bi, bj, alive = carry
        i = (s + offs[None, :]) // 2  # (1+PB broadcast, W)
        j = (s - offs[None, :]) // 2
        parity = ((s + offs[None, :]) % 2) == 0
        av, va = fetch(a, ba, sa, i, la, lmax_a)
        bv, vb = fetch(b, bb, sb, j, lb, lmax_b)
        valid = parity & va & vb & (i >= 0) & (j >= 0)
        sub = jnp.where(av == bv, match, mismatch)
        diag = h2 + sub
        up = jnp.concatenate(
            [jnp.full((pb, 1), NEG), h1[:, :-1]], axis=1
        ) + gap
        left = jnp.concatenate(
            [h1[:, 1:], jnp.full((pb, 1), NEG)], axis=1
        ) + gap
        h = jnp.maximum(diag, jnp.maximum(up, left))
        h = jnp.where(valid, h, NEG)
        h = jnp.where(h < best[:, None] - xdrop, NEG, h)
        h = jnp.where(alive[:, None], h, NEG)
        m = jnp.max(h, axis=1)
        am = jnp.argmax(h, axis=1)
        improved = m > best
        best2 = jnp.where(improved, m, best)
        ii = jnp.take_along_axis(i, am[:, None], axis=1)[:, 0]
        jj = jnp.take_along_axis(j, am[:, None], axis=1)[:, 0]
        bi2 = jnp.where(improved, ii + 1, bi)
        bj2 = jnp.where(improved, jj + 1, bj)
        alive2 = jnp.any(h > NEG, axis=1) & (s + 1 < la[:, 0] + lb[:, 0] - 1)
        return (h, h1, best2, bi2, bj2, alive2)

    h1 = jnp.full((pb, w), NEG)
    h2 = jnp.where((offs == 0)[None, :], 0, NEG) | jnp.zeros((pb, w), jnp.int32)
    init = (
        h1, h2,
        jnp.zeros((pb,), jnp.int32),
        jnp.zeros((pb,), jnp.int32),
        jnp.zeros((pb,), jnp.int32),
        jnp.ones((pb,), bool),
    )
    h1, h2, best, bi, bj, alive = jax.lax.fori_loop(0, max_steps, step_fn, init)
    score_ref[...] = best
    ai_ref[...] = bi
    bj_ref[...] = bj


@functools.partial(
    jax.jit,
    static_argnames=(
        "band", "max_steps", "xdrop", "match", "mismatch", "gap",
        "pairs_per_block", "interpret",
    ),
)
def xdrop_pallas(
    a, base_a, step_a, len_a, b, base_b, step_b, len_b, *,
    band: int = 33, max_steps: int = 256, xdrop: int = 15, match: int = 1,
    mismatch: int = -1, gap: int = -1, pairs_per_block: int = 8,
    interpret: bool | str = "auto",
):
    interpret = resolve_interpret(interpret)
    e, lmax_a = a.shape
    lmax_b = b.shape[1]
    pb = min(pairs_per_block, e)
    pe = -(-e // pb) * pb
    pad = pe - e

    def p1(x):
        return jnp.pad(x, ((0, pad),))

    def p2(x, l):
        return jnp.pad(x, ((0, pad), (0, 0)))

    a = p2(a, lmax_a)
    b = p2(b, lmax_b)
    base_a, step_a, len_a = p1(base_a), p1(step_a), p1(len_a)
    base_b, step_b, len_b = p1(base_b), p1(step_b), p1(len_b)
    grid = (pe // pb,)
    kernel = functools.partial(
        _xdrop_kernel, band=band, max_steps=max_steps, xdrop=xdrop,
        match=match, mismatch=mismatch, gap=gap,
    )
    seq_spec_a = pl.BlockSpec((pb, lmax_a), lambda i: (i, 0))
    seq_spec_b = pl.BlockSpec((pb, lmax_b), lambda i: (i, 0))
    scal = pl.BlockSpec((pb,), lambda i: (i,))
    score, ai, bj = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[seq_spec_a, scal, scal, scal, seq_spec_b, scal, scal, scal],
        out_specs=[scal, scal, scal],
        out_shape=[
            jax.ShapeDtypeStruct((pe,), jnp.int32),
            jax.ShapeDtypeStruct((pe,), jnp.int32),
            jax.ShapeDtypeStruct((pe,), jnp.int32),
        ],
        interpret=interpret,
    )(
        a, base_a.astype(jnp.int32), step_a.astype(jnp.int32),
        len_a.astype(jnp.int32), b, base_b.astype(jnp.int32),
        step_b.astype(jnp.int32), len_b.astype(jnp.int32),
    )
    return score[:e], ai[:e], bj[:e]
