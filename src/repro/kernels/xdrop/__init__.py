from .ops import xdrop_extend_batch  # noqa: F401
from .ref import xdrop_extend_batch_ref  # noqa: F401
