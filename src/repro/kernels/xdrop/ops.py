"""Jit'd public wrapper for the x-drop kernel."""

from __future__ import annotations

import jax

from .xdrop import xdrop_pallas
from .ref import xdrop_extend_batch_ref  # noqa: F401


def xdrop_extend_batch(a, base_a, step_a, len_a, b, base_b, step_b, len_b,
                       **kw):
    interpret = jax.default_backend() != "tpu"
    return xdrop_pallas(
        a, base_a, step_a, len_a, b, base_b, step_b, len_b,
        interpret=interpret, **kw,
    )
