"""Public wrapper for the x-drop kernel + backend-dispatch registration.

Both backends of the ``xdrop_extend`` op share one signature (see
core/backend.py): the oracle ignores the kernel-side tuning knobs
(``pairs_per_block``, ``interpret``).
"""

from __future__ import annotations

from ...core.backend import register_op, resolve_interpret
from ...obs.trace import span
from .xdrop import xdrop_pallas
from .ref import xdrop_extend_batch_ref  # noqa: F401


def xdrop_extend_batch(a, base_a, step_a, len_a, b, base_b, step_b, len_b,
                       *, pairs_per_block: int | None = None,
                       interpret: bool | str = "auto", **kw):
    """Batched single-direction x-drop extension on the Pallas kernel.

    ``pairs_per_block=None`` picks the block size for the platform: a small
    sublane-friendly block when compiled, the whole batch in interpret mode
    (the grid loop is unrolled at trace time there, so fewer blocks = smaller
    HLO and one kernel instantiation)."""
    if pairs_per_block is None:
        pairs_per_block = int(a.shape[0]) if resolve_interpret(interpret) else 8
    with span("kernel_launch", kind="kernel", kernel="xdrop_extend",
              pairs=int(a.shape[0]), pairs_per_block=pairs_per_block):
        return xdrop_pallas(
            a, base_a, step_a, len_a, b, base_b, step_b, len_b,
            pairs_per_block=max(1, pairs_per_block), interpret=interpret, **kw,
        )


def _xdrop_reference(a, base_a, step_a, len_a, b, base_b, step_b, len_b,
                     *, pairs_per_block=None, interpret=None, **kw):
    """Reference backend: kernel tuning knobs accepted and ignored."""
    return xdrop_extend_batch_ref(
        a, base_a, step_a, len_a, b, base_b, step_b, len_b, **kw
    )


register_op("xdrop_extend", "pallas", xdrop_extend_batch)
register_op("xdrop_extend", "reference", _xdrop_reference)
