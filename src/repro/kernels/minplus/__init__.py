from .ops import minplus_matmul  # noqa: F401
from .ref import minplus_matmul_ref  # noqa: F401
