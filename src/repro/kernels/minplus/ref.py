"""Pure-jnp oracle for the dense orientation-resolved min-plus matmul.

N[i, j, 2x+y] = min_k min_c A[i, k, 2x+c] + B[k, j, 2c+y]
(the dense-block core of Algorithm 2's N = R²; see core/semiring.py)."""

from __future__ import annotations

import jax.numpy as jnp


def minplus_matmul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """a (M, K, 4), b (K, N, 4) -> (M, N, 4), f32, +inf = absent."""
    m, k, _ = a.shape
    n = b.shape[1]
    am = a.reshape(m, k, 2, 2)
    bm = b.reshape(k, n, 2, 2)
    # s[m, n, x, c, y] over k — reduce k in chunks to bound memory
    out = jnp.full((m, n, 2, 2), jnp.inf, jnp.float32)
    step = max(1, min(k, 512 * 512 // max(m * n // max(m, n), 1), 64))
    for k0 in range(0, k, step):
        ak = am[:, k0 : k0 + step]  # (M, kc, 2, 2)
        bk = bm[k0 : k0 + step]  # (kc, N, 2, 2)
        s = ak[:, :, None, :, :, None] + bk[None, :, :, None, :, :]
        # dims: (M, kc, N, x, c, y) -> min over kc (1) and c (4)
        s = jnp.min(s, axis=(1, 4))
        out = jnp.minimum(out, s)
    return out.reshape(m, n, 4)
