"""Pallas TPU kernel: blocked dense min-plus matmul with orientation combos.

Hardware adaptation (DESIGN.md §2): min-plus is not a (+,×) ring, so the MXU
is unusable — the kernel instead tiles (BM, BK)·(BK, BN) panels into VMEM and
reduces k with VPU broadcast-add + min, accumulating the output block across
the k grid dimension in-place (the revisited-output accumulation pattern).
The orientation contraction (min over the middle strand c) rides along as two
extra lanes.

Block shapes default to (128, 128, 128) — 8×128-lane aligned; the innermost
expansion buffer is (BM, BN, 2, 2, 2) f32 = 512 KB, well inside VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...core.backend import resolve_interpret

INF = float("inf")  # plain python float: Pallas kernels cannot capture traced consts


def _minplus_kernel(a_ref, b_ref, o_ref):
    bm = a_ref.shape[0]
    bk = a_ref.shape[1]
    bn = b_ref.shape[1]

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.full((bm, bn, 4), INF, jnp.float32)

    a = a_ref[...].reshape(bm, bk, 2, 2)
    b = b_ref[...].reshape(bk, bn, 2, 2)

    def body(k, acc):
        ak = jax.lax.dynamic_slice_in_dim(a, k, 1, axis=1)[:, 0]  # (BM, 2, 2)
        bk_ = jax.lax.dynamic_slice_in_dim(b, k, 1, axis=0)[0]  # (BN, 2, 2)
        s = ak[:, None, :, :, None] + bk_[None, :, None, :, :]
        # (BM, BN, x, c, y) -> min over c
        return jnp.minimum(acc, jnp.min(s, axis=3))

    acc0 = jnp.full((bm, bn, 2, 2), INF, jnp.float32)
    acc = jax.lax.fori_loop(0, bk, body, acc0)
    o_ref[...] = jnp.minimum(o_ref[...], acc.reshape(bm, bn, 4))


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def minplus_pallas(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool | str = "auto",
) -> jnp.ndarray:
    """a (M, K, 4), b (K, N, 4) -> (M, N, 4) f32.

    ``interpret="auto"`` compiles on TPU and interprets elsewhere."""
    interpret = resolve_interpret(interpret)
    m, k, _ = a.shape
    n = b.shape[1]
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    pm, pn, pk = -(-m // bm) * bm, -(-n // bn) * bn, -(-k // bk) * bk
    if (pm, pk) != (m, k):
        a = jnp.pad(a, ((0, pm - m), (0, pk - k), (0, 0)),
                    constant_values=jnp.inf)
    if (pk, pn) != (k, n):
        b = jnp.pad(b, ((0, pk - k), (0, pn - n), (0, 0)),
                    constant_values=jnp.inf)
    grid = (pm // bm, pn // bn, pk // bk)
    out = pl.pallas_call(
        _minplus_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk, 4), lambda i, j, kk: (i, kk, 0)),
            pl.BlockSpec((bk, bn, 4), lambda i, j, kk: (kk, j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn, 4), lambda i, j, kk: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((pm, pn, 4), jnp.float32),
        interpret=interpret,
    )(a.astype(jnp.float32), b.astype(jnp.float32))
    return out[:m, :n]
