"""Jit'd public wrapper: picks the Pallas kernel (interpret on CPU, compiled
on TPU) and exposes the same signature as the oracle."""

from __future__ import annotations

import jax

from .minplus import minplus_pallas
from .ref import minplus_matmul_ref  # noqa: F401


def minplus_matmul(a, b, *, block_m: int = 128, block_n: int = 128,
                   block_k: int = 128):
    interpret = jax.default_backend() != "tpu"
    return minplus_pallas(
        a, b, block_m=block_m, block_n=block_n, block_k=block_k,
        interpret=interpret,
    )
