"""Public wrapper for the min-plus kernel + backend-dispatch registration.

Both backends of the ``minplus_dense`` op share one signature
(``(a, b) -> n``, see core/backend.py); block sizes and interpret mode are
kernel-side tuning knobs the dispatcher's callers never see.
"""

from __future__ import annotations

from ...core.backend import register_op
from ...obs.trace import span
from .minplus import minplus_pallas
from .ref import minplus_matmul_ref  # noqa: F401


def minplus_matmul(a, b, *, block_m: int = 128, block_n: int = 128,
                   block_k: int = 128, interpret: bool | str = "auto"):
    """Dense orientation-resolved min-plus matmul on the Pallas kernel."""
    with span("kernel_launch", kind="kernel", kernel="minplus_dense",
              m=int(a.shape[0]), k=int(a.shape[1]), n=int(b.shape[1])):
        return minplus_pallas(
            a, b, block_m=block_m, block_n=block_n, block_k=block_k,
            interpret=interpret,
        )


def _minplus_reference(a, b, *, block_m=None, block_n=None, block_k=None,
                       interpret=None):
    """Reference backend: block/interpret knobs accepted and ignored."""
    return minplus_matmul_ref(a, b)


register_op("minplus_dense", "pallas", minplus_matmul)
register_op("minplus_dense", "reference", _minplus_reference)
