"""Pure-jnp oracle for the banded pileup-vote consensus op (DESIGN.md §2.8).

Semantics (shared bit-for-bit with the Pallas kernel):

* every piece scatters its oriented read bases onto contig columns
  ``offset_of_base_b = start + b``; each in-range base adds one vote to
  ``counts[contig, column, base]`` — but only if the vote is *coherent*: in
  the ±``COH_WIN`` column window around it (center excluded) the read must
  match the draft on ≥ ``COH_NUM/COH_DEN`` of the positions where both are
  defined, with at least ``COH_MIN_VALID`` such positions.  A read whose
  placement has drifted relative to the draft (indel errors accumulate a
  random walk away from each read's anchor) fails the gate and abstains,
  so incoherent pileups degrade to "keep the draft" instead of flipping
  columns on correlated-drift noise;
* the polished base of a column is ``argmax(counts)`` (ties resolve to the
  smallest base code, the jnp/np argmax convention) — applied only where the
  column has ``depth ≥ min_depth`` votes *and* the winner holds a strict
  majority (``2·win > depth``); otherwise the draft base is kept;
* ``agree`` is the vote count of the *final* base (winner where the vote
  applied, draft base elsewhere) — the numerator of the per-column identity
  estimate.

All quantities are integer counts, so oracle/kernel parity is exact.
"""

from __future__ import annotations

import jax.numpy as jnp

# vote-coherence gate (shared by oracle, kernel, and host walk)
COH_WIN = 4  # columns inspected on each side of a vote
COH_NUM, COH_DEN = 3, 4  # accept iff COH_DEN·matches ≥ COH_NUM·valid
COH_MIN_VALID = 4  # and at least this many comparable positions


def _vote(counts, draft, *, min_depth: int):
    """Shared vote epilogue: counts (..., 4) int32, draft (...) uint8."""
    depth = jnp.sum(counts, axis=-1)
    win = jnp.max(counts, axis=-1)
    winner = jnp.argmax(counts, axis=-1).astype(jnp.uint8)
    change = (depth >= min_depth) & (2 * win > depth)
    polished = jnp.where(change, winner, draft)
    agree = jnp.take_along_axis(
        counts, polished.astype(jnp.int32)[..., None], axis=-1
    )[..., 0]
    return polished, depth, agree


def pileup_vote_ref(draft, pieces, start, plen, *, min_depth: int = 2):
    """draft (C, L) uint8, pieces (C, M, LR) uint8 (oriented, zero-padded),
    start (C, M) int32 (column of piece base 0, may be negative), plen
    (C, M) int32 -> (polished (C, L) uint8, depth (C, L) i32, agree (C, L)
    i32).

    Scatter-add accumulation; the M axis is reduced in chunks so the
    (C, chunk, LR) index tensors stay bounded.
    """
    c, l = draft.shape
    m, lr = pieces.shape[1], pieces.shape[2]
    counts = jnp.zeros((c, l + 1, 4), jnp.int32)
    rows = jnp.arange(c, dtype=jnp.int32)[:, None, None]
    b = jnp.arange(lr, dtype=jnp.int32)[None, None, :]
    di = draft.astype(jnp.int32)
    step = max(1, min(m, (1 << 22) // max(c * lr, 1)))
    for m0 in range(0, m, step):
        pc = pieces[:, m0 : m0 + step].astype(jnp.int32)
        pl_ = plen[:, m0 : m0 + step, None]
        col = start[:, m0 : m0 + step, None] + b
        ok = (b < pl_) & (col >= 0) & (col < l)
        # coherence gate: read-vs-draft agreement on the ±COH_WIN window
        match = jnp.zeros(col.shape, jnp.int32)
        valid = jnp.zeros(col.shape, jnp.int32)
        for w in range(-COH_WIN, COH_WIN + 1):
            if w == 0:
                continue
            rb = b + w
            cb = col + w
            v = (rb >= 0) & (rb < pl_) & (cb >= 0) & (cb < l)
            rv = jnp.take_along_axis(pc, jnp.clip(rb, 0, lr - 1), axis=2)
            dv = jnp.take_along_axis(
                di[:, None, :], jnp.clip(cb, 0, l - 1), axis=2
            )
            match = match + (v & (rv == dv)).astype(jnp.int32)
            valid = valid + v.astype(jnp.int32)
        ok &= (COH_DEN * match >= COH_NUM * valid) & (valid >= COH_MIN_VALID)
        counts = counts.at[
            rows, jnp.where(ok, col, l), jnp.clip(pc, 0, 3)
        ].add(ok.astype(jnp.int32))
    return _vote(counts[:, :l], draft, min_depth=min_depth)
