"""Public wrapper for the pileup-vote kernel + backend-dispatch registration.

Both backends of the ``consensus`` op share one signature (see
core/backend.py): ``(draft, pieces, start, plen, *, min_depth, band,
interpret) -> (polished, depth, agree)``; the oracle ignores the kernel-side
tuning knobs (``band``, ``interpret``).
"""

from __future__ import annotations

from ...core.backend import register_op
from ...obs.trace import span
from .pileup import pileup_pallas
from .ref import pileup_vote_ref  # noqa: F401


def pileup_vote(draft, pieces, start, plen, *, min_depth: int = 2,
                band: int = 512, interpret: bool | str = "auto"):
    """Banded pileup + majority vote on the Pallas kernel (DESIGN.md §2.8)."""
    with span("kernel_launch", kind="kernel", kernel="pileup_vote",
              contigs=int(draft.shape[0]), band=band):
        return pileup_pallas(
            draft, pieces, start, plen, min_depth=min_depth, band=band,
            interpret=interpret,
        )


def _pileup_reference(draft, pieces, start, plen, *, min_depth: int = 2,
                      band=None, interpret=None):
    """Reference backend: kernel tuning knobs accepted and ignored."""
    return pileup_vote_ref(draft, pieces, start, plen, min_depth=min_depth)


register_op("consensus", "pallas", pileup_vote)
register_op("consensus", "reference", _pileup_reference)
