from .ops import pileup_vote, pileup_vote_ref  # noqa: F401
