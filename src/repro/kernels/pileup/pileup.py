"""Pallas TPU kernel: banded pileup accumulation + majority vote (consensus).

Hardware adaptation (DESIGN.md §2.8): the full base-count pileup tensor
``(n_contigs, max_len, 4)`` would be the largest array in the pipeline, so it
is never materialized in HBM — the grid tiles it as (contig, column-band)
blocks and each program accumulates a ``(4, band)`` int32 count block in
VMEM/VREGs by looping over the contig's pieces (fixed trip count M, the
chain-capacity padding of ``ContigSet``).  Each piece contributes via a
banded ``take_along_axis`` gather of its oriented bases (the same VMEM
sequence-staging pattern as the x-drop wavefront kernel), and the vote
epilogue (argmax + strict-majority + min-depth gating) runs on the block
before only the three ``(band,)`` result lanes are written back.

Counts are integers and the tie-break is first-max-wins, so the kernel is
bit-for-bit identical to the jnp oracle in ``ref.py`` — the parity contract
of the ``consensus`` op (DESIGN.md §2.5).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...core.backend import resolve_interpret
from .ref import COH_DEN, COH_MIN_VALID, COH_NUM, COH_WIN


def _pileup_kernel(
    draft_ref, row_ref, pieces_ref, start_ref, plen_ref,
    pol_ref, dep_ref, agr_ref,
    *, band: int, min_depth: int, l_full: int,
):
    # l_full is the UNPADDED column count: votes and coherence comparisons
    # beyond it are invalid (bit-parity with the oracle, which never sees
    # the band-multiple padding)
    m, lr = pieces_ref.shape[1], pieces_ref.shape[2]
    cols = pl.program_id(1) * band + jnp.arange(band, dtype=jnp.int32)
    pieces = pieces_ref[0]  # (M, LR) uint8
    draft_row = row_ref[...].astype(jnp.int32)  # (1, L) — coherence halo
    starts = start_ref[0].astype(jnp.int32)  # (M,)
    plens = plen_ref[0].astype(jnp.int32)

    def body(t, counts):
        s = jax.lax.dynamic_slice_in_dim(starts, t, 1)[0]
        ln = jax.lax.dynamic_slice_in_dim(plens, t, 1)[0]
        row = jax.lax.dynamic_slice_in_dim(
            pieces, t, 1, axis=0
        ).astype(jnp.int32)  # (1, LR)
        idx = cols - s  # (B,)
        ok = (idx >= 0) & (idx < ln) & (cols < l_full)
        base = jnp.take_along_axis(
            row, jnp.clip(idx, 0, lr - 1)[None, :], axis=1
        )[0]  # (B,)
        # coherence gate (see ref.py): the read must locally agree with the
        # draft around the voted column, else the vote abstains
        match = jnp.zeros((band,), jnp.int32)
        valid = jnp.zeros((band,), jnp.int32)
        for w in range(-COH_WIN, COH_WIN + 1):
            if w == 0:
                continue
            rb = idx + w
            cb = cols + w
            v = (rb >= 0) & (rb < ln) & (cb >= 0) & (cb < l_full)
            rv = jnp.take_along_axis(
                row, jnp.clip(rb, 0, lr - 1)[None, :], axis=1
            )[0]
            dv = jnp.take_along_axis(
                draft_row, jnp.clip(cb, 0, l_full - 1)[None, :], axis=1
            )[0]
            match = match + (v & (rv == dv)).astype(jnp.int32)
            valid = valid + v.astype(jnp.int32)
        ok &= (COH_DEN * match >= COH_NUM * valid) & (valid >= COH_MIN_VALID)
        hit = (jnp.arange(4, dtype=jnp.int32)[:, None] == base[None, :]) & ok
        return counts + hit.astype(jnp.int32)

    counts = jax.lax.fori_loop(
        0, m, body, jnp.zeros((4, band), jnp.int32)
    )

    # vote epilogue — 4 base lanes, unrolled first-max-wins (== argmax
    # tie-break of the oracle)
    dep = jnp.sum(counts, axis=0)
    best = counts[0]
    winner = jnp.zeros((band,), jnp.int32)
    for q in range(1, 4):
        better = counts[q] > best
        best = jnp.where(better, counts[q], best)
        winner = jnp.where(better, q, winner)
    draft = draft_ref[0].astype(jnp.int32)
    change = (dep >= min_depth) & (2 * best > dep)
    pol = jnp.where(change, winner, draft)
    agree = jnp.zeros((band,), jnp.int32)
    for q in range(4):
        agree = jnp.where(pol == q, counts[q], agree)
    pol_ref[0] = pol.astype(jnp.uint8)
    dep_ref[0] = dep
    agr_ref[0] = agree


@functools.partial(
    jax.jit, static_argnames=("min_depth", "band", "interpret")
)
def pileup_pallas(
    draft, pieces, start, plen, *, min_depth: int = 2, band: int = 512,
    interpret: bool | str = "auto",
):
    """draft (C, L) uint8, pieces (C, M, LR) uint8, start/plen (C, M) int32
    -> (polished (C, L) uint8, depth (C, L) i32, agree (C, L) i32).

    ``interpret="auto"`` compiles on TPU and interprets elsewhere."""
    interpret = resolve_interpret(interpret)
    c, l = draft.shape
    m, lr = pieces.shape[1], pieces.shape[2]
    b = min(band, l)
    lp = -(-l // b) * b
    if lp != l:
        draft = jnp.pad(draft, ((0, 0), (0, lp - l)))
    grid = (c, lp // b)
    kernel = functools.partial(
        _pileup_kernel, band=b, min_depth=min_depth, l_full=l
    )
    blk = pl.BlockSpec((1, b), lambda i, j: (i, j))
    # the draft goes in twice: banded (the vote fallback for this block) and
    # as the whole row (the ±COH_WIN coherence halo crosses band boundaries)
    pol, dep, agr = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            blk,
            pl.BlockSpec((1, lp), lambda i, j: (i, 0)),
            pl.BlockSpec((1, m, lr), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, m), lambda i, j: (i, 0)),
            pl.BlockSpec((1, m), lambda i, j: (i, 0)),
        ],
        out_specs=[blk, blk, blk],
        out_shape=[
            jax.ShapeDtypeStruct((c, lp), jnp.uint8),
            jax.ShapeDtypeStruct((c, lp), jnp.int32),
            jax.ShapeDtypeStruct((c, lp), jnp.int32),
        ],
        interpret=interpret,
    )(
        draft, draft, pieces, start.astype(jnp.int32),
        plen.astype(jnp.int32),
    )
    return pol[:, :l], dep[:, :l], agr[:, :l]
