# Pallas TPU kernels for the pipeline's compute hot spots:
#   minplus/ — dense-block min-plus semiring matmul (transitive reduction)
#   xdrop/   — banded x-drop alignment wavefront (pairwise alignment)
#   pileup/  — banded pileup accumulation + majority vote (consensus)
#   cc/      — fused hook/shortcut connected-components rounds
#   spgemm/  — fused ring-SUMMA local SpGEMM stage batches (overlap stage)
# Validated on CPU via interpret=True against the pure-jnp oracles (ref.py).
# Importing this package registers every kernel (and its oracle) with the
# backend dispatch layer in core/backend.py.
from .cc import cc_labels_pallas, cc_labels_ref  # noqa: F401
from .minplus import minplus_matmul, minplus_matmul_ref  # noqa: F401
from .pileup import pileup_vote, pileup_vote_ref  # noqa: F401
from .spgemm import (  # noqa: F401
    spgemm_ring_stages_pallas,
    spgemm_ring_stages_ref,
)
from .xdrop import xdrop_extend_batch, xdrop_extend_batch_ref  # noqa: F401
