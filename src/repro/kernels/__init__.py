# Pallas TPU kernels for the pipeline's compute hot spots:
#   minplus/ — dense-block min-plus semiring matmul (transitive reduction)
#   xdrop/   — banded x-drop alignment wavefront (pairwise alignment)
# Validated on CPU via interpret=True against the pure-jnp oracles (ref.py).
