"""Pallas TPU kernel: fused hook/shortcut connected-components rounds.

Hardware adaptation (DESIGN.md §2.9): the jnp oracle issues one XLA
gather/scatter pair — a full HBM round trip for the label vector and the ELL
neighbour blocks — per hook/shortcut round.  This kernel keeps the labels
*and* both neighbour blocks VMEM-resident across ``rounds`` consecutive
rounds: one ``pallas_call`` loads ``cols`` (out-neighbours), ``colsT``
(in-neighbours, the ELL transpose built once by ``ops.py``) and the label
row, then runs a ``fori_loop`` of fused rounds entirely in VMEM before
writing the labels (plus a changed flag) back once.

The scatter-min of the oracle's push step is re-expressed as a gather-min
over the *transposed* adjacency — ``min`` over the identical edge set, so the
kernel is bit-for-bit identical to ``ref.py`` (the parity contract of the
``cc_labels`` op).  All gathers use the ``take_along_axis``-on-a-``(1, N)``
row idiom shared with the pileup kernel (§2.8).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...core.backend import resolve_interpret

_BIG = 2**30  # plain python int: Pallas kernels cannot capture traced consts


def _cc_rounds_kernel(
    oc_ref, ic_ref, lab_ref, out_ref, chg_ref, *, n: int, k_out: int,
    k_in: int, rounds: int,
):
    oc = oc_ref[...]  # (1, n·k_out) int32, -1 = empty
    ic = ic_ref[...]  # (1, n·k_in)  int32, -1 = empty
    oc_safe = jnp.clip(oc, 0, n - 1)
    ic_safe = jnp.clip(ic, 0, n - 1)
    om = oc >= 0
    im = ic >= 0

    def gather_min(l, idx_safe, mask, kk):
        # l (1, n); idx (1, n·kk) → per-row min over the kk neighbour slots
        g = jnp.take_along_axis(l, idx_safe, axis=1)
        g = jnp.where(mask, g, _BIG).reshape(n, kk)
        return jnp.min(g, axis=1).reshape(1, n)

    def rd(_, carry):
        l, chg = carry
        # hook: pull the min label over out-neighbours...
        l1 = jnp.minimum(l, gather_min(l, oc_safe, om, k_out))
        # ...then over in-neighbours (== the oracle's scatter-min push)
        l2 = jnp.minimum(l1, gather_min(l1, ic_safe, im, k_in))
        # shortcut: jump to the label's label
        l3 = jnp.take_along_axis(l2, l2, axis=1)
        return l3, chg | jnp.any(l3 != l)

    l0 = lab_ref[...]
    l, chg = jax.lax.fori_loop(0, rounds, rd, (l0, jnp.bool_(False)))
    out_ref[...] = l
    chg_ref[...] = chg.astype(jnp.int32).reshape(1, 1)


@functools.partial(jax.jit, static_argnames=("rounds", "interpret"))
def cc_rounds_pallas(
    oc_flat: jnp.ndarray,
    ic_flat: jnp.ndarray,
    labels: jnp.ndarray,
    *,
    rounds: int,
    interpret: bool | str = "auto",
):
    """Run ``rounds`` fused hook/shortcut rounds in one VMEM-resident call.

    Args:
      oc_flat: ``(1, n·k_out)`` int32 flattened out-neighbour ELL columns.
      ic_flat: ``(1, n·k_in)`` int32 flattened in-neighbour ELL columns
        (the transpose of ``oc_flat``; see ``ops.transpose_ell``).
      labels: ``(1, n)`` int32 current labels.
      rounds: fused rounds per call (static).

    Returns:
      ``(labels', changed)`` with ``labels'`` ``(1, n)`` int32 and ``changed``
      ``(1, 1)`` int32 — nonzero iff any round changed any label.
    """
    interpret = resolve_interpret(interpret)
    n = labels.shape[1]
    k_out = oc_flat.shape[1] // n
    k_in = ic_flat.shape[1] // n
    kernel = functools.partial(
        _cc_rounds_kernel, n=n, k_out=k_out, k_in=k_in, rounds=rounds
    )
    return pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((1, n * k_out), lambda i: (0, 0)),
            pl.BlockSpec((1, n * k_in), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, n), jnp.int32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        interpret=interpret,
    )(oc_flat, ic_flat, labels)
