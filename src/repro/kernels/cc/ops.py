"""Public wrapper for the fused cc kernel + backend-dispatch registration.

Both backends of the ``cc_labels`` op share one signature
(``(cols, *, max_iters) -> (labels, iters)``, see core/backend.py).  The
Pallas path adds two kernel-side knobs the dispatcher's callers never see:
``rounds_per_call`` (how many hook/shortcut rounds stay fused in VMEM per
HBM round trip) and ``interpret``.

HBM-round-trip accounting: the oracle touches HBM once per round; the fused
path touches it once per *chunk* of ``rounds_per_call`` rounds, i.e.
``ceil(iters / rounds_per_call)`` times — ``hbm_round_trips`` makes this
measurable (bench_contigs reports both).
"""

from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from ...core.backend import register_op
from ...core.spmat import next_pow2
from ...obs.trace import span
from .cc import cc_rounds_pallas
from .ref import cc_labels_ref

# VMEM budget for the fused kernel's resident set (labels + both neighbour
# blocks); above it the pallas backend falls back to the oracle — documented
# behaviour, bit-identical either way.
VMEM_BUDGET_BYTES = 8 << 20


@partial(jax.jit, static_argnames=("k_in",))
def _transpose_ell_sized(cols: jnp.ndarray, *, k_in: int) -> jnp.ndarray:
    """ELL transpose with static in-capacity ``k_in`` (known ≥ max in-degree):
    row v of the result lists the sources u of the edges ``u→v``."""
    n, k = cols.shape
    m = cols >= 0
    src = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[:, None], (n, k))
    dst = jnp.where(m, cols, n).reshape(-1)
    order = jnp.argsort(dst)  # stable: preserves (src, slot) order per dst
    ds = dst[order]
    ss = src.reshape(-1)[order]
    rank = jnp.arange(n * k) - jnp.searchsorted(ds, ds, side="left")
    out = (
        jnp.full((n + 1, k_in), -1, jnp.int32)
        .at[ds, jnp.clip(rank, 0, k_in - 1)]
        .set(ss)[:n]
    )
    return out


def _in_capacity(cols: jnp.ndarray) -> int:
    """Pow-2 in-capacity (≥ max in-degree) the ELL transpose will use."""
    n = cols.shape[0]
    m = cols >= 0
    safe = jnp.where(m, cols, n)
    in_deg = (
        jnp.zeros(n + 1, jnp.int32)
        .at[safe.reshape(-1)]
        .add(m.reshape(-1).astype(jnp.int32))[:n]
    )
    return next_pow2(int(jnp.max(in_deg)))


def transpose_ell(cols: jnp.ndarray) -> jnp.ndarray:
    """In-neighbour ELL of an out-neighbour ELL ``cols`` (n, K).

    The in-capacity is host-sized to the next power of two of the max
    in-degree (the §2.6 pow-2 staging idiom), so the number of distinct
    compiled shapes stays logarithmic.  Returns ``(n, k_in)`` int32, ``-1``
    padded, sources sorted ascending per row.
    """
    return _transpose_ell_sized(cols, k_in=_in_capacity(cols))


def _resident_bytes(n: int, k_out: int, k_in: int) -> int:
    """VMEM-resident set of the fused kernel: labels ×2 + both ELL blocks."""
    return 4 * (n * k_out + n * k_in + 2 * n)


def fused_path_fits(cols: jnp.ndarray) -> bool:
    """True iff :func:`cc_labels_pallas` will actually run the fused kernel
    for this adjacency (False = its resident set exceeds
    ``VMEM_BUDGET_BYTES`` and it falls back to the oracle, paying one HBM
    round trip per round).  Benchmarks consult this so fused-vs-oracle
    round-trip comparisons are never fabricated on fallen-back sizes."""
    n, k = cols.shape
    return _resident_bytes(n, k, _in_capacity(cols)) <= VMEM_BUDGET_BYTES


@partial(jax.jit, static_argnames=("rounds", "n_chunks", "rem", "interpret"))
def _drive_chunks(oc_flat, ic_flat, labels0, *, rounds, n_chunks, rem,
                  interpret):
    """Chunked driver: while changed, run ``rounds`` fused rounds per call
    (≤ ``n_chunks`` chunks), then at most one ``rem``-round tail call so the
    total never exceeds the caller's ``max_iters`` — exact parity with the
    oracle's capped ``while_loop``."""

    def body(carry):
        lab, _, it, chunks = carry
        lab2, chg2 = cc_rounds_pallas(
            oc_flat, ic_flat, lab, rounds=rounds, interpret=interpret
        )
        return lab2, chg2[0, 0] > 0, it + rounds, chunks + 1

    def cond(carry):
        _, changed, _, chunks = carry
        return changed & (chunks < n_chunks)

    lab, changed, iters, chunks = jax.lax.while_loop(
        cond, body, (labels0, jnp.bool_(True), jnp.int32(0), jnp.int32(0))
    )
    if rem:
        def tail(args):
            lab, iters, chunks = args
            lab2, _ = cc_rounds_pallas(
                oc_flat, ic_flat, lab, rounds=rem, interpret=interpret
            )
            return lab2, iters + rem, chunks + 1

        lab, iters, chunks = jax.lax.cond(
            changed, tail, lambda a: a, (lab, iters, chunks)
        )
    return lab, iters, chunks


def cc_labels_pallas(
    cols: jnp.ndarray,
    *,
    max_iters: int | None = None,
    rounds_per_call: int = 8,
    interpret: bool | str = "auto",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused-kernel backend of the ``cc_labels`` op.

    Bit-identical labels to :func:`~repro.kernels.cc.ref.cc_labels_ref`; the
    returned iteration count is the number of rounds *executed* (a multiple
    of ``rounds_per_call`` plus a possible tail), which may exceed the
    oracle's exact rounds-to-convergence by up to ``rounds_per_call − 1``
    idempotent fixed-point rounds.  Falls back to the oracle when the
    VMEM-resident set (labels + out/in neighbour blocks) would exceed
    ``VMEM_BUDGET_BYTES``.
    """
    n, k = cols.shape
    if max_iters is None:
        max_iters = n
    cols_t = transpose_ell(cols)
    k_in = cols_t.shape[1]
    fused = _resident_bytes(n, k, k_in) <= VMEM_BUDGET_BYTES
    with span("kernel_launch", kind="kernel", kernel="cc_labels",
              fused=fused, n=n, k_out=k, k_in=k_in):
        if not fused:
            return cc_labels_ref(cols, max_iters=max_iters)
        rounds = max(1, min(rounds_per_call, max_iters))
        n_chunks = max_iters // rounds
        rem = max_iters % rounds
        lab, iters, _ = _drive_chunks(
            cols.reshape(1, -1), cols_t.reshape(1, -1),
            jnp.arange(n, dtype=jnp.int32).reshape(1, n),
            rounds=rounds, n_chunks=n_chunks, rem=rem, interpret=interpret,
        )
        return lab.reshape(-1), iters


def hbm_round_trips(iters: int, rounds_per_call: int = 8) -> int:
    """HBM round trips the fused path needs for ``iters`` executed rounds
    (the oracle needs ``iters``)."""
    return -(-int(iters) // max(1, rounds_per_call))


register_op("cc_labels", "reference", cc_labels_ref)
register_op("cc_labels", "pallas", cc_labels_pallas)
