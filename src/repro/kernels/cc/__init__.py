"""Fused connected-components (hook/shortcut) kernel package.

``ops.py`` registers both backends of the ``cc_labels`` op with the dispatch
layer (DESIGN.md §2.5/§2.9): ``ref.py`` is the one-round-per-HBM-round-trip
jnp oracle, ``cc.py`` the Pallas kernel that fuses ``rounds_per_call``
hook/shortcut rounds into a single VMEM-resident call.
"""

from .ops import (  # noqa: F401
    cc_labels_pallas,
    fused_path_fits,
    hbm_round_trips,
    transpose_ell,
)
from .ref import cc_labels_ref  # noqa: F401
