"""Pure-jnp oracle for the hook/shortcut connected-components rounds.

One round = hook (gather-min over out-neighbours, then scatter-min along
edges, i.e. a min over in-neighbours) + one pointer-jump shortcut
(``l ← l[l]``), iterated to a fixed point under a ``lax.while_loop``.  This
is the Shiloach–Vishkin-style min-label propagation previously inlined in
``core/components.connected_components``; it now lives here as the
``"reference"`` backend of the ``cc_labels`` op (DESIGN.md §2.5/§2.9) so the
fused Pallas kernel in ``cc.py`` has a bit-for-bit oracle.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

_BIG = jnp.int32(2**30)


def cc_labels_ref(
    cols: jnp.ndarray,
    *,
    max_iters: int | None = None,
    rounds_per_call: int | None = None,
    interpret: bool | str = "auto",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Min-label connected components of an ELL adjacency, one XLA round trip
    per hook/shortcut round.

    Args:
      cols: ``(n, K)`` int32 ELL column indices (``-1`` = empty slot); the
        adjacency is treated as undirected (labels hook across ``u→v`` in
        both directions) and is assumed square (labels span ``n`` rows).
      max_iters: round cap; defaults to ``n`` (correctness over speed on
        adversarial orderings — the convergence test exits early).
      rounds_per_call / interpret: kernel-side tuning knobs of the Pallas
        backend, accepted and ignored here (shared op signature).

    Returns:
      ``(labels, n_iterations)`` — ``labels`` is ``(n,)`` int32, the minimum
      vertex id of each component; ``n_iterations`` the exact number of
      hook/shortcut rounds executed before the labels stopped changing.
    """
    del rounds_per_call, interpret
    n = cols.shape[0]
    if max_iters is None:
        max_iters = n
    m = cols >= 0
    mf = m.reshape(-1)
    # Masked slots are routed to index 0 with a ⊕-identity (_BIG) value, so
    # both the gather and the scatter-min are no-ops there; this avoids
    # concatenating a dummy slot, which GSPMD mis-partitions when the inputs
    # arrive sharded (the contig path runs this on mesh-resident arrays).
    safe = jnp.clip(jnp.where(m, cols, 0), 0, n - 1)
    sf = safe.reshape(-1)

    def cond(carry):
        _, changed, it = carry
        return changed & (it < max_iters)

    def body(carry):
        l, _, it = carry
        # hook: pull the min label over out-neighbours...
        pulled = jnp.min(jnp.where(m, l[safe], _BIG), axis=1)
        l1 = jnp.minimum(l, pulled)
        # ...and push labels along edges (covers the reverse direction)
        push = jnp.where(mf, jnp.broadcast_to(l1[:, None], m.shape).reshape(-1), _BIG)
        l2 = l1.at[sf].min(push)
        # shortcut: jump to the label's label
        l3 = l2[l2]
        return l3, jnp.any(l3 != l), it + 1

    labels, _, iters = jax.lax.while_loop(
        cond, body, (jnp.arange(n, dtype=jnp.int32), jnp.bool_(True), jnp.int32(0))
    )
    return labels, iters
