"""jnp oracle for the ring-SUMMA local SpGEMM stage batch (``spgemm_ring_stages``).

One ring-SUMMA stage multiplies a local A panel (rebased into the current
B row-block's index range) by the local B panel and compacts the result to a
``capacity``-slot ELL buffer — exactly ``core.spgemm.spgemm`` on the rebased
panel.  The op batches ``S`` consecutive stages: the reference runs them as
``S`` separate multiplies (one HBM round trip per stage for the stage
buffers), the Pallas backend fuses them into one VMEM-resident grid program
(``spgemm.py``).

The per-stage buffers are kept *separate* (stage axis leading) rather than
⊕-merged into a running accumulator: the overlap semiring's position-pair ⊕
is order-dependent (first ``NUM_POS_PAIRS`` pairs win), so the caller
(``core.summa.summa_ring``) reorders the buffers into canonical k-block
order before the single final merge — that makes the distributed product
bit-identical to the local ``spgemm``, which combines candidates in
ascending k order.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ...core.semiring import Semiring
from ...core.spgemm import spgemm
from ...core.spmat import EllMatrix, NO_COL


def _rebase_panel(a_cols: jnp.ndarray, off, nb: int) -> jnp.ndarray:
    """Rebase global A column ids into the B row-block ``[off, off+nb)``;
    out-of-block slots become empty (they belong to other ring stages)."""
    rebased = a_cols - off
    in_range = (a_cols >= 0) & (rebased >= 0) & (rebased < nb)
    return jnp.where(in_range, rebased, NO_COL)


@partial(
    jax.jit,
    static_argnames=("semiring", "capacity", "n_cols_out", "interpret"),
)
def spgemm_ring_stages_ref(
    offsets: jnp.ndarray,
    a_cols: jnp.ndarray,
    a_vals,
    b_cols: jnp.ndarray,
    b_vals,
    *,
    semiring: Semiring,
    capacity: int,
    n_cols_out: int,
    interpret: bool | str = "auto",
):
    """Reference backend of ``spgemm_ring_stages``.

    Args:
      offsets: ``(S,)`` int32 — per-stage B row-block offset (A ids are
        rebased by it before the multiply).
      a_cols: ``(S, n, K_A)`` int32 stacked A panels (global column ids).
      a_vals: value pytree, leaves ``(S, n, K_A, ...)``.
      b_cols: ``(S, nb, K_B)`` int32 stacked B panels (output column ids).
      b_vals: value pytree, leaves ``(S, nb, K_B, ...)``.
      semiring / capacity / n_cols_out: the local-multiply contract of
        ``core.spgemm.spgemm``.
      interpret: accepted for signature parity with the Pallas backend;
        unused (the oracle is plain jnp).

    Returns:
      ``(st_cols, st_vals, overflow)`` — per-stage ELL buffers ``(S, n,
      capacity)`` (cols int32, vals pytree) and the summed overflow count.
    """
    del interpret
    stages, _, _ = a_cols.shape
    nb = b_cols.shape[1]
    st_cols, st_vals, ovf = [], [], jnp.int32(0)
    for s in range(stages):
        ac = _rebase_panel(a_cols[s], offsets[s], nb)
        a_loc = EllMatrix(
            cols=ac, vals=jax.tree.map(lambda v: v[s], a_vals), n_cols=nb
        )
        b_loc = EllMatrix(
            cols=b_cols[s],
            vals=jax.tree.map(lambda v: v[s], b_vals),
            n_cols=n_cols_out,
        )
        c, so = spgemm(a_loc, b_loc, semiring=semiring, capacity=capacity)
        st_cols.append(c.cols)
        st_vals.append(c.vals)
        ovf = ovf + so
    out_cols = jnp.stack(st_cols)
    out_vals = jax.tree.map(lambda *xs: jnp.stack(xs), *st_vals)
    return out_cols, out_vals, ovf
