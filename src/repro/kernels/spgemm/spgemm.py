"""Pallas TPU kernel: fused ring-SUMMA local SpGEMM stages.

Hardware adaptation (DESIGN.md §2.11): the jnp oracle runs one
gather → semiring-⊗ → sort-by-column → segmented-⊕ → compact pipeline per
ring stage, paying a full HBM round trip per stage for the stage's candidate
buffers.  This kernel fuses ``S`` consecutive stages into one grid program:
one ``pallas_call`` loads the stacked A/B panels, runs every stage's row
pipeline with the stage-output ELL block **VMEM-resident across the ring
steps** — the stationary operand of the C-stationary Cannon schedule — and
writes the per-stage buffers back once.

The candidate merge inside each stage calls the exact
``core.spmat.merge_sorted_rows`` code the oracle uses, so the kernel is
bit-for-bit identical to ``ref.py`` (the parity contract of the
``spgemm_ring_stages`` op, asserted by ``tests/test_kernels.py``).  Panel
rebasing offsets are traced per-device values (they depend on the device's
grid coordinates), so they enter as a small int32 input rather than closure
constants — Pallas kernels cannot capture traced consts.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...core.backend import resolve_interpret
from ...core.semiring import Semiring
from ...core.spmat import merge_sorted_rows


def _stage_multiply(ac, av, bc, bv, off, *, semiring, capacity, nb):
    """One ring stage: rebase → gather → ⊗ → merge (the ``core.spgemm``
    row-expansion pipeline, transliterated so it runs on VMEM residents)."""
    n, ka = ac.shape
    kb = bc.shape[1]
    rebased = ac - off
    in_range = (ac >= 0) & (rebased >= 0) & (rebased < nb)
    ac = jnp.where(in_range, rebased, -1)
    a_valid = ac >= 0
    safe = jnp.where(a_valid, ac, 0)
    b_cols_g = bc[safe]  # (n, KA, KB)
    b_vals_g = jax.tree.map(lambda v: v[safe], bv)
    a_vals_e = jax.tree.map(lambda v: v[:, :, None, ...], av)
    cand_vals = semiring.mul(a_vals_e, b_vals_g)
    cand_valid = (
        a_valid[:, :, None] & (b_cols_g >= 0) & ~semiring.is_zero(cand_vals)
    )
    cand_cols = jnp.where(cand_valid, b_cols_g, -1).reshape(n, ka * kb)
    cand_vals = jax.tree.map(
        lambda v: v.reshape((n, ka * kb) + v.shape[3:]), cand_vals
    )
    return merge_sorted_rows(
        cand_cols, cand_vals, capacity=capacity, semiring=semiring
    )


def _spgemm_stages_kernel(
    *refs,
    semiring: Semiring,
    capacity: int,
    stages: int,
    n: int,
    ka: int,
    nb: int,
    kb: int,
    a_treedef,
    b_treedef,
    a_tails,
    b_tails,
    c_tails,
):
    """Kernel body.  ``refs`` = (off, a_cols, *a_leaves, b_cols, *b_leaves)
    inputs followed by (st_cols, *st_leaves, ovf) outputs, every array
    flattened to one ``(1, numel)`` row (the shared flat-row BlockSpec idiom
    of the cc/pileup kernels)."""
    na, nbl = len(a_tails), len(b_tails)
    it = iter(refs)
    off_ref = next(it)
    a_cols_ref = next(it)
    a_leaf_refs = [next(it) for _ in range(na)]
    b_cols_ref = next(it)
    b_leaf_refs = [next(it) for _ in range(nbl)]
    out_cols_ref = next(it)
    out_leaf_refs = [next(it) for _ in range(len(c_tails))]
    ovf_ref = next(it)

    off = off_ref[...]  # (1, S)
    a_cols = a_cols_ref[...].reshape(stages, n, ka)
    a_vals = jax.tree.unflatten(
        a_treedef,
        [r[...].reshape((stages, n, ka) + t)
         for r, t in zip(a_leaf_refs, a_tails)],
    )
    b_cols = b_cols_ref[...].reshape(stages, nb, kb)
    b_vals = jax.tree.unflatten(
        b_treedef,
        [r[...].reshape((stages, nb, kb) + t)
         for r, t in zip(b_leaf_refs, b_tails)],
    )

    st_cols, st_vals = [], []
    ovf = jnp.int32(0)
    for s in range(stages):  # static unroll: stage buffers stay in VMEM
        cc, cv, so = _stage_multiply(
            a_cols[s],
            jax.tree.map(lambda v: v[s], a_vals),
            b_cols[s],
            jax.tree.map(lambda v: v[s], b_vals),
            off[0, s],
            semiring=semiring,
            capacity=capacity,
            nb=nb,
        )
        st_cols.append(cc)
        st_vals.append(cv)
        ovf = ovf + so

    out_cols_ref[...] = jnp.stack(st_cols).reshape(1, -1)
    out_leaves = jax.tree.leaves(
        jax.tree.map(lambda *xs: jnp.stack(xs), *st_vals)
    )
    for r, leaf in zip(out_leaf_refs, out_leaves):
        r[...] = leaf.reshape(1, -1)
    ovf_ref[...] = ovf.reshape(1, 1)


@functools.partial(
    jax.jit, static_argnames=("semiring", "capacity", "n_cols_out", "interpret")
)
def spgemm_ring_stages_pallas(
    offsets: jnp.ndarray,
    a_cols: jnp.ndarray,
    a_vals,
    b_cols: jnp.ndarray,
    b_vals,
    *,
    semiring: Semiring,
    capacity: int,
    n_cols_out: int,
    interpret: bool | str = "auto",
):
    """Fused-kernel backend of ``spgemm_ring_stages`` — same signature and
    bit-identical outputs as :func:`~repro.kernels.spgemm.ref
    .spgemm_ring_stages_ref`, one ``pallas_call`` per stage batch.

    Use :func:`~repro.kernels.spgemm.ops.spgemm_ring_stages_pallas` (the
    registered op) in pipeline code: it adds the VMEM-budget fallback this
    raw wrapper does not have.
    """
    del n_cols_out  # output ids are never re-indexed inside the kernel
    interpret = resolve_interpret(interpret)
    stages, n, ka = a_cols.shape
    _, nb, kb = b_cols.shape
    a_leaves, a_treedef = jax.tree.flatten(a_vals)
    b_leaves, b_treedef = jax.tree.flatten(b_vals)
    a_tails = tuple(leaf.shape[3:] for leaf in a_leaves)
    b_tails = tuple(leaf.shape[3:] for leaf in b_leaves)
    zero = semiring.zero((1, 1))
    c_zero_leaves = jax.tree.leaves(zero)
    c_tails = tuple(leaf.shape[2:] for leaf in c_zero_leaves)

    kernel = functools.partial(
        _spgemm_stages_kernel,
        semiring=semiring,
        capacity=capacity,
        stages=stages,
        n=n,
        ka=ka,
        nb=nb,
        kb=kb,
        a_treedef=a_treedef,
        b_treedef=b_treedef,
        a_tails=a_tails,
        b_tails=b_tails,
        c_tails=c_tails,
    )

    def flat(x):
        return x.reshape(1, -1)

    inputs = (
        [flat(offsets.astype(jnp.int32)), flat(a_cols)]
        + [flat(leaf) for leaf in a_leaves]
        + [flat(b_cols)]
        + [flat(leaf) for leaf in b_leaves]
    )
    in_specs = [
        pl.BlockSpec(x.shape, lambda i: (0, 0)) for x in inputs
    ]
    out_elems = [(stages * n * capacity, jnp.int32)]
    for tail, zleaf in zip(c_tails, c_zero_leaves):
        numel = stages * n * capacity
        for t in tail:
            numel *= t
        out_elems.append((numel, zleaf.dtype))
    out_elems.append((1, jnp.int32))  # overflow
    out_specs = [
        pl.BlockSpec((1, numel), lambda i: (0, 0)) for numel, _ in out_elems
    ]
    out_shape = [
        jax.ShapeDtypeStruct((1, numel), dtype) for numel, dtype in out_elems
    ]
    outs = pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*inputs)

    st_cols = outs[0].reshape(stages, n, capacity)
    st_leaves = [
        r.reshape((stages, n, capacity) + t)
        for r, t in zip(outs[1:-1], c_tails)
    ]
    st_vals = jax.tree.unflatten(jax.tree.structure(zero), st_leaves)
    ovf = outs[-1][0, 0]
    return st_cols, st_vals, ovf
