"""Public wrapper for the fused SpGEMM kernel + backend-dispatch registration.

Both backends of the ``spgemm_ring_stages`` op share one signature
(``(offsets, a_cols, a_vals, b_cols, b_vals, *, semiring, capacity,
n_cols_out, interpret) -> (st_cols, st_vals, overflow)``, see
core/backend.py).  The Pallas path keeps the whole stage batch — panels,
candidate scratch and the per-stage output ELL buffers — VMEM-resident for
the duration of one call, so the ring SUMMA driver (``core.summa.summa_ring``)
pays one HBM round trip per *batch* of ``stages_per_call`` ring stages where
the oracle pays one per stage.

HBM-round-trip accounting: :func:`hbm_round_trips` makes the fused-vs-oracle
trade measurable the same way ``kernels/cc/ops.py`` does — the oracle needs
``stages`` trips, the fused path ``ceil(stages / stages_per_call)``
(``bench_overlap`` reports both, ``tests/test_kernels.py`` asserts the
inequality).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core.backend import register_op
from ...core.semiring import Semiring
from ...obs.trace import span
from .ref import spgemm_ring_stages_ref
from .spgemm import spgemm_ring_stages_pallas as _pallas_raw

# VMEM budget for the fused kernel's resident set (stacked panels + stage
# output buffers + the per-stage candidate expand/sort scratch); above it the
# pallas backend falls back to the oracle — documented behaviour,
# bit-identical either way.
VMEM_BUDGET_BYTES = 8 << 20


def _words_per_slot(vals) -> int:
    """Scalar words per ELL slot of a value pytree whose leaves have leading
    dims (..., slot, *tail): 1 for the column id + the tail elements of every
    leaf (all value dtypes in the pipeline are 4-byte)."""
    words = 1
    for leaf in jax.tree.leaves(vals):
        t = 1
        for d in leaf.shape[2:]:
            t *= d
        words += t
    return words


def _value_words(vals, tail_from: int) -> int:
    """Per-slot value words of a pytree with ``tail_from`` leading dims."""
    words = 0
    for leaf in jax.tree.leaves(vals):
        t = 1
        for d in leaf.shape[tail_from:]:
            t *= d
        words += t
    return words


def _resident_bytes(
    stages: int, n: int, ka: int, nb: int, kb: int, capacity: int,
    a_vals, b_vals, semiring: Semiring,
) -> int:
    """VMEM-resident set of one fused call: A/B panel stacks, the stacked
    stage output buffers and the widest per-stage candidate buffer."""
    wa = 1 + _value_words(a_vals, 3)
    wb = 1 + _value_words(b_vals, 3)
    wc = 1 + _value_words(semiring.zero((1, 1)), 2)
    panels = stages * (n * ka * wa + nb * kb * wb)
    outputs = stages * n * capacity * wc
    scratch = n * ka * kb * wc  # candidate expand/sort buffer of one stage
    return 4 * (panels + outputs + scratch)


def fused_path_fits(
    a_cols: jnp.ndarray, a_vals, b_cols: jnp.ndarray, b_vals, *,
    capacity: int, semiring: Semiring,
) -> bool:
    """True iff :func:`spgemm_ring_stages_pallas` will actually run the fused
    kernel for this stage batch (False = its resident set exceeds
    ``VMEM_BUDGET_BYTES`` and it falls back to the oracle, paying one HBM
    round trip per stage).  ``summa_ring`` consults this so the
    ``spgemm_hbm_round_trips`` evidence stat is never fabricated on
    fallen-back sizes."""
    stages, n, ka = a_cols.shape
    _, nb, kb = b_cols.shape
    return (
        _resident_bytes(stages, n, ka, nb, kb, capacity, a_vals, b_vals,
                        semiring)
        <= VMEM_BUDGET_BYTES
    )


def spgemm_ring_stages_pallas(
    offsets: jnp.ndarray,
    a_cols: jnp.ndarray,
    a_vals,
    b_cols: jnp.ndarray,
    b_vals,
    *,
    semiring: Semiring,
    capacity: int,
    n_cols_out: int,
    interpret: bool | str = "auto",
):
    """Pallas backend of the ``spgemm_ring_stages`` op: the fused kernel with
    the VMEM-budget fallback.  Bit-identical stage buffers and overflow
    counts to :func:`~repro.kernels.spgemm.ref.spgemm_ring_stages_ref`."""
    fused = fused_path_fits(a_cols, a_vals, b_cols, b_vals,
                            capacity=capacity, semiring=semiring)
    with span("kernel_launch", kind="kernel", kernel="spgemm_ring_stages",
              fused=fused, stages=int(a_cols.shape[0]),
              rows=int(a_cols.shape[1])):
        if not fused:
            return spgemm_ring_stages_ref(
                offsets, a_cols, a_vals, b_cols, b_vals, semiring=semiring,
                capacity=capacity, n_cols_out=n_cols_out,
            )
        return _pallas_raw(
            offsets, a_cols, a_vals, b_cols, b_vals, semiring=semiring,
            capacity=capacity, n_cols_out=n_cols_out, interpret=interpret,
        )


def hbm_round_trips(stages: int, stages_per_call: int = 4) -> int:
    """HBM round trips the fused path needs for ``stages`` ring stages (the
    oracle needs ``stages``)."""
    return -(-int(stages) // max(1, stages_per_call))


register_op("spgemm_ring_stages", "reference", spgemm_ring_stages_ref)
register_op("spgemm_ring_stages", "pallas", spgemm_ring_stages_pallas)
