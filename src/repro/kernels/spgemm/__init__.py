# Fused ring-SUMMA local SpGEMM stage kernel (DESIGN.md §2.11):
#   ref.py    — jnp oracle (one HBM round trip per ring stage)
#   spgemm.py — Pallas grid program fusing a stage batch in VMEM
#   ops.py    — VMEM-budget fallback + backend-dispatch registration
from .ops import (  # noqa: F401
    VMEM_BUDGET_BYTES,
    fused_path_fits,
    hbm_round_trips,
    spgemm_ring_stages_pallas,
)
from .ref import spgemm_ring_stages_ref  # noqa: F401
