"""Deterministic sharded data pipeline.

``SyntheticLMData`` generates a reproducible token stream per (epoch, step,
host-shard) — a stand-in for a real corpus reader with the properties the
fault-tolerance story needs: (a) deterministic resume — restarting from a
checkpoint at step k regenerates exactly the batches ≥ k; (b) host-sharded —
each data-parallel shard draws a disjoint slice; (c) prefetchable.

``TokenPacker`` packs variable-length documents into fixed (B, S) training
rows with cross-document attention boundaries marked by a separator token
(packing is what makes the assigned train_4k shape realistic).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, List

import numpy as np


@dataclasses.dataclass
class SyntheticLMData:
    vocab_size: int
    batch_size: int  # global
    seq_len: int
    seed: int = 0
    frontend: str = "token"
    d_model: int = 0  # for embed-frontend archs

    def batch_at(self, step: int, shard: int = 0, n_shards: int = 1) -> dict:
        """Deterministic batch for (step, shard) — resume-safe."""
        b_local = self.batch_size // n_shards
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 4096 + shard
        )
        if self.frontend == "token":
            # markov-ish stream so loss has learnable structure
            base = rng.integers(1, self.vocab_size, size=(b_local, 1))
            steps = rng.integers(0, 17, size=(b_local, self.seq_len))
            toks = (base + np.cumsum(steps, axis=1)) % self.vocab_size
            tokens = toks.astype(np.int32)
            labels = np.roll(tokens, -1, axis=1).astype(np.int32)
            labels[:, -1] = -1
            return {"tokens": tokens, "labels": labels}
        emb = rng.normal(0, 1, size=(b_local, self.seq_len, self.d_model))
        labels = rng.integers(0, self.vocab_size,
                              size=(b_local, self.seq_len)).astype(np.int32)
        return {"embeddings": emb.astype(np.float32), "labels": labels}

    def iter_batches(self, start_step: int = 0, shard: int = 0,
                     n_shards: int = 1) -> Iterator[dict]:
        step = start_step
        while True:
            yield self.batch_at(step, shard, n_shards)
            step += 1


@dataclasses.dataclass
class TokenPacker:
    seq_len: int
    sep_token: int = 0

    def pack(self, docs: List[np.ndarray]) -> np.ndarray:
        """Greedy first-fit packing of documents into rows of seq_len."""
        rows: List[List[int]] = []
        for d in docs:
            d = list(d) + [self.sep_token]
            placed = False
            for r in rows:
                if len(r) + len(d) <= self.seq_len:
                    r.extend(d)
                    placed = True
                    break
            if not placed:
                for off in range(0, len(d), self.seq_len):
                    rows.append(d[off : off + self.seq_len])
        out = np.full((len(rows), self.seq_len), self.sep_token, np.int32)
        for i, r in enumerate(rows):
            out[i, : len(r)] = r[: self.seq_len]
        return out
