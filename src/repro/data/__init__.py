from .pipeline import SyntheticLMData, TokenPacker  # noqa: F401
