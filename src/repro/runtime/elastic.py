"""Elastic scaling: reshard a training state onto a different mesh.

Checkpoints store logical (global) arrays (see checkpoint.py), so growing or
shrinking the pod allocation is: build the new mesh → recompute sharding
rules → device_put.  This file provides the in-memory path (no disk round
trip) used when an allocation changes under a live job.
"""

from __future__ import annotations

from typing import Any

import jax

from .sharding import apply_sharding_rules


def reshard_state(state: Any, new_mesh, *, fsdp: bool = False,
                  params_only: bool = False) -> Any:
    """state = (params, opt_state, step) or any pytree of arrays.  Gathers to
    host only when necessary (same-topology fast path is a device_put)."""
    if params_only:
        shardings = apply_sharding_rules(state, new_mesh, fsdp=fsdp)
        return jax.device_put(state, shardings)
    params, opt_state, step = state
    pshard = apply_sharding_rules(params, new_mesh, fsdp=fsdp)
    new_params = jax.device_put(params, pshard)
    # Adam moments shard exactly like their parameters
    mshard = jax.tree.map(lambda s: s, pshard)
    new_opt = type(opt_state)(
        mu=jax.device_put(opt_state.mu, mshard),
        nu=jax.device_put(opt_state.nu, mshard),
    )
    return new_params, new_opt, step
