from .sharding import param_sharding_rules, apply_sharding_rules, batch_sharding  # noqa: F401
from .compression import bf16_compress, int8_compress, CompressedAllReduce  # noqa: F401
from .straggler import StragglerMonitor  # noqa: F401
from .elastic import reshard_state  # noqa: F401
