"""Gradient compression with error feedback (distributed-optimization trick).

``CompressedAllReduce`` wraps the data-parallel gradient reduction:
gradients are compressed (bf16 or int8 with per-tensor scale), all-reduced in
the compressed domain, and the quantization error is fed back into the next
step's gradients (error-feedback accumulators make the compression unbiased
over time — Seide et al.'14 / Karimireddy et al.'19 style).

At 512+ chips the DP all-reduce of a 9B-param fp32 gradient is 36 GB/step;
int8 cuts wire bytes 4× at the cost of one fp32 residual buffer.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


def bf16_compress(g):
    return g.astype(jnp.bfloat16)


def bf16_decompress(c):
    return c.astype(jnp.float32)


def int8_compress(g):
    """Per-tensor symmetric int8. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(g)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decompress(q, scale):
    return q.astype(jnp.float32) * scale


@dataclasses.dataclass(frozen=True)
class CompressedAllReduce:
    """mode: "none" | "bf16" | "int8". Use inside shard_map/pmean context via
    ``reduce(grads, axis_names)`` or standalone for error-feedback compression
    with ``compress_ef``."""

    mode: str = "bf16"

    def init_error(self, params) -> Any:
        if self.mode == "none":
            return None
        return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)

    def compress_ef(self, grads, error):
        """Error-feedback compression: returns (decompressed-compressed
        grads, new_error).  The wire format is what an all-reduce would
        carry."""
        if self.mode == "none":
            return grads, error

        def one(g, e):
            g32 = g.astype(jnp.float32) + e
            if self.mode == "bf16":
                c = bf16_compress(g32)
                d = bf16_decompress(c)
            else:
                q, s = int8_compress(g32)
                d = int8_decompress(q, s)
            return d, g32 - d

        flat_g, tdef = jax.tree.flatten(grads)
        flat_e = jax.tree.leaves(error)
        outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
        dec = jax.tree.unflatten(tdef, [o[0] for o in outs])
        err = jax.tree.unflatten(tdef, [o[1] for o in outs])
        return dec, err

    def reduce(self, grads, axis_names):
        """psum-mean of compressed gradients (inside shard_map)."""
        if self.mode == "none":
            return jax.lax.pmean(grads, axis_names)
        if self.mode == "bf16":
            c = jax.tree.map(bf16_compress, grads)
            r = jax.lax.pmean(c, axis_names)
            return jax.tree.map(bf16_decompress, r)
        # int8: reduce in int32 to avoid overflow, rescale by max scale
        def one(g):
            q, s = int8_compress(g)
            qsum = jax.lax.psum(q.astype(jnp.int32), axis_names)
            smax = jax.lax.pmax(s, axis_names)
            n = jax.lax.psum(1, axis_names)
            return qsum.astype(jnp.float32) * smax / n

        return jax.tree.map(one, grads)

    def wire_bytes(self, params) -> int:
        per = {"none": 4, "bf16": 2, "int8": 1}[self.mode]
        return sum(int(p.size) * per for p in jax.tree.leaves(params))
