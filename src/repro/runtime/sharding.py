"""Parameter/batch sharding rules (t5x-style path-pattern rules).

Training layout: DP over ("pod","data"), TP over "model", optional
FSDP-style extra sharding of the big matrices' non-TP axis over "data".

Rules are matched on the flattened parameter path (e.g.
"slots/0/attn/wq"); first match wins.
"""

from __future__ import annotations

import re
from typing import Any, List, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def param_sharding_rules(mesh: Mesh, *, fsdp: bool = False) -> List[Tuple[str, P]]:
    dp = "data" if "data" in mesh.axis_names else None
    f = dp if fsdp else None
    # NOTE: stacked layer params have a leading (n_periods,) axis -> specs
    # below are prefixed with None at apply time for paths under "slots/".
    return [
        (r".*embed$", P("model", None)),  # (V, D) vocab-sharded
        (r".*unembed$", P(None, "model")),  # (D, V)
        (r".*attn/wq$", P(f, "model")),
        # kv heads < tp for most GQA archs: replicate kv projections over
        # "model" (MaxText-style kv replication) — the local head-repeat then
        # shards the full q-head dim with no reshard roundtrip.
        (r".*attn/wk$", P(f, None)),
        (r".*attn/wv$", P(f, None)),
        (r".*attn/wo$", P("model", f)),
        (r".*q_norm$|.*k_norm$", P()),
        (r".*(mlp|shared)/w_gate$", P(f, "model")),
        (r".*(mlp|shared)/w_up$", P(f, "model")),
        (r".*(mlp|shared)/w_down$", P("model", f)),
        (r".*(mlp|shared)/w_in$", P(f, "model")),
        (r".*(mlp|shared)/w_out$", P("model", f)),
        (r".*moe/router$", P(f, None)),
        (r".*moe/w_gate$", P("model", f, None)),  # (E, D, F) expert-sharded
        (r".*moe/w_up$", P("model", f, None)),
        (r".*moe/w_down$", P("model", f, None)),
        (r".*ssm/in_proj$", P(f, "model")),
        (r".*ssm/out_proj$", P("model", f)),
        (r".*ssm/conv_w$", P(None, "model")),
        (r".*ssm/conv_b$", P("model")),
        (r".*ssm/norm$", P("model")),
        (r".*", P()),  # norms, scalars: replicated
    ]


def _spec_for(path: str, rules, stacked: bool) -> P:
    for pat, spec in rules:
        if re.match(pat, path):
            if stacked:
                return P(None, *spec)
            return spec
    return P()


def apply_sharding_rules(params: Any, mesh: Mesh, *, fsdp: bool = False) -> Any:
    """Returns a pytree of NamedSharding matching ``params``."""
    rules = param_sharding_rules(mesh, fsdp=fsdp)
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        key = "/".join(str(p.key) if hasattr(p, "key") else str(p.idx)
                       for p in path)
        stacked = key.startswith("slots/")
        spec = _spec_for(key, rules, stacked)
        # drop axes that don't divide the dim (e.g. tiny reduced configs)
        clean = []
        for i, ax in enumerate(spec):
            if ax is None:
                clean.append(None)
                continue
            axes = (ax,) if isinstance(ax, str) else tuple(ax)
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            if i < leaf.ndim and leaf.shape[i] % size == 0:
                clean.append(ax)
            else:
                clean.append(None)
        out.append(NamedSharding(mesh, P(*clean)))
    return jax.tree_util.tree_unflatten(treedef, out)


def batch_sharding(mesh: Mesh, batch_size: int | None = None) -> NamedSharding:
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if batch_size is not None:
        n = 1
        for a in dp_axes:
            n *= mesh.shape[a]
        if batch_size % n != 0:
            return NamedSharding(mesh, P())  # e.g. long_500k batch=1
    return NamedSharding(mesh, P(dp_axes))


def cache_sharding(mesh: Mesh, caches: Any, *, seq_sharded: bool) -> Any:
    """KV caches: (period, B, S, H, D) — batch over dp axes and, for long
    contexts, S over 'model' (split-KV decode)."""
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def spec(leaf):
        n_dp = 1
        for a in dp_axes:
            n_dp *= mesh.shape[a]
        bdim = leaf.shape[1] if leaf.ndim > 1 else 1
        bspec = dp_axes if bdim % n_dp == 0 else None
        if leaf.ndim >= 3 and seq_sharded and leaf.shape[2] % mesh.shape.get("model", 1) == 0:
            return NamedSharding(mesh, P(None, bspec, "model"))
        return NamedSharding(mesh, P(None, bspec))

    return jax.tree.map(spec, caches)
