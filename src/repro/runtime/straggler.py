"""Straggler detection & mitigation policy (host-side runtime service).

On a real multi-host deployment each host reports per-step wall-clock; the
monitor keeps an EWMA per host, flags hosts slower than
``threshold × median`` for ``patience`` consecutive steps, and the launcher
acts on the flags (re-shard the data pipeline away from the host / swap in a
hot spare / exclude from the next allocation — hooks below).  The detection
logic is deterministic and unit-tested with injected timings; the actuation
hooks are no-ops on a single host.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional


@dataclasses.dataclass
class StragglerMonitor:
    n_hosts: int
    threshold: float = 1.5  # × median EWMA
    patience: int = 3
    alpha: float = 0.3  # EWMA coefficient
    on_straggler: Optional[Callable[[int], None]] = None

    def __post_init__(self):
        self._ewma: List[Optional[float]] = [None] * self.n_hosts
        self._strikes = [0] * self.n_hosts
        self.flagged: set = set()
        self.history: List[Dict] = []

    def report(self, host: int, step_time: float) -> None:
        prev = self._ewma[host]
        self._ewma[host] = (
            step_time if prev is None
            else self.alpha * step_time + (1 - self.alpha) * prev
        )

    def evaluate(self) -> List[int]:
        """Call once per step after all reports; returns newly flagged hosts."""
        vals = [v for v in self._ewma if v is not None]
        if len(vals) < max(2, self.n_hosts // 2):
            return []
        med = sorted(vals)[len(vals) // 2]
        new = []
        for h, v in enumerate(self._ewma):
            if v is None:
                continue
            if v > self.threshold * med:
                self._strikes[h] += 1
            else:
                self._strikes[h] = 0
                self.flagged.discard(h)
            if self._strikes[h] >= self.patience and h not in self.flagged:
                self.flagged.add(h)
                new.append(h)
                if self.on_straggler:
                    self.on_straggler(h)
        self.history.append({"median": med, "flagged": sorted(self.flagged)})
        return new

    # --- actuation hooks (no-ops on single host; launcher overrides) ---
    def reassign_data_shards(self, host: int):  # pragma: no cover - hook
        """Move the host's input shards to its neighbours (deterministic
        round-robin), so a slow host never gates the input pipeline."""
        return [(host, (host + 1) % self.n_hosts)]
