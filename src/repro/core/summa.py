"""Distributed 2D Sparse SUMMA over semirings (paper §IV-D, §V-B).

Process-grid mapping
--------------------
CombBLAS organizes P processes as a √P×√P grid; we map grid **rows** onto the
mesh axes ``row_axes`` (``("data",)`` single-pod, ``("pod", "data")``
multi-pod) and grid **columns** onto ``col_axis`` ("model").

A distributed sparse matrix (``DistEll``) is a global ELL whose
(rows, capacity) arrays are sharded ``P(row_axes, col_axis)``: the capacity
axis is split into per-grid-column *blocks*, so the local shard of device
(i, j) is exactly CombBLAS's 2D block A_ij — entries of rows
``i·n/pr …`` whose (global) column ids fall in grid-column j's range.

Algorithms
----------
* ``summa_allgather`` — the broadcast-all SUMMA variant: all-gather A along
  grid rows' *column* axis (each device obtains its full block-row of A) and
  B along grid *rows* (full block-column of B), then one local semiring
  SpGEMM.  Moves the same words as staged SUMMA (W = am/√P per the paper's
  Table I) with √P× the panel memory — the right trade at dry-run scale and
  the baseline for §Perf.
* ``summa_ring`` — Cannon-style ring for square grids: pre-skew with
  ``collective_permute``, then √P pipelined stages of (local multiply ⊕
  rotate).  Panel memory O(block); the per-stage permutes overlap with the
  local multiply under XLA's latency-hiding scheduler — this is the
  compute/comm-overlap variant recorded in EXPERIMENTS.md §Perf.
* ``dist_transitive_reduction`` — Algorithm 2 with the N = R² square computed
  by distributed SUMMA, the row-max reduced with an all-reduce over the grid
  row, and the prune/element-wise steps local (they are "executed in-place so
  that they do not contribute to communication time", §V-D).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import pvary, shard_map
from .semiring import INF, Semiring, minplus_orient_semiring as MPSR, tree_where
from .spgemm import spgemm
from .spmat import EllMatrix, NO_COL, from_coo, merge_sorted_rows, prune


@dataclasses.dataclass
class DistEll:
    """A 2D-block-distributed ELL matrix (host-side handle)."""

    mat: EllMatrix  # global arrays, sharded P(row_axes, col_axis)
    mesh: Mesh
    row_axes: tuple  # mesh axes carrying grid rows, e.g. ("pod", "data")
    col_axis: str  # mesh axis carrying grid columns

    @property
    def pr(self) -> int:
        return int(
            jnp.prod(jnp.array([self.mesh.shape[a] for a in self.row_axes]))
        )

    @property
    def pc(self) -> int:
        return self.mesh.shape[self.col_axis]

    @property
    def block_capacity(self) -> int:
        return self.mat.capacity // self.pc

    def spec(self) -> P:
        return P(self.row_axes, self.col_axis)


def distribute_ell(
    rows: jnp.ndarray,
    cols: jnp.ndarray,
    vals: Any,
    valid: jnp.ndarray,
    *,
    n_rows: int,
    n_cols: int,
    block_capacity: int,
    semiring: Semiring,
    mesh: Mesh,
    row_axes: Sequence[str] = ("data",),
    col_axis: str = "model",
):
    """Build a DistEll from COO triplets.  Entries are bucketed by global
    column block (col // ceil(n_cols/pc)); each (row, block) gets
    ``block_capacity`` slots.  Returns (DistEll, overflow)."""
    pc = mesh.shape[col_axis]
    cb = -(-n_cols // pc)  # ceil
    blk = jnp.where(valid, cols // cb, 0)
    # rank key: one pseudo-row per (row, block)
    prow = rows * pc + blk
    m2, overflow = from_coo(
        prow,
        cols,
        vals,
        valid,
        n_rows=n_rows * pc,
        n_cols=n_cols,
        capacity=block_capacity,
        semiring=semiring,
    )
    g_cols = m2.cols.reshape(n_rows, pc * block_capacity)
    g_vals = jax.tree.map(
        lambda v: v.reshape((n_rows, pc * block_capacity) + v.shape[2:]), m2.vals
    )
    spec = P(tuple(row_axes), col_axis)
    sharding = NamedSharding(mesh, spec)
    mat = EllMatrix(
        cols=jax.device_put(g_cols, sharding),
        vals=jax.tree.map(lambda x: jax.device_put(x, sharding), g_vals),
        n_cols=n_cols,
    )
    return (
        DistEll(mat=mat, mesh=mesh, row_axes=tuple(row_axes), col_axis=col_axis),
        overflow,
    )


def collect(d: DistEll) -> EllMatrix:
    """Gather a DistEll to a host-local EllMatrix (tests / small outputs)."""
    return jax.tree.map(lambda x: jax.device_get(x), d.mat)


def _local_spgemm_panels(
    a_cols, a_vals, b_cols, b_vals, *, semiring, capacity, n_cols_out,
    b_row_offset=None, row_chunk=None,
):
    """Local multiply of an A panel (n_loc, KA; global m-ids) by a B panel
    (rows a contiguous global row-block starting at ``b_row_offset``, or the
    full m when offset is None)."""
    if b_row_offset is not None:
        nb = b_cols.shape[0]
        rebased = a_cols - b_row_offset
        in_range = (rebased >= 0) & (rebased < nb) & (a_cols >= 0)
        a_cols = jnp.where(in_range, rebased, NO_COL)
    a = EllMatrix(cols=a_cols, vals=a_vals, n_cols=b_cols.shape[0])
    b = EllMatrix(cols=b_cols, vals=b_vals, n_cols=n_cols_out)
    c, ovf = spgemm(a, b, semiring=semiring, capacity=capacity,
                    row_chunk=row_chunk)
    return c.cols, c.vals, ovf


def summa_allgather(
    a: DistEll, b: DistEll, *, semiring: Semiring, out_block_capacity: int,
    row_chunk: int | None = None, build_only: bool = False,
):
    """C = A ⊗ B (n×m · m×p). Returns (DistEll C, overflow).

    Per-device comm: one all-gather of A along the grid columns
    (words = nnz(A)·pc/P ≈ am/√P, matching Table I) and one all-gather of B
    along the grid rows (words = nnz(B)·pr/P)."""
    mesh = a.mesh
    row_axes, col_axis = a.row_axes, a.col_axis
    spec = P(row_axes, col_axis)
    n_cols_out = b.mat.n_cols

    def f(a_cols, a_vals, b_cols, b_vals):
        # Block-row panel of A: local shard already holds the device's column
        # block; gather the rest of the row (grid-column axis).
        ac = jax.lax.all_gather(a_cols, col_axis, axis=1, tiled=True)
        av = jax.tree.map(
            lambda v: jax.lax.all_gather(v, col_axis, axis=1, tiled=True), a_vals
        )
        # Block-column panel of B: gather all grid rows.
        bc = b_cols
        bv = b_vals
        for ax in reversed(row_axes):
            bc = jax.lax.all_gather(bc, ax, axis=0, tiled=True)
            bv = jax.tree.map(
                lambda v: jax.lax.all_gather(v, ax, axis=0, tiled=True), bv
            )
        cc, cv, ovf = _local_spgemm_panels(
            ac, av, bc, bv,
            semiring=semiring,
            capacity=out_block_capacity,
            n_cols_out=n_cols_out,
            row_chunk=row_chunk,
        )
        return cc, cv, jax.lax.psum(ovf, (*row_axes, col_axis))

    fm = jax.jit(
        shard_map(
            f,
            mesh=mesh,
            in_specs=(spec, spec, spec, spec),
            out_specs=(spec, spec, P()),
        )
    )
    if build_only:
        return fm
    cc, cv, ovf = fm(a.mat.cols, a.mat.vals, b.mat.cols, b.mat.vals)
    cm = EllMatrix(cols=cc, vals=cv, n_cols=n_cols_out)
    return DistEll(mat=cm, mesh=mesh, row_axes=row_axes, col_axis=col_axis), ovf


def _skew_a(mat: EllMatrix, pr: int, pc: int) -> EllMatrix:
    """Cannon pre-skew of A (host/global view): block (i, j) ← block
    (i, (i+j) mod pc).  The capacity axis carries the column blocks, so this
    is a per-block-row roll of block slices."""
    n, ktot = mat.cols.shape
    kb = ktot // pc
    nb = n // pr
    i_of_row = jnp.arange(n) // nb  # grid row per matrix row
    j_of_slot = jnp.arange(ktot) // kb
    s_of_slot = jnp.arange(ktot) % kb
    src_j = (i_of_row[:, None] + j_of_slot[None, :]) % pc
    idx = src_j * kb + s_of_slot[None, :]
    take = lambda x: jnp.take_along_axis(
        x, idx.reshape(idx.shape + (1,) * (x.ndim - 2)), axis=1
    )
    return EllMatrix(
        cols=take(mat.cols), vals=jax.tree.map(take, mat.vals), n_cols=mat.n_cols
    )


def _skew_b(mat: EllMatrix, pr: int, pc: int) -> EllMatrix:
    """Cannon pre-skew of B: block (i, j) ← block ((i+j) mod pr, j) — a
    per-block-column roll of row blocks."""
    n, ktot = mat.cols.shape
    kb = ktot // pc
    nb = n // pr
    i_of_row = jnp.arange(n) // nb
    r_in_blk = jnp.arange(n) % nb
    j_of_slot = jnp.arange(ktot) // kb
    src_i = (i_of_row[:, None] + j_of_slot[None, :]) % pr  # (n, ktot)
    src_row = src_i * nb + r_in_blk[:, None]
    take = lambda x: x[src_row, jnp.arange(ktot)[None, :]]
    return EllMatrix(
        cols=take(mat.cols), vals=jax.tree.map(take, mat.vals), n_cols=mat.n_cols
    )


def summa_ring(a: DistEll, b: DistEll, *, semiring: Semiring, out_block_capacity: int):
    """Cannon-style ring SUMMA for square grids (pr == pc, single row axis).

    After the pre-skew, device (i, j) holds A(i, (i+j) mod pc) and
    B((i+j) mod pr, j); each of the pc stages does a local semiring multiply,
    ⊕-merges into the accumulator, and rotates A left / B up with a static
    ``ppermute`` ring.  Panel memory O(block) vs O(√P·block) for the
    all-gather variant; the rotations overlap with the local multiply under
    XLA's latency-hiding scheduler."""
    mesh = a.mesh
    assert len(a.row_axes) == 1, "ring SUMMA requires a single grid-row axis"
    (row_axis,) = a.row_axes
    col_axis = a.col_axis
    pr, pc = mesh.shape[row_axis], mesh.shape[col_axis]
    assert pr == pc, "ring SUMMA requires a square grid"
    spec = P((row_axis,), col_axis)
    n_cols_out = b.mat.n_cols
    m_total = b.mat.cols.shape[0]
    nb_b = m_total // pr  # B block row count == A column-block width
    cb = -(-a.mat.n_cols // pc)

    a_sk = _skew_a(a.mat, pr, pc)
    b_sk = _skew_b(b.mat, pr, pc)

    def f(a_cols, a_vals, b_cols, b_vals):
        i = jax.lax.axis_index(row_axis)
        j = jax.lax.axis_index(col_axis)
        n_loc = a_cols.shape[0]
        both = (row_axis, col_axis)
        acc_cols = pvary(
            jnp.full((n_loc, out_block_capacity), NO_COL, dtype=jnp.int32), both
        )
        acc_vals = jax.tree.map(
            lambda x: pvary(x, both),
            semiring.zero((n_loc, out_block_capacity)),
        )
        left = [((t + 1) % pc, t) for t in range(pc)]  # rotate left/up

        def stage(s, carry):
            acc_cols, acc_vals, ac, av, bc, bv, ovf = carry
            k = (i + j + s) % pc  # current panel index
            cc, cv, so = _local_spgemm_panels(
                ac, av, bc, bv,
                semiring=semiring,
                capacity=out_block_capacity,
                n_cols_out=n_cols_out,
                b_row_offset=k * nb_b,
            )
            merged_cols = jnp.concatenate([acc_cols, cc], axis=1)
            merged_vals = jax.tree.map(
                lambda x, y: jnp.concatenate([x, y], axis=1), acc_vals, cv
            )
            mc, mv, mo = merge_sorted_rows(
                merged_cols, merged_vals,
                capacity=out_block_capacity, semiring=semiring,
            )
            ac2 = jax.lax.ppermute(ac, col_axis, left)
            av2 = jax.tree.map(lambda v: jax.lax.ppermute(v, col_axis, left), av)
            bc2 = jax.lax.ppermute(bc, row_axis, left)
            bv2 = jax.tree.map(lambda v: jax.lax.ppermute(v, row_axis, left), bv)
            return (mc, mv, ac2, av2, bc2, bv2, ovf + so + mo)

        init = (
            acc_cols, acc_vals, a_cols, a_vals, b_cols, b_vals,
            pvary(jnp.int32(0), both),
        )
        acc_cols, acc_vals, *_, ovf = jax.lax.fori_loop(0, pc, stage, init)
        return acc_cols, acc_vals, jax.lax.psum(ovf, (row_axis, col_axis))

    fm = jax.jit(
        shard_map(
            f, mesh=mesh, in_specs=(spec, spec, spec, spec),
            out_specs=(spec, spec, P()),
        )
    )
    cc, cv, ovf = fm(a_sk.cols, a_sk.vals, b_sk.cols, b_sk.vals)
    cm = EllMatrix(cols=cc, vals=cv, n_cols=n_cols_out)
    return DistEll(mat=cm, mesh=mesh, row_axes=a.row_axes, col_axis=col_axis), ovf


# ---------------------------------------------------------------------------
# Distributed transitive reduction (Algorithm 2 on the mesh).
# ---------------------------------------------------------------------------


def dist_transitive_reduction(
    r: DistEll,
    fuzz: float = 200.0,
    *,
    n_block_capacity: int | None = None,
    max_iters: int = 10,
    fused: bool = False,
    row_chunk: int | None = None,
    build_only: bool = False,
):
    """Distributed Algorithm 2.  ``fused=True`` uses the sampled square
    (beyond-paper; N restricted to R's pattern — the A panel gather still
    happens, but no B-panel pattern growth and no stage sort)."""
    mesh = r.mesh
    row_axes, col_axis = r.row_axes, r.col_axis
    spec = P(row_axes, col_axis)
    kb = r.block_capacity
    if n_block_capacity is None:
        n_block_capacity = min(kb * kb, 4 * kb)
    n_total = r.mat.n_cols

    def f(r_cols, r_vals):
        def nnz_of(cols):
            return jax.lax.psum(
                jnp.sum(cols >= 0).astype(jnp.int32), (*row_axes, col_axis)
            )

        def body(carry):
            r_cols, r_vals, prev, cur, it = carry
            # --- N = R² (lines 3-4): allgather panels, local multiply ---
            ac = jax.lax.all_gather(r_cols, col_axis, axis=1, tiled=True)
            av = jax.lax.all_gather(r_vals, col_axis, axis=1, tiled=True)
            bc, bv = r_cols, r_vals
            for ax in reversed(row_axes):
                bc = jax.lax.all_gather(bc, ax, axis=0, tiled=True)
                bv = jax.lax.all_gather(bv, ax, axis=0, tiled=True)
            a_loc = EllMatrix(cols=ac, vals=av, n_cols=n_total)
            b_loc = EllMatrix(cols=bc, vals=bv, n_cols=n_total)
            if fused:
                from .spgemm import spgemm_masked

                mask = EllMatrix(cols=r_cols, vals=r_vals, n_cols=n_total)
                n_at_r = spgemm_masked(a_loc, b_loc, mask, semiring=MPSR,
                                       row_chunk=row_chunk)
                got, found = n_at_r.vals, mask.mask
            else:
                n_loc, _ = spgemm(
                    a_loc, b_loc, semiring=MPSR, capacity=n_block_capacity,
                    row_chunk=row_chunk,
                )
                got, found = n_loc.lookup(MPSR, r_cols)
            # --- M = rowmax + fuzz (lines 5-7): local max, all-reduce row ---
            vals_m = jnp.where(jnp.isfinite(r_vals), r_vals, -INF)
            vals_m = jnp.where((r_cols >= 0)[:, :, None], vals_m, -INF)
            local_max = jnp.max(vals_m, axis=(1, 2))
            row_max = jax.lax.pmax(local_max, col_axis) + fuzz
            # --- I = M ≥ N with orientation checks (line 8) ---
            trans = (
                (got <= row_max[:, None, None])
                & jnp.isfinite(got)
                & found[:, :, None]
                & jnp.isfinite(r_vals)
            )
            # --- prune (line 9), local/in-place per §V-D ---
            new_vals = jnp.where(trans, INF, r_vals)
            dead = ~jnp.any(jnp.isfinite(new_vals), axis=-1) & (r_cols >= 0)
            pruned = prune(
                EllMatrix(cols=r_cols, vals=new_vals, n_cols=n_total), dead, MPSR
            )
            return (pruned.cols, pruned.vals, cur, nnz_of(pruned.cols), it + 1)

        def cond(carry):
            _, _, prev, cur, it = carry
            return (cur != prev) & (it < max_iters)

        init = (r_cols, r_vals, jnp.int32(-1), nnz_of(r_cols), jnp.int32(0))
        r_cols, r_vals, _, nnz_f, iters = jax.lax.while_loop(cond, body, init)
        return r_cols, r_vals, iters, nnz_f

    fm = jax.jit(
        shard_map(
            f, mesh=mesh, in_specs=(spec, spec),
            out_specs=(spec, spec, P(), P()),
        )
    )
    if build_only:
        return fm
    cols, vals, iters, nnz_f = fm(r.mat.cols, r.mat.vals)
    out = DistEll(
        mat=EllMatrix(cols=cols, vals=vals, n_cols=n_total),
        mesh=mesh,
        row_axes=row_axes,
        col_axis=col_axis,
    )
    return out, iters, nnz_f
