"""Distributed 2D Sparse SUMMA over semirings (paper §IV-D, §V-B).

Process-grid mapping
--------------------
CombBLAS organizes P processes as a √P×√P grid; we map grid **rows** onto the
mesh axes ``row_axes`` (``("data",)`` single-pod, ``("pod", "data")``
multi-pod) and grid **columns** onto ``col_axis`` ("model").

A distributed sparse matrix (``DistEll``) is a global ELL whose
(rows, capacity) arrays are sharded ``P(row_axes, col_axis)``: the capacity
axis is split into per-grid-column *blocks*, so the local shard of device
(i, j) is exactly CombBLAS's 2D block A_ij — entries of rows
``i·n/pr …`` whose (global) column ids fall in grid-column j's range.

Algorithms
----------
* ``summa_allgather`` — the broadcast-all SUMMA variant: all-gather A along
  grid rows' *column* axis (each device obtains its full block-row of A) and
  B along grid *rows* (full block-column of B), then one local semiring
  SpGEMM.  Moves the same words as staged SUMMA (W = am/√P per the paper's
  Table I) with √P× the panel memory — the right trade at dry-run scale and
  the baseline for §Perf.
* ``summa_ring`` — Cannon-style explicit-exchange ring for square grids:
  pre-skew once, then √P pipelined stages of (fused local semiring multiply ⊕
  ``ppermute`` rotate), the rotate for the next stage batch overlapping the
  in-flight multiply under XLA's latency-hiding scheduler.  The local
  multiply is the backend-dispatched ``spgemm_ring_stages`` op
  (``kernels/spgemm/``, DESIGN.md §2.11); per-stage buffers are reordered
  into canonical k-block order before the single final merge so the
  distributed product is bit-identical to the local ``spgemm`` even under
  the order-dependent overlap-semiring ⊕.  Every ``ppermute`` is accounted:
  ``exchange_words_summa``/``exchange_rounds_summa`` in the returned stats
  are the measured twins of ``bench_comm_model.words_summa`` (the paper's
  Table I W = am/√P term).  Non-square or multi-row-axis grids route loudly
  to ``summa_allgather`` (recorded in stats) instead of asserting.
* ``dist_transitive_reduction`` — Algorithm 2 with the N = R² square computed
  by distributed SUMMA, the row-max reduced with an all-reduce over the grid
  row, and the prune/element-wise steps local (they are "executed in-place so
  that they do not contribute to communication time", §V-D).
  ``summa="ring"`` (or :func:`dist_transitive_reduction_ring`) computes the
  square with the explicit-exchange ring instead of the all-gather panels.
* ``overlap_spgemm_shard_map`` — the pipeline's overlap-stage entry point
  (``PipelineConfig.distribution="shard_map"``): pad + distribute host-local
  A/Aᵀ, ring SUMMA, collect and canonically re-merge — bit-identical ELL
  output and overflow counts to the local ``spgemm`` whenever no per-block
  capacity truncates (the pipeline's static capacities guarantee that for
  the operands; output rows overflowing ``capacity`` truncate identically).
"""

from __future__ import annotations

import dataclasses
import math
from functools import lru_cache, partial
from typing import Any, Dict, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import pvary, shard_map
from ..obs import schema, validated
from ..obs.trace import span
from .backend import dispatch, resolve_backend
from .semiring import INF, Semiring, minplus_orient_semiring as MPSR, tree_where
from .spgemm import spgemm
from .spmat import EllMatrix, NO_COL, from_coo, merge_sorted_rows, prune


@dataclasses.dataclass
class DistEll:
    """A 2D-block-distributed ELL matrix (host-side handle)."""

    mat: EllMatrix  # global arrays, sharded P(row_axes, col_axis)
    mesh: Mesh
    row_axes: tuple  # mesh axes carrying grid rows, e.g. ("pod", "data")
    col_axis: str  # mesh axis carrying grid columns

    @property
    def pr(self) -> int:
        """Process-grid rows (product of the row-axis mesh sizes)."""
        return int(
            jnp.prod(jnp.array([self.mesh.shape[a] for a in self.row_axes]))
        )

    @property
    def pc(self) -> int:
        """Process-grid columns (the ``col_axis`` mesh size)."""
        return self.mesh.shape[self.col_axis]

    @property
    def block_capacity(self) -> int:
        """Per-column-block slot capacity (global capacity / ``pc``)."""
        return self.mat.capacity // self.pc

    def spec(self) -> P:
        """The ``PartitionSpec`` placing rows on ``row_axes``, slots on ``col_axis``."""
        return P(self.row_axes, self.col_axis)


def distribute_ell(
    rows: jnp.ndarray,
    cols: jnp.ndarray,
    vals: Any,
    valid: jnp.ndarray,
    *,
    n_rows: int,
    n_cols: int,
    block_capacity: int,
    semiring: Semiring,
    mesh: Mesh,
    row_axes: Sequence[str] = ("data",),
    col_axis: str = "model",
):
    """Build a DistEll from COO triplets.  Entries are bucketed by global
    column block (col // ceil(n_cols/pc)); each (row, block) gets
    ``block_capacity`` slots.  Returns (DistEll, overflow)."""
    pc = mesh.shape[col_axis]
    cb = -(-n_cols // pc)  # ceil
    blk = jnp.where(valid, cols // cb, 0)
    # rank key: one pseudo-row per (row, block)
    prow = rows * pc + blk
    m2, overflow = from_coo(
        prow,
        cols,
        vals,
        valid,
        n_rows=n_rows * pc,
        n_cols=n_cols,
        capacity=block_capacity,
        semiring=semiring,
    )
    g_cols = m2.cols.reshape(n_rows, pc * block_capacity)
    g_vals = jax.tree.map(
        lambda v: v.reshape((n_rows, pc * block_capacity) + v.shape[2:]), m2.vals
    )
    spec = P(tuple(row_axes), col_axis)
    sharding = NamedSharding(mesh, spec)
    mat = EllMatrix(
        cols=jax.device_put(g_cols, sharding),
        vals=jax.tree.map(lambda x: jax.device_put(x, sharding), g_vals),
        n_cols=n_cols,
    )
    return (
        DistEll(mat=mat, mesh=mesh, row_axes=tuple(row_axes), col_axis=col_axis),
        overflow,
    )


def collect(d: DistEll) -> EllMatrix:
    """Gather a DistEll to a host-local EllMatrix (tests / small outputs)."""
    return jax.tree.map(lambda x: jax.device_get(x), d.mat)


def _local_spgemm_panels(
    a_cols, a_vals, b_cols, b_vals, *, semiring, capacity, n_cols_out,
    b_row_offset=None, row_chunk=None,
):
    """Local multiply of an A panel (n_loc, KA; global m-ids) by a B panel
    (rows a contiguous global row-block starting at ``b_row_offset``, or the
    full m when offset is None)."""
    if b_row_offset is not None:
        nb = b_cols.shape[0]
        rebased = a_cols - b_row_offset
        in_range = (rebased >= 0) & (rebased < nb) & (a_cols >= 0)
        a_cols = jnp.where(in_range, rebased, NO_COL)
    a = EllMatrix(cols=a_cols, vals=a_vals, n_cols=b_cols.shape[0])
    b = EllMatrix(cols=b_cols, vals=b_vals, n_cols=n_cols_out)
    c, ovf = spgemm(a, b, semiring=semiring, capacity=capacity,
                    row_chunk=row_chunk)
    return c.cols, c.vals, ovf


@lru_cache(maxsize=None)
def _allgather_program(
    mesh: Mesh, row_axes: tuple, col_axis: str, semiring: Semiring,
    out_block_capacity: int, n_cols_out: int, row_chunk: int | None,
):
    """Build (and cache) the jitted all-gather SUMMA program for one
    (mesh, axes, semiring, capacity, out-width, chunking) key.

    Same motivation as :func:`_ring_program`: the pre-split code rebuilt
    ``jax.jit(shard_map(f))`` inside ``summa_allgather`` on every call, so
    the fresh closure identity defeated jit's cache and every overlap
    SpGEMM re-traced.  Shapes need not key — jit specializes per shape
    under one cached callable."""
    spec = P(row_axes, col_axis)

    def f(a_cols, a_vals, b_cols, b_vals):
        # Block-row panel of A: local shard already holds the device's column
        # block; gather the rest of the row (grid-column axis).
        # repro: noqa[R003] — XLA-scheduled all-gathers: the analytic
        # exchange_words_summa model covers them; stats are present-and-zero
        # for the explicit-exchange counters by contract.
        ac = jax.lax.all_gather(a_cols, col_axis, axis=1, tiled=True)
        av = jax.tree.map(
            lambda v: jax.lax.all_gather(v, col_axis, axis=1, tiled=True), a_vals
        )
        # Block-column panel of B: gather all grid rows.
        bc = b_cols
        bv = b_vals
        for ax in reversed(row_axes):
            bc = jax.lax.all_gather(bc, ax, axis=0, tiled=True)
            bv = jax.tree.map(
                lambda v: jax.lax.all_gather(v, ax, axis=0, tiled=True), bv
            )
        cc, cv, ovf = _local_spgemm_panels(
            ac, av, bc, bv,
            semiring=semiring,
            capacity=out_block_capacity,
            n_cols_out=n_cols_out,
            row_chunk=row_chunk,
        )
        return cc, cv, jax.lax.psum(ovf, (*row_axes, col_axis))

    return jax.jit(
        shard_map(
            f,
            mesh=mesh,
            in_specs=(spec, spec, spec, spec),
            out_specs=(spec, spec, P()),
        )
    )


def summa_allgather(
    a: DistEll, b: DistEll, *, semiring: Semiring, out_block_capacity: int,
    row_chunk: int | None = None, build_only: bool = False,
):
    """C = A ⊗ B (n×m · m×p). Returns (DistEll C, overflow).

    Per-device comm: one all-gather of A along the grid columns
    (words = nnz(A)·pc/P ≈ am/√P, matching Table I) and one all-gather of B
    along the grid rows (words = nnz(B)·pr/P)."""
    n_cols_out = b.mat.n_cols
    fm = _allgather_program(
        a.mesh, a.row_axes, a.col_axis, semiring, out_block_capacity,
        n_cols_out, row_chunk,
    )
    if build_only:
        return fm
    cc, cv, ovf = fm(a.mat.cols, a.mat.vals, b.mat.cols, b.mat.vals)
    cm = EllMatrix(cols=cc, vals=cv, n_cols=n_cols_out)
    return (
        DistEll(mat=cm, mesh=a.mesh, row_axes=a.row_axes,
                col_axis=a.col_axis),
        ovf,
    )


def _skew_a(mat: EllMatrix, pr: int, pc: int) -> EllMatrix:
    """Cannon pre-skew of A (host/global view): block (i, j) ← block
    (i, (i+j) mod pc).  The capacity axis carries the column blocks, so this
    is a per-block-row roll of block slices."""
    n, ktot = mat.cols.shape
    kb = ktot // pc
    nb = n // pr
    i_of_row = jnp.arange(n) // nb  # grid row per matrix row
    j_of_slot = jnp.arange(ktot) // kb
    s_of_slot = jnp.arange(ktot) % kb
    src_j = (i_of_row[:, None] + j_of_slot[None, :]) % pc
    idx = src_j * kb + s_of_slot[None, :]
    take = lambda x: jnp.take_along_axis(
        x, idx.reshape(idx.shape + (1,) * (x.ndim - 2)), axis=1
    )
    return EllMatrix(
        cols=take(mat.cols), vals=jax.tree.map(take, mat.vals), n_cols=mat.n_cols
    )


def _skew_b(mat: EllMatrix, pr: int, pc: int) -> EllMatrix:
    """Cannon pre-skew of B: block (i, j) ← block ((i+j) mod pr, j) — a
    per-block-column roll of row blocks."""
    n, ktot = mat.cols.shape
    kb = ktot // pc
    nb = n // pr
    i_of_row = jnp.arange(n) // nb
    r_in_blk = jnp.arange(n) % nb
    j_of_slot = jnp.arange(ktot) // kb
    src_i = (i_of_row[:, None] + j_of_slot[None, :]) % pr  # (n, ktot)
    src_row = src_i * nb + r_in_blk[:, None]
    take = lambda x: x[src_row, jnp.arange(ktot)[None, :]]
    return EllMatrix(
        cols=take(mat.cols), vals=jax.tree.map(take, mat.vals), n_cols=mat.n_cols
    )


def default_summa_mesh() -> Mesh:
    """2D ``("data", "model")`` grid over all visible devices, pr·pc = P with
    pr the largest divisor of P that is ≤ √P (4 → 2×2, 8 → 2×4, 9 → 3×3).
    Square whenever P is a perfect square — the shape ``summa_ring``'s
    explicit-exchange path needs; otherwise the ring routes to the recorded
    all-gather fallback."""
    devs = jax.devices()
    d = len(devs)
    pr = max(1, int(math.isqrt(d)))
    while d % pr:
        pr -= 1
    pc = d // pr
    kwargs = {}
    try:  # jax ≥ 0.5 wants explicit axis types
        from jax.sharding import AxisType  # type: ignore[attr-defined]

        kwargs["axis_types"] = (AxisType.Auto, AxisType.Auto)
    except ImportError:  # pragma: no cover - version-dependent
        pass
    return jax.make_mesh((pr, pc), ("data", "model"), devices=devs, **kwargs)


def _slot_words(vals: Any) -> int:
    """Scalar (4-byte) words exchanged per occupied-or-not ELL slot: the
    int32 column id plus every value-leaf element behind it.  Used for the
    per-``ppermute`` word accounting; the analytic twin lives in
    ``benchmarks/bench_comm_model.words_summa``."""
    words = 1
    for leaf in jax.tree.leaves(vals):
        t = 1
        for d in leaf.shape[2:]:
            t *= d
        words += t
    return words


def distribute_ell_blocks(
    mat: EllMatrix,
    *,
    block_capacity: int,
    semiring: Semiring,
    mesh: Mesh,
    row_axes: Sequence[str] = ("data",),
    col_axis: str = "model",
):
    """Distribute an already-built (row-sorted) host EllMatrix into the 2D
    block layout without re-merging its entries.

    Unlike :func:`distribute_ell` this never needs the semiring ⊕ (entries of
    one ELL row are already unique and column-sorted, so a row's slice for
    grid-column block j is contiguous); ``semiring`` only supplies the zero
    fill for empty slots.  Entry → slot: block = col // ceil(n_cols/pc), rank
    = #same-block predecessors in the row, slot = block·capacity + rank.
    Returns (DistEll, overflow) where overflow counts entries beyond
    ``block_capacity`` in some (row, block) — zero whenever ``block_capacity``
    ≥ the source capacity, the pipeline's configuration."""
    pc = mesh.shape[col_axis]
    n, k = mat.cols.shape
    pr = 1
    for ax in row_axes:
        pr *= mesh.shape[ax]
    if n % pr:
        raise ValueError(
            f"distribute_ell_blocks: {n} rows not divisible by grid rows {pr}"
        )
    cb = -(-mat.n_cols // pc)  # ceil: global column ids per grid column
    valid = mat.cols >= 0
    blk = jnp.where(valid, mat.cols // cb, pc)  # pc = dummy block
    # Rank within (row, block): count same-block predecessors per slot.
    tril = jnp.tril(jnp.ones((k, k), dtype=bool), -1)
    rank = jnp.sum((blk[:, :, None] == blk[:, None, :]) & tril[None], axis=2)
    in_cap = valid & (rank < block_capacity)
    overflow = jnp.sum(valid & (rank >= block_capacity)).astype(jnp.int32)
    # One spare trailing column absorbs every masked-out scatter.
    slot = jnp.where(in_cap, blk * block_capacity + rank, pc * block_capacity)
    rows = jnp.broadcast_to(jnp.arange(n)[:, None], (n, k))
    g_cols = (
        jnp.full((n, pc * block_capacity + 1), NO_COL, dtype=jnp.int32)
        .at[rows, slot].set(jnp.where(in_cap, mat.cols, NO_COL))[:, :-1]
    )
    zero_full = semiring.zero((n, pc * block_capacity + 1))
    g_vals = jax.tree.map(
        lambda z, v: z.at[rows, slot].set(v)[:, :-1], zero_full, mat.vals
    )
    spec = P(tuple(row_axes), col_axis)
    sharding = NamedSharding(mesh, spec)
    out = EllMatrix(
        cols=jax.device_put(g_cols, sharding),
        vals=jax.tree.map(lambda x: jax.device_put(x, sharding), g_vals),
        n_cols=mat.n_cols,
    )
    return (
        DistEll(mat=out, mesh=mesh, row_axes=tuple(row_axes), col_axis=col_axis),
        overflow,
    )


@lru_cache(maxsize=None)
def _ring_program(
    mesh: Mesh, row_axis: str, col_axis: str, pc: int, g: int,
    semiring: Semiring, out_block_capacity: int, n_cols_out: int,
    backend: str, n_loc: int, nb_b: int, wa_rot: int, wb_rot: int,
):
    """Build (and cache) the jitted shard_map ring program for one
    (mesh, grid, semiring, capacity, backend, shape) key.

    Caching is what makes repeated ``summa_ring`` calls steady-state: the
    old per-call ``jax.jit(shard_map(f))`` re-traced and re-compiled the
    whole ring every call (the pre-split ``BENCH_6.json`` overlap row is
    ~14 s of almost pure jit time; ``BENCH_7.json`` splits that into
    ``compile_ms`` vs steady-state ``ms``), and
    ``dist_transitive_reduction_ring`` paid it once per pass.  ``Semiring`` is a frozen dataclass and ``Mesh``
    hashes by value, so both key directly.

    Returns ``(fm, acct)`` where ``acct`` is the trace-time exchange
    accounting dict: the traced body resets it at the start of every trace
    and increments it next to each ``ppermute``, so after the first call it
    holds the per-device words/rounds of the deterministic schedule —
    cached calls reuse the dict, re-traces recount idempotently."""
    spec = P((row_axis,), col_axis)
    acct = {"words": 0, "rounds": 0}
    op = dispatch("spgemm_ring_stages", backend)
    left = [((t + 1) % pc, t) for t in range(pc)]  # rotate left/up

    def f(a_cols, a_vals, b_cols, b_vals):
        acct["words"] = 0  # fresh trace: recount the schedule
        acct["rounds"] = 0
        i = jax.lax.axis_index(row_axis)
        j = jax.lax.axis_index(col_axis)
        both = (row_axis, col_axis)

        def rotate(ac, av, bc, bv):
            # Trace-time accounting: these counters measure the per-device
            # words of every ppermute issued by one execution's schedule.
            acct["words"] += wa_rot + wb_rot
            acct["rounds"] += 1
            ac = jax.lax.ppermute(ac, col_axis, left)
            av = jax.tree.map(lambda v: jax.lax.ppermute(v, col_axis, left), av)
            bc = jax.lax.ppermute(bc, row_axis, left)
            bv = jax.tree.map(lambda v: jax.lax.ppermute(v, row_axis, left), bv)
            return ac, av, bc, bv

        cur = (a_cols, a_vals, b_cols, b_vals)
        chunks_cols, chunks_vals = [], []
        ovf = pvary(jnp.int32(0), both)
        s = 0
        while s < pc:
            sc = min(g, pc - s)
            with span("SpGEMM", kind="phase", phase="ring_stage", s=s,
                      stages=sc):
                panels = [cur]
                for _ in range(sc - 1):
                    cur = rotate(*cur)
                    panels.append(cur)
                st_a_cols = jnp.stack([p[0] for p in panels])
                st_a_vals = jax.tree.map(
                    lambda *xs: jnp.stack(xs), *[p[1] for p in panels]
                )
                st_b_cols = jnp.stack([p[2] for p in panels])
                st_b_vals = jax.tree.map(
                    lambda *xs: jnp.stack(xs), *[p[3] for p in panels]
                )
                offsets = (((i + j + s + jnp.arange(sc)) % pc) * nb_b).astype(
                    jnp.int32
                )
                if s + sc < pc:
                    # Rotation feeding the NEXT batch, issued before the
                    # batch's multiply consumes its own (already stacked)
                    # panels — XLA is free to overlap the exchange with the
                    # in-flight compute.
                    cur = rotate(*cur)
                cc, cv, so = op(
                    offsets, st_a_cols, st_a_vals, st_b_cols, st_b_vals,
                    semiring=semiring, capacity=out_block_capacity,
                    n_cols_out=n_cols_out,
                )
            chunks_cols.append(cc)
            chunks_vals.append(cv)
            ovf = ovf + so
            s += sc
        st_cols = jnp.concatenate(chunks_cols, axis=0)  # (pc, n_loc, cap)
        st_vals = jax.tree.map(
            lambda *xs: jnp.concatenate(xs, axis=0), *chunks_vals
        )
        # Canonical reorder: buffer q ← stage producing k-block q, so the
        # final merge sees candidates in ascending global-column order — the
        # exact sequence the local spgemm's a-slot-ascending expansion feeds
        # merge_sorted_rows (bit-parity for order-dependent ⊕).
        order = (jnp.arange(pc) - (i + j)) % pc
        st_cols = jnp.take(st_cols, order, axis=0)
        st_vals = jax.tree.map(lambda v: jnp.take(v, order, axis=0), st_vals)
        merged_cols = jnp.moveaxis(st_cols, 0, 1).reshape(
            n_loc, pc * out_block_capacity
        )
        merged_vals = jax.tree.map(
            lambda v: jnp.moveaxis(v, 0, 1).reshape(
                (n_loc, pc * out_block_capacity) + v.shape[3:]
            ),
            st_vals,
        )
        with span("SpGEMM", kind="phase", phase="stage_merge"):
            mc, mv, mo = merge_sorted_rows(
                merged_cols, merged_vals,
                capacity=out_block_capacity, semiring=semiring,
            )
        return mc, mv, jax.lax.psum(ovf + mo, both)

    fm = jax.jit(
        shard_map(
            f, mesh=mesh, in_specs=(spec, spec, spec, spec),
            out_specs=(spec, spec, P()),
        )
    )
    return fm, acct


def summa_ring(
    a: DistEll,
    b: DistEll,
    *,
    semiring: Semiring,
    out_block_capacity: int,
    backend: str = "auto",
    stages_per_call: int = 4,
    strict: bool = False,
):
    """Explicit-exchange Cannon ring SUMMA.  Returns (DistEll C, overflow,
    stats).

    Square single-row-axis grids run the ring: one host-side pre-skew, then
    pc stages grouped into batches of ``stages_per_call``.  Each batch is one
    call of the backend-dispatched ``spgemm_ring_stages`` op (the fused
    Pallas kernel keeps panels and stage outputs VMEM-resident for the whole
    batch); between batches a single ``ppermute`` rotation runs concurrently
    with the in-flight multiply under XLA's latency-hiding scheduler — the
    compute/communication overlap the paper attributes to staged SUMMA.

    Bit-parity: stage s on device (i, j) multiplies k-block (i+j+s) mod pc —
    a device-dependent order under which the overlap semiring's ⊕ (keep-first
    position pairs) is NOT invariant.  The op therefore returns per-stage
    buffers which are reordered into canonical ascending-k order and merged
    **once**, reproducing the exact candidate sequence of the local
    ``spgemm`` — bit-identical values and overflow counts.

    Stats: ``exchange_words_summa``/``exchange_rounds_summa`` are counted at
    trace time next to each ``ppermute`` (measured, per device); CI
    cross-checks them against ``bench_comm_model.words_summa``.
    ``spgemm_hbm_round_trips`` records what the resolved backend actually
    pays (the fused kernel: ceil(pc/stages_per_call); the per-stage
    reference: pc).

    Non-square or multi-row-axis grids cannot form the ring; they raise when
    ``strict`` and otherwise route to :func:`summa_allgather`, recording
    ``summa_algorithm="allgather_fallback"`` + the reason, with the exchange
    stats present and zero (that path has no explicit exchanges)."""
    mesh = a.mesh
    fallback_reason = None
    if len(a.row_axes) != 1:
        fallback_reason = f"multi-axis grid rows {a.row_axes}"
    else:
        (row_axis,) = a.row_axes
        col_axis = a.col_axis
        pr, pc = mesh.shape[row_axis], mesh.shape[col_axis]
        if pr != pc:
            fallback_reason = f"non-square grid {pr}x{pc}"
    if fallback_reason is not None:
        if strict:
            raise ValueError(
                "summa_ring requires a square grid with a single row axis: "
                + fallback_reason
            )
        out, ovf = summa_allgather(
            a, b, semiring=semiring, out_block_capacity=out_block_capacity
        )
        return out, ovf, validated({
            "summa_algorithm": "allgather_fallback",
            "summa_fallback_reason": fallback_reason,
            **schema.zero_defaults("summa_exchange"),
        }, context="summa_allgather_fallback",
            require_groups=("summa_exchange",))

    spec = P((row_axis,), col_axis)
    n_cols_out = b.mat.n_cols
    n_total = a.mat.cols.shape[0]
    m_total = b.mat.cols.shape[0]
    n_loc = n_total // pr
    nb_b = m_total // pr  # B block row count == A panel's rebased id range
    ka = a.block_capacity
    kb = b.block_capacity
    # Words moved by one rotation of both panels (per device, 4-byte scalars).
    wa_rot = n_loc * ka * _slot_words(a.mat.vals)
    wb_rot = nb_b * kb * _slot_words(b.mat.vals)

    resolved = resolve_backend(backend)
    with span("SpGEMM", kind="phase", phase="skew"):
        a_sk = _skew_a(a.mat, pr, pc)
        b_sk = _skew_b(b.mat, pr, pc)
    g = max(1, min(stages_per_call, pc))
    fm, acct = _ring_program(
        mesh, row_axis, col_axis, pc, g, semiring, out_block_capacity,
        n_cols_out, resolved, n_loc, nb_b, wa_rot, wb_rot,
    )
    with span("SpGEMM", kind="phase", phase="ring", pc=pc,
              stages_per_call=g) as sp:
        cc, cv, ovf = sp.set_output(
            fm(a_sk.cols, a_sk.vals, b_sk.cols, b_sk.vals)
        )
    cm = EllMatrix(cols=cc, vals=cv, n_cols=n_cols_out)
    fused = False
    if resolved == "pallas":
        from ..kernels.spgemm.ops import fused_path_fits

        sds = jax.ShapeDtypeStruct
        chunk = min(g, pc)
        a_cols_l = sds((chunk, n_loc, ka), jnp.int32)
        a_vals_l = jax.tree.map(
            lambda v: sds((chunk, n_loc, ka) + v.shape[2:], v.dtype),
            a.mat.vals,
        )
        b_cols_l = sds((chunk, nb_b, kb), jnp.int32)
        b_vals_l = jax.tree.map(
            lambda v: sds((chunk, nb_b, kb) + v.shape[2:], v.dtype),
            b.mat.vals,
        )
        fused = fused_path_fits(
            a_cols_l, a_vals_l, b_cols_l, b_vals_l,
            capacity=out_block_capacity, semiring=semiring,
        )
    from ..kernels.spgemm.ops import hbm_round_trips

    stats = validated({
        "summa_algorithm": "ring",
        "summa_stages": pc,
        "summa_backend": resolved if fused else "reference",
        "exchange_words_summa": acct["words"],
        "exchange_rounds_summa": acct["rounds"],
        "spgemm_hbm_round_trips": hbm_round_trips(pc, g) if fused else pc,
        "spgemm_hbm_round_trips_reference": pc,
    }, context="summa_ring", require_groups=("summa_exchange",))
    return (
        DistEll(mat=cm, mesh=mesh, row_axes=a.row_axes, col_axis=col_axis),
        ovf,
        stats,
    )


def overlap_spgemm_shard_map(
    a: EllMatrix,
    b: EllMatrix,
    *,
    semiring: Semiring,
    operand_semiring: Semiring,
    capacity: int,
    mesh: Mesh | None = None,
    backend: str = "auto",
    stages_per_call: int = 4,
):
    """Distributed C = A ⊗ B for host-local ELL operands — the overlap
    stage's ``distribution="shard_map"`` path (``Pipeline`` calls this for
    the candidate SpGEMM, tests call it directly for parity).

    Pads both operands' rows up to a multiple of the grid rows (empty rows),
    block-distributes them with :func:`distribute_ell_blocks` at their full
    source capacities (so distribution itself can never overflow), runs
    :func:`summa_ring`, then collects and re-merges the block outputs into a
    host EllMatrix of ``capacity`` slots per row.  Bit-identical to
    ``spgemm(a, b, semiring=semiring, capacity=capacity)`` — values and
    overflow count — whenever no single column block contributes more than
    ``capacity`` entries to one output row (the final merge then sees the
    same candidate sequence the local expansion feeds it).

    ``operand_semiring`` supplies the zero fill for the operands' empty
    slots (the operands' value trees differ from the output's).  Returns
    (EllMatrix, overflow, stats) with the :func:`summa_ring` stats passed
    through."""
    if mesh is None:
        mesh = default_summa_mesh()
    if "model" not in mesh.axis_names or len(mesh.axis_names) < 2:
        raise ValueError(
            "overlap_spgemm_shard_map needs a 2D mesh with a 'model' column "
            f"axis; got axes {mesh.axis_names}.  Build one with "
            "default_summa_mesh() or launch.mesh.make_test_mesh."
        )
    col_axis = "model"
    row_axes = tuple(
        ax for ax in ("pod", "data") if ax in mesh.axis_names
    ) or (next(ax for ax in mesh.axis_names if ax != col_axis),)
    pr = 1
    for ax in row_axes:
        pr *= mesh.shape[ax]

    def pad_rows(mat: EllMatrix) -> tuple[EllMatrix, int]:
        n = mat.cols.shape[0]
        n_pad = -(-n // pr) * pr
        if n_pad == n:
            return mat, n
        pad = n_pad - n
        cols = jnp.concatenate(
            [mat.cols,
             jnp.full((pad, mat.cols.shape[1]), NO_COL, dtype=jnp.int32)]
        )
        zero = operand_semiring.zero((pad, mat.cols.shape[1]))
        vals = jax.tree.map(
            lambda v, z: jnp.concatenate([v, z]), mat.vals, zero
        )
        return EllMatrix(cols=cols, vals=vals, n_cols=mat.n_cols), n

    a_pad, n_rows = pad_rows(a)
    b_pad, _ = pad_rows(b)
    with span("SpGEMM", kind="phase", phase="distribute") as sp:
        da, ovf_da = distribute_ell_blocks(
            a_pad, block_capacity=a.capacity, semiring=operand_semiring,
            mesh=mesh, row_axes=row_axes, col_axis=col_axis,
        )
        db, ovf_db = distribute_ell_blocks(
            b_pad, block_capacity=b.capacity, semiring=operand_semiring,
            mesh=mesh, row_axes=row_axes, col_axis=col_axis,
        )
        sp.set_output((da.mat.cols, db.mat.cols))
    cd, ovf_ring, stats = summa_ring(
        da, db, semiring=semiring, out_block_capacity=capacity,
        backend=backend, stages_per_call=stages_per_call,
    )
    with span("SpGEMM", kind="phase", phase="collect_merge"):
        g = collect(cd)
        mc, mv, mo = merge_sorted_rows(
            g.cols, g.vals, capacity=capacity, semiring=semiring
        )
    out = EllMatrix(
        cols=mc[:n_rows],
        vals=jax.tree.map(lambda v: v[:n_rows], mv),
        n_cols=b.n_cols,
    )
    overflow = (
        jnp.int32(ovf_da) + jnp.int32(ovf_db) + jnp.int32(ovf_ring)
        + jnp.int32(mo)
    )
    return out, overflow, stats


# ---------------------------------------------------------------------------
# Distributed transitive reduction (Algorithm 2 on the mesh).
# ---------------------------------------------------------------------------


def dist_transitive_reduction(
    r: DistEll,
    fuzz: float = 200.0,
    *,
    n_block_capacity: int | None = None,
    max_iters: int = 10,
    fused: bool = False,
    row_chunk: int | None = None,
    build_only: bool = False,
    summa: str = "allgather",
):
    """Distributed Algorithm 2.  ``fused=True`` uses the sampled square
    (beyond-paper; N restricted to R's pattern — the A panel gather still
    happens, but no B-panel pattern growth and no stage sort).

    ``summa="ring"`` computes the N = R² square with the explicit-exchange
    ring (:func:`dist_transitive_reduction_ring`) instead of the all-gather
    panels; incompatible with ``fused``/``row_chunk``/``build_only`` (the
    ring iterates host-side so each iteration's exchanges are accounted)."""
    if summa not in ("allgather", "ring"):
        raise ValueError(f"unknown summa variant {summa!r}")
    if summa == "ring":
        if fused or build_only or row_chunk is not None:
            raise ValueError(
                "summa='ring' supports neither fused nor row_chunk nor "
                "build_only"
            )
        out, iters, nnz_f, _ = dist_transitive_reduction_ring(
            r, fuzz, n_block_capacity=n_block_capacity, max_iters=max_iters
        )
        return out, iters, nnz_f
    kb = r.block_capacity
    if n_block_capacity is None:
        n_block_capacity = min(kb * kb, 4 * kb)
    n_total = r.mat.n_cols
    fm = _tr_program(
        r.mesh, r.row_axes, r.col_axis, n_total, n_block_capacity,
        float(fuzz), max_iters, fused, row_chunk,
    )
    if build_only:
        return fm
    cols, vals, iters, nnz_f = fm(r.mat.cols, r.mat.vals)
    out = DistEll(
        mat=EllMatrix(cols=cols, vals=vals, n_cols=n_total),
        mesh=r.mesh,
        row_axes=r.row_axes,
        col_axis=r.col_axis,
    )
    return out, iters, nnz_f


@lru_cache(maxsize=None)
def _tr_program(
    mesh: Mesh, row_axes: tuple, col_axis: str, n_total: int,
    n_block_capacity: int, fuzz: float, max_iters: int, fused: bool,
    row_chunk: int | None,
):
    """Build (and cache) the jitted all-gather transitive-reduction program
    (the full ``while_loop`` fixed-point of Algorithm 2) for one
    (mesh, axes, capacity, fuzz, iteration-policy) key.

    Pre-split, ``dist_transitive_reduction`` rebuilt ``jax.jit(shard_map)``
    per call — every TR invocation in the cell pipeline re-traced the whole
    fixed-point loop (the R001/PR 7 hazard class)."""
    spec = P(row_axes, col_axis)

    def f(r_cols, r_vals):
        def nnz_of(cols):
            # repro: noqa[R003] — scalar nnz tally for the fixed-point
            # test, not a data exchange; excluded from the words model.
            return jax.lax.psum(
                jnp.sum(cols >= 0).astype(jnp.int32), (*row_axes, col_axis)
            )

        def body(carry):
            r_cols, r_vals, prev, cur, it = carry
            # --- N = R² (lines 3-4): allgather panels, local multiply ---
            # repro: noqa[R003] — XLA-scheduled all-gather variant:
            # unaccounted by design (summa='ring' is the measured path);
            # exchange stats stay present-and-zero per the schema contract.
            ac = jax.lax.all_gather(r_cols, col_axis, axis=1, tiled=True)
            av = jax.lax.all_gather(r_vals, col_axis, axis=1, tiled=True)
            bc, bv = r_cols, r_vals
            for ax in reversed(row_axes):
                bc = jax.lax.all_gather(bc, ax, axis=0, tiled=True)
                bv = jax.lax.all_gather(bv, ax, axis=0, tiled=True)
            a_loc = EllMatrix(cols=ac, vals=av, n_cols=n_total)
            b_loc = EllMatrix(cols=bc, vals=bv, n_cols=n_total)
            if fused:
                from .spgemm import spgemm_masked

                mask = EllMatrix(cols=r_cols, vals=r_vals, n_cols=n_total)
                n_at_r = spgemm_masked(a_loc, b_loc, mask, semiring=MPSR,
                                       row_chunk=row_chunk)
                got, found = n_at_r.vals, mask.mask
            else:
                n_loc, _ = spgemm(
                    a_loc, b_loc, semiring=MPSR, capacity=n_block_capacity,
                    row_chunk=row_chunk,
                )
                got, found = n_loc.lookup(MPSR, r_cols)
            # --- M = rowmax + fuzz (lines 5-7): local max, all-reduce row ---
            vals_m = jnp.where(jnp.isfinite(r_vals), r_vals, -INF)
            vals_m = jnp.where((r_cols >= 0)[:, :, None], vals_m, -INF)
            local_max = jnp.max(vals_m, axis=(1, 2))
            row_max = jax.lax.pmax(local_max, col_axis) + fuzz
            # --- I = M ≥ N with orientation checks (line 8) ---
            trans = (
                (got <= row_max[:, None, None])
                & jnp.isfinite(got)
                & found[:, :, None]
                & jnp.isfinite(r_vals)
            )
            # --- prune (line 9), local/in-place per §V-D ---
            new_vals = jnp.where(trans, INF, r_vals)
            dead = ~jnp.any(jnp.isfinite(new_vals), axis=-1) & (r_cols >= 0)
            pruned = prune(
                EllMatrix(cols=r_cols, vals=new_vals, n_cols=n_total), dead, MPSR
            )
            return (pruned.cols, pruned.vals, cur, nnz_of(pruned.cols), it + 1)

        def cond(carry):
            _, _, prev, cur, it = carry
            return (cur != prev) & (it < max_iters)

        init = (r_cols, r_vals, jnp.int32(-1), nnz_of(r_cols), jnp.int32(0))
        r_cols, r_vals, _, nnz_f, iters = jax.lax.while_loop(cond, body, init)
        return r_cols, r_vals, iters, nnz_f

    return jax.jit(
        shard_map(
            f, mesh=mesh, in_specs=(spec, spec),
            out_specs=(spec, spec, P(), P()),
        )
    )


@lru_cache(maxsize=None)
def _tr_prune_program(
    mesh: Mesh, row_axes: tuple, col_axis: str, n_total: int, fuzz: float,
):
    """Build (and cache) the jitted prune step of the ring transitive
    reduction (lines 5-9 of Algorithm 2, local per §V-D).

    The host-side pass loop of :func:`dist_transitive_reduction_ring` calls
    this program once per pass; pre-split it rebuilt ``jax.jit(shard_map)``
    every pass, so each TR pass paid a full re-trace on top of the ring."""
    spec = P(row_axes, col_axis)

    def prune_step(r_cols, r_vals, n_cols_blk, n_vals_blk):
        n_loc = EllMatrix(cols=n_cols_blk, vals=n_vals_blk, n_cols=n_total)
        got, found = n_loc.lookup(MPSR, r_cols)
        vals_m = jnp.where(jnp.isfinite(r_vals), r_vals, -INF)
        vals_m = jnp.where((r_cols >= 0)[:, :, None], vals_m, -INF)
        local_max = jnp.max(vals_m, axis=(1, 2))
        # repro: noqa[R003] — scalar row-max pmax + nnz psum: convergence
        # bookkeeping of the §V-D local prune, not a data exchange; the
        # ring program accounts every word that actually rotates.
        row_max = jax.lax.pmax(local_max, col_axis) + fuzz
        trans = (
            (got <= row_max[:, None, None])
            & jnp.isfinite(got)
            & found[:, :, None]
            & jnp.isfinite(r_vals)
        )
        new_vals = jnp.where(trans, INF, r_vals)
        dead = ~jnp.any(jnp.isfinite(new_vals), axis=-1) & (r_cols >= 0)
        pruned = prune(
            EllMatrix(cols=r_cols, vals=new_vals, n_cols=n_total), dead, MPSR
        )
        nnz = jax.lax.psum(
            jnp.sum(pruned.cols >= 0).astype(jnp.int32), (*row_axes, col_axis)
        )
        return pruned.cols, pruned.vals, nnz

    return jax.jit(
        shard_map(
            prune_step, mesh=mesh, in_specs=(spec, spec, spec, spec),
            out_specs=(spec, spec, P()),
        )
    )


def dist_transitive_reduction_ring(
    r: DistEll,
    fuzz: float = 200.0,
    *,
    n_block_capacity: int | None = None,
    max_iters: int = 10,
    backend: str = "auto",
    stages_per_call: int = 4,
):
    """Distributed Algorithm 2 with the N = R² square on the explicit
    exchange ring.  Returns (DistEll, iters, nnz, stats).

    Unlike the all-gather variant's single ``lax.while_loop``, the iteration
    loop runs host-side: each pass is one :func:`summa_ring` (whose
    ``ppermute`` exchanges are measured per call) followed by a jitted
    shard_map prune step — the lookup / fuzzed row-max pmax / prune pipeline
    of lines 5-9, local per §V-D.  Host-driving the loop is what lets the
    exchange accounting see every rotation; the fixed-point test (nnz
    unchanged) costs one scalar device→host read per pass.  Stats accumulate
    ``exchange_words_summa``/``exchange_rounds_summa`` across passes (zero
    when the grid routes to the all-gather fallback)."""
    mesh = r.mesh
    row_axes, col_axis = r.row_axes, r.col_axis
    kb = r.block_capacity
    if n_block_capacity is None:
        n_block_capacity = min(kb * kb, 4 * kb)
    n_total = r.mat.n_cols
    pf = _tr_prune_program(mesh, row_axes, col_axis, n_total, float(fuzz))

    cur = r
    nnz_cur = int(jnp.sum(r.mat.cols >= 0))
    prev = -1
    it = 0
    stats = {**schema.zero_defaults("summa_exchange"),
             "summa_algorithm": None}
    while nnz_cur != prev and it < max_iters:
        n_sq, _, st = summa_ring(
            cur, cur, semiring=MPSR, out_block_capacity=n_block_capacity,
            backend=backend, stages_per_call=stages_per_call,
        )
        cols, vals, nnz_new = pf(
            cur.mat.cols, cur.mat.vals, n_sq.mat.cols, n_sq.mat.vals
        )
        cur = DistEll(
            mat=EllMatrix(cols=cols, vals=vals, n_cols=n_total),
            mesh=mesh, row_axes=row_axes, col_axis=col_axis,
        )
        stats["exchange_words_summa"] += st["exchange_words_summa"]
        stats["exchange_rounds_summa"] += st["exchange_rounds_summa"]
        stats["summa_algorithm"] = st["summa_algorithm"]
        prev = nnz_cur
        nnz_cur = int(nnz_new)
        it += 1
    return cur, it, nnz_cur, stats
