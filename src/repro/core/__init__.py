# The paper's primary contribution: sparse linear algebra over custom
# semirings for overlap detection (SpGEMM) and transitive reduction, with 2D
# SUMMA distribution (diBELLA 2D, Guidi et al. 2020).
from .semiring import (  # noqa: F401
    INF,
    NUM_POS_PAIRS,
    Semiring,
    bool_semiring,
    count_semiring,
    minplus_orient_semiring,
    overlap_semiring,
    plus_times_f32,
)
from .backend import (  # noqa: F401
    BACKENDS,
    DISTRIBUTIONS,
    available_backends,
    dispatch,
    register_op,
    resolve_backend,
    resolve_distribution,
    resolve_interpret,
)
from .spmat import (  # noqa: F401
    EllMatrix,
    from_coo,
    map_row_blocks,
    merge_sorted_rows,
    prune,
)
from .components import (  # noqa: F401
    break_cycles,
    chain_rank,
    connected_components,
    degrees,
    expand_states,
    path_components,
)
from .components_dist import (  # noqa: F401
    doubling_shard_map,
    infer_row_axes,
)
from .spgemm import spgemm, spgemm_masked, transpose  # noqa: F401
from .string_graph import (  # noqa: F401
    OverlapClass,
    build_overlap_graph,
    classify_overlaps,
    drop_contained,
    edge_list,
)
from .transitive_reduction import (  # noqa: F401
    TRStats,
    transitive_reduction,
    transitive_reduction_fused,
)
from .summa import (  # noqa: F401
    DistEll,
    collect,
    default_summa_mesh,
    dist_transitive_reduction,
    dist_transitive_reduction_ring,
    distribute_ell,
    distribute_ell_blocks,
    overlap_spgemm_shard_map,
    summa_allgather,
    summa_ring,
)
