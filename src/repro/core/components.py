"""Device-side graph primitives for contig generation (DESIGN.md §2.7).

The 2022 follow-up to diBELLA 2D (Guidi et al., *Distributed-Memory Parallel
Contig Generation for De Novo Long-Read Genome Assembly*) shows that the last
host-sequential stage of the pipeline — walking unitigs out of the string
matrix S — is itself expressible as sparse array algebra: branch pruning is an
elementwise degree filter, unitig membership is connected components, and the
in-chain order is a pointer-doubling (log-step) traversal.  This module holds
those primitives; `assembly/contig_gen.py` composes them into the Contigs
stage.

Everything here is jit-compatible with static shapes, with one documented
exception: ``connected_components`` with the ``"pallas"`` backend (what
``"auto"`` resolves to on TPU) host-sizes the transposed-adjacency capacity
between its jitted pieces — the §2.6/§2.7 pow-2 staging idiom — so that
code path must be *called from* host level, not traced under an outer
``jax.jit`` (its ``"reference"`` backend remains a pure ``lax.while_loop``
and traces fine).  The module's primitives below are all pure jax:

* ``expand_states`` — re-encodes the n×n MinPlus 4-vector string matrix as the
  2n-vertex *state graph* (vertex ``2·read + strand``) in ELL form with scalar
  suffix values.  This is the array analogue of the host walk's
  ``(read, strand)`` dict keys.
* ``degrees`` — out-degree per row, in-degree per column (scatter-add).
* ``connected_components`` — min-label propagation with pointer-jumping
  shortcuts (Shiloach–Vishkin style hooking) over an ELL adjacency treated as
  undirected; runs as a ``lax.while_loop`` with a convergence test and
  returns the iteration count.
* ``break_cycles`` / ``chain_rank`` / ``path_components`` — pointer doubling
  over a *functional* successor/predecessor pair (each vertex has ≤1 kept
  out-edge and ≤1 kept in-edge, so components are disjoint paths and
  cycles): ``break_cycles`` cuts each cycle at its minimum-id vertex (making
  it the chain head), ``chain_rank`` resolves every vertex's chain head and
  rank (distance from the head), and ``path_components`` labels each chain
  with its minimum vertex — all in O(log n) doubling rounds regardless of
  how vertex ids are permuted along the chains.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .spmat import EllMatrix, NO_COL

_BIG = jnp.int32(2**30)


def _log2_ceil(n: int) -> int:
    return max(1, int(n - 1).bit_length())


def expand_state_rows(
    cols: jnp.ndarray, vals: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Row-local core of :func:`expand_states`: expand ``(m, K)`` string-
    matrix rows (MinPlus 4-vector values ``(m, K, 4)``) into the ``(2m, 2K)``
    state-graph rows they generate, with scalar suffix values.

    Row ``i`` of the input produces state rows ``2i`` (strand a=0) and
    ``2i+1`` (a=1); output *column* ids are global state ids ``2j+b``
    regardless of which rows are present, so the expansion can run on any
    contiguous row shard — this is what lets the shard_map contig stage
    (``core/components_dist.py``) expand its local read rows without any
    exchange.  Rows are recompacted to the sorted-ascending ELL invariant.
    Returns ``(cols, vals)`` of shape ``(2m, 2K)``.
    """
    n, k = cols.shape
    # vals (m, K, 4) -> (m, 2, K, 2): [read, a, slot, b]
    v4 = jnp.transpose(vals.reshape(n, k, 2, 2), (0, 2, 1, 3))
    j = cols[:, None, :, None]  # broadcast to [read, a, slot, b]
    tgt = 2 * j + jnp.arange(2)[None, None, None, :]
    out = jnp.where((j >= 0) & jnp.isfinite(v4), tgt, NO_COL)
    out = out.reshape(2 * n, 2 * k).astype(jnp.int32)
    sval = v4.reshape(2 * n, 2 * k)
    # recompact: sort each row by column, invalid slots (key=BIG) to the end
    key = jnp.where(out >= 0, out, _BIG)
    order = jnp.argsort(key, axis=1)
    sorted_key = jnp.take_along_axis(key, order, axis=1)
    out_cols = jnp.where(sorted_key < _BIG, sorted_key, NO_COL)
    out_vals = jnp.take_along_axis(sval, order, axis=1)
    out_vals = jnp.where(out_cols >= 0, out_vals, jnp.inf)
    return out_cols, out_vals


def expand_states(s: EllMatrix) -> EllMatrix:
    """Expand an n×n MinPlus-4-vector string matrix into its 2n×2n state
    graph: combo ``2a+b`` of edge ``i→j`` becomes the scalar-valued edge
    ``2i+a → 2j+b`` (value = suffix length, slot masked where +inf).

    The 2n-state encoding is the array analogue of the host walk's
    ``(read, strand)`` dict keys: state ``2r`` is read r forward, ``2r+1``
    read r reverse-complement, and ``state ^ 1`` is the RC twin — which is
    what makes RC-twin chain dedup a pure index transform downstream
    (``assembly/contig_gen.py``).

    Rows are recompacted to the EllMatrix sorted-ascending invariant.  The
    output capacity is 2K: each of the K source slots contributes at most two
    targets (``b ∈ {0, 1}``) per source strand ``a``.  The row-local
    expansion itself is :func:`expand_state_rows`.
    """
    n = s.cols.shape[0]
    out_cols, out_vals = expand_state_rows(s.cols, s.vals)
    return EllMatrix(cols=out_cols, vals=out_vals, n_cols=2 * n)


def degrees(adj: EllMatrix) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(out_deg, in_deg) of an ELL adjacency, both (n_rows,) int32.  Assumes
    square adjacency (n_cols == n_rows), as produced by ``expand_states``."""
    m = adj.mask
    out_deg = jnp.sum(m, axis=1).astype(jnp.int32)
    safe = jnp.where(m, adj.cols, adj.n_cols)
    in_deg = (
        jnp.zeros(adj.n_cols + 1, jnp.int32)
        .at[safe.reshape(-1)]
        .add(m.reshape(-1).astype(jnp.int32))[: adj.n_cols]
    )
    return out_deg, in_deg


def connected_components(
    adj: EllMatrix, *, max_iters: int | None = None, backend: str = "auto"
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Minimum-label connected components of an ELL adjacency, treated as
    undirected (labels hook across ``u→v`` in both directions).

    Each round does one hook (gather-min over out-neighbours + scatter-min
    over in-neighbours) followed by one pointer-jump shortcut (``l ← l[l]``);
    the loop exits when labels stop changing.  The shortcut makes typical
    (id-correlated) graphs converge in O(log n) rounds, but on adversarial
    vertex orderings — e.g. a path whose minimum sits mid-chain behind
    non-monotone labels — propagation needs Θ(n) rounds, so the default cap
    is ``n`` (correctness over speed; the convergence test exits early).
    For the disjoint-path graphs of the contig stage use
    :func:`path_components`, which is O(log n) unconditionally.

    The hook/shortcut loop is dispatched as the op ``cc_labels``
    (DESIGN.md §2.5/§2.9): ``"reference"`` runs one XLA round trip per
    round, ``"pallas"`` fuses blocks of rounds into VMEM-resident kernel
    calls (bit-identical labels; the iteration count then reports rounds
    *executed*, a multiple of the fusion factor).  Note the ``"pallas"``
    path host-sizes its transpose capacity (§2.6 staging), so call it from
    host level rather than under an outer ``jax.jit`` — see the module
    docstring.

    Returns ``(labels (n,) int32 — min vertex id per component,
    n_iterations)``.
    """
    from .backend import dispatch

    return dispatch("cc_labels", backend)(adj.cols, max_iters=max_iters)


def path_components(
    succ: jnp.ndarray, pred: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Component labels (minimum vertex id) of a disjoint union of simple
    paths given successor/predecessor pointers (−1 = none).

    Pointer doubling with running minima in both directions: after round k,
    ``mf[u]``/``mb[u]`` hold the minimum over the 2^k vertices down-/upstream
    of u, so ⌈log₂ n⌉+1 rounds cover any chain — unlike generic min-label
    propagation this is O(log n) regardless of how vertex ids are permuted
    along the path (a mid-chain minimum needs Θ(n) hook rounds to reach the
    ends).  Also correct on residual cycles: the accumulated window then
    wraps, yielding the cycle minimum.  Returns ``(labels, n_iterations)``.
    """
    n = succ.shape[0]
    max_iters = _log2_ceil(n) + 1
    ids = jnp.arange(n, dtype=jnp.int32)

    def jump(t, m):
        safe = jnp.where(t >= 0, t, 0)
        m2 = jnp.where(t >= 0, jnp.minimum(m, m[safe]), m)
        t2 = jnp.where(t >= 0, t[safe], -1)
        return t2, m2

    def cond(carry):
        tf, tb, _, _, it = carry
        return (jnp.any(tf >= 0) | jnp.any(tb >= 0)) & (it < max_iters)

    def body(carry):
        tf, tb, mf, mb, it = carry
        tf, mf = jump(tf, mf)
        tb, mb = jump(tb, mb)
        return tf, tb, mf, mb, it + 1

    _, _, mf, mb, iters = jax.lax.while_loop(
        cond, body, (succ, pred, ids, ids, jnp.int32(0))
    )
    return jnp.minimum(mf, mb), iters


def break_cycles(
    succ: jnp.ndarray, pred: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Cut every cycle of a functional graph at its minimum-id vertex.

    Input invariant: ``succ``/``pred`` are (n,) int32 *inverse partial
    functions* (−1 = none) — ``succ[u] == v ⇔ pred[v] == u`` — as produced
    by the branch cut (each vertex has ≤1 kept out-edge and ≤1 kept
    in-edge), so components are disjoint simple paths and cycles.  Pointer
    doubling with a running path-minimum classifies each vertex: after
    ⌈log₂ n⌉+1 doublings a vertex whose 2^k-step pointer never fell off the
    end lies on a cycle, and its accumulated minimum is the cycle minimum.
    The kept edge *entering* each cycle minimum is deleted, turning every
    cycle into a path whose head is the minimum — the same canonical head
    the host walk picks.

    Output invariant: ``(succ', pred')`` is again an inverse partial
    function pair and is cycle-free — the precondition of
    :func:`chain_rank` and :func:`path_components`.  Returns
    ``(succ', pred', n_cut)``.
    """
    n = succ.shape[0]
    rounds = _log2_ceil(n) + 1
    ids = jnp.arange(n, dtype=jnp.int32)

    def step(_, carry):
        t, m = carry
        safe = jnp.where(t >= 0, t, 0)
        m2 = jnp.where(t >= 0, jnp.minimum(m, m[safe]), m)
        t2 = jnp.where(t >= 0, t[safe], -1)
        return t2, m2

    t, m = jax.lax.fori_loop(0, rounds, step, (succ, ids))
    on_cycle = t >= 0
    # the cycle vertex pointing at the cycle minimum loses its out-edge
    cut = on_cycle & (succ == m)
    n_cut = jnp.sum(cut).astype(jnp.int32)
    succ2 = jnp.where(cut, -1, succ)
    pred2 = jnp.where(on_cycle & (ids == m), -1, pred)
    return succ2, pred2, n_cut


def chain_rank(pred: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Head and rank of every vertex of a disjoint union of simple paths,
    given predecessor pointers (−1 = chain head).

    Input invariant: ``pred`` must be cycle-free (run :func:`break_cycles`
    first) — on a residual cycle the parent jumps never reach a fixed point
    and the loop would only stop at the iteration cap, with ranks
    undefined.  Classic pointer doubling: ``par ← par[par]`` while
    accumulating jumped distance; converges in ⌈log₂ L⌉ rounds for the
    longest chain L (checked with a ``while_loop`` so the returned
    iteration count reflects the actual chain structure).  Returns
    ``(head, rank, n_iterations)`` with ``head[u]`` the chain head's vertex
    id and ``rank[u]`` the distance from it (head rank = 0).
    """
    n = pred.shape[0]
    max_iters = _log2_ceil(n) + 1
    par0 = jnp.where(pred >= 0, pred, jnp.arange(n, dtype=jnp.int32))
    d0 = (pred >= 0).astype(jnp.int32)

    def cond(carry):
        par, _, it = carry
        return jnp.any(par[par] != par) & (it < max_iters)

    def body(carry):
        par, d, it = carry
        return par[par], d + d[par], it + 1

    par, rank, iters = jax.lax.while_loop(cond, body, (par0, d0, jnp.int32(0)))
    return par, rank, iters
