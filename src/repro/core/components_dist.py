"""shard_map pointer-doubling for the contig stages (DESIGN.md §2.9).

The GSPMD device contig path (§2.7) leaves the partitioning of every
doubling round to the auto-sharder, which re-materializes the full pointer
arrays on every gather.  This module is the explicitly-distributed variant
following the 2022 diBELLA contig paper's neighbor-communication model: the
(2n,) state arrays are sharded ``P(row_axes)`` over the mesh's grid-row axes
(the same ``("pod", "data")`` convention as ``runtime/sharding.py`` and
SUMMA, §5), and each doubling round exchanges the pointer/minimum vectors
with an explicit ``ppermute`` ring all-gather; convergence tests and cut
counts reduce with ``psum``.

One ``shard_map`` call covers the whole doubling middle of the contig stage
— ``break_cycles`` → ``path_components`` → ``chain_rank`` — so the arrays
never leave the mesh between phases.  Per-device exchange volume is exactly
accountable: each ring all-gather moves ``n·(P−1)/P`` words, and a round
costs 2 (break_cycles), 4 (path_components) or 2 (chain_rank) gathers —
:func:`exchange_words` is the measured counterpart of the analytic model in
``benchmarks/bench_comm_model.py`` (see docs/communication.md).

All arithmetic is the same int32 doubling as ``core/components.py``, so the
results — and the ``path_components`` iteration count — are bit-identical to
the local/GSPMD path (asserted in ``tests/test_distributed.py``).
"""

from __future__ import annotations

from functools import lru_cache
from typing import Any, Dict, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..compat import shard_map
from .components import _log2_ceil

# ring all-gathers issued per doubling round, by phase (see module
# docstring).  chain_rank reuses the convergence probe's gathered parent
# vector as the next round's jump table, so it pays 2 gathers per round
# (d + updated par) plus one initial parent gather.
GATHERS_PER_ROUND = {"break_cycles": 2, "path_components": 4, "chain_rank": 2}


def infer_row_axes(mesh) -> Tuple[str, ...]:
    """Grid-row axes of ``mesh`` per the ``runtime/sharding.py`` convention:
    the ``("pod", "data")`` axes that are present, else the first axis."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return axes if axes else (mesh.axis_names[0],)


def default_row_mesh():
    """1D ``("data",)`` mesh over all visible devices — the fallback mesh for
    ``distribution="shard_map"`` when the caller did not build one."""
    devs = jax.devices()
    kwargs = {}
    try:  # jax ≥ 0.5 wants explicit axis types
        from jax.sharding import AxisType  # type: ignore[attr-defined]

        kwargs["axis_types"] = (AxisType.Auto,)
    except ImportError:  # pragma: no cover - version-dependent
        pass
    return jax.make_mesh((len(devs),), ("data",), devices=devs, **kwargs)


def _ring_all_gather(x: jnp.ndarray, axis_name: str, n_shards: int):
    """ppermute ring all-gather: (n/P,) local shard → (n,) global vector.

    ``P−1`` neighbor hops of ``n/P`` words each; device ``j`` receives shard
    ``(j−s) mod P`` on hop ``s`` and re-rolls the stack into global id
    order."""
    if n_shards == 1:
        return x
    perm = [(i, (i + 1) % n_shards) for i in range(n_shards)]
    parts = [x]
    cur = x
    for _ in range(n_shards - 1):
        cur = jax.lax.ppermute(cur, axis_name, perm)
        parts.append(cur)
    stacked = jnp.stack(parts)  # parts[s] holds shard (j − s) mod P
    j = jax.lax.axis_index(axis_name)
    idx = (j - jnp.arange(n_shards, dtype=jnp.int32)) % n_shards
    return jnp.take(stacked, idx, axis=0).reshape((-1,) + x.shape[1:])


@lru_cache(maxsize=None)
def _make_doubling(mesh, row_axes: Tuple[str, ...], n_pad: int):
    """Build (and cache per (mesh, axes, size)) the jitted shard_map callable
    running the full doubling middle on ``(n_pad,)`` succ/pred shards."""
    sizes = tuple(mesh.shape[a] for a in row_axes)
    p = 1
    for s in sizes:
        p *= s
    n_loc = n_pad // p
    max_rounds = _log2_ceil(n_pad) + 1
    spec = P(row_axes)
    rspec = P()

    def gather(x):
        for ax in reversed(row_axes):
            x = _ring_all_gather(x, ax, mesh.shape[ax])
        return x

    def psum_all(x):
        return jax.lax.psum(x, row_axes)

    def f(succ_l, pred_l):
        idx = jnp.int32(0)
        for a in row_axes:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        ids_l = idx * n_loc + jnp.arange(n_loc, dtype=jnp.int32)

        # --- break_cycles: fixed doubling rounds, cut each cycle at its
        # minimum (same element-wise math as components.break_cycles) ---
        def bc_round(_, carry):
            t_l, m_l = carry
            t_g, m_g = gather(t_l), gather(m_l)
            safe = jnp.where(t_l >= 0, t_l, 0)
            m2 = jnp.where(t_l >= 0, jnp.minimum(m_l, m_g[safe]), m_l)
            t2 = jnp.where(t_l >= 0, t_g[safe], -1)
            return t2, m2

        t, m = jax.lax.fori_loop(0, max_rounds, bc_round, (succ_l, ids_l))
        on_cycle = t >= 0
        cut = on_cycle & (succ_l == m)
        n_cut = psum_all(jnp.sum(cut).astype(jnp.int32))
        succ2 = jnp.where(cut, -1, succ_l)
        pred2 = jnp.where(on_cycle & (ids_l == m), -1, pred_l)

        # --- path_components: while-loop doubling with running minima in
        # both directions; the psum'd continue flag replicates the local
        # convergence test exactly (bit-identical iteration count) ---
        def pc_cond(c):
            return c[5] & (c[4] < max_rounds)

        def pc_body(c):
            tf, tb, mf, mb, it, _ = c
            tf_g, mf_g = gather(tf), gather(mf)
            tb_g, mb_g = gather(tb), gather(mb)
            sf = jnp.where(tf >= 0, tf, 0)
            mf2 = jnp.where(tf >= 0, jnp.minimum(mf, mf_g[sf]), mf)
            tf2 = jnp.where(tf >= 0, tf_g[sf], -1)
            sb = jnp.where(tb >= 0, tb, 0)
            mb2 = jnp.where(tb >= 0, jnp.minimum(mb, mb_g[sb]), mb)
            tb2 = jnp.where(tb >= 0, tb_g[sb], -1)
            cont = psum_all(
                (jnp.any(tf2 >= 0) | jnp.any(tb2 >= 0)).astype(jnp.int32)
            ) > 0
            return tf2, tb2, mf2, mb2, it + 1, cont

        cont0 = psum_all(
            (jnp.any(succ2 >= 0) | jnp.any(pred2 >= 0)).astype(jnp.int32)
        ) > 0
        tf, tb, mf, mb, pc_iters, _ = jax.lax.while_loop(
            pc_cond, pc_body,
            (succ2, pred2, ids_l, ids_l, jnp.int32(0), cont0),
        )
        labels = jnp.minimum(mf, mb)

        # --- chain_rank: parent-jumping with distance accumulation.  The
        # gathered parent vector is carried across rounds: the convergence
        # probe's gather doubles as the next round's jump table ---
        par0 = jnp.where(pred2 >= 0, pred2, ids_l)
        d0 = (pred2 >= 0).astype(jnp.int32)
        par0_g = gather(par0)
        cont0r = psum_all(jnp.any(par0_g[par0] != par0).astype(jnp.int32)) > 0

        def cr_cond(c):
            return c[4] & (c[3] < max_rounds)

        def cr_body(c):
            par, d, par_g, it, _ = c
            d_g = gather(d)
            par2 = par_g[par]
            d2 = d + d_g[par]
            par2_g = gather(par2)
            cont = psum_all(
                jnp.any(par2_g[par2] != par2).astype(jnp.int32)
            ) > 0
            return par2, d2, par2_g, it + 1, cont

        head, rank, _, cr_iters, _ = jax.lax.while_loop(
            cr_cond, cr_body, (par0, d0, par0_g, jnp.int32(0), cont0r)
        )

        return succ2, pred2, labels, head, rank, n_cut, pc_iters, cr_iters

    return jax.jit(
        shard_map(
            f, mesh=mesh, in_specs=(spec, spec),
            out_specs=(spec, spec, spec, spec, spec, rspec, rspec, rspec),
        )
    )


def exchange_words(n_pad: int, p: int, bc_rounds: int, pc_iters: int,
                   cr_iters: int) -> int:
    """Per-device words exchanged by one doubling middle: each ring
    all-gather ships ``n·(P−1)/P`` words, break_cycles/path_components/
    chain_rank issue 2/4/2 gathers per round (+1 for chain_rank's initial
    parent gather, which seeds both the convergence probe and round 1's
    jump table)."""
    per_gather = n_pad * (p - 1) // p
    gathers = (
        GATHERS_PER_ROUND["break_cycles"] * bc_rounds
        + GATHERS_PER_ROUND["path_components"] * pc_iters
        + GATHERS_PER_ROUND["chain_rank"] * cr_iters
        + 1
    )
    return gathers * per_gather


def doubling_shard_map(
    succ: jnp.ndarray,
    pred: jnp.ndarray,
    *,
    mesh,
    row_axes: Sequence[str] | None = None,
) -> Dict[str, Any]:
    """Distributed doubling middle of the contig stage: ``break_cycles`` →
    ``path_components`` → ``chain_rank`` under one ``shard_map``.

    Args:
      succ / pred: ``(n,)`` int32 functional successor/predecessor pointers
        (``−1`` = none), the branch-cut output of the state graph.
      mesh: the device mesh; arrays are sharded ``P(row_axes)`` over it.
      row_axes: grid-row axes (default: :func:`infer_row_axes`).

    Returns a dict with the same arrays the local doubling produces —
    ``succ``, ``pred`` (cycle-cut), ``labels``, ``head``, ``rank`` — plus
    ``n_cut``, ``cc_iterations`` (bit-identical to the local
    ``path_components`` count), ``cr_iterations``, ``bc_rounds`` and the
    per-device ``exchange_words`` of the whole middle.
    """
    if row_axes is None:
        row_axes = infer_row_axes(mesh)
    row_axes = tuple(row_axes)
    n = succ.shape[0]
    p = 1
    for a in row_axes:
        p *= mesh.shape[a]
    n_pad = -(-n // p) * p
    if n_pad != n:
        fill = jnp.full(n_pad - n, -1, jnp.int32)
        succ = jnp.concatenate([succ, fill])
        pred = jnp.concatenate([pred, fill])
    fn = _make_doubling(mesh, row_axes, n_pad)
    s2, p2, labels, head, rank, n_cut, pc_iters, cr_iters = fn(succ, pred)
    bc_rounds = _log2_ceil(n_pad) + 1
    return {
        "succ": s2[:n],
        "pred": p2[:n],
        "labels": labels[:n],
        "head": head[:n],
        "rank": rank[:n],
        "n_cut": n_cut,
        "cc_iterations": pc_iters,
        "cr_iterations": cr_iters,
        "bc_rounds": bc_rounds,
        "exchange_words": exchange_words(
            n_pad, p, bc_rounds, int(pc_iters), int(cr_iters)
        ),
    }
