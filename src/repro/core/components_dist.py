"""shard_map contig stages: branch cut, pointer doubling, chain ordering
(DESIGN.md §2.9/§2.10).

The GSPMD device contig path (§2.7) leaves the partitioning of every
doubling round to the auto-sharder, which re-materializes the full pointer
arrays on every gather.  This module is the explicitly-distributed variant
following the 2022 diBELLA contig paper's neighbor-communication model: the
(2n,) state arrays are sharded ``P(row_axes)`` over the mesh's grid-row axes
(the same ``("pod", "data")`` convention as ``runtime/sharding.py`` and
SUMMA, §5), and every exchange is explicit: ``ppermute`` ring all-gathers
for the doubling jumps, ``ppermute`` partner exchanges for the sort network,
``psum``/``pmax`` for degree tallies, convergence tests and cut counts.

Two entry points:

* :func:`doubling_shard_map` — the PR 4 surface: one ``shard_map`` covering
  the doubling middle ``break_cycles`` → ``path_components`` →
  ``chain_rank``.
* :func:`contig_stage_shard_map` — the whole Contigs chain stage under a
  *single* ``shard_map`` region: distributed **branch cut** (per-shard
  degree tallies + one ``psum`` round), the doubling middle, and a
  distributed **chain ordering** built on a ring-bitonic merge-split sort
  over ``ppermute`` (§2.10) — replacing the host-shaped global ``lexsort``
  of ``assembly/contig_gen._order_chains`` so
  ``generate_contigs(distribution="shard_map")`` never leaves the mesh
  between sub-stages.

Per-device exchange volume is exactly accountable: each ring all-gather
moves ``n·(P−1)/P`` words, each sort stage ships the local ``(key, rank,
idx)`` triple block (``3·n/P`` words), and the cut phase pays
``CUT_ALLREDUCES`` ring allreduces (reduce-scatter + all-gather ≙ 2 gathers
each).  :func:`exchange_words` / :func:`exchange_words_sort` are the
measured counterparts of the analytic models in
``benchmarks/bench_comm_model.py`` (``words_contig_doubling`` /
``words_chain_sort``; see docs/communication.md).

All arithmetic is the same int32 doubling/sort-key math as
``core/components.py`` and ``assembly/contig_gen.py``, so the results — the
``path_components`` iteration count and the final ContigSet tensors — are
bit-identical to the local/GSPMD path (asserted in
``tests/test_distributed.py``).
"""

from __future__ import annotations

from functools import lru_cache
from typing import Any, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..compat import shard_map
from ..obs.trace import span
from .components import _log2_ceil, expand_state_rows

# ring all-gathers issued per doubling round, by phase (see module
# docstring).  chain_rank reuses the convergence probe's gathered parent
# vector as the next round's jump table, so it pays 2 gathers per round
# (d + updated par) plus one initial parent gather.
GATHERS_PER_ROUND = {"break_cycles": 2, "path_components": 4, "chain_rank": 2}

# full-vector allreduces of the distributed branch cut: the in-degree tally
# (psum), the pred scatter (pmax over a −1-initialized buffer — in-deg==1
# makes it single-writer) and the in-suffix scatter (psum, single-writer).
# One ring allreduce ≙ reduce-scatter + all-gather = 2 ring gathers of
# n·(P−1)/P words each.
CUT_ALLREDUCES = 3

# words per element shipped by one merge-split hop of the chain sort: the
# (labkey, rank, idx) triple — idx doubles as the stability tie-break *and*
# the payload (it IS the sorted state permutation).
SORT_WORDS = 3

# ineligible-chain sort key of assembly/contig_gen (states whose chain head
# has no out-edges sort after every real label); padded states get +1 so
# they sort strictly last and slice off cleanly.
_SORT_BIG = jnp.int32(2**30)


def infer_row_axes(mesh) -> Tuple[str, ...]:
    """Grid-row axes of ``mesh`` per the ``runtime/sharding.py`` convention:
    the ``("pod", "data")`` axes that are present, else the first axis."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return axes if axes else (mesh.axis_names[0],)


def default_row_mesh():
    """1D ``("data",)`` mesh over all visible devices — the fallback mesh for
    ``distribution="shard_map"`` when the caller did not build one."""
    devs = jax.devices()
    kwargs = {}
    try:  # jax ≥ 0.5 wants explicit axis types
        from jax.sharding import AxisType  # type: ignore[attr-defined]

        kwargs["axis_types"] = (AxisType.Auto,)
    except ImportError:  # pragma: no cover - version-dependent
        pass
    return jax.make_mesh((len(devs),), ("data",), devices=devs, **kwargs)


def _ring_all_gather(x: jnp.ndarray, axis_name: str, n_shards: int):
    """ppermute ring all-gather: (n/P,) local shard → (n,) global vector.

    ``P−1`` neighbor hops of ``n/P`` words each; device ``j`` receives shard
    ``(j−s) mod P`` on hop ``s`` and re-rolls the stack into global id
    order."""
    if n_shards == 1:
        return x
    perm = [(i, (i + 1) % n_shards) for i in range(n_shards)]
    parts = [x]
    cur = x
    for _ in range(n_shards - 1):
        # repro: noqa[R003] — shared helper: callers count these P−1 ring
        # hops analytically (the exchange_words_* models over GATHERS_PER_*
        # constants), not via a trace-time acct dict.
        cur = jax.lax.ppermute(cur, axis_name, perm)
        parts.append(cur)
    stacked = jnp.stack(parts)  # parts[s] holds shard (j − s) mod P
    j = jax.lax.axis_index(axis_name)
    idx = (j - jnp.arange(n_shards, dtype=jnp.int32)) % n_shards
    return jnp.take(stacked, idx, axis=0).reshape((-1,) + x.shape[1:])


def _doubling_phases(succ_l, pred_l, ids_l, gather, psum_all, max_rounds):
    """Shared shard-local body of the doubling middle — ``break_cycles`` →
    ``path_components`` → ``chain_rank`` — parameterized over the exchange
    closures so :func:`doubling_shard_map` and :func:`contig_stage_shard_map`
    run the exact same int32 arithmetic (bit-identical results and iteration
    counts).  Returns ``(succ2, pred2, labels, head, rank, n_cut, pc_iters,
    cr_iters)``."""

    # --- break_cycles: fixed doubling rounds, cut each cycle at its
    # minimum (same element-wise math as components.break_cycles) ---
    def bc_round(_, carry):
        t_l, m_l = carry
        t_g, m_g = gather(t_l), gather(m_l)
        safe = jnp.where(t_l >= 0, t_l, 0)
        m2 = jnp.where(t_l >= 0, jnp.minimum(m_l, m_g[safe]), m_l)
        t2 = jnp.where(t_l >= 0, t_g[safe], -1)
        return t2, m2

    t, m = jax.lax.fori_loop(0, max_rounds, bc_round, (succ_l, ids_l))
    on_cycle = t >= 0
    cut = on_cycle & (succ_l == m)
    n_cut = psum_all(jnp.sum(cut).astype(jnp.int32))
    succ2 = jnp.where(cut, -1, succ_l)
    pred2 = jnp.where(on_cycle & (ids_l == m), -1, pred_l)

    # --- path_components: while-loop doubling with running minima in
    # both directions; the psum'd continue flag replicates the local
    # convergence test exactly (bit-identical iteration count) ---
    def pc_cond(c):
        return c[5] & (c[4] < max_rounds)

    def pc_body(c):
        tf, tb, mf, mb, it, _ = c
        tf_g, mf_g = gather(tf), gather(mf)
        tb_g, mb_g = gather(tb), gather(mb)
        sf = jnp.where(tf >= 0, tf, 0)
        mf2 = jnp.where(tf >= 0, jnp.minimum(mf, mf_g[sf]), mf)
        tf2 = jnp.where(tf >= 0, tf_g[sf], -1)
        sb = jnp.where(tb >= 0, tb, 0)
        mb2 = jnp.where(tb >= 0, jnp.minimum(mb, mb_g[sb]), mb)
        tb2 = jnp.where(tb >= 0, tb_g[sb], -1)
        cont = psum_all(
            (jnp.any(tf2 >= 0) | jnp.any(tb2 >= 0)).astype(jnp.int32)
        ) > 0
        return tf2, tb2, mf2, mb2, it + 1, cont

    cont0 = psum_all(
        (jnp.any(succ2 >= 0) | jnp.any(pred2 >= 0)).astype(jnp.int32)
    ) > 0
    tf, tb, mf, mb, pc_iters, _ = jax.lax.while_loop(
        pc_cond, pc_body,
        (succ2, pred2, ids_l, ids_l, jnp.int32(0), cont0),
    )
    labels = jnp.minimum(mf, mb)

    # --- chain_rank: parent-jumping with distance accumulation.  The
    # gathered parent vector is carried across rounds: the convergence
    # probe's gather doubles as the next round's jump table ---
    par0 = jnp.where(pred2 >= 0, pred2, ids_l)
    d0 = (pred2 >= 0).astype(jnp.int32)
    par0_g = gather(par0)
    cont0r = psum_all(jnp.any(par0_g[par0] != par0).astype(jnp.int32)) > 0

    def cr_cond(c):
        return c[4] & (c[3] < max_rounds)

    def cr_body(c):
        par, d, par_g, it, _ = c
        d_g = gather(d)
        par2 = par_g[par]
        d2 = d + d_g[par]
        par2_g = gather(par2)
        cont = psum_all(
            jnp.any(par2_g[par2] != par2).astype(jnp.int32)
        ) > 0
        return par2, d2, par2_g, it + 1, cont

    head, rank, _, cr_iters, _ = jax.lax.while_loop(
        cr_cond, cr_body, (par0, d0, par0_g, jnp.int32(0), cont0r)
    )

    return succ2, pred2, labels, head, rank, n_cut, pc_iters, cr_iters


def _mesh_closures(mesh, row_axes: Tuple[str, ...]):
    """Exchange closures over ``mesh``'s grid-row axes: nested per-axis ring
    all-gather, multi-axis ``psum``, and the row-axis count P."""
    p = 1
    for a in row_axes:
        p *= mesh.shape[a]

    def gather(x):
        for ax in reversed(row_axes):
            x = _ring_all_gather(x, ax, mesh.shape[ax])
        return x

    def psum_all(x):
        # repro: noqa[R003] — scalar tallies and convergence probes only;
        # excluded from the exchange-words model by design.
        return jax.lax.psum(x, row_axes)

    return gather, psum_all, p


@lru_cache(maxsize=None)
def _make_doubling(mesh, row_axes: Tuple[str, ...], n_pad: int):
    """Build (and cache per (mesh, axes, size)) the jitted shard_map callable
    running the full doubling middle on ``(n_pad,)`` succ/pred shards."""
    gather, psum_all, p = _mesh_closures(mesh, row_axes)
    n_loc = n_pad // p
    max_rounds = _log2_ceil(n_pad) + 1
    spec = P(row_axes)
    rspec = P()

    def f(succ_l, pred_l):
        idx = jnp.int32(0)
        for a in row_axes:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        ids_l = idx * n_loc + jnp.arange(n_loc, dtype=jnp.int32)
        return _doubling_phases(succ_l, pred_l, ids_l, gather, psum_all,
                                max_rounds)

    return jax.jit(
        shard_map(
            f, mesh=mesh, in_specs=(spec, spec),
            out_specs=(spec, spec, spec, spec, spec, rspec, rspec, rspec),
        )
    )


def exchange_words(n_pad: int, p: int, bc_rounds: int, pc_iters: int,
                   cr_iters: int) -> int:
    """Per-device words exchanged by one doubling middle: each ring
    all-gather ships ``n·(P−1)/P`` words, break_cycles/path_components/
    chain_rank issue 2/4/2 gathers per round (+1 for chain_rank's initial
    parent gather, which seeds both the convergence probe and round 1's
    jump table)."""
    per_gather = n_pad * (p - 1) // p
    gathers = (
        GATHERS_PER_ROUND["break_cycles"] * bc_rounds
        + GATHERS_PER_ROUND["path_components"] * pc_iters
        + GATHERS_PER_ROUND["chain_rank"] * cr_iters
        + 1
    )
    return gathers * per_gather


def doubling_shard_map(
    succ: jnp.ndarray,
    pred: jnp.ndarray,
    *,
    mesh,
    row_axes: Sequence[str] | None = None,
) -> Dict[str, Any]:
    """Distributed doubling middle of the contig stage: ``break_cycles`` →
    ``path_components`` → ``chain_rank`` under one ``shard_map``.

    Args:
      succ / pred: ``(n,)`` int32 functional successor/predecessor pointers
        (``−1`` = none), the branch-cut output of the state graph.
      mesh: the device mesh; arrays are sharded ``P(row_axes)`` over it.
      row_axes: grid-row axes (default: :func:`infer_row_axes`).

    Returns a dict with the same arrays the local doubling produces —
    ``succ``, ``pred`` (cycle-cut), ``labels``, ``head``, ``rank`` — plus
    ``n_cut``, ``cc_iterations`` (bit-identical to the local
    ``path_components`` count), ``cr_iterations``, ``bc_rounds`` and the
    per-device ``exchange_words`` of the whole middle.
    """
    if row_axes is None:
        row_axes = infer_row_axes(mesh)
    row_axes = tuple(row_axes)
    n = succ.shape[0]
    p = 1
    for a in row_axes:
        p *= mesh.shape[a]
    n_pad = -(-n // p) * p
    if n_pad != n:
        fill = jnp.full(n_pad - n, -1, jnp.int32)
        succ = jnp.concatenate([succ, fill])
        pred = jnp.concatenate([pred, fill])
    fn = _make_doubling(mesh, row_axes, n_pad)
    s2, p2, labels, head, rank, n_cut, pc_iters, cr_iters = fn(succ, pred)
    bc_rounds = _log2_ceil(n_pad) + 1
    return {
        "succ": s2[:n],
        "pred": p2[:n],
        "labels": labels[:n],
        "head": head[:n],
        "rank": rank[:n],
        "n_cut": n_cut,
        "cc_iterations": pc_iters,
        "cr_iterations": cr_iters,
        "bc_rounds": bc_rounds,
        "exchange_words": exchange_words(
            n_pad, p, bc_rounds, int(pc_iters), int(cr_iters)
        ),
    }


# ---------------------------------------------------------------------------
# Ring-bitonic chain ordering + end-to-end contig stage (DESIGN.md §2.10).
# ---------------------------------------------------------------------------


def n_sort_stages(p: int) -> int:
    """Comparator stages of the cross-shard sort network over ``p`` shards:
    the bitonic network's ``log₂P·(log₂P+1)/2`` when ``p`` is a power of
    two, else the odd-even transposition fallback's ``p`` stages (see
    :func:`sort_network`).  ``p ≤ 1`` needs no network."""
    if p <= 1:
        return 0
    if p & (p - 1) == 0:
        lg = p.bit_length() - 1
        return lg * (lg + 1) // 2
    return p


def sort_network(p: int) -> List[List[Tuple[int, int]]]:
    """Comparator schedule sorting ``p`` shard-resident blocks ascending by
    linear shard rank.

    Returns a list of stages; each stage is a list of ``(lo, hi)`` shard
    pairs meaning: the pair exchanges blocks, merges, and ``lo`` keeps the
    lower half, ``hi`` the upper (a *merge-split*).  By the sorted-block
    adaptation theorem (Knuth TAOCP 5.3.4, Baudet–Stevenson), replacing
    every compare-exchange of a valid sorting network with a merge-split on
    locally-sorted blocks yields globally sorted blocks — so the schedule is
    exactly a sorting network on ``p`` wires:

    * ``p`` a power of two → Batcher's bitonic network,
      ``log₂P·(log₂P+1)/2`` stages.  Every stage pairs ``i`` with ``i ^ j``
      (single differing rank bit), so each stage is one ``ppermute`` whose
      partner permutation is a fixed-point-free involution — the reason
      bitonic is preferred over the ring-structured odd-even transposition
      network, which needs ``P`` stages (see DESIGN.md §2.10).
    * otherwise → odd-even transposition (``p`` stages, adjacent pairs;
      one shard idles per stage when ``p`` is odd).
    """
    if p <= 1:
        return []
    stages: List[List[Tuple[int, int]]] = []
    if p & (p - 1) == 0:
        k = 2
        while k <= p:
            j = k // 2
            while j >= 1:
                st = []
                for i in range(p):
                    partner = i ^ j
                    if partner > i:
                        # ascending block (min toward low rank) when the k-bit
                        # of i is 0, descending otherwise — Batcher's rule
                        st.append((i, partner) if (i & k) == 0
                                  else (partner, i))
                stages.append(st)
                j //= 2
            k *= 2
    else:
        for r in range(p):
            stages.append([(i, i + 1) for i in range(r % 2, p - 1, 2)])
    return stages


def exchange_words_sort(n_pad: int, p: int) -> int:
    """Per-device words exchanged by the distributed chain ordering: one
    eligibility ring all-gather of out-degrees (``n·(P−1)/P`` words) plus
    ``n_sort_stages(P)`` merge-split hops of the local ``(labkey, rank,
    idx)`` triple block (``SORT_WORDS·n/P`` words each).  Scalar boundary
    shifts and the P-word chain-prefix exchange are ignored, as the psum
    convergence flags are elsewhere.  Data-independent — the network shape
    is fixed by P — so the analytic twin
    (``bench_comm_model.words_chain_sort``) must match it exactly."""
    if p <= 1:
        return 0
    return n_pad * (p - 1) // p + SORT_WORDS * (n_pad // p) * n_sort_stages(p)


def exchange_words_cut(n_pad: int, p: int) -> int:
    """Per-device words of the distributed branch cut: ``CUT_ALLREDUCES``
    full-vector ring allreduces (reduce-scatter + all-gather, 2 ring gathers
    of ``n·(P−1)/P`` words each) in its single ``psum`` round."""
    if p <= 1:
        return 0
    return CUT_ALLREDUCES * 2 * (n_pad * (p - 1) // p)


@lru_cache(maxsize=None)
def _make_contig_stage(mesh, row_axes: Tuple[str, ...], n_read_pad: int,
                       n_reads: int):
    """Build (and cache per (mesh, axes, sizes)) the jitted shard_map
    callable running branch cut → doubling → chain ordering on
    ``(n_read_pad, K)`` string-matrix row shards.  ``n_read_pad`` is a
    multiple of P so every shard holds an even number of states (read pairs
    never split across shards); states ≥ ``2·n_reads`` are padding."""
    gather, psum_all, p = _mesh_closures(mesh, row_axes)
    n_states = 2 * n_read_pad
    n_loc = n_states // p  # even by construction
    max_rounds = _log2_ceil(n_states) + 1
    stages = sort_network(p)
    spec = P(row_axes)
    rspec = P()
    axes = tuple(row_axes)

    def f(cols_l, vals_l):
        idx = jnp.int32(0)
        for a in row_axes:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        ids_l = idx * n_loc + jnp.arange(n_loc, dtype=jnp.int32)

        # --- branch cut: expand local read rows to state rows (row-local,
        # no exchange), tally degrees per shard, one psum round ---
        with span("Contigs", kind="phase", phase="cut"):
            g_cols, g_vals = expand_state_rows(cols_l, vals_l)
            mask = g_cols >= 0
            out_deg_l = jnp.sum(mask, axis=1).astype(jnp.int32)
            tally_to = jnp.where(mask, g_cols, n_states).reshape(-1)
            tally = (
                jnp.zeros(n_states + 1, jnp.int32)
                .at[tally_to]
                .add(1)[:n_states]
            )
            in_deg = psum_all(tally)  # global in-degree, replicated

            tgt = jnp.max(jnp.where(mask, g_cols, -1), axis=1)
            suf = jnp.sum(jnp.where(mask, g_vals, 0.0), axis=1)
            tgt_safe = jnp.where(tgt >= 0, tgt, 0)
            kept = (out_deg_l == 1) & (tgt >= 0) & (in_deg[tgt_safe] == 1)
            succ_l = jnp.where(kept, tgt, -1)
            n_branch_cut = psum_all(
                jnp.sum(out_deg_l) - jnp.sum(kept).astype(jnp.int32)
            )

            # pred / in-suffix: in-deg(target)==1 makes both scatters single-
            # writer, so a −1-init pmax (resp. 0-init psum) equals the local
            # `.at[].set()` exactly; each shard then slices its own chunk back
            scat = jnp.where(kept, succ_l, n_states)
            pred_buf = (
                jnp.full(n_states + 1, -1, jnp.int32)
                .at[scat]
                .max(ids_l)[:n_states]
            )
            pred_l = jax.lax.dynamic_slice(
                # repro: noqa[R003] — contig cut/sort collectives: the
                # schedule is data-independent and counted analytically by
                # exchange_words_cut/_sort in contig_stage_shard_map.
                jax.lax.pmax(pred_buf, axes), (idx * n_loc,), (n_loc,)
            )
            insuf_buf = (
                jnp.zeros(n_states + 1, jnp.float32)
                .at[scat]
                .add(suf)[:n_states]
            )
            insuf_l = jax.lax.dynamic_slice(
                psum_all(insuf_buf), (idx * n_loc,), (n_loc,)
            )
            in_deg_l = jax.lax.dynamic_slice(in_deg, (idx * n_loc,), (n_loc,))
            has_edge_l = (out_deg_l + in_deg_l).reshape(-1, 2).sum(axis=1) > 0

        # --- doubling middle (shared body, §2.9) ---
        with span("Contigs", kind="phase", phase="doubling"):
            succ2, pred2, labels, head, rank, n_cut, pc_iters, cr_iters = (
                _doubling_phases(succ_l, pred_l, ids_l, gather, psum_all,
                                 max_rounds)
            )

        # --- chain ordering: ring-bitonic merge-split sort (§2.10) over
        # the (labkey, rank, idx) triples; idx makes keys globally unique,
        # so the unique sorted order equals the local path's stable
        # lexsort((rank, labkey)) bit for bit ---
        with span("Contigs", kind="phase", phase="sort",
                  sort_stages=len(stages)):
            out_deg_g = gather(out_deg_l)  # eligibility: out_deg[head]
            elig_l = out_deg_g[head] > 0
            labkey = jnp.where(elig_l, labels, _SORT_BIG)
            labkey = jnp.where(ids_l >= 2 * n_reads, _SORT_BIG + 1, labkey)

            order = jnp.lexsort((ids_l, rank, labkey))
            k1, k2, k3 = labkey[order], rank[order], ids_l[order]
            for pairs in stages:
                perm = [pq for ab in pairs for pq in (ab, ab[::-1])]
                role_tab = np.zeros(p, np.int32)
                for lo, hi in pairs:
                    role_tab[lo], role_tab[hi] = 1, -1
                role = jnp.asarray(role_tab)[idx]
                r1 = jax.lax.ppermute(k1, axes, perm)
                r2 = jax.lax.ppermute(k2, axes, perm)
                r3 = jax.lax.ppermute(k3, axes, perm)
                c1 = jnp.concatenate([k1, r1])
                c2 = jnp.concatenate([k2, r2])
                c3 = jnp.concatenate([k3, r3])
                o = jnp.lexsort((c3, c2, c1))
                sel = jnp.where(role >= 0, o[:n_loc], o[n_loc:])
                # an idle shard (odd-P transposition stages) keeps its block
                k1 = jnp.where(role == 0, k1, c1[sel])
                k2 = jnp.where(role == 0, k2, c2[sel])
                k3 = jnp.where(role == 0, k3, c3[sel])

        # chain boundaries: previous element's labkey, shipped across the
        # shard seam by a single-hop ring shift (1 word)
        prev_last = jax.lax.ppermute(
            k1[-1:], axes, [(i, (i + 1) % p) for i in range(p)]
        ) if p > 1 else k1[-1:]
        prev = jnp.concatenate([prev_last, k1[:-1]])
        pos0 = (jnp.arange(n_loc) == 0) & (idx == 0)
        prev = jnp.where(pos0, -1, prev)
        elig_s = k1 < _SORT_BIG
        new_chain = elig_s & (k1 != prev)

        # global chain index: local cumsum + exclusive shard prefix (one
        # psum of a P-word one-hot vector)
        loc_chains = jnp.sum(new_chain).astype(jnp.int32)
        sums = psum_all(jnp.zeros(p, jnp.int32).at[idx].set(loc_chains))
        prefix = jnp.sum(jnp.where(jnp.arange(p) < idx, sums, 0))
        chain_idx = prefix + jnp.cumsum(new_chain.astype(jnp.int32)) - 1
        n_chains = jnp.sum(sums)
        max_chain = jax.lax.pmax(
            jnp.max(jnp.where(elig_s, k2, -1)), axes
        ) + 1

        return (k3, elig_s, k2, chain_idx, new_chain, insuf_l, has_edge_l,
                n_chains, max_chain, n_branch_cut, n_cut, pc_iters, cr_iters)

    return jax.jit(
        shard_map(
            f, mesh=mesh, in_specs=(spec, spec),
            out_specs=(spec,) * 7 + (rspec,) * 6,
        )
    )


def contig_stage_shard_map(
    s, *, mesh, row_axes: Sequence[str] | None = None
) -> Tuple[Dict[str, Any], Dict[str, int]]:
    """End-to-end distributed chain stage of contig generation: branch cut →
    doubling middle → ring-bitonic chain ordering under a *single*
    ``shard_map`` region (DESIGN.md §2.10) — no GSPMD sub-stage remains.

    Args:
      s: the string matrix S (``EllMatrix``, MinPlus 4-vector values); its
        read rows are padded to a multiple of P and sharded ``P(row_axes)``.
      mesh / row_axes: the device mesh and its grid-row axes (default:
        :func:`infer_row_axes`).

    Returns ``(st, stats)``: ``st`` is the chain-state pytree with exactly
    the keys ``assembly/contig_gen._order_chains`` produces (bit-identical
    values — asserted in ``tests/test_distributed.py``), ``stats`` the
    per-device exchange accounting split by phase (``exchange_words_cut`` /
    ``_doubling`` / ``_sort``, plus the totals and per-phase round counts;
    see docs/communication.md).
    """
    if row_axes is None:
        row_axes = infer_row_axes(mesh)
    row_axes = tuple(row_axes)
    p = 1
    for a in row_axes:
        p *= mesh.shape[a]
    n = s.cols.shape[0]
    k = s.cols.shape[1]
    n_read_pad = -(-n // p) * p
    cols, vals = s.cols, s.vals
    if n_read_pad != n:
        pad = n_read_pad - n
        cols = jnp.concatenate(
            [cols, jnp.full((pad, k), -1, jnp.int32)]
        )
        vals = jnp.concatenate(
            [vals, jnp.full((pad,) + vals.shape[1:], jnp.inf, vals.dtype)]
        )
    fn = _make_contig_stage(mesh, row_axes, n_read_pad, n)
    with span("Contigs", kind="phase", phase="chain_stage", p=p) as sp:
        (state_s, elig_s, rank_s, chain_idx_s, new_chain, insuf, has_edge,
         n_chains, max_chain, n_branch_cut, n_cut, pc_iters, cr_iters) = (
            sp.set_output(fn(cols, vals))
        )
    n2 = 2 * n
    n_pad = 2 * n_read_pad
    st = {
        "state_s": state_s[:n2],
        "elig_s": elig_s[:n2],
        "rank_s": rank_s[:n2],
        "chain_idx_s": chain_idx_s[:n2],
        "new_chain": new_chain[:n2],
        "insuf": insuf[:n2],
        "has_edge": has_edge[:n],
        "n_chains": n_chains,
        "max_chain": max_chain,
        "n_branch_cut": n_branch_cut,
        "cc_iterations": pc_iters,
    }
    bc_rounds = _log2_ceil(n_pad) + 1
    w_cut = exchange_words_cut(n_pad, p)
    w_dbl = exchange_words(n_pad, p, bc_rounds, int(pc_iters), int(cr_iters))
    w_sort = exchange_words_sort(n_pad, p)
    r_dbl = bc_rounds + int(pc_iters) + int(cr_iters)
    r_sort = n_sort_stages(p) + 1  # merge-split stages + eligibility gather
    stats = {
        "exchange_words": w_cut + w_dbl + w_sort,
        "exchange_rounds": 1 + r_dbl + r_sort,
        "exchange_words_cut": w_cut,
        "exchange_words_doubling": w_dbl,
        "exchange_words_sort": w_sort,
        "exchange_rounds_doubling": r_dbl,
        "exchange_rounds_sort": r_sort,
    }
    return st, stats
