"""Bidirected string-graph construction from alignment results (paper §II, §IV-E).

Orientation encoding
--------------------
Each overlap is stored as directed entries ``i → j`` tagged with strand bits
``(s_i, s_j)``: "read i used in orientation s_i has a suffix that overlaps a
prefix of read j used in orientation s_j".  This is algebraically equivalent to
the paper's bidirected arrow-head formulation (Fig. 1):

    paper case 1  (suf(v1)  ~ pre(v2)):   i→j (0,0)
    paper case 2  (suf(v1)  ~ pre(v2')):  i→j (0,1)
    paper case 3  (suf(v1') ~ pre(v2)):   i→j (1,0)
    paper case 4  (suf(v1') ~ pre(v2')) ≡ j→i (0,0)

Every proper dovetail overlap emits exactly two directed entries — ``i→j``
with strands (a,b) and overhang |unmatched suffix of j|, and the complement
``j→i`` with strands (1−b, 1−a) and overhang |unmatched prefix of i| — so the
matrix R is structurally symmetric and a walk can be traversed on either
strand (paper: "we want to walk both v1→v2→v3 and v3'→v2'→v1'").

The per-entry value is the MinPlus 4-vector of ``semiring.minplus_orient_semiring``
(suffix length at combo 2·s_i + s_j, +inf elsewhere).

Overlap classification from alignment coordinates (BELLA/miniasm convention):
with i kept forward and j in its aligned orientation ``s``, alignment spans
[bi, ei) on i (length li) and [bj, ej) on j (length lj):

    contained   : the overlap covers one read end to end → discarded here
                  ("contained overlaps are discarded during transitive
                  reduction regardless of their alignment scores", §IV-D)
    dovetail i→j: ei ≈ li and bj ≈ 0  (suffix of i meets prefix of oriented j)
    dovetail j→i: bi ≈ 0 and ej ≈ lj
    internal    : neither — a repeat-induced partial match; dropped.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .semiring import INF, minplus_orient_semiring
from .spmat import EllMatrix, from_coo


class OverlapClass(NamedTuple):
    """Per-pair classification flags + directed-edge payloads.

    For a pair classified ``fwd_ij`` (suffix of i meets prefix of oriented j)
    the two directed entries are (i→j, strands_ij, suf_ij=right_j) and its
    complement (j→i, comp(strands_ij), suf_ij_comp=left_i).  For ``fwd_ji``
    they are (j→i, strands_ji, suf_ji=right_i) and (i→j, comp(strands_ji),
    suf_ji_comp=left_j)."""

    contained_i: jnp.ndarray  # i is contained in j
    contained_j: jnp.ndarray
    fwd_ij: jnp.ndarray  # dovetail edge i→j exists
    fwd_ji: jnp.ndarray  # dovetail edge j→i exists
    suf_ij: jnp.ndarray  # overhang of oriented j beyond the overlap
    suf_ij_comp: jnp.ndarray  # overhang of i on the reverse walk (= bi)
    suf_ji: jnp.ndarray  # overhang of i beyond the overlap (= li - ei)
    suf_ji_comp: jnp.ndarray  # overhang of oriented j on reverse walk (= bj)
    strands_ij: jnp.ndarray  # (E, 2) int32: (s_i, s_j) for edge i→j
    strands_ji: jnp.ndarray


def classify_overlaps(
    bi, ei, li, bj, ej, lj, strand_j, *, end_fuzz: int = 25
) -> OverlapClass:
    """Vectorized overlap classification. All args (E,) int32 arrays; coords of
    j are in its *oriented* frame (already flipped if strand_j == 1)."""
    bi, ei, li = (jnp.asarray(x, jnp.int32) for x in (bi, ei, li))
    bj, ej, lj = (jnp.asarray(x, jnp.int32) for x in (bj, ej, lj))
    s = jnp.asarray(strand_j, jnp.int32)

    left_i = bi
    right_i = li - ei
    left_j = bj
    right_j = lj - ej

    cont_i = (left_i <= end_fuzz) & (right_i <= end_fuzz)
    cont_j = (left_j <= end_fuzz) & (right_j <= end_fuzz)
    # if both contained (equal-span reads) treat the shorter as contained in
    # the longer; ties → i contained.
    both = cont_i & cont_j
    cont_i = cont_i & (~both | (li <= lj))
    cont_j = cont_j & (~both | (lj < li))

    proper_ij = (right_i <= end_fuzz) & (left_j <= end_fuzz)
    proper_ji = (left_i <= end_fuzz) & (right_j <= end_fuzz)
    anycont = cont_i | cont_j
    fwd_ij = proper_ij & ~anycont
    fwd_ji = proper_ji & ~anycont

    # edge i→j: i forward (0), j in strand s
    strands_ij = jnp.stack([jnp.zeros_like(s), s], axis=-1)
    # edge j→i: oriented-j suffix ~ i prefix → j used in strand s, i forward
    strands_ji = jnp.stack([s, jnp.zeros_like(s)], axis=-1)
    return OverlapClass(
        contained_i=cont_i,
        contained_j=cont_j,
        fwd_ij=fwd_ij,
        fwd_ji=fwd_ji,
        suf_ij=right_j,
        suf_ij_comp=left_i,
        suf_ji=right_i,
        suf_ji_comp=left_j,
        strands_ij=strands_ij,
        strands_ji=strands_ji,
    )


def _mp_entry(suffix, strands):
    """(E,) suffix + (E,2) strands -> (E,4) MinPlus value."""
    combo = 2 * strands[:, 0] + strands[:, 1]
    return jnp.where(
        jnp.arange(4)[None, :] == combo[:, None],
        jnp.asarray(suffix, jnp.float32)[:, None],
        INF,
    )


@partial(jax.jit, static_argnames=("n_reads", "capacity"))
def build_overlap_graph(
    read_i: jnp.ndarray,
    read_j: jnp.ndarray,
    cls: OverlapClass,
    valid: jnp.ndarray,
    *,
    n_reads: int,
    capacity: int,
):
    """Assemble the overlap matrix R (reads × reads, MinPlus 4-vector values)
    from classified pairs.  Each proper dovetail contributes:

        R[i, j] ⊕= value(suffix_ij at strands_ij)           (edge i→j)
        R[j, i] ⊕= value(suffix_ji at (1−s_j, 1−s_i))       (complement)

    plus the same two entries for pairs classified in the j→i direction.
    Returns (R: EllMatrix, contained: (n,) bool, overflow)."""
    sr = minplus_orient_semiring

    e_ij = _mp_entry(cls.suf_ij, cls.strands_ij)
    comp_ij = jnp.stack([1 - cls.strands_ij[:, 1], 1 - cls.strands_ij[:, 0]], -1)
    e_ij_c = _mp_entry(cls.suf_ij_comp, comp_ij)

    e_ji = _mp_entry(cls.suf_ji, cls.strands_ji)
    comp_ji = jnp.stack([1 - cls.strands_ji[:, 1], 1 - cls.strands_ji[:, 0]], -1)
    e_ji_c = _mp_entry(cls.suf_ji_comp, comp_ji)

    rows = jnp.concatenate([read_i, read_j, read_j, read_i])
    cols = jnp.concatenate([read_j, read_i, read_i, read_j])
    vals = jnp.concatenate([e_ij, e_ij_c, e_ji, e_ji_c])
    ok = jnp.concatenate(
        [
            valid & cls.fwd_ij,
            valid & cls.fwd_ij,
            valid & cls.fwd_ji,
            valid & cls.fwd_ji,
        ]
    )

    mat, overflow = from_coo(
        rows,
        cols,
        vals,
        ok,
        n_rows=n_reads,
        n_cols=n_reads,
        capacity=capacity,
        semiring=sr,
    )
    contained = jnp.zeros((n_reads,), bool)
    safe_i = jnp.where(valid, read_i, 0)
    safe_j = jnp.where(valid, read_j, 0)
    contained = contained.at[safe_i].max(valid & cls.contained_i)
    contained = contained.at[safe_j].max(valid & cls.contained_j)
    return mat, contained, overflow


def drop_contained(mat: EllMatrix, contained: jnp.ndarray) -> EllMatrix:
    """Remove all edges incident to contained reads (paper §IV-D)."""
    from .spmat import prune

    n, k = mat.cols.shape
    safe = jnp.where(mat.mask, mat.cols, 0)
    drop = contained[:, None] | (contained[safe] & mat.mask)
    return prune(mat, drop & mat.mask, minplus_orient_semiring)


def edge_list(mat: EllMatrix):
    """Host-side edge list [(i, j, combo, suffix)] for tests/inspection."""
    import numpy as np

    cols = np.asarray(mat.cols)
    vals = np.asarray(mat.vals)
    out = []
    for i in range(cols.shape[0]):
        for q in range(cols.shape[1]):
            j = cols[i, q]
            if j < 0:
                continue
            for c in range(4):
                v = vals[i, q, c]
                if np.isfinite(v):
                    out.append((i, int(j), c, float(v)))
    return out
