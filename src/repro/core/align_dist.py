"""Distributed x-drop extension along the candidate-pair axis (DESIGN.md
§2.12).

The alignment stage's compacted candidate bucket (``assembly/pipeline.py``)
is embarrassingly parallel per pair, so the distribution is a plain block
split of the bucket over the mesh's grid-row axes — the same
``("pod", "data")`` convention as ``components_dist`` — inside ONE shard_map
region with every exchanged word counted:

1. **gather_reads** — each device holds an ``n/P`` row shard of the read
   code matrix; a counting ppermute ring all-gather (``P−1`` hops per axis,
   nested axes telescope to ``(n/P)·(P−1)·L`` words per device) replicates
   the full matrix so any candidate pair can be gathered locally.
2. **extend** — the local ``bucket/P`` candidate slice gathers its read
   rows, orients strand-1 partners with ``revcomp``, and runs
   ``assembly.alignment.batch_extend`` — the existing ``kernels/xdrop`` op
   through the normal backend dispatch, so the op/kernel spans and the
   reference↔pallas parity contract are untouched.
3. **scatter_scores** — the five ``PairAlignment`` int32 outputs stack into
   one ``(5, bucket)`` buffer; each device writes only its own block
   (single-writer) and one ``psum`` allreduce replicates the result
   (ring allreduce ≙ reduce-scatter + all-gather =
   ``2·(5·bucket/P)·(P−1)`` words per device).

Accounting follows ``core/summa.py``: the cached program builder returns
``(fm, acct)``; the traced body resets ``acct`` and increments it next to
each exchange, so the measured ``exchange_words_align`` is exact and
data-independent — cross-checked against ``bench_comm_model.words_align``
by ``scripts/check_smoke_comm.py``.

Per-pair independence makes the split bit-safe: every bucket entry sees
exactly the inputs the local/GSPMD path feeds it, so scores, accepted-pair
sets and overflow counts are bit-identical (asserted in
``tests/test_align_dist.py`` on 2×2 and multipod meshes).
"""

from __future__ import annotations

from functools import lru_cache
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..compat import shard_map
from ..obs import validated
from ..obs.trace import span
from .backend import resolve_backend
from .components_dist import default_row_mesh, infer_row_axes

#: arrays of a PairAlignment result (score, bi, ei, bj, ej) — the scatter
#: ships all five stacked as one (5, bucket) int32 buffer.
ALIGN_OUTPUTS = 5

#: cand dict keys, in the positional order the shard_map program takes them.
_CAND_KEYS = ("i", "j", "li", "lj", "pa", "pb", "strand")


def _pad_multiple(x: int, p: int) -> int:
    """Smallest multiple of ``p`` that is ≥ ``x``."""
    return -(-x // p) * p


@lru_cache(maxsize=None)
def _align_program(
    mesh, row_axes: Tuple[str, ...], n_pad: int, row_width: int,
    bucket_pad: int, backend: str, k: int, xdrop: int, match: int,
    mismatch: int, gap: int, band: int, max_steps: int,
):
    """Build (and cache) the jitted shard_map alignment program for one
    (mesh, axes, shape, backend, scoring) key.

    Returns ``(fm, acct)`` where ``acct`` is the trace-time exchange
    accounting dict (``core/summa.py`` convention): the traced body resets
    it at the start of every trace and increments it next to each exchange,
    so cached calls reuse the counted schedule and re-traces recount
    idempotently."""
    from ..assembly import alignment as al  # lazy: core must not import
    from ..assembly.kmers import revcomp  # assembly at module load

    p = 1
    for a in row_axes:
        p *= mesh.shape[a]
    blk = bucket_pad // p
    acct = {"words": 0, "rounds": 0}
    # score-scatter allreduce words per device: one psum of the replicated
    # (5, bucket_pad) buffer ≙ reduce-scatter + all-gather
    w_scatter = 2 * (ALIGN_OUTPUTS * bucket_pad // p) * (p - 1)

    def _counted_gather(x):
        """Ring all-gather of the row shard over every row axis (innermost
        first, mirroring ``components_dist._mesh_closures``), with the
        per-device words of each ppermute hop counted as it is traced."""
        for ax in reversed(row_axes):
            s_ax = mesh.shape[ax]
            if s_ax == 1:
                continue
            perm = [(t, (t + 1) % s_ax) for t in range(s_ax)]
            hop_words = int(np.prod(x.shape))
            parts = [x]
            cur = x
            for _ in range(s_ax - 1):
                acct["words"] += hop_words
                acct["rounds"] += 1
                cur = jax.lax.ppermute(cur, ax, perm)
                parts.append(cur)
            stacked = jnp.stack(parts)  # parts[s] holds shard (t − s) mod P
            t = jax.lax.axis_index(ax)
            order = (t - jnp.arange(s_ax, dtype=jnp.int32)) % s_ax
            x = jnp.take(stacked, order, axis=0).reshape(
                (-1,) + x.shape[1:]
            )
        return x

    def f(codes_l, i_l, j_l, li_l, lj_l, pa_l, pb_l, strand_l):
        acct["words"] = 0  # fresh trace: recount the schedule
        acct["rounds"] = 0
        idx = jnp.int32(0)
        for a in row_axes:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)

        with span("Alignment", kind="phase", phase="gather_reads"):
            codes_full = _counted_gather(codes_l)

        with span("Alignment", kind="phase", phase="extend"):
            ai = codes_full[i_l]
            bj = codes_full[j_l]
            bj = jnp.where((strand_l == 1)[:, None], revcomp(bj, lj_l), bj)
            out = al.batch_extend(
                ai, li_l, bj, lj_l, pa_l, pb_l, k=k, backend=backend,
                xdrop=xdrop, match=match, mismatch=mismatch, gap=gap,
                band=band, max_steps=max_steps,
            )

        with span("Alignment", kind="phase", phase="scatter_scores"):
            stacked = jnp.stack(tuple(out)).astype(jnp.int32)  # (5, blk)
            buf = jnp.zeros((ALIGN_OUTPUTS, bucket_pad), jnp.int32)
            buf = jax.lax.dynamic_update_slice(
                buf, stacked, (jnp.int32(0), idx * blk)
            )
            if p > 1:
                acct["words"] += w_scatter
                acct["rounds"] += 1
            full = jax.lax.psum(buf, row_axes)
        return full

    cspec = P(row_axes)
    fm = jax.jit(
        shard_map(
            f, mesh=mesh,
            in_specs=(cspec,) * (1 + len(_CAND_KEYS)),
            out_specs=P(),
        )
    )
    return fm, acct


def align_bucket_shard_map(
    codes,
    cand: Dict[str, Any],
    *,
    k: int,
    mesh=None,
    row_axes: Optional[Tuple[str, ...]] = None,
    backend: str = "reference",
    xdrop: int = 15,
    match: int = 1,
    mismatch: int = -1,
    gap: int = -1,
    band: int = 33,
    max_steps: int = 512,
):
    """Run the compacted candidate bucket through the distributed x-drop
    extension (module docstring) and return ``(PairAlignment, stats)``.

    ``codes`` is the full (n, L) uint8 read matrix; ``cand`` is the
    pipeline's compaction dict (keys ``i, j, li, lj, pa, pb, strand``, all
    (bucket,) int32).  Reads are padded to a multiple of the row-device
    count P with zero rows and the bucket to a multiple of P with zero
    pairs; pad pairs compute the same deterministic garbage on every path
    and are sliced off, so the first ``bucket`` entries are bit-identical
    to the local path.  ``stats`` carries the measured
    ``exchange_words_align`` / ``exchange_rounds_align`` (the
    "align_exchange" schema group), exact against
    ``bench_comm_model.words_align``."""
    if mesh is None:
        mesh = default_row_mesh()
    row_axes = tuple(row_axes) if row_axes is not None else infer_row_axes(mesh)
    p = 1
    for a in row_axes:
        p *= mesh.shape[a]

    codes = jnp.asarray(codes, jnp.uint8)
    n, row_width = codes.shape
    bucket = int(cand["i"].shape[0])
    n_pad = _pad_multiple(n, p)
    bucket_pad = _pad_multiple(bucket, p)
    if n_pad != n:
        codes = jnp.concatenate(
            [codes, jnp.zeros((n_pad - n, row_width), codes.dtype)]
        )

    def _pad1(x):
        x = jnp.asarray(x, jnp.int32)
        if bucket_pad == bucket:
            return x
        return jnp.concatenate(
            [x, jnp.zeros((bucket_pad - bucket,), jnp.int32)]
        )

    fm, acct = _align_program(
        mesh, row_axes, n_pad, row_width, bucket_pad,
        resolve_backend(backend), k, xdrop, match, mismatch, gap, band,
        max_steps,
    )
    with span("Alignment", kind="phase", phase="pair_exchange", p=p,
              bucket=bucket_pad) as sp:
        full = sp.set_output(
            fm(codes, *(_pad1(cand[key]) for key in _CAND_KEYS))
        )

    from ..assembly.alignment import PairAlignment

    res = PairAlignment(*(full[t, :bucket] for t in range(ALIGN_OUTPUTS)))
    stats = validated({
        "exchange_words_align": acct["words"],
        "exchange_rounds_align": acct["rounds"],
    }, context="align_bucket_shard_map", require_groups=("align_exchange",))
    return res, stats
