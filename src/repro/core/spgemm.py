"""Local semiring SpGEMM over static-capacity ELL matrices.

TPU adaptation of CombBLAS's hash/heap local multiply (paper §IV-D): the
row-expansion ``gather → sort-by-column → segmented-⊕ → compact`` pipeline is
branch-free and fully static-shaped.  For each row i of A we gather the B-rows
indexed by A's column slots, apply the semiring ⊗ to the (K_A × K_B) candidate
grid, then merge candidates sharing an output column with ⊕.

Also provides:
  * ``spgemm_masked`` — the *sampled* semiring product ``(A ⊗ B) ∘ pattern(M)``
    (an SDDMM analogue).  This is the beyond-paper optimization used by the
    fused transitive-reduction step: Algorithm 2 only ever reads N = R² at
    R's own nonzero positions, so we never materialize N's (much denser)
    pattern and skip the candidate sort entirely.
  * ``transpose`` — explicit ELL transpose (paper line 5, Aᵀ).
"""

from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from .semiring import Semiring, tree_where, INF
from .spmat import EllMatrix, NO_COL, from_coo, map_row_blocks, merge_sorted_rows


@partial(jax.jit, static_argnames=("semiring", "capacity", "row_chunk"))
def spgemm(
    a: EllMatrix, b: EllMatrix, *, semiring: Semiring, capacity: int,
    row_chunk: int | None = None,
):
    """C = A ⊗ B over ``semiring``; returns (EllMatrix C, overflow count).

    a: (n × m) with row capacity K_A;  b: (m × p) with row capacity K_B.
    Work/row = K_A·K_B candidates (static).  ``row_chunk`` bounds the
    candidate expand/sort buffer by mapping over row blocks — required at
    production scale where n·K_A·K_B would not fit HBM."""
    if row_chunk is not None and a.cols.shape[0] > row_chunk:
        return _spgemm_chunked(
            a, b, semiring=semiring, capacity=capacity, row_chunk=row_chunk
        )
    n, ka = a.cols.shape
    kb = b.cols.shape[1]
    a_valid = a.mask
    safe = jnp.where(a_valid, a.cols, 0)

    b_cols_g = b.cols[safe]  # (n, KA, KB)
    b_vals_g = jax.tree.map(lambda v: v[safe], b.vals)

    a_vals_e = jax.tree.map(lambda v: v[:, :, None, ...], a.vals)
    cand_vals = semiring.mul(a_vals_e, b_vals_g)
    cand_valid = (
        a_valid[:, :, None]
        & (b_cols_g >= 0)
        & ~semiring.is_zero(cand_vals)
    )
    cand_cols = jnp.where(cand_valid, b_cols_g, NO_COL).reshape(n, ka * kb)
    cand_vals = jax.tree.map(
        lambda v: v.reshape((n, ka * kb) + v.shape[3:]), cand_vals
    )
    out_cols, out_vals, overflow = merge_sorted_rows(
        cand_cols, cand_vals, capacity=capacity, semiring=semiring
    )
    return EllMatrix(cols=out_cols, vals=out_vals, n_cols=b.n_cols), overflow


def _spgemm_chunked(a, b, *, semiring, capacity, row_chunk):
    n = a.cols.shape[0]

    def one(chunk):
        cc, cv = chunk
        am = EllMatrix(cols=cc, vals=cv, n_cols=a.n_cols)
        c, ovf = spgemm(am, b, semiring=semiring, capacity=capacity)
        return (c.cols, c.vals), ovf

    (oc, ov), ovfs = map_row_blocks(
        one, (a.cols, a.vals), n_rows=n, row_chunk=row_chunk,
        fills=(-1, jax.tree.map(lambda _: 0, a.vals)),
    )
    return EllMatrix(cols=oc, vals=ov, n_cols=b.n_cols), jnp.sum(ovfs)


@partial(jax.jit, static_argnames=("semiring", "row_chunk"))
def spgemm_masked(
    a: EllMatrix, b: EllMatrix, mask: EllMatrix, *, semiring: Semiring,
    row_chunk: int | None = None,
):
    if row_chunk is not None and a.cols.shape[0] > row_chunk:
        return _spgemm_masked_chunked(
            a, b, mask, semiring=semiring, row_chunk=row_chunk
        )
    return _spgemm_masked_impl(a, b, mask, semiring=semiring)


def _spgemm_masked_chunked(a, b, mask, *, semiring, row_chunk):
    n = a.cols.shape[0]

    def one(chunk):
        cc, cv, kc, kv = chunk
        am = EllMatrix(cols=cc, vals=cv, n_cols=a.n_cols)
        mm = EllMatrix(cols=kc, vals=kv, n_cols=mask.n_cols)
        return _spgemm_masked_impl(am, b, mm, semiring=semiring).vals, None

    vals, _ = map_row_blocks(
        one, (a.cols, a.vals, mask.cols, mask.vals), n_rows=n,
        row_chunk=row_chunk,
        fills=(-1, jax.tree.map(lambda _: 0, a.vals),
               -1, jax.tree.map(lambda _: 0, mask.vals)),
    )
    return EllMatrix(cols=mask.cols, vals=vals, n_cols=mask.n_cols)


def _spgemm_masked_impl(a: EllMatrix, b: EllMatrix, mask: EllMatrix, *,
                        semiring: Semiring):
    """Sampled semiring product: N = (A ⊗ B) restricted to pattern(mask).

    Returns an EllMatrix sharing ``mask``'s cols array whose values are
    ``⊕_k A[i,k] ⊗ B[k, mask.cols[i,q]]``.  No sort, no pattern growth:
    work/row = K_A·K_B candidate ⊗ plus a (K_A·K_B × K_mask) column match.
    """
    n, ka = a.cols.shape
    kb = b.cols.shape[1]
    km = mask.cols.shape[1]
    a_valid = a.mask
    safe = jnp.where(a_valid, a.cols, 0)
    b_cols_g = b.cols[safe]  # (n, KA, KB)
    b_vals_g = jax.tree.map(lambda v: v[safe], b.vals)
    a_vals_e = jax.tree.map(lambda v: v[:, :, None, ...], a.vals)
    cand_vals = semiring.mul(a_vals_e, b_vals_g)
    cand_valid = a_valid[:, :, None] & (b_cols_g >= 0) & ~semiring.is_zero(cand_vals)
    cand_cols = jnp.where(cand_valid, b_cols_g, NO_COL).reshape(n, ka * kb)
    cand_vals = jax.tree.map(lambda v: v.reshape((n, ka * kb) + v.shape[3:]), cand_vals)

    q = ka * kb

    def _log_reduce(vals, width):
        """⊕-reduce value pytree along axis 1 (length ``width``)."""
        cur = vals
        while width > 1:
            if width % 2:
                zpad = semiring.zero((n, 1))
                cur = jax.tree.map(
                    lambda x, z: jnp.concatenate(
                        [x, jnp.broadcast_to(z, (n, 1) + x.shape[2:])], axis=1
                    ),
                    cur,
                    zpad,
                )
                width += 1
            left = jax.tree.map(lambda x: x[:, 0::2], cur)
            right = jax.tree.map(lambda x: x[:, 1::2], cur)
            cur = semiring.add(left, right)
            width //= 2
        return jax.tree.map(lambda x: x[:, 0], cur)

    # Scan over mask slots so we never materialize an (n, Q, Km) value grid.
    def slot_body(_, slot_cols):  # slot_cols: (n,)
        hits = (cand_cols == slot_cols[:, None]) & (slot_cols[:, None] >= 0)
        contrib = tree_where(hits, cand_vals, semiring.zero((n, q)))
        return None, _log_reduce(contrib, q)

    _, out = jax.lax.scan(slot_body, None, mask.cols.T)
    out_vals = jax.tree.map(lambda x: jnp.moveaxis(x, 0, 1), out)  # (n, Km, ...)
    out_vals = tree_where(mask.cols >= 0, out_vals, semiring.zero((n, km)))
    return EllMatrix(cols=mask.cols, vals=out_vals, n_cols=mask.n_cols)


@partial(jax.jit, static_argnames=("capacity", "semiring"))
def transpose(a: EllMatrix, *, capacity: int, semiring: Semiring):
    """Explicit ELL transpose (paper Alg. 1 line 5). Returns (Aᵀ, overflow)."""
    n, k = a.cols.shape
    rows = jnp.broadcast_to(jnp.arange(n)[:, None], (n, k)).reshape(-1)
    cols = a.cols.reshape(-1)
    valid = cols >= 0
    vals = jax.tree.map(lambda v: v.reshape((n * k,) + v.shape[2:]), a.vals)
    return from_coo(
        cols,
        rows,
        vals,
        valid,
        n_rows=a.n_cols,
        n_cols=n,
        capacity=capacity,
        semiring=semiring,
    )
