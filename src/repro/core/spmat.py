"""Static-capacity sparse matrices for TPU (ELL layout).

CombBLAS stores dynamically-sized CSC/DCSC blocks; XLA/TPU require static
shapes.  We therefore store a sparse ``n_rows × n_cols`` matrix as

  * ``cols``: ``(n_rows, capacity)`` int32, column index per slot, ``-1`` empty,
    **sorted ascending within each row** (invalid slots pushed to the end);
  * ``vals``: an arbitrary value pytree whose leaves have leading shape
    ``(n_rows, capacity, ...)`` — semiring values live here.

The capacity is semantically justified by the pipeline itself: k-mer frequency
is capped (max freq u), so A's columns have ≤u entries; overlap/string matrices
have bounded row density (paper Table III).  Overflow is *surfaced* via an
``overflow`` counter rather than silently dropped.

All constructors run under jit with static ``n_rows``/``n_cols``/``capacity``.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .semiring import Semiring, tree_where

# numpy scalar (not a jnp array) so code using it can be traced inside Pallas
# kernel bodies — jax inlines numpy scalars as jaxpr literals where a device
# array would be a captured constant, which pallas_call rejects.
NO_COL = np.int32(-1)


def next_pow2(x: int) -> int:
    """Smallest power of two ≥ max(x, 1) — the shared bucket-padding policy
    (compacted alignment driver, contig-stage staging): pow-2 padding keeps
    the number of distinct compiled shapes logarithmic in the live count."""
    return 1 << max(0, int(x) - 1).bit_length()


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["cols", "vals"],
    meta_fields=["n_cols"],
)
@dataclasses.dataclass
class EllMatrix:
    """ELL sparse matrix: see module docstring.  A pytree (jit-transparent)."""

    cols: jnp.ndarray  # (n_rows, capacity) int32; -1 = empty; row-sorted
    vals: Any  # pytree, leaves (n_rows, capacity, ...)
    n_cols: int  # static

    @property
    def n_rows(self) -> int:
        return self.cols.shape[0]

    @property
    def capacity(self) -> int:
        return self.cols.shape[1]

    @property
    def mask(self) -> jnp.ndarray:
        return self.cols >= 0

    def nnz(self) -> jnp.ndarray:
        return jnp.sum(self.mask)

    def row_nnz(self) -> jnp.ndarray:
        return jnp.sum(self.mask, axis=1)

    def to_dense(self, semiring: Semiring) -> Any:
        """Densify values (absent -> semiring zero). Returns pytree of
        leaves with shape (n_rows, n_cols, ...)."""
        n, k = self.cols.shape
        # masked slots scatter to a dummy column so they never race
        safe = jnp.where(self.mask, self.cols, self.n_cols)
        rows = jnp.broadcast_to(jnp.arange(n)[:, None], (n, k))
        zero = semiring.zero((n, self.n_cols + 1))

        def scat(z, v):
            return z.at[rows, safe].set(v)[:, : self.n_cols]

        return jax.tree.map(scat, zero, self.vals)

    def lookup(self, semiring: Semiring, query_cols: jnp.ndarray):
        """Row-wise sorted lookup: for each (i, q) return the value at
        ``self[i, query_cols[i, q]]`` (semiring zero if absent).

        query_cols: (n_rows, Q) int32 (may contain -1).
        Returns (vals pytree with leading (n_rows, Q), found mask).
        """
        n, k = self.cols.shape
        big = jnp.where(self.mask, self.cols, jnp.int32(2**30))
        q = query_cols
        pos = jax.vmap(jnp.searchsorted)(big, jnp.where(q >= 0, q, 0))
        pos = jnp.clip(pos, 0, k - 1)
        hit_col = jnp.take_along_axis(big, pos, axis=1)
        found = (hit_col == q) & (q >= 0)
        got = jax.tree.map(
            lambda v: jnp.take_along_axis(
                v, pos.reshape(pos.shape + (1,) * (v.ndim - 2)), axis=1
            ),
            self.vals,
        )
        zero = semiring.zero(q.shape)
        return tree_where(found, got, zero), found


def map_row_blocks(fn, inputs: Any, *, n_rows: int, row_chunk: int,
                   fills: Any = None):
    """Map ``fn`` over fixed-size row blocks of ``inputs`` with ``lax.map``.

    The shared chunking combinator behind ``spgemm``'s row-chunked paths and
    the pipeline's compacted alignment driver: it bounds peak memory of a
    per-row computation by processing ``row_chunk`` rows at a time while
    tracing ``fn`` exactly once.

    Args:
      fn: ``block -> (row_out, aux)`` where ``block`` is ``inputs`` restricted
        to ``row_chunk`` rows, ``row_out`` is a pytree whose leaves have
        leading dim ``row_chunk``, and ``aux`` is any per-block pytree
        (``None`` if unused).
      inputs: pytree of arrays with leading dim ``n_rows``.
      fills: pytree matching ``inputs`` of scalar pad values for the rows
        padded onto the last block (default 0 everywhere).

    Returns ``(row_out, aux)`` with ``row_out`` leaves reassembled to leading
    dim ``n_rows`` and ``aux`` leaves stacked over the ``ceil(n_rows /
    row_chunk)`` blocks (callers reduce, e.g. summing overflow counters).
    """
    nb = -(-n_rows // row_chunk)
    pad = nb * row_chunk - n_rows
    if fills is None:
        fills = jax.tree.map(lambda _: 0, inputs)

    def blockify(x, fill):
        xp = jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1),
                     constant_values=fill)
        return xp.reshape((nb, row_chunk) + x.shape[1:])

    blocks = jax.tree.map(blockify, inputs, fills)
    row_out, aux = jax.lax.map(fn, blocks)
    merged = jax.tree.map(
        lambda v: v.reshape((nb * row_chunk,) + v.shape[2:])[:n_rows], row_out
    )
    return merged, aux


def _segmented_combine(flags: jnp.ndarray, vals: Any, add, axis: int = 0) -> Any:
    """Inclusive segmented scan along ``axis``: combine vals within runs
    (flags==True starts a new run).  Returns scanned vals (run-prefix sums
    under ``add``); the last element of each run holds the run total."""

    def op(x, y):
        fx, vx = x
        fy, vy = y
        v = tree_where(fy, vy, add(vx, vy))
        return (fx | fy, v)

    _, out = jax.lax.associative_scan(op, (flags, vals), axis=axis)
    return out


def _rank_in_row_sorted(rows_sorted: jnp.ndarray, kept: jnp.ndarray) -> jnp.ndarray:
    """Given row ids sorted ascending and a kept mask, rank of each kept entry
    among kept entries of the same row (0-based)."""
    c = jnp.cumsum(kept.astype(jnp.int32))
    base_idx = jnp.searchsorted(rows_sorted, rows_sorted, side="left")
    c_base = jnp.take(c, base_idx)
    kept_base = jnp.take(kept.astype(jnp.int32), base_idx)
    return c - c_base + kept_base - 1


@partial(jax.jit, static_argnames=("n_rows", "n_cols", "capacity", "semiring"))
def from_coo(
    rows: jnp.ndarray,
    cols: jnp.ndarray,
    vals: Any,
    valid: jnp.ndarray,
    *,
    n_rows: int,
    n_cols: int,
    capacity: int,
    semiring: Semiring,
):
    """Build an EllMatrix from COO triplets, merging duplicate (row, col)
    entries with ``semiring.add`` (merge order = input order, stable).

    Returns (EllMatrix, overflow_count)."""
    e = rows.shape[0]
    rkey = jnp.where(valid, rows, n_rows)
    ckey = jnp.where(valid, cols, n_cols)
    order = jnp.lexsort((ckey, rkey))
    rs, cs = rkey[order], ckey[order]
    vs = jax.tree.map(lambda x: x[order], vals)
    valid_s = valid[order]

    prev_r = jnp.concatenate([jnp.full((1,), -2, rs.dtype), rs[:-1]])
    prev_c = jnp.concatenate([jnp.full((1,), -2, cs.dtype), cs[:-1]])
    new_run = (rs != prev_r) | (cs != prev_c)
    scanned = _segmented_combine(new_run, vs, semiring.add, axis=0)
    next_new = jnp.concatenate([new_run[1:], jnp.ones((1,), bool)])
    kept = next_new & valid_s  # last element of each (row,col) run

    rank = _rank_in_row_sorted(rs, kept)
    in_cap = kept & (rank < capacity)
    overflow = jnp.sum(kept & (rank >= capacity))

    # Masked entries scatter to a dummy row (n_rows) so they can never race
    # with a live write.
    safe_r = jnp.where(in_cap, rs, n_rows)
    safe_k = jnp.where(in_cap, rank, 0)
    out_cols = jnp.full((n_rows + 1, capacity), NO_COL)
    out_cols = out_cols.at[safe_r, safe_k].set(cs.astype(jnp.int32))[:n_rows]
    zero = semiring.zero((n_rows + 1, capacity))

    def scat(z, v):
        return z.at[safe_r, safe_k].set(v)[:n_rows]

    out_vals = jax.tree.map(scat, zero, scanned)
    return EllMatrix(cols=out_cols, vals=out_vals, n_cols=n_cols), overflow


def merge_sorted_rows(
    cand_cols: jnp.ndarray, cand_vals: Any, *, capacity: int, semiring: Semiring
):
    """Per-row candidate merge: given (n, Q) candidate columns (−1 = invalid)
    and value pytree (n, Q, ...), sort each row by column, ⊕-combine duplicates
    and compact into an ELL row of ``capacity`` slots.

    The workhorse of the local SpGEMM.  Returns (cols, vals, overflow)."""
    n, q = cand_cols.shape
    big = np.int32(2**30)  # numpy scalar: stays a literal under Pallas tracing
    key = jnp.where(cand_cols >= 0, cand_cols, big)
    order = jnp.argsort(key, axis=1)
    cs = jnp.take_along_axis(key, order, axis=1)
    vs = jax.tree.map(
        lambda v: jnp.take_along_axis(
            v, order.reshape(order.shape + (1,) * (v.ndim - 2)), axis=1
        ),
        cand_vals,
    )
    valid = cs < big
    prev = jnp.concatenate([jnp.full((n, 1), -2, cs.dtype), cs[:, :-1]], axis=1)
    new_run = cs != prev
    scanned = _segmented_combine(new_run, vs, semiring.add, axis=1)
    next_new = jnp.concatenate([new_run[:, 1:], jnp.ones((n, 1), bool)], axis=1)
    kept = next_new & valid & ~semiring.is_zero(scanned)

    # Compact: stable argsort moves kept entries (already col-ascending) first.
    ckey = jnp.where(kept, cs, big)
    order2 = jnp.argsort(ckey, axis=1)[:, :capacity]
    out_cols_raw = jnp.take_along_axis(ckey, order2, axis=1)
    out_cols = jnp.where(out_cols_raw < big, out_cols_raw.astype(jnp.int32), NO_COL)
    out_vals = jax.tree.map(
        lambda v: jnp.take_along_axis(
            v, order2.reshape(order2.shape + (1,) * (v.ndim - 2)), axis=1
        ),
        scanned,
    )
    out_vals = tree_where(out_cols >= 0, out_vals, semiring.zero((n, capacity)))
    overflow = jnp.sum(jnp.maximum(jnp.sum(kept, axis=1) - capacity, 0))
    return out_cols, out_vals, overflow


def ell_equal(a: EllMatrix, b: EllMatrix) -> bool:
    """Structural + value equality (host-side, for tests)."""
    if a.n_cols != b.n_cols or a.n_rows != b.n_rows:
        return False
    da = jax.tree.leaves(a.vals)
    db = jax.tree.leaves(b.vals)
    import numpy as np

    if not np.array_equal(np.asarray(a.cols), np.asarray(b.cols)):
        return False
    return all(
        np.allclose(np.asarray(x), np.asarray(y), equal_nan=True)
        for x, y in zip(da, db)
    )


def prune(mat: EllMatrix, drop: jnp.ndarray, semiring: Semiring) -> EllMatrix:
    """Remove entries where ``drop`` (n, capacity) is True, recompacting rows
    so they stay sorted-by-column (the paper's R ∘ ¬I, §IV-E)."""
    n, k = mat.cols.shape
    keep = mat.mask & ~drop
    big = np.int32(2**30)
    key = jnp.where(keep, mat.cols, big)
    order = jnp.argsort(key, axis=1)
    new_raw = jnp.take_along_axis(key, order, axis=1)
    new_cols = jnp.where(new_raw < big, new_raw, NO_COL)
    new_vals = jax.tree.map(
        lambda v: jnp.take_along_axis(
            v, order.reshape(order.shape + (1,) * (v.ndim - 2)), axis=1
        ),
        mat.vals,
    )
    new_vals = tree_where(new_cols >= 0, new_vals, semiring.zero((n, k)))
    return EllMatrix(cols=new_cols, vals=new_vals, n_cols=mat.n_cols)
