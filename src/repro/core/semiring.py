"""Custom semirings for diBELLA-2D style sparse linear algebra (paper §IV, Alg. 3).

A semiring here is a pair of vectorized callables over *value pytrees* plus an
explicit additive identity.  Values are pytrees of jnp arrays whose leading
dimensions are broadcast dimensions; ``mul``/``add`` must be shape-polymorphic
elementwise maps so the same semiring drives the local ELL SpGEMM, the
distributed SUMMA, and the Pallas block kernels.

Provided semirings
------------------
* ``minplus_orient_semiring`` — the paper's Algorithm-3 MinPlus semiring with
  bidirected-walk validity.  Each value is a ``(..., 4)`` float32 array ``V``
  holding the overlap-suffix length for each (strand-of-left-end,
  strand-of-right-end) combination, ``V[2a+b]`` with ``a,b ∈ {0,1}`` and
  ``inf`` = absent.  ``mul`` is a 2×2 min-plus matrix product — the contraction
  over the middle strand *is* the paper's "heads adjacent to the intermediate
  node must be consistent" check; ``add`` is elementwise min.
* ``overlap_semiring`` — the SpGEMM semiring for ``C = A·Aᵀ`` (paper §IV-D):
  ``mul`` pairs the two positions of a shared k-mer, ``add`` counts shared
  k-mers and concatenates up to ``NUM_POS_PAIRS`` position pairs.
* ``bool_semiring`` / ``count_semiring`` — utility semirings for pattern
  algebra and tests.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

INF = jnp.inf
# Number of shared k-mer position pairs kept per read pair ("for this work we
# store two k-mer positions for each read pair", paper §IV-D).
NUM_POS_PAIRS = 2


@dataclasses.dataclass(frozen=True)
class Semiring:
    """A (⊕, ⊗) pair over value pytrees with explicit identity handling.

    Attributes:
      name: human-readable identifier.
      mul: ``(a_vals, b_vals) -> out_vals``; elementwise over broadcast dims.
        May return the additive identity to signal "no contribution" (e.g. an
        orientation-invalid path).
      add: associative, commutative combine of two value pytrees.
      zero: ``(prefix_shape) -> vals`` additive identity with the given
        leading shape.
      is_zero: ``vals -> bool array`` of the broadcast shape; True where the
        value equals the additive identity (entry should be treated as absent).
    """

    name: str
    mul: Callable[[Any, Any], Any]
    add: Callable[[Any, Any], Any]
    zero: Callable[[tuple], Any]
    is_zero: Callable[[Any], jnp.ndarray]


# ---------------------------------------------------------------------------
# MinPlus semiring with bidirected-walk validity (paper Algorithm 3).
# ---------------------------------------------------------------------------


def _mp_mul(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """2×2 min-plus matmul over the trailing orientation axis.

    ``out[2a+b] = min_c a[2*ax+c] + b[2*c+b]``.  A path i→k→j is valid iff the
    strand in which k is used by (i,k) equals the strand used by (k,j); invalid
    combinations contribute the identity (+inf) automatically.
    """
    prefix = jnp.broadcast_shapes(a.shape[:-1], b.shape[:-1])
    am = a.reshape(a.shape[:-1] + (2, 2))
    bm = b.reshape(b.shape[:-1] + (2, 2))
    # out[..., x, y] = min_c am[..., x, c] + bm[..., c, y]
    s = am[..., :, :, None] + bm[..., None, :, :]
    out = jnp.min(s, axis=-2)
    return out.reshape(prefix + (4,))


def _mp_add(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return jnp.minimum(a, b)


def _mp_zero(prefix_shape: tuple) -> jnp.ndarray:
    return jnp.full(prefix_shape + (4,), INF, dtype=jnp.float32)


def _mp_is_zero(v: jnp.ndarray) -> jnp.ndarray:
    return jnp.all(~jnp.isfinite(v), axis=-1)


minplus_orient_semiring = Semiring(
    name="minplus_orient",
    mul=_mp_mul,
    add=_mp_add,
    zero=_mp_zero,
    is_zero=_mp_is_zero,
)


def mp_value(suffix_len, strand_i, strand_j) -> jnp.ndarray:
    """Build a single-orientation MinPlus value: suffix length at combo
    (strand_i, strand_j), inf elsewhere.  Broadcasts over leading dims."""
    suffix_len = jnp.asarray(suffix_len, jnp.float32)
    combo = 2 * jnp.asarray(strand_i, jnp.int32) + jnp.asarray(strand_j, jnp.int32)
    base = jnp.full(suffix_len.shape + (4,), INF, dtype=jnp.float32)
    return base.at[..., :].set(
        jnp.where(
            jnp.arange(4) == combo[..., None], suffix_len[..., None], INF
        )
    )


# ---------------------------------------------------------------------------
# Overlap-detection semiring for C = A·Aᵀ (paper §IV-D).
# ---------------------------------------------------------------------------
# A-values:   {"pos": int32}  — position of the k-mer in the read.
# C-values:   {"cnt": int32, "apos": (NUM_POS_PAIRS,) int32,
#              "bpos": (NUM_POS_PAIRS,) int32}
# ``mul`` turns one shared k-mer into (cnt=1, the position pair);
# ``add`` sums counts and keeps the first NUM_POS_PAIRS pairs (the paper
# concatenates "as long as it is smaller than the number of positions to be
# stored"); with a deterministic merge order this is associative.

# numpy scalar so overlap-semiring code stays Pallas-traceable (a jnp scalar
# would be a captured constant inside kernel bodies, which pallas_call rejects)
_NOPOS = np.int32(-1)


def _ov_mul(a: Any, b: Any) -> Any:
    apos = jnp.asarray(a["pos"], jnp.int32)
    bpos = jnp.asarray(b["pos"], jnp.int32)
    shape = jnp.broadcast_shapes(apos.shape, bpos.shape)
    apos = jnp.broadcast_to(apos, shape)
    bpos = jnp.broadcast_to(bpos, shape)
    pad = jnp.full(shape + (NUM_POS_PAIRS - 1,), _NOPOS)
    return {
        "cnt": jnp.ones(shape, jnp.int32),
        "apos": jnp.concatenate([apos[..., None], pad], axis=-1),
        "bpos": jnp.concatenate([bpos[..., None], pad], axis=-1),
    }


def _take_first_pairs(xa, xb, xn, ya, yb):
    """Concatenate y's pairs after x's xn valid pairs, truncate."""
    # slots: for slot s in [0, NUM_POS_PAIRS): value = xa[s] if s < xn else
    # ya[s - xn].
    s = jnp.arange(NUM_POS_PAIRS)
    xn_b = xn[..., None]
    from_x = s < xn_b
    yidx = jnp.clip(s - xn_b, 0, NUM_POS_PAIRS - 1)
    out_a = jnp.where(from_x, xa, jnp.take_along_axis(ya, yidx, axis=-1))
    out_b = jnp.where(from_x, xb, jnp.take_along_axis(yb, yidx, axis=-1))
    return out_a, out_b


def _ov_add(x: Any, y: Any) -> Any:
    xn = jnp.minimum(x["cnt"], NUM_POS_PAIRS)
    out_a, out_b = _take_first_pairs(x["apos"], x["bpos"], xn, y["apos"], y["bpos"])
    return {"cnt": x["cnt"] + y["cnt"], "apos": out_a, "bpos": out_b}


def _ov_zero(prefix_shape: tuple) -> Any:
    return {
        "cnt": jnp.zeros(prefix_shape, jnp.int32),
        "apos": jnp.full(prefix_shape + (NUM_POS_PAIRS,), _NOPOS),
        "bpos": jnp.full(prefix_shape + (NUM_POS_PAIRS,), _NOPOS),
    }


def _ov_is_zero(v: Any) -> jnp.ndarray:
    return v["cnt"] == 0


overlap_semiring = Semiring(
    name="overlap_pospair",
    mul=_ov_mul,
    add=_ov_add,
    zero=_ov_zero,
    is_zero=_ov_is_zero,
)


# ---------------------------------------------------------------------------
# Utility semirings.
# ---------------------------------------------------------------------------

bool_semiring = Semiring(
    name="bool",
    mul=lambda a, b: jnp.logical_and(a, b),
    add=lambda a, b: jnp.logical_or(a, b),
    zero=lambda s: jnp.zeros(s, bool),
    is_zero=lambda v: ~v,
)

count_semiring = Semiring(
    name="count",
    mul=lambda a, b: (jnp.asarray(a, jnp.int32) * jnp.asarray(b, jnp.int32)),
    add=lambda a, b: a + b,
    zero=lambda s: jnp.zeros(s, jnp.int32),
    is_zero=lambda v: v == 0,
)

plus_times_f32 = Semiring(
    name="plus_times_f32",
    mul=lambda a, b: a * b,
    add=lambda a, b: a + b,
    zero=lambda s: jnp.zeros(s, jnp.float32),
    is_zero=lambda v: v == 0.0,
)


def tree_where(mask: jnp.ndarray, a: Any, b: Any) -> Any:
    """``jnp.where`` lifted to value pytrees; mask broadcasts on leading dims."""

    def _w(x, y):
        m = mask.reshape(mask.shape + (1,) * (x.ndim - mask.ndim))
        return jnp.where(m, x, y)

    return jax.tree.map(_w, a, b)


def tree_take(vals: Any, idx: jnp.ndarray, axis: int = 0) -> Any:
    return jax.tree.map(lambda x: jnp.take(x, idx, axis=axis), vals)
