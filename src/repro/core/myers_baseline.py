"""Sequential Myers-style transitive reduction — the correctness oracle.

Myers' linear-time fragment-assembly algorithm [paper ref 10] iterates over
each node v, bounds candidate paths by ``longest(v) + fuzz`` and marks edges
v→w transitive when reachable via a valid two-hop walk.  The paper's
Algorithm 2 is the semiring-parallel formulation of exactly this rule, so the
two must produce identical string graphs; tests assert graph equality.

This module is deliberately plain Python/numpy (host-side, sequential) — it is
both the oracle for property-based tests and the "competing implementation" in
our Table-VI-style benchmark (SORA/Spark being unavailable, Myers' own
algorithm is the natural sequential baseline; see DESIGN.md §2).

Graph representation: ``{(i, j): [s00, s01, s10, s11]}`` — suffix length per
(strand_i, strand_j) combo, ``math.inf`` = absent.
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import numpy as np

Edges = Dict[Tuple[int, int], list]


def from_ell(mat) -> Edges:
    """EllMatrix (MinPlus 4-vector values) -> dict graph."""
    cols = np.asarray(mat.cols)
    vals = np.asarray(mat.vals)
    edges: Edges = {}
    for i in range(cols.shape[0]):
        for q in range(cols.shape[1]):
            j = int(cols[i, q])
            if j < 0:
                continue
            v = [float(x) if np.isfinite(x) else math.inf for x in vals[i, q]]
            if any(math.isfinite(x) for x in v):
                edges[(i, j)] = v
    return edges


def myers_transitive_reduction(
    edges: Edges, fuzz: float = 200.0, max_iters: int = 10
) -> Tuple[Edges, int]:
    """Iterated Myers rule, combo-resolved. Returns (string graph, rounds)."""
    edges = {k: list(v) for k, v in edges.items()}
    out_adj: Dict[int, list] = {}

    def rebuild():
        out_adj.clear()
        for (i, j), v in edges.items():
            out_adj.setdefault(i, []).append(j)

    rounds = 0
    for _ in range(max_iters):
        rebuild()
        rowmax = {}
        for (i, j), v in edges.items():
            m = max((x for x in v if math.isfinite(x)), default=-math.inf)
            rowmax[i] = max(rowmax.get(i, -math.inf), m)

        marks = []  # (i, j, combo)
        for (i, j), vij in edges.items():
            bound = rowmax[i] + fuzz
            for a in (0, 1):
                for b in (0, 1):
                    if not math.isfinite(vij[2 * a + b]):
                        continue
                    best = math.inf
                    for k in out_adj.get(i, ()):  # middle nodes
                        vik = edges.get((i, k))
                        vkj = edges.get((k, j))
                        if vik is None or vkj is None:
                            continue
                        for c in (0, 1):
                            s = vik[2 * a + c] + vkj[2 * c + b]
                            if s < best:
                                best = s
                    if best <= bound:
                        marks.append((i, j, 2 * a + b))
        if not marks:
            break
        for i, j, combo in marks:
            edges[(i, j)][combo] = math.inf
        dead = [k for k, v in edges.items() if not any(math.isfinite(x) for x in v)]
        for k in dead:
            del edges[k]
        rounds += 1
    return edges, rounds


def graphs_equal(a: Edges, b: Edges, tol: float = 1e-4) -> bool:
    if set(a) != set(b):
        return False
    for k in a:
        for x, y in zip(a[k], b[k]):
            fx, fy = math.isfinite(x), math.isfinite(y)
            if fx != fy:
                return False
            if fx and abs(x - y) > tol:
                return False
    return True


def dense_square_transitive_reduction(
    edges: Edges, n: int, fuzz: float = 200.0, max_iters: int = 10
) -> Tuple[Edges, int]:
    """Naive dense baseline: materializes the full n×n×4 min-plus square each
    round (the O(n³) comparison point for the Table-VI benchmark)."""
    inf = math.inf
    # Doubled-vertex formulation: T[(i,a), (j,b)] = suffix of edge i→j at
    # strand combo (a, b); the orientation-valid square is then a plain
    # min-plus matrix square of the 2n×2n matrix.
    t = np.full((2 * n, 2 * n), inf, dtype=np.float64)
    for (i, j), v in edges.items():
        for a in (0, 1):
            for b in (0, 1):
                t[2 * i + a, 2 * j + b] = v[2 * a + b]
    rounds = 0
    for _ in range(max_iters):
        finite = np.isfinite(t)
        rowmax = np.where(finite, t, -inf).reshape(n, 2 * 2 * n).max(axis=1)
        # blocked min-plus square to bound memory at O(n²) per row-block
        nsq = np.empty_like(t)
        for r0 in range(0, 2 * n, 64):
            r1 = min(r0 + 64, 2 * n)
            nsq[r0:r1] = np.min(t[r0:r1, :, None] + t[None, :, :], axis=1)
        bound = np.repeat(rowmax, 2)[:, None] + fuzz
        trans = finite & np.isfinite(nsq) & (nsq <= bound)
        if not trans.any():
            break
        t[trans] = inf
        rounds += 1
    out: Edges = {}
    fin = np.isfinite(t)
    for i in range(n):
        for j in range(n):
            blk = t[2 * i : 2 * i + 2, 2 * j : 2 * j + 2]
            if np.isfinite(blk).any():
                out[(i, j)] = list(blk.reshape(4))
    return out, rounds
