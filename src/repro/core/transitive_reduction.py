"""Parallel transitive reduction (paper Algorithm 2) over MinPlus semiring.

Two implementations:

* ``transitive_reduction`` — **paper-faithful**: each round materializes the
  full two-hop neighbour matrix ``N = R²`` under the orientation-resolved
  MinPlus semiring (Alg. 3), builds the maximal-suffix matrix
  ``M = rowmax(R) + fuzz`` broadcast over R's pattern (lines 5–7), flags
  ``I = M ≥ N`` on the pattern intersection with the departure/destination
  orientation checks (line 8; our 4-vector values make the check an index
  lookup), prunes ``R ← R ∘ ¬I`` (line 9) and iterates until nnz is stable
  (line 11).

* ``transitive_reduction_fused`` — **beyond-paper TPU optimization**: Alg. 2
  only ever reads N at R's own nonzero positions, so we compute the *sampled*
  square ``N∘pattern(R)`` directly (``spgemm_masked``), skipping the candidate
  sort and N's pattern growth.  Results are bit-identical to the faithful
  version whenever the faithful N-capacity does not overflow (asserted in
  tests); unlike the faithful path it cannot lose min-candidates to capacity
  overflow.

Both run the convergence loop as a ``lax.while_loop`` with static shapes and
return (S, TRStats).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from .backend import dispatch, resolve_backend
from .semiring import INF, minplus_orient_semiring as SR
from .spgemm import spgemm, spgemm_masked
from .spmat import EllMatrix, prune


# Above this many rows the dense-square Pallas TR path would materialize an
# (n, n, 4) f32 operand per iteration (O(n²) HBM); fall back to the O(n·K)
# sampled ELL square instead.  4096 rows ≈ 256 MB per operand.
TR_DENSE_MAX_ROWS = 4096


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["iterations", "nnz_initial", "nnz_final", "n_overflow"],
    meta_fields=["backend"],
)
@dataclasses.dataclass
class TRStats:
    """Convergence + integrity counters of one transitive-reduction run.

    ``n_overflow`` counts N-capacity overflow events of the faithful path —
    when it is nonzero the faithful result may have lost min-candidates, so
    any faithful-vs-fused divergence must be read against it (asserted in
    ``tests/test_transitive_reduction.py``).  ``backend`` records the kernel
    path that *actually ran* (``"reference"`` / ``"pallas"``): the fused
    variant silently falls back to the sampled ELL square above
    ``TR_DENSE_MAX_ROWS``, and benchmark rows must not mislabel that
    (surfaced as ``tr_backend`` in pipeline stats / ``bench_breakdown``).
    """

    iterations: jnp.ndarray
    nnz_initial: jnp.ndarray
    nnz_final: jnp.ndarray
    n_overflow: jnp.ndarray  # N-capacity overflow events (faithful path only)
    backend: str = "reference"  # backend actually used (post-fallback)


def row_max_suffix(r: EllMatrix) -> jnp.ndarray:
    """Per-row max finite suffix over all slots and orientation combos
    (paper line 5: ``v ← R.REDUCE(Row, 0, max)``)."""
    vals = jnp.where(jnp.isfinite(r.vals), r.vals, -INF)
    vals = jnp.where(r.mask[:, :, None], vals, -INF)
    return jnp.max(vals, axis=(1, 2))


def _transitive_combos(r: EllMatrix, n_at_r, found, v) -> jnp.ndarray:
    """Line 8: combo (a,b) of R[i,j] is transitive iff a valid 2-hop path with
    the same end orientations exists (N[i,j][a,b] finite) and its min-plus
    length ≤ v[i] = rowmax_i + fuzz.  Returns (n, K, 4) bool."""
    n_vals = n_at_r  # (n, K, 4)
    cond = (n_vals <= v[:, None, None]) & jnp.isfinite(n_vals)
    cond &= found[:, :, None] & r.mask[:, :, None] & jnp.isfinite(r.vals)
    return cond


def _prune_combos(r: EllMatrix, transitive: jnp.ndarray) -> EllMatrix:
    """Set transitive combos to +inf; drop slots whose combos are all inf
    (paper line 9: R ← R ∘ ¬I) and recompact rows."""
    new_vals = jnp.where(transitive, INF, r.vals)
    dead = ~jnp.any(jnp.isfinite(new_vals), axis=-1) & r.mask
    r2 = EllMatrix(cols=r.cols, vals=new_vals, n_cols=r.n_cols)
    return prune(r2, dead, SR)


@partial(jax.jit, static_argnames=("n_capacity", "max_iters", "fused", "backend"))
def _tr_impl(
    r: EllMatrix,
    fuzz: float,
    *,
    n_capacity: int,
    max_iters: int,
    fused: bool,
    backend: str = "reference",
) -> Tuple[EllMatrix, TRStats]:
    nnz0 = r.nnz()

    def cond(carry):
        _, prev, cur, it, _ = carry
        return (cur != prev) & (it < max_iters)

    def body(carry):
        r, _, cur, it, ovf = carry
        v = row_max_suffix(r) + fuzz
        if fused and backend == "pallas":
            # Dense orientation-resolved min-plus square on the Pallas kernel,
            # sampled back at R's own pattern.  Bit-identical to the sampled
            # ELL square: absent entries are +inf, the additive identity, so
            # contracting over all n columns equals contracting over R's
            # slots, and neither path can lose min-candidates to capacity.
            minplus = dispatch("minplus_dense", "pallas")
            dense = r.to_dense(SR)
            nd = minplus(dense, dense)
            n = r.cols.shape[0]
            safe = jnp.where(r.mask, r.cols, 0)
            vals_at_r = nd[jnp.arange(n)[:, None], safe]
            found = r.mask
            step_ovf = jnp.int32(0)
        elif fused:
            n_at_r = spgemm_masked(r, r, r, semiring=SR)
            found = r.mask
            vals_at_r = n_at_r.vals
            step_ovf = jnp.int32(0)
        else:
            n_full, step_ovf = spgemm(r, r, semiring=SR, capacity=n_capacity)
            got, found = n_full.lookup(SR, jnp.where(r.mask, r.cols, -1))
            vals_at_r = got
        trans = _transitive_combos(r, vals_at_r, found, v)
        r2 = _prune_combos(r, trans)
        return (r2, cur, r2.nnz(), it + 1, ovf + step_ovf.astype(jnp.int32))

    init = (r, jnp.int32(-1), nnz0.astype(jnp.int32), jnp.int32(0), jnp.int32(0))
    r_out, _, nnz_f, iters, ovf = jax.lax.while_loop(cond, body, init)
    return r_out, TRStats(
        iterations=iters, nnz_initial=nnz0, nnz_final=nnz_f, n_overflow=ovf,
        backend=backend if fused else "reference",
    )


def transitive_reduction(
    r: EllMatrix,
    fuzz: float = 200.0,
    *,
    n_capacity: int | None = None,
    max_iters: int = 10,
    backend: str = "reference",
) -> Tuple[EllMatrix, TRStats]:
    """Paper-faithful Algorithm 2.  ``n_capacity`` bounds N = R² rows
    (default: min(K², 4K)).

    ``backend`` is accepted for API uniformity but the faithful path always
    runs the capacity-bounded ELL square: its overflow accounting is part of
    its contract, and the dense kernel square (which cannot overflow) would
    silently change results whenever N overflows ``n_capacity``.  Use
    ``transitive_reduction_fused`` for the kernel-backed variant."""
    k = r.capacity
    if n_capacity is None:
        n_capacity = min(k * k, 4 * k)
    resolve_backend(backend)  # validate, then ignore (see docstring)
    return _tr_impl(
        r, jnp.float32(fuzz), n_capacity=n_capacity, max_iters=max_iters,
        fused=False, backend="reference",
    )


def transitive_reduction_fused(
    r: EllMatrix, fuzz: float = 200.0, *, max_iters: int = 10,
    backend: str = "reference",
) -> Tuple[EllMatrix, TRStats]:
    """Beyond-paper fused/sampled variant (see module docstring).
    ``backend="pallas"`` routes the sampled square through the dense
    min-plus Pallas kernel (bit-identical, see ``_tr_impl``); graphs wider
    than ``TR_DENSE_MAX_ROWS`` fall back to the O(n·K) ELL square rather
    than materializing an O(n²) dense operand per iteration.  The fallback
    is *recorded*: ``TRStats.backend`` reports the path that actually ran,
    so a ``backend="pallas"`` request downgraded to ``"reference"`` cannot
    be mislabelled in benchmark rows (`bench_breakdown`'s ``tr_stats``)."""
    b = resolve_backend(backend)
    if b == "pallas" and r.cols.shape[0] > TR_DENSE_MAX_ROWS:
        b = "reference"
    return _tr_impl(
        r, jnp.float32(fuzz), n_capacity=1, max_iters=max_iters, fused=True,
        backend=b,
    )
