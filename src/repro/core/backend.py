"""Kernel-backend dispatch layer (DESIGN.md §2.5).

The pipeline's two compute hot spots — batched x-drop seed extension
(paper §IV-D) and the dense min-plus squares inside transitive reduction
(Algorithm 2) — each exist twice in this repo: a pure-jnp *reference*
implementation (the oracle) and a Pallas TPU *kernel*.  This module is the
single seam that decides, per op, which one runs:

  * ``"reference"`` — the jnp oracle.  Always available, runs anywhere.
  * ``"pallas"``    — the Pallas kernel.  Compiled on TPU; on other platforms
    it runs in interpret mode (bit-identical semantics, no speedup) so parity
    tests and CI exercise the exact kernel code path.
  * ``"auto"``      — platform detection: ``"pallas"`` (compiled) when the
    default JAX backend is TPU, ``"reference"`` elsewhere.

Contract
--------
Implementations register under a string op name via :func:`register_op`; the
kernels package registers both backends for every op it provides when it is
imported (``dispatch`` imports it lazily, so ``core`` never depends on
``kernels`` at module-import time and the ``core → kernels → assembly → core``
cycle is broken).  Registered implementations of one op must agree *exactly*
(same outputs bit-for-bit on the same inputs) — asserted by the parity tests
in ``tests/test_kernels.py`` and the golden-assembly test in
``tests/test_backend.py``.  Anything that holds for one backend's output may
therefore be assumed for the other's.

Current ops
-----------
``xdrop_extend``
    ``(a, base_a, step_a, len_a, b, base_b, step_b, len_b, *, xdrop, match,
    mismatch, gap, band, max_steps, pairs_per_block) -> (score, ai, bj)``
    batched single-direction x-drop extension.
``minplus_dense``
    ``(a, b) -> n`` with ``a (M, K, 4)``, ``b (K, N, 4)``, ``n (M, N, 4)``
    f32; the orientation-resolved dense min-plus matmul of Algorithm 2.
``contig_gen``
    ``(s_mat, codes, lengths, contained) -> ContigSet`` — the Contigs stage
    (DESIGN.md §2.7): ``reference`` is the host walk in
    ``assembly/contigs.py``, ``pallas`` the device array path in
    ``assembly/contig_gen.py``; both must produce identical contigs
    (asserted chain-by-chain by ``tests/test_contigs.py``).
``consensus``
    ``(draft, pieces, start, plen, *, min_depth, band, interpret) ->
    (polished, depth, agree)`` — the banded pileup + majority-vote hot loop
    of the consensus stage (DESIGN.md §2.8): ``reference`` is the jnp
    scatter-add oracle, ``pallas`` the column-banded VMEM accumulation
    kernel; integer counts make the parity exact
    (``tests/test_consensus.py``).
``cc_labels``
    ``(cols, *, max_iters) -> (labels, iters)`` — the hook/shortcut
    connected-components rounds (DESIGN.md §2.9): ``reference`` runs one
    XLA gather/scatter round trip per round, ``pallas`` fuses blocks of
    rounds into VMEM-resident kernel calls (``kernels/cc/``); labels agree
    bit-for-bit (``tests/test_components.py``).
``spgemm_ring_stages``
    ``(offsets, a_cols, a_vals, b_cols, b_vals, *, semiring, capacity,
    n_cols_out, interpret) -> (st_cols, st_vals, overflow)`` — a batch of
    ring-SUMMA local SpGEMM stages (DESIGN.md §2.11): ``reference`` runs the
    gather → ⊗ → merge pipeline once per stage, ``pallas`` fuses the whole
    batch into one grid program with the stage outputs VMEM-resident
    (``kernels/spgemm/``); per-stage buffers agree bit-for-bit
    (``tests/test_kernels.py``), and ``core.summa.summa_ring`` dispatches
    between them.

Distribution axis
-----------------
Orthogonal to the backend axis, the device contig path has a
*distribution* axis (DESIGN.md §2.9): ``"gspmd"`` leaves partitioning to
the auto-sharder, ``"shard_map"`` runs the doubling middle with explicit
``ppermute``/``psum`` neighbor exchanges (``core/components_dist.py``).
Both must produce bit-identical results — asserted in
``tests/test_distributed.py``.  ``resolve_distribution`` validates the
knob the same way ``resolve_backend`` does.
"""

from __future__ import annotations

import functools
from typing import Callable, Dict, Tuple

import jax

from ..obs.trace import span

BACKENDS = ("auto", "reference", "pallas")

DISTRIBUTIONS = ("gspmd", "shard_map")

_REGISTRY: Dict[Tuple[str, str], Callable] = {}


def resolve_backend(backend: str = "auto") -> str:
    """Resolve a ``PipelineConfig.backend`` value to a concrete backend."""
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; expected one of {BACKENDS}")
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "reference"
    return backend


def resolve_distribution(distribution: str = "gspmd") -> str:
    """Validate a ``PipelineConfig.distribution`` value (DESIGN.md §2.9).

    Unlike the backend axis there is no ``"auto"``: GSPMD is always safe, so
    the explicit-exchange path is strictly opt-in."""
    if distribution not in DISTRIBUTIONS:
        raise ValueError(
            f"unknown distribution {distribution!r}; "
            f"expected one of {DISTRIBUTIONS}"
        )
    return distribution


def resolve_interpret(interpret: bool | str = "auto") -> bool:
    """Resolve a kernel's ``interpret`` flag: ``"auto"`` means compiled on
    TPU, interpret mode everywhere else."""
    if interpret == "auto":
        return jax.default_backend() != "tpu"
    return bool(interpret)


def register_op(op: str, backend: str, fn: Callable) -> Callable:
    """Register ``fn`` as the ``backend`` implementation of ``op``.

    Called by the kernels layer at import time; re-registration overwrites
    (latest wins) so tests can inject instrumented implementations."""
    if backend not in BACKENDS or backend == "auto":
        raise ValueError(f"backend must be 'reference' or 'pallas', got {backend!r}")
    _REGISTRY[(op, backend)] = fn
    return fn


def available_backends(op: str) -> Tuple[str, ...]:
    """Concrete backends registered for ``op`` (sorted; empty if unknown)."""
    _ensure_registered()
    return tuple(sorted(b for (o, b) in _REGISTRY if o == op))


def _ensure_registered() -> None:
    # Default implementations live in repro.kernels (xdrop_extend,
    # minplus_dense) and repro.assembly.contig_gen (contig_gen); importing
    # them triggers their register_op calls.  Lazy so core stays import-light
    # and the core → kernels/assembly → core cycle stays broken.
    from .. import kernels  # noqa: F401
    from ..assembly import contig_gen  # noqa: F401


def dispatch(op: str, backend: str = "auto") -> Callable:
    """Return the implementation of ``op`` for ``backend`` (resolving
    ``"auto"`` by platform).

    The returned callable is the registered implementation wrapped in an
    ``obs.span`` (name ``"op:<op>"``, kind ``"op"``) — the single place
    every dispatched call gets its launch span, so pipeline traces nest
    stage → shard_map phase → op without per-op wiring.  Inside a jit trace
    the span fires at trace time, which is where the nesting lives."""
    b = resolve_backend(backend)
    key = (op, b)
    if key not in _REGISTRY:
        _ensure_registered()
    if key not in _REGISTRY:
        known = sorted({o for (o, _) in _REGISTRY})
        raise KeyError(f"no {b!r} implementation registered for op {op!r}; "
                       f"known ops: {known}")
    fn = _REGISTRY[key]

    @functools.wraps(fn)
    def dispatched(*args, **kwargs):
        with span(f"op:{op}", kind="op", op=op, backend=b):
            return fn(*args, **kwargs)

    return dispatched
