"""LR schedules."""

from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(peak: float, warmup_steps: int, total_steps: int,
                    floor_frac: float = 0.1):
    def lr(step):
        s = jnp.asarray(step, jnp.float32)
        warm = peak * s / jnp.maximum(1.0, warmup_steps)
        prog = jnp.clip(
            (s - warmup_steps) / jnp.maximum(1.0, total_steps - warmup_steps),
            0.0, 1.0,
        )
        cos = peak * (floor_frac + (1 - floor_frac) * 0.5 *
                      (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(s < warmup_steps, warm, cos)

    return lr
