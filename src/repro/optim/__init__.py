from .adamw import AdamW, OptState  # noqa: F401
from .schedule import cosine_schedule  # noqa: F401
