"""AdamW with decoupled weight decay, global-norm clipping and fp32 master
moments (pure JAX; no external deps)."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    learning_rate: Callable[[jnp.ndarray], jnp.ndarray] | float = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float | None = 1.0

    def init(self, params) -> OptState:
        z = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return OptState(mu=jax.tree.map(z, params), nu=jax.tree.map(z, params))

    def _lr(self, step):
        if callable(self.learning_rate):
            return self.learning_rate(step)
        return jnp.asarray(self.learning_rate, jnp.float32)

    def update(self, grads, state: OptState, params, step):
        """Returns (updates, new_state); apply as p + u."""
        g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if self.clip_norm is not None:
            gn = jnp.sqrt(
                sum(jnp.sum(g * g) for g in jax.tree.leaves(g32))
            )
            scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gn, 1e-12))
            g32 = jax.tree.map(lambda g: g * scale, g32)
        t = step.astype(jnp.float32) + 1.0
        mu = jax.tree.map(lambda m, g: self.b1 * m + (1 - self.b1) * g,
                          state.mu, g32)
        nu = jax.tree.map(lambda v, g: self.b2 * v + (1 - self.b2) * g * g,
                          state.nu, g32)
        bc1 = 1.0 - self.b1 ** t
        bc2 = 1.0 - self.b2 ** t
        lr = self._lr(step)

        def upd(p, m, v):
            mhat = m / bc1
            vhat = v / bc2
            u = -lr * (mhat / (jnp.sqrt(vhat) + self.eps)
                       + self.weight_decay * p.astype(jnp.float32))
            return u.astype(p.dtype)

        updates = jax.tree.map(upd, params, mu, nu)
        return updates, OptState(mu=mu, nu=nu)
