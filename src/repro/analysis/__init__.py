"""AST-based static-analysis suite for the repo's JAX/Pallas contracts.

The pipeline's correctness rests on conventions the compiler cannot check:
every ``ppermute`` must flow into the exchange accounting that
``bench_comm_model`` cross-checks, every emitted metric must be registered
in ``obs/schema.py``, jitted shard_map programs must be built once, and
Pallas kernels must not capture module-level ``jnp`` constants.  Each rule
here encodes one of those contracts as a stdlib-``ast`` pass distilled from
a real bug in this repo's history (docs/static-analysis.md has the
catalog); the suite is the third CI gate beside the comm-model and trace
checkers::

    python -m repro.analysis check [paths...] [--rule R001] \
        [--baseline analysis_baseline.json] [--json findings.json]

Intentional exceptions carry an inline ``# repro: noqa[RULE]`` with a
justification; justified legacy findings ride in the committed baseline.
No third-party imports anywhere in this package: it runs in the
dependency-free CI docs job.
"""

from .engine import (
    Finding,
    RunResult,
    load_baseline,
    load_rules,
    run,
    write_baseline,
)

__all__ = [
    "Finding",
    "RunResult",
    "load_baseline",
    "load_rules",
    "run",
    "write_baseline",
]
