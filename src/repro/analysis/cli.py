"""Command-line front end: ``python -m repro.analysis check``.

Exit status 0 when every finding is suppressed (inline noqa) or baselined;
1 when live findings remain; 2 on usage errors.  ``--json`` writes the
machine-readable findings artifact CI uploads; ``--write-baseline``
regenerates the committed baseline from the current tree (run it after
justifying, not instead of fixing).
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from . import engine
from .rules import ALL_RULES
from .rules.d002_doc_links import DEFAULT_DOC_ROOTS

#: directories walked when ``check`` is given no paths: the code surface
#: the CI gate covers plus the docs surface D002 needs.
DEFAULT_PATHS = ["src", "benchmarks", "scripts"] + DEFAULT_DOC_ROOTS


def _build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description=__doc__.splitlines()[0],
    )
    sub = ap.add_subparsers(dest="command", required=True)
    chk = sub.add_parser("check", help="run the rules and report findings")
    chk.add_argument("paths", nargs="*", default=None,
                     help="files/dirs to check (default: src benchmarks "
                          "scripts + docs surface)")
    chk.add_argument("--rule", action="append", dest="rules", metavar="ID",
                     help="run only this rule id (repeatable)")
    chk.add_argument("--baseline", type=Path, default=None,
                     help="committed baseline JSON; matching findings do "
                          "not fail the run")
    chk.add_argument("--json", type=Path, default=None, dest="json_out",
                     help="write the findings artifact to this path")
    chk.add_argument("--write-baseline", type=Path, default=None,
                     help="write the current findings as a new baseline "
                          "and exit 0")
    chk.add_argument("--list-rules", action="store_true",
                     help="print the rule catalog and exit")
    return ap


def main(argv=None) -> int:
    """Entry point; returns the process exit status."""
    args = _build_parser().parse_args(argv)
    if args.list_rules:
        for mod in ALL_RULES:
            print(f"{mod.RULE_ID}  {mod.TITLE}")
        return 0

    paths = args.paths or [str(engine.REPO / p) for p in DEFAULT_PATHS
                           if (engine.REPO / p).exists()]
    try:
        result = engine.run(
            paths, rules=args.rules, baseline=args.baseline,
        )
    except (ValueError, FileNotFoundError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    if args.json_out:
        args.json_out.write_text(json.dumps({
            "version": engine.BASELINE_VERSION,
            "rules": list(result.rules),
            "files": result.files,
            "suppressed": result.suppressed,
            "baselined": result.baselined,
            "findings": [f.to_json() for f in result.findings],
        }, indent=2) + "\n")

    if args.write_baseline:
        engine.write_baseline(args.write_baseline, result.findings)
        print(f"wrote {len(result.findings)} finding(s) to baseline "
              f"{args.write_baseline}")
        return 0

    for f in result.findings:
        print(f.render())
        if f.hint:
            print(f"    hint: {f.hint}")
    tail = (f"{result.files} files, {len(result.rules)} rules, "
            f"{result.suppressed} noqa-suppressed, "
            f"{result.baselined} baselined")
    if result.findings:
        print(f"{len(result.findings)} finding(s) ({tail})",
              file=sys.stderr)
        return 1
    print(f"analysis clean ({tail})")
    return 0
