"""R006 host-sync-in-span: device→host synchronization inside a phase span.

The hazard: ``obs.trace.span`` measures a shard_map phase by wall-clock,
syncing **once** on exit through its own path (``Span.set_output`` →
``obs.trace.sync``).  A stray ``.block_until_ready()`` / ``np.asarray`` /
``float(...)`` on a device value *inside* the span body forces an extra
blocking round-trip mid-phase: the span stops measuring the async schedule
(the compute/exchange overlap the ring SUMMA exists for), the watermark
attribution shifts, and on a real TPU the dispatch pipeline drains — a
perf bug that looks like "the phase got slower" with no code to blame.

Scope: the body of every ``with span(..., kind="phase")`` block.  Flagged:
``.block_until_ready()``, ``jax.device_get`` / ``device_get``,
``np.asarray`` / ``np.array`` / ``jnp.asarray``-of-device-values idioms,
and ``float(...)`` on a non-literal (the implicit-sync cast).  The
tracer's own sync path — ``sp.set_output(...)`` and ``obs.trace.sync`` —
is exactly the sanctioned exception and is never flagged.
"""

from __future__ import annotations

import ast

from ..engine import Finding
from ._ast_util import call_name, terminal, walk_calls

RULE_ID = "R006"
TITLE = "host sync inside a traced phase span"
SUFFIXES = (".py",)
HINT = ("move the host read outside the span (or hand the value to "
        "sp.set_output(...), the span's own sync-on-exit path)")

_SYNC_ATTRS = {"block_until_ready"}
_SYNC_CALLS = {"device_get", "asarray", "array"}
_SYNC_CALL_ROOTS = ("jax.", "np.", "numpy.")


def _phase_span_withs(ctx):
    """Every ``with span(..., kind="phase")`` node in the file."""
    for node in ast.walk(ctx.tree):
        if not isinstance(node, (ast.With, ast.AsyncWith)):
            continue
        for item in node.items:
            call = item.context_expr
            if not isinstance(call, ast.Call) \
                    or terminal(call_name(call)) != "span":
                continue
            for kw in call.keywords:
                if kw.arg == "kind" and isinstance(kw.value, ast.Constant) \
                        and kw.value.value == "phase":
                    yield node
                    break


def _hazard(node: ast.AST):
    if not isinstance(node, ast.Call):
        return None
    name = call_name(node)
    if isinstance(node.func, ast.Attribute) \
            and node.func.attr in _SYNC_ATTRS:
        return f".{node.func.attr}() forces a device sync"
    if name and terminal(name) in _SYNC_CALLS:
        if name in _SYNC_CALLS or name.startswith(_SYNC_CALL_ROOTS):
            return f"{name}(...) pulls the value to host"
    if name == "float" and node.args \
            and not isinstance(node.args[0], ast.Constant):
        return "float(...) implicitly syncs a device scalar"
    return None


def check(ctx, project):
    """Yield a finding per host-sync call inside a phase-span body."""
    if ctx.tree is None:
        return
    seen = set()
    for w in _phase_span_withs(ctx):
        for stmt in w.body:
            for node in ast.walk(stmt):
                what = _hazard(node)
                if what is None or id(node) in seen:
                    continue
                seen.add(id(node))
                qual = ctx.qualname(node)
                yield Finding(
                    path=ctx.rel, line=node.lineno, rule=RULE_ID,
                    message=(f"{what} inside a kind='phase' span — the "
                             "span stops measuring the async schedule"),
                    hint=HINT, context=qual,
                )
