"""R002 captured-device-constant: module-level ``jnp`` scalars in kernels.

The bug this rule encodes (fixed in PR 6): ``core/spmat.py``-style
module-level constants (``NO_COL = jnp.int32(-1)``, ``_NOPOS``, the merge
``big``) were referenced from inside Pallas kernel bodies.  A module-level
``jnp.*`` value is a **concrete device array**; captured by a
``pallas_call`` kernel it becomes a constant the Mosaic lowering either
rejects outright or silently materializes per-launch.  The fix is a plain
Python/NumPy literal (``_BIG = 2**30`` in ``kernels/cc/cc.py``, ``np.int32``
literals in ``core/spmat.py``).

Scope: files under a ``kernels/`` package.  A *kernel body* is any function
passed to ``pl.pallas_call`` (directly or through ``functools.partial``),
plus any function named ``*_kernel`` (the repo's naming convention).
Flagged: a load of a module-level name whose initializer contains a
``jnp.*`` expression, from inside such a body.
"""

from __future__ import annotations

import ast

from ..engine import Finding
from ._ast_util import call_name, dotted, references_name, terminal, \
    walk_calls

RULE_ID = "R002"
TITLE = "Pallas kernel captures a module-level jnp constant"
SUFFIXES = (".py",)
HINT = ("use a plain Python/numpy literal inside the kernel "
        "(kernels/cc/cc.py's `_BIG = 2**30` pattern); jnp module constants "
        "are device arrays the Mosaic lowering cannot capture")


def _jnp_rooted(tree: ast.AST) -> bool:
    """Whether any ``jnp.*`` / ``jax.numpy.*`` attribute occurs in ``tree``."""
    for node in ast.walk(tree):
        if isinstance(node, ast.Attribute):
            name = dotted(node)
            if name and (name.startswith("jnp.")
                         or name.startswith("jax.numpy.")):
                return True
    return False


def _module_jnp_constants(tree: ast.Module) -> dict:
    """Module-level ``NAME = <expr containing jnp.*>`` assignments."""
    out = {}
    for stmt in tree.body:
        targets = []
        if isinstance(stmt, ast.Assign):
            targets, value = stmt.targets, stmt.value
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            targets, value = [stmt.target], stmt.value
        else:
            continue
        if not _jnp_rooted(value):
            continue
        for t in targets:
            if isinstance(t, ast.Name):
                out[t.id] = stmt.lineno
    return out


def _kernel_functions(ctx) -> dict:
    """name -> FunctionDef for every Pallas kernel body in the file."""
    fns = {
        node.name: node
        for node in ast.walk(ctx.tree)
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
    }
    kernels = {name: fn for name, fn in fns.items()
               if name.endswith("_kernel")}
    for call in walk_calls(ctx.tree):
        if terminal(call_name(call)) != "pallas_call" or not call.args:
            continue
        target = call.args[0]
        # unwrap functools.partial(kernel_fn, ...)
        if isinstance(target, ast.Call) \
                and terminal(call_name(target)) == "partial" and target.args:
            target = target.args[0]
        name = dotted(target)
        if name and terminal(name) in fns:
            kernels[terminal(name)] = fns[terminal(name)]
    return kernels


def check(ctx, project):
    """Yield a finding per jnp-constant load inside a kernel body."""
    if ctx.tree is None or "kernels" not in ctx.rel.split("/"):
        return
    constants = _module_jnp_constants(ctx.tree)
    if not constants:
        return
    for kname, fn in _kernel_functions(ctx).items():
        for ref in references_name(fn, constants):
            yield Finding(
                path=ctx.rel, line=ref.lineno, rule=RULE_ID,
                message=(f"Pallas kernel {kname}() captures module-level "
                         f"jnp constant {ref.id!r} — the PR 6 pallas_call "
                         "captured-constant bug"),
                hint=HINT, context=kname,
            )
