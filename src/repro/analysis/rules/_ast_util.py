"""Small shared AST helpers for the rule modules (stdlib ``ast`` only)."""

from __future__ import annotations

import ast
from typing import Iterator, Optional, Tuple


def dotted(node: ast.AST) -> Optional[str]:
    """The dotted name of a ``Name``/``Attribute`` chain (``"jax.lax.psum"``),
    or None when the chain roots in something else (a call, a subscript)."""
    parts = []
    cur = node
    while isinstance(cur, ast.Attribute):
        parts.append(cur.attr)
        cur = cur.value
    if isinstance(cur, ast.Name):
        parts.append(cur.id)
        return ".".join(reversed(parts))
    return None


def call_name(node: ast.Call) -> Optional[str]:
    """Dotted name of a call's callee (None for computed callees)."""
    return dotted(node.func)


def terminal(name: Optional[str]) -> str:
    """Last component of a dotted name (``"jax.lax.psum"`` → ``"psum"``)."""
    return name.rsplit(".", 1)[-1] if name else ""


def walk_calls(tree: ast.AST) -> Iterator[ast.Call]:
    """Every ``Call`` node under ``tree``."""
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            yield node


def decorator_names(fn: ast.AST) -> Tuple[str, ...]:
    """Terminal names of a function's decorators, unwrapping decorator
    factories (``@lru_cache(maxsize=None)`` → ``"lru_cache"``) and
    ``functools.partial(jax.jit, ...)`` (→ ``"jit"``)."""
    out = []
    for dec in getattr(fn, "decorator_list", ()):
        target = dec
        if isinstance(target, ast.Call):
            callee = terminal(dotted(target.func))
            if callee == "partial" and target.args:
                target = target.args[0]
            else:
                target = target.func
        name = dotted(target)
        if name:
            out.append(terminal(name))
    return tuple(out)


def references_name(tree: ast.AST, names) -> Iterator[ast.Name]:
    """Every ``Name`` load of one of ``names`` under ``tree``."""
    names = set(names)
    for node in ast.walk(tree):
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load) \
                and node.id in names:
            yield node


def str_const(node: ast.AST) -> Optional[str]:
    """The value of a string constant node, else None."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None
