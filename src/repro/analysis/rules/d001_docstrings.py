"""D001 public-API docstrings: the pydocstyle-subset lint as a rule.

Folded in from ``scripts/lint_docstrings.py`` (PR 4), which remains a thin
shim over this module so existing CI invocations and ``tests/test_docs.py``
keep passing.  Codes (kept in the message for continuity):

  D100  module must have a docstring
  D101  public class must have a docstring
  D102  public method must have a docstring
  D103  public function must have a docstring

"Public" = name without a leading underscore, at module or class top
level; nested defs are implementation detail and not walked.

Scope: the curated :data:`TARGETS` list — the public-API modules whose
docstrings carry documented contracts — when walking directories; any
Python file passed to the CLI *explicitly* is always checked, which is how
the shim and the fixtures drive it.
"""

from __future__ import annotations

import ast

from ..engine import Finding

RULE_ID = "D001"
TITLE = "missing public-API docstring (pydocstyle subset)"
SUFFIXES = (".py",)
HINT = "add a docstring stating the contract (see docs/static-analysis.md)"

#: the modules whose public APIs carry the documented contracts (grown
#: PR-by-PR; PR 10 adds the static-analysis suite itself — its engine,
#: contracts and rule surfaces are the contract docs/static-analysis.md
#: documents).
TARGETS = [
    "src/repro/core/align_dist.py",
    "src/repro/core/components.py",
    "src/repro/core/components_dist.py",
    "src/repro/core/backend.py",
    "src/repro/core/summa.py",
    "src/repro/core/transitive_reduction.py",
    "src/repro/assembly/contig_gen.py",
    "src/repro/kernels/cc/ref.py",
    "src/repro/kernels/cc/cc.py",
    "src/repro/kernels/cc/ops.py",
    "src/repro/kernels/spgemm/ref.py",
    "src/repro/kernels/spgemm/spgemm.py",
    "src/repro/kernels/spgemm/ops.py",
    "src/repro/obs/trace.py",
    "src/repro/obs/metrics.py",
    "src/repro/obs/schema.py",
    "src/repro/obs/export.py",
    "src/repro/obs/memory.py",
    "src/repro/obs/experiments.py",
    "src/repro/analysis/engine.py",
    "src/repro/analysis/cli.py",
    "src/repro/analysis/contracts.py",
    "src/repro/analysis/rules/r001_retrace.py",
    "src/repro/analysis/rules/r002_captured_constant.py",
    "src/repro/analysis/rules/r003_unaccounted_exchange.py",
    "src/repro/analysis/rules/r004_unregistered_metric.py",
    "src/repro/analysis/rules/r005_nondeterminism.py",
    "src/repro/analysis/rules/r006_host_sync.py",
    "src/repro/analysis/rules/d001_docstrings.py",
    "src/repro/analysis/rules/d002_doc_links.py",
    "benchmarks/_timing.py",
    "benchmarks/engine.py",
    "scripts/check_smoke_comm.py",
    "scripts/check_bench_regression.py",
    "scripts/check_trace.py",
    "scripts/lint_docstrings.py",
]


def _has_docstring(node) -> bool:
    doc = ast.get_docstring(node, clean=False)
    return bool(doc and doc.strip())


def lint_tree(tree: ast.Module):
    """Yield ``(lineno, code, message, context)`` violations for one
    parsed module — the old ``lint_file`` body, shared with the shim."""
    if not _has_docstring(tree):
        yield 1, "D100", "missing module docstring", "<module>"

    def walk(node, in_class, prefix):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.ClassDef):
                qual = f"{prefix}{child.name}"
                if not child.name.startswith("_") \
                        and not _has_docstring(child):
                    yield (child.lineno, "D101",
                           f"missing class docstring: {child.name}", qual)
                yield from walk(child, True, qual + ".")
            elif isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if not child.name.startswith("_") \
                        and not _has_docstring(child):
                    code = "D102" if in_class else "D103"
                    kind = "method" if in_class else "function"
                    yield (child.lineno, code,
                           f"missing {kind} docstring: {child.name}",
                           f"{prefix}{child.name}")
                # nested defs are implementation detail: not walked

    yield from walk(tree, False, "")


def check(ctx, project):
    """Yield a finding per missing docstring on an in-scope file."""
    if ctx.tree is None:
        return
    if ctx.rel not in TARGETS and not getattr(ctx, "explicit", False):
        return
    for lineno, code, msg, context in lint_tree(ctx.tree):
        yield Finding(
            path=ctx.rel, line=lineno, rule=RULE_ID,
            message=f"{code} {msg}", hint=HINT, context=context,
        )
