"""D002 markdown links: every relative link target must exist.

Folded in from ``scripts/check_docs_links.py`` (PR 4), which remains a thin
shim over this module.  External ``http(s)://`` links are syntax-checked
only (CI stays hermetic); ``file.md#anchor`` links are checked for the file
part; in-page ``#anchor`` links are skipped.
"""

from __future__ import annotations

import re
from pathlib import Path

from ..engine import Finding

RULE_ID = "D002"
TITLE = "broken relative markdown link"
SUFFIXES = (".md",)
HINT = "fix the target path (links resolve relative to the linking file)"

#: the docs surface walked when the CLI is given no explicit paths.
DEFAULT_DOC_ROOTS = ["README.md", "DESIGN.md", "ROADMAP.md", "docs"]

# [text](target) — excludes images' alt-text brackets by allowing them too
_LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)\)")


def broken_links(text: str, base: Path):
    """Yield ``(lineno, target)`` for every unresolvable relative link."""
    for lineno, line in enumerate(text.splitlines(), start=1):
        for target in _LINK_RE.findall(line):
            if re.match(r"^[a-z][a-z0-9+.-]*:", target):
                continue  # http:, https:, mailto:
            if target.startswith("#"):
                continue  # in-page anchor
            rel = target.split("#", 1)[0]
            if not (base / rel).exists():
                yield lineno, target


def check(ctx, project):
    """Yield a finding per broken relative link in a markdown file."""
    for lineno, target in broken_links(ctx.text, ctx.path.parent):
        yield Finding(
            path=ctx.rel, line=lineno, rule=RULE_ID,
            message=f"broken link -> {target}", hint=HINT,
            context="<module>",
        )
