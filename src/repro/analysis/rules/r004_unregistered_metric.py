"""R004 unregistered-metric: a stats key absent from the declared schema.

The bug class this rule encodes (PR 5's present-and-zero fix, PR 7's
registry): before ``obs/schema.py`` every emitter invented keys inline, and
a typo'd or unregistered key surfaced only when a downstream consumer (a
benchmark row diff, a test key tuple) happened to touch it — or never, as
with the missing present-and-zero exchange stats on the gspmd path.  The
``Metrics`` accumulator now validates at *run* time; this rule validates at
*read* time, so an emission site that no test executes (an error path, a
fallback branch) still cannot introduce an undeclared key.

Checked sites: ``*.emit("key", ...)``, ``*.emit_many({...})`` and
``validated({...})`` dict-literal keys against the registry, and
``seed_zero`` / ``zero_defaults`` / ``group_keys`` string arguments against
the declared zero groups.  The registry is **parsed** from
``obs/schema.py`` (the ``_SPECS`` tuple), never imported — the analyzer
runs where jax is not installed.
"""

from __future__ import annotations

import ast
from pathlib import Path

from ..engine import Finding
from ._ast_util import call_name, str_const, terminal, walk_calls

RULE_ID = "R004"
TITLE = "stats key or zero-group not registered in obs/schema.py"
SUFFIXES = (".py",)
HINT = ("register the key in src/repro/obs/schema.py's _SPECS (kind + unit "
        "+ description, and its present-and-zero group if it is an "
        "exchange counter)")

#: registry source, relative to the repo root.
SCHEMA_PATH = "src/repro/obs/schema.py"

_SPEC_BUILDERS = {"_c", "_g", "_l", "MetricSpec"}
_KEY_SITES = {"emit"}
_DICT_SITES = {"emit_many", "validated"}
_GROUP_SITES = {"seed_zero", "zero_defaults", "group_keys"}


def load_registry(repo: Path):
    """Parse ``(metric names, zero groups)`` out of the schema source."""
    tree = ast.parse((repo / SCHEMA_PATH).read_text(), filename=SCHEMA_PATH)
    names, groups = set(), set()
    for call in walk_calls(tree):
        callee = terminal(call_name(call))
        if callee not in _SPEC_BUILDERS or not call.args:
            continue
        name = str_const(call.args[0])
        if name is None:
            continue
        names.add(name)
        group = None
        if callee == "_c" and len(call.args) >= 4:
            group = str_const(call.args[3])
        elif callee == "MetricSpec" and len(call.args) >= 5:
            group = str_const(call.args[4])
        for kw in call.keywords:
            if kw.arg == "zero_group":
                group = str_const(kw.value)
        if group:
            groups.add(group)
    if not names:
        raise ValueError(f"{SCHEMA_PATH}: no metric specs parsed — did the "
                         "_SPECS registry move?")
    return frozenset(names), frozenset(groups)


def _registry(project):
    return project.cache(
        "metric_registry", lambda: load_registry(project.repo)
    )


def check(ctx, project):
    """Yield a finding per unregistered key/group at an emission site."""
    if ctx.tree is None or ctx.rel == SCHEMA_PATH:
        return
    names, groups = _registry(project)
    for call in walk_calls(ctx.tree):
        callee = terminal(call_name(call))
        if callee in _KEY_SITES and call.args:
            key = str_const(call.args[0])
            if key is not None and key not in names:
                yield _finding(ctx, call, f"stats key {key!r} is not "
                               "registered in obs/schema.py")
        elif callee in _DICT_SITES and call.args \
                and isinstance(call.args[0], ast.Dict):
            for k in call.args[0].keys:
                key = str_const(k) if k is not None else None
                if key is not None and key not in names:
                    yield _finding(ctx, call, f"stats key {key!r} (in "
                                   f"{callee}) is not registered in "
                                   "obs/schema.py")
        elif callee in _GROUP_SITES and call.args:
            grp = str_const(call.args[0])
            if grp is not None and grp not in groups:
                yield _finding(ctx, call, f"zero-group {grp!r} (in "
                               f"{callee}) is not a declared "
                               "present-and-zero group")


def _finding(ctx, call, message):
    qual = ctx.qualname(call)
    return Finding(path=ctx.rel, line=call.lineno, rule=RULE_ID,
                   message=message, hint=HINT, context=qual)
