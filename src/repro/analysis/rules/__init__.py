"""Rule registry: every shipped rule module, in id order.

Explicit imports (not filesystem discovery) keep the set deterministic and
the docs honest: a rule exists iff it is listed here, and
``python -m repro.analysis check --list-rules`` prints exactly this table.
"""

from . import (
    r001_retrace,
    r002_captured_constant,
    r003_unaccounted_exchange,
    r004_unregistered_metric,
    r005_nondeterminism,
    r006_host_sync,
    d001_docstrings,
    d002_doc_links,
)

#: the shipped rules, in the order findings cite them.
ALL_RULES = (
    r001_retrace,
    r002_captured_constant,
    r003_unaccounted_exchange,
    r004_unregistered_metric,
    r005_nondeterminism,
    r006_host_sync,
    d001_docstrings,
    d002_doc_links,
)
