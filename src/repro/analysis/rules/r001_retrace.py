"""R001 retrace-hazard: per-call construction of a jitted/shard_map program.

The bug this rule encodes (fixed in PR 7): ``core/summa.py`` built
``jax.jit(shard_map(f))`` inside ``summa_ring`` on **every call**, so every
overlap SpGEMM — and every pass of the ring transitive reduction driving it
— re-traced and re-compiled the whole ring (~14 s/call in the committed
``BENCH_6.json`` row).  A freshly-constructed callable (a closure defined in
the function, or a new ``shard_map`` wrapper) has a new identity, so
``jax.jit``'s cache can never hit.

Flagged: a ``jax.jit(...)`` / ``shard_map(...)`` call inside a function
body, unless an enclosing function is memoized (``functools.lru_cache`` /
``cache`` decorator — the ``_ring_program`` pattern) or is a one-shot
builder by naming convention (``make_*`` / ``build_*``), or the program is
immediately AOT-lowered (``jax.jit(f).lower(...)`` — the dry-run path pays
compilation on purpose).  Module-level construction is always fine, a
``jit(shard_map(f))`` composite is reported once at the outer call, and a
``shard_map(...)(args)`` invoked in the same expression is exempt: under
the enclosing jitted step it is consumed at trace time (the model-layer
idiom), so no per-call cache identity exists to miss.
"""

from __future__ import annotations

import ast

from ..engine import Finding
from ._ast_util import call_name, decorator_names, terminal, walk_calls

RULE_ID = "R001"
TITLE = "jit/shard_map program constructed per call (retrace hazard)"
HINT = ("cache the built callable: move construction to module level or an "
        "@functools.lru_cache program builder (core/summa._ring_program "
        "pattern)")
SUFFIXES = (".py",)

_PROGRAM_BUILDERS = {"jit", "shard_map", "pjit"}
_CACHED_DECORATORS = {"lru_cache", "cache"}
_BUILDER_PREFIXES = ("make_", "build_", "_make_", "_build_")


def _is_aot_lowered(ctx, call: ast.Call) -> bool:
    """``jax.jit(...)`` whose result is immediately ``.lower()``ed."""
    parent = ctx.parents.get(id(call))
    return isinstance(parent, ast.Attribute) and parent.attr == "lower"


def _is_builder_argument(ctx, call: ast.Call) -> bool:
    """Inner half of ``jax.jit(shard_map(f))``: report the composite once,
    at the outermost builder call."""
    parent = ctx.parents.get(id(call))
    return (isinstance(parent, ast.Call)
            and terminal(call_name(parent)) in _PROGRAM_BUILDERS)


def _is_invoked_shard_map(ctx, call: ast.Call, name: str) -> bool:
    """``shard_map(f, ...)(args)`` invoked in the same expression.

    Inside a function that is itself traced by an outer ``jax.jit`` (the
    model forward / serve step), the wrapper is consumed at trace time and
    becomes part of the enclosing program — construction identity never
    reaches a jit cache.  ``jit(...)(args)`` gets no such pass: an
    immediately-invoked jit re-traces eagerly on every call.
    """
    if name == "jit":
        return False
    parent = ctx.parents.get(id(call))
    return isinstance(parent, ast.Call) and parent.func is call


def check(ctx, project):
    """Yield a finding per uncached program construction in ``ctx``."""
    if ctx.tree is None:
        return
    for call in walk_calls(ctx.tree):
        name = terminal(call_name(call))
        if name not in _PROGRAM_BUILDERS:
            continue
        chain = ctx.enclosing_functions(call)
        if not chain:
            continue  # module level: constructed once at import
        if any(set(decorator_names(fn)) & _CACHED_DECORATORS
               for fn in chain):
            continue  # memoized program builder
        if any(fn.name.startswith(_BUILDER_PREFIXES) for fn in chain):
            continue  # one-shot builder by convention: caller caches
        if _is_aot_lowered(ctx, call):
            continue  # AOT lowering pays compilation deliberately
        if _is_builder_argument(ctx, call):
            continue  # jit(shard_map(f)): reported once at the outer call
        if _is_invoked_shard_map(ctx, call, name):
            continue  # shard_map(...)(x): traced into the enclosing program
        qual = ctx.qualname(call)
        yield Finding(
            path=ctx.rel, line=call.lineno, rule=RULE_ID,
            message=(f"{name}(...) program constructed inside {qual}(): "
                     "every call re-traces and re-compiles (the PR 7 "
                     "summa_ring retrace bug)"),
            hint=HINT, context=qual,
        )
