"""R005 nondeterminism: host-side entropy inside a traced program builder.

The hazard: code inside a function handed to ``jax.jit`` / ``shard_map``
runs at *trace* time.  A ``time.time()`` / ``random.*`` call there bakes
one arbitrary host value into the compiled program — every later cached
call silently reuses it — and worse, it changes per re-trace, so two runs
of "the same" program differ and the seeded-determinism contract of
``tests/test_align_dist.py`` (assemble() byte-identical across runs) breaks
in ways that are invisible at the call site.  Iterating a ``set`` at trace
time is the same bug through ordering: the trace order (and therefore the
schedule and any order-dependent ⊕) varies per process hash seed.

Scope: functions that are traced — decorated with ``jit``/``shard_map``
(including ``functools.partial(jax.jit, ...)``), or passed by name to a
``jit``/``shard_map`` call anywhere in the same file — and every function
nested inside them.  Flagged inside: ``time.*`` clock calls, ``random.*`` /
``np.random.*`` draws, ``uuid`` / ``os.urandom``, and ``for``-iteration
over a ``set`` literal or ``set()`` call (wrap in ``sorted(...)`` to fix).
"""

from __future__ import annotations

import ast

from ..engine import Finding
from ._ast_util import call_name, decorator_names, dotted, terminal, \
    walk_calls

RULE_ID = "R005"
TITLE = "nondeterministic host call inside a traced program"
SUFFIXES = (".py",)
HINT = ("hoist the value out of the traced function and pass it as an "
        "argument (or a builder-cache key); iterate sorted(...) instead of "
        "a raw set")

_TRACERS = {"jit", "pjit", "shard_map"}

_CLOCKS = {"time.time", "time.monotonic", "time.perf_counter",
           "time.process_time", "time.time_ns", "time.perf_counter_ns"}
_ENTROPY_PREFIXES = ("random.", "np.random.", "numpy.random.", "uuid.")
_ENTROPY_CALLS = {"os.urandom", "datetime.now", "datetime.utcnow"}


def _traced_functions(ctx):
    """Innermost set of FunctionDefs that are traced (see module docstring);
    nested defs inherit tracedness from any ancestor."""
    fns = [n for n in ast.walk(ctx.tree)
           if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
    traced_names = set()
    for call in walk_calls(ctx.tree):
        if terminal(call_name(call)) in _TRACERS and call.args:
            name = dotted(call.args[0])
            if name:
                traced_names.add(terminal(name))
    traced = set()
    for fn in fns:
        if fn.name in traced_names \
                or set(decorator_names(fn)) & _TRACERS:
            traced.add(id(fn))
    # close over nesting: a def inside a traced def is traced
    for fn in fns:
        if any(id(anc) in traced for anc in ctx.enclosing_functions(fn)):
            traced.add(id(fn))
    return traced


def _hazard(node: ast.AST):
    """A (line, description) when ``node`` is a nondeterminism hazard."""
    if isinstance(node, ast.Call):
        name = call_name(node)
        if not name:
            return None
        if name in _CLOCKS or name in _ENTROPY_CALLS:
            return node.lineno, f"{name}() call"
        if name.startswith(_ENTROPY_PREFIXES):
            return node.lineno, f"{name}() call"
    if isinstance(node, ast.For):
        it = node.iter
        if isinstance(it, ast.Set):
            return node.lineno, "iteration over a set literal"
        if isinstance(it, ast.Call) and terminal(call_name(it)) == "set":
            return node.lineno, "iteration over set(...)"
    return None


def check(ctx, project):
    """Yield a finding per hazard inside a traced function."""
    if ctx.tree is None:
        return
    traced = _traced_functions(ctx)
    if not traced:
        return
    seen = set()
    for fn in ast.walk(ctx.tree):
        if id(fn) not in traced:
            continue
        for node in ast.walk(fn):
            hit = _hazard(node)
            if hit is None or id(node) in seen:
                continue
            seen.add(id(node))
            line, what = hit
            qual = ctx.qualname(node)
            yield Finding(
                path=ctx.rel, line=line, rule=RULE_ID,
                message=(f"{what} inside traced function {fn.name}(): the "
                         "value/order is baked in at trace time and varies "
                         "per re-trace"),
                hint=HINT, context=qual,
            )
