"""R003 unaccounted-exchange: a collective outside the accounting contract.

The hazard this rule encodes (caught by review in PR 5): the repo's
communication claims rest on *measured* exchange volumes —
``exchange_words_*`` stats counted next to every ``ppermute`` (the ``acct``
dict of ``core/summa._ring_program`` / ``core/align_dist._align_program``)
or derived from the data-independent schedule by the analytic
``exchange_words_*`` helpers of ``core/components_dist`` — and CI
cross-checks them against ``bench_comm_model`` (the paper's Table I).  A
``lax.ppermute`` added to an explicit-exchange module without touching the
accounting silently breaks that contract: the model check still passes
(both sides miss the new words) and hours of cluster time go unexplained.

Scope: ``core/*_dist.py`` and ``core/summa.py`` (the explicit-exchange
modules; the vocabulary lives in ``analysis.contracts``).  For each
innermost function containing a ``jax.lax`` collective, the rule requires
*somewhere in its enclosing function chain* either an ``acct[...]``-style
accumulator increment or a call to an analytic ``exchange_words_*`` /
``words_*`` model helper.  One finding per unaccounted function, anchored
at its first collective call.
"""

from __future__ import annotations

import ast

from .. import contracts
from ..engine import Finding
from ._ast_util import call_name, dotted, terminal, walk_calls

RULE_ID = "R003"
TITLE = "collective call without exchange accounting"
SUFFIXES = (".py",)
HINT = ("count the exchange: increment the program's acct dict next to the "
        "collective (summa._ring_program pattern) or extend the analytic "
        "exchange_words_* model feeding the stats, so "
        "bench_comm_model/check_smoke_comm keep cross-checking every word")


def _in_scope(rel: str) -> bool:
    parts = rel.split("/")
    if "core" not in parts:
        return False
    name = parts[-1]
    return name.endswith("_dist.py") or name == "summa.py"


def _is_collective(call: ast.Call) -> bool:
    name = call_name(call)
    if not name or terminal(name) not in contracts.COLLECTIVE_OPS:
        return False
    # require a lax-rooted callee (jax.lax.psum / lax.ppermute), so local
    # helpers that happen to share a name stay out of scope
    return ".lax." in f".{name}." or name.startswith("lax.")


def _accounts(tree: ast.AST) -> bool:
    """Whether ``tree`` contains an exchange-accounting construct."""
    for node in ast.walk(tree):
        if isinstance(node, ast.AugAssign):
            target = node.target
            if isinstance(target, ast.Subscript):
                base = dotted(target.value)
                if base and terminal(base) in \
                        contracts.ACCOUNTING_ACCUMULATORS:
                    return True
            if isinstance(target, ast.Name) and (
                    "words" in target.id or "rounds" in target.id):
                return True
        elif isinstance(node, ast.Call):
            callee = terminal(call_name(node))
            if callee.startswith(contracts.ACCOUNTING_CALL_PREFIXES):
                return True
    return False


def check(ctx, project):
    """Yield one finding per function with unaccounted collectives."""
    if ctx.tree is None or not _in_scope(ctx.rel):
        return
    by_fn = {}
    for call in walk_calls(ctx.tree):
        if not _is_collective(call):
            continue
        chain = ctx.enclosing_functions(call)
        if not chain:
            continue  # module-level collective: not a traced program
        fn = chain[0]
        by_fn.setdefault(id(fn), (fn, chain, []))[2].append(call)
    for fn, chain, calls in by_fn.values():
        if any(_accounts(f) for f in chain):
            continue
        first = min(calls, key=lambda c: c.lineno)
        ops = sorted({terminal(call_name(c)) for c in calls})
        qual = ctx.qualname(first)
        yield Finding(
            path=ctx.rel, line=first.lineno, rule=RULE_ID,
            message=(f"{qual}() issues {', '.join(ops)} with no exchange "
                     "accounting in its enclosing scope — the words move "
                     "but exchange_words_* never sees them"),
            hint=HINT, context=qual,
        )
