"""Rule engine of the repro static-analysis suite (docs/static-analysis.md).

A *rule* is a module under ``repro.analysis.rules`` exporting

* ``RULE_ID`` — short stable id (``"R001"``, ``"D002"``),
* ``TITLE`` — one-line description shown by ``--list-rules``,
* ``HINT`` — the fix hint appended to every finding,
* ``SUFFIXES`` — file suffixes the rule consumes (``(".py",)`` /
  ``(".md",)``),
* ``check(ctx, project)`` — yields :class:`Finding` objects for one file.

The engine owns everything around the rules: walking the target paths,
parsing each Python file once into a shared :class:`FileContext`, inline
``# repro: noqa[RULE]`` suppressions, the committed JSON baseline that lets
justified legacy findings ride without blocking CI, and the findings model
(repo-relative ``file:line`` + rule id + message + fix hint).

Everything here is stdlib-only (``ast``, ``json``, ``re``): the suite runs
in the dependency-free CI docs job, where ``jax`` is not installed — rules
that need repo metadata (the ``obs/schema.py`` metric registry, say) read
it by parsing source, never by importing it.
"""

from __future__ import annotations

import ast
import dataclasses
import json
import re
from pathlib import Path
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

#: repo root (engine lives at src/repro/analysis/engine.py).
REPO = Path(__file__).resolve().parents[3]

#: directory names never walked for target files.
_SKIP_DIRS = {"__pycache__", ".git", ".bench_cache", ".pytest_cache",
              "node_modules"}

#: inline suppression: ``# repro: noqa[R001]`` / ``# repro: noqa[R001,D002]``
#: on the finding's line, or in the comment-only block directly above it
#: (room for the one-line justification every suppression must carry).
_NOQA_RE = re.compile(r"#\s*repro:\s*noqa\[([A-Za-z0-9,\s]+)\]")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation: where, what, and how to fix it.

    ``context`` is the enclosing symbol (function/class qualname, or
    ``"<module>"``) — it keys the baseline together with ``path``, ``rule``
    and ``message`` so baselined findings survive unrelated line drift."""

    path: str  # repo-relative, "/"-separated
    line: int
    rule: str
    message: str
    hint: str = ""
    context: str = "<module>"

    def key(self) -> Tuple[str, str, str, str]:
        """The line-number-free identity used for baseline matching."""
        return (self.path, self.rule, self.context, self.message)

    def to_json(self) -> Dict[str, Any]:
        """JSON-artifact shape (one dict per finding)."""
        return {
            "path": self.path,
            "line": self.line,
            "rule": self.rule,
            "message": self.message,
            "hint": self.hint,
            "context": self.context,
        }

    def render(self) -> str:
        """``path:line: RULE message`` — the CLI output line."""
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


class FileContext:
    """One target file, parsed once and shared by every rule.

    Lazily exposes the AST (``tree``), a child→parent node map
    (``parents``), and the source lines; Python files that fail to parse
    produce a synthetic ``E999`` finding instead of crashing the run."""

    def __init__(self, path: Path, repo: Path = REPO):
        self.path = path
        self.repo = repo
        self.rel = path.resolve().relative_to(repo).as_posix() \
            if path.resolve().is_relative_to(repo) else path.as_posix()
        self.text = path.read_text()
        self.lines = self.text.splitlines()
        self.suffix = path.suffix
        self._tree: Optional[ast.AST] = None
        self._parents: Optional[Dict[int, ast.AST]] = None
        self.parse_error: Optional[SyntaxError] = None

    @property
    def tree(self) -> Optional[ast.AST]:
        """The parsed module AST (None for non-Python or unparsable files)."""
        if self._tree is None and self.suffix == ".py" \
                and self.parse_error is None:
            try:
                self._tree = ast.parse(self.text, filename=str(self.path))
            except SyntaxError as e:  # pragma: no cover - target repo parses
                self.parse_error = e
        return self._tree

    @property
    def parents(self) -> Dict[int, ast.AST]:
        """Map ``id(child node) -> parent node`` over the whole tree."""
        if self._parents is None:
            self._parents = {}
            if self.tree is not None:
                for node in ast.walk(self.tree):
                    for child in ast.iter_child_nodes(node):
                        self._parents[id(child)] = node
        return self._parents

    def enclosing_functions(self, node: ast.AST) -> List[ast.AST]:
        """Function defs containing ``node``, innermost first."""
        out = []
        cur = self.parents.get(id(node))
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                out.append(cur)
            cur = self.parents.get(id(cur))
        return out

    def qualname(self, node: ast.AST) -> str:
        """Dotted name of the scope holding ``node`` (``"<module>"`` at top
        level) — the baseline ``context`` component."""
        names = []
        cur = self.parents.get(id(node))
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.ClassDef)):
                names.append(cur.name)
            cur = self.parents.get(id(cur))
        return ".".join(reversed(names)) or "<module>"

    def noqa_rules(self, line: int) -> frozenset:
        """Rule ids suppressed at physical ``line`` (1-based).

        Looks at the line itself plus the contiguous comment-only block
        right above it, so justifications too long for a trailing comment
        can ride in a lead comment."""
        rules: set = set()
        idx = line - 1
        if not (0 <= idx < len(self.lines)):
            return frozenset()
        candidates = [self.lines[idx]]
        j = idx - 1
        while j >= 0 and self.lines[j].lstrip().startswith("#"):
            candidates.append(self.lines[j])
            j -= 1
        for text in candidates:
            m = _NOQA_RE.search(text)
            if m:
                rules.update(
                    s.strip().upper() for s in m.group(1).split(",")
                    if s.strip()
                )
        return frozenset(rules)


class Project:
    """Run-wide shared state handed to every rule.

    Carries the repo root plus lazily-built caches rules share — e.g. the
    metric registry AST-parsed from ``obs/schema.py`` (rule R004) — so a
    rule never pays its setup cost per file."""

    def __init__(self, repo: Path = REPO):
        self.repo = repo
        self._caches: Dict[str, Any] = {}

    def cache(self, key: str, build) -> Any:
        """Memoize ``build()`` under ``key`` for the lifetime of the run."""
        if key not in self._caches:
            self._caches[key] = build()
        return self._caches[key]


def load_rules(only: Optional[Sequence[str]] = None) -> List[Any]:
    """The registered rule modules, optionally filtered to ids in ``only``.

    Unknown ids in ``only`` raise — a typo'd ``--rule R01`` must fail, not
    silently check nothing."""
    from .rules import ALL_RULES

    if only is None:
        return list(ALL_RULES)
    wanted = {r.upper() for r in only}
    known = {m.RULE_ID for m in ALL_RULES}
    unknown = wanted - known
    if unknown:
        raise ValueError(
            f"unknown rule id(s) {sorted(unknown)}; known: {sorted(known)}"
        )
    return [m for m in ALL_RULES if m.RULE_ID in wanted]


def walk_targets(paths: Sequence[Path], suffixes: Iterable[str]) -> List[Path]:
    """Expand files/dirs into the sorted target file list.

    Directories are walked recursively for the given suffixes; explicit
    file arguments are kept regardless of suffix filters so one-off checks
    of a single file always see it."""
    suffixes = set(suffixes)
    out: List[Path] = []
    for p in paths:
        if p.is_dir():
            for f in sorted(p.rglob("*")):
                if f.suffix in suffixes and f.is_file() \
                        and not _skipped(f, p):
                    out.append(f)
        elif p.is_file():
            out.append(p)
        else:
            raise FileNotFoundError(f"no such target: {p}")
    seen, uniq = set(), []
    for f in out:
        r = f.resolve()
        if r not in seen:
            seen.add(r)
            uniq.append(f)
    return uniq


def _skipped(f: Path, root: Path) -> bool:
    return any(part in _SKIP_DIRS or part.startswith(".")
               for part in f.relative_to(root).parts[:-1])


@dataclasses.dataclass
class RunResult:
    """Outcome of one engine run: live findings plus suppression tallies."""

    findings: List[Finding]
    suppressed: int = 0  # inline-noqa'd
    baselined: int = 0  # matched the committed baseline
    files: int = 0
    rules: Tuple[str, ...] = ()

    @property
    def ok(self) -> bool:
        """True when no non-baselined, non-suppressed finding remains."""
        return not self.findings


def run(
    paths: Sequence[Path],
    *,
    rules: Optional[Sequence[str]] = None,
    baseline: Optional[Path] = None,
    repo: Path = REPO,
) -> RunResult:
    """Run the suite over ``paths`` and return the :class:`RunResult`.

    Explicit-file arguments are marked on their context (rules like D001
    that scope themselves to a curated target list still check a file the
    user named directly).  Findings on a ``# repro: noqa[RULE]`` line are
    suppressed; findings whose :meth:`Finding.key` appears in ``baseline``
    are counted but not returned."""
    mods = load_rules(rules)
    suffixes = {s for m in mods for s in m.SUFFIXES}
    files = walk_targets([Path(p) for p in paths], suffixes)
    explicit = {Path(p).resolve() for p in paths if Path(p).is_file()}
    project = Project(repo)
    base_keys = load_baseline(baseline) if baseline else frozenset()

    findings: List[Finding] = []
    suppressed = baselined = 0
    for f in files:
        ctx = FileContext(f, repo)
        ctx.explicit = f.resolve() in explicit  # type: ignore[attr-defined]
        for mod in mods:
            if ctx.suffix not in mod.SUFFIXES:
                continue
            for finding in mod.check(ctx, project):
                if finding.rule in ctx.noqa_rules(finding.line):
                    suppressed += 1
                elif finding.key() in base_keys:
                    baselined += 1
                else:
                    findings.append(finding)
    findings.sort(key=lambda x: (x.path, x.line, x.rule))
    return RunResult(
        findings=findings, suppressed=suppressed, baselined=baselined,
        files=len(files), rules=tuple(m.RULE_ID for m in mods),
    )


# ---------------------------------------------------------------------------
# Baseline file: committed JSON of justified legacy findings.
# ---------------------------------------------------------------------------

BASELINE_VERSION = 1


def load_baseline(path: Path) -> frozenset:
    """The set of baselined :meth:`Finding.key` tuples from a baseline file.

    A missing file is an error (CI pointing at a renamed baseline must
    fail loudly); an empty findings list is fine."""
    doc = json.loads(Path(path).read_text())
    if doc.get("version") != BASELINE_VERSION:
        raise ValueError(
            f"{path}: unsupported baseline version {doc.get('version')!r}"
        )
    return frozenset(
        (e["path"], e["rule"], e.get("context", "<module>"), e["message"])
        for e in doc.get("findings", ())
    )


def write_baseline(path: Path, findings: Iterable[Finding]) -> None:
    """Write ``findings`` as a baseline file (sorted, line-number-free)."""
    entries = sorted(
        (
            {"path": f.path, "rule": f.rule, "context": f.context,
             "message": f.message}
            for f in findings
        ),
        key=lambda e: (e["path"], e["rule"], e["context"], e["message"]),
    )
    Path(path).write_text(
        json.dumps({"version": BASELINE_VERSION, "findings": entries},
                   indent=2) + "\n"
    )
