"""Single source of truth for the repo's observability and comm contracts.

Three CI gates used to carry private copies of these tables —
``scripts/check_trace.py`` hardcoded the Algorithm 1 stage order and the
per-stage required phase spans, ``scripts/check_smoke_comm.py`` hardcoded
the (measured, model) exchange-word field pairs — and the static-analysis
rule ``R003`` (unaccounted-exchange) needs the same vocabulary to know
which accumulators count as exchange accounting.  They all import from
here now, so adding a distributed phase means editing one table and every
checker follows.

Everything in this module is stdlib-only data: it must be importable both
from the dependency-free CI docs job (``python -m repro.analysis``) and
from the gate scripts, which are loaded by file path outside any package.
"""

from __future__ import annotations

#: Algorithm 1 stage order — every name must appear among the root spans of
#: an exported pipeline trace, in this order (docs/observability.md).
STAGES = (
    "CountKmer",
    "CreateSpMat",
    "SpGEMM",
    "Alignment",
    "BuildR",
    "TrReduction",
    "Contigs",
    "Consensus",
)

#: Required ``kind="phase"`` descendant spans per stage root span: the
#: explicit-exchange schedule each distributed stage must actually trace
#: (DESIGN.md §2.10-§2.12).  Stages absent from this table have no phase
#: contract.
STAGE_PHASES = {
    "SpGEMM": ("skew", "ring", "ring_stage", "collect_merge"),
    "Contigs": ("chain_stage", "cut", "doubling", "sort"),
    "Alignment": ("pair_exchange", "gather_reads", "extend",
                  "scatter_scores"),
}

#: Comm-model cross-check contract: one (benchmark op, measured stats field,
#: analytic model field) triple per shard_map phase whose exchange volume is
#: data-independent and therefore must match the ``bench_comm_model``
#: prediction exactly (docs/communication.md).
COMM_CONTRACTS = (
    ("contigs", "exchange_words_sort", "model_words_sort"),
    ("overlap", "exchange_words_summa", "model_words_summa"),
    ("align", "exchange_words_align", "model_words_align"),
)

#: ``jax.lax`` collectives that move data between devices and therefore fall
#: under the exchange-accounting contract: every call site in an
#: explicit-exchange module must be covered by an accounting increment or an
#: analytic ``exchange_words_*`` model (rule R003).
COLLECTIVE_OPS = ("ppermute", "psum", "pmax", "pmin", "all_gather",
                  "all_to_all")

#: Names that count as exchange accounting at a collective call site: the
#: trace-time accumulator dict incremented next to each ``ppermute``
#: (``core/summa.py`` / ``core/align_dist.py`` convention) ...
ACCOUNTING_ACCUMULATORS = ("acct",)

#: ... and the analytic per-phase word-count helpers whose results flow into
#: the ``exchange_words_*`` stats keys (``core/components_dist.py``
#: convention — the schedule is data-independent, so the model IS the
#: measurement).
ACCOUNTING_CALL_PREFIXES = ("exchange_words", "words_")
