"""Observability layer: span tracing, typed metrics, trace export.

Three pieces (docs/observability.md):

* ``obs.trace`` — hierarchical :func:`span` timing with device sync on
  exit; the single timing code path for pipeline stages, shard_map phases
  and kernel launches.
* ``obs.schema`` / ``obs.metrics`` — the declared metric registry and the
  validating :class:`Metrics` accumulator the stats dicts emit through.
* ``obs.export`` — Chrome trace-event / Perfetto JSON artifact writer.
* ``obs.memory`` — device-memory (HBM) watermark sampling with a
  live-buffer fallback; spans and benchmark records carry its columns.
* ``obs.experiments`` — declarative experiment engine: content-addressed
  result cache + append-only perf trajectory (``benchmarks/engine.py``).
"""

from .trace import Span, Tracer, current_tracer, span, sync, tracing
from .metrics import Metrics, MetricsError, validated
from .export import span_tree, to_chrome_trace, write_chrome_trace
from .memory import MemorySample, Watermark, sample, watermark
from . import schema

__all__ = [
    "Span",
    "Tracer",
    "current_tracer",
    "span",
    "sync",
    "tracing",
    "Metrics",
    "MetricsError",
    "validated",
    "schema",
    "span_tree",
    "to_chrome_trace",
    "write_chrome_trace",
    "MemorySample",
    "Watermark",
    "sample",
    "watermark",
]
