"""Declared metric schema for the pipeline's stats surface.

Every key the assembly pipeline emits into ``AssemblyResult.stats`` — and
every key the distributed sub-stages feed it through (``ContigSet.stats``,
``summa_ring``'s stats dict, ``TRStats``'s flattened ``tr_*`` fields) — is
registered here as a :class:`MetricSpec` with a kind, a unit and, where the
paper's accounting contract demands it, a *present-and-zero* guarantee:
exchange counters exist on **every** path and are zero where no explicit
exchange runs (gspmd auto-sharding, host walk), so distribution-axis
benchmark rows compare without key-existence checks (DESIGN.md §2.10).

The zero contracts used to be scattered: a hardcoded dict in
``assembly/contig_gen.py``, inline literals in ``core/summa.py`` and
``assembly/pipeline.py``, and per-test key tuples in ``tests/test_contigs``
/ ``tests/test_summa_dist``.  They are now derived from this registry in
one place (:func:`zero_defaults`) and validated in one place
(:func:`validate_stats`); ``tests/test_obs.py`` parametrizes over the
gspmd / shard_map / host emission paths.
"""

from __future__ import annotations

import dataclasses
import numbers
from typing import Any, Dict, List, Mapping, Optional, Tuple

#: kinds a metric can declare: monotone event/volume counts, point-in-time
#: measurements, categorical strings, and nested stat dicts.
KINDS = ("counter", "gauge", "label", "group")


@dataclasses.dataclass(frozen=True)
class MetricSpec:
    """One registered metric: its kind, unit and contract.

    ``zero_group`` names the present-and-zero contract the key belongs to
    (``"contig_exchange"``, ``"summa_exchange"``, ``"align_exchange"``) —
    every key of a group is
    emitted on every path, zero where the phase did not run — or ``None``
    for keys without a presence guarantee."""

    name: str
    kind: str
    unit: str
    description: str
    zero_group: Optional[str] = None

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"{self.name}: unknown metric kind {self.kind!r}")


def _c(name, unit, desc, zero_group=None):
    return MetricSpec(name, "counter", unit, desc, zero_group)


def _g(name, unit, desc):
    return MetricSpec(name, "gauge", unit, desc)


def _l(name, desc):
    return MetricSpec(name, "label", "label", desc)


_SPECS: Tuple[MetricSpec, ...] = (
    # --- pipeline-wide ---
    _c("n_reads", "reads", "input reads"),
    _l("backend", "resolved kernel backend (reference|pallas)"),
    # --- device-memory watermark (obs/memory.py) ---
    _c("peak_hbm_bytes", "bytes",
       "device-memory high-water mark over the assemble window "
       "(allocator peak_bytes_in_use, or the sampled live-buffer peak on "
       "backends without memory_stats)"),
    _c("hbm_bytes_in_use", "bytes",
       "device memory in use when the assemble window closed"),
    _l("hbm_source", "memory sampling path that produced the watermark "
       "(device_stats|live_buffers)"),
    # --- CountKmer ---
    _c("m_reliable", "kmers", "reliable k-mers kept (paper's |M|)"),
    _c("n_unique_kmers", "kmers", "distinct k-mers seen"),
    _c("n_singletons", "kmers", "k-mers seen exactly once"),
    # --- CreateSpMat ---
    _c("overflow_A", "entries", "A entries dropped by K_A row capacity"),
    _c("nnz_A", "entries", "nonzeros of the reads x kmers matrix A"),
    # --- SpGEMM / ring SUMMA (core/summa.py) ---
    _l("overlap_distribution", "overlap-stage distribution (gspmd|shard_map)"),
    _l("summa_algorithm", "SUMMA variant that ran (ring|allgather_fallback)"),
    _l("summa_fallback_reason", "why the ring routed to all-gather"),
    _l("summa_backend", "ring-stage op backend that ran (reference|pallas)"),
    _c("summa_stages", "stages", "ring pipeline stages (pc = sqrt(P))"),
    _c("exchange_words_summa", "words",
       "4-byte words per device moved by the ring SUMMA ppermutes "
       "(paper Table I W = am/sqrt(P))", "summa_exchange"),
    _c("exchange_rounds_summa", "rounds",
       "ppermute rotations issued by the ring SUMMA", "summa_exchange"),
    _c("spgemm_hbm_round_trips", "trips",
       "HBM round trips the resolved SpGEMM backend pays "
       "(fused: ceil(pc/stages_per_call))"),
    _c("spgemm_hbm_round_trips_reference", "trips",
       "HBM round trips of the per-stage reference path (= pc)"),
    _c("overflow_C", "entries", "candidate entries dropped by K_C capacity"),
    _c("nnz_C", "entries", "nonzeros of the candidate matrix C = A*At"),
    _g("c_density", "entries/read", "nnz_C per read"),
    # --- Alignment (core/align_dist.py distributed x-drop) ---
    _c("n_aligned", "pairs", "live candidate pairs aligned"),
    _c("align_candidates", "slots", "candidate slots (n * K_C)"),
    _c("align_bucket", "slots", "pow-2 compacted alignment bucket size"),
    _c("n_passed", "pairs", "pairs passing the score/length gates"),
    _l("align_distribution",
       "alignment-stage distribution (gspmd|shard_map)"),
    _c("exchange_words_align", "words",
       "per-device words of the alignment stage's explicit exchanges "
       "(read-row ring gather + score-scatter allreduce, "
       "bench_comm_model.words_align)", "align_exchange"),
    _c("exchange_rounds_align", "rounds",
       "explicit exchange rounds of the alignment stage (ring hops + the "
       "scatter allreduce)", "align_exchange"),
    # --- BuildR ---
    _c("overflow_R", "entries", "overlap entries dropped by K_R capacity"),
    _c("nnz_R", "entries", "nonzeros of the overlap graph R"),
    _g("r_density", "entries/read", "nnz_R per read"),
    _c("n_contained", "reads", "reads dropped as contained"),
    # --- TrReduction (TRStats flattened) ---
    _c("tr_iterations", "iterations", "Algorithm 2 passes to fixed point"),
    _l("tr_backend", "TR path that actually ran (pallas|reference; "
       "surfaces the dense-cap silent downgrade)"),
    _c("tr_overflow", "rows", "rows overflowing the sampled-square capacity"),
    _c("nnz_S", "entries", "nonzeros of the string matrix S"),
    _g("s_density", "entries/read", "nnz_S per read"),
    # --- Contigs (ContigSet.stats) ---
    MetricSpec("contigs", "group", "dict",
               "contig_stats summary (nested dict)"),
    _c("n_branch_cut", "edges", "state-graph edges removed by the branch cut"),
    _c("cc_iterations", "iterations", "pointer-doubling rounds to converge"),
    _l("distribution", "contig-stage partitioning that ran "
       "(gspmd|shard_map|host)"),
    _c("exchange_words", "words",
       "total per-device words of the contig stage's explicit exchanges",
       "contig_exchange"),
    _c("exchange_rounds", "rounds",
       "total explicit exchange rounds of the contig stage",
       "contig_exchange"),
    _c("exchange_words_cut", "words",
       "branch-cut allreduce words (CUT_ALLREDUCES ring allreduces)",
       "contig_exchange"),
    _c("exchange_words_doubling", "words",
       "doubling-middle ring all-gather words", "contig_exchange"),
    _c("exchange_words_sort", "words",
       "ring-bitonic chain-sort merge-split words", "contig_exchange"),
    _c("exchange_rounds_doubling", "rounds",
       "doubling-middle exchange rounds", "contig_exchange"),
    _c("exchange_rounds_sort", "rounds",
       "chain-sort exchange stages (+1 eligibility gather)",
       "contig_exchange"),
    # --- Consensus ---
    _g("consensus_depth_mean", "votes", "mean pileup depth over re-called "
       "columns"),
    _g("identity_estimate", "ratio", "estimated per-base identity of the "
       "polished contigs"),
    _g("qv_estimate", "phred", "Phred-scaled identity estimate"),
    _c("consensus_changed", "columns", "contig columns changed by polishing"),
    _c("n_junction_shifted", "junctions",
       "chain junctions re-anchored by the shift search"),
)

#: name -> spec for every registered metric.
SCHEMA: Dict[str, MetricSpec] = {s.name: s for s in _SPECS}

#: the declared present-and-zero groups (see :class:`MetricSpec`).
ZERO_GROUPS: Tuple[str, ...] = tuple(sorted(
    {s.zero_group for s in _SPECS if s.zero_group}
))


def spec(name: str) -> MetricSpec:
    """The :class:`MetricSpec` registered for ``name`` (KeyError if none)."""
    return SCHEMA[name]


def is_registered(name: str) -> bool:
    """Whether ``name`` is a registered metric."""
    return name in SCHEMA


def group_keys(zero_group: str) -> Tuple[str, ...]:
    """Keys bound to a present-and-zero group, in registration order."""
    keys = tuple(s.name for s in _SPECS if s.zero_group == zero_group)
    if not keys:
        raise KeyError(f"unknown zero group {zero_group!r}; "
                       f"known: {ZERO_GROUPS}")
    return keys


def zero_defaults(zero_group: str) -> Dict[str, int]:
    """The present-and-zero seed dict for a group — the single source the
    emitters start from (``assembly/contig_gen.ZERO_EXCHANGE_STATS`` and the
    pipeline's summa seeding are both derived from this)."""
    return {k: 0 for k in group_keys(zero_group)}


def _kind_ok(kind: str, value: Any) -> bool:
    if kind == "counter":
        return (isinstance(value, numbers.Integral)
                and not isinstance(value, bool))
    if kind == "gauge":
        return (isinstance(value, numbers.Real)
                and not isinstance(value, bool))
    if kind == "label":
        return value is None or isinstance(value, str)
    if kind == "group":
        return isinstance(value, Mapping)
    return False  # pragma: no cover - KINDS is closed


def validate_stats(
    stats: Mapping[str, Any],
    *,
    context: str = "stats",
    require_groups: Tuple[str, ...] = (),
) -> List[str]:
    """Validate a stats dict against the registry; return violations.

    Checks: every key is registered; every value matches its declared kind
    (counters integral, gauges real, labels str-or-None, groups mappings);
    and every key of each group in ``require_groups`` is present (the
    present-and-zero contract).  An empty list means clean."""
    out = []
    for key, val in stats.items():
        s = SCHEMA.get(key)
        if s is None:
            out.append(f"{context}: unregistered stats key {key!r}")
        elif not _kind_ok(s.kind, val):
            out.append(
                f"{context}: {key} = {val!r} is not a valid {s.kind} "
                f"({s.unit})"
            )
    for grp in require_groups:
        for key in group_keys(grp):
            if key not in stats:
                out.append(
                    f"{context}: missing {grp} present-and-zero key {key!r}"
                )
    return out
