"""Trace-artifact export: Chrome trace-event / Perfetto JSON.

Serializes a :class:`~repro.obs.trace.Tracer`'s span forest into the Chrome
trace-event JSON object format (https://ui.perfetto.dev loads it directly,
as does ``chrome://tracing``): one ``"X"`` complete event per span with
microsecond ``ts``/``dur`` relative to the tracer epoch, span attributes in
``args``.  All spans share one ``pid``/``tid`` — the tracer is host-
sequential, so parent/child nesting is exactly ts/dur containment, which is
how Perfetto stacks them.

Alongside ``traceEvents`` the file carries a ``spanTree`` key (ignored by
trace viewers) with the explicit nesting — ``scripts/check_trace.py``
asserts the stage → phase → kernel structure against it without having to
re-derive containment from timestamps.
"""

from __future__ import annotations

import json
from typing import Any, Dict

from .trace import Span, Tracer


def _jsonable(v: Any) -> Any:
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    try:
        return int(v)  # 0-d device/numpy scalars
    except (TypeError, ValueError):
        return repr(v)


def span_tree(sp: Span) -> Dict[str, Any]:
    """One span (and its subtree) as a plain nested dict."""
    return {
        "name": sp.name,
        "ms": round(sp.duration_ms, 4),
        "attrs": {k: _jsonable(v) for k, v in sp.attrs.items()},
        "children": [span_tree(c) for c in sp.children],
    }


def to_chrome_trace(tracer: Tracer) -> Dict[str, Any]:
    """The tracer's span forest as a Chrome trace-event JSON object."""
    events = []
    for sp in tracer.spans():
        t1 = sp.t1 if sp.t1 is not None else sp.t0
        events.append({
            "name": sp.name,
            "ph": "X",
            "ts": (sp.t0 - tracer.epoch) * 1e6,
            "dur": max((t1 - sp.t0) * 1e6, 0.001),
            "pid": 0,
            "tid": 0,
            "cat": str(sp.attrs.get("kind", "span")),
            "args": {k: _jsonable(v) for k, v in sp.attrs.items()},
        })
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "spanTree": [span_tree(r) for r in tracer.roots],
    }


def write_chrome_trace(tracer: Tracer, path: str) -> str:
    """Write the Chrome trace JSON for ``tracer`` to ``path``; returns
    ``path``.  Open the file at https://ui.perfetto.dev (or
    ``chrome://tracing``) for the timeline view."""
    with open(path, "w") as f:
        json.dump(to_chrome_trace(tracer), f, indent=1)
    return path
