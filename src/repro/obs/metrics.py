"""Typed metrics registry: the emit surface over ``obs.schema``.

A :class:`Metrics` instance is a validating accumulator the pipeline and its
sub-stages write through instead of assigning into a bare dict.  Every
:meth:`Metrics.emit` checks the key against the declared schema (registered
name, kind-compatible value) at write time — so an unregistered or
mistyped stat fails where it is emitted, not in a downstream test — and
:meth:`Metrics.as_dict` returns the plain dict shape every existing
consumer (benchmarks, tests, JSON artifacts) already expects: the
compatibility shim that keeps ``AssemblyResult.stats`` and
``ContigSet.stats`` ordinary dicts.
"""

from __future__ import annotations

from typing import Any, Dict, Mapping, Optional

from . import schema


class MetricsError(ValueError):
    """An emission violated the declared schema (unknown key / wrong kind)."""


class Metrics:
    """Schema-validated stats accumulator with a dict-compatible view.

    ``strict=True`` (the default) raises :class:`MetricsError` on the first
    violation; ``strict=False`` collects violations in :attr:`violations`
    instead (used by tests that probe the contract itself)."""

    def __init__(self, *, context: str = "stats", strict: bool = True):
        self._values: Dict[str, Any] = {}
        self.context = context
        self.strict = strict
        self.violations: list = []

    def _check(self, name: str, value: Any) -> None:
        s = schema.SCHEMA.get(name)
        if s is None:
            msg = f"{self.context}: unregistered stats key {name!r}"
        elif not schema._kind_ok(s.kind, value):
            msg = (f"{self.context}: {name} = {value!r} is not a valid "
                   f"{s.kind} ({s.unit})")
        else:
            return
        if self.strict:
            raise MetricsError(msg)
        self.violations.append(msg)

    def emit(self, name: str, value: Any) -> Any:
        """Record one metric value (validated against the schema);
        returns ``value`` so emission can wrap an expression in place."""
        self._check(name, value)
        self._values[name] = value
        return value

    def emit_many(self, values: Mapping[str, Any]) -> None:
        """Record every ``(name, value)`` of a mapping, each validated."""
        for name, value in values.items():
            self.emit(name, value)

    def seed_zero(self, zero_group: str) -> None:
        """Seed a present-and-zero group: every key of ``zero_group`` is set
        to 0 unless already emitted — the one place the presence half of the
        contract is enforced (DESIGN.md §2.10)."""
        for key, zero in schema.zero_defaults(zero_group).items():
            self._values.setdefault(key, zero)

    def get(self, name: str, default: Any = None) -> Any:
        """The recorded value for ``name`` (or ``default``)."""
        return self._values.get(name, default)

    def __contains__(self, name: str) -> bool:
        return name in self._values

    def __getitem__(self, name: str) -> Any:
        return self._values[name]

    def as_dict(self) -> Dict[str, Any]:
        """The plain-dict compatibility view (a copy, insertion-ordered)."""
        return dict(self._values)


def validated(stats: Mapping[str, Any], *, context: str = "stats",
              require_groups: tuple = ()) -> Dict[str, Any]:
    """Validate a ready-made stats dict against the schema and return it as
    a plain dict; raises :class:`MetricsError` on any violation.  The
    one-shot form of :class:`Metrics` for emitters that already assemble
    their stats in one expression."""
    problems = schema.validate_stats(
        stats, context=context, require_groups=require_groups
    )
    if problems:
        raise MetricsError("; ".join(problems))
    return dict(stats)
