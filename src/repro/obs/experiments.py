"""Declarative experiment engine: cached runs + append-only perf trajectory.

The benchmark surface used to be artisanal: each PR hand-wrote one
``BENCH_<n>.json`` snapshot and the regression gate diffed the latest pair.
This module turns it into a *persistent* experiment engine in the style of
rtl-experiments' ``framework.py`` (content-addressed result cache,
incremental ``todo``/``run``/``report``/``csv`` verbs) and Cydonia's
``RunExperiment`` (declarative experiment list, artifact trail):

* an :class:`Experiment` is a declarative spec — a runner module key, its
  kwargs, and the backend/distribution axis labels it covers;
* its :func:`experiment_id` is a stable hash of that spec **plus a code
  fingerprint** (:func:`code_fingerprint` over the source files the result
  depends on), so editing the benchmark or the library invalidates exactly
  the affected cache entries and an untouched tree re-runs for free;
* the :class:`ExperimentEngine` keeps one JSON result file per experiment
  id under ``.bench_cache/`` and appends every *new* ``(experiment_id,
  row)`` pair to the trajectory store ``bench/trajectory.jsonl`` — one
  record per experiment row per code snapshot, append-only, superseding
  the one-file-per-PR ``BENCH_<n>.json`` convention (old snapshots remain
  readable as history via :func:`load_bench_snapshots`).

The concrete experiment list and the CLI live in ``benchmarks/engine.py``;
``scripts/check_bench_regression.py`` gates fresh records against the
trajectory.  Every record carries ``ms``, ``compile_ms`` and
``peak_hbm_bytes`` (``obs.memory``), so both "faster" and "smaller" are
queryable trajectories rather than commit-message assertions.
"""

from __future__ import annotations

import dataclasses
import glob
import hashlib
import json
import os
import time
from typing import Any, Callable, Dict, Iterable, List, Mapping, Optional

#: record fields every trajectory row must carry (the engine fails loudly on
#: a runner that drops one — a silently thinner record must not cache).
REQUIRED_RECORD_FIELDS = ("name", "ms", "compile_ms", "peak_hbm_bytes")


def _canonical(obj: Any) -> str:
    """Deterministic JSON for hashing (sorted keys, no whitespace)."""
    return json.dumps(obj, sort_keys=True, separators=(",", ":"),
                      default=repr)


def code_fingerprint(paths: Iterable[str],
                     root: Optional[str] = None) -> str:
    """Stable hex digest of the contents of every file under ``paths``.

    Directories are walked recursively (``.py`` files only, sorted), plain
    files are hashed as-is; missing paths contribute their name so a
    deleted dependency still changes the fingerprint.  File names enter
    the digest *relative to* ``root`` (default: the common parent of
    ``paths``) with ``/`` separators, so two checkouts of the same tree —
    different machines, different absolute paths — agree on the
    fingerprint and can share ``.bench_cache/`` entries and trajectory
    dedup keys."""
    h = hashlib.sha256()
    abs_paths = sorted(os.path.abspath(p) for p in paths)
    if root is None and abs_paths:
        root = os.path.commonpath(abs_paths)
        if not os.path.isdir(root):
            root = os.path.dirname(root)
    for path in abs_paths:
        if os.path.isdir(path):
            files = sorted(glob.glob(os.path.join(path, "**", "*.py"),
                                     recursive=True))
        else:
            files = [path]
        for f in files:
            rel = os.path.relpath(f, root) if root else f
            h.update(rel.replace(os.sep, "/").encode())
            try:
                with open(f, "rb") as fh:
                    h.update(fh.read())
            except OSError:
                h.update(b"<missing>")
    return h.hexdigest()[:16]


@dataclasses.dataclass(frozen=True)
class Experiment:
    """One declarative experiment: runner key, kwargs, and axis labels.

    ``module`` names the runner (a benchmark module key for the concrete
    registry in ``benchmarks/engine.py``); ``kwargs`` are passed to it
    verbatim; ``axes`` are the backend/distribution labels the experiment
    pins, folded into the id so the same module under a different axis is a
    different cache entry."""

    module: str
    kwargs: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    axes: Mapping[str, str] = dataclasses.field(default_factory=dict)

    @property
    def label(self) -> str:
        """Human-readable ``module[axis=value,...]`` tag for reports."""
        ax = ",".join(f"{k}={v}" for k, v in sorted(self.axes.items()))
        return f"{self.module}[{ax}]" if ax else self.module

    def spec(self) -> Dict[str, Any]:
        """The experiment as a plain JSON-able dict (hashed for the id)."""
        return {
            "module": self.module,
            "kwargs": dict(self.kwargs),
            "axes": dict(self.axes),
        }


def experiment_id(exp: Experiment, fingerprint: str) -> str:
    """Stable id: hash of the experiment spec + the code fingerprint."""
    h = hashlib.sha256()
    h.update(_canonical(exp.spec()).encode())
    h.update(fingerprint.encode())
    return h.hexdigest()[:16]


def validate_records(records: List[Mapping[str, Any]],
                     context: str) -> List[str]:
    """Check every record carries :data:`REQUIRED_RECORD_FIELDS`."""
    problems = []
    for rec in records:
        for field in REQUIRED_RECORD_FIELDS:
            if field not in rec:
                problems.append(
                    f"{context}: record {rec.get('name', '?')!r} "
                    f"missing required field {field!r}")
    return problems


def load_bench_snapshots(root: str) -> List[Dict[str, Any]]:
    """Legacy history: every committed ``BENCH_<n>.json`` as trajectory rows.

    Each file becomes one snapshot (labelled by its basename); its records
    are passed through unchanged, so pre-memory/pre-split rows simply lack
    the newer fields — consumers gate on field presence, as
    ``scripts/check_bench_regression.py`` does."""
    out = []
    for path in sorted(glob.glob(os.path.join(root, "BENCH_*.json"))):
        try:
            with open(path) as f:
                records = json.load(f)
        except (OSError, ValueError):
            continue
        snap = os.path.splitext(os.path.basename(path))[0]
        for rec in records:
            if isinstance(rec, dict) and "name" in rec:
                out.append({"snapshot": snap, **rec})
    return out


class ExperimentEngine:
    """Cached experiment runs + the append-only perf trajectory.

    ``runner(experiment)`` must return a list of record dicts (one per
    benchmark row, each carrying :data:`REQUIRED_RECORD_FIELDS`).  Results
    are cached under ``cache_dir/<experiment_id>.json``; because the id
    folds in the code fingerprint, a cache hit means *this exact code and
    spec already ran* — ``run()`` then serves the cached records without
    executing anything, and ``todo()`` reports only fingerprint-fresh
    pending experiments."""

    def __init__(
        self,
        experiments: Iterable[Experiment],
        runner: Callable[[Experiment], List[Dict[str, Any]]],
        *,
        cache_dir: str = ".bench_cache",
        trajectory_path: str = os.path.join("bench", "trajectory.jsonl"),
        fingerprint: str = "",
    ):
        self.experiments = list(experiments)
        self.runner = runner
        self.cache_dir = cache_dir
        self.trajectory_path = trajectory_path
        self.fingerprint = fingerprint

    # -- cache ------------------------------------------------------------

    def id_of(self, exp: Experiment) -> str:
        """The content-addressed id of ``exp`` under the engine's
        fingerprint."""
        return experiment_id(exp, self.fingerprint)

    def _cache_path(self, exp: Experiment) -> str:
        return os.path.join(self.cache_dir, self.id_of(exp) + ".json")

    def cached(self, exp: Experiment) -> Optional[Dict[str, Any]]:
        """The cached result document for ``exp``, or None on a miss."""
        try:
            with open(self._cache_path(exp)) as f:
                return json.load(f)
        except (OSError, ValueError):
            return None

    def todo(self) -> List[Experiment]:
        """Experiments with no cached result at the current fingerprint."""
        return [e for e in self.experiments if self.cached(e) is None]

    # -- run --------------------------------------------------------------

    def run(
        self,
        only: Optional[Iterable[str]] = None,
        force: bool = False,
        log: Callable[[str], None] = lambda msg: None,
    ) -> Dict[str, Any]:
        """Run pending experiments (cache hits are served, not re-run).

        ``only`` restricts to the given module keys; ``force`` re-runs even
        on a hit.  Returns ``{"records", "fresh_records", "ran", "hits",
        "wall_s"}`` (``fresh_records`` = rows produced by this invocation,
        the trajectory delta); every fresh result is written to the cache
        and its rows appended to the trajectory store (deduplicated on
        ``(experiment_id, name)``)."""
        t_start = time.perf_counter()
        selected = [e for e in self.experiments
                    if only is None or e.module in set(only)]
        ran, hits, all_records, fresh_records = [], [], [], []
        for exp in selected:
            eid = self.id_of(exp)
            doc = None if force else self.cached(exp)
            if doc is not None:
                hits.append(exp)
                log(f"# cache hit {exp.label} ({eid})")
                all_records.extend(doc["records"])
                continue
            log(f"# running {exp.label} ({eid})")
            t0 = time.perf_counter()
            records = self.runner(exp)
            wall_s = time.perf_counter() - t0
            problems = validate_records(records, exp.label)
            if problems:
                raise ValueError("; ".join(problems))
            # stamp provenance into the records themselves (not only the
            # trajectory rows): the regression gate uses the fingerprint to
            # exclude a fresh run's own rows from its baseline
            for rec in records:
                rec.setdefault("experiment_id", eid)
                rec.setdefault("fingerprint", self.fingerprint)
            doc = {
                "experiment_id": eid,
                "spec": exp.spec(),
                "fingerprint": self.fingerprint,
                "created": time.time(),
                "wall_s": wall_s,
                "records": records,
            }
            os.makedirs(self.cache_dir, exist_ok=True)
            with open(self._cache_path(exp), "w") as f:
                json.dump(doc, f, indent=1)
            self._append_trajectory(eid, records)
            ran.append(exp)
            all_records.extend(records)
            fresh_records.extend(records)
        return {
            "records": all_records,
            "fresh_records": fresh_records,
            "ran": [e.label for e in ran],
            "hits": [e.label for e in hits],
            "wall_s": time.perf_counter() - t_start,
        }

    # -- trajectory -------------------------------------------------------

    def load_trajectory(self) -> List[Dict[str, Any]]:
        """Every record of the trajectory store (empty when absent)."""
        out = []
        try:
            with open(self.trajectory_path) as f:
                for line in f:
                    line = line.strip()
                    if line:
                        out.append(json.loads(line))
        except OSError:
            pass
        return out

    def _append_trajectory(self, eid: str,
                           records: List[Dict[str, Any]]) -> int:
        seen = {(r.get("experiment_id"), r.get("name"))
                for r in self.load_trajectory()}
        fresh = [r for r in records if (eid, r.get("name")) not in seen]
        if not fresh:
            return 0
        os.makedirs(os.path.dirname(self.trajectory_path) or ".",
                    exist_ok=True)
        with open(self.trajectory_path, "a") as f:
            for rec in fresh:
                row = {
                    "experiment_id": eid,
                    "fingerprint": self.fingerprint,
                    "ts": round(time.time(), 3),
                    **rec,
                }
                f.write(json.dumps(row, sort_keys=True) + "\n")
        return len(fresh)

    # -- report / csv -----------------------------------------------------

    def report_rows(self) -> List[Dict[str, Any]]:
        """One summary row per experiment: cache state + record count."""
        rows = []
        for exp in self.experiments:
            doc = self.cached(exp)
            rows.append({
                "experiment": exp.label,
                "id": self.id_of(exp),
                "state": "cached" if doc else "pending",
                "records": len(doc["records"]) if doc else 0,
                "wall_s": round(doc["wall_s"], 2) if doc else None,
            })
        return rows

    def csv_rows(self) -> List[List[Any]]:
        """Header + one CSV row per cached benchmark record."""
        header = ["experiment", "name", "ms", "compile_ms",
                  "peak_hbm_bytes", "hbm_source", "derived"]
        rows: List[List[Any]] = [header]
        for exp in self.experiments:
            doc = self.cached(exp)
            if doc is None:
                continue
            for rec in doc["records"]:
                rows.append([
                    exp.label, rec.get("name"), rec.get("ms"),
                    rec.get("compile_ms"), rec.get("peak_hbm_bytes"),
                    rec.get("hbm_source"), rec.get("derived"),
                ])
        return rows
