"""Device-memory (HBM) watermark telemetry (docs/observability.md).

HBM capacity is the current genome-size ceiling (ROADMAP item 3), yet until
this module nothing in the repo *measured* device memory — "smaller" was as
unqueryable as "faster" was before the perf trajectory.  One sampling code
path serves every consumer:

* :func:`sample` takes one :class:`MemorySample` — ``bytes_in_use`` plus the
  best-known ``peak_bytes`` — from ``device.memory_stats()`` where the
  backend reports it (TPU/GPU allocator stats: ``bytes_in_use`` /
  ``peak_bytes_in_use``, maxed over devices since the per-device watermark
  is what binds HBM capacity), falling back to **live-buffer accounting**
  (sum of ``nbytes`` over ``jax.live_arrays()``) on backends that return
  ``None`` (the CPU backend, hence every CI run).  The ``source`` field
  (``"device_stats"`` | ``"live_buffers"``) travels with every number so a
  fallback measurement is never mistaken for an allocator watermark.
* :func:`watermark` is a context manager yielding a :class:`Watermark`:
  every :func:`sample` taken anywhere inside the window — including the
  ones nested spans and nested watermarks take — is folded into the
  window's ``peak_hbm_bytes``, so an outer watermark's peak is at least as
  fine-grained as its inner span boundaries.  On the fallback path the
  peak is therefore *sampled* (span-boundary granularity), not continuous;
  on the device-stats path the allocator's own high-water mark is used.
* ``obs.trace.span`` samples on enter/exit while a memory-enabled
  :class:`~repro.obs.trace.Tracer` is active and attaches
  ``peak_hbm_bytes`` / ``hbm_bytes_in_use`` / ``hbm_delta_bytes`` /
  ``hbm_source`` to the span, so Chrome-trace exports carry HBM columns
  and ``scripts/check_trace.py`` can assert memory attribution on stage
  spans.
* ``benchmarks/_timing.timed`` wraps its calls in a watermark, so every
  benchmark record carries ``peak_hbm_bytes``; the pipeline wraps
  ``assemble`` likewise and emits the ``peak_hbm_bytes``-family stats keys
  (``obs.schema``).
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Iterator, List, Optional

import jax

#: sample sources: backend allocator stats vs the live-buffer fallback.
SOURCES = ("device_stats", "live_buffers")


@dataclasses.dataclass(frozen=True)
class MemorySample:
    """One point-in-time device-memory reading.

    ``bytes_in_use`` is current allocation; ``peak_bytes`` is the best-known
    high-water mark at sample time (allocator-reported on the device-stats
    path, == ``bytes_in_use`` on the live-buffer fallback); ``source`` names
    the path that produced the numbers."""

    bytes_in_use: int
    peak_bytes: int
    source: str


def _device_stats() -> Optional[MemorySample]:
    """Allocator stats maxed over devices, or None when unavailable.

    ``memory_stats()`` returns None on the CPU backend and may raise on
    exotic platforms; both cases route to the live-buffer fallback."""
    in_use = peak = None
    try:
        for dev in jax.devices():
            stats = dev.memory_stats()
            if not stats:
                return None
            b = int(stats.get("bytes_in_use", 0))
            p = int(stats.get("peak_bytes_in_use", b))
            in_use = b if in_use is None else max(in_use, b)
            peak = p if peak is None else max(peak, p)
    except Exception:  # pragma: no cover - platform-dependent
        return None
    if in_use is None:  # pragma: no cover - no devices
        return None
    return MemorySample(in_use, max(peak, in_use), "device_stats")


def _live_buffer_bytes() -> int:
    """Total ``nbytes`` of every live device array (the CPU fallback)."""
    total = 0
    for buf in jax.live_arrays():
        try:
            total += int(buf.nbytes)
        except Exception:  # pragma: no cover - deleted buffer race
            pass
    return total


@dataclasses.dataclass
class Watermark:
    """Device-memory accounting for one :func:`watermark` window.

    ``peak_hbm_bytes`` folds every sample taken while the window was open
    (enter/exit plus any nested span or watermark samples);
    ``hbm_bytes_in_use`` is the reading at exit, ``delta_bytes`` the
    exit-minus-enter growth, ``source`` the sampling path."""

    enter: Optional[MemorySample] = None
    exit: Optional[MemorySample] = None
    peak_hbm_bytes: int = 0
    source: str = "live_buffers"

    def _observe(self, s: MemorySample) -> None:
        self.peak_hbm_bytes = max(self.peak_hbm_bytes, s.peak_bytes)
        self.source = s.source

    @property
    def hbm_bytes_in_use(self) -> int:
        """Bytes in use at window exit (0 before the window closed)."""
        return 0 if self.exit is None else self.exit.bytes_in_use

    @property
    def delta_bytes(self) -> int:
        """Exit-minus-enter growth in bytes in use."""
        if self.enter is None or self.exit is None:
            return 0
        return self.exit.bytes_in_use - self.enter.bytes_in_use


#: per-thread registry of open watermark windows: every sample folds into
#: all of the *calling thread's* windows, so outer windows see the sample
#: points their nested spans take while concurrent threads never fold
#: samples into each other's accounting.
_LOCAL = threading.local()


def _open_watermarks() -> List[Watermark]:
    """The calling thread's stack of currently-open watermark windows."""
    try:
        return _LOCAL.open
    except AttributeError:
        out: List[Watermark] = []
        _LOCAL.open = out
        return out


def sample() -> MemorySample:
    """Take one memory sample and fold it into every open watermark.

    Prefers backend allocator stats (``device.memory_stats()``); falls back
    to live-buffer accounting when the backend reports none."""
    s = _device_stats()
    if s is None:
        b = _live_buffer_bytes()
        s = MemorySample(b, b, "live_buffers")
    for w in _open_watermarks():
        w._observe(s)
    return s


@contextlib.contextmanager
def watermark() -> Iterator[Watermark]:
    """Open a device-memory watermark window.

    Yields the :class:`Watermark`; samples on enter and exit, and absorbs
    every sample nested code takes in between (spans under an active
    memory-enabled tracer, nested watermarks, explicit :func:`sample`
    calls)."""
    w = Watermark()
    opened = _open_watermarks()
    opened.append(w)
    try:
        w.enter = sample()
        yield w
    finally:
        try:
            w.exit = sample()
        finally:
            opened.remove(w)
