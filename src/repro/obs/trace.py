"""Hierarchical span tracing for the assembly pipeline (docs/observability.md).

One timing code path for the whole repo: a :func:`span` context manager that

* records host wall-clock on enter/exit (``time.perf_counter``);
* device-syncs on exit when the span was handed an output
  (:meth:`Span.set_output`), so a stage span measures execution rather than
  async dispatch — the ``_tic`` semantics of ``assembly/pipeline.py``, now
  fixed to descend *arbitrary* pytrees including plain (unregistered)
  dataclasses like ``ContigSet``, which ``jax.block_until_ready`` treats as
  opaque leaves and silently skips;
* nests: spans opened while another span is live become its children, so a
  pipeline run produces a tree — stages → shard_map phases → kernel
  launches.  Spans opened inside a ``jit``-traced function fire at *trace
  time* (host Python still runs), which is exactly when the nesting is
  meaningful; cached jits re-execute without re-tracing and therefore
  without re-emitting their inner spans (a fresh process — e.g. the CI
  smoke run — always traces once);
* optionally forwards every span to ``jax.profiler.TraceAnnotation`` so the
  same structure shows up in an XLA profiler capture
  (``Tracer(annotate=True)``, enabled via ``PipelineConfig.trace``).

Spans work with or without an active :class:`Tracer`: without one they
still time and sync (that is what keeps ``_tic`` a thin wrapper), they are
just not recorded.  Activate a tracer for a region with :func:`tracing`;
export the recorded tree with ``obs.export``.
"""

from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any, Dict, Iterator, List, Optional

import jax


def _device_leaves(obj: Any, seen: set) -> list:
    """Collect every leaf of ``obj`` carrying ``block_until_ready``,
    descending containers *and* plain dataclass instances (which
    ``jax.tree`` treats as opaque leaves)."""
    if obj is None or id(obj) in seen:
        return []
    seen.add(id(obj))
    if isinstance(obj, jax.core.Tracer):
        return []  # inside a jit trace: nothing to sync
    if hasattr(obj, "block_until_ready"):
        return [obj]
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        out = []
        for f in dataclasses.fields(obj):
            out.extend(_device_leaves(getattr(obj, f.name, None), seen))
        return out
    out = []
    for leaf in jax.tree.leaves(obj):
        if leaf is obj:
            continue  # jax saw it as one opaque leaf and it is not an array
        out.extend(_device_leaves(leaf, seen))
    return out


def sync(out: Any) -> Any:
    """Block until every device array reachable from ``out`` is ready.

    Unlike raw ``jax.block_until_ready`` this descends plain dataclasses
    (``ContigSet``, ``ConsensusResult``, …), lists of them, and nested
    dicts — any mix of pytrees and unregistered containers.  Tracers (under
    an active jit trace) are skipped.  Returns ``out``."""
    for leaf in _device_leaves(out, set()):
        leaf.block_until_ready()
    return out


@dataclasses.dataclass
class Span:
    """One timed region: name, free-form attributes, wall-clock interval and
    child spans (populated when a :class:`Tracer` is active)."""

    name: str
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    t0: float = 0.0
    t1: Optional[float] = None
    children: List["Span"] = dataclasses.field(default_factory=list)
    _out: Any = dataclasses.field(default=None, repr=False)

    def set_output(self, out: Any) -> Any:
        """Register ``out`` to be device-synced when the span closes (the
        block-until-ready stage-timing contract).  Returns ``out``."""
        self._out = out
        return out

    def annotate(self, **attrs: Any) -> None:
        """Attach extra attributes to the span after it was opened."""
        self.attrs.update(attrs)

    @property
    def duration_s(self) -> float:
        """Span wall-clock in seconds (0.0 while still open)."""
        return 0.0 if self.t1 is None else self.t1 - self.t0

    @property
    def duration_ms(self) -> float:
        """Span wall-clock in milliseconds (0.0 while still open)."""
        return self.duration_s * 1e3

    def walk(self) -> Iterator["Span"]:
        """Yield this span and every descendant, depth-first preorder."""
        yield self
        for child in self.children:
            yield from child.walk()


class Tracer:
    """Collects a forest of :class:`Span` trees for one traced region.

    ``annotate=True`` additionally wraps every span in a
    ``jax.profiler.TraceAnnotation`` so an XLA profiler capture taken around
    the same region shows the identical hierarchy.  ``memory=True`` (the
    default) samples device memory (``obs.memory``) on every span boundary
    and attaches ``peak_hbm_bytes`` / ``hbm_bytes_in_use`` /
    ``hbm_delta_bytes`` / ``hbm_source`` to each span, so exported traces
    carry HBM columns next to the wall-clock ones."""

    def __init__(self, annotate: bool = False, memory: bool = True):
        self.roots: List[Span] = []
        self._stack: List[Span] = []
        self.annotate = annotate
        self.memory = memory
        self.epoch = time.perf_counter()

    def _push(self, sp: Span) -> None:
        (self._stack[-1].children if self._stack else self.roots).append(sp)
        self._stack.append(sp)

    def _pop(self, sp: Span) -> None:
        if self._stack and self._stack[-1] is sp:
            self._stack.pop()

    def spans(self) -> Iterator[Span]:
        """Yield every recorded span, depth-first preorder across roots."""
        for root in self.roots:
            yield from root.walk()

    def find(self, name: str) -> List[Span]:
        """All recorded spans with the given name."""
        return [sp for sp in self.spans() if sp.name == name]


_ACTIVE: Optional[Tracer] = None


def current_tracer() -> Optional[Tracer]:
    """The tracer activated by the innermost :func:`tracing`, or None."""
    return _ACTIVE


@contextlib.contextmanager
def tracing(tracer: Optional[Tracer]):
    """Activate ``tracer`` for the dynamic extent of the with-block.

    Pass ``None`` to run untraced (spans still time + sync — useful to keep
    one code path for the traced and untraced pipeline)."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = tracer
    try:
        yield tracer
    finally:
        _ACTIVE = prev


@contextlib.contextmanager
def span(name: str, **attrs: Any):
    """Open a span: ``with span("SpGEMM", phase="ring_stage", i=s) as sp``.

    Yields the :class:`Span`; on exit the span device-syncs whatever was
    handed to :meth:`Span.set_output`, closes its wall-clock interval, and —
    when a tracer is active — records itself under the enclosing span."""
    tracer = _ACTIVE
    sp = Span(name=name, attrs=dict(attrs))
    ann = None
    wm = None
    if tracer is not None:
        tracer._push(sp)
        if tracer.annotate:
            try:
                ann = jax.profiler.TraceAnnotation(name)
                ann.__enter__()
            except Exception:  # pragma: no cover - profiler unavailable
                ann = None
        if tracer.memory:
            from . import memory as _memory

            wm = _memory.Watermark()
            opened = _memory._open_watermarks()
            opened.append(wm)
            try:
                wm.enter = _memory.sample()
            except Exception:
                # telemetry must not kill the span, and a failed enter
                # sample must not leave the watermark registered (every
                # later sample would fold into it forever): pop it and run
                # the span without memory attribution
                opened.remove(wm)
                wm = None
    sp.t0 = time.perf_counter()
    try:
        yield sp
    finally:
        sync(sp._out)
        sp.t1 = time.perf_counter()
        if wm is not None:
            from . import memory as _memory

            try:
                wm.exit = _memory.sample()
            except Exception:
                pass  # exit attrs degrade to the enter-side numbers
            finally:
                _memory._open_watermarks().remove(wm)
            sp.attrs.setdefault("peak_hbm_bytes", wm.peak_hbm_bytes)
            sp.attrs.setdefault("hbm_bytes_in_use", wm.hbm_bytes_in_use)
            sp.attrs.setdefault("hbm_delta_bytes", wm.delta_bytes)
            sp.attrs.setdefault("hbm_source", wm.source)
        if ann is not None:
            ann.__exit__(None, None, None)
        if tracer is not None:
            tracer._pop(sp)
