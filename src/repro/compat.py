"""Version-compatibility shims for the installed jax.

The codebase targets current jax APIs; older installations spell some of
them differently.  Import the symbols from here instead of guessing:

* ``shard_map`` — ``jax.shard_map`` (new) or
  ``jax.experimental.shard_map.shard_map`` (pre-0.6).
* ``pvary`` — ``jax.lax.pvary`` (new); identity on older jax, whose
  shard_map has no varying-manual-axes tracking to satisfy.
"""

from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # pragma: no cover - version-dependent
    from functools import partial as _partial

    from jax.experimental.shard_map import shard_map as _shard_map_old

    def shard_map(f=None, *, mesh, in_specs, out_specs, check_vma=False):
        """Old-jax adapter: ``check_vma`` is spelled ``check_rep`` there, and
        its replication checker predates rules for ``while``/``scan`` bodies
        (used by SUMMA's ring loop), so it stays off."""
        kw = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)
        if f is None:
            return _partial(_shard_map_old, **kw)
        return _shard_map_old(f, **kw)

if hasattr(jax.lax, "pvary"):
    pvary = jax.lax.pvary
else:  # pragma: no cover - version-dependent
    def pvary(x, axis_name):
        return x
