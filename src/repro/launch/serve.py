"""Batched decode serving driver (greedy sampling with KV/SSM caches).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --reduced \
        --batch 4 --prompt-len 32 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, reduced_config
from ..models.model import (
    init_cache,
    init_params,
    make_prefill_step,
    make_serve_step,
)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    max_len = args.prompt_len + args.gen
    caches = init_cache(cfg, args.batch, max_len)
    # repro: noqa[R001] — CLI entry: built exactly once per process.
    prefill = jax.jit(make_prefill_step(cfg), donate_argnums=(1,))
    # repro: noqa[R001] — CLI entry: built exactly once per process.
    step = jax.jit(make_serve_step(cfg), donate_argnums=(1,))

    rng = np.random.default_rng(args.seed)
    if cfg.frontend == "token":
        prompt = {"tokens": jnp.asarray(
            rng.integers(1, cfg.vocab_size, (args.batch, args.prompt_len)),
            jnp.int32)}
        mk = lambda tok: {"tokens": tok}
    else:
        prompt = {"embeddings": jnp.asarray(
            rng.normal(0, 1, (args.batch, args.prompt_len, cfg.d_model)),
            jnp.bfloat16)}
        emb = params["unembed"]  # reuse as a pseudo-embedding for the demo

        def mk(tok):
            e = emb.T[tok].astype(jnp.bfloat16)
            return {"embeddings": e}

    t0 = time.perf_counter()
    logits, caches = prefill(params, caches, prompt)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    toks = [jnp.argmax(logits, -1).astype(jnp.int32)]
    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        batch = mk(toks[-1][:, None])
        logits, caches = step(
            params, caches, batch, jnp.int32(args.prompt_len + i)
        )
        toks.append(jnp.argmax(logits, -1).astype(jnp.int32))
    jax.block_until_ready(toks[-1])
    t_dec = time.perf_counter() - t0
    out = jnp.stack(toks, 1)
    tps = args.batch * (args.gen - 1) / max(t_dec, 1e-9)
    print(f"prefill {t_prefill*1e3:.1f} ms; decode {t_dec*1e3:.1f} ms "
          f"({tps:.1f} tok/s); sample row: {out[0][:16].tolist()}")
    return out


if __name__ == "__main__":
    main()
