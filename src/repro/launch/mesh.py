"""Production mesh construction (see MULTI-POD DRY-RUN spec).

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state; the dry-run sets
XLA_FLAGS before any jax import to obtain 512 host devices.
"""

from __future__ import annotations

import jax

try:  # jax ≥ 0.5 exposes explicit axis types; older versions have neither
    from jax.sharding import AxisType  # type: ignore[attr-defined]
except ImportError:  # pragma: no cover - version-dependent
    AxisType = None


def _axis_type_kwargs(n_axes: int) -> dict:
    """``axis_types=(Auto, ...)`` where supported; {} on older jax (whose
    ``jax.make_mesh`` predates the parameter and defaults to auto anyway)."""
    if AxisType is None:
        return {}
    return {"axis_types": (AxisType.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s_ in shape:
        n *= s_
    # the dry-run spawns 512 host devices; the single-pod mesh uses the first
    # 256 of them
    devs = jax.devices()[:n]
    return jax.make_mesh(
        shape, axes, devices=devs, **_axis_type_kwargs(len(axes))
    )


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for unit tests (host devices)."""
    n = 1
    for s_ in shape:
        n *= s_
    return jax.make_mesh(
        shape, axes, devices=jax.devices()[:n], **_axis_type_kwargs(len(axes))
    )


# TPU v5e-class hardware constants used by the roofline (per chip).
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW_PER_LINK = 50e9  # B/s  (~50 GB/s/link)
