"""Dry-run cell for the paper's own pipeline (``--arch dibella``).

Lowers, on the production mesh, the two distributed matrix stages of
Algorithm 1/2 at H.-sapiens scale (Table IV):

  * overlap SpGEMM  C = A·Aᵀ  (position-pair semiring, 2D SUMMA all-gather)
  * transitive reduction loop on R (MinPlus semiring, sampled or full square)

Inputs are ShapeDtypeStructs — the 4.2M-read matrices are never allocated.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.semiring import minplus_orient_semiring as MPSR, overlap_semiring
from ..core.summa import DistEll, dist_transitive_reduction, summa_allgather
from ..core.spmat import EllMatrix


def build_cells(cfg, mesh, *, fused_tr: bool = True, row_chunk: int = 4096):
    """Returns {"overlap": (fn, args_sds), "tr": (fn, args_sds)} ready for
    ``fn.lower(*args).compile()``."""
    row_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    col_axis = "model"
    pc = mesh.shape[col_axis]
    n = cfg.n_reads
    m = cfg.m_kmers

    # ---- overlap: C = A (n×m, pos values) · Aᵀ (m×n) ----
    ka = pc * cfg.read_capacity
    ku = pc * cfg.kmer_capacity
    a_cols = jax.ShapeDtypeStruct((n, ka), jnp.int32)
    a_vals = {"pos": jax.ShapeDtypeStruct((n, ka), jnp.int32)}
    at_cols = jax.ShapeDtypeStruct((m, ku), jnp.int32)
    at_vals = {"pos": jax.ShapeDtypeStruct((m, ku), jnp.int32)}

    a_d = DistEll(
        mat=EllMatrix(cols=a_cols, vals=a_vals, n_cols=m),
        mesh=mesh, row_axes=row_axes, col_axis=col_axis,
    )
    at_d = DistEll(
        mat=EllMatrix(cols=at_cols, vals=at_vals, n_cols=n),
        mesh=mesh, row_axes=row_axes, col_axis=col_axis,
    )
    overlap_fn = summa_allgather(
        a_d, at_d, semiring=overlap_semiring,
        out_block_capacity=cfg.overlap_block_capacity,
        row_chunk=row_chunk, build_only=True,
    )
    overlap_args = (a_cols, a_vals, at_cols, at_vals)

    # ---- transitive reduction on R (n×n, MinPlus 4-vectors) ----
    kr = pc * cfg.r_block_capacity
    r_cols = jax.ShapeDtypeStruct((n, kr), jnp.int32)
    r_vals = jax.ShapeDtypeStruct((n, kr, 4), jnp.float32)
    r_d = DistEll(
        mat=EllMatrix(cols=r_cols, vals=r_vals, n_cols=n),
        mesh=mesh, row_axes=row_axes, col_axis=col_axis,
    )
    tr_fn = dist_transitive_reduction(
        r_d, cfg.tr_fuzz, fused=fused_tr, row_chunk=row_chunk,
        build_only=True,
    )
    tr_args = (r_cols, r_vals)
    return {"overlap": (overlap_fn, overlap_args), "tr": (tr_fn, tr_args)}
