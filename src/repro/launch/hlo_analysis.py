"""HLO post-partitioning analysis: collective bytes + while-loop awareness.

``cost_analysis()`` gives FLOPs/bytes but NOT collective traffic, so we parse
the compiled HLO text (§ROOFLINE spec) and sum the sizes of every
``all-gather`` / ``all-reduce`` / ``reduce-scatter`` / ``all-to-all`` /
``collective-permute`` instruction.

Conventions (XLA prints operand *names*, not shapes, so we use the
instruction's OUTPUT shape — stated per op):
  all-gather          output = full gathered buffer ≈ bytes received ×P/(P−1)
  all-reduce          output = payload exchanged (ring: 2×(P−1)/P × this)
  reduce-scatter      output = received shard (input = this × group)
  all-to-all          output = buffer resent
  collective-permute  output = bytes sent

While-loop handling: scanned layer stacks and the TR convergence loop appear
ONCE in HLO.  XLA stamps every instruction with
``metadata={op_name="jit(...)/.../while/body/..."}``; any collective whose
op_name contains ``/while/`` is multiplied by ``default_loop_trips`` (the
caller passes the known scan length / TR iteration count).

CPU-upcast correction: the XLA *CPU* backend converts bf16 dot operands to
f32, so collectives adjacent to matmuls are measured at 2× their TPU size
(verified: the gathered operands are ``convert*`` fusions).  f32 collectives
whose operand is produced by a convert fusion are additionally counted at
bf16 size in ``total_bytes_tpu_estimate`` — the number a TPU compile of the
same HLO would move.  Both totals are recorded.
"""

from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]*)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+(all-gather|all-reduce|reduce-scatter|"
    r"all-to-all|collective-permute)(-start|-done)?\("
)


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo: str, default_loop_trips: int = 1) -> Dict:
    """Loop-aware per-device collective byte count (see module docstring).
    Returns {"total_bytes", "by_op", "static_bytes", "flagged",
    "n_instructions"}."""
    # first pass: map instruction name -> producing line (for upcast check)
    defs: Dict[str, str] = {}
    for raw in hlo.splitlines():
        dm = re.match(r"\s*(?:ROOT )?%([\w\.\-]+) = ", raw)
        if dm:
            defs[dm.group(1)] = raw

    total = 0
    total_tpu = 0
    static = 0
    by_op: Dict[str, int] = {}
    n_inst = 0
    for raw in hlo.splitlines():
        ln = raw.strip()
        m = _OP_RE.search(ln)
        if not m:
            continue
        if m.group(3) == "-done":  # -start carries the shape; skip the pair
            continue
        out_part = m.group(1)
        op = m.group(2)
        b = 0
        f32_bytes = 0
        for dt, dims in _SHAPE_RE.findall(out_part):
            sb = _shape_bytes(dt, dims)
            b += sb
            if dt == "f32":
                f32_bytes += sb
        if b == 0:
            continue
        n_inst += 1
        meta = re.search(r'op_name="([^"]*)"', ln)
        in_loop = bool(meta and "/while/" in meta.group(1))
        trips = default_loop_trips if in_loop else 1
        # CPU-upcast detection: operand produced by a convert fusion
        b_tpu = b
        if f32_bytes:
            om = re.search(r"\(%([\w\.\-]+)[,)]", ln[ln.index(op):])
            if om and "convert" in defs.get(om.group(1), ""):
                b_tpu = b - f32_bytes // 2
        by_op[op] = by_op.get(op, 0) + b * trips
        total += b * trips
        total_tpu += b_tpu * trips
        static += b
    return {
        "total_bytes": total,
        "total_bytes_tpu_estimate": total_tpu,
        "by_op": by_op,
        "static_bytes": static,
        "flagged": False,
        "n_instructions": n_inst,
    }
