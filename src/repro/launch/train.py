"""End-to-end training driver with fault tolerance.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --reduced \
        --steps 50 --ckpt-dir /tmp/ckpt [--resume] [--compress int8]

Features (DESIGN.md §3): deterministic resume from the latest checkpoint
(data pipeline regenerates exactly the batches ≥ restored step), atomic async
checkpointing with keep-policy, straggler monitoring hooks, gradient
compression with error feedback, mesh-aware sharding (full configs) or
single-device (reduced/smoke).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import CheckpointManager, restore_latest
from ..configs import get_config, reduced_config
from ..data import SyntheticLMData
from ..models.model import init_params, loss_fn
from ..optim import AdamW, cosine_schedule
from ..runtime import CompressedAllReduce, StragglerMonitor
from ..runtime.sharding import apply_sharding_rules, batch_sharding


def make_state(cfg, opt, key, mesh=None, fsdp=True):
    params = init_params(cfg, key)
    if mesh is not None:
        params = jax.device_put(
            params, apply_sharding_rules(params, mesh, fsdp=fsdp)
        )
    opt_state = opt.init(params)
    return (params, opt_state, jnp.int32(0))


def build_train_step(cfg, opt, comp: CompressedAllReduce, mesh=None):
    def train_step(state, batch, err):
        params, opt_state, step = state
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch, cfg, mesh=mesh)
        )(params)
        if comp.mode != "none":
            grads, err = comp.compress_ef(grads, err)
        updates, opt_state = opt.update(grads, opt_state, params, step)
        params = jax.tree.map(lambda p, u: p + u, params, updates)
        gnorm = jnp.sqrt(
            sum(jnp.sum(g.astype(jnp.float32) ** 2)
                for g in jax.tree.leaves(grads))
        )
        return (params, opt_state, step + 1), err, {
            "loss": loss, "grad_norm": gnorm,
        }

    return jax.jit(train_step, donate_argnums=(0, 2))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--reduced", action="store_true",
                    help="train the smoke-scale config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress", choices=["none", "bf16", "int8"],
                    default="none")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args(argv)

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    opt = AdamW(learning_rate=cosine_schedule(args.lr, 10, args.steps))
    comp = CompressedAllReduce(mode=args.compress)
    key = jax.random.PRNGKey(args.seed)

    state = make_state(cfg, opt, key)
    err = comp.init_error(state[0]) if comp.mode != "none" else ()
    start_step = 0
    mgr = None
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, keep=3)
        if args.resume:
            restored, step = restore_latest(args.ckpt_dir, state)
            if restored is not None:
                state = restored
                start_step = int(state[2])
                print(f"[resume] restored step {start_step}")

    data = SyntheticLMData(
        vocab_size=cfg.vocab_size, batch_size=args.batch, seq_len=args.seq,
        seed=args.seed, frontend=cfg.frontend, d_model=cfg.d_model,
    )
    step_fn = build_train_step(cfg, opt, comp)
    monitor = StragglerMonitor(n_hosts=jax.process_count())

    losses = []
    for step in range(start_step, args.steps):
        t0 = time.perf_counter()
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}
        state, err, metrics = step_fn(state, batch, err)
        loss = float(metrics["loss"])
        losses.append(loss)
        dt = time.perf_counter() - t0
        monitor.report(jax.process_index(), dt)
        monitor.evaluate()
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {loss:8.4f} "
                  f"gnorm {float(metrics['grad_norm']):8.3f} {dt*1e3:7.1f} ms")
        if mgr and (step + 1) % args.ckpt_every == 0:
            mgr.save(step + 1, state, meta={"arch": cfg.name})
    if mgr:
        mgr.save(args.steps, state, meta={"arch": cfg.name})
        mgr.wait()
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
    return losses


if __name__ == "__main__":
    main()
