"""Roofline terms from a compiled dry-run artifact (§ROOFLINE ANALYSIS).

    compute    = HLO_FLOPs / (chips × 197e12)
    memory     = HLO_bytes / (chips × 819e9)
    collective = collective_bytes / (chips × 50e9 × links)

``cost_analysis()`` on the SPMD-partitioned module reports PER-DEVICE
FLOPs/bytes (the partitioned module is the per-device program), so we
multiply by the device count to get the global numerator, then divide again —
i.e. the per-device analysis IS the per-chip term; we keep both conventions
explicit in the record.  Collective bytes are per-device from the parsed HLO.

MODEL_FLOPS = 6·N·D for training (2·N fwd + 4·N bwd per token), 2·N_active·D
for decode forward; the ratio MODEL_FLOPS/HLO_FLOPs measures how much
compiled compute is "useful" (catches remat/causal-mask overcounting).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from .mesh import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    model_flops_global: float
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    bottleneck: str = ""
    useful_ratio: float = 0.0
    peak_memory_bytes: float = 0.0
    loop_flagged: bool = False

    def finalize(self, ici_links: int = 4):
        self.compute_s = self.flops_per_device / PEAK_FLOPS_BF16
        self.memory_s = self.bytes_per_device / HBM_BW
        self.collective_s = self.collective_bytes_per_device / (
            ICI_BW_PER_LINK * ici_links
        )
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        self.bottleneck = max(terms, key=terms.get)
        total_hlo_flops = self.flops_per_device * self.chips
        self.useful_ratio = (
            self.model_flops_global / total_hlo_flops if total_hlo_flops else 0.0
        )
        return self

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


def model_flops(cfg, shape_kind: str, seq_len: int, global_batch: int) -> float:
    """6·N·D train / 2·N_active·D decode-or-prefill forward."""
    n_active = cfg.active_param_count()
    if shape_kind == "train":
        tokens = seq_len * global_batch
        return 6.0 * n_active * tokens
    if shape_kind == "prefill":
        tokens = seq_len * global_batch
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * global_batch


def roofline_fraction(t: RooflineTerms) -> float:
    """Fraction of the dominant-term-bound runtime that is useful compute:
    (MODEL_FLOPS/chips/peak) / max(term).  1.0 = at the roofline."""
    ideal = (t.model_flops_global / t.chips) / PEAK_FLOPS_BF16
    dom = max(t.compute_s, t.memory_s, t.collective_s)
    return ideal / dom if dom > 0 else 0.0


# ---------------------------------------------------------------------------
# Analytic per-cell cost model.
#
# XLA's cost_analysis() counts while-loop bodies ONCE, so scanned layer
# stacks / CE chunks / flash blocks are undercounted by their trip counts
# (verified: gemma3 prefill HLO flops ≈ model/34).  The roofline compute and
# memory terms therefore come from this analytic model (exact for our own
# implementation — including the full-rectangle flash attention and the
# GShard dispatch); the raw HLO numbers are recorded alongside as
# `*_hlo_raw`.  Collective bytes stay HLO-parsed (the parser multiplies
# loop-body collectives by trip count via op_name metadata).
# ---------------------------------------------------------------------------


def _attn_flops(cfg, b, sq, skv, *, train):
    hq, dh = cfg.n_heads, cfg.head_dim
    d = cfg.d_model
    hkv = cfg.n_kv_heads
    proj = 2 * b * sq * d * (hq * dh) + 2 * 2 * b * sq * d * (hkv * dh) \
        + 2 * b * sq * (hq * dh) * d
    # our flash computes the full S×S rectangle then masks (DESIGN.md §8)
    core = 2 * 2 * b * hq * sq * skv * dh
    return (proj + core) * (3 if train else 1)


def _mlp_flops(cfg, b, s, *, train):
    d = cfg.d_model
    if cfg.family == "moe":
        per_tok = 3 * 2 * d * cfg.d_ff_expert * cfg.top_k
        if cfg.d_ff_shared:
            per_tok += 3 * 2 * d * cfg.d_ff_shared
        per_tok += 2 * d * cfg.n_experts_padded  # router
    elif cfg.mlp_type == "gelu":
        per_tok = 2 * 2 * d * cfg.d_ff
    elif cfg.d_ff:
        per_tok = 3 * 2 * d * cfg.d_ff
    else:
        per_tok = 0
    return per_tok * b * s * (3 if train else 1)


def _ssd_flops(cfg, b, s, *, train, decode=False):
    if cfg.family not in ("ssm", "hybrid"):
        return 0
    from ..models.ssm import mamba2_params_shapes

    dims = mamba2_params_shapes(
        cfg.d_model, expand=cfg.ssm_expand, headdim=cfg.ssm_headdim,
        state=cfg.ssm_state, conv_width=cfg.conv_width,
    )
    di, h, n = dims["d_inner"], dims["n_heads"], cfg.ssm_state
    p = di // h
    d = cfg.d_model
    proj = 2 * b * s * d * dims["in_features"] + 2 * b * s * di * d
    conv = 2 * b * s * dims["conv_dim"] * cfg.conv_width
    if decode:
        core = 2 * b * h * n * p * 2  # state update + readout
    else:
        q = min(cfg.ssd_chunk, s)
        nc = -(-s // q)
        intra = nc * (2 * b * q * q * n + 2 * b * q * q * h
                      + 2 * b * q * q * h * p)
        inter = nc * (2 * b * h * n * p * q * 2)
        core = intra + inter
    return (proj + conv + core) * (3 if train else 1)


def _ce_flops(cfg, b, s):
    return 3 * 2 * b * s * cfg.d_model * cfg.vocab_padded  # fwd+bwd


def analytic_costs(cfg, shape_kind: str, seq_len: int, global_batch: int,
                   chips: int):
    """(flops_per_chip, bytes_per_chip) for one step of this cell."""
    b = global_batch
    train = shape_kind == "train"
    if shape_kind == "decode":
        sq, skv = 1, seq_len
    else:
        sq = skv = seq_len

    per_layer = 0
    if cfg.family in ("dense", "moe", "audio", "vlm"):
        per_layer = _attn_flops(cfg, b, sq, skv, train=train) \
            + _mlp_flops(cfg, b, sq, train=train)
    elif cfg.family == "ssm":
        per_layer = _ssd_flops(cfg, b, sq, train=train,
                               decode=shape_kind == "decode")
    elif cfg.family == "hybrid":
        # hymba: most layers sliding-window — cap skv at the window
        skv_eff = min(skv, cfg.sliding_window or skv)
        per_layer = _attn_flops(cfg, b, sq, skv_eff, train=train) \
            + _ssd_flops(cfg, b, sq, train=train,
                         decode=shape_kind == "decode") \
            + _mlp_flops(cfg, b, sq, train=train)
    if cfg.family == "dense" and cfg.local_global_every:
        # gemma3: 5/6 of layers see only the window
        skv_loc = min(skv, cfg.sliding_window or skv)
        loc = _attn_flops(cfg, b, sq, skv_loc, train=train) \
            + _mlp_flops(cfg, b, sq, train=train)
        n_glob = cfg.n_layers // cfg.local_global_every
        flops = (cfg.n_layers - n_glob) * loc + n_glob * per_layer
    else:
        flops = cfg.n_layers * per_layer
    if train:
        flops += _ce_flops(cfg, b, sq)
    else:
        flops += 2 * b * sq * cfg.d_model * cfg.vocab_padded  # head fwd

    # ---- bytes (HBM traffic model, per chip) ----
    n_params = cfg.param_count()
    dt = 2  # bf16 compute reads
    if train:
        # params: read fwd + read bwd (remat ⇒ ×2 fwd reads) + grad write
        # + AdamW (read p,m,v + write p,m,v) in fp32
        param_traffic = n_params * (3 * dt + 4 + 6 * 4)
        act = 2 * b * sq * cfg.d_model * dt  # residual stream w+r per layer
        act_traffic = cfg.n_layers * 6 * act  # qkv/mlp intermediates ~6×
        logits = 2 * b * sq * cfg.vocab_padded * 4 / max(1, 1)
        total_bytes = param_traffic + act_traffic + logits
    elif shape_kind == "prefill":
        param_traffic = n_params * dt
        act_traffic = cfg.n_layers * 6 * b * sq * cfg.d_model * dt
        cache_w = cfg.n_layers * 2 * b * sq * cfg.n_kv_heads * cfg.head_dim * dt
        total_bytes = param_traffic + act_traffic + cache_w
    else:  # decode: read all params + full KV cache once per token
        param_traffic = n_params * dt
        if cfg.family == "ssm":
            cache = 0  # O(1) state
        else:
            kv_len = skv
            if cfg.family == "hybrid":
                kv_len = min(skv, cfg.sliding_window or skv)
            cache = cfg.n_layers * 2 * b * kv_len * cfg.n_kv_heads \
                * cfg.head_dim * dt
            if cfg.local_global_every:
                n_glob = cfg.n_layers // cfg.local_global_every
                loc_len = min(skv, cfg.sliding_window or skv)
                cache = (cfg.n_layers - n_glob) * 2 * b * loc_len \
                    * cfg.n_kv_heads * cfg.head_dim * dt \
                    + n_glob * 2 * b * skv * cfg.n_kv_heads * cfg.head_dim * dt
        total_bytes = param_traffic + cache
    return flops / chips, total_bytes / chips
