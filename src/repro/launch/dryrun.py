import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import: jax locks the device
# count at first backend init (MULTI-POD DRY-RUN spec, step 0).

import argparse  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from ..configs import (  # noqa: E402
    ALL_NAMES,
    SHAPES,
    batch_specs,
    cache_specs,
    get_config,
    runs_cell,
)
from ..models.model import init_params, make_train_step, make_serve_step, make_prefill_step, init_cache  # noqa: E402
from ..optim import AdamW, cosine_schedule  # noqa: E402
from ..runtime.sharding import apply_sharding_rules, batch_sharding, cache_sharding  # noqa: E402
from .hlo_analysis import collective_bytes  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402
from .roofline import (  # noqa: E402
    RooflineTerms,
    analytic_costs,
    model_flops,
    roofline_fraction,
)

CACHE_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                         "experiments", "dryrun")


def _sds_like(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree
    )


def _serve_dtype(tree):
    def cast(s):
        if s.dtype == jnp.float32:
            return jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
        return s

    return jax.tree.map(cast, tree)


def lower_cell(arch: str, shape_name: str, multi_pod: bool, *,
               fsdp: bool = True, moe_impl: str | None = None,
               summa_variant: str = "allgather", tr_variant: str = "fused",
               mixed_precision: bool = False, cfg_overrides: dict | None = None):
    """Lower + compile one (arch × shape × mesh) cell; returns result dict."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = len(mesh.devices.flatten())
    t0 = time.time()

    if arch == "dibella":
        from .dibella_cell import build_cells

        cfg = get_config(arch)
        if cfg_overrides:
            import dataclasses

            cfg = dataclasses.replace(cfg, **cfg_overrides)
        cells = build_cells(cfg, mesh, fused_tr=(tr_variant == "fused"))
        out = {"arch": arch, "shape": shape_name, "mesh": "multi" if multi_pod else "single",
               "chips": chips, "stages": {}}
        tot_flops = tot_bytes = tot_coll = 0.0
        peak_mem = 0
        # loop-trip correction for HLO cost_analysis (bodies counted once):
        # row-chunk lax.map lowers to a while loop; the TR loop adds ×iters.
        pr = chips // mesh.shape["model"]
        n_chunks = max(1, (cfg.n_reads // pr) // 4096)
        tr_iters = 3  # paper §V-D: small constant
        for stage, (fn, args) in cells.items():
            lo = fn.lower(*args)
            co = lo.compile()
            ca = co.cost_analysis() or {}
            ma = co.memory_analysis()
            trips = n_chunks if stage == "overlap" else tr_iters * n_chunks
            cb = collective_bytes(co.as_text(), default_loop_trips=tr_iters)
            stage_mem = (ma.argument_size_in_bytes + ma.temp_size_in_bytes
                         + ma.output_size_in_bytes)
            peak_mem = max(peak_mem, stage_mem)
            out["stages"][stage] = {
                "loop_trip_multiplier": trips,
                "flops_per_device": float(ca.get("flops", 0.0)) * trips,
                "bytes_per_device": float(ca.get("bytes accessed", 0.0)) * trips,
                "collective_bytes_per_device": float(cb["total_bytes"]),
                "collective_by_op": cb["by_op"],
                "memory": {
                    "argument": ma.argument_size_in_bytes,
                    "temp": ma.temp_size_in_bytes,
                    "output": ma.output_size_in_bytes,
                },
            }
            tot_flops += float(ca.get("flops", 0.0)) * trips
            tot_bytes += float(ca.get("bytes accessed", 0.0)) * trips
            tot_coll += float(cb["total_bytes"])
        # MODEL_FLOPS analogue: semiring ops of the sampled TR + overlap
        # (each candidate k-mer pair = 1 ⊗; each TR candidate = 8 add+min)
        pc = mesh.shape["model"]
        model_ops = (
            cfg.n_reads * (pc * cfg.read_capacity) * cfg.kmer_capacity
            + 3 * cfg.n_reads * (pc * cfg.r_block_capacity) ** 2 * 8
        )
        terms = RooflineTerms(
            arch=arch, shape=shape_name,
            mesh="multi" if multi_pod else "single", chips=chips,
            flops_per_device=tot_flops, bytes_per_device=tot_bytes,
            collective_bytes_per_device=tot_coll,
            model_flops_global=float(model_ops),
            peak_memory_bytes=float(peak_mem),
        ).finalize()
        out["roofline"] = terms.to_dict()
        out["roofline_fraction"] = roofline_fraction(terms)
        out["compile_seconds"] = time.time() - t0
        return out

    cfg = get_config(arch)
    import dataclasses

    if moe_impl:
        cfg = dataclasses.replace(cfg, moe_impl=moe_impl)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    if not runs_cell(arch, shape_name):
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": "pure full-attention arch at 524k decode "
                          "(DESIGN.md §4)"}

    batch_sds = batch_specs(cfg, shape)
    params_sds = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    p_shardings = apply_sharding_rules(params_sds, mesh, fsdp=fsdp)
    b_sharding = jax.tree.map(
        lambda sds: batch_sharding(mesh, sds.shape[0]), batch_sds
    )

    if shape.kind == "train":
        opt = AdamW(learning_rate=cosine_schedule(3e-4, 100, 10000))
        opt_sds = jax.eval_shape(lambda: opt.init(params_sds))
        o_shardings = type(opt_sds)(
            mu=jax.tree.map(lambda s: s, p_shardings),
            nu=jax.tree.map(lambda s: s, p_shardings),
        )
        step_sds = jax.ShapeDtypeStruct((), jnp.int32)
        state_sds = (params_sds, opt_sds, step_sds)
        from jax.sharding import NamedSharding, PartitionSpec as P

        state_sh = (p_shardings, o_shardings, NamedSharding(mesh, P()))
        fn = make_train_step(cfg, opt, mesh=mesh,
                             mixed_precision=mixed_precision)
        lowered = jax.jit(
            fn, in_shardings=(state_sh, b_sharding), donate_argnums=(0,)
        ).lower(state_sds, batch_sds)
        loop_trips = cfg.n_periods
    elif shape.kind == "prefill":
        params_serve = _serve_dtype(params_sds)
        ps = apply_sharding_rules(params_serve, mesh, fsdp=False)
        caches = cache_specs(cfg, shape)
        c_shard = cache_sharding(mesh, caches, seq_sharded=True)
        fn = make_prefill_step(cfg, mesh=mesh)
        lowered = jax.jit(
            fn, in_shardings=(ps, c_shard, b_sharding), donate_argnums=(1,)
        ).lower(params_serve, caches, batch_sds)
        loop_trips = cfg.n_periods
    else:  # decode
        params_serve = _serve_dtype(params_sds)
        ps = apply_sharding_rules(params_serve, mesh, fsdp=False)
        caches = cache_specs(cfg, shape)
        seq_sharded = True
        c_shard = cache_sharding(mesh, caches, seq_sharded=seq_sharded)
        fn = make_serve_step(
            cfg, mesh=mesh,
            seq_shards=mesh.shape["model"] if seq_sharded else 1,
        )
        pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
        from jax.sharding import NamedSharding, PartitionSpec as P

        lowered = jax.jit(
            fn,
            in_shardings=(ps, c_shard, b_sharding, NamedSharding(mesh, P())),
            donate_argnums=(1,),
        ).lower(params_serve, caches, batch_sds, pos_sds)
        loop_trips = cfg.n_periods

    compiled = lowered.compile()
    ca = compiled.cost_analysis() or {}
    ma = compiled.memory_analysis()
    cb = collective_bytes(compiled.as_text(), default_loop_trips=loop_trips)
    mf = model_flops(cfg, shape.kind, shape.seq_len, shape.global_batch)
    # analytic compute/memory (HLO cost_analysis counts while bodies once —
    # see roofline.py); HLO raw numbers recorded alongside.
    an_flops, an_bytes = analytic_costs(
        cfg, shape.kind, shape.seq_len, shape.global_batch, chips
    )
    terms = RooflineTerms(
        arch=arch, shape=shape_name,
        mesh="multi" if multi_pod else "single", chips=chips,
        flops_per_device=an_flops,
        bytes_per_device=an_bytes,
        collective_bytes_per_device=float(
            cb.get("total_bytes_tpu_estimate", cb["total_bytes"])
        ),
        model_flops_global=mf,
        peak_memory_bytes=float(
            ma.argument_size_in_bytes + ma.temp_size_in_bytes
        ),
        loop_flagged=cb["flagged"],
    ).finalize()
    return {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "chips": chips,
        "memory": {
            "argument_bytes_per_device": ma.argument_size_in_bytes,
            "temp_bytes_per_device": ma.temp_size_in_bytes,
            "output_bytes_per_device": ma.output_size_in_bytes,
            "fits_16GB": bool(
                ma.argument_size_in_bytes + ma.temp_size_in_bytes < 16e9
            ),
        },
        "cost_hlo_raw": {k: float(v) for k, v in ca.items()
                         if k in ("flops", "bytes accessed")},
        "collectives": cb["by_op"],
        "collective_bytes": cb["total_bytes"],
        "collective_bytes_tpu_estimate": cb.get(
            "total_bytes_tpu_estimate", cb["total_bytes"]),
        "roofline": terms.to_dict(),
        "roofline_fraction": roofline_fraction(terms),
        "compile_seconds": time.time() - t0,
    }


def cell_path(arch, shape, mesh_kind, tag=""):
    os.makedirs(CACHE_DIR, exist_ok=True)
    t = f"_{tag}" if tag else ""
    return os.path.join(CACHE_DIR, f"{arch}__{shape}__{mesh_kind}{t}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--fsdp", action="store_true", default=True)
    ap.add_argument("--no-fsdp", dest="fsdp", action="store_false")
    ap.add_argument("--moe-impl", default=None)
    ap.add_argument("--tr-variant", default="fused")
    ap.add_argument("--mixed-precision", action="store_true")
    ap.add_argument("--ssd-bf16", action="store_true")
    ap.add_argument("--batch-over-model", action="store_true")
    ap.add_argument("--sharded-cache-update", action="store_true")
    ap.add_argument("--ce-chunk", type=int, default=None)
    ap.add_argument("--dibella-u", type=int, default=None)
    ap.add_argument("--bf16-grad-act", action="store_true")
    ap.add_argument("--decode-unroll", action="store_true")
    ap.add_argument("--ssd-chunk", type=int, default=None)
    ap.add_argument("--tag", default="")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    if args.all:
        # enumerate the full matrix as subprocesses (isolation per compile)
        import subprocess

        cells = []
        for arch in ALL_NAMES:
            shapes = ["train_4k"] if arch == "dibella" else list(SHAPES)
            for shape in shapes:
                for mk in ("single", "multi"):
                    cells.append((arch, shape, mk))
        for arch, shape, mk in cells:
            path = cell_path(arch, shape, mk, args.tag)
            if os.path.exists(path) and not args.force:
                print(f"[cached] {arch} {shape} {mk}")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch",
                   arch, "--shape", shape, "--mesh", mk]
            if args.tag:
                cmd += ["--tag", args.tag]
            print(f"[run] {arch} {shape} {mk}", flush=True)
            r = subprocess.run(cmd, capture_output=True, text=True)
            if r.returncode != 0:
                print(r.stdout[-2000:])
                print(r.stderr[-4000:])
                print(f"[FAIL] {arch} {shape} {mk}")
        return

    overrides = {}
    if args.ssd_bf16:
        overrides["ssd_bf16"] = True
    if args.batch_over_model:
        overrides["batch_over_model"] = True
    if args.sharded_cache_update:
        overrides["sharded_cache_update"] = True
    if args.ce_chunk:
        overrides["ce_chunk"] = args.ce_chunk
    if args.dibella_u:
        overrides["kmer_capacity"] = args.dibella_u
    if args.bf16_grad_act:
        overrides["bf16_grad_activations"] = True
    if args.decode_unroll:
        overrides["decode_unroll"] = True
    if args.ssd_chunk:
        overrides["ssd_chunk"] = args.ssd_chunk
    res = lower_cell(
        args.arch, args.shape, args.mesh == "multi", fsdp=args.fsdp,
        moe_impl=args.moe_impl, tr_variant=args.tr_variant,
        mixed_precision=args.mixed_precision, cfg_overrides=overrides or None,
    )
    path = cell_path(args.arch, args.shape, args.mesh, args.tag)
    with open(path, "w") as f:
        json.dump(res, f, indent=1)
    if res.get("skipped"):
        print(f"SKIP {args.arch} {args.shape}: {res['reason']}")
        return
    print(json.dumps(
        {k: res.get(k) for k in ("arch", "shape", "mesh", "chips",
                                 "collective_bytes", "roofline_fraction",
                                 "compile_seconds")},
        indent=1,
    ))
    print("memory:", res.get("memory") or res.get("stages", {}).keys())
    rt = res["roofline"]
    print(f"terms: compute={rt['compute_s']:.4e}s memory={rt['memory_s']:.4e}s "
          f"collective={rt['collective_s']:.4e}s -> {rt['bottleneck']}")


if __name__ == "__main__":
    main()
