# LM substrate: the 10 assigned architectures (dense / MoE / SSM / hybrid /
# audio / VLM backbones) as pure-JAX modules with mesh-aware sharding.
from .model import ModelConfig, init_params, make_train_step, make_serve_step  # noqa: F401
