"""Attention: chunked (flash-style) training/prefill path + split-KV decode.

* ``flash_attention`` — pure-JAX online-softmax attention, scanned over query
  and KV blocks so the S×S score matrix is never materialized (required at
  32k prefill; a 32768² f32 score buffer would be 4 GB/head).  Supports GQA,
  causal masking, and sliding windows.
* ``decode_attention`` — one-token attention over a KV cache.  When the cache
  is sequence-sharded (long contexts), ``decode_attention_sharded`` runs the
  flash-decoding split-KV merge under shard_map: each model-shard computes
  local (m, l, o) statistics over its KV slice and the merge is two psums and
  a pmax — the TPU-native analogue of FlashDecoding.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..compat import shard_map

NEG_INF = -1e30


def _gqa_expand(q, n_kv: int):
    """(B, S, Hq, D) -> (B, S, Hkv, G, D)."""
    b, s, hq, d = q.shape
    return q.reshape(b, s, n_kv, hq // n_kv, d)


def flash_attention(
    q: jnp.ndarray,  # (B, Sq, Hq, D)
    k: jnp.ndarray,  # (B, Skv, Hkv, D)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,  # global position of q[0] (for cached prefill)
    q_block: int = 512,
    kv_block: int = 512,
) -> jnp.ndarray:
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    qb = min(q_block, sq)
    kb = min(kv_block, skv)
    n_qb = -(-sq // qb)
    n_kb = -(-skv // kb)
    # pad to block multiples
    q = jnp.pad(q, ((0, 0), (0, n_qb * qb - sq), (0, 0), (0, 0)))
    k = jnp.pad(k, ((0, 0), (0, n_kb * kb - skv), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, n_kb * kb - skv), (0, 0), (0, 0)))
    qr = q.reshape(b, n_qb, qb, hkv, g, d).transpose(1, 0, 3, 4, 2, 5)
    kr = k.reshape(b, n_kb, kb, hkv, d).transpose(1, 0, 3, 2, 4)
    vr = v.reshape(b, n_kb, kb, hkv, d).transpose(1, 0, 3, 2, 4)
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)

    def q_step(_, qi_and_blk):
        qi, qblk = qi_and_blk  # qblk: (B, Hkv, G, qb, D)
        qpos = q_offset + qi * qb + jnp.arange(qb)

        @jax.checkpoint  # flash backward recomputes p; never store S² scores
        def kv_step(carry, ki_and_kv):
            m, l, acc = carry
            ki, kblk, vblk = ki_and_kv  # (B, Hkv, kb, D)
            kpos = ki * kb + jnp.arange(kb)
            s_ = jnp.einsum(
                "bhgqd,bhkd->bhgqk", qblk, kblk,
                preferred_element_type=jnp.float32,
            ) * scale
            mask = jnp.ones((qb, kb), bool)
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            if window is not None:
                mask &= kpos[None, :] > qpos[:, None] - window
            mask &= (kpos < skv)[None, :]
            s_ = jnp.where(mask[None, None, None], s_, NEG_INF)
            m2 = jnp.maximum(m, jnp.max(s_, axis=-1))
            p = jnp.exp(s_ - m2[..., None])
            corr = jnp.exp(m - m2)
            l2 = l * corr + jnp.sum(p, axis=-1)
            acc2 = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p.astype(vblk.dtype), vblk,
                preferred_element_type=jnp.float32,
            )
            return (m2, l2, acc2), None

        m0 = jnp.full((b, hkv, g, qb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, qb), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, qb, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(n_kb), kr, vr)
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(qblk.dtype)

    _, outs = jax.lax.scan(
        jax.checkpoint(q_step), None, (jnp.arange(n_qb), qr)
    )
    # outs: (n_qb, B, Hkv, G, qb, D)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, n_qb * qb, hq, d)
    return out[:, :sq].astype(q.dtype)


def decode_attention(
    q: jnp.ndarray,  # (B, 1, Hq, D)
    k_cache: jnp.ndarray,  # (B, S, Hkv, D)
    v_cache: jnp.ndarray,
    cur_len: jnp.ndarray,  # (B,) or scalar: valid cache length
    *,
    window: int | None = None,
) -> jnp.ndarray:
    """Single-token attention over the cache (dense; cache fits per device)."""
    b, s, hkv, d = k_cache.shape
    hq = q.shape[2]
    g = hq // hkv
    qr = q.reshape(b, hkv, g, d)
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    s_ = jnp.einsum(
        "bhgd,bshd->bhgs", qr, k_cache, preferred_element_type=jnp.float32
    ) * scale
    pos = jnp.arange(s)
    cur = jnp.asarray(cur_len)
    cur = cur[:, None] if cur.ndim == 1 else cur
    mask = pos[None, :] < cur
    if window is not None:
        mask &= pos[None, :] >= cur - window
    s_ = jnp.where(mask[:, None, None, :], s_, NEG_INF)
    p = jax.nn.softmax(s_, axis=-1)
    out = jnp.einsum(
        "bhgs,bshd->bhgd", p.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(b, 1, hq, d).astype(q.dtype)


def decode_attention_sharded(
    q, k_cache, v_cache, cur_len, *, mesh, seq_axis: str = "model",
    window=None,
):
    """FlashDecoding-style split-KV decode: the cache's sequence dim is
    sharded over ``seq_axis`` (batch stays sharded over the data axes); each
    shard computes local softmax statistics and the merge is pmax + two psums
    (DESIGN.md §Perf)."""
    from jax.sharding import PartitionSpec as P

    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_shards = mesh.shape[seq_axis]
    n_dp = 1
    for a in dp_axes:
        n_dp *= mesh.shape[a]
    bg, s, hkv, d = k_cache.shape
    b = bg // max(1, n_dp) if bg % max(1, n_dp) == 0 else bg
    dp_axes = dp_axes if bg % max(1, n_dp) == 0 else ()
    s_loc = s // n_shards
    hq = q.shape[2]
    g = hq // hkv
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)

    def f(q, kc, vc, cur):
        idx = jax.lax.axis_index(seq_axis)
        qr = q.reshape(b, hkv, g, d)
        s_ = jnp.einsum(
            "bhgd,bshd->bhgs", qr, kc, preferred_element_type=jnp.float32
        ) * scale
        pos = idx * s_loc + jnp.arange(s_loc)
        cur2 = jnp.asarray(cur).reshape(b, 1)
        mask = pos[None, :] < cur2
        if window is not None:
            mask &= pos[None, :] >= cur2 - window
        s_ = jnp.where(mask[:, None, None, :], s_, NEG_INF)
        m_loc = jnp.max(s_, axis=-1)
        m = jax.lax.pmax(m_loc, seq_axis)
        p = jnp.exp(s_ - m[..., None])
        l = jax.lax.psum(jnp.sum(p, axis=-1), seq_axis)
        o = jax.lax.psum(
            jnp.einsum(
                "bhgs,bshd->bhgd", p.astype(vc.dtype), vc,
                preferred_element_type=jnp.float32,
            ),
            seq_axis,
        )
        out = o / jnp.maximum(l, 1e-30)[..., None]
        return out.reshape(b, 1, hq, d).astype(q.dtype)

    dp = dp_axes if dp_axes else None
    return shard_map(
        f,
        mesh=mesh,
        in_specs=(
            P(dp), P(dp, seq_axis), P(dp, seq_axis), P(dp),
        ),
        out_specs=P(dp),
    )(q, k_cache, v_cache, cur_len)


def cache_update(k_cache, v_cache, k_new, v_new, pos):
    """Write k/v_new (B, S_new, Hkv, D) at position ``pos`` (scalar)."""
    k_cache = jax.lax.dynamic_update_slice(
        k_cache, k_new.astype(k_cache.dtype), (0, pos, 0, 0)
    )
    v_cache = jax.lax.dynamic_update_slice(
        v_cache, v_new.astype(v_cache.dtype), (0, pos, 0, 0)
    )
    return k_cache, v_cache


def cache_update_sharded(k_cache, v_cache, k_new, v_new, pos, *, mesh,
                         seq_axis: str = "model"):
    """Owner-writes single-token cache update for a sequence-sharded cache
    (§Perf: the GSPMD dynamic_update_slice on a seq-sharded cache gathers the
    whole cache to every device; here only the owning shard writes)."""
    from jax.sharding import PartitionSpec as P

    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_dp = 1
    for a in dp_axes:
        n_dp *= mesh.shape[a]
    bg = k_cache.shape[0]
    dp = dp_axes if bg % max(1, n_dp) == 0 else None

    def f(kc, vc, kn, vn):
        s_loc = kc.shape[1]
        idx = jax.lax.axis_index(seq_axis)
        local = pos - idx * s_loc
        owner = (local >= 0) & (local < s_loc)
        safe = jnp.clip(local, 0, s_loc - 1)
        kw = jax.lax.dynamic_update_slice(
            kc, kn.astype(kc.dtype), (0, safe, 0, 0))
        vw = jax.lax.dynamic_update_slice(
            vc, vn.astype(vc.dtype), (0, safe, 0, 0))
        kc2 = jnp.where(owner, kw, kc)
        vc2 = jnp.where(owner, vw, vc)
        return kc2, vc2

    return shard_map(
        f, mesh=mesh,
        in_specs=(P(dp, seq_axis), P(dp, seq_axis), P(dp), P(dp)),
        out_specs=(P(dp, seq_axis), P(dp, seq_axis)),
        check_vma=False,  # owner-write: result provably consistent per shard
    )(k_cache, v_cache, k_new, v_new)
