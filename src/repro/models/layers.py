"""Shared transformer layers (pure JAX, mesh-aware via logical specs).

Params are nested dicts of fp32 arrays; compute casts to the config dtype
(bf16 by default) with fp32 softmax/normalization statistics.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


def rope_freqs(positions: jnp.ndarray, d_head: int, theta: float) -> tuple:
    """positions (...,) -> cos/sin (..., d_head//2), fp32."""
    half = d_head // 2
    inv = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def _rope_rotate(x, cos, sin, sign):
    d = x.shape[-1]
    x1, x2 = x[..., : d // 2], x[..., d // 2 :]
    c = cos[..., None, :]  # broadcast over heads
    s = sign * sin[..., None, :]
    out1 = x1 * c - x2 * s
    out2 = x2 * c + x1 * s
    return jnp.concatenate([out1, out2], axis=-1).astype(x.dtype)


@jax.custom_vjp
def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x (..., S, H, D); cos/sin (..., S, D//2). Rotate-half convention.

    custom_vjp: the transpose of a rotation is the inverse rotation; doing it
    explicitly keeps the cotangent in x's dtype — without this the f32
    cos/sin promote every q/k/v cotangent (and every backward collective
    downstream of them) to f32.  Forward math is unchanged (f32 angles)."""
    return _rope_rotate(x, cos, sin, 1.0)


def _rope_fwd(x, cos, sin):
    return _rope_rotate(x, cos, sin, 1.0), (cos, sin)


def _rope_bwd(res, g):
    cos, sin = res
    dx = _rope_rotate(g, cos, sin, -1.0)  # exact transpose, cast to g.dtype
    return (dx, jnp.zeros_like(cos), jnp.zeros_like(sin))


apply_rope.defvjp(_rope_fwd, _rope_bwd)


def dense(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    return jnp.einsum("...d,df->...f", x, w.astype(x.dtype))


def swiglu(x: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray, w_down: jnp.ndarray):
    g = dense(x, w_gate)
    u = dense(x, w_up)
    return dense(jax.nn.silu(g) * u, w_down)


def init_dense(key, d_in: int, d_out: int, scale: float | None = None):
    scale = scale if scale is not None else (1.0 / jnp.sqrt(d_in))
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(
        jnp.float32
    )


def split_keys(key, n: int):
    return list(jax.random.split(key, n))


def param_count(params: Any) -> int:
    return sum(int(p.size) for p in jax.tree.leaves(params))
