"""Mixture-of-Experts FFN (qwen2-moe: 60 routed top-4 + shared; granite-moe:
32 routed top-8).

Two dispatch implementations (both capacity-bounded, GShard-style):

* ``moe_ffn_gspmd`` — one-hot cumsum positions + scatter into an (E, C, D)
  buffer, sharding left to GSPMD (baseline; the compiler's collective choice
  for the scatter is part of the §Perf story).
* ``moe_ffn_shardmap`` — explicit expert parallelism: activations are
  replicated across the "model" axis (they already are, post-attention in a
  Megatron block), each shard dispatches *locally* to its E/tp experts and the
  combine is the same psum the TP MLP needs anyway.  No all-to-all at all.
  This reuses the capacity-bounded static-shape idiom of ``core/spmat.py``
  (token→expert dispatch is a sparse boolean matrix, DESIGN.md §4).

Expert counts are padded to a multiple of the model-axis size (60 → 64 for
qwen2-moe); padded experts get −inf router logits and zero weights.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..compat import shard_map
from .layers import dense


def router_topk(x, w_router, n_experts_real: int, top_k: int):
    """Returns (weights (T, K) fp32, idx (T, K) int32)."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), w_router)
    e_pad = w_router.shape[1]
    if e_pad > n_experts_real:
        pad_mask = jnp.arange(e_pad) >= n_experts_real
        logits = jnp.where(pad_mask[None, :], -1e30, logits)
    topv, topi = jax.lax.top_k(logits, top_k)
    w = jax.nn.softmax(topv, axis=-1)
    return w, topi.astype(jnp.int32)


def expert_ffn(xe, w_gate, w_up, w_down):
    """xe (E, C, D); weights (E, D, F)/(E, F, D)."""
    g = jnp.einsum("ecd,edf->ecf", xe, w_gate.astype(xe.dtype))
    u = jnp.einsum("ecd,edf->ecf", xe, w_up.astype(xe.dtype))
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, w_down.astype(xe.dtype))


def _dispatch_combine(x, w, idx, params, capacity: int):
    """Shared dispatch→FFN→combine given (T,K) routing. O(T·K·E) bookkeeping
    ints + (E, C, D) buffer."""
    t, d = x.shape
    k = idx.shape[1]
    e = params["w_gate"].shape[0]
    flat_e = idx.reshape(t * k)
    flat_w = w.reshape(t * k)
    oh = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)  # (T·K, E)
    pos = jnp.cumsum(oh, axis=0) - 1
    flat_pos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = flat_pos < capacity
    safe_e = jnp.where(keep, flat_e, e)  # dummy expert row for overflow
    safe_p = jnp.where(keep, flat_pos, 0)
    tok = jnp.repeat(jnp.arange(t), k)
    buf = jnp.zeros((e + 1, capacity, d), x.dtype)
    buf = buf.at[safe_e, safe_p].add(x[tok])
    y_e = expert_ffn(buf[:e], params["w_gate"], params["w_up"], params["w_down"])
    # combine: gather back each assignment's expert output, weight, sum over K
    y_pad = jnp.concatenate([y_e, jnp.zeros((1, capacity, d), y_e.dtype)], 0)
    y_tok = y_pad[safe_e, safe_p] * (flat_w * keep)[:, None].astype(y_e.dtype)
    return jnp.zeros((t, d), y_e.dtype).at[tok].add(y_tok)


def moe_ffn_gspmd(
    x,  # (T, D) token-major
    params,  # router (D, E); w_gate/w_up (E, D, F); w_down (E, F, D)
    *,
    n_experts_real: int,
    top_k: int,
    capacity_factor: float = 1.25,
):
    t, d = x.shape
    e = params["w_gate"].shape[0]
    w, idx = router_topk(x, params["router"], n_experts_real, top_k)
    capacity = max(1, int(t * top_k * capacity_factor / e))
    return _dispatch_combine(x, w, idx, params, capacity)


def moe_ffn_shardmap(
    x,  # (T, D), sharded over token axes, replicated over "model"
    params,  # experts sharded over "model" on the leading E axis
    *,
    mesh,
    n_experts_real: int,
    top_k: int,
    capacity_factor: float = 1.25,
    token_axes=("data",),
    expert_axis: str = "model",
):
    from jax.sharding import PartitionSpec as P

    tp = mesh.shape[expert_axis]
    e = params["w_gate"].shape[0]
    e_loc = e // tp

    def f(x, router, w_gate, w_up, w_down):
        t = x.shape[0]
        my = jax.lax.axis_index(expert_axis)
        w, idx = router_topk(x, router, n_experts_real, top_k)
        # keep only assignments destined to this shard's experts
        local = (idx >= my * e_loc) & (idx < (my + 1) * e_loc)
        idx_l = jnp.where(local, idx - my * e_loc, e_loc)
        w_l = jnp.where(local, w, 0.0)
        capacity = max(1, int(t * top_k * capacity_factor / e))
        p_loc = {"w_gate": w_gate, "w_up": w_up, "w_down": w_down}
        y = _dispatch_combine(x, w_l, idx_l.astype(jnp.int32), p_loc, capacity)
        return jax.lax.psum(y, expert_axis)

    return shard_map(
        f,
        mesh=mesh,
        in_specs=(
            P(tuple(token_axes), None),
            P(),
            P(expert_axis), P(expert_axis), P(expert_axis),
        ),
        out_specs=P(tuple(token_axes), None),
    )(x, params["router"], params["w_gate"], params["w_up"], params["w_down"])
