"""ModelConfig + parameter init + train/serve step factories for all 10
assigned architectures (dense / MoE / SSM / hybrid / audio / VLM backbones).

Design notes
------------
* Layers are stacked per *period slot* and iterated with ``lax.scan`` +
  ``jax.checkpoint`` — one lowered layer body regardless of depth (compile
  time at 512 fake devices) and remat'ed activations (memory at 4k×256).
  gemma3's 5:1 local:global pattern makes the period 6; everything else is 1.
* Cross-entropy is token-chunked (scan + checkpoint) so the (tokens, vocab)
  logits are never materialized (gemma3's 262k vocab at 1M train tokens would
  be ≳0.5 TB).
* Vocab sizes are padded to multiples of 256 so the unembed shards evenly on
  a 16-wide model axis; padded logits are masked out of the loss.
* MoE expert counts are padded to a multiple of the model axis (60→64).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..compat import shard_map
from .attention import (
    cache_update,
    decode_attention,
    decode_attention_sharded,
    flash_attention,
)
from .layers import apply_rope, dense, init_dense, rms_norm, rope_freqs
from .moe import moe_ffn_gspmd, moe_ffn_shardmap
from .ssm import SSMState, mamba2_forward, mamba2_params_shapes


def _pad_to(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def _dp_axes(mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _csc(x, mesh, *spec):
    """with_sharding_constraint that silently drops axes which don't divide
    the dimension (tiny smoke configs, gemma3's 8 heads on a 16-wide model
    axis, batch=1 long-context cells...)."""
    if mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P

    clean = []
    for i, ax in enumerate(spec):
        if ax is None or i >= x.ndim:
            clean.append(None)
            continue
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        sz = 1
        for a in axes:
            if a not in mesh.axis_names:
                sz = 0
                break
            sz *= mesh.shape[a]
        clean.append(ax if sz and x.shape[i] % sz == 0 else None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*clean)))


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: Optional[int] = None
    qk_norm: bool = False
    rope_theta: float = 1e4
    sliding_window: Optional[int] = None  # window for local layers
    local_global_period: int = 1  # period-slot grouping (scan body width)
    local_global_every: int = 0  # gemma3: every 6th layer is global (5:1)
    rope_theta_local: float = 1e4  # gemma3: local layers use 10k theta
    mlp_type: str = "swiglu"  # swiglu | gelu | geglu | none
    # moe
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    d_ff_shared: int = 0
    # ssm
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    conv_width: int = 4
    # hybrid (hymba): attn ∥ ssm in every block; these layers are global attn
    hybrid_global_layers: tuple = ()
    frontend: str = "token"  # token | embed (audio/vlm stub)
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    moe_impl: str = "shardmap"  # shardmap | gspmd
    ce_chunk: int = 1024
    ssd_chunk: int = 128
    ssd_bf16: bool = False  # §Perf: bf16 SSD intra-chunk buffers
    bf16_grad_activations: bool = False  # §Perf: bf16 activation cotangents
    batch_over_model: bool = False  # §Perf: SSM/hybrid shard batch over model
    sharded_cache_update: bool = False  # §Perf: owner-writes decode cache
    decode_unroll: bool = False  # §Perf: unroll decode layers (in-place cache)

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        return _pad_to(self.vocab_size, 256)

    @property
    def n_experts_padded(self) -> int:
        return _pad_to(self.n_experts, 16) if self.n_experts else 0

    @property
    def period(self) -> int:
        return self.local_global_period

    @property
    def n_periods(self) -> int:
        assert self.n_layers % self.period == 0
        return self.n_layers // self.period

    def slot_kind(self, slot: int) -> str:
        """Layer kind for period slot (gemma3: slots 0-4 local, 5 global)."""
        if self.family == "ssm":
            return "ssm"
        if self.family == "hybrid":
            return "hybrid"
        if self.period > 1:
            return "attn_local" if slot < self.period - 1 else "attn"
        if self.sliding_window is not None and self.period == 1:
            return "attn_local"
        return "attn"

    def param_count(self) -> int:
        """Analytic parameter count (true vocab)."""
        d, f = self.d_model, self.d_ff
        hq, hkv, dh = self.n_heads, self.n_kv_heads, self.head_dim
        per = 0
        if self.family in ("dense", "moe", "audio", "vlm", "hybrid"):
            per += d * (hq * dh) + 2 * d * (hkv * dh) + (hq * dh) * d
        if self.family == "ssm" or self.family == "hybrid":
            dims = mamba2_params_shapes(
                d, expand=self.ssm_expand, headdim=self.ssm_headdim,
                state=self.ssm_state, conv_width=self.conv_width,
            )
            per += d * dims["in_features"] + dims["d_inner"] * d
            per += dims["conv_width"] * dims["conv_dim"]
        if self.family == "moe":
            per += d * self.n_experts  # router
            per += self.n_experts * 3 * d * self.d_ff_expert
            if self.d_ff_shared:
                per += 3 * d * self.d_ff_shared
        elif self.mlp_type == "gelu" and f:
            per += 2 * d * f
        elif f:
            per += 3 * d * f
        total = self.n_layers * per + 2 * self.vocab_size * d
        return total

    def active_param_count(self) -> int:
        """Activated params per token (= param_count for non-MoE)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        per_moe_full = self.n_experts * 3 * d * self.d_ff_expert
        per_moe_act = self.top_k * 3 * d * self.d_ff_expert
        return self.param_count() - self.n_layers * (per_moe_full - per_moe_act)


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------


def _init_attn(key, cfg: ModelConfig):
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": init_dense(ks[0], d, hq * dh),
        "wk": init_dense(ks[1], d, hkv * dh),
        "wv": init_dense(ks[2], d, hkv * dh),
        "wo": init_dense(ks[3], hq * dh, d),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((dh,), jnp.float32)
        p["k_norm"] = jnp.zeros((dh,), jnp.float32)
    return p


def _init_mlp(key, cfg: ModelConfig, d_ff: int):
    ks = jax.random.split(key, 3)
    if cfg.mlp_type == "gelu":
        return {
            "w_in": init_dense(ks[0], cfg.d_model, d_ff),
            "w_out": init_dense(ks[1], d_ff, cfg.d_model),
        }
    return {
        "w_gate": init_dense(ks[0], cfg.d_model, d_ff),
        "w_up": init_dense(ks[1], cfg.d_model, d_ff),
        "w_down": init_dense(ks[2], d_ff, cfg.d_model),
    }


def _init_moe(key, cfg: ModelConfig):
    e = cfg.n_experts_padded
    fe = cfg.d_ff_expert
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    real = jnp.arange(e) < cfg.n_experts
    mask = real[:, None, None].astype(jnp.float32)

    def ew(k, sh):
        return (jax.random.normal(k, sh, jnp.float32) / jnp.sqrt(sh[1])) * mask

    p = {
        "router": init_dense(ks[0], d, e),
        "w_gate": ew(ks[1], (e, d, fe)),
        "w_up": ew(ks[2], (e, d, fe)),
        "w_down": ew(ks[3], (e, fe, d)),
    }
    if cfg.d_ff_shared:
        p["shared"] = _init_mlp(jax.random.fold_in(key, 7), cfg, cfg.d_ff_shared)
    return p


def _init_ssm(key, cfg: ModelConfig):
    dims = mamba2_params_shapes(
        cfg.d_model, expand=cfg.ssm_expand, headdim=cfg.ssm_headdim,
        state=cfg.ssm_state, conv_width=cfg.conv_width,
    )
    ks = jax.random.split(key, 3)
    h = dims["n_heads"]
    return {
        "in_proj": init_dense(ks[0], cfg.d_model, dims["in_features"]),
        "out_proj": init_dense(ks[1], dims["d_inner"], cfg.d_model),
        "conv_w": jax.random.normal(
            ks[2], (dims["conv_width"], dims["conv_dim"]), jnp.float32
        ) * 0.2,
        "conv_b": jnp.zeros((dims["conv_dim"],), jnp.float32),
        "dt_bias": jnp.full((h,), -2.0, jnp.float32),
        "a_log": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm": jnp.zeros((dims["d_inner"],), jnp.float32),
    }


def _init_slot(key, cfg: ModelConfig, slot: int):
    kind = cfg.slot_kind(slot)
    ks = jax.random.split(key, 4)
    p: dict = {"ln1": jnp.zeros((cfg.d_model,), jnp.float32)}
    if kind in ("attn", "attn_local"):
        p["attn"] = _init_attn(ks[0], cfg)
    elif kind == "ssm":
        p["ssm"] = _init_ssm(ks[0], cfg)
    elif kind == "hybrid":
        p["attn"] = _init_attn(ks[0], cfg)
        p["ssm"] = _init_ssm(ks[1], cfg)
        p["bnorm_a"] = jnp.zeros((cfg.d_model,), jnp.float32)
        p["bnorm_s"] = jnp.zeros((cfg.d_model,), jnp.float32)
    if cfg.family == "moe":
        p["ln2"] = jnp.zeros((cfg.d_model,), jnp.float32)
        p["moe"] = _init_moe(ks[2], cfg)
    elif cfg.d_ff and cfg.mlp_type != "none" and cfg.family != "ssm":
        p["ln2"] = jnp.zeros((cfg.d_model,), jnp.float32)
        p["mlp"] = _init_mlp(ks[2], cfg, cfg.d_ff)
    return p


def init_params(cfg: ModelConfig, key) -> Any:
    ks = jax.random.split(key, 3)
    params: dict = {"final_norm": jnp.zeros((cfg.d_model,), jnp.float32)}
    if cfg.frontend == "token":
        params["embed"] = (
            jax.random.normal(ks[0], (cfg.vocab_padded, cfg.d_model), jnp.float32)
            * 0.02
        )
    params["unembed"] = init_dense(ks[1], cfg.d_model, cfg.vocab_padded)

    def slot_stack(slot):
        def one(i):
            k = jax.random.fold_in(ks[2], slot * 10007 + i)
            return _init_slot(k, cfg, slot)

        leaves = [one(i) for i in range(cfg.n_periods)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *leaves)

    params["slots"] = [slot_stack(s) for s in range(cfg.period)]
    return params


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _attn_forward(x, p, cfg: ModelConfig, *, window, positions, kv=None,
                  cache=None, pos=None, mesh=None, seq_shards: int = 1,
                  theta=None):
    """x (B, S, D). Returns (out, (k, v) or updated cache)."""
    b, s, d = x.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dp = _dp_axes(mesh) if mesh is not None else None
    q = dense(x, p["wq"]).reshape(b, s, hq, dh)
    k = dense(x, p["wk"]).reshape(b, s, hkv, dh)
    v = dense(x, p["wv"]).reshape(b, s, hkv, dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    cos, sin = rope_freqs(positions, dh,
                          cfg.rope_theta if theta is None else theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    g = hq // hkv
    if mesh is not None:
        q = _csc(q, mesh, dp, None, "model", None)
    if cache is None:
        # GQA via kv-repeat: keeps the head dim shardable over "model"
        # (splitting Hq into (Hkv, G) would break TP whenever Hkv < tp).
        pass
        kf = jnp.repeat(k, g, axis=2) if g > 1 else k
        vf = jnp.repeat(v, g, axis=2) if g > 1 else v
        if mesh is not None:
            # kv: gathered over seq (every q shard attends the full KV) and
            # replicated over heads — head-sharding Hkv < tp would force the
            # SPMD "involuntary full remat" path
            kf = _csc(kf, mesh, dp, None, None, None)
            vf = _csc(vf, mesh, dp, None, None, None)
        out = flash_attention(q, kf, vf, causal=True, window=window)
        new_cache = None
    else:
        if (s == 1 and mesh is not None and seq_shards > 1
                and cfg.sharded_cache_update):
            from .attention import cache_update_sharded

            kc, vc = cache_update_sharded(
                cache["k"], cache["v"], k, v, pos, mesh=mesh)
        else:
            kc, vc = cache_update(cache["k"], cache["v"], k, v, pos)
        cur = pos + s
        if s == 1:
            if mesh is not None and seq_shards > 1:
                out = decode_attention_sharded(
                    q, kc, vc, jnp.full((b,), cur), mesh=mesh, window=window
                )
            else:
                out = decode_attention(q, kc, vc, jnp.full((b,), cur),
                                       window=window)
        else:  # prefill into cache
            kf = jnp.repeat(k, g, axis=2) if g > 1 else k
            vf = jnp.repeat(v, g, axis=2) if g > 1 else v
            if mesh is not None:
                kf = _csc(kf, mesh, dp, None, "model", None)
                vf = _csc(vf, mesh, dp, None, "model", None)
            out = flash_attention(q, kf, vf, causal=True, window=window,
                                  q_offset=pos)
        new_cache = {"k": kc, "v": vc}
    out = dense(out.reshape(b, s, hq * dh), p["wo"])
    if mesh is not None:
        out = _csc(out, mesh, dp, None, None)
    return out, new_cache


def _mlp_forward(x, p, cfg: ModelConfig, mesh=None):
    dp = _dp_axes(mesh) if mesh is not None else None
    if cfg.mlp_type == "gelu":
        h = dense(x, p["w_in"])
        h = _csc(h, mesh, dp, None, "model")
        return dense(jax.nn.gelu(h), p["w_out"])
    act = jax.nn.gelu if cfg.mlp_type == "geglu" else jax.nn.silu
    g = act(_csc(dense(x, p["w_gate"]), mesh, dp, None, "model"))
    u = _csc(dense(x, p["w_up"]), mesh, dp, None, "model")
    return dense(g * u, p["w_down"])


def _moe_forward(x, p, cfg: ModelConfig, mesh=None):
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    if cfg.moe_impl == "shardmap" and mesh is not None:
        token_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        y = moe_ffn_shardmap(
            xt, p, mesh=mesh, n_experts_real=cfg.n_experts, top_k=cfg.top_k,
            token_axes=token_axes,
        )
    else:
        y = moe_ffn_gspmd(
            xt, p, n_experts_real=cfg.n_experts, top_k=cfg.top_k
        )
    y = y.reshape(b, s, d)
    if "shared" in p:
        y = y + _mlp_forward(x, p["shared"], cfg, mesh=mesh)
    return y


def _slot_forward(x, p, cfg: ModelConfig, slot: int, *, positions, cache=None,
                  pos=None, mesh=None, seq_shards: int = 1, layer_idx=None):
    kind = cfg.slot_kind(slot)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if mesh is not None:
        # pin the SP layout on the bf16 norm OUTPUT: otherwise GSPMD hoists
        # the seq all-gather before rms_norm's final cast and moves f32
        h = _csc(h, mesh, _resid_batch_axes(cfg, mesh), _resid_seq_axis(cfg),
                 None)
    new_cache = cache
    if kind in ("attn", "attn_local"):
        window = cfg.sliding_window if kind == "attn_local" else None
        theta = cfg.rope_theta
        if cfg.local_global_every and window is not None and layer_idx is not None:
            # gemma3 5:1 pattern as a traced switch (34 layers, one scan body)
            every = cfg.local_global_every
            is_global = (layer_idx % every) == (every - 1)
            window = jnp.where(is_global, jnp.int32(2**30), window)
            theta = jnp.where(is_global, cfg.rope_theta, cfg.rope_theta_local)
        a, new_cache = _attn_forward(
            h, p["attn"], cfg, window=window, positions=positions,
            cache=cache, pos=pos, mesh=mesh, seq_shards=seq_shards,
            theta=theta,
        )
        if mesh is not None:
            a = _csc(a, mesh, _resid_batch_axes(cfg, mesh),
                     _resid_seq_axis(cfg), None)
        x = x + a
    elif kind == "ssm":
        state = None if cache is None else SSMState(h=cache["h"], conv=cache["conv"])
        a, st = mamba2_forward(h, p["ssm"], cfg, state=state,
                               chunk=cfg.ssd_chunk, mesh=mesh)
        x = x + a
        new_cache = None if cache is None else {"h": st.h, "conv": st.conv}
    elif kind == "hybrid":
        # hymba: parallel attn + ssm heads; global attn on designated layers
        # (window passed as a traced scalar so the scanned body stays uniform)
        window = cfg.sliding_window
        if (
            window is not None
            and layer_idx is not None
            and cfg.hybrid_global_layers
        ):
            is_global = jnp.any(
                layer_idx == jnp.asarray(cfg.hybrid_global_layers)
            )
            window = jnp.where(is_global, jnp.int32(2**30), window)
        att_cache = None if cache is None else cache["attn"]
        a, ac = _attn_forward(
            h, p["attn"], cfg, window=window, positions=positions,
            cache=att_cache, pos=pos, mesh=mesh, seq_shards=seq_shards,
        )
        state = None if cache is None else SSMState(
            h=cache["ssm"]["h"], conv=cache["ssm"]["conv"]
        )
        m, st = mamba2_forward(h, p["ssm"], cfg, state=state,
                               chunk=cfg.ssd_chunk, mesh=mesh)
        out = 0.5 * (
            rms_norm(a, p["bnorm_a"], cfg.norm_eps)
            + rms_norm(m, p["bnorm_s"], cfg.norm_eps)
        )
        x = x + out
        new_cache = (
            None if cache is None
            else {"attn": ac, "ssm": {"h": st.h, "conv": st.conv}}
        )
    if "mlp" in p or "moe" in p:
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        if mesh is not None:
            h2 = _csc(h2, mesh, _resid_batch_axes(cfg, mesh),
                      _resid_seq_axis(cfg), None)
        if "mlp" in p:
            m_out = _mlp_forward(h2, p["mlp"], cfg, mesh=mesh)
        else:
            m_out = _moe_forward(h2, p["moe"], cfg, mesh=mesh)
        if mesh is not None:
            # reduce-scatter the bf16 block output (not a later f32 upcast)
            m_out = _csc(m_out, mesh, _resid_batch_axes(cfg, mesh),
                         _resid_seq_axis(cfg), None)
        x = x + m_out
    if mesh is not None:
        x = _csc(x, mesh, _resid_batch_axes(cfg, mesh), _resid_seq_axis(cfg),
                 None)
    if cfg.bf16_grad_activations:
        x = _bf16_grad_barrier(x)
    return x, new_cache


def _resid_seq_axis(cfg: ModelConfig):
    """Megatron-style sequence parallelism: the residual stream between
    blocks is sharded over "model" along the sequence for attention-family
    archs (norms/residuals run on 1/tp of the tokens; remat carries shrink
    tp×).  SSM/hybrid keep a replicated stream — the SSD chunk scan is
    sequential along S and must not cross shard boundaries."""
    return None if cfg.family in ("ssm", "hybrid") else "model"


@jax.custom_vjp
def _bf16_grad_barrier(x):
    """Identity forward; casts the cotangent to bf16 (then back to x's
    dtype).  Placed at block boundaries so backward activation collectives
    (SP all-gathers / TP reduces of the residual cotangent) move bf16
    instead of f32 — §Perf for collective-bound train cells."""
    return x


def _bgb_fwd(x):
    # residuals must be jax types: carry the dtype via a 0-size array
    return x, jnp.zeros((0,), x.dtype)


def _bgb_bwd(res, g):
    return (g.astype(jnp.bfloat16).astype(res.dtype),)


_bf16_grad_barrier.defvjp(_bgb_fwd, _bgb_bwd)


def _resid_batch_axes(cfg: ModelConfig, mesh):
    """SSM/hybrid §Perf option: treat "model" as a second data axis for the
    residual stream (SSD TP gives little; B/dev shrinks tp×)."""
    dp = _dp_axes(mesh)
    if cfg.batch_over_model and cfg.family in ("ssm", "hybrid"):
        return dp + ("model",)
    return dp


def forward(params, batch, cfg: ModelConfig, *, mesh=None, caches=None,
            pos=None, seq_shards: int = 1):
    """Full stack. batch: {"tokens": (B,S) int32} or {"embeddings": (B,S,D)}.
    Returns (hidden (B,S,D), new_caches)."""
    dt = jnp.dtype(cfg.dtype)
    if cfg.frontend == "token":
        x = params["embed"][batch["tokens"]].astype(dt)
    else:
        x = batch["embeddings"].astype(dt)
    if mesh is not None:
        x = _csc(x, mesh, _resid_batch_axes(cfg, mesh), _resid_seq_axis(cfg),
                 None)
    b, s, _ = x.shape
    base = 0 if pos is None else pos
    positions = base + jnp.arange(s)

    def body(carry, xs):
        x = carry
        lp = xs["params"]
        lc = xs.get("cache")
        pidx = xs["pidx"]
        new_c = []
        for slot in range(cfg.period):
            sp = lp[slot]
            sc = None if lc is None else lc[slot]
            x, nc = _slot_forward(
                x, sp, cfg, slot, positions=positions, cache=sc, pos=pos,
                mesh=mesh, seq_shards=seq_shards,
                layer_idx=pidx * cfg.period + slot,
            )
            new_c.append(nc)
        out_c = None if lc is None else new_c
        return x, out_c

    if caches is not None and cfg.decode_unroll and s == 1:
        # §Perf (decode): python-unrolled layers write the cache stack with
        # .at[i].set — the whole stack aliases the donated input instead of
        # being re-materialized by a scan's ys buffers.
        new_caches = caches
        for i in range(cfg.n_periods):
            lp = [jax.tree.map(lambda a: a[i], sp) for sp in params["slots"]]
            for slot in range(cfg.period):
                sc = jax.tree.map(lambda a: a[i], new_caches[slot])
                x, nc = _slot_forward(
                    x, lp[slot], cfg, slot, positions=positions, cache=sc,
                    pos=pos, mesh=mesh, seq_shards=seq_shards,
                    layer_idx=i * cfg.period + slot,
                )
                new_caches[slot] = jax.tree.map(
                    lambda full, upd: full.at[i].set(upd),
                    new_caches[slot], nc,
                )
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return x, new_caches

    xs = {
        "params": params["slots"],
        "pidx": jnp.arange(cfg.n_periods),
    }
    if caches is not None:
        xs["cache"] = caches
    body_fn = jax.checkpoint(body) if caches is None else body
    x, new_caches = jax.lax.scan(body_fn, x, xs)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, new_caches


def chunked_ce_loss(x, labels, w_unembed, cfg: ModelConfig, *, mesh=None):
    """Sequence-chunked, vocab-parallel cross entropy.  x (B,S,D); labels
    (B,S) int32 (−1 = ignore).  Never materializes (B·S, vocab): the scan
    walks S-chunks (batch stays dp-sharded, the scanned dim is unsharded)
    and the per-chunk logits are vocab-sharded over "model" so logsumexp
    reduces with one small psum — Megatron-style vocab-parallel CE."""
    b, s, d = x.shape
    dp = _dp_axes(mesh) if mesh is not None else None
    cs = min(cfg.ce_chunk, s)
    n_chunks = -(-s // cs)
    pad = n_chunks * cs - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    xc = jnp.moveaxis(x.reshape(b, n_chunks, cs, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(b, n_chunks, cs), 1, 0)

    if mesh is not None:
        # explicit vocab-parallel CE (shard_map): GSPMD's own partitioning of
        # the logit einsum kept materializing/gathering full-vocab logits
        # (~10 GB/device at 152k vocab); making the max/sum/gold reductions
        # explicit pins the wire traffic to three (B, cs) psums per chunk.
        from jax.sharding import PartitionSpec as P

        v_loc = cfg.vocab_padded // mesh.shape["model"]

        def ce_local(xi, li, w):
            my = jax.lax.axis_index("model")
            logits = jnp.einsum(
                "btd,dv->btv", xi, w.astype(xi.dtype),
                preferred_element_type=jnp.float32,
            )
            vids = my * v_loc + jax.lax.broadcasted_iota(
                jnp.int32, logits.shape, 2
            )
            logits = jnp.where(vids < cfg.vocab_size, logits, -1e30)
            # pmax has no JVP rule; gather the 16 per-shard maxima instead
            m_loc = jax.lax.stop_gradient(jnp.max(logits, axis=-1))
            m = jnp.max(jax.lax.all_gather(m_loc, "model", axis=0), axis=0)
            se = jax.lax.psum(
                jnp.sum(jnp.exp(logits - m[..., None]), axis=-1), "model"
            )
            lse = m + jnp.log(se)
            gold = jax.lax.psum(
                jnp.sum(jnp.where(vids == li[..., None], logits, 0.0), -1),
                "model",
            )
            wt = (li >= 0).astype(jnp.float32)
            loss = jnp.sum((lse - gold) * wt)
            cnt = jnp.sum(wt)
            loss = jax.lax.psum(loss, dp) if dp else loss
            cnt = jax.lax.psum(cnt, dp) if dp else cnt
            return loss, cnt

        # check_vma=False: lse/gold are psummed over "model" so loss is
        # provably model-invariant, but the vma tracker marks the all-gathered
        # max as varying and can't see the invariance.
        # repro: noqa[R001] — built at trace time of the jitted train step
        # (assigned and consumed inside one trace), not per eager call.
        ce_sm = shard_map(
            ce_local,
            mesh=mesh,
            in_specs=(P(dp), P(dp), P(None, "model")),
            out_specs=(P(), P()),
            check_vma=False,
        )

        @jax.checkpoint
        def ce_chunk(carry, inp):
            xi, li = inp
            loss, cnt = ce_sm(xi, li, w_unembed)
            return (carry[0] + loss, carry[1] + cnt), None

    else:

        @jax.checkpoint
        def ce_chunk(carry, inp):
            xi, li = inp  # (B, cs, D), (B, cs)
            logits = jnp.einsum(
                "btd,dv->btv", xi, w_unembed.astype(xi.dtype),
                preferred_element_type=jnp.float32,
            )
            vids = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
            logits = jnp.where(vids < cfg.vocab_size, logits, -1e30)
            lse = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.sum(
                jnp.where(vids == li[..., None], logits, 0.0), axis=-1
            )
            w = (li >= 0).astype(jnp.float32)
            loss = jnp.sum((lse - gold) * w)
            return (carry[0] + loss, carry[1] + jnp.sum(w)), None

    (tot, cnt), _ = jax.lax.scan(ce_chunk, (0.0, 0.0), (xc, lc))
    return tot / jnp.maximum(cnt, 1.0)


def loss_fn(params, batch, cfg: ModelConfig, *, mesh=None):
    x, _ = forward(params, batch, cfg, mesh=mesh)
    if mesh is not None:
        # leave sequence parallelism before the loss: the CE scan chunks the
        # seq dim, which must not stay sharded (scan slices it)
        x = _csc(x, mesh, _dp_axes(mesh), None, None)
    if cfg.bf16_grad_activations:
        # The CE backward emits an f32 x-cotangent; the backward layer-scan
        # carries ONE dtype for all iterations, so without this cast the f32
        # infects all n_layers of backward activation collectives (in-body
        # barriers get promoted away by carry-dtype unification).
        x = _bf16_grad_barrier(x)
    return chunked_ce_loss(x, batch["labels"], params["unembed"], cfg,
                           mesh=mesh)


# ---------------------------------------------------------------------------
# Step factories
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, optimizer, *, mesh=None,
                    mixed_precision: bool = False):
    """Returns train_step(state, batch) -> (state, metrics).  ``optimizer`` is
    a repro.optim object with init/update.  ``mixed_precision`` keeps f32
    master params in the state but computes (and therefore FSDP-gathers and
    grad-reduces) in bf16 — §Perf optimization for collective-bound cells."""

    def compute_loss(p, batch):
        if mixed_precision:
            p = jax.tree.map(
                lambda x: x.astype(jnp.bfloat16)
                if x.dtype == jnp.float32 else x, p)
        return loss_fn(p, batch, cfg, mesh=mesh)

    def train_step(state, batch):
        params, opt_state, step = state
        loss, grads = jax.value_and_grad(
            lambda p: compute_loss(p, batch)
        )(params)
        updates, opt_state = optimizer.update(grads, opt_state, params, step)
        params = jax.tree.map(lambda p, u: p + u, params, updates)
        gnorm = jnp.sqrt(
            sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads))
        )
        return (params, opt_state, step + 1), {"loss": loss, "grad_norm": gnorm}

    return train_step


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int, dtype=None):
    """Per-period-slot stacked caches."""
    dt = dtype or jnp.dtype(cfg.dtype)
    npd = cfg.n_periods
    hkv, dh = cfg.n_kv_heads, cfg.head_dim

    def attn_cache():
        return {
            "k": jnp.zeros((npd, batch_size, max_len, hkv, dh), dt),
            "v": jnp.zeros((npd, batch_size, max_len, hkv, dh), dt),
        }

    def ssm_cache():
        dims = mamba2_params_shapes(
            cfg.d_model, expand=cfg.ssm_expand, headdim=cfg.ssm_headdim,
            state=cfg.ssm_state, conv_width=cfg.conv_width,
        )
        return {
            "h": jnp.zeros(
                (npd, batch_size, dims["n_heads"], cfg.ssm_state,
                 dims["d_inner"] // dims["n_heads"]),
                jnp.float32,
            ),
            "conv": jnp.zeros(
                (npd, batch_size, cfg.conv_width - 1, dims["conv_dim"]), dt
            ),
        }

    caches = []
    for slot in range(cfg.period):
        kind = cfg.slot_kind(slot)
        if kind in ("attn", "attn_local"):
            caches.append(attn_cache())
        elif kind == "ssm":
            caches.append(ssm_cache())
        else:  # hybrid
            caches.append({"attn": attn_cache(), "ssm": ssm_cache()})
    return caches


def make_serve_step(cfg: ModelConfig, *, mesh=None, seq_shards: int = 1):
    """Returns serve_step(params, caches, tokens, pos) -> (logits, caches):
    one decode step with a KV/SSM cache at position ``pos``."""

    def serve_step(params, caches, batch, pos):
        x, new_caches = forward(
            params, batch, cfg, mesh=mesh, caches=caches, pos=pos,
            seq_shards=seq_shards,
        )
        # only the final token's logits; full (tiny) vocab head is fine at S=1
        logits = jnp.einsum(
            "bd,dv->bv", x[:, -1], params["unembed"].astype(x.dtype),
            preferred_element_type=jnp.float32,
        )
        return logits, new_caches

    return serve_step


def make_prefill_step(cfg: ModelConfig, *, mesh=None):
    def prefill(params, caches, batch):
        x, new_caches = forward(
            params, batch, cfg, mesh=mesh, caches=caches, pos=0
        )
        logits = jnp.einsum(
            "bd,dv->bv", x[:, -1], params["unembed"].astype(x.dtype),
            preferred_element_type=jnp.float32,
        )
        return logits, new_caches

    return prefill
