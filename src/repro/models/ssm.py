"""Mamba-2 SSD (state-space duality) layer — chunked scan formulation.

Training/prefill uses the SSD block decomposition (Mamba-2 paper §6): within a
chunk of Q tokens the recurrence is materialized as a decay-masked quadratic
form (maps onto the MXU); across chunks a (B, H, N, P) state is carried by a
``lax.scan``.  Decode keeps the recurrent state explicitly — O(1) per token,
which is why mamba2/hymba are the archs that run the ``long_500k`` cell.

Shapes: d_inner = expand·d_model, H = d_inner/headdim heads, state N,
B/C shared across heads (G = 1 group), per-step decay a_t = exp(Δ_t·A).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .layers import dense, rms_norm


class SSMState(NamedTuple):
    h: jnp.ndarray  # (B, H, N, P) inter-chunk state
    conv: jnp.ndarray  # (B, W-1, conv_dim) conv tail


def _causal_conv(x, w, b, conv_state=None):
    """Depthwise causal conv1d. x (B, S, C), w (W, C), b (C,).
    Returns (y, new_tail)."""
    bsz, s, c = x.shape
    wlen = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((bsz, wlen - 1, c), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = jnp.zeros_like(x)
    for t in range(wlen):
        y = y + xp[:, t : t + s, :] * w[t].astype(x.dtype)
    y = y + b.astype(x.dtype)
    return jax.nn.silu(y), xp[:, -(wlen - 1) :, :] if wlen > 1 else pad


def ssd_chunked(xh, dt, a_log, bmat, cmat, *, chunk: int = 128,
                compute_bf16: bool = False):
    """SSD forward.

    xh (B, S, H, P); dt (B, S, H) post-softplus; a_log (H,) (A = −exp(a_log));
    bmat/cmat (B, S, N).  Returns y (B, S, H, P) and final state (B, H, N, P).
    ``compute_bf16`` keeps the Δ-scaled inputs and chunk outputs in bf16
    (§Perf memory fix for train_4k; the recurrent state h stays f32).
    """
    b, s, h, p = xh.shape
    n = bmat.shape[-1]
    q = min(chunk, s)
    nc = -(-s // q)
    pad = nc * q - s
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
    a = -jnp.exp(a_log.astype(jnp.float32))  # (H,) negative
    loga = dt.astype(jnp.float32) * a  # (B, S', H) log decay per step
    cdt = jnp.bfloat16 if compute_bf16 else jnp.float32
    xc = (xh * dt[..., None]).astype(cdt)  # Δ-scaled input

    xs = xc.reshape(b, nc, q, h, p).transpose(1, 0, 2, 3, 4)
    ls = loga.reshape(b, nc, q, h).transpose(1, 0, 2, 3)
    bs = bmat.reshape(b, nc, q, n).transpose(1, 0, 2, 3).astype(cdt)
    cs = cmat.reshape(b, nc, q, n).transpose(1, 0, 2, 3).astype(cdt)

    @jax.checkpoint  # backward recomputes intra-chunk buffers
    def chunk_step(hstate, inp):
        xq, lq, bq, cq = inp  # (B,Q,H,P), (B,Q,H), (B,Q,N), (B,Q,N)
        cum = jnp.cumsum(lq, axis=1)  # L_t inclusive
        # intra-chunk: scores[t, s] = (C_t·B_s) exp(L_t − L_s) for s ≤ t
        cb = jnp.einsum("btn,bsn->bts", cq, bq,
                        preferred_element_type=jnp.float32)  # (B,Q,Q)
        gap = cum[:, :, None, :] - cum[:, None, :, :]  # (B,t,s,H)
        tri = (jnp.arange(q)[:, None] >= jnp.arange(q)[None, :])[None, :, :, None]
        w = (jnp.where(tri, jnp.exp(gap), 0.0) * cb[..., None]).astype(cdt)
        y_intra = jnp.einsum("btsh,bshp->bthp", w, xq,
                             preferred_element_type=jnp.float32)
        # contribution of the carried state: Y_t += C_t · h · exp(L_t)
        y_inter = jnp.einsum(
            "btn,bhnp->bthp", cq.astype(jnp.float32), hstate
        ) * jnp.exp(cum)[..., None]
        # new state: h' = exp(L_end) h + Σ_s exp(L_end − L_s) B_s ⊗ x_s
        lend = cum[:, -1, :]  # (B,H)
        decay_s = jnp.exp(lend[:, None, :] - cum).astype(cdt)  # (B,Q,H)
        s_chunk = jnp.einsum("bsn,bsh,bshp->bhnp", bq, decay_s, xq,
                             preferred_element_type=jnp.float32)
        h2 = jnp.exp(lend)[:, :, None, None] * hstate + s_chunk
        return h2, y_intra + y_inter

    h0 = jnp.zeros((b, h, n, p), jnp.float32)
    hfin, ys = jax.lax.scan(chunk_step, h0, (xs, ls, bs, cs))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, nc * q, h, p)[:, :s]
    return y.astype(xh.dtype), hfin


def ssd_decode_step(hstate, x1, dt1, a_log, b1, c1):
    """One-token recurrent update. x1 (B, H, P), dt1 (B, H), b1/c1 (B, N)."""
    a = -jnp.exp(a_log.astype(jnp.float32))
    decay = jnp.exp(dt1.astype(jnp.float32) * a)  # (B, H)
    upd = jnp.einsum("bn,bhp->bhnp", b1.astype(jnp.float32),
                     (x1 * dt1[..., None]).astype(jnp.float32))
    h2 = decay[:, :, None, None] * hstate + upd
    y = jnp.einsum("bn,bhnp->bhp", c1.astype(jnp.float32), h2)
    return h2, y.astype(x1.dtype)


def mamba2_params_shapes(d_model: int, *, expand: int, headdim: int, state: int,
                         conv_width: int):
    d_inner = expand * d_model
    h = d_inner // headdim
    conv_dim = d_inner + 2 * state
    return {
        "d_inner": d_inner,
        "n_heads": h,
        "conv_dim": conv_dim,
        "in_features": 2 * d_inner + 2 * state + h,
        "conv_width": conv_width,
    }


def mamba2_forward(x, params, cfg, *, state: SSMState | None = None,
                   chunk: int = 128, mesh=None):
    """Full Mamba-2 mixer. x (B, S, D). Returns (y (B, S, D), SSMState)."""
    bsz, s, _ = x.shape
    dims = mamba2_params_shapes(
        x.shape[-1], expand=cfg.ssm_expand, headdim=cfg.ssm_headdim,
        state=cfg.ssm_state, conv_width=cfg.conv_width,
    )
    di, h, n = dims["d_inner"], dims["n_heads"], cfg.ssm_state
    proj = dense(x, params["in_proj"])  # (B,S, 2di+2n+h)
    z, xbc, dt = jnp.split(proj, [di, 2 * di + 2 * n], axis=-1)
    xconv, new_tail = _causal_conv(
        xbc, params["conv_w"], params["conv_b"],
        None if state is None else state.conv,
    )
    xh = xconv[..., :di].reshape(bsz, s, h, di // h)
    if mesh is not None:
        # SSM heads are independent → shard H over "model" (TP for SSD)
        from .model import _csc, _dp_axes

        xh = _csc(xh, mesh, _dp_axes(mesh), None, "model", None)
    bmat = xconv[..., di : di + n]
    cmat = xconv[..., di + n :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    if s == 1 and state is not None:
        h2, y1 = ssd_decode_step(
            state.h, xh[:, 0], dt[:, 0], params["a_log"], bmat[:, 0], cmat[:, 0]
        )
        y = y1[:, None]
        hfin = h2
    else:
        y, hfin = ssd_chunked(xh, dt, params["a_log"], bmat, cmat, chunk=chunk,
                              compute_bf16=getattr(cfg, "ssd_bf16", False))
    y = y + xh * params["d_skip"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(bsz, s, di)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 params["norm"])
    out = dense(y, params["out_proj"])
    return out, SSMState(h=hfin, conv=new_tail)
