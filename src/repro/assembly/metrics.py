"""Truth-based assembly quality metrics (DESIGN.md §2.8).

Host-side numpy validation helpers, not part of the compute path: map a
contig back to its simulated-genome interval through the per-read truth
positions carried by ``simulate.ReadSet``, and measure per-base identity with
a banded edit-distance DP.  Used by the examples and the consensus tests to
report pre- vs post-polish identity against ground truth — the measured
counterpart of the vote-agreement *estimate* the consensus stage computes on
device.
"""

from __future__ import annotations

from typing import List, Tuple

import numpy as np


def banded_edit_distance(a, b, band: int = 64) -> int:
    """Levenshtein distance restricted to |i−j| ≤ band (unit costs).

    The band is widened to at least the length difference + 1, so the result
    equals the exact distance whenever the optimal path stays within the
    band — always true for the small drifts measured here."""
    a = np.asarray(a)
    b = np.asarray(b)
    la, lb = len(a), len(b)
    if la == 0 or lb == 0:
        return la + lb
    band = max(int(band), abs(la - lb) + 1)
    ks = np.arange(-band, band + 1)  # slot k ↔ column j = i + k
    inf = la + lb + 1
    # row 0: dp[0][j] = j
    prev = np.where((ks >= 0) & (ks <= lb), np.abs(ks), inf)
    for i in range(1, la + 1):
        j = i + ks
        bj = np.clip(j - 1, 0, lb - 1)
        sub = np.where(a[i - 1] == b[bj], 0, 1)
        diag = prev + sub  # dp[i-1][j-1] lives in the same slot
        up = np.concatenate([prev[1:], [inf]]) + 1  # dp[i-1][j]
        cand = np.minimum(diag, up)
        cand = np.where((j >= 1) & (j <= lb), cand, inf)
        if i <= band:  # slot for j == 0 exists: dp[i][0] = i
            cand[band - i] = i
        # close the row under left-gaps: dp[i][j] = min_{j'≤j} cand[j'] + (j−j')
        cur = np.minimum.accumulate(cand - j) + j
        prev = np.minimum(cur, inf)
    k_final = lb - la + band
    return int(prev[k_final])


def identity(a, b, band: int = 64) -> float:
    """Per-base identity 1 − edit/max(len) between two code arrays."""
    la, lb = len(a), len(b)
    if max(la, lb) == 0:
        return 1.0
    return 1.0 - banded_edit_distance(a, b, band) / max(la, lb)


def contig_truth_interval(contig, readset) -> Tuple[int, int, int]:
    """Genome interval ``(lo, hi, orientation)`` a contig derives from.

    Each chain read (r, s) maps to ``[truth_start[r], truth_end[r])`` with
    contig-vs-genome orientation ``truth_strand[r] ^ s``; the contig's
    orientation is the majority over its reads (they agree on any correct
    layout) and the interval is the union span."""
    rs = [r for r, _ in contig.reads]
    lo = int(min(readset.truth_start[r] for r in rs))
    hi = int(max(readset.truth_end[r] for r in rs))
    flips = [int(readset.truth_strand[r]) ^ int(s) for r, s in contig.reads]
    o = int(sum(flips) * 2 >= len(flips))
    return lo, hi, o


def contig_identity_vs_truth(contig, readset, band: int = 64) -> float:
    """Identity of a contig against its own simulated-genome interval."""
    lo, hi, o = contig_truth_interval(contig, readset)
    ref = readset.genome[lo:hi]
    if o:
        ref = (3 - ref)[::-1]
    return identity(contig.codes, ref, band=band)


def assembly_identity(
    contigs: List, readset, *, min_reads: int = 1, band: int = 64,
) -> Tuple[float, int]:
    """Length-weighted mean identity over contigs with ≥ ``min_reads`` chain
    reads.  Returns ``(identity, total_bases_measured)``."""
    num = 0.0
    den = 0
    for c in contigs:
        if len(c.reads) < min_reads or c.length == 0:
            continue
        num += contig_identity_vs_truth(c, readset, band=band) * c.length
        den += c.length
    return (num / den if den else 1.0), den
