"""Contig extraction: walk non-branching paths of the bidirected string graph.

A walk state is (read, strand); edge (i→j, strands (a, b), suffix ℓ) connects
state (i, a) to (j, b) and appends the last ℓ bases of oriented-j to the
contig.  This module is the **host-side reference backend** of the Contigs
stage (``assembly/contig_gen.py`` holds the device path; both implement the
same canonical partition and must produce identical contigs — asserted by the
golden parity suite in ``tests/test_contigs.py``).

Canonical unitig partition (DESIGN.md §2.7)
-------------------------------------------
An edge u→v of the state graph is *kept* iff out-degree(u) == 1 and
in-degree(v) == 1 (the branch-cut rule of the 2022 contig-generation paper:
branching vertices terminate chains on both sides).  Kept edges form disjoint
simple paths and cycles; cycles are cut at their minimum-id state, which
becomes the head.  One contig is emitted per chain whose head has at least
one outgoing edge in the *original* state graph.  The rule is purely local,
so the partition — unlike a visited-set walk — does not depend on traversal
order, which is what lets the device backend reproduce it exactly.

Reverse-complement twins: every chain c = [u0..uk] has a formal twin
t = [uk^1..u0^1] (strand-flipped reversal).  c is dropped iff t is *also* an
emitted chain and t < c lexicographically — i.e. each twin pair is emitted
once, as its lexicographically smaller representative.  (Keying on the chain
itself, not on the ``frozenset`` of read ids, means two distinct chains that
happen to visit the same reads in different orders both survive.)
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

from .kmers import BASES


@dataclasses.dataclass
class Contig:
    reads: List[Tuple[int, int]]  # (read, strand) chain
    length: int
    codes: np.ndarray


@dataclasses.dataclass
class ContigStats:
    n_contigs: int
    total_length: int
    n50: int
    longest: int
    l50: int
    mean_length: float


def _oriented(codes_row: np.ndarray, length: int, strand: int) -> np.ndarray:
    r = codes_row[:length]
    return (3 - r[::-1]) if strand else r


def materialize_rows(codes, lengths, states, n_contigs: int) -> List[Contig]:
    """Shared contig-tensor materialization: rows of ``codes``/``lengths``
    with their ``states`` chains (−1 padded) become ``Contig`` objects —
    used by both the draft ``ContigSet`` and the polished
    ``ConsensusResult`` so the two can never drift apart."""
    codes = np.asarray(codes)
    lens = np.asarray(lengths)
    states = np.asarray(states)
    out: List[Contig] = []
    for i in range(n_contigs):
        ss = states[i][states[i] >= 0]
        out.append(
            Contig(
                reads=[(int(s) >> 1, int(s) & 1) for s in ss],
                length=int(lens[i]),
                codes=codes[i, : lens[i]].copy(),
            )
        )
    return out


def state_edges(s_mat):
    """Host-side state-graph expansion: ``(out_edges, in_deg, has_edge)``
    where ``out_edges[u] = [(v, suffix), ...]`` over states ``u = 2·read +
    strand`` and ``has_edge`` is per *read* (any edge on either strand, in
    either direction)."""
    cols = np.asarray(s_mat.cols)
    vals = np.asarray(s_mat.vals)
    n = cols.shape[0]
    out_edges: Dict[int, List] = {}
    in_deg: Dict[int, int] = {}
    has_edge = np.zeros(n, bool)
    for i in range(n):
        for q in range(cols.shape[1]):
            j = int(cols[i, q])
            if j < 0:
                continue
            for combo in range(4):
                suf = vals[i, q, combo]
                if not np.isfinite(suf):
                    continue
                a, b = combo >> 1, combo & 1
                out_edges.setdefault(2 * i + a, []).append((2 * j + b, int(suf)))
                in_deg[2 * j + b] = in_deg.get(2 * j + b, 0) + 1
                has_edge[i] = has_edge[j] = True
    return out_edges, in_deg, has_edge


def extract_contig_chains(s_mat, _edges=None):
    """Canonical unitig partition of the state graph (see module docstring).

    Returns ``(chains, n_branch_cut)`` where each chain is a list of
    ``(state, in_suffix)`` pairs (``in_suffix`` of the head is 0), chains are
    sorted by their minimum state id, and reverse-complement twins are
    already deduplicated.  ``_edges`` takes a precomputed ``state_edges``
    result to avoid re-expanding the graph."""
    out_edges, in_deg, _ = _edges if _edges is not None else state_edges(s_mat)

    # branch-cut rule: keep u→v iff out_deg(u) == 1 and in_deg(v) == 1
    succ: Dict[int, Tuple[int, int]] = {}
    pred: Dict[int, int] = {}
    n_branch_cut = 0
    for u, es in out_edges.items():
        if len(es) == 1 and in_deg.get(es[0][0], 0) == 1:
            v, suf = es[0]
            succ[u] = (v, suf)
            pred[v] = u
        else:
            n_branch_cut += len(es)

    # cut cycles at their minimum state (canonical head)
    seen: set = set()
    for u in list(succ):
        if u in seen:
            continue
        path = []
        on_path: set = set()
        cur = u
        while cur in succ and cur not in seen and cur not in on_path:
            path.append(cur)
            on_path.add(cur)
            cur = succ[cur][0]
        seen.update(on_path)
        if cur in on_path:  # found a cycle; cut the edge entering its min
            cyc = path[path.index(cur):]
            mn = min(cyc)
            prv = pred.pop(mn)
            del succ[prv]

    # chains from heads (no kept in-edge); emit iff head has out-edges
    states = set(out_edges) | set(in_deg)
    emitted: List[List[Tuple[int, int]]] = []
    for h in states:
        if h in pred or h not in out_edges:
            continue
        chain = [(h, 0)]
        cur = h
        while cur in succ:
            v, suf = succ[cur]
            chain.append((v, suf))
            cur = v
        emitted.append(chain)

    # RC-twin dedup: drop c iff its twin is also emitted and twin < c
    keys = {tuple(s for s, _ in c): c for c in emitted}
    kept = []
    for key, c in keys.items():
        twin = tuple(s ^ 1 for s in reversed(key))
        if twin in keys and twin < key:
            continue
        kept.append(c)
    kept.sort(key=lambda c: min(s for s, _ in c))
    return kept, n_branch_cut


def extract_contigs(s_mat, codes, lengths, contained=None) -> List[Contig]:
    """s_mat: EllMatrix string graph (MinPlus 4-vector values).  Reads marked
    ``contained`` are redundant (they lie inside another read) and are not
    emitted as singleton contigs."""
    edges = state_edges(s_mat)
    chains, _ = extract_contig_chains(s_mat, _edges=edges)
    return materialize_contigs(chains, edges[2], codes, lengths, contained)


def materialize_contigs(
    chains, has_edge, codes, lengths, contained=None
) -> List[Contig]:
    """Turn chains of ``(state, in_suffix)`` into sequence-bearing contigs and
    append the isolated-read singletons."""
    codes = np.asarray(codes)
    lengths = np.asarray(lengths)
    n = codes.shape[0]

    contigs: List[Contig] = []
    for chain in chains:
        seq = []
        for t, (state, suf) in enumerate(chain):
            r, s = state >> 1, state & 1
            orient = _oriented(codes[r], lengths[r], s)
            if t == 0:
                seq.append(orient)
            else:
                # a state appends at most its whole read (clamp keeps the
                # backends in agreement on degenerate suffix > length edges)
                suf = min(suf, len(orient))
                seq.append(orient[len(orient) - suf:] if suf > 0 else orient[:0])
        full = np.concatenate(seq) if seq else np.zeros(0, np.uint8)
        contigs.append(
            Contig(
                reads=[(s >> 1, s & 1) for s, _ in chain],
                length=len(full),
                codes=full,
            )
        )

    # isolated reads (no edges at all) become singleton contigs
    cont = (
        np.zeros(n, bool) if contained is None else np.asarray(contained, bool)
    )
    for i in range(n):
        if not has_edge[i] and not cont[i]:
            contigs.append(
                Contig(
                    reads=[(i, 0)],
                    length=int(lengths[i]),
                    codes=codes[i][: lengths[i]].copy(),
                )
            )
    return contigs


def pileup_polish_host(
    draft_codes, draft_lengths, states, offsets, widths, read_codes,
    read_lengths, *, min_depth: int = 2,
):
    """Host dict-and-loop walk of the consensus pileup (DESIGN.md §2.8) —
    the slow, obviously-correct cross-check for the ``consensus`` op's two
    array backends (``kernels/pileup``).  Same vote semantics: votes pass
    the local-coherence gate (read-vs-draft agreement on the ±COH_WIN
    window) before counting, and a column is re-called to the
    smallest-base-code argmax of its vote counts iff it has ``depth ≥
    min_depth`` and a strict majority; otherwise the draft base is kept.
    Returns ``(polished, depth, agree)`` numpy arrays."""
    from ..kernels.pileup.ref import COH_DEN, COH_MIN_VALID, COH_NUM, COH_WIN

    draft = np.asarray(draft_codes)
    dlens = np.asarray(draft_lengths)
    states = np.asarray(states)
    offsets = np.asarray(offsets)
    widths = np.asarray(widths)
    rcodes = np.asarray(read_codes)
    rlens = np.asarray(read_lengths)
    c = draft.shape[0]
    # data-dependent column capacity — the max contig length, not the input
    # tensor's (backend-specific) padding; matches polish_contig_set
    l = max(int(dlens.max(initial=0)), 1)
    draft = draft[:, :l] if draft.shape[1] >= l else np.pad(
        draft, ((0, 0), (0, l - draft.shape[1]))
    )
    counts = np.zeros((c, l, 4), np.int64)
    for i in range(c):
        for t in range(states.shape[1]):
            s = int(states[i, t])
            if s < 0:
                continue
            r, flip = s >> 1, s & 1
            ln = int(rlens[r])
            oriented = _oriented(rcodes[r], ln, flip)
            start = int(offsets[i, t]) + int(widths[i, t]) - ln
            for b in range(ln):
                col = start + b
                if not (0 <= col < l):
                    continue
                match = valid = 0
                for w in range(-COH_WIN, COH_WIN + 1):
                    if w == 0 or not (0 <= b + w < ln):
                        continue
                    if not (0 <= col + w < l):
                        continue
                    valid += 1
                    match += int(oriented[b + w]) == int(draft[i, col + w])
                if COH_DEN * match >= COH_NUM * valid and valid >= COH_MIN_VALID:
                    counts[i, col, int(oriented[b])] += 1
    depth = counts.sum(axis=2)
    win = counts.max(axis=2)
    winner = counts.argmax(axis=2)
    change = (depth >= min_depth) & (2 * win > depth)
    polished = np.where(change, winner, draft).astype(np.uint8)
    agree = np.take_along_axis(
        counts, polished[:, :, None].astype(np.int64), axis=2
    )[:, :, 0]
    # columns past each contig's length are padding in every backend
    colmask = np.arange(l)[None, :] < dlens[:, None]
    polished = np.where(colmask, polished, 0).astype(np.uint8)
    return polished, depth.astype(np.int32), agree.astype(np.int32)


def read_components(s_mat) -> np.ndarray:
    """Connected components of the string graph at *read* granularity
    (both strands of a read collapse to one vertex): ``(n,)`` int array
    labeling each read with the minimum read id of its component.

    The canonical grouping key for multi-chromosome / scaffolding output —
    contigs whose reads share a component derive from one connected piece of
    the assembly (``io_fasta.write_contig_fasta`` groups FASTA records by
    it)."""
    cols = np.asarray(s_mat.cols)
    vals = np.asarray(s_mat.vals)
    n = cols.shape[0]
    parent = np.arange(n)

    def find(x):
        root = x
        while parent[root] != root:
            root = parent[root]
        while parent[x] != root:
            parent[x], x = root, parent[x]
        return root

    for i in range(n):
        for q in range(cols.shape[1]):
            j = int(cols[i, q])
            if j < 0 or not np.isfinite(vals[i, q]).any():
                continue
            ri, rj = find(i), find(int(j))
            if ri != rj:
                parent[max(ri, rj)] = min(ri, rj)
    return np.asarray([find(i) for i in range(n)])


def contig_components(contigs: List[Contig], components: np.ndarray):
    """Component label per contig: the component of its reads (which agree
    by construction — a chain never crosses components)."""
    return [int(components[c.reads[0][0]]) for c in contigs]


def contig_stats(contigs: List[Contig]) -> ContigStats:
    if not contigs:
        return ContigStats(0, 0, 0, 0, 0, 0.0)
    ls = sorted((c.length for c in contigs), reverse=True)
    total = sum(ls)
    if total == 0:
        # all-empty contigs: N50/L50 are undefined — report zeros explicitly
        # rather than whatever the accumulation loop happens to leave behind
        return ContigStats(len(ls), 0, 0, 0, 0, 0.0)
    acc, n50, l50 = 0, 0, 0
    for rank, x in enumerate(ls):
        acc += x
        if acc * 2 >= total:
            n50, l50 = x, rank + 1
            break
    return ContigStats(len(ls), total, n50, ls[0], l50, total / len(ls))


def contig_str(c: Contig) -> str:
    return "".join(BASES[int(x)] for x in c.codes)
