"""Contig extraction: walk non-branching paths of the bidirected string graph.

A walk state is (read, strand); edge (i→j, strands (a, b), suffix ℓ) connects
state (i, a) to (j, b) and appends the last ℓ bases of oriented-j to the
contig.  Unitigs are maximal chains through states with in-degree = out-degree
= 1; each unitig and its reverse-complement twin are emitted once.  Host-side
(graph walking is the tiny tail of the pipeline; the paper stops at the
string graph, this is the minimal consensus-free "C" to make examples
end-to-end).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

from .kmers import BASES


@dataclasses.dataclass
class Contig:
    reads: List[Tuple[int, int]]  # (read, strand) chain
    length: int
    codes: np.ndarray


@dataclasses.dataclass
class ContigStats:
    n_contigs: int
    total_length: int
    n50: int
    longest: int


def _oriented(codes_row: np.ndarray, length: int, strand: int) -> np.ndarray:
    r = codes_row[:length]
    return (3 - r[::-1]) if strand else r


def extract_contigs(s_mat, codes, lengths, contained=None) -> List[Contig]:
    """s_mat: EllMatrix string graph (MinPlus 4-vector values).  Reads marked
    ``contained`` are redundant (they lie inside another read) and are not
    emitted as singleton contigs."""
    cols = np.asarray(s_mat.cols)
    vals = np.asarray(s_mat.vals)
    codes = np.asarray(codes)
    lengths = np.asarray(lengths)
    n = cols.shape[0]

    # state graph over (read, strand)
    out_edges: Dict[Tuple[int, int], List] = {}
    in_deg: Dict[Tuple[int, int], int] = {}
    used_read = np.zeros(n, bool)
    has_edge = np.zeros(n, bool)
    for i in range(n):
        for q in range(cols.shape[1]):
            j = int(cols[i, q])
            if j < 0:
                continue
            for combo in range(4):
                suf = vals[i, q, combo]
                if not np.isfinite(suf):
                    continue
                a, b = combo >> 1, combo & 1
                out_edges.setdefault((i, a), []).append((j, b, int(suf)))
                in_deg[(j, b)] = in_deg.get((j, b), 0) + 1
                has_edge[i] = has_edge[j] = True

    def linear(state):
        return len(out_edges.get(state, [])) == 1 and in_deg.get(state, 0) == 1

    contigs: List[Contig] = []
    visited = set()

    def walk(start):
        chain = [start]
        seq = [_oriented(codes[start[0]], lengths[start[0]], start[1])]
        cur = start
        while True:
            outs = out_edges.get(cur, [])
            if len(outs) != 1:
                break
            j, b, suf = outs[0]
            nxt = (j, b)
            if in_deg.get(nxt, 0) != 1 or nxt in visited or nxt == start:
                break
            chain.append(nxt)
            visited.add(nxt)
            orient = _oriented(codes[j], lengths[j], b)
            seq.append(orient[len(orient) - suf :] if suf > 0 else orient[:0])
            cur = nxt
        full = np.concatenate(seq) if seq else np.zeros(0, np.uint8)
        return Contig(reads=chain, length=len(full), codes=full)

    # starts: states that are not mid-chain
    states = set(out_edges) | set(in_deg)
    for st in sorted(states):
        if st in visited:
            continue
        if not linear(st):
            if out_edges.get(st):
                visited.add(st)
                contigs.append(walk(st))
            continue
    # pure cycles / remaining linear chains
    for st in sorted(states):
        if st not in visited and out_edges.get(st):
            visited.add(st)
            contigs.append(walk(st))

    # deduplicate reverse-complement twins (same read set)
    seen = set()
    uniq: List[Contig] = []
    for c in contigs:
        key = frozenset(r for r, _ in c.reads)
        if key in seen:
            continue
        seen.add(key)
        uniq.append(c)

    # isolated reads (no edges at all) become singleton contigs
    cont = (
        np.zeros(n, bool) if contained is None else np.asarray(contained, bool)
    )
    for i in range(n):
        if not has_edge[i] and not cont[i]:
            uniq.append(
                Contig(
                    reads=[(i, 0)],
                    length=int(lengths[i]),
                    codes=codes[i][: lengths[i]].copy(),
                )
            )
    return uniq


def contig_stats(contigs: List[Contig]) -> ContigStats:
    if not contigs:
        return ContigStats(0, 0, 0, 0)
    ls = sorted((c.length for c in contigs), reverse=True)
    total = sum(ls)
    acc, n50 = 0, 0
    for x in ls:
        acc += x
        if acc >= total / 2:
            n50 = x
            break
    return ContigStats(len(contigs), total, n50, ls[0])


def contig_str(c: Contig) -> str:
    return "".join(BASES[int(x)] for x in c.codes)
