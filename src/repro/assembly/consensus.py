"""Consensus stage: device-side pileup polishing of the contig tensor
(DESIGN.md §2.8).

The OLC paradigm's third act.  After contig generation the contig tensor is a
raw concatenation of error-bearing read suffixes, which bounds per-base
identity at ~(1−e) and k-mer recall at ~(1−e)^k.  This stage maps every
chain read back onto its contig using the layout the Contigs stage already
computed (``ContigSet.offsets/widths``: piece t's *last* ``width`` oriented
bases sit at columns ``[offset, offset + width)``, so the full oriented read
starts at ``offset + width − read_length``), then polishes in three array
steps, none of which loops over reads in Python:

1. **junction refinement** — the chain offsets inherit the x-drop endpoint
   fuzz of the alignment stage (a suffix wrong by ±δ shifts every later read
   and bakes δ inserted/deleted bases into the draft at the junction), so
   each piece's placement against its predecessor is re-estimated by banded
   cross-correlation (shift search in ``[−junction_radius, junction_radius]``
   over the overlap region — all chain pairs scored at once) and the layout
   is rebuilt by cumulative sum of the corrected relative offsets;
2. **draft re-scatter** — the corrected layout re-materializes the draft
   tensor (same last-``width``-bases scatter as the Contigs stage), undoing
   the junction indels;
3. **pileup vote** — the op ``consensus`` (DESIGN.md §2.5) accumulates the
   per-column base-count pileup of every read at its corrected placement and
   re-calls each column by majority vote (strict majority + ``min_depth``
   gating; draft base retained otherwise).  ``"reference"`` is the jnp
   scatter-add oracle, ``"pallas"`` the column-banded Pallas kernel
   (``kernels/pileup``); integer vote counts make the two bit-for-bit
   identical (``tests/test_consensus.py``).  Steps 1–2 are shared jnp code,
   so whole-stage backend parity follows from op parity.

Per-column depth and the vote-agreement fraction give a contig-level
identity/QV estimate for free.

Scope note: refinement is per-junction (one shift per consecutive read
pair), which cancels the dominant, accumulating placement error.  Indel
errors *inside* a read still decay vote coherence away from the read's
anchor (the strict-majority gate keeps those columns on the draft rather
than flipping them on noise); banded per-read realignment against the draft
is the follow-up (ROADMAP).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from ..core.backend import dispatch
from .contigs import Contig, materialize_rows

# junction refinement scores the last JUNCTION_WIN bases of each overlap —
# drift there is what anchors the piece boundary (see _refine_layout)
JUNCTION_WIN = 64


@dataclasses.dataclass
class ConsensusResult:
    """Polished contig tensors + per-column/per-contig quality evidence.

    Rows beyond ``n_contigs`` are padding, aligned with the ``ContigSet``
    the result was polished from.  ``lengths`` is the *refined* layout's
    length per contig (junction refinement can shrink or grow a contig by a
    few bases per junction).  ``identity``/``qv`` are *estimates* from vote
    agreement (fraction of pileup votes agreeing with the emitted base), not
    truth-based measurements — ``assembly/metrics.py`` has the measured
    counterpart."""

    codes: Any  # (C, L) uint8, polished bases
    lengths: Any  # (C,) int32, refined contig lengths
    states: Any  # (C, M) int32, -1 padded (carried from the ContigSet)
    depth: Any  # (C, L) int32, pileup depth per column
    agree: Any  # (C, L) int32, votes agreeing with the emitted base
    depth_mean: Any  # (C,) f32, mean pileup depth per contig
    identity: Any  # (C,) f32, per-contig identity estimate
    qv: Any  # (C,) f32, −10·log10(1 − identity), capped
    n_contigs: int
    stats: Dict[str, float]

    def to_contigs(self) -> List[Contig]:
        return materialize_rows(
            self.codes, self.lengths, self.states, self.n_contigs
        )


@jax.jit
def _gather_pieces(states, offsets, widths, codes, lengths):
    """Orient every chain read and compute its nominal contig placement.

    Returns ``(pieces (C, M, LR) uint8, start (C, M) i32, plen (C, M) i32)``
    where ``pieces[c, t]`` is read t of contig c in contig orientation
    (zero-padded past its length) and ``start`` is the contig column of its
    base 0 under the Contigs-stage layout."""
    lr = codes.shape[1]
    valid = states >= 0
    r = jnp.where(valid, states >> 1, 0)
    rc = (jnp.where(valid, states & 1, 0) == 1)[:, :, None]
    ln = jnp.where(valid, lengths[r], 0)
    start = jnp.where(valid, offsets + widths - ln, 0)
    b = jnp.arange(lr, dtype=jnp.int32)[None, None, :]
    idx = jnp.where(rc, ln[:, :, None] - 1 - b, b)
    base = jnp.take_along_axis(codes[r], jnp.clip(idx, 0, lr - 1), axis=2)
    base = jnp.where(rc, 3 - base, base)
    pieces = jnp.where(b < ln[:, :, None], base, 0).astype(jnp.uint8)
    return pieces, start.astype(jnp.int32), ln.astype(jnp.int32)


@partial(jax.jit, static_argnames=("radius",))
def _refine_layout(pieces, start, plen, *, radius: int):
    """Re-estimate each junction's relative offset by banded correlation.

    For every chain pair (t−1, t) the nominal relative offset
    ``Δ = start_t − start_{t−1}`` is searched over ``Δ + δ, |δ| ≤ radius``
    for the shift maximizing base agreement on the *junction end* of the
    overlap region (its last ``JUNCTION_WIN`` bases).  The junction-local
    window matters on indel-bearing reads: drift varies across the overlap,
    and the piece boundary must be anchored by the drift where the piece
    starts appending, not by the overlap-wide average.  A shift
    is only applied when it beats the nominal placement *decisively*
    (by > max(8, nominal/2) matching bases — i.e. the nominal window looks
    like noise while the shifted one looks like a real overlap): the nominal
    offset came from a real x-drop alignment, so on indel-bearing overlaps —
    where the correlation profile is smeared and a one-shift correction
    cannot model the within-read drift anyway — the layout is left alone,
    and error-free layouts are returned unchanged exactly.  Corrected
    placements are the
    cumulative sum of corrected offsets; the piece layout (offset = previous
    running end, width = newly appended bases) is rebuilt from them.
    Returns ``(start', offset', width', lengths', n_shifted)``."""
    c, m, lr = pieces.shape
    valid = plen > 0
    prev = jnp.roll(pieces, 1, axis=1).astype(jnp.int32)
    prev_len = jnp.roll(plen, 1, axis=1)
    prev_start = jnp.roll(start, 1, axis=1)
    t_pos = jnp.arange(m, dtype=jnp.int32)[None, :]
    pair = valid & (t_pos >= 1) & (prev_len > 0)
    delta0 = jnp.where(pair, start - prev_start, 0)

    b = jnp.arange(lr, dtype=jnp.int32)[None, None, :]
    cur = pieces.astype(jnp.int32)
    # nominal overlap length of each pair; only its junction-side tail is
    # scored (b ∈ [ov − JUNCTION_WIN, ov))
    ov = jnp.where(pair, prev_start + prev_len - start, 0)

    def score_at(d):
        idx = b + delta0[:, :, None] + d
        ok = (
            pair[:, :, None]
            & (b < plen[:, :, None])
            & (b >= (ov - JUNCTION_WIN)[:, :, None])
            & (idx >= 0)
            & (idx < prev_len[:, :, None])
        )
        pv = jnp.take_along_axis(prev, jnp.clip(idx, 0, lr - 1), axis=2)
        return jnp.sum(ok & (pv == cur), axis=2).astype(jnp.int32)

    # δ = 0 first so ties keep the nominal layout; then outward by |δ|
    shifts = [0]
    for d in range(1, radius + 1):
        shifts.extend((-d, d))
    sc = jnp.stack([score_at(d) for d in shifts], axis=-1)  # (C, M, S)
    pick = jnp.argmax(sc, axis=-1)
    dbest = jnp.asarray(shifts, jnp.int32)[pick]
    best = jnp.max(sc, axis=-1)
    sc0 = sc[..., 0]  # the nominal placement (δ = 0 is candidate 0)
    decisive = best > sc0 + jnp.maximum(8, sc0 // 2)
    # ...and the winning window must look like a genuinely coherent overlap
    # (≥ 80% matches): on indel-bearing overlaps no single shift reaches
    # that, so the alignment-derived nominal layout is kept
    strong = 5 * best >= 4 * jnp.minimum(ov, JUNCTION_WIN)
    dbest = jnp.where(pair & decisive & strong, dbest, 0)

    # corrected placement: cumsum of per-junction offsets (head starts at 0)
    step = jnp.where(pair, delta0 + dbest, 0)
    new_start = jnp.cumsum(step, axis=1)
    # piece layout: running-max ends make widths non-negative even if a
    # refined read turns out contained in its predecessors
    ends = jnp.where(valid, new_start + plen, 0)
    run_end = jax.lax.cummax(ends, axis=1)
    prev_end = jnp.concatenate(
        [jnp.zeros((c, 1), run_end.dtype), run_end[:, :-1]], axis=1
    )
    new_width = jnp.where(valid, jnp.maximum(run_end - prev_end, 0), 0)
    new_off = jnp.where(valid, prev_end, 0)
    new_len = jnp.max(run_end, axis=1).astype(jnp.int32)
    n_shifted = jnp.sum(dbest != 0)
    return (
        new_start.astype(jnp.int32),
        new_off.astype(jnp.int32),
        new_width.astype(jnp.int32),
        new_len,
        n_shifted,
    )


@partial(jax.jit, static_argnames=("l",))
def _rescatter_draft(pieces, offs, widths, plen, *, l: int):
    """Re-materialize the draft under a (refined) layout: piece t writes its
    last ``width`` bases at columns ``[offset, offset + width)`` — the same
    contract as the Contigs-stage gather (DESIGN.md §2.7)."""
    c, m, lr = pieces.shape
    b = jnp.arange(lr, dtype=jnp.int32)[None, None, :]
    cols = offs[:, :, None] + b - (plen - widths)[:, :, None]
    on = (b >= (plen - widths)[:, :, None]) & (b < plen[:, :, None])
    on &= (cols >= 0) & (cols < l)
    rows = jnp.arange(c, dtype=jnp.int32)[:, None, None]
    out = jnp.zeros((c, l + 1), jnp.uint8)
    out = out.at[rows, jnp.where(on, cols, l)].set(jnp.where(on, pieces, 0))
    return out[:, :l]


@jax.jit
def _quality(draft, polished, depth, agree, lengths):
    """Shared (backend-independent) reductions over the op outputs."""
    l = draft.shape[1]
    colmask = jnp.arange(l)[None, :] < lengths[:, None]
    covered = colmask & (depth > 0)
    num = jnp.sum(jnp.where(covered, agree, 0), axis=1)
    den = jnp.sum(jnp.where(covered, depth, 0), axis=1)
    ident = num.astype(jnp.float32) / jnp.maximum(den, 1)
    ident = jnp.where(den > 0, ident, 1.0)
    qv = -10.0 * jnp.log10(jnp.maximum(1.0 - ident, 1e-6))
    dsum = jnp.sum(jnp.where(colmask, depth, 0), axis=1)
    depth_c = dsum.astype(jnp.float32) / jnp.maximum(
        jnp.sum(colmask, axis=1), 1
    )
    n_cols = jnp.maximum(jnp.sum(colmask), 1)
    depth_mean = jnp.sum(dsum) / n_cols
    overall = jnp.sum(num).astype(jnp.float32) / jnp.maximum(jnp.sum(den), 1)
    n_changed = jnp.sum((polished != draft) & colmask)
    return ident, qv, depth_c, depth_mean, overall, n_changed


def polish_contig_set(
    cset, codes, lengths, *, backend: str = "auto", min_depth: int = 2,
    band: int = 512, junction_radius: int = 12,
) -> ConsensusResult:
    """Polish a ``ContigSet`` against its own reads via the ``consensus`` op.

    ``codes``/``lengths`` are the read tensors the contigs were generated
    from; ``min_depth``/``band``/``junction_radius`` are the
    ``PipelineConfig`` knobs ``min_depth``/``pileup_band``/
    ``junction_radius`` (``junction_radius=0`` skips refinement and votes on
    the Contigs-stage layout as-is).

    The result's column capacity is the maximum (refined) contig length —
    a *data-dependent* width, deliberately not the input tensor's padded
    width: refinement may grow a contig past the draft's capacity (nothing
    may be truncated), and the two contig backends pad their ContigSets
    differently (exact vs pow2), so any capacity-derived bound would leak
    backend-dependent behavior into the bit-parity contract."""
    states = jnp.asarray(cset.states, jnp.int32)
    pieces, start, plen = _gather_pieces(
        states,
        jnp.asarray(cset.offsets, jnp.int32),
        jnp.asarray(cset.widths, jnp.int32),
        jnp.asarray(codes, jnp.uint8),
        jnp.asarray(lengths, jnp.int32),
    )
    if junction_radius > 0:
        start, offs, widths, lens, n_shifted = _refine_layout(
            pieces, start, plen, radius=junction_radius
        )
        l_op = max(int(jnp.max(lens)), 1)
        draft = _rescatter_draft(pieces, offs, widths, plen, l=l_op)
    else:
        lens = jnp.asarray(cset.lengths, jnp.int32)
        n_shifted = jnp.int32(0)
        l_op = max(int(jnp.max(lens)), 1)
        d0 = jnp.asarray(cset.codes, jnp.uint8)
        draft = (
            d0[:, :l_op] if d0.shape[1] >= l_op
            else jnp.pad(d0, ((0, 0), (0, l_op - d0.shape[1])))
        )
    polished, depth, agree = dispatch("consensus", backend)(
        draft, pieces, start, plen, min_depth=min_depth, band=band
    )
    ident, qv, depth_c, depth_mean, overall, n_changed = _quality(
        draft, polished, depth, agree, lens
    )
    return ConsensusResult(
        codes=polished,
        lengths=lens,
        states=states,
        depth=depth,
        agree=agree,
        depth_mean=depth_c,
        identity=ident,
        qv=qv,
        n_contigs=cset.n_contigs,
        stats={
            "consensus_depth_mean": float(depth_mean),
            "identity_estimate": float(overall),
            "qv_estimate": float(
                -10.0 * np.log10(max(1.0 - float(overall), 1e-6))
            ),
            "n_changed": int(n_changed),
            "n_junction_shifted": int(n_shifted),
        },
    )
