"""Synthetic long-read dataset generator (PacBio-CLR-like, paper Table IV).

Host-side numpy (data generation, not part of the compute path).  Generates a
random genome, samples reads at a target depth with normally-distributed
lengths, flips half the reads to the reverse strand, and corrupts them with
substitutions and short indels at a configurable error rate (CLR errors are
indel-dominated; we default to 60% indels / 40% substitutions of the total
error budget).  Ground-truth positions are returned for validation.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class ReadSet:
    codes: np.ndarray  # (n, L_max) uint8
    lengths: np.ndarray  # (n,) int32
    truth_start: np.ndarray  # (n,) genome start of the error-free template
    truth_end: np.ndarray
    truth_strand: np.ndarray  # (n,) 0 fwd / 1 rc
    genome: np.ndarray  # (G,) uint8

    @property
    def n_reads(self) -> int:
        return self.codes.shape[0]

    @property
    def depth(self) -> float:
        return float(self.lengths.sum()) / len(self.genome)


def simulate_genome(rng: np.random.Generator, length: int) -> np.ndarray:
    return rng.integers(0, 4, size=length, dtype=np.uint8)


def _corrupt(read: np.ndarray, rng, error_rate: float, indel_frac: float):
    if error_rate <= 0:
        return read
    n_err = rng.poisson(error_rate * len(read))
    out = list(read)
    for _ in range(n_err):
        if not out:
            break
        p = rng.integers(0, len(out))
        r = rng.random()
        if r < 1 - indel_frac:  # substitution
            out[p] = (out[p] + rng.integers(1, 4)) % 4
        elif r < 1 - indel_frac / 2:  # deletion
            del out[p]
        else:  # insertion
            out.insert(p, rng.integers(0, 4))
    return np.asarray(out, np.uint8)


def simulate_reads(
    genome: np.ndarray,
    *,
    depth: float = 15.0,
    mean_len: int = 1200,
    std_len: int = 200,
    min_len: int = 300,
    error_rate: float = 0.0,
    indel_frac: float = 0.6,
    seed: int = 0,
    circular: bool = False,
) -> ReadSet:
    rng = np.random.default_rng(seed)
    g = len(genome)
    n = max(2, int(round(depth * g / mean_len)))
    lengths = np.clip(
        rng.normal(mean_len, std_len, size=n).astype(int), min_len, None
    )
    if circular:
        starts = rng.integers(0, g, size=n)
    else:
        starts = rng.integers(0, np.maximum(1, g - lengths), size=n)
        lengths = np.minimum(lengths, g - starts)
    strands = rng.integers(0, 2, size=n)

    reads = []
    for s, l, st in zip(starts, lengths, strands):
        if circular and s + l > g:
            tmpl = np.concatenate([genome[s:], genome[: (s + l) % g]])
        else:
            tmpl = genome[s : s + l]
        if st:
            tmpl = 3 - tmpl[::-1]
        reads.append(_corrupt(tmpl, rng, error_rate, indel_frac))

    lmax = max(len(r) for r in reads)
    codes = np.zeros((n, lmax), np.uint8)
    out_len = np.zeros(n, np.int32)
    for i, r in enumerate(reads):
        codes[i, : len(r)] = r
        out_len[i] = len(r)
    return ReadSet(
        codes=codes,
        lengths=out_len,
        truth_start=starts.astype(np.int64),
        truth_end=(starts + lengths).astype(np.int64),
        truth_strand=strands.astype(np.int32),
        genome=genome,
    )
