"""K-mer extraction and canonicalization (paper §IV-C).

Reads are (n, L_max) uint8 code arrays (A=0, C=1, G=2, T=3) with per-read
lengths.  K-mers are packed 2 bits/base into a (hi, lo) pair of int32 words
(hi: bases 0–14, lo: bases 15–29), supporting k ≤ 30 without 64-bit types
(jax x64 stays off so the LM substrate keeps default dtypes).  The canonical
form is the lexicographic min of the k-mer and its reverse complement; each
instance also carries the strand bit c (0 ⟺ canonical == forward), which the
aligner uses to orient read pairs (s_pair = c_i XOR c_j).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

COMPLEMENT = 3  # complement(code) = 3 - code
BASES = "ACGT"


def encode_seq(s: str) -> jnp.ndarray:
    lut = {c: i for i, c in enumerate(BASES)}
    return jnp.asarray([lut.get(c, 0) for c in s.upper()], jnp.uint8)


def decode_seq(codes) -> str:
    import numpy as np

    return "".join(BASES[int(c)] for c in np.asarray(codes))


def revcomp(codes: jnp.ndarray, length: jnp.ndarray | int) -> jnp.ndarray:
    """Reverse-complement of padded code rows (padding stays at the end).
    Works batched: codes (..., L), length (...)."""
    lmax = codes.shape[-1]
    idx = jnp.asarray(length)[..., None] - 1 - jnp.arange(lmax)
    safe = jnp.clip(idx, 0, lmax - 1)
    idx_b = jnp.broadcast_to(safe, codes.shape)
    out = COMPLEMENT - jnp.take_along_axis(
        codes.astype(jnp.int32), idx_b, axis=-1
    )
    return jnp.where(idx >= 0, out, 0).astype(jnp.uint8)


def _pack(window_codes: jnp.ndarray, k: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Pack (..., k) codes into (hi, lo) int32 words, 15 bases per word,
    big-endian within the word so (hi, lo) ordering is lexicographic."""
    assert k <= 30, "k ≤ 30 supported (2×15 bases in int32)"
    k_hi = min(k, 15)
    c = window_codes.astype(jnp.int32)
    hi = jnp.zeros(c.shape[:-1], jnp.int32)
    for t in range(k_hi):
        hi = hi * 4 + c[..., t]
    # left-align so shorter-than-15 prefixes still compare lexicographically
    hi = hi * (4 ** (15 - k_hi))
    lo = jnp.zeros(c.shape[:-1], jnp.int32)
    for t in range(k_hi, k):
        lo = lo * 4 + c[..., t]
    lo = lo * (4 ** (15 - max(0, k - 15)))
    return hi, lo


@partial(jax.jit, static_argnames=("k",))
def extract_kmers(codes: jnp.ndarray, lengths: jnp.ndarray, *, k: int):
    """All canonical k-mer instances of each read.

    Returns dict with (n, P) arrays where P = L_max − k + 1:
      hi, lo  — packed canonical k-mer
      strand  — 0 if canonical == forward k-mer else 1
      pos     — start position in the (forward) read
      valid   — position in range
    """
    n, lmax = codes.shape
    p = lmax - k + 1
    pos = jnp.arange(p)
    win = pos[:, None] + jnp.arange(k)[None, :]  # (P, k)
    w = codes[:, win]  # (n, P, k)
    fwd_hi, fwd_lo = _pack(w, k)
    wrc = (COMPLEMENT - w[..., ::-1].astype(jnp.int32)).astype(jnp.uint8)
    rc_hi, rc_lo = _pack(wrc, k)
    fwd_smaller = (fwd_hi < rc_hi) | ((fwd_hi == rc_hi) & (fwd_lo <= rc_lo))
    hi = jnp.where(fwd_smaller, fwd_hi, rc_hi)
    lo = jnp.where(fwd_smaller, fwd_lo, rc_lo)
    strand = (~fwd_smaller).astype(jnp.int32)
    valid = pos[None, :] < (lengths[:, None] - k + 1)
    return {
        "hi": hi,
        "lo": lo,
        "strand": strand,
        "pos": jnp.broadcast_to(pos[None, :], (n, p)).astype(jnp.int32),
        "valid": valid,
    }
