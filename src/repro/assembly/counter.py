"""Sort-based k-mer counting and A-matrix construction (paper §IV-C/D).

Hardware adaptation (DESIGN.md §2): HipMer-style distributed hash tables are
replaced by one global sort of the packed canonical k-mer stream — on TPU the
sort plays the role of the MPI_Alltoallv exchange (keys are "routed" to their
sorted position) and gives exact counts, unique ranks, reliable-k-mer
selection and A-matrix column ids in a single fused pass:

  sort (hi, lo) → run boundaries → per-run counts → reliable runs
       → compact reliable-unique rank = A column id → scatter back via the
         inverse permutation → COO triplets of A (and Aᵀ directly).

K-mer selection keeps frequencies in [lower, upper]: singletons are sequencing
errors, high-frequency k-mers are repeats (BELLA's reliable k-mer criterion;
the paper uses max frequency 4 for its experiments).
"""

from __future__ import annotations

from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from ..core.semiring import Semiring
from ..core.spmat import EllMatrix, from_coo

# "keep-first" semiring used to build A / Aᵀ (duplicate (row,col) instances of
# a k-mer within the same read keep the first position).
first_semiring = Semiring(
    name="first_pos",
    mul=lambda a, b: {"pos": a["pos"] + 0 * b["pos"]},
    add=lambda x, y: x,
    zero=lambda s: {"pos": jnp.full(s, -1, jnp.int32)},
    is_zero=lambda v: v["pos"] < 0,
)


class KmerCount(NamedTuple):
    """Fused counting result (all flat (n·P,) instance-aligned arrays)."""

    read_id: jnp.ndarray
    pos_code: jnp.ndarray  # pos*2 + strand
    col_id: jnp.ndarray  # compact reliable-kmer id, -1 if unreliable
    count: jnp.ndarray  # frequency of this instance's k-mer
    reliable: jnp.ndarray  # bool
    m_reliable: jnp.ndarray  # scalar: number of reliable unique k-mers
    n_unique: jnp.ndarray  # scalar
    n_singleton: jnp.ndarray  # scalar


@partial(jax.jit, static_argnames=("lower", "upper"))
def count_and_select(kmers: dict, *, lower: int = 2, upper: int = 8) -> KmerCount:
    """See module docstring. ``kmers`` is the dict from extract_kmers."""
    n, p = kmers["hi"].shape
    e = n * p
    hi = kmers["hi"].reshape(e)
    lo = kmers["lo"].reshape(e)
    valid = kmers["valid"].reshape(e)
    read_id = jnp.broadcast_to(jnp.arange(n)[:, None], (n, p)).reshape(e)
    pos_code = (kmers["pos"] * 2 + kmers["strand"]).reshape(e)

    big = jnp.int32(2**30)
    hik = jnp.where(valid, hi, big)
    lok = jnp.where(valid, lo, big)
    order = jnp.lexsort((lok, hik))
    hs, ls, vs = hik[order], lok[order], valid[order]

    prev_h = jnp.concatenate([jnp.full((1,), -1, hs.dtype), hs[:-1]])
    prev_l = jnp.concatenate([jnp.full((1,), -1, ls.dtype), ls[:-1]])
    new_run = (hs != prev_h) | (ls != prev_l)

    idx = jnp.arange(e)
    run_start = jax.lax.associative_scan(jnp.maximum, jnp.where(new_run, idx, -1))
    next_new = jnp.concatenate([new_run[1:], jnp.ones((1,), bool)])
    run_end = jax.lax.associative_scan(
        jnp.minimum, jnp.where(next_new, idx, e), reverse=True
    )
    count_s = jnp.where(vs, run_end - run_start + 1, 0)

    reliable_s = vs & (count_s >= lower) & (count_s <= upper)
    # compact id: rank among reliable runs
    rel_run_start = new_run & reliable_s
    col_s = jnp.cumsum(rel_run_start.astype(jnp.int32)) - 1
    col_s = jnp.where(reliable_s, col_s, -1)

    m_reliable = jnp.sum(rel_run_start.astype(jnp.int32))
    n_unique = jnp.sum((new_run & vs).astype(jnp.int32))
    n_singleton = jnp.sum((new_run & vs & (count_s < lower)).astype(jnp.int32))

    inv = jnp.zeros((e,), jnp.int32).at[order].set(jnp.arange(e, dtype=jnp.int32))
    return KmerCount(
        read_id=read_id,
        pos_code=pos_code,
        col_id=col_s[inv],
        count=count_s[inv],
        reliable=reliable_s[inv],
        m_reliable=m_reliable,
        n_unique=n_unique,
        n_singleton=n_singleton,
    )


@partial(jax.jit, static_argnames=("n_reads", "m_capacity", "read_capacity", "kmer_capacity"))
def build_matrices(
    kc: KmerCount,
    *,
    n_reads: int,
    m_capacity: int,
    read_capacity: int,
    kmer_capacity: int,
):
    """Build A (reads × k-mers, value = pos*2+strand) and Aᵀ from the fused
    counting result.  ``kmer_capacity`` should equal the ``upper`` frequency
    bound — the paper's frequency cap is what makes Aᵀ's row capacity exact.
    Returns (A, Aᵀ, overflow_a, overflow_at)."""
    ok = kc.reliable & (kc.col_id >= 0)
    vals = {"pos": kc.pos_code}
    a, ovf_a = from_coo(
        kc.read_id,
        kc.col_id,
        vals,
        ok,
        n_rows=n_reads,
        n_cols=m_capacity,
        capacity=read_capacity,
        semiring=first_semiring,
    )
    at, ovf_at = from_coo(
        kc.col_id,
        kc.read_id,
        vals,
        ok,
        n_rows=m_capacity,
        n_cols=n_reads,
        capacity=kmer_capacity,
        semiring=first_semiring,
    )
    return a, at, ovf_a, ovf_at
