"""JAX Bloom filter (paper §IV-C: singleton elimination during counting).

The sort-based counter (counter.py) does not *need* a Bloom filter — sorting
yields exact counts — but the paper's two-phase streaming design (insert into
Bloom, then count only repeated k-mers) matters when the k-mer stream does not
fit memory.  We keep a faithful, fully vectorized implementation with
``n_hashes`` murmur-style hashes; bits are stored as a bool array so the
insert scatter is duplicate-safe.  Property-tested for the no-false-negative
invariant.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

_MIX = (
    jnp.uint32(0x85EBCA6B),
    jnp.uint32(0xC2B2AE35),
    jnp.uint32(0x27D4EB2F),
    jnp.uint32(0x165667B1),
)


def _hash(hi: jnp.ndarray, lo: jnp.ndarray, seed: int) -> jnp.ndarray:
    """Murmur-style finalizer over the packed k-mer words."""
    x = hi.astype(jnp.uint32) ^ (lo.astype(jnp.uint32) * _MIX[seed % 4])
    x ^= x >> 16
    x *= jnp.uint32(0x7FEB352D)
    x ^= x >> 15
    x *= jnp.uint32(0x846CA68B)
    x ^= x >> 16
    x += jnp.uint32(seed) * _MIX[(seed + 1) % 4]
    return x


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["bits"],
    meta_fields=["n_hashes"],
)
@dataclasses.dataclass
class BloomFilter:
    bits: jnp.ndarray  # (n_bits,) bool
    n_hashes: int

    @property
    def n_bits(self) -> int:
        return self.bits.shape[0]

    @staticmethod
    def create(n_bits: int, n_hashes: int = 3) -> "BloomFilter":
        return BloomFilter(bits=jnp.zeros((n_bits,), bool), n_hashes=n_hashes)

    def _slots(self, hi, lo):
        return [
            (_hash(hi, lo, s) % jnp.uint32(self.n_bits)).astype(jnp.int32)
            for s in range(self.n_hashes)
        ]

    def insert(self, hi, lo, valid) -> "BloomFilter":
        bits = self.bits
        for slot in self._slots(hi, lo):
            # .at[].max is duplicate-safe (True wins in any order)
            bits = bits.at[slot].max(valid)
        return BloomFilter(bits=bits, n_hashes=self.n_hashes)

    def query(self, hi, lo) -> jnp.ndarray:
        hit = jnp.ones(jnp.broadcast_shapes(hi.shape, lo.shape), bool)
        for slot in self._slots(hi, lo):
            hit &= self.bits[slot]
        return hit
