# OLC assembly substrate: FASTA I/O, k-mer counting, read simulation,
# x-drop alignment, contig generation (host walk + device path, DESIGN.md
# §2.7), and the Algorithm-1 pipeline.
