# OLC assembly substrate: FASTA I/O, k-mer counting, read simulation,
# x-drop alignment, contig extraction, and the Algorithm-1 pipeline.
