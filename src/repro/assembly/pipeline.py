"""diBELLA 2D pipeline — the paper's Algorithm 1, end to end.

    reads → k-mer count/select → A, Aᵀ → C = A·Aᵀ (overlap semiring)
          → x-drop alignment on nnz(C) → prune by score → R
          → transitive reduction (Algorithm 2) → S → contigs
          → consensus (pileup polish, DESIGN.md §2.8)

Every stage is the JAX/TPU adaptation documented in DESIGN.md §2; stages are
individually jitted, and the overlap SpGEMM + transitive reduction can run
either locally or 2D-distributed over a mesh (SUMMA).  Per-stage wall-clock is
collected for the Fig. 5–8 style breakdown benchmark; with
``PipelineConfig.trace`` the same stage boundaries open :mod:`repro.obs`
spans, nesting the shard_map phase and kernel-launch spans the sub-stages
emit, and the resulting span tree is exportable as a Chrome trace
(``repro.obs.write_chrome_trace``).
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..core.backend import resolve_backend, resolve_distribution
from ..core.semiring import overlap_semiring
from ..core.spgemm import spgemm
from ..core.spmat import map_row_blocks, next_pow2
from ..core.summa import default_summa_mesh, overlap_spgemm_shard_map
from ..core.string_graph import build_overlap_graph, classify_overlaps, drop_contained
from ..core.transitive_reduction import (
    transitive_reduction,
    transitive_reduction_fused,
)
from ..obs import Metrics, Tracer, span, tracing, watermark
from . import alignment as al
from .consensus import polish_contig_set
from .contig_gen import generate_contigs
from .contigs import contig_stats
from .counter import build_matrices, count_and_select
from .kmers import extract_kmers, revcomp


@dataclasses.dataclass
class PipelineConfig:
    k: int = 15
    lower: int = 2  # reliable k-mer frequency window [lower, upper]
    upper: int = 8
    read_capacity: int = 128  # K_A: reliable k-mers kept per read
    m_capacity: int = 1 << 16  # static bound on reliable-unique k-mers
    overlap_capacity: int = 64  # K_C: candidate overlaps per read
    r_capacity: int = 48  # K_R: overlap-graph row capacity
    min_shared_kmers: int = 2
    # alignment
    xdrop: int = 20
    match: int = 1
    mismatch: int = -1
    gap: int = -1
    band: int = 65
    max_steps: int = 4096
    score_frac: float = 0.35  # accept if score ≥ frac · overlap span
    min_overlap: int = 100
    end_fuzz: int = 40
    # transitive reduction
    tr_fuzz: float = 150.0
    tr_max_iters: int = 8
    fused_tr: bool = True  # beyond-paper sampled square (DESIGN.md §2)
    align_chunk: int = 4096
    # consensus polishing of the contig tensor (DESIGN.md §2.8)
    polish: bool = True
    min_depth: int = 2  # pileup votes required before a column is re-called
    pileup_band: int = 512  # contig columns per pileup kernel block
    junction_radius: int = 12  # chain-junction refinement shift search radius
    # kernel backend for the hot ops (x-drop extension, min-plus squares):
    # "auto" = compiled Pallas on TPU, reference jnp elsewhere (DESIGN.md §2.5)
    backend: str = "auto"
    # distribution of the explicitly-exchanged stages (DESIGN.md §2.9-§2.11):
    # "gspmd" = auto-sharded, "shard_map" = (a) the overlap SpGEMM on the
    # explicit-exchange ring SUMMA (core/summa.py, 2D ("data", "model") mesh
    # built when `mesh` lacks a "model" axis), (b) the x-drop extension
    # block-split along the candidate-pair axis over the mesh's grid-row
    # axes (core/align_dist.py, §2.12) and (c) the contig chain stage's
    # branch cut + doubling + ring-bitonic ordering under one ppermute/psum
    # exchange region over `mesh` (a 1D device mesh is built when None)
    distribution: str = "gspmd"
    mesh: Any = None
    # ring-SUMMA stages fused per spgemm_ring_stages call (the fused Pallas
    # kernel's HBM round trips = ceil(√P / this))
    summa_stages_per_call: int = 4
    # collect a hierarchical span trace (stage → shard_map phase → kernel
    # launch) on AssemblyResult.trace; spans also forward to
    # jax.profiler.TraceAnnotation so device profiles carry the same names
    trace: bool = False


@dataclasses.dataclass
class AssemblyResult:
    r_graph: Any  # overlap matrix R (EllMatrix)
    s_graph: Any  # string matrix S (EllMatrix)
    contigs: list  # draft contigs (raw read concatenation)
    stats: Dict[str, Any]
    timings: Dict[str, float]
    contained: Any = None  # (n,) bool, reads dropped as contained
    consensus: Any = None  # ConsensusResult when cfg.polish (DESIGN.md §2.8)
    trace: Any = None  # obs.Tracer with the span tree when cfg.trace

    @functools.cached_property
    def polished_contigs(self) -> list:
        """Consensus-polished contigs (materialized once from the polished
        tensor); falls back to the draft when the polish stage was
        disabled."""
        return self.consensus.to_contigs() if self.consensus else self.contigs


@contextlib.contextmanager
def _tic(timings, key):
    """Stage timing as a thin wrapper over :func:`repro.obs.span` — the one
    timing code path.  The span device-syncs on whatever the body passes to
    ``sp.set_output`` (any pytree, dataclasses included), so the recorded
    wall-clock measures execution rather than async dispatch, and the stage
    appears in the active tracer's tree when tracing is on."""
    with span(key, kind="stage") as sp:
        yield sp
    timings[key] = timings.get(key, 0.0) + sp.duration_s


def assemble(codes, lengths, cfg: PipelineConfig = PipelineConfig()) -> AssemblyResult:
    # the whole run executes under a device-memory watermark (obs/memory.py)
    # so every AssemblyResult.stats carries the peak_hbm_bytes family —
    # HBM capacity is the genome-size ceiling, and the watermark is what the
    # bench trajectory and the regression gate track
    with watermark() as wm:
        tracer = Tracer(annotate=True) if cfg.trace else None
        if tracer is None:
            res = _assemble(codes, lengths, cfg, tracer=None)
        else:
            with tracing(tracer):
                res = _assemble(codes, lengths, cfg, tracer=tracer)
    from ..obs import validated

    res.stats.update(validated({
        "peak_hbm_bytes": wm.peak_hbm_bytes,
        "hbm_bytes_in_use": wm.hbm_bytes_in_use,
        "hbm_source": wm.source,
    }, context="assemble"))
    return res


def _assemble(codes, lengths, cfg: PipelineConfig, *, tracer) -> AssemblyResult:
    codes = jnp.asarray(codes, jnp.uint8)
    lengths = jnp.asarray(lengths, jnp.int32)
    n = codes.shape[0]
    backend = resolve_backend(cfg.backend)
    timings: Dict[str, float] = {}
    metrics = Metrics(context="assemble")
    metrics.emit("n_reads", int(n))
    metrics.emit("backend", backend)

    # --- CountKmer (paper: CountKmer) ---
    with _tic(timings, "CountKmer") as sp:
        kmers = extract_kmers(codes, lengths, k=cfg.k)
        kc = sp.set_output(
            count_and_select(kmers, lower=cfg.lower, upper=cfg.upper)
        )
    metrics.emit_many({
        "m_reliable": int(kc.m_reliable),
        "n_unique_kmers": int(kc.n_unique),
        "n_singletons": int(kc.n_singleton),
    })
    assert int(kc.m_reliable) <= cfg.m_capacity, (
        f"m_capacity too small: {int(kc.m_reliable)} > {cfg.m_capacity}"
    )

    # --- CreateSpMat: A and Aᵀ ---
    with _tic(timings, "CreateSpMat") as sp:
        a, at, ovf_a, ovf_at = build_matrices(
            kc,
            n_reads=int(n),
            m_capacity=cfg.m_capacity,
            read_capacity=cfg.read_capacity,
            kmer_capacity=cfg.upper,
        )
        sp.set_output((a.cols, at.cols))
    metrics.emit("overflow_A", int(ovf_a))
    metrics.emit("nnz_A", int(a.nnz()))

    # --- SpGEMM: C = A·Aᵀ under the overlap semiring ---
    # distribution="shard_map" runs it on the explicit-exchange ring SUMMA
    # (zero GSPMD sub-stages, DESIGN.md §2.11) — bit-identical to the local
    # product, with the per-ppermute exchange words surfaced in stats.  The
    # summa exchange stats are present-and-zero on the gspmd path, same
    # contract as the contig-stage exchange keys below (seeded from
    # obs.schema's "summa_exchange" group after the branch).
    with _tic(timings, "SpGEMM") as sp:
        if resolve_distribution(cfg.distribution) == "shard_map":
            from .counter import first_semiring

            summa_mesh = cfg.mesh
            if (
                summa_mesh is None
                or "model" not in getattr(summa_mesh, "axis_names", ())
                or len(summa_mesh.axis_names) < 2
            ):
                summa_mesh = default_summa_mesh()
            c_mat, ovf_c, summa_stats = overlap_spgemm_shard_map(
                a, at, semiring=overlap_semiring,
                operand_semiring=first_semiring,
                capacity=cfg.overlap_capacity, mesh=summa_mesh,
                backend=backend,
                stages_per_call=cfg.summa_stages_per_call,
            )
            metrics.emit("overlap_distribution", "shard_map")
            metrics.emit_many(summa_stats)
        else:
            c_mat, ovf_c = spgemm(
                a, at, semiring=overlap_semiring, capacity=cfg.overlap_capacity
            )
            metrics.emit("overlap_distribution", "gspmd")
        sp.set_output(c_mat.cols)
    metrics.seed_zero("summa_exchange")
    metrics.emit("overflow_C", int(ovf_c))
    metrics.emit("nnz_C", int(c_mat.nnz()))
    metrics.emit("c_density", metrics["nnz_C"] / max(1, int(n)))

    # --- Pairwise alignment on nnz(C) (upper triangle; each pair once) ---
    with _tic(timings, "Alignment") as sp:
        kq = cfg.overlap_capacity
        pair_i = jnp.broadcast_to(jnp.arange(n)[:, None], (n, kq)).reshape(-1)
        pair_j = c_mat.cols.reshape(-1)
        cnt = c_mat.vals["cnt"].reshape(-1)
        apos = c_mat.vals["apos"][..., 0].reshape(-1)
        bpos = c_mat.vals["bpos"][..., 0].reshape(-1)
        pv = (pair_j > pair_i) & (cnt >= cfg.min_shared_kmers)

        pa = apos // 2
        ca = apos % 2
        pb = bpos // 2
        cb = bpos % 2
        strand = jnp.where(pv, ca ^ cb, 0)
        li = lengths[jnp.where(pv, pair_i, 0)]
        lj = lengths[jnp.where(pv, pair_j, 0)]
        pb_or = jnp.where(strand == 1, lj - cfg.k - pb, pb)

        # Candidate compaction: C's ELL layout leaves most of the n × K_C
        # slots masked — instead of aligning every slot, gather the pv-valid
        # pairs into a bucket padded to the next power of two of the live
        # count, align only the bucket (row-chunked), and scatter results
        # back to slot order.
        e_total = int(pair_i.shape[0])
        n_live = int(jnp.sum(pv))
        bucket = next_pow2(n_live)
        idx = jnp.nonzero(pv, size=bucket, fill_value=0)[0]
        live = jnp.arange(bucket) < n_live

        cand = {
            "i": pair_i[idx],
            "j": pair_j[idx],
            "li": li[idx],
            "lj": lj[idx],
            "pa": jnp.maximum(pa[idx], 0),
            "pb": jnp.maximum(pb_or[idx], 0),
            "strand": strand[idx],
        }

        # distribution="shard_map" redistributes the bucket over the mesh's
        # grid-row axes inside one explicit-exchange shard_map region
        # (core/align_dist.py, DESIGN.md §2.12) — bit-identical per-pair
        # results, with the gather/scatter words surfaced in stats.  The
        # align exchange stats are present-and-zero on the gspmd path
        # (seeded from obs.schema's "align_exchange" group after the
        # branch), same contract as the summa keys above.
        if resolve_distribution(cfg.distribution) == "shard_map":
            from ..core.align_dist import align_bucket_shard_map

            res_b, align_stats = align_bucket_shard_map(
                codes, cand, k=cfg.k, mesh=cfg.mesh, backend=backend,
                xdrop=cfg.xdrop, match=cfg.match, mismatch=cfg.mismatch,
                gap=cfg.gap, band=cfg.band, max_steps=cfg.max_steps,
            )
            metrics.emit("align_distribution", "shard_map")
            metrics.emit_many(align_stats)
        else:
            def _align_block(blk):
                ai = codes[blk["i"]]
                bj = codes[blk["j"]]
                bj = jnp.where(
                    (blk["strand"] == 1)[:, None], revcomp(bj, blk["lj"]), bj
                )
                out = al.batch_extend(
                    ai, blk["li"], bj, blk["lj"], blk["pa"], blk["pb"],
                    k=cfg.k, backend=backend, xdrop=cfg.xdrop,
                    match=cfg.match, mismatch=cfg.mismatch, gap=cfg.gap,
                    band=cfg.band, max_steps=cfg.max_steps,
                )
                return tuple(out), None

            res_b, _ = map_row_blocks(
                _align_block, cand, n_rows=bucket,
                row_chunk=min(cfg.align_chunk, bucket),
            )
            metrics.emit("align_distribution", "gspmd")

        # Scatter bucket results back to the (n · K_C,) slot layout; dead
        # slots (pv False) keep zeros and are masked out of ``passed`` below.
        safe_slot = jnp.where(live, idx, e_total)

        def _scatter(x):
            buf = jnp.zeros((e_total + 1,) + x.shape[1:], x.dtype)
            return buf.at[safe_slot].set(x)[:e_total]

        res = al.PairAlignment(*(_scatter(x) for x in res_b))
        sp.set_output(res.score)

    ospan = jnp.minimum(res.ei - res.bi, res.ej - res.bj)
    passed = (
        pv
        & (res.score >= cfg.score_frac * ospan)
        & (ospan >= cfg.min_overlap)
    )
    metrics.seed_zero("align_exchange")
    metrics.emit_many({
        "n_aligned": n_live,
        "align_candidates": e_total,
        "align_bucket": int(bucket),
        "n_passed": int(jnp.sum(passed)),
    })

    # --- Build R: classify overlaps, drop contained ---
    with _tic(timings, "BuildR") as sp:
        cls = classify_overlaps(
            res.bi, res.ei, li, res.bj, res.ej, lj, strand,
            end_fuzz=cfg.end_fuzz,
        )
        r_mat, contained, ovf_r = build_overlap_graph(
            pair_i, pair_j, cls, passed, n_reads=int(n),
            capacity=cfg.r_capacity,
        )
        r_mat = drop_contained(r_mat, contained)
        sp.set_output(r_mat.cols)
    metrics.emit("overflow_R", int(ovf_r))
    metrics.emit("nnz_R", int(r_mat.nnz()))
    metrics.emit("r_density", metrics["nnz_R"] / max(1, int(n)))
    metrics.emit("n_contained", int(jnp.sum(contained)))

    # --- TrReduction: Algorithm 2 ---
    with _tic(timings, "TrReduction") as sp:
        tr = transitive_reduction_fused if cfg.fused_tr else transitive_reduction
        s_mat, tr_stats = tr(
            r_mat, fuzz=cfg.tr_fuzz, max_iters=cfg.tr_max_iters,
            backend=backend,
        )
        sp.set_output(s_mat.cols)
    metrics.emit("tr_iterations", int(tr_stats.iterations))
    # the kernel path that actually ran: transitive_reduction_fused silently
    # downgrades backend="pallas" to the sampled ELL square above
    # TR_DENSE_MAX_ROWS, and benchmark rows must label the real path
    metrics.emit("tr_backend", tr_stats.backend)
    metrics.emit("tr_overflow", int(tr_stats.n_overflow))
    metrics.emit("nnz_S", int(s_mat.nnz()))
    metrics.emit("s_density", metrics["nnz_S"] / max(1, int(n)))

    # --- Contigs (backend-dispatched: host walk or device path, §2.7;
    # distribution-dispatched: gspmd or shard_map doubling, §2.9) ---
    with _tic(timings, "Contigs") as sp:
        cset = generate_contigs(
            s_mat, codes, lengths, contained, backend=backend,
            distribution=cfg.distribution, mesh=cfg.mesh,
        )
        contigs = cset.to_contigs()
        cs = contig_stats(contigs)
        sp.set_output(cset.codes)
    metrics.emit("contigs", dataclasses.asdict(cs))
    metrics.emit("n_branch_cut", cset.stats["n_branch_cut"])
    metrics.emit("cc_iterations", cset.stats["cc_iterations"])
    # what actually ran: "gspmd"/"shard_map" on the device path, "host" when
    # the backend resolved to the reference walk (the knob then has no
    # effect — surfaced rather than silently re-labelled)
    metrics.emit("distribution", cset.stats["distribution"])
    # exchange accounting is present-and-zero on paths without explicit
    # exchanges (gspmd / host), so distribution-axis benchmark rows compare
    # without key-existence checks (DESIGN.md §2.10); the key set is the
    # schema's "contig_exchange" group
    metrics.emit_many({
        key: val for key, val in cset.stats.items()
        if key.startswith("exchange_")
    })
    metrics.seed_zero("contig_exchange")

    # --- Consensus: pileup polishing of the contig tensor (§2.8) ---
    cres = None
    if cfg.polish:
        with _tic(timings, "Consensus") as sp:
            cres = polish_contig_set(
                cset, codes, lengths, backend=backend,
                min_depth=cfg.min_depth, band=cfg.pileup_band,
                junction_radius=cfg.junction_radius,
            )
            sp.set_output(cres.codes)
        metrics.emit_many({
            "consensus_depth_mean": cres.stats["consensus_depth_mean"],
            "identity_estimate": cres.stats["identity_estimate"],
            "qv_estimate": cres.stats["qv_estimate"],
            "consensus_changed": cres.stats["n_changed"],
            "n_junction_shifted": cres.stats["n_junction_shifted"],
        })

    return AssemblyResult(
        r_graph=r_mat, s_graph=s_mat, contigs=contigs, stats=metrics.as_dict(),
        timings=timings, contained=contained, consensus=cres, trace=tracer,
    )
