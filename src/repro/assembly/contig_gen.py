"""Device-side contig generation (DESIGN.md §2.7).

The Contigs stage of Algorithm 1, rebuilt as jittable array algorithms over
the string matrix S — the approach of the diBELLA follow-up paper
(*Distributed-Memory Parallel Contig Generation for De Novo Long-Read Genome
Assembly*, Guidi et al. 2022), which expresses contig generation as sparse
matrix operations so it can run on the same mesh as the SpGEMM and the
transitive reduction:

1. expand S into the 2n-vertex state graph (``core/components.expand_states``);
2. branch-cut: keep edge u→v iff out-degree(u) == 1 and in-degree(v) == 1
   (the per-vertex degree filter of the 2022 paper's algorithm) — kept edges
   form disjoint paths and cycles;
3. cut cycles at their minimum state (``break_cycles``), label unitigs with
   pointer-doubling path components (``path_components``), order states
   within each unitig by pointer-doubling rank (``chain_rank``);
4. deduplicate reverse-complement twin chains (lexicographic canonical
   representative), lay out each contig as (destination row, offset) per
   state, and gather the oriented read suffixes into one padded
   ``(n_contigs, max_len)`` uint8 tensor with a single batched scatter.

No step loops over reads in Python; the only host interaction is reading four
scalars (#chains, max chain length, #contigs, max contig length) to pick
power-of-two padded shapes between the three jitted stages — the same
host-sized/pow2-padded staging the alignment candidate compaction uses
(DESIGN.md §2.6).

Backend contract: the op ``contig_gen`` is registered with the dispatch layer
(DESIGN.md §2.5).  ``"reference"`` is the host dict-and-loop walk in
``assembly/contigs.py``; ``"pallas"`` is this device path (pure XLA array
ops — it needs no hand-written kernel, but it is the implementation that
runs on the accelerator/mesh, which is what the backend axis selects).  Both
must produce identical contigs — asserted chain-by-chain by the golden
parity suite in ``tests/test_contigs.py``.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from ..core.backend import dispatch, register_op
from ..obs import schema, validated
from ..core.components import (
    break_cycles,
    chain_rank,
    degrees,
    expand_states,
    path_components,
)
from ..core.semiring import minplus_orient_semiring
from ..core.spmat import EllMatrix, from_coo, next_pow2
from .contigs import (
    Contig,
    extract_contig_chains,
    materialize_contigs,
    materialize_rows,
    state_edges,
)

_BIG = jnp.int32(2**30)


@dataclasses.dataclass
class ContigSet:
    """Batched contig tensors + the thin materialization layer.

    ``codes``/``lengths``/``states`` rows beyond ``n_contigs`` are padding.
    ``states`` holds the (read, strand) chain as state ids ``2·read+strand``
    (−1 padded); singleton contigs have a single state ``2·read``.

    ``offsets``/``widths`` are the per-piece read provenance consumed by the
    consensus stage (DESIGN.md §2.8), aligned with ``states``: piece t of a
    contig wrote its last ``widths[c, t]`` oriented bases at contig columns
    ``[offsets[c, t], offsets[c, t] + widths[c, t])``, so the *full* oriented
    read spans columns starting at ``offsets + widths − read_length``.
    Entries where ``states < 0`` are zero padding."""

    codes: Any  # (C, L) uint8
    lengths: Any  # (C,) int32
    states: Any  # (C, M) int32, -1 padded
    offsets: Any  # (C, M) int32, piece destination column
    widths: Any  # (C, M) int32, bases the piece appended
    n_contigs: int
    # n_branch_cut, cc_iterations, distribution ("gspmd"|"shard_map"|"host")
    # and the exchange accounting (§2.9/§2.10): exchange_words/-_rounds plus
    # the per-phase split (exchange_words_cut/_doubling/_sort,
    # exchange_rounds_doubling/_sort) — present on every path, zero where no
    # explicit exchange runs (gspmd auto-sharding, host walk)
    stats: Dict[str, Any]

    def to_contigs(self) -> List[Contig]:
        """Materialize the padded tensors into host ``Contig`` records (the
        thin layer consumed by ``contig_stats``/FASTA output)."""
        return materialize_rows(
            self.codes, self.lengths, self.states, self.n_contigs
        )


def string_matrix_from_edges(n_reads, edges, *, capacity=8) -> EllMatrix:
    """Build a MinPlus string matrix from an explicit edge list — test and
    benchmark scaffolding.  ``edges``: iterable of ``(i, j, strand_i,
    strand_j, suffix)`` directed state-graph edges."""
    edges = list(edges)
    if not edges:
        edges = [(0, 0, 0, 0, 0)]
        ok = jnp.zeros(1, bool)
    else:
        ok = jnp.ones(len(edges), bool)
    arr = np.asarray(edges, np.int64)
    e = arr.shape[0]
    combo = 2 * arr[:, 2] + arr[:, 3]
    vals = np.full((e, 4), np.inf, np.float32)
    vals[np.arange(e), combo] = arr[:, 4]
    mat, _ = from_coo(
        jnp.asarray(arr[:, 0], jnp.int32),
        jnp.asarray(arr[:, 1], jnp.int32),
        jnp.asarray(vals),
        ok,
        n_rows=n_reads,
        n_cols=n_reads,
        capacity=capacity,
        semiring=minplus_orient_semiring,
    )
    return mat


def consistent_chain_graph(n, seed, *, err=0.0, break_every=None):
    """Dovetail-chain string matrix whose reads really are slices of one
    synthetic genome (optionally ``err`` substitutions, optionally broken
    into separate chains every ``break_every`` reads) — test and benchmark
    scaffolding for the consensus stage, where overlap votes must be
    genome-coherent to pass the coherence gate (DESIGN.md §2.8).  Returns
    ``(s_mat, codes, lengths, genome)``."""
    rng = np.random.default_rng(seed)
    lengths = rng.integers(180, 250, n).astype(np.int32)
    pos = np.zeros(n, np.int64)
    edges = []
    for i in range(n - 1):
        ov = int(min(rng.integers(80, 140), lengths[i] - 1,
                     lengths[i + 1] - 1))
        pos[i + 1] = pos[i] + lengths[i] - ov
        if break_every is None or i % break_every != break_every - 1:
            edges.append((i, i + 1, 0, 0, int(lengths[i + 1]) - ov))
            edges.append((i + 1, i, 1, 1, int(lengths[i]) - ov))
    genome = rng.integers(0, 4, int(pos[-1] + lengths[-1]), dtype=np.uint8)
    lmax = int(lengths.max())
    codes = np.zeros((n, lmax), np.uint8)
    for i in range(n):
        codes[i, : lengths[i]] = genome[pos[i] : pos[i] + lengths[i]]
    if err > 0:
        flip = rng.random((n, lmax)) < err
        codes = np.where(
            flip, (codes + rng.integers(1, 4, (n, lmax))) % 4, codes
        ).astype(np.uint8)
    return string_matrix_from_edges(n, edges, capacity=8), codes, lengths, genome


# ---------------------------------------------------------------------------
# Stage 1: state graph, branch cut, components, rank — fully static shapes.
# Split into graph-cut / doubling / chain-ordering so the doubling middle can
# swap between the local (GSPMD auto-sharded) path and the shard_map
# explicit-exchange path (DESIGN.md §2.9) without re-tracing the rest.
# ---------------------------------------------------------------------------


@jax.jit
def _graph_cut(s: EllMatrix):
    """State graph + branch cut: expand S into the 2n-state graph, keep edge
    u→v iff out-deg(u) == 1 and in-deg(v) == 1 (the 2022 paper's degree
    filter), and emit the functional succ/pred pointer pair the doubling
    stages consume."""
    g = expand_states(s)
    n2 = g.n_cols
    out_deg, in_deg = degrees(g)

    # branch cut: keep u→v iff out_deg(u)==1 and in_deg(v)==1.  For rows with
    # out_deg==1 the single target/suffix fall out of a masked max/sum.
    tgt = jnp.max(jnp.where(g.mask, g.cols, -1), axis=1)
    suf = jnp.sum(jnp.where(g.mask, g.vals, 0.0), axis=1)
    tgt_safe = jnp.where(tgt >= 0, tgt, 0)
    kept = (out_deg == 1) & (tgt >= 0) & (in_deg[tgt_safe] == 1)
    succ0 = jnp.where(kept, tgt, -1)
    n_branch_cut = jnp.sum(out_deg) - jnp.sum(kept).astype(jnp.int32)

    # pred + in-suffix: in_deg(target)==1 makes the scatter single-writer
    scat = jnp.where(kept, succ0, n2)
    ids = jnp.arange(n2, dtype=jnp.int32)
    pred0 = jnp.full(n2 + 1, -1, jnp.int32).at[scat].set(ids)[:n2]
    insuf = jnp.zeros(n2 + 1, jnp.float32).at[scat].set(suf)[:n2]

    has_edge = (out_deg + in_deg).reshape(-1, 2).sum(axis=1) > 0  # per read
    return {
        "succ0": succ0,
        "pred0": pred0,
        "insuf": insuf,
        "out_deg": out_deg,
        "has_edge": has_edge,
        "n_branch_cut": n_branch_cut,
    }


@jax.jit
def _doubling_local(succ0, pred0):
    """Local (single-jit, GSPMD-sharded) doubling middle: cut cycles, label
    unitigs, rank states within each chain.

    path_components' doubling is O(log n) for any id permutation along the
    chain (generic min-label propagation needs Θ(n) rounds on permuted
    paths and would truncate long unitigs)."""
    succ, pred, _ = break_cycles(succ0, pred0)
    labels, cc_iters = path_components(succ, pred)
    head, rank, _ = chain_rank(pred)
    return {
        "labels": labels,
        "head": head,
        "rank": rank,
        "cc_iterations": cc_iters,
    }


@jax.jit
def _order_chains(cut, dbl):
    """Group states by (unitig label, in-chain rank): eligible chains first,
    label-ascending — the canonical chain order both backends share."""
    out_deg, insuf = cut["out_deg"], cut["insuf"]
    labels, head, rank = dbl["labels"], dbl["head"], dbl["rank"]
    n2 = labels.shape[0]
    eligible = out_deg[head] > 0  # a chain emits iff its head has out-edges

    order = jnp.lexsort((rank, jnp.where(eligible, labels, _BIG)))
    state_s = order.astype(jnp.int32)
    elig_s = eligible[order]
    lab_s = labels[order]
    rank_s = rank[order]
    prev = jnp.where(jnp.arange(n2) == 0, -1, jnp.roll(lab_s, 1))
    new_chain = elig_s & (lab_s != prev)
    chain_idx_s = jnp.cumsum(new_chain.astype(jnp.int32)) - 1

    return {
        "state_s": state_s,
        "elig_s": elig_s,
        "rank_s": rank_s,
        "chain_idx_s": chain_idx_s,
        "new_chain": new_chain,
        "insuf": insuf,
        "has_edge": cut["has_edge"],
        "n_chains": jnp.sum(new_chain).astype(jnp.int32),
        "max_chain": jnp.max(jnp.where(elig_s, rank_s, -1)) + 1,
        "n_branch_cut": cut["n_branch_cut"],
        "cc_iterations": dbl["cc_iterations"],
    }


# exchange accounting is part of the ContigSet.stats contract on *every*
# path: present-and-zero where no explicit exchange runs (gspmd / host), so
# `bench_contigs --distribution` rows stay comparable without key-existence
# checks (the shard_map path overwrites these with measured values).  The key
# set is declared once, in obs/schema.py's "contig_exchange" group.
ZERO_EXCHANGE_STATS = schema.zero_defaults("contig_exchange")


def _chain_state(
    s: EllMatrix, *, distribution: str = "gspmd", mesh=None, row_axes=None
):
    """Stage 1 driver: graph cut → doubling middle → chain ordering.

    ``distribution`` selects the whole chain stage (DESIGN.md §2.9/§2.10):
    ``"gspmd"`` runs the auto-sharded local path (`_graph_cut` →
    `_doubling_local` → `_order_chains`); ``"shard_map"`` runs all three
    sub-stages — distributed branch cut, explicit ``ppermute``/``psum``
    doubling, ring-bitonic chain ordering — under the single ``shard_map``
    region of ``core/components_dist.contig_stage_shard_map`` over ``mesh``
    (built on demand when absent), so the arrays never leave the mesh
    between sub-stages.

    Returns ``(st, dist_stats)``: ``st`` is the pytree the jitted layout/
    gather stages consume (kept free of host scalars so their traces are
    shared across calls); ``dist_stats`` holds the exchange accounting —
    measured per-phase words/rounds on the shard_map path, present-and-zero
    otherwise."""
    if distribution == "shard_map":
        from ..core.components_dist import (
            contig_stage_shard_map,
            default_row_mesh,
        )

        if mesh is None:
            mesh = default_row_mesh()
        st, xstats = contig_stage_shard_map(s, mesh=mesh, row_axes=row_axes)
        return st, {**ZERO_EXCHANGE_STATS, **xstats}
    cut = _graph_cut(s)
    st = _order_chains(cut, _doubling_local(cut["succ0"], cut["pred0"]))
    return st, dict(ZERO_EXCHANGE_STATS)


# ---------------------------------------------------------------------------
# Stage 2: chain rows, RC-twin dedup, per-piece destination layout.
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("ca", "m"))
def _chain_layout(st, lengths, contained, *, ca, m):
    state_s, elig_s = st["state_s"], st["elig_s"]
    rank_s, chain_idx_s = st["rank_s"], st["chain_idx_s"]
    n2 = state_s.shape[0]

    chain_safe = jnp.where(elig_s, chain_idx_s, ca)
    rows = (
        jnp.full((ca + 1, m), -1, jnp.int32)
        .at[chain_safe, jnp.minimum(rank_s, m - 1)]
        .set(state_s)[:ca]
    )
    valid = rows[:, 0] >= 0
    chain_len = jnp.sum(rows >= 0, axis=1).astype(jnp.int32)
    heads = rows[:, 0]
    tail = jnp.take_along_axis(
        rows, jnp.maximum(chain_len - 1, 0)[:, None], axis=1
    )[:, 0]

    # RC-twin dedup: chain c = [u0..uk] is dropped iff its twin
    # t = [uk^1..u0^1] is also an emitted chain and t < c lexicographically.
    # Heads are unique, so "t emitted" ⇔ the chain headed by tail^1 equals t.
    tcol = jnp.clip(chain_len[:, None] - 1 - jnp.arange(m)[None, :], 0, m - 1)
    tw = jnp.take_along_axis(rows, tcol, axis=1)
    tw = jnp.where(jnp.arange(m)[None, :] < chain_len[:, None], tw ^ 1, -1)
    chain_of_head = (
        jnp.full(n2 + 1, -1, jnp.int32)
        .at[jnp.where(valid, heads, n2)]
        .set(jnp.arange(ca, dtype=jnp.int32))[:n2]
    )
    twin_head = jnp.clip(jnp.where(valid, tail ^ 1, 0), 0, n2 - 1)
    cand = jnp.where(valid, chain_of_head[twin_head], -1)
    cand_safe = jnp.where(cand >= 0, cand, 0)
    is_twin = (
        (cand >= 0)
        & (chain_len[cand_safe] == chain_len)
        & jnp.all(rows[cand_safe] == tw, axis=1)
    )
    neq = (rows != tw) & (jnp.arange(m)[None, :] < chain_len[:, None])
    first = jnp.argmax(neq, axis=1)
    a = jnp.take_along_axis(rows, first[:, None], axis=1)[:, 0]
    b = jnp.take_along_axis(tw, first[:, None], axis=1)[:, 0]
    keep = valid & ~(is_twin & jnp.any(neq, axis=1) & (b < a))

    contig_row_of_chain = jnp.cumsum(keep.astype(jnp.int32)) - 1
    n_chain_contigs = jnp.sum(keep).astype(jnp.int32)

    # piece layout in sorted state space: width (bases this state appends),
    # destination offset (segmented prefix sum within the chain).  Gathers
    # through chain ids are clip+mask (elig_s guards range) rather than
    # dummy-slot concatenation, which GSPMD mis-partitions on sharded inputs.
    chain_clip = jnp.clip(chain_idx_s, 0, ca - 1)
    piece_on = elig_s & keep[chain_clip]
    read_len = lengths[state_s >> 1]
    width = jnp.where(
        rank_s == 0,
        read_len,
        # a state appends at most its whole read (clamp keeps the backends
        # in agreement on degenerate suffix > length edges)
        jnp.minimum(jnp.round(st["insuf"][state_s]).astype(jnp.int32), read_len),
    )
    width = jnp.where(piece_on, width, 0)
    # segmented exclusive prefix sum of widths within each chain, built from
    # plain cumsum + scatter-add (associative_scan mis-lowers on sharded
    # inputs): global exclusive sum minus the chain's base offset
    excl = jnp.cumsum(width) - width
    seg_total = jnp.zeros(ca + 1, jnp.int32).at[chain_safe].add(width)[:ca]
    seg_base = jnp.cumsum(seg_total) - seg_total
    dst = jnp.where(piece_on, excl - seg_base[chain_clip], 0)
    piece_row = jnp.where(piece_on, contig_row_of_chain[chain_clip], 0)
    end = seg_total  # contig length = total width of its chain

    # per-piece provenance in chain-row layout (aligned with ``rows``): the
    # consensus stage (DESIGN.md §2.8) maps every read back onto its contig
    # through (offset, width)
    prov_col = jnp.minimum(rank_s, m - 1)
    dst_rows = (
        jnp.zeros((ca + 1, m), jnp.int32).at[chain_safe, prov_col].set(dst)[:ca]
    )
    width_rows = (
        jnp.zeros((ca + 1, m), jnp.int32)
        .at[chain_safe, prov_col]
        .set(width)[:ca]
    )

    # isolated reads (no state-graph edges at all) → singleton contigs
    iso = ~st["has_edge"] & ~contained
    iso_row = n_chain_contigs + jnp.cumsum(iso.astype(jnp.int32)) - 1
    n_contigs = n_chain_contigs + jnp.sum(iso).astype(jnp.int32)
    max_len = jnp.maximum(
        jnp.max(jnp.where(keep, end, 0)), jnp.max(jnp.where(iso, lengths, 0))
    )
    return {
        "rows": rows,
        "dst_rows": dst_rows,
        "width_rows": width_rows,
        "keep": keep,
        "contig_row_of_chain": contig_row_of_chain,
        "contig_len": end,
        "piece_on": piece_on,
        "piece_row": piece_row,
        "dst": dst,
        "width": width,
        "iso": iso,
        "iso_row": iso_row,
        "n_contigs": n_contigs,
        "max_len": max_len,
    }


# ---------------------------------------------------------------------------
# Stage 3: batched oriented-suffix gather into the padded contig tensor.
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("c", "l"))
def _gather_codes(st, lay, codes, lengths, *, c, l):
    n, lr = codes.shape

    def scatter(out, state, take, dstoff, rowidx, on):
        # piece = last `take` bases of the oriented read: forward reads index
        # len−take+b; reverse-complement reads index take−1−b and complement
        r = state >> 1
        rc = (state & 1)[:, None] == 1
        ln = lengths[r][:, None]
        tk = take[:, None]
        b = jnp.arange(lr)[None, :]
        idx = jnp.where(rc, tk - 1 - b, ln - tk + b)
        base = codes[r[:, None], jnp.clip(idx, 0, lr - 1)]
        base = jnp.where(rc, 3 - base, base)
        ok = on[:, None] & (b < tk)
        return out.at[
            jnp.where(ok, rowidx[:, None], c), jnp.where(ok, dstoff[:, None] + b, l)
        ].set(jnp.where(ok, base, 0))

    # two piece families share one buffer: the 2n chain states (masked) and
    # the n isolated reads (kept as separate scatters — concatenating
    # differently-sharded operands trips GSPMD)
    out = jnp.zeros((c + 1, l + 1), jnp.uint8)
    out = scatter(
        out, st["state_s"], lay["width"], lay["dst"], lay["piece_row"],
        lay["piece_on"],
    )
    out = scatter(
        out,
        2 * jnp.arange(n, dtype=jnp.int32),
        jnp.where(lay["iso"], lengths, 0),
        jnp.zeros(n, jnp.int32),
        lay["iso_row"],
        lay["iso"],
    )[:c, :l]

    keep, iso = lay["keep"], lay["iso"]
    crow = jnp.where(keep, lay["contig_row_of_chain"], c)
    irow = jnp.where(iso, lay["iso_row"], c)
    out_len = (
        jnp.zeros(c + 1, jnp.int32)
        .at[crow]
        .set(lay["contig_len"])
        .at[irow]
        .set(jnp.where(iso, lengths, 0))[:c]
    )
    m = lay["rows"].shape[1]
    out_states = (
        jnp.full((c + 1, m), -1, jnp.int32)
        .at[crow, :]
        .set(lay["rows"])
        .at[irow, 0]
        .set(2 * jnp.arange(n))[:c]
    )
    # piece provenance (DESIGN.md §2.8): isolated singletons are one piece of
    # the full read at offset 0
    out_offs = (
        jnp.zeros((c + 1, m), jnp.int32).at[crow, :].set(lay["dst_rows"])[:c]
    )
    out_widths = (
        jnp.zeros((c + 1, m), jnp.int32)
        .at[crow, :]
        .set(lay["width_rows"])
        .at[irow, 0]
        .set(jnp.where(iso, lengths, 0))[:c]
    )
    return out, out_len, out_states, out_offs, out_widths


# ---------------------------------------------------------------------------
# Backends + dispatch entry point.
# ---------------------------------------------------------------------------


def _device_contig_gen(
    s_mat, codes, lengths, contained=None, *, distribution: str = "gspmd",
    mesh=None, row_axes=None,
) -> ContigSet:
    """Device array path of the ``contig_gen`` op (DESIGN.md §2.7/§2.9).

    ``distribution="gspmd"`` (default) leaves partitioning to the
    auto-sharder; ``"shard_map"`` routes the whole chain stage (branch cut
    → doubling → chain ordering) through the single explicit-exchange
    region over ``mesh`` and surfaces the per-device, per-phase
    ``exchange_words*``/``exchange_rounds*`` in ``ContigSet.stats``.  Both
    distributions produce bit-identical tensors."""
    codes = jnp.asarray(codes, jnp.uint8)
    lengths = jnp.asarray(lengths, jnp.int32)
    n = codes.shape[0]
    contained = (
        jnp.zeros(n, bool) if contained is None else jnp.asarray(contained, bool)
    )
    st, dist_stats = _chain_state(
        s_mat, distribution=distribution, mesh=mesh, row_axes=row_axes
    )
    ca = next_pow2(int(st["n_chains"]))
    m = next_pow2(int(st["max_chain"]))
    lay = _chain_layout(st, lengths, contained, ca=ca, m=m)
    c = next_pow2(int(lay["n_contigs"]))
    l = next_pow2(int(lay["max_len"]))
    out_codes, out_len, out_states, out_offs, out_widths = _gather_codes(
        st, lay, codes, lengths, c=c, l=l
    )
    stats = validated(
        {
            "n_branch_cut": int(st["n_branch_cut"]),
            "cc_iterations": int(st["cc_iterations"]),
            "distribution": distribution,
            **dist_stats,
        },
        context="contig_gen", require_groups=("contig_exchange",),
    )
    return ContigSet(
        codes=out_codes,
        lengths=out_len,
        states=out_states,
        offsets=out_offs,
        widths=out_widths,
        n_contigs=int(lay["n_contigs"]),
        stats=stats,
    )


def _reference_contig_gen(
    s_mat, codes, lengths, contained=None, *, distribution: str = "gspmd",
    mesh=None, row_axes=None,
) -> ContigSet:
    """Host walk (assembly/contigs.py) packed into the ContigSet contract.

    The distribution knobs are accepted and ignored (shared op signature):
    the host walk is single-process by construction, so its stats report
    ``distribution="host"`` — truthful when a ``"shard_map"`` request lands
    on the reference backend (e.g. ``backend="auto"`` off-TPU)."""
    del distribution, mesh, row_axes
    codes = np.asarray(codes)
    lengths = np.asarray(lengths)
    edges = state_edges(s_mat)
    chains, n_branch_cut = extract_contig_chains(s_mat, _edges=edges)
    contigs = materialize_contigs(chains, edges[2], codes, lengths, contained)
    c = len(contigs)
    lmax = max((ct.length for ct in contigs), default=0)
    mmax = max((len(ct.reads) for ct in contigs), default=1)
    out = np.zeros((c, lmax), np.uint8)
    lens = np.zeros(c, np.int32)
    states = np.full((c, mmax), -1, np.int32)
    offs = np.zeros((c, mmax), np.int32)
    widths = np.zeros((c, mmax), np.int32)
    # materialize_contigs appends isolated singletons after the chain contigs,
    # so chains[i] is the provenance of contigs[i] and every later contig is a
    # single full-read piece at offset 0
    for i, ct in enumerate(contigs):
        out[i, : ct.length] = ct.codes
        lens[i] = ct.length
        for t, (r, s) in enumerate(ct.reads):
            states[i, t] = 2 * r + s
        if i < len(chains):
            off = 0
            for t, (state, suf) in enumerate(chains[i]):
                w = int(lengths[state >> 1]) if t == 0 else min(
                    int(suf), int(lengths[state >> 1])
                )
                offs[i, t] = off
                widths[i, t] = w
                off += w
        else:
            widths[i, 0] = lens[i]
    return ContigSet(
        codes=out,
        lengths=lens,
        states=states,
        offsets=offs,
        widths=widths,
        n_contigs=c,
        stats=validated(
            {
                "n_branch_cut": int(n_branch_cut),
                "cc_iterations": 0,
                "distribution": "host",
                **ZERO_EXCHANGE_STATS,
            },
            context="contig_gen_host", require_groups=("contig_exchange",),
        ),
    )


# The "pallas" slot of the contig_gen op is the device array path: it is the
# implementation that runs on-accelerator (pure XLA, no hand kernel needed),
# which is exactly what the backend axis selects (DESIGN.md §2.5/§2.7).
register_op("contig_gen", "reference", _reference_contig_gen)
register_op("contig_gen", "pallas", _device_contig_gen)


def generate_contigs(
    s_mat, codes, lengths, contained=None, *, backend: str = "auto",
    distribution: str = "gspmd", mesh=None, row_axes=None,
) -> ContigSet:
    """Contigs stage entry point: dispatch the registered ``contig_gen``
    backend (DESIGN.md §2.5) on string matrix S.

    Args:
      s_mat: the string matrix S (``EllMatrix``, MinPlus 4-vector values).
      codes / lengths: ``(n, L)`` uint8 read bases and ``(n,)`` int32 read
        lengths.
      contained: optional ``(n,)`` bool — reads already dropped as contained
        (they emit no singleton contig).
      backend: ``"reference"`` (host walk), ``"pallas"`` (device array
        path) or ``"auto"`` (platform detection), per DESIGN.md §2.5.
      distribution: partitioning of the device path's chain stage —
        ``"gspmd"`` (auto-sharded) or ``"shard_map"`` (branch cut, doubling
        and ring-bitonic chain ordering under one explicit
        ``ppermute``/``psum`` exchange region over ``mesh``; DESIGN.md
        §2.9/§2.10).  Only the device path partitions: when ``backend``
        resolves to ``"reference"`` the knob has no effect and the returned
        stats report ``distribution="host"``.
      mesh / row_axes: mesh for ``distribution="shard_map"`` (defaults: a 1D
        mesh over all devices; grid-row axes per ``infer_row_axes``).

    Returns a :class:`ContigSet`; all backend/distribution combinations
    produce identical contigs (the §2.5 parity contract).
    """
    from ..core.backend import resolve_distribution

    return dispatch("contig_gen", backend)(
        s_mat, codes, lengths, contained,
        distribution=resolve_distribution(distribution), mesh=mesh,
        row_axes=row_axes,
    )
