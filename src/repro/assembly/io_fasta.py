"""FASTA I/O with chunked parallel-read emulation (paper §IV-B).

The paper reads equal-sized independent chunks per MPI rank.  On a single
host we mirror the interface: ``read_fasta_sharded(path, shard, n_shards)``
byte-splits the file, aligns chunk boundaries to record starts (same protocol
as parallel MPI-IO readers: a rank owns every record that *starts* in its
chunk), and parses only its share.
"""

from __future__ import annotations

import os
from typing import List, Tuple

import numpy as np

from .kmers import BASES

_LUT = np.full(256, 0, np.uint8)
for _i, _c in enumerate(BASES):
    _LUT[ord(_c)] = _i
    _LUT[ord(_c.lower())] = _i


def parse_fasta(text: str) -> Tuple[List[str], List[str]]:
    names, seqs = [], []
    cur: List[str] = []
    for line in text.splitlines():
        if not line:
            continue
        if line.startswith(">"):
            if cur:
                seqs.append("".join(cur))
                cur = []
            names.append(line[1:].strip())
        else:
            cur.append(line.strip())
    if cur:
        seqs.append("".join(cur))
    return names, seqs


def read_fasta_sharded(path: str, shard: int = 0, n_shards: int = 1):
    """Parse the shard-th byte chunk of a FASTA file (records that start in
    the chunk belong to it). Returns (names, codes (n, Lmax) uint8, lengths)."""
    size = os.path.getsize(path)
    lo = size * shard // n_shards
    hi = size * (shard + 1) // n_shards
    with open(path, "rb") as f:
        f.seek(lo)
        buf = f.read(hi - lo)
        # include the tail of the record spilling past hi
        tail = b""
        while True:
            chunk = f.read(1 << 16)
            if not chunk:
                break
            nxt = chunk.find(b">")
            if nxt >= 0:
                tail += chunk[:nxt]
                break
            tail += chunk
    data = buf + tail
    # drop the partial record at the head (it belongs to the previous shard)
    if shard > 0:
        first = data.find(b">")
        data = data[first:] if first >= 0 else b""
    names, seqs = parse_fasta(data.decode("ascii", errors="ignore"))
    return names, *pack_reads(seqs)


def pack_reads(seqs: List[str]):
    n = len(seqs)
    lmax = max((len(s) for s in seqs), default=1)
    codes = np.zeros((n, lmax), np.uint8)
    lens = np.zeros((n,), np.int32)
    for i, s in enumerate(seqs):
        b = np.frombuffer(s.encode(), np.uint8)
        codes[i, : len(b)] = _LUT[b]
        lens[i] = len(b)
    return codes, lens


def _emit_record(f, name: str, seq: str) -> None:
    f.write(f">{name}\n")
    for off in range(0, len(seq), 80):
        f.write(seq[off : off + 80] + "\n")


def write_fasta(path: str, names, codes, lengths) -> None:
    with open(path, "w") as f:
        for i, name in enumerate(names):
            seq = "".join(BASES[int(c)] for c in codes[i][: int(lengths[i])])
            _emit_record(f, name, seq)


def write_contig_fasta(
    path: str, contigs, components=None, identity=None, depth=None,
) -> int:
    """Write assembled contigs grouped by string-graph connected component,
    with per-component assembly stats in every header (the first slice of
    the scaffolding / multi-chromosome workload: one genome piece = one
    record group).

    ``components``: per-contig component labels (``contigs.read_components``
    + ``contig_components``); contigs of one component are emitted
    consecutively, components ordered by label.  ``identity``/``depth``:
    optional per-contig consensus identity estimate and mean pileup depth
    (``ConsensusResult``) appended to headers.  Returns the number of
    records written."""
    from .contigs import contig_stats

    comp = (
        list(components)
        if components is not None
        else [0] * len(contigs)
    )
    groups = {}
    for idx, c in enumerate(comp):
        groups.setdefault(c, []).append(idx)
    n_written = 0
    with open(path, "w") as f:
        for rank, c in enumerate(sorted(groups)):
            idxs = groups[c]
            cs = contig_stats([contigs[i] for i in idxs])
            tag = (
                f"component={rank} comp_contigs={cs.n_contigs} "
                f"comp_total={cs.total_length} comp_n50={cs.n50}"
            )
            for k, i in enumerate(idxs):
                ct = contigs[i]
                hdr = (
                    f"contig_{rank}_{k} length={ct.length} "
                    f"reads={len(ct.reads)} {tag}"
                )
                if identity is not None:
                    hdr += f" identity={float(identity[i]):.4f}"
                if depth is not None:
                    hdr += f" depth={float(depth[i]):.1f}"
                _emit_record(f, hdr, "".join(BASES[int(x)] for x in ct.codes))
                n_written += 1
    return n_written
