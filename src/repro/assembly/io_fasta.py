"""FASTA I/O with chunked parallel-read emulation (paper §IV-B).

The paper reads equal-sized independent chunks per MPI rank.  On a single
host we mirror the interface: ``read_fasta_sharded(path, shard, n_shards)``
byte-splits the file, aligns chunk boundaries to record starts (same protocol
as parallel MPI-IO readers: a rank owns every record that *starts* in its
chunk), and parses only its share.
"""

from __future__ import annotations

import os
from typing import List, Tuple

import numpy as np

from .kmers import BASES

_LUT = np.full(256, 0, np.uint8)
for _i, _c in enumerate(BASES):
    _LUT[ord(_c)] = _i
    _LUT[ord(_c.lower())] = _i


def parse_fasta(text: str) -> Tuple[List[str], List[str]]:
    names, seqs = [], []
    cur: List[str] = []
    for line in text.splitlines():
        if not line:
            continue
        if line.startswith(">"):
            if cur:
                seqs.append("".join(cur))
                cur = []
            names.append(line[1:].strip())
        else:
            cur.append(line.strip())
    if cur:
        seqs.append("".join(cur))
    return names, seqs


def read_fasta_sharded(path: str, shard: int = 0, n_shards: int = 1):
    """Parse the shard-th byte chunk of a FASTA file (records that start in
    the chunk belong to it). Returns (names, codes (n, Lmax) uint8, lengths)."""
    size = os.path.getsize(path)
    lo = size * shard // n_shards
    hi = size * (shard + 1) // n_shards
    with open(path, "rb") as f:
        f.seek(lo)
        buf = f.read(hi - lo)
        # include the tail of the record spilling past hi
        tail = b""
        while True:
            chunk = f.read(1 << 16)
            if not chunk:
                break
            nxt = chunk.find(b">")
            if nxt >= 0:
                tail += chunk[:nxt]
                break
            tail += chunk
    data = buf + tail
    # drop the partial record at the head (it belongs to the previous shard)
    if shard > 0:
        first = data.find(b">")
        data = data[first:] if first >= 0 else b""
    names, seqs = parse_fasta(data.decode("ascii", errors="ignore"))
    return names, *pack_reads(seqs)


def pack_reads(seqs: List[str]):
    n = len(seqs)
    lmax = max((len(s) for s in seqs), default=1)
    codes = np.zeros((n, lmax), np.uint8)
    lens = np.zeros((n,), np.int32)
    for i, s in enumerate(seqs):
        b = np.frombuffer(s.encode(), np.uint8)
        codes[i, : len(b)] = _LUT[b]
        lens[i] = len(b)
    return codes, lens


def write_fasta(path: str, names, codes, lengths) -> None:
    with open(path, "w") as f:
        for i, name in enumerate(names):
            seq = "".join(BASES[int(c)] for c in codes[i][: int(lengths[i])])
            f.write(f">{name}\n")
            for off in range(0, len(seq), 80):
                f.write(seq[off : off + 80] + "\n")
