"""Seed-and-extend x-drop pairwise alignment (paper §IV-D).

SeqAn's SSE x-drop extension is replaced by an anti-diagonal wavefront DP
whose band lives in VREG lanes (and, in the Pallas kernel, VMEM): at step
s = i + j the wavefront holds scores for diagonal offsets d = i − j within a
static band; the three moves are

    diagonal  (i−1, j−1) → H[s−2][d]      + match/mismatch
    up        (i−1, j)   → H[s−1][d−1]    + gap
    left      (i, j−1)   → H[s−1][d+1]    + gap

Cells are valid when (s+d) is even, and cells scoring below ``best − x`` are
retired (x-drop).  The loop exits when the whole wavefront is retired.

This module is the pure-jnp oracle; ``repro.kernels.xdrop`` is the Pallas
version validated against it.  The driver (``extend_pair``) runs forward and
backward extensions from the seed and produces the alignment coordinates the
overlap classifier consumes.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..core.backend import dispatch

NEG = jnp.int32(-(10**9) // 2)


class Extension(NamedTuple):
    score: jnp.ndarray  # best extension score (0 = empty extension)
    ai: jnp.ndarray  # chars consumed of a
    bj: jnp.ndarray  # chars consumed of b


def _fetch(codes, base, step, t, limit):
    """codes[base + step*t] with validity t < limit."""
    idx = base + step * t
    safe = jnp.clip(idx, 0, codes.shape[-1] - 1)
    return codes[safe].astype(jnp.int32), (t >= 0) & (t < limit)


@partial(jax.jit, static_argnames=("band", "max_steps"))
def xdrop_extend(
    a,
    base_a,
    step_a,
    len_a,
    b,
    base_b,
    step_b,
    len_b,
    *,
    xdrop: int = 15,
    match: int = 1,
    mismatch: int = -1,
    gap: int = -1,
    band: int = 33,
    max_steps: int = 512,
) -> Extension:
    """Single-pair x-drop extension (see module docstring).

    ``a[base_a + step_a * t]`` for t ∈ [0, len_a) is the extension text of a
    (step −1 walks backwards from a seed), similarly for b."""
    w = band
    c = w // 2
    offs = jnp.arange(w) - c  # d = i − j per lane

    def step_fn(carry):
        s, h1, h2, best, bi, bj, alive = carry
        i = (s + offs) // 2
        j = (s - offs) // 2
        parity_ok = ((s + offs) % 2) == 0
        ai, va = _fetch(a, base_a, step_a, i, len_a)
        bjv, vb = _fetch(b, base_b, step_b, j, len_b)
        valid = parity_ok & va & vb & (i >= 0) & (j >= 0)
        sub = jnp.where(ai == bjv, match, mismatch)
        diag = h2 + sub
        up = jnp.concatenate([jnp.full((1,), NEG), h1[:-1]]) + gap
        left = jnp.concatenate([h1[1:], jnp.full((1,), NEG)]) + gap
        h = jnp.maximum(diag, jnp.maximum(up, left))
        h = jnp.where(valid, h, NEG)
        h = jnp.where(h < best - xdrop, NEG, h)  # x-drop retirement
        m = jnp.max(h)
        am = jnp.argmax(h)
        improved = m > best
        best2 = jnp.where(improved, m, best)
        bi2 = jnp.where(improved, i[am] + 1, bi)
        bj2 = jnp.where(improved, j[am] + 1, bj)
        return (s + 1, h, h1, best2, bi2, bj2, jnp.any(h > NEG))

    def cond_fn(carry):
        s, _, _, _, _, _, alive = carry
        return alive & (s < jnp.minimum(max_steps, len_a + len_b - 1))

    h1 = jnp.full((w,), NEG)  # wavefront s−1 (empty)
    h2 = jnp.where(offs == 0, 0, NEG)  # virtual origin at s−2
    init = (
        jnp.int32(0), h1, h2, jnp.int32(0), jnp.int32(0), jnp.int32(0),
        jnp.bool_(True),
    )
    _, _, _, best, bi, bj, _ = jax.lax.while_loop(cond_fn, step_fn, init)
    return Extension(score=best, ai=bi, bj=bj)


class PairAlignment(NamedTuple):
    score: jnp.ndarray
    bi: jnp.ndarray  # [bi, ei) on read i (forward frame)
    ei: jnp.ndarray
    bj: jnp.ndarray  # [bj, ej) on read j (oriented frame)
    ej: jnp.ndarray


@partial(jax.jit, static_argnames=("k", "band", "max_steps"))
def extend_pair(
    a,
    la,
    b_oriented,
    lb,
    pa,
    pb,
    *,
    k: int,
    xdrop: int = 15,
    match: int = 1,
    mismatch: int = -1,
    gap: int = -1,
    band: int = 33,
    max_steps: int = 512,
) -> PairAlignment:
    """Seed-and-extend around an exact k-mer seed at (pa on a, pb on oriented
    b).  Forward from the seed end, backward from the seed start."""
    kw = dict(
        xdrop=xdrop, match=match, mismatch=mismatch, gap=gap, band=band,
        max_steps=max_steps,
    )
    fwd = xdrop_extend(
        a, pa + k, 1, la - pa - k, b_oriented, pb + k, 1, lb - pb - k, **kw
    )
    bwd = xdrop_extend(
        a, pa - 1, -1, pa, b_oriented, pb - 1, -1, pb, **kw
    )
    score = k * match + fwd.score + bwd.score
    return PairAlignment(
        score=score,
        bi=pa - bwd.ai,
        ei=pa + k + fwd.ai,
        bj=pb - bwd.bj,
        ej=pb + k + fwd.bj,
    )


def batch_extend(
    a_codes, a_len, b_codes_oriented, b_len, pa, pb, *, k,
    backend: str = "reference", match: int = 1,
    pairs_per_block: int | None = None, **kw
) -> PairAlignment:
    """Batched seed-and-extend through the kernel-backend dispatch layer
    (core/backend.py): forward and backward extensions each run as one
    batched ``xdrop_extend`` op on the selected backend, then combine into
    the same ``PairAlignment`` as ``extend_pair``."""
    fn = dispatch("xdrop_extend", backend)
    pa = jnp.asarray(pa, jnp.int32)
    pb = jnp.asarray(pb, jnp.int32)
    a_len = jnp.asarray(a_len, jnp.int32)
    b_len = jnp.asarray(b_len, jnp.int32)
    step = jnp.ones(pa.shape, jnp.int32)
    kw = dict(match=match, pairs_per_block=pairs_per_block, **kw)
    fs, fa, fb = fn(
        a_codes, pa + k, step, a_len - pa - k,
        b_codes_oriented, pb + k, step, b_len - pb - k, **kw
    )
    bs, ba, bb = fn(
        a_codes, pa - 1, -step, pa, b_codes_oriented, pb - 1, -step, pb, **kw
    )
    return PairAlignment(
        score=k * match + fs + bs,
        bi=pa - ba,
        ei=pa + k + fa,
        bj=pb - bb,
        ej=pb + k + fb,
    )
