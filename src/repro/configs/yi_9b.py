"""Yi-9B [arXiv:2403.04652] — llama-arch GQA.

48L d_model=4096 32H (kv=4) d_ff=11008 vocab=64000."""
import dataclasses

from ..models.model import ModelConfig

CONFIG = ModelConfig(
    name="yi-9b",
    family="dense",
    n_layers=48,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    d_ff=11008,
    vocab_size=64000,
    rope_theta=5e6,
)


def reduced():
    return dataclasses.replace(
        CONFIG, name="yi-reduced", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=256,
    )
