"""Architecture registry: the 10 assigned public configs + the paper's own
pipeline ("dibella").  ``get_config(name)`` returns a ModelConfig (LM archs)
or the DibellaConfig marker; ``reduced_config(name)`` returns the smoke-test
reduction of the same family.
"""

from __future__ import annotations

import dataclasses
import importlib

from .shapes import SHAPES, ShapeSpec, batch_specs, cache_specs, runs_cell  # noqa: F401

_MODULES = {
    "musicgen-large": "musicgen_large",
    "mamba2-1.3b": "mamba2_1p3b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2p7b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "qwen3-4b": "qwen3_4b",
    "gemma3-4b": "gemma3_4b",
    "yi-9b": "yi_9b",
    "phi3-mini-3.8b": "phi3_mini_3p8b",
    "hymba-1.5b": "hymba_1p5b",
    "internvl2-26b": "internvl2_26b",
    "dibella": "dibella",
}

ARCH_NAMES = [k for k in _MODULES if k != "dibella"]
ALL_NAMES = list(_MODULES)


def get_config(name: str):
    mod = importlib.import_module(f".{_MODULES[name]}", __package__)
    return mod.CONFIG


def reduced_config(name: str):
    mod = importlib.import_module(f".{_MODULES[name]}", __package__)
    return mod.reduced()
