"""Qwen3-4B [hf:Qwen/Qwen3-4B family].

36L d_model=2560 32H (kv=8, head_dim=128) d_ff=9728 vocab=151936; qk-norm."""
import dataclasses

from ..models.model import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b",
    family="dense",
    n_layers=36,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=9728,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1e6,
)


def reduced():
    return dataclasses.replace(
        CONFIG, name="qwen3-reduced", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_head=16, d_ff=128, vocab_size=256,
    )
