"""Granite 3.0 1B-A400M base [hf:ibm-granite/granite-3.0-1b-a400m-base].

24L d_model=1024 16H (kv=8) vocab=49155; MoE: 32 experts top-8, d_ff=512."""
import dataclasses

from ..models.model import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    n_experts=32,
    top_k=8,
    d_ff_expert=512,
    d_ff_shared=0,
    rope_theta=1e4,
)


def reduced():
    return dataclasses.replace(
        CONFIG, name="granite-reduced", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, vocab_size=256, n_experts=8, top_k=4, d_ff_expert=32,
        d_ff=32,
    )
