"""Mamba-2 1.3B — attention-free SSD [arXiv:2405.21060].

48L d_model=2048 vocab=50280 ssm_state=128 (expand 2, headdim 64)."""
import dataclasses

from ..models.model import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=1,
    n_kv_heads=1,
    d_ff=0,
    mlp_type="none",
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    conv_width=4,
)


def reduced():
    return dataclasses.replace(
        CONFIG, name="mamba2-reduced", n_layers=2, d_model=64, vocab_size=256,
        ssm_state=16, ssm_headdim=16,
    )
