"""The paper's own pipeline as a selectable arch (``--arch dibella``).

The dry-run cell for dibella lowers one distributed overlap SpGEMM
(C = A·Aᵀ over the position-pair semiring) plus one distributed transitive-
reduction round on the production mesh — the paper-representative hillclimb
target (DESIGN.md §4).  Sizes follow the H. sapiens row of Table IV scaled to
static capacities (n = 4.42M reads, r ≈ 8, k-mer cap u = 8)."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class DibellaConfig:
    name: str = "dibella"
    family: str = "assembly"
    n_reads: int = 4_194_304  # ~H. sapiens Table IV (4.42M), pow2-padded
    m_kmers: int = 1 << 24  # reliable k-mer space
    read_capacity: int = 64  # K_A block capacity per grid column
    kmer_capacity: int = 8  # u (max k-mer frequency, paper uses 4-8)
    overlap_block_capacity: int = 16  # K_C per grid column block
    r_block_capacity: int = 8  # K_R per grid column block
    tr_fuzz: float = 1000.0

    def reduced_sizes(self):
        return dataclasses.replace(
            self, n_reads=256, m_kmers=4096, read_capacity=8,
            overlap_block_capacity=8, r_block_capacity=4,
        )


CONFIG = DibellaConfig()


def reduced():
    return CONFIG.reduced_sizes()
