"""Assigned input shapes (one set shared by all 10 LM archs) and the
ShapeDtypeStruct input_specs used by the multi-pod dry-run.

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a KV
cache of seq_len), NOT ``train_step``.  ``long_500k`` requires sub-quadratic
attention and therefore only runs for SSM/hybrid/mostly-local archs
(DESIGN.md §4); pure full-attention archs skip it.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

# archs that can run the 524k-token decode cell (sub-quadratic / mostly-local)
LONG_CONTEXT_ARCHS = {"mamba2-1.3b", "hymba-1.5b", "gemma3-4b"}


def runs_cell(arch_name: str, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return arch_name in LONG_CONTEXT_ARCHS
    return True


def batch_specs(cfg, shape: ShapeSpec):
    """ShapeDtypeStructs for the step inputs (no allocation).

    train:   {tokens|embeddings, labels}
    prefill: {tokens|embeddings}
    decode:  {tokens|embeddings} for ONE token (+ cache specs via
             ``cache_specs``)."""
    b = shape.global_batch
    s = shape.seq_len if shape.kind != "decode" else 1
    dt = jnp.dtype(cfg.dtype)
    if cfg.frontend == "token":
        batch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    else:
        batch = {"embeddings": jax.ShapeDtypeStruct((b, s, cfg.d_model), dt)}
    if shape.kind == "train":
        batch["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    return batch


def cache_specs(cfg, shape: ShapeSpec):
    """ShapeDtypeStructs for the decode cache (eval_shape over init_cache)."""
    from ..models.model import init_cache

    return jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len)
    )
