"""Qwen1.5-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B].

24L d_model=2048 16H (kv=16) vocab=151936; MoE: 60 routed experts top-4
(d_ff=1408 each) + 4 shared experts (merged 4×1408 = 5632).  Experts padded
60→64 for the 16-wide model axis."""
import dataclasses

from ..models.model import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=151936,
    n_experts=60,
    top_k=4,
    d_ff_expert=1408,
    d_ff_shared=5632,
    rope_theta=1e6,
)


def reduced():
    return dataclasses.replace(
        CONFIG, name="qwen2moe-reduced", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, vocab_size=256, n_experts=8, top_k=2, d_ff_expert=32,
        d_ff_shared=64, d_ff=32,
    )
