"""Gemma-3 4B [hf:google/gemma-3-4b-pt family].

34L d_model=2560 8H (kv=4, head_dim=256) d_ff=10240 vocab=262144;
5:1 local:global sliding-window (window 1024, global every 6th layer,
local theta 10k / global 1M); qk-norm; GeGLU.  Runs long_500k (mostly-local
KV)."""
import dataclasses

from ..models.model import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    d_head=256,
    d_ff=10240,
    vocab_size=262144,
    qk_norm=True,
    mlp_type="geglu",
    sliding_window=1024,
    local_global_every=6,
    rope_theta=1e6,
    rope_theta_local=1e4,
)


def reduced():
    return dataclasses.replace(
        CONFIG, name="gemma3-reduced", n_layers=6, d_model=64, n_heads=4,
        n_kv_heads=2, d_head=16, d_ff=128, vocab_size=256, sliding_window=32,
    )
