"""Hymba-1.5B [arXiv:2411.13676] — parallel attention + mamba heads.

32L d_model=1600 25H (kv=5, head_dim=64) d_ff=5504 vocab=32001 ssm_state=16;
sliding-window attention except 3 global layers (first/middle/last).  Runs
long_500k (hybrid)."""
import dataclasses

from ..models.model import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_head=64,
    d_ff=5504,
    vocab_size=32001,
    ssm_state=16,
    ssm_expand=2,
    ssm_headdim=64,
    sliding_window=1024,
    hybrid_global_layers=(0, 15, 31),
    rope_theta=1e4,
)


def reduced():
    return dataclasses.replace(
        CONFIG, name="hymba-reduced", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_head=16, d_ff=128, vocab_size=256, ssm_state=16,
        ssm_headdim=16, sliding_window=32, hybrid_global_layers=(0,),
    )
