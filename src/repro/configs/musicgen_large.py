"""MusicGen-large decoder backbone over EnCodec tokens [arXiv:2306.05284; hf].

48L d_model=2048 32H (kv=32) d_ff=8192 vocab=2048.  The EnCodec frontend is a
STUB per the brief: input_specs provide precomputed frame embeddings (B, S, D)
and the head predicts codebook tokens (vocab 2048)."""
import dataclasses

from ..models.model import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    mlp_type="gelu",
    frontend="embed",
    rope_theta=1e4,
)


def reduced():
    return dataclasses.replace(
        CONFIG, name="musicgen-reduced", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab_size=256,
    )
