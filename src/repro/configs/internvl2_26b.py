"""InternVL2-26B language backbone (InternLM2-20B) [arXiv:2404.16821].

48L d_model=6144 48H (kv=8) d_ff=16384 vocab=92553.  The InternViT frontend is
a STUB per the brief: input_specs provide precomputed patch embeddings."""
import dataclasses

from ..models.model import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    frontend="embed",
    rope_theta=1e6,
)


def reduced():
    return dataclasses.replace(
        CONFIG, name="internvl2-reduced", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=256,
    )
