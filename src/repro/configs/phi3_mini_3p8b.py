"""Phi-3-mini 3.8B [arXiv:2404.14219] — RoPE SwiGLU GQA (kv=32 ⇒ MHA).

32L d_model=3072 32H (kv=32, head_dim=96) d_ff=8192 vocab=32064."""
import dataclasses

from ..models.model import ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_head=96,
    d_ff=8192,
    vocab_size=32064,
    rope_theta=1e4,
)


def reduced():
    return dataclasses.replace(
        CONFIG, name="phi3-reduced", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_head=16, d_ff=128, vocab_size=256,
    )
