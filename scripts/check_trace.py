#!/usr/bin/env python
"""Assert the span-tree structure of an exported pipeline trace.

Reads the Chrome-trace JSON written by ``benchmarks/run.py --trace-dir``
(the ``spanTree`` side-channel key — explicit nesting, no timestamp
containment to re-derive) and checks the observability contract of
``docs/observability.md``:

* the root spans are the pipeline stages, in Algorithm 1 order —
  CountKmer → CreateSpMat → SpGEMM → Alignment → BuildR → TrReduction →
  Contigs → Consensus;
* the SpGEMM stage nests shard_map phase spans, including at least one
  ``phase="ring_stage"`` descendant (the explicit-exchange ring actually
  traced) and the skew/ring/collect phases around it;
* the Contigs stage nests the chain-stage phase spans (cut → doubling →
  sort under ``phase="chain_stage"``);
* the Alignment stage nests the distributed x-drop phase spans
  (``pair_exchange`` around the shard_map call; ``gather_reads`` →
  ``extend`` → ``scatter_scores`` inside it, DESIGN.md §2.12);
* every ``kind="kernel"`` span sits under a ``kind="op"`` span (kernel
  launches are reached through the dispatch layer, never free-floating);
* every stage root span carries memory attribution — the
  ``peak_hbm_bytes`` / ``hbm_bytes_in_use`` / ``hbm_source`` attrs the
  tracer's per-span watermark (``repro.obs.memory``) attaches, so the
  exported trace answers "which stage holds the high-water mark", not
  just "which stage is slow".

Exits 1 with a per-check message when the structure is violated.  Run from
the repo root::

    python scripts/check_trace.py TRACE_DIR/assemble_trace.json
"""

from __future__ import annotations

import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

# Algorithm 1 stage order and per-stage phase contract: the single source
# shared with check_smoke_comm.py and analysis rule R003 (PR 10).
from repro.analysis.contracts import STAGE_PHASES, STAGES  # noqa: E402


def _walk(node, depth=0):
    yield node, depth
    for child in node.get("children", ()):
        yield from _walk(child, depth + 1)


def _descendants(node):
    for child in node.get("children", ()):
        yield from _walk(child)


def _phases(node):
    return {n["attrs"].get("phase") for n, _ in _descendants(node)
            if n["attrs"].get("kind") == "phase"}


def check(tree) -> list:
    """Return failure messages for one ``spanTree`` list; empty = clean."""
    failures = []
    roots = [n["name"] for n in tree]
    stage_pos = [roots.index(s) for s in STAGES if s in roots]
    missing = [s for s in STAGES if s not in roots]
    if missing:
        failures.append(f"missing stage root span(s): {', '.join(missing)}"
                        f" (roots: {roots})")
    if stage_pos != sorted(stage_pos):
        failures.append(f"stage roots out of Algorithm 1 order: {roots}")

    by_name = {n["name"]: n for n in tree}
    for stage, required in STAGE_PHASES.items():
        node = by_name.get(stage)
        if node is None:
            continue  # the missing root is already reported above
        phases = _phases(node)
        for ph in required:
            if ph in phases:
                continue
            if stage == "SpGEMM" and ph == "ring_stage":
                failures.append(
                    "SpGEMM stage has no phase='ring_stage' descendant — "
                    "the explicit-exchange ring was not traced "
                    f"(phases: {phases})")
            else:
                failures.append(
                    f"{stage} stage missing phase={ph!r} span")

    for root in tree:
        if root["name"] not in STAGES:
            continue
        attrs = root["attrs"]
        missing_mem = [k for k in ("peak_hbm_bytes", "hbm_bytes_in_use",
                                   "hbm_source") if k not in attrs]
        if missing_mem:
            failures.append(
                f"stage span {root['name']!r} lacks memory attribution "
                f"attr(s) {', '.join(missing_mem)} — the tracer watermark "
                "did not run for this span")

    for root in tree:
        for node, _ in _walk(root):
            if node["attrs"].get("kind") != "kernel":
                continue
            # a kernel span must have an op-span ancestor somewhere up the
            # path — recompute by scanning: find it on any walk that holds
            # node in its subtree
            if not _has_op_ancestor(root, node):
                failures.append(
                    f"kernel span {node['name']!r} "
                    f"({node['attrs'].get('kernel')}) has no kind='op' "
                    "ancestor — a kernel launch bypassed the dispatch layer")
    return failures


def _has_op_ancestor(root, target, in_op=False) -> bool:
    if root is target:
        return in_op
    in_op = in_op or root["attrs"].get("kind") == "op"
    return any(_has_op_ancestor(c, target, in_op)
               for c in root.get("children", ()))


def main(argv) -> int:
    """Check each trace path in ``argv``; 0 = structure holds everywhere."""
    if not argv:
        print("usage: check_trace.py TRACE.json [...]", file=sys.stderr)
        return 2
    failed = 0
    for path in argv:
        with open(path) as f:
            doc = json.load(f)
        tree = doc.get("spanTree")
        if not tree:
            print(f"{path}: no spanTree key — not a pipeline trace export")
            failed += 1
            continue
        failures = check(tree)
        for msg in failures:
            print(f"{path}: {msg}")
            failed += 1
        if not failures:
            n_spans = sum(1 for r in tree for _ in _walk(r))
            print(f"{path}: span-tree structure ok ({n_spans} spans, "
                  f"{len(tree)} roots)")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
