#!/usr/bin/env python
"""Assert the comm-model cross-check on a benchmark smoke artifact.

Reads the JSON records emitted by ``benchmarks/run.py --json`` and checks
every ``contigs[*/shard_map]`` row: the *measured* sort-phase exchange
volume (``exchange_words_sort``, accounted per ``ppermute`` issued by
``core/components_dist.contig_stage_shard_map``) must agree with the
analytic model (``model_words_sort`` = ``bench_comm_model.words_chain_sort``)
to within 10%.  The sort network is data-independent, so in practice the two
are equal — the tolerance only absorbs future schedule tweaks.

Also checks every ``overlap[shard_map]`` row under the same contract: the
measured ring-SUMMA exchange volume (``exchange_words_summa``, accounted per
``ppermute`` issued by ``core/summa.summa_ring``) against the analytic
``model_words_summa`` (= ``bench_comm_model.words_summa``, Table I
W = am/√P).  The ring schedule moves whole ELL panels regardless of data, so
these too are exactly equal in practice.

And every ``align[shard_map]`` row: the measured distributed x-drop
exchange volume (``exchange_words_align``, accounted per ``ppermute`` /
allreduce issued by ``core/align_dist.align_bucket_shard_map``) against the
analytic ``model_words_align`` (= ``bench_comm_model.words_align``) — the
gather/scatter schedule is fixed by (n, L, bucket, P), so exact again.

Exits 1 when a row disagrees or when no shard_map contig, overlap or align
row is present at all (a silently dropped distribution axis must
fail CI, not pass it).  Run from the repo root::

    python scripts/check_smoke_comm.py BENCH_smoke.json
"""

from __future__ import annotations

import json
import re
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

# the (op, measured, model) triples under contract: the single source
# shared with check_trace.py and analysis rule R003 (PR 10).
from repro.analysis.contracts import COMM_CONTRACTS  # noqa: E402

TOL = 0.10


def _field(derived: str, key: str) -> int | None:
    m = re.search(rf"(?:^|;){re.escape(key)}=(-?\d+)", derived)
    return int(m.group(1)) if m else None


# one (measured, model) field pair per shard_map phase under contract
_CONTRACTS = COMM_CONTRACTS


def _shard_rows(records, op: str) -> list:
    return [r for r in records
            if r.get("op") == op
            and "shard_map" in (r.get("backend") or "")]


def check(records) -> list:
    """Return ``(name, message)`` failures for the shard_map contig and
    overlap rows of one smoke-artifact record list; empty means every
    cross-check holds."""
    failures = []
    for op, mkey, wkey in _CONTRACTS:
        rows = _shard_rows(records, op)
        if not rows:
            failures.append(
                ("<artifact>",
                 f"no {op}[*/shard_map] rows found — the distribution "
                 "axis was dropped from the smoke run"))
            continue
        for r in rows:
            measured = _field(r["derived"], mkey)
            model = _field(r["derived"], wkey)
            if measured is None or model is None:
                failures.append(
                    (r["name"],
                     f"missing {mkey}/{wkey} fields in {r['derived']!r}"))
                continue
            if measured == model == 0:
                continue  # P == 1: ring degenerates, both sides exactly 0
            if abs(measured - model) > TOL * max(abs(model), 1):
                failures.append(
                    (r["name"],
                     f"measured {mkey}={measured} deviates from "
                     f"{wkey}={model} by more than {TOL:.0%}")
                )
    return failures


def main(argv) -> int:
    """Check each artifact path in ``argv``; 0 = all cross-checks hold."""
    if not argv:
        print("usage: check_smoke_comm.py BENCH.json [...]", file=sys.stderr)
        return 2
    failed = 0
    for path in argv:
        with open(path) as f:
            records = json.load(f)
        failures = check(records)
        for name, msg in failures:
            print(f"{path}: {name}: {msg}")
            failed += 1
        if not failures:
            counts = ", ".join(
                f"{len(_shard_rows(records, op))} {op}"
                for op, _, _ in _CONTRACTS)
            print(f"{path}: comm-model cross-check ok "
                  f"(shard_map rows: {counts})")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
