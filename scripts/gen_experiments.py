"""Generate EXPERIMENTS.md from the dry-run JSON cache + hillclimb tags.

Usage: python scripts/gen_experiments.py > EXPERIMENTS.md
"""

import glob
import json
import os

ROOT = os.path.join(os.path.dirname(__file__), "..")
DRY = os.path.join(ROOT, "experiments", "dryrun")


def load(tag=""):
    out = {}
    for f in sorted(glob.glob(os.path.join(DRY, "*.json"))):
        base = os.path.basename(f)[:-5]
        parts = base.split("__")
        if len(parts) != 3:
            continue
        arch, shape, mesh_tag = parts
        if tag:
            if not mesh_tag.endswith("_" + tag):
                continue
            mesh = mesh_tag[: -len("_" + tag)]
        else:
            if "_" in mesh_tag:
                continue
            mesh = mesh_tag
        out[(arch, shape, mesh)] = json.load(open(f))
    return out


def fmt_cell(d):
    r = d["roofline"]
    return (f"{r['compute_s']:.2e} | {r['memory_s']:.2e} | "
            f"{r['collective_s']:.2e} | **{r['bottleneck']}** | "
            f"{r['useful_ratio']:.2f} | {d.get('roofline_fraction', 0):.3f}")


def main():
    base = load()
    print(HEADER)

    # ---------------- §Dry-run ----------------
    print(DRYRUN_INTRO)
    print("| arch | shape | mesh | chips | arg GB/dev | temp GB/dev | "
          "fits 16GB | collective GB/dev | compile s |")
    print("|---|---|---|---|---|---|---|---|---|")
    for (arch, shape, mesh), d in sorted(base.items()):
        if d.get("skipped"):
            print(f"| {arch} | {shape} | {mesh} | — | — | — | "
                  f"SKIP (see DESIGN.md §4) | — | — |")
            continue
        if arch == "dibella":
            stages = d["stages"]
            am = sum(s["memory"]["argument"] for s in stages.values()) / 1e9
            tm = max(s["memory"]["temp"] for s in stages.values()) / 1e9
            cb = sum(s["collective_bytes_per_device"]
                     for s in stages.values()) / 1e9
            print(f"| {arch} | overlap+TR | {mesh} | {d['chips']} | "
                  f"{am:.1f} | {tm:.1f} | {am + tm < 16:} | {cb:.2f} | "
                  f"{d['compile_seconds']:.0f} |")
            continue
        m = d["memory"]
        print(f"| {arch} | {shape} | {mesh} | {d['chips']} | "
              f"{m['argument_bytes_per_device'] / 1e9:.1f} | "
              f"{m['temp_bytes_per_device'] / 1e9:.1f} | "
              f"{m['fits_16GB']} | {d['collective_bytes'] / 1e9:.2f} | "
              f"{d['compile_seconds']:.0f} |")

    # ---------------- §Roofline ----------------
    print(ROOFLINE_INTRO)
    for mesh in ("single", "multi"):
        chips = 256 if mesh == "single" else 512
        print(f"\n#### {'Single-pod 16×16' if mesh == 'single' else 'Multi-pod 2×16×16'} ({chips} chips)\n")
        print("| arch | shape | compute_s | memory_s | collective_s | "
              "bottleneck | useful | frac |")
        print("|---|---|---|---|---|---|---|---|")
        for (arch, shape, m), d in sorted(base.items()):
            if m != mesh:
                continue
            if d.get("skipped"):
                print(f"| {arch} | {shape} | — | — | — | SKIP | — | — |")
                continue
            print(f"| {arch} | {shape if arch != 'dibella' else 'overlap+TR'}"
                  f" | {fmt_cell(d)} |")
    print(ROOFLINE_NOTES)

    # ---------------- §Perf ----------------
    print(PERF_INTRO)
    print(perf_tables())
    print(PERF_NARRATIVE)
    print(FOOTER)


def perf_tables():
    """Before/after pairs from tagged runs."""
    lines = []
    pairs = [
        ("dibella", "train_4k", "single",
         [("faithful", "it-0: paper-faithful full N=R² (baseline)"),
          ("", "it-1: fused sampled-square TR (beyond-paper, default)"),
          ("u4", "it-2: + k-mer frequency cap u=8→4 (paper's own setting)")]),
        ("yi-9b", "train_4k", "single",
         [("mp", "it-1 attempt: mixed precision (raw parser — REFUTED)"),
          ("bgrad", "it-2 attempt: grad barrier + rope vjp (raw — REFUTED)"),
          ("", "it-3: artifact root-caused → TPU-estimate collective term")]),
        ("granite-moe-1b-a400m", "train_4k", "single",
         [("", "baseline (shard_map EP dispatch)"),
          ("gspmd", "ablation: GSPMD one-hot dispatch (10× WORSE)"),
          ("bgrad", "bf16 grad barrier (REFUTED on CPU)")]),
        ("mamba2-1.3b", "train_4k", "single",
         [("", "it-0: baseline"),
          ("ssdbf16", "it-1: ssd_bf16 alone (REFUTED: peak is elsewhere)"),
          ("ssdopt", "it-2: + batch-over-model (40→19.4 GB)"),
          ("ssdopt2", "it-3: + ssd_chunk 64")]),
        ("gemma3-4b", "long_500k", "single",
         [("", "it-0: baseline (full-length caches)"),
          ("cacheopt", "it-1: owner-writes cache update (REFUTED)"),
          ("unroll", "it-2: decode unroll (REFUTED — worse liveness)")]),
        ("phi3-mini-3.8b", "decode_32k", "single",
         [("", "baseline (scan ys cache copies)"),
          ("unroll", "decode unroll (REFUTED)")]),
    ]
    for arch, shape, mesh, variants in pairs:
        lines.append(f"\n#### {arch} / {shape} ({mesh}-pod)\n")
        lines.append("| variant | compute_s | memory_s | collective_s | "
                     "bottleneck | frac | temp GB/dev |")
        lines.append("|---|---|---|---|---|---|---|")
        for tag, desc in variants:
            d = (load(tag) if tag else load()).get((arch, shape, mesh))
            if d is None or d.get("skipped"):
                lines.append(f"| {desc} | (not run) | | | | | |")
                continue
            r = d["roofline"]
            if arch == "dibella":
                tm = max(s["memory"]["temp"]
                         for s in d["stages"].values()) / 1e9
            else:
                tm = d["memory"]["temp_bytes_per_device"] / 1e9
            lines.append(
                f"| {desc} | {r['compute_s']:.2e} | {r['memory_s']:.2e} | "
                f"{r['collective_s']:.2e} | {r['bottleneck']} | "
                f"{d.get('roofline_fraction', 0):.3f} | {tm:.1f} |")
    return "\n".join(lines)


HEADER = """# EXPERIMENTS — diBELLA-2D-JAX

All numbers in this file are reproducible:
  * dry-run/roofline: `PYTHONPATH=src python -m repro.launch.dryrun --all`
    (cached JSONs in `experiments/dryrun/`; this file is generated from them
    by `scripts/gen_experiments.py`),
  * paper-claim validations: `PYTHONPATH=src python -m benchmarks.run`
    (`bench_output.txt`),
  * correctness: `PYTHONPATH=src pytest tests/` (`test_output.txt`).

## §Validation against the paper's own claims

| paper claim | our check | result |
|---|---|---|
| Alg. 2 ≡ string graph (Myers) | property tests vs sequential Myers oracle, random + genome graphs | **exact equality** (tests/test_transitive_reduction.py) |
| TR converges in a small constant number of iterations (§V-D) | pipeline + property tests | 2–3 iterations on all inputs |
| c ≈ 2d for a perfect overlapper (§V-C) | simulated datasets | c/2d = 1.01–1.28 (bench_sparsity) |
| 2D beats 1D comm at P ∈ [10², 10⁴] (Table I) | cost model w/ Table III/IV constants | 2D wins at every P ≤ 16384 for both genomes (bench_comm_model) |
| TR ≫ competing distributed TR (Table VI) | semiring TR vs dense-square TR (same input) | 54–600× vs dense square; sequential Myers wins at n ≤ 16k on 1 CPU core (expected: the paper's win is *distributed*; see §Scaling note) |
| overlap: 2D vs 1D (Fig 9) | SpGEMM vs outer-product emulation | 2D 151× faster at equal output (the 1D variant materializes all pair duplicates; the paper's 1.2–1.9× is against a tuned hash-table 1D) |
| end-to-end assembly works | 8–30 kb genomes, 3–5% error reads | single contig covering ≥95% of the genome; contig k-mer recall > 0.9 |
"""

DRYRUN_INTRO = """
## §Dry-run (MULTI-POD deliverable)

Every (architecture × input-shape) cell lowers **and compiles** with
`jax.jit(step).lower(...).compile()` on BOTH production meshes
(16×16 = 256 chips and 2×16×16 = 512 chips; 512 fake host devices).
`long_500k` is architecture-gated (DESIGN.md §4).  `dibella` lowers the
distributed overlap SpGEMM + transitive reduction at H. sapiens scale
(4.2M reads).  Collective GB/dev is parsed from the partitioned HLO with
while-loop trip correction (launch/hlo_analysis.py).
"""

ROOFLINE_INTRO = """
## §Roofline

Terms per chip and step (TPU v5e class: 197 TFLOP/s bf16, 819 GB/s HBM,
4 × 50 GB/s ICI links):

    compute_s    = FLOPs / (chips × 197e12)       [analytic model — XLA
                   cost_analysis counts while bodies once; raw HLO numbers
                   are in the JSONs as cost_hlo_raw]
    memory_s     = HBM bytes / (chips × 819e9)    [analytic traffic model]
    collective_s = collective bytes / (chips × 200e9)  [HLO-parsed, loop-aware]

`useful` = MODEL_FLOPS / total FLOPs (6·N·D train, 2·N_active·D decode);
`frac` = roofline fraction = ideal-compute-time / dominant-term-time —
**this is the §Perf score**.
"""

ROOFLINE_NOTES = """
### Reading the table (one sentence per regime on what moves the bottleneck)

* **train_4k — collective-bound everywhere.**  Megatron TP at tp=16 moves
  ~4·S·D bytes/layer/device against 6·N·D/P useful FLOPs; the fix is fewer
  bytes per collective (mixed-precision gathers/reductions, §Perf it-2) and
  higher arithmetic intensity per device (larger per-device batch).
* **prefill_32k — collective-bound, higher fractions** (more FLOPs per
  gathered byte at 32k tokens; yi-9b reaches 0.42 at baseline).
* **decode — memory-bound** (every token reads all params + the KV cache;
  the term ratio matches the classic decode arithmetic-intensity argument);
  the fix is cache layout (windowed local layers for gemma3) and batched
  speculative decoding (out of scope).
* **dibella — memory-bound** (semiring SpGEMM is sort/gather traffic with
  ~0.3 useful-FLOP ratio; the paper's own finding that assembly is
  communication/memory-limited, not compute-limited, reproduces on TPU).
* **single→multi pod** halves per-chip terms at fixed global batch (the pod
  axis extends DP); collective terms stay roughly constant per chip for TP
  traffic and halve for DP traffic — visible as slightly higher multi-pod
  fractions for the MoE/dense train cells.
"""

PERF_INTRO = """
## §Perf — hillclimbing log

Cells hillclimbed (per the brief: worst fraction / most collective-bound /
paper-representative):

1. **dibella overlap+TR** (paper-representative; memory-bound)
2. **yi-9b train_4k** (most collective-bound: collective/compute ≈ 120×)
3. **granite-moe-1b-a400m train_4k** (worst roofline fraction: 0.08)

plus two memory-driven fixes (mamba2 train, gemma3 long-context decode)
required for the "fits 16 GB" deployability bar.
"""

PERF_NARRATIVE = """
### Hypothesis → change → measure → validate log

**dibella-1 (paper-faithful baseline → fused sampled square).**
*Hypothesis:* Alg. 2 reads N=R² only at R's nonzeros; the full square
materializes an N-pattern ~r× denser than R and sorts K² candidates per
row — the sampled square should cut the TR stage's bytes substantially.
*Measured:* TR-stage bytes 1492 → 875 GB/dev (−41%); total memory term
7.44 s → 6.68 s; output graphs bit-identical (property-tested).
**Confirmed.**  This is the headline beyond-paper optimization: the paper
pays for a CombBLAS-shaped SpGEMM because that is the primitive its
library offers; on TPU the SDDMM-style fusion is faster and immune to
N-capacity overflow.

**dibella-2 (k-mer cap u=8→4).**  *Hypothesis:* the overlap SpGEMM's
candidate count (and the B-panel bytes) scale linearly with the frequency
cap u; the paper's own experiments use max frequency 4, so u=4 should
roughly halve the overlap stage's traffic.  *Measured:* overlap bytes
4598 → 2516 GB/dev (−45%); total memory term 6.68 → 4.14 s.  **Confirmed.**
Net over both iterations: dominant term **7.44 → 4.14 s (1.8×)**.

**yi-1 (mixed precision) — REFUTED, twice, instructively.**
*Hypothesis:* FSDP param gathers + grad reduce-scatters move f32; bf16
compute params should halve them.  *Measured:* collective bytes unchanged
to the byte.  *Diagnosis 1:* XLA already hoists the per-layer
``w.astype(bf16)`` before the FSDP all-gather, so param gathers were bf16
all along; the grad-reduce dtype is pinned by the cast-transpose.
*Follow-up hypothesis:* the f32 activation collectives come from the rope
(f32 cos/sin promote every q/k/v cotangent) and from the CE cotangent
entering the backward scan (carry-dtype unification f32-infects all 48
layers).  *Changes:* custom-vjp rope with exact bf16 transpose; bf16 grad
barrier before CE.  *Measured:* still unchanged to the byte.
*Diagnosis 2 (root cause, verified by operand tracing):* the **XLA CPU
backend converts every bf16 dot operand to f32** (`convert*` fusions feed
the gathers), so on this container every matmul-adjacent collective is
measured at 2× its TPU size — no program-level change can move it.
*Action:* the HLO parser now reports `total_bytes_tpu_estimate` (f32
collectives fed by convert fusions counted at bf16 size); the roofline
collective term uses the TPU estimate, the raw number stays in the JSON.
yi-9b train_4k: raw 919.7 GB/dev (collective 4.60 s, fraction 0.240) →
TPU-estimate 622 GB/dev (collective 3.11 s, fraction **0.355**) — the
baseline row of the table carries the corrected term; the it-1/it-2 rows
keep the raw-parser numbers they were measured with.  The refuted chain is kept here deliberately — the
three "no-op" measurements are what localized the artifact.

**granite (worst fraction) + EP-dispatch ablation.**  *Hypothesis:* our
shard_map expert dispatch (replicate tokens across "model", dispatch to
local experts, psum) beats the GSPMD one-hot/scatter formulation, which
must materialize global (E, C, D) buffers.  *Measured:* GSPMD dispatch is
**10.4× worse** on the collective term (0.767 → 7.978 s) and 5× on temp
memory (8.6 → 44.5 GB — does not fit).  **Confirmed** — the framework's
default is the right one.  The remaining inefficiency is structural:
d_model=1024 across tp=16 leaves 64 dims/shard; the cost model says a
tp=4 re-slicing of the same 256 chips lifts the fraction 0.08 → ~0.25
(future work: the brief fixes the mesh shape).

**mamba2 train (memory).**  *it-1 hypothesis:* f32 SSD intra-chunk buffers
dominate → bf16 them.  *Measured:* unchanged — **refuted**, the peak is
elsewhere.  *it-2:* batch-over-model (B/dev 16→1 for the SSD scan) —
**confirmed for memory** (40.0 → 19.4 GB) at the cost of per-layer param
gathers in the (CPU-inflated) collective term.  *it-3:* ssd_chunk 128→64 —
**no change** (19.4 GB), confirming the residual peak is the outer-scan
remat carries + backward working set, not intra-chunk buffers.  Stopped
per the <5% criterion; next steps (not implemented): host-offloaded remat
carries or pipeline parallelism over "pod".

**decode memory (gemma3 long_500k, phi3/musicgen decode_32k).**
*it-1 hypothesis:* the seq-sharded cache update gathers the cache —
owner-writes shard_map update should fix it.  *Measured:* unchanged —
**refuted**; GSPMD already partitioned the update correctly.
*it-2 hypothesis:* the layer *scan* re-materializes the cache stack as
fresh `ys` buffers (scan outputs cannot alias inputs slice-wise); an
unrolled decode with `.at[i].set` writes should alias in place.
*Measured:* **refuted again — and worse** (gemma3 long: 12.3 → 18.3 GB
temp; phi3 decode: 17.4 → 26.2): without the scan's serialization, buffer
assignment keeps more per-layer copies live simultaneously.  *Diagnosis
that survives:* jax/XLA currently cannot express "scan whose ys alias its
xs"; the honest fixes are a paged/block-table cache layout (the
vLLM-on-TPU design) or windowed caches for gemma3's 28 local layers —
both are cache-*layout* changes, orthogonal to the paper's technique, and
recorded as the next iteration.  The decode-unroll path stays in the tree
(flag, argmax-identical logits) as it remains the right shape for real
donation-aliasing decode runtimes.

### Stopping criterion

dibella: it-3 candidates (ring SUMMA for panel memory, value-packing the
4-combo suffixes into cols high bits) napkin-math to <5% on the dominant
term after it-1+it-2 — stopped.  yi: stopped after the measurement artifact
was root-caused (further program-level iterations cannot be validated on
this container; the TPU-estimate column is the honest score).  granite:
structural (mesh re-slicing) — out of scope.  The remaining 37 cells carry
baseline-only numbers in §Roofline.
"""

FOOTER = """
## §Scaling note (paper Fig. 4 analogue)

`bench_scaling` measures the distributed TR across 1/2/4 fake host devices
on one physical CPU core — efficiency collapses by construction (the core is
time-sliced), so wall-clock scaling is NOT claimable from this container.
The structural scaling argument lives in the roofline table: per-chip
compute/memory terms halve from 256→512 chips at fixed problem size while
collective terms stay flat (SUMMA words ∝ 1/√P per Table I), matching the
paper's >80% parallel-efficiency regime.

## Reproduction commands

```
PYTHONPATH=src python -m repro.launch.dryrun --all
PYTHONPATH=src python -m repro.launch.dryrun --arch dibella --shape train_4k \\
    --mesh single --tr-variant faithful --tag faithful --force
PYTHONPATH=src python -m repro.launch.dryrun --arch dibella --shape train_4k \\
    --mesh single --dibella-u 4 --tag u4 --force
PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k \\
    --mesh single --mixed-precision --tag mp --force
PYTHONPATH=src python -m repro.launch.dryrun --arch granite-moe-1b-a400m \\
    --shape train_4k --mesh single --moe-impl gspmd --tag gspmd --force
PYTHONPATH=src python -m repro.launch.dryrun --arch mamba2-1.3b --shape train_4k \\
    --mesh single --ssd-bf16 --batch-over-model --tag ssdopt --force
PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-4b --shape long_500k \\
    --mesh single --decode-unroll --tag unroll --force
python scripts/gen_experiments.py > EXPERIMENTS.md
```
"""


if __name__ == "__main__":
    main()
