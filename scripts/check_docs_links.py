#!/usr/bin/env python
"""Markdown link checker for the repo's docs surface.

Resolves every relative ``[text](target)`` link in README.md, DESIGN.md,
ROADMAP.md and docs/*.md against the working tree and fails if a target file
does not exist.  External (``http(s)://``) links are syntax-checked only —
CI must stay hermetic.  Anchors (``file.md#section``) are checked for the
file part.

    python scripts/check_docs_links.py [files...]

Exit status 1 with one ``path: broken link -> target`` per failure; CI runs
this in the docs job, tests/test_docs.py runs it in tier-1.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

DEFAULT_TARGETS = ["README.md", "DESIGN.md", "ROADMAP.md", "docs"]

# [text](target) — excludes images' alt-text brackets by allowing them too
_LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)\)")


def check_file(path: Path) -> list:
    """Return the broken relative link targets of one markdown file."""
    broken = []
    for target in _LINK_RE.findall(path.read_text()):
        if re.match(r"^[a-z][a-z0-9+.-]*:", target):  # http:, https:, mailto:
            continue
        if target.startswith("#"):  # in-page anchor
            continue
        rel = target.split("#", 1)[0]
        if not (path.parent / rel).exists():
            broken.append(target)
    return broken


def main(argv) -> int:
    """Check the given files/dirs (or the default docs set); 0 = clean."""
    roots = [Path(a) for a in argv] or [REPO / t for t in DEFAULT_TARGETS]
    files = []
    for r in roots:
        files.extend(sorted(r.glob("*.md")) if r.is_dir() else [r])
    failed = 0
    for f in files:
        for target in check_file(f):
            rel = f.relative_to(REPO) if f.is_absolute() else f
            print(f"{rel}: broken link -> {target}")
            failed += 1
    if failed:
        print(f"{failed} broken link(s)", file=sys.stderr)
        return 1
    print(f"links ok ({len(files)} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
