#!/usr/bin/env python
"""Markdown link checker — thin shim over rule D002 of ``repro.analysis``.

PR 10 folded the link resolution (relative ``[text](target)`` links must
exist; external links syntax-checked only so CI stays hermetic; anchors
checked for the file part) into
``repro.analysis.rules.d002_doc_links``; this wrapper keeps the old entry
point and output format alive for the CI docs job and tests/test_docs.py.

    python scripts/check_docs_links.py [files...]

Exit status 1 with one ``path: broken link -> target`` per failure.  The
full suite is ``python -m repro.analysis check``.
"""

from __future__ import annotations

import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))

from repro.analysis.rules.d002_doc_links import (  # noqa: E402
    DEFAULT_DOC_ROOTS,
    broken_links,
)

#: old name for the rule's docs surface, kept for importers.
DEFAULT_TARGETS = DEFAULT_DOC_ROOTS


def check_file(path: Path) -> list:
    """Return the broken relative link targets of one markdown file."""
    return [t for _, t in broken_links(path.read_text(), path.parent)]


def main(argv) -> int:
    """Check the given files/dirs (or the default docs set); 0 = clean."""
    roots = [Path(a) for a in argv] or [REPO / t for t in DEFAULT_TARGETS]
    files = []
    for r in roots:
        files.extend(sorted(r.glob("*.md")) if r.is_dir() else [r])
    failed = 0
    for f in files:
        for target in check_file(f):
            rel = f.relative_to(REPO) if f.is_absolute() else f
            print(f"{rel}: broken link -> {target}")
            failed += 1
    if failed:
        print(f"{failed} broken link(s)", file=sys.stderr)
        return 1
    print(f"links ok ({len(files)} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
