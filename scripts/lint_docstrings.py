#!/usr/bin/env python
"""Docstring lint — thin shim over rule D001 of ``repro.analysis``.

PR 10 folded the dependency-free pydocstyle subset (D100/D101/D102/D103,
empty docstrings rejected) into
``repro.analysis.rules.d001_docstrings``; this wrapper keeps the old entry
point and output format alive for the CI docs job and tests/test_docs.py.
The canonical target list now lives on the rule module.  Run from the repo
root:

    python scripts/lint_docstrings.py [files...]

Exit status 1 with one ``path:line: CODE message`` per violation.  The
full suite (this rule plus the trace-safety rules) is
``python -m repro.analysis check``.
"""

from __future__ import annotations

import ast
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))

from repro.analysis.rules.d001_docstrings import (  # noqa: E402
    TARGETS,
    lint_tree,
)

#: old name for the rule's curated module list, kept for importers.
DEFAULT_TARGETS = TARGETS


def lint_file(path: Path) -> list:
    """Return ``(lineno, code, message)`` violations for one file."""
    tree = ast.parse(path.read_text(), filename=str(path))
    return [(lineno, code, msg) for lineno, code, msg, _ in lint_tree(tree)]


def main(argv) -> int:
    """Lint the given files (or the D001 target list); 0 = clean."""
    targets = [Path(a) for a in argv] or [REPO / t for t in DEFAULT_TARGETS]
    failed = 0
    for t in targets:
        for lineno, code, msg in lint_file(t):
            print(f"{t.relative_to(REPO) if t.is_absolute() else t}:"
                  f"{lineno}: {code} {msg}")
            failed += 1
    if failed:
        print(f"{failed} docstring violation(s)", file=sys.stderr)
        return 1
    print(f"docstring lint clean ({len(targets)} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
