#!/usr/bin/env python
"""Docstring lint: a dependency-free pydocstyle/ruff-D subset.

Enforced rules (on the module list below — the public-API surface the docs
satellite of DESIGN.md §2.9 hardened):

  D100  module must have a docstring
  D101  public class must have a docstring
  D102  public method must have a docstring
  D103  public function must have a docstring
  D419  docstring must be non-empty

"Public" = name without a leading underscore, at module or class top level.
``@overload``/``@property`` setters and nested defs are out of scope.  Run
from the repo root:

    python scripts/lint_docstrings.py [files...]

Exit status 1 with one ``path:line: CODE message`` per violation; CI runs
this in the docs job, tests/test_docs.py runs it in tier-1.
"""

from __future__ import annotations

import ast
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

# the modules whose public APIs carry the documented contracts (PR 5 widened
# the scope to the TR module — its TRStats.backend accounting is contractual
# — and the smoke-artifact checker scripts; PR 6 adds the ring-SUMMA module
# and the fused SpGEMM kernel family; PR 7 adds the observability layer —
# its span/metrics/export surfaces are the contract docs/observability.md
# documents — plus the trace checker and the shared benchmark timer; PR 8
# adds the HBM watermark module, the experiment engine and its CLI)
DEFAULT_TARGETS = [
    "src/repro/core/align_dist.py",
    "src/repro/core/components.py",
    "src/repro/core/components_dist.py",
    "src/repro/core/backend.py",
    "src/repro/core/summa.py",
    "src/repro/core/transitive_reduction.py",
    "src/repro/assembly/contig_gen.py",
    "src/repro/kernels/cc/ref.py",
    "src/repro/kernels/cc/cc.py",
    "src/repro/kernels/cc/ops.py",
    "src/repro/kernels/spgemm/ref.py",
    "src/repro/kernels/spgemm/spgemm.py",
    "src/repro/kernels/spgemm/ops.py",
    "src/repro/obs/trace.py",
    "src/repro/obs/metrics.py",
    "src/repro/obs/schema.py",
    "src/repro/obs/export.py",
    "src/repro/obs/memory.py",
    "src/repro/obs/experiments.py",
    "benchmarks/_timing.py",
    "benchmarks/engine.py",
    "scripts/check_smoke_comm.py",
    "scripts/check_bench_regression.py",
    "scripts/check_trace.py",
    "scripts/lint_docstrings.py",
]


def _has_docstring(node) -> bool:
    doc = ast.get_docstring(node, clean=False)
    return bool(doc and doc.strip())


def lint_file(path: Path) -> list:
    """Return ``(lineno, code, message)`` violations for one file."""
    tree = ast.parse(path.read_text(), filename=str(path))
    out = []
    if not _has_docstring(tree):
        out.append((1, "D100", "missing module docstring"))

    def walk(node, in_class):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.ClassDef):
                if not child.name.startswith("_") and not _has_docstring(child):
                    out.append(
                        (child.lineno, "D101",
                         f"missing class docstring: {child.name}")
                    )
                walk(child, in_class=True)
            elif isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if not child.name.startswith("_") and not _has_docstring(child):
                    code = "D102" if in_class else "D103"
                    kind = "method" if in_class else "function"
                    out.append(
                        (child.lineno, code,
                         f"missing {kind} docstring: {child.name}")
                    )
                # nested defs are implementation detail: not walked

    walk(tree, in_class=False)
    return out


def main(argv) -> int:
    """Lint the given files (or the default target list); 0 = clean."""
    targets = [Path(a) for a in argv] or [REPO / t for t in DEFAULT_TARGETS]
    failed = 0
    for t in targets:
        for lineno, code, msg in lint_file(t):
            print(f"{t.relative_to(REPO) if t.is_absolute() else t}:"
                  f"{lineno}: {code} {msg}")
            failed += 1
    if failed:
        print(f"{failed} docstring violation(s)", file=sys.stderr)
        return 1
    print(f"docstring lint clean ({len(targets)} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
