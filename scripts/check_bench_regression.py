#!/usr/bin/env python
"""Gate fresh benchmark records against the perf/memory trajectory.

Usage (CI calls this after ``benchmarks/engine.py run --smoke``)::

    python scripts/check_bench_regression.py FRESH.json [BASELINE ...]

The first argument is the freshly generated record file; every further
argument is a baseline — either the append-only trajectory store
(``bench/trajectory.jsonl``, one JSON record per line) or a legacy
``BENCH_<n>.json`` snapshot.  With no baselines given, the script
auto-discovers ``bench/trajectory.jsonl`` at the repo root and falls back
to the latest committed ``BENCH_<n>.json`` when the store is absent.

Rows are matched by ``name``.  Two gates, both deliberately *gross* so
runner noise passes and only real faults fail:

* **time** — fresh wall-clock above ``RATIO``× the best previous ``ms``
  of that row (an accidental de-jit, a dropped fused path);
* **memory** — fresh ``peak_hbm_bytes`` above ``MEM_RATIO``× the best
  (smallest) previous watermark of that row, with a ``MIN_BYTES`` floor
  (a leaked buffer, a densified intermediate).  Rows whose baseline
  predates memory telemetry simply skip this gate.

"Best previous" means best across *every* snapshot in every baseline
file: a ``.jsonl`` trajectory holds one row per code snapshot, and the
per-era fold of :func:`_fold_best` applies within a file exactly as it
does across files, so the baseline cannot ratchet to merely the most
recent measurement.  Baseline rows stamped with the fresh run's own
``fingerprint`` are excluded outright — the experiment engine appends
fresh rows to the trajectory before CI reaches this gate, and a
measurement must never serve as its own baseline.

Coverage is part of the contract: a baseline row that is *missing* from
the fresh records fails with a per-row message (a silently dropped
benchmark must not read as "no regression").  Rows only in the fresh set
stay informational — the set is expected to grow per PR.

Baselines that predate the warmup/steady-state split (records without a
``compile_ms`` field — their ``ms`` folds XLA compile into wall-clock) are
*skipped with a notice* instead of ratio-compared: a steady-state fresh
measurement against a compile-dominated baseline would pass trivially and
mask real regressions behind a meaningless headroom.

Exit status: 0 = no gross regression and full coverage, 1 = a row
regressed or disappeared, 2 = usage error.
"""

from __future__ import annotations

import glob
import json
import os
import re
import sys

# fresh ms must stay below RATIO x best previous ms for the same row name
RATIO = 5.0

# rows faster than this on both sides are skipped: at microsecond scale the
# ratio test measures timer noise, not the benchmark
MIN_MS = 1.0

# fresh peak_hbm_bytes must stay below MEM_RATIO x the smallest previous
# watermark for the same row name
MEM_RATIO = 2.0

# watermarks below this on both sides are skipped: small pools churn with
# allocator noise, not with the benchmark's working set
MIN_BYTES = 1 << 20


def _rows(path: str):
    """Yield record dicts from a ``.json`` snapshot or ``.jsonl`` store."""
    with open(path) as f:
        if path.endswith(".jsonl"):
            for line in f:
                line = line.strip()
                if line:
                    yield json.loads(line)
        else:
            for rec in json.load(f):
                yield rec


def _fingerprints(path: str) -> frozenset:
    """Every non-empty ``fingerprint`` carried by the records of ``path``."""
    return frozenset(fp for fp in (r.get("fingerprint")
                                   for r in _rows(path)) if fp)


def _fold_best(best: dict, name: str, ms: float, split: bool,
               peak, exp) -> None:
    """Fold one record into ``best`` (name -> (ms, split, peak, exp)).

    For time, a compile-split record always beats a pre-split one (its
    ``ms`` is actually comparable); within the same era the fastest wins.
    For memory, the smallest recorded watermark wins independently."""
    if name not in best:
        best[name] = (ms, split, peak, exp)
        return
    b_ms, b_split, b_peak, b_exp = best[name]
    if (split, -ms) > (b_split, -b_ms):
        b_ms, b_split = ms, split
    if peak is not None and (b_peak is None or peak < b_peak):
        b_peak = peak
    best[name] = (b_ms, b_split, b_peak, b_exp or exp)


def _load(path: str, exclude_fps: frozenset = frozenset()) -> dict:
    """Best record per ``name`` within one file: map ``name`` -> ``(ms,
    has_compile_split, peak_bytes_or_None, experiment_label_or_None)``.

    Duplicate names (a ``.jsonl`` trajectory holds one row per code
    snapshot) fold via :func:`_fold_best`, so the result is the best
    measurement *ever recorded* in the file, not merely its most recent
    row, and a later pre-split row never displaces a split baseline.
    Rows whose ``fingerprint`` is in ``exclude_fps`` are skipped — they
    came from the same code snapshot as the fresh run (the engine appends
    to the trajectory before the gate runs) and must not serve as their
    own baseline."""
    out: dict = {}
    for r in _rows(path):
        if "name" not in r or "ms" not in r:
            continue
        if r.get("fingerprint") in exclude_fps:
            continue
        peak = r.get("peak_hbm_bytes")
        _fold_best(out, r["name"], float(r["ms"]), "compile_ms" in r,
                   None if peak is None else int(peak), r.get("experiment"))
    return out


def _merge_best(paths, exclude_fps: frozenset = frozenset()) -> dict:
    """Best baseline per row name across ``paths`` (:func:`_fold_best`
    semantics within and across files)."""
    best: dict = {}
    for path in paths:
        for name, (ms, split, peak, exp) in _load(path,
                                                  exclude_fps).items():
            _fold_best(best, name, ms, split, peak, exp)
    return best


def check(fresh: dict, previous: dict) -> tuple:
    """Compare ``fresh`` vs ``previous`` (name -> (ms, split, peak, exp)).

    Returns ``(failures, notices)``: failures are ``(name, message)`` pairs
    for time- or memory-regressed rows *and* baseline rows missing from the
    fresh records; notices are rows skipped because their baseline predates
    the compile/steady-state split.  Coverage is scoped by experiment
    label: a baseline row from an experiment the fresh run did not execute
    at all (e.g. a full-size sweep in the trajectory store vs a smoke run)
    is out of scope, not a dropped benchmark; unlabelled legacy baselines
    stay fully in scope, and a fresh set carrying *no* experiment labels
    at all (legacy ``benchmarks/run.py`` output) keeps every baseline row
    in scope — full pre-engine coverage, not a blanket skip."""
    failures = []
    notices = []
    for name, (ms, _, peak, _exp) in sorted(fresh.items()):
        if name not in previous:
            continue  # new row: informational only
        base, base_split, base_peak, _bexp = previous[name]
        if not base_split:
            notices.append(
                (name,
                 f"baseline {base:.1f} ms has no compile_ms field "
                 "(compile-dominated measurement) — skipped, not compared"))
        elif ms <= MIN_MS and base <= MIN_MS:
            pass  # sub-millisecond rows: ratio is timer noise
        elif ms > RATIO * max(base, MIN_MS):
            failures.append(
                (name,
                 f"{ms:.1f} ms vs previous best {base:.1f} ms "
                 f"(> {RATIO:.0f}x)"))
        if peak is not None and base_peak is not None:
            if not (peak <= MIN_BYTES and base_peak <= MIN_BYTES) and \
                    peak > MEM_RATIO * max(base_peak, MIN_BYTES):
                failures.append(
                    (name,
                     f"peak_hbm_bytes {peak} vs previous best {base_peak} "
                     f"(> {MEM_RATIO:.0f}x) — device-memory watermark grew"))
    fresh_labels = {exp for (_, _, _, exp) in fresh.values()
                    if exp is not None}
    for name in sorted(set(previous) - set(fresh)):
        exp = previous[name][3]
        if fresh_labels and exp is not None and exp not in fresh_labels:
            continue  # whole experiment out of scope for this run
        failures.append(
            (name,
             f"baseline row missing from fresh records (previous best "
             f"{previous[name][0]:.1f} ms) — benchmark dropped or renamed "
             "without updating the trajectory"))
    return failures, notices


def _default_baselines(fresh_path: str, root: str = None) -> list:
    """Auto-discovered baselines: the trajectory store when present, else
    the latest committed ``BENCH_<n>.json`` snapshot (numeric ``<n>``,
    so ``BENCH_10`` beats ``BENCH_2``).  ``root`` defaults to the repo
    root (this script's grandparent directory)."""
    if root is None:
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    traj = os.path.join(root, "bench", "trajectory.jsonl")
    if os.path.exists(traj):
        return [traj]
    snaps = []
    for path in glob.glob(os.path.join(root, "BENCH_*.json")):
        m = re.match(r"BENCH_(\d+)\.json$", os.path.basename(path))
        if m and os.path.abspath(path) != os.path.abspath(fresh_path):
            snaps.append((int(m.group(1)), path))
    return [max(snaps)[1]] if snaps else []


def main(argv) -> int:
    """Compare ``argv[0]`` against the best of ``argv[1:]`` per row."""
    if not argv:
        print("usage: check_bench_regression.py FRESH.json [BASELINE ...]",
              file=sys.stderr)
        return 2
    fresh_path, prev_paths = argv[0], argv[1:]
    # the fresh file may also appear in the previous list (CI passes
    # `git ls-files`, and the snapshot itself is committed) — drop it
    prev_paths = [p for p in prev_paths if p != fresh_path]
    if not prev_paths:
        prev_paths = _default_baselines(fresh_path)
    if not prev_paths:
        print(f"{fresh_path}: no trajectory store or BENCH_*.json to diff "
              "against — trajectory starts here")
        return 0
    fresh = _load(fresh_path)
    # baseline rows from the fresh run's own code snapshot (the engine
    # appends to the trajectory before CI reaches this gate) are dropped:
    # a measurement is never its own baseline
    fresh_fps = _fingerprints(fresh_path)
    best = _merge_best(prev_paths, exclude_fps=fresh_fps)
    failures, notices = check(fresh, best)
    for name, msg in notices:
        print(f"note: {fresh_path}: {name}: {msg}")
    for name, msg in failures:
        print(f"{fresh_path}: {name}: {msg}")
    new = sorted(set(fresh) - set(best))
    if new:
        print(f"note: {len(new)} new row(s): {', '.join(new)}")
    if not failures:
        shared = len(set(fresh) & set(best))
        print(f"{fresh_path}: no gross perf/memory regression vs "
              f"{', '.join(os.path.basename(p) for p in prev_paths)} "
              f"({shared} shared row(s), {len(notices)} skipped pre-split "
              f"baseline(s), thresholds {RATIO:.0f}x time / "
              f"{MEM_RATIO:.0f}x memory)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
