#!/usr/bin/env python
"""Diff a fresh benchmark snapshot against committed ``BENCH_*.json`` ones.

Usage (CI calls this after regenerating the snapshot on the smoke grid)::

    python scripts/check_bench_regression.py FRESH.json [PREVIOUS.json ...]

The first argument is the freshly generated snapshot; every further argument
is a previously committed trajectory file (``git ls-files 'BENCH_*.json'``).
Rows are matched by ``name``.  A row regresses when its fresh wall-clock
exceeds ``RATIO``× the *best* previous measurement of that row — a deliberate
threshold far above runner noise, so only gross slowdowns (an accidental
de-jit, a dropped fused path) fail CI while normal jitter passes.

Rows present only on one side are reported informationally and never fail:
the benchmark set is expected to grow per PR, and a renamed row should not
block the PR that renames it.  With no previous snapshots at all the script
succeeds immediately (first PR in the trajectory).

Exit status: 0 = no gross regression, 1 = at least one row regressed,
2 = usage error.
"""

from __future__ import annotations

import json
import sys

# fresh ms must stay below RATIO x best previous ms for the same row name
RATIO = 5.0

# rows faster than this on both sides are skipped: at microsecond scale the
# ratio test measures timer noise, not the benchmark
MIN_MS = 1.0


def _load(path: str) -> dict:
    """Map ``name`` -> ``ms`` for one snapshot file."""
    with open(path) as f:
        records = json.load(f)
    return {r["name"]: float(r["ms"]) for r in records if "name" in r}


def check(fresh: dict, previous: dict) -> list:
    """Return ``(name, message)`` regressions of ``fresh`` vs ``previous``
    (a name -> best-previous-ms map); empty means no gross slowdown."""
    failures = []
    for name, ms in sorted(fresh.items()):
        base = previous.get(name)
        if base is None:
            continue  # new row: informational only
        if ms <= MIN_MS and base <= MIN_MS:
            continue  # sub-millisecond rows: ratio is timer noise
        if ms > RATIO * max(base, MIN_MS):
            failures.append(
                (name,
                 f"{ms:.1f} ms vs previous best {base:.1f} ms "
                 f"(> {RATIO:.0f}x)"))
    return failures


def main(argv) -> int:
    """Compare ``argv[0]`` against the best of ``argv[1:]`` per row."""
    if not argv:
        print("usage: check_bench_regression.py FRESH.json [PREV.json ...]",
              file=sys.stderr)
        return 2
    fresh_path, prev_paths = argv[0], argv[1:]
    # the fresh file may also appear in the previous list (CI passes
    # `git ls-files`, and the snapshot itself is committed) — drop it
    prev_paths = [p for p in prev_paths if p != fresh_path]
    if not prev_paths:
        print(f"{fresh_path}: no previous BENCH_*.json to diff against — "
              "trajectory starts here")
        return 0
    fresh = _load(fresh_path)
    best: dict = {}
    for path in prev_paths:
        for name, ms in _load(path).items():
            if name not in best or ms < best[name]:
                best[name] = ms
    failures = check(fresh, best)
    for name, msg in failures:
        print(f"{fresh_path}: {name}: {msg}")
    new = sorted(set(fresh) - set(best))
    gone = sorted(set(best) - set(fresh))
    if new:
        print(f"note: {len(new)} new row(s): {', '.join(new)}")
    if gone:
        print(f"note: {len(gone)} row(s) no longer measured: "
              f"{', '.join(gone)}")
    if not failures:
        shared = len(set(fresh) & set(best))
        print(f"{fresh_path}: no gross perf regression "
              f"({shared} shared row(s), threshold {RATIO:.0f}x)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
