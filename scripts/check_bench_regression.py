#!/usr/bin/env python
"""Diff a fresh benchmark snapshot against committed ``BENCH_*.json`` ones.

Usage (CI calls this after regenerating the snapshot on the smoke grid)::

    python scripts/check_bench_regression.py FRESH.json [PREVIOUS.json ...]

The first argument is the freshly generated snapshot; every further argument
is a previously committed trajectory file (``git ls-files 'BENCH_*.json'``).
Rows are matched by ``name``.  A row regresses when its fresh wall-clock
exceeds ``RATIO``× the *best* previous measurement of that row — a deliberate
threshold far above runner noise, so only gross slowdowns (an accidental
de-jit, a dropped fused path) fail CI while normal jitter passes.

Coverage is part of the contract: a baseline row that is *missing* from the
fresh snapshot fails with a per-row message (a silently dropped benchmark
must not read as "no regression").  Rows only in the fresh snapshot stay
informational — the set is expected to grow per PR.

Baselines that predate the warmup/steady-state split (records without a
``compile_ms`` field — their ``ms`` folds XLA compile into wall-clock) are
*skipped with a notice* instead of ratio-compared: a steady-state fresh
measurement against a compile-dominated baseline would pass trivially and
mask real regressions behind a meaningless headroom.

Exit status: 0 = no gross regression and full coverage, 1 = a row regressed
or disappeared, 2 = usage error.
"""

from __future__ import annotations

import json
import sys

# fresh ms must stay below RATIO x best previous ms for the same row name
RATIO = 5.0

# rows faster than this on both sides are skipped: at microsecond scale the
# ratio test measures timer noise, not the benchmark
MIN_MS = 1.0


def _load(path: str) -> dict:
    """Map ``name`` -> ``(ms, has_compile_split)`` for one snapshot file."""
    with open(path) as f:
        records = json.load(f)
    return {r["name"]: (float(r["ms"]), "compile_ms" in r)
            for r in records if "name" in r}


def check(fresh: dict, previous: dict) -> tuple:
    """Compare ``fresh`` vs ``previous`` (name -> (best ms, split flag)).

    Returns ``(failures, notices)``: failures are ``(name, message)`` pairs
    for regressed rows *and* baseline rows missing from the fresh snapshot;
    notices are rows skipped because their baseline predates the
    compile/steady-state split."""
    failures = []
    notices = []
    for name, (ms, _) in sorted(fresh.items()):
        if name not in previous:
            continue  # new row: informational only
        base, base_split = previous[name]
        if not base_split:
            notices.append(
                (name,
                 f"baseline {base:.1f} ms has no compile_ms field "
                 "(compile-dominated measurement) — skipped, not compared"))
            continue
        if ms <= MIN_MS and base <= MIN_MS:
            continue  # sub-millisecond rows: ratio is timer noise
        if ms > RATIO * max(base, MIN_MS):
            failures.append(
                (name,
                 f"{ms:.1f} ms vs previous best {base:.1f} ms "
                 f"(> {RATIO:.0f}x)"))
    for name in sorted(set(previous) - set(fresh)):
        failures.append(
            (name,
             f"baseline row missing from fresh snapshot (previous best "
             f"{previous[name][0]:.1f} ms) — benchmark dropped or renamed "
             "without updating the trajectory"))
    return failures, notices


def main(argv) -> int:
    """Compare ``argv[0]`` against the best of ``argv[1:]`` per row."""
    if not argv:
        print("usage: check_bench_regression.py FRESH.json [PREV.json ...]",
              file=sys.stderr)
        return 2
    fresh_path, prev_paths = argv[0], argv[1:]
    # the fresh file may also appear in the previous list (CI passes
    # `git ls-files`, and the snapshot itself is committed) — drop it
    prev_paths = [p for p in prev_paths if p != fresh_path]
    if not prev_paths:
        print(f"{fresh_path}: no previous BENCH_*.json to diff against — "
              "trajectory starts here")
        return 0
    fresh = _load(fresh_path)
    best: dict = {}
    for path in prev_paths:
        for name, (ms, split) in _load(path).items():
            # a compile-split baseline always beats a pre-split one (its ms
            # is actually comparable); within the same era, best wins
            if (name not in best or (split, -ms) > (best[name][1],
                                                    -best[name][0])):
                best[name] = (ms, split)
    failures, notices = check(fresh, best)
    for name, msg in notices:
        print(f"note: {fresh_path}: {name}: {msg}")
    for name, msg in failures:
        print(f"{fresh_path}: {name}: {msg}")
    new = sorted(set(fresh) - set(best))
    if new:
        print(f"note: {len(new)} new row(s): {', '.join(new)}")
    if not failures:
        shared = len(set(fresh) & set(best))
        print(f"{fresh_path}: no gross perf regression "
              f"({shared} shared row(s), {len(notices)} skipped pre-split "
              f"baseline(s), threshold {RATIO:.0f}x)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
