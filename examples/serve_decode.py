"""Serve a model with batched requests: prefill + greedy decode with KV/SSM
caches (compare attention vs SSM cache behaviour).

    PYTHONPATH=src python examples/serve_decode.py --arch mamba2-1.3b
"""

import argparse

from repro.launch.serve import main as serve_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()
    serve_main(["--arch", args.arch, "--reduced", "--batch",
                str(args.batch), "--gen", str(args.gen)])


if __name__ == "__main__":
    main()
