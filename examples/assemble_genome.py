"""End-to-end driver (the paper's kind of workload): assemble a larger
simulated long-read dataset, report Table-III/IV-style statistics, polish
the contig tensor (DESIGN.md §2.8), validate pre- vs post-consensus identity
against the known genome, and write component-grouped FASTA.

    PYTHONPATH=src python examples/assemble_genome.py [--genome-kb 40]
"""

import argparse
import time

import numpy as np

from repro.assembly.contigs import contig_components, read_components
from repro.assembly.io_fasta import write_contig_fasta
from repro.assembly.metrics import assembly_identity
from repro.assembly.pipeline import PipelineConfig, assemble
from repro.assembly.simulate import simulate_genome, simulate_reads


def kmer_recall(contig, genome, k=15, stride=3):
    """Exact-k-mer recall of the contig against the genome (genome sampled
    at stride 1 so offsets align).  The draft contig carries read errors,
    bounding recall at ~(1-e)^k; the consensus stage exists to lift it."""

    def kms(x, st):
        return {tuple(x[i: i + k]) for i in range(0, len(x) - k + 1, st)}

    rc = (3 - genome)[::-1]
    gk = kms(genome, 1) | kms(rc, 1)
    ck = kms(contig, stride)
    return len(ck & gk) / max(1, len(ck))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--genome-kb", type=int, default=30)
    ap.add_argument("--depth", type=float, default=14)
    ap.add_argument("--error-rate", type=float, default=0.05)
    ap.add_argument("--indel-frac", type=float, default=0.6,
                    help="fraction of errors that are indels (0 = CCS-like "
                         "substitutions, 0.6 = CLR-like)")
    ap.add_argument("--out", default="/tmp/contigs.fasta")
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    genome = simulate_genome(rng, args.genome_kb * 1000)
    reads = simulate_reads(genome, depth=args.depth, mean_len=1400,
                           std_len=250, error_rate=args.error_rate,
                           indel_frac=args.indel_frac, seed=1)
    print(f"[data] genome {len(genome)/1e3:.0f} kb, {reads.n_reads} reads, "
          f"depth {reads.depth:.1f}, error {args.error_rate:.0%} "
          f"(indel {args.indel_frac:.0%})")

    cfg = PipelineConfig(
        m_capacity=1 << 17, upper=int(4 * args.depth), read_capacity=160,
        overlap_capacity=64, r_capacity=40, band=65, max_steps=4096,
        xdrop=30, align_chunk=4096,
    )
    t0 = time.time()
    res = assemble(reads.codes, reads.lengths, cfg)
    print(f"[run] {time.time()-t0:.1f}s total; stages:",
          {k: round(v, 1) for k, v in res.timings.items()})

    s = res.stats
    print(f"[stats] c={s['c_density']:.1f} (2d={2*args.depth:.0f}) "
          f"r={s['r_density']:.2f} s={s['s_density']:.2f} "
          f"TR iters={s['tr_iterations']} "
          f"nnz R->S {s['nnz_R']}->{s['nnz_S']}")
    cs = s["contigs"]
    print(f"[contigs] n={cs['n_contigs']} N50={cs['n50']} L50={cs['l50']} "
          f"mean={cs['mean_length']:.0f} longest={cs['longest']} "
          f"total={cs['total_length']}")

    # pre- vs post-consensus identity against the simulated truth positions
    band = max(64, int(8 * args.error_rate * 1400))
    draft_id, nb = assembly_identity(res.contigs, reads, min_reads=2,
                                     band=band)
    pol_id, _ = assembly_identity(res.polished_contigs, reads, min_reads=2,
                                  band=band)
    print(f"[consensus] depth {s['consensus_depth_mean']:.1f}x, "
          f"{s['consensus_changed']} columns re-called, "
          f"{s['n_junction_shifted']} junctions re-anchored; "
          f"identity vs truth ({nb} bases): draft {draft_id:.4f} -> "
          f"polished {pol_id:.4f} "
          f"(estimate {s['identity_estimate']:.4f}, QV~{s['qv_estimate']:.1f})")

    polished = res.polished_contigs
    longest = max(polished, key=lambda c: c.length)
    rec = kmer_recall(longest.codes, genome)
    print(f"[validate] longest polished contig k-mer recall: {rec:.3f}")

    comps = contig_components(polished, read_components(res.s_graph))
    n_rec = write_contig_fasta(
        args.out, polished, comps,
        identity=np.asarray(res.consensus.identity),
        depth=np.asarray(res.consensus.depth_mean),
    )
    print(f"[out] {args.out}: {n_rec} records, "
          f"{len(set(comps))} component group(s)")


if __name__ == "__main__":
    main()
