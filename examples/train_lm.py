"""Train a language model with the framework's full substrate: deterministic
sharded data, AdamW + cosine, checkpoint/restart, straggler monitoring.

Default is a CPU-scale reduced config; ``--preset 100m`` trains a ~100M-param
model (the brief's end-to-end target — takes hours on CPU, minutes on a TPU
host).

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""

import argparse
import dataclasses

from repro.configs import reduced_config
from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--preset", choices=["smoke", "100m"], default="smoke")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    if args.preset == "100m":
        # ~100M params: 12L × d768 (GPT-2-small class) on the qwen3 recipe
        import repro.configs as C
        from repro.models.model import ModelConfig
        cfg = dataclasses.replace(
            reduced_config("qwen3-4b"), name="repro-100m", n_layers=12,
            d_model=768, n_heads=12, n_kv_heads=4, d_head=64, d_ff=3072,
            vocab_size=32000,
        )
        # register it so the train driver can find it
        import repro.configs.qwen3_4b as q
        orig = q.reduced
        q.reduced = lambda: cfg
        argv = ["--arch", "qwen3-4b", "--reduced", "--steps",
                str(args.steps), "--batch", "8", "--seq", "512",
                "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50"]
    else:
        argv = ["--arch", args.arch, "--reduced", "--steps", str(args.steps),
                "--batch", "8", "--seq", "128", "--ckpt-dir", args.ckpt_dir,
                "--ckpt-every", "50"]
    if args.resume:
        argv.append("--resume")
    losses = train_main(argv)
    assert losses[-1] < losses[0], "loss should decrease"
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f} over {len(losses)} steps")


if __name__ == "__main__":
    main()
