"""Quickstart: assemble a small synthetic genome end to end (paper Alg. 1
plus the consensus polish, DESIGN.md §2.8).

    PYTHONPATH=src python examples/quickstart.py [--error-rate 0.03]
"""

import argparse

import numpy as np

from repro.assembly.contigs import contig_str
from repro.assembly.metrics import assembly_identity
from repro.assembly.pipeline import PipelineConfig, assemble
from repro.assembly.simulate import simulate_genome, simulate_reads


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--error-rate", type=float, default=0.03)
    ap.add_argument("--indel-frac", type=float, default=0.0,
                    help="fraction of errors that are indels; 0 (CCS-like "
                         "substitutions) is where pileup polish shines — at "
                         "CLR-like 0.6 the coherence gate mostly abstains "
                         "(DESIGN.md §2.8)")
    args = ap.parse_args()

    rng = np.random.default_rng(42)
    genome = simulate_genome(rng, 8_000)
    reads = simulate_reads(genome, depth=12, mean_len=900, std_len=120,
                           error_rate=args.error_rate,
                           indel_frac=args.indel_frac, seed=1)
    print(f"genome {len(genome)} bp; {reads.n_reads} reads, "
          f"depth {reads.depth:.1f}, error {args.error_rate:.0%}")

    cfg = PipelineConfig(m_capacity=1 << 15, upper=48, read_capacity=128,
                         overlap_capacity=48, r_capacity=32, band=33,
                         max_steps=2048, align_chunk=8192)
    res = assemble(reads.codes, reads.lengths, cfg)

    print("\npipeline stages (paper Fig. 5-8 layers):")
    for k, v in res.timings.items():
        print(f"  {k:<12} {v:7.2f} s")
    print("\nstatistics (paper Table III analogues):")
    for k in ("c_density", "r_density", "s_density", "tr_iterations",
              "n_contained", "n_branch_cut", "cc_iterations"):
        print(f"  {k:<15} {res.stats[k]}")
    cs = res.stats["contigs"]
    print(f"\ncontigs: {cs['n_contigs']}  N50={cs['n50']}  L50={cs['l50']}  "
          f"mean={cs['mean_length']:.0f}  "
          f"longest={cs['longest']} (genome={len(genome)})")

    # consensus: measured pre- vs post-polish identity against the simulated
    # truth, next to the pipeline's on-device vote-agreement estimate
    draft_id, nb = assembly_identity(res.contigs, reads, min_reads=2)
    pol_id, _ = assembly_identity(res.polished_contigs, reads, min_reads=2)
    print(f"\nconsensus (DESIGN.md §2.8): depth "
          f"{res.stats['consensus_depth_mean']:.1f}x, "
          f"{res.stats['consensus_changed']} columns re-called, "
          f"{res.stats['n_junction_shifted']} junctions re-anchored")
    print(f"identity vs truth ({nb} bases): draft {draft_id:.4f} -> "
          f"polished {pol_id:.4f} "
          f"(on-device estimate {res.stats['identity_estimate']:.4f}, "
          f"QV~{res.stats['qv_estimate']:.1f})")
    longest = max(res.polished_contigs, key=lambda c: c.length)
    print(f"longest polished contig head: {contig_str(longest)[:60]}...")


if __name__ == "__main__":
    main()
