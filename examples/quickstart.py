"""Quickstart: assemble a small synthetic genome end to end (paper Alg. 1).

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.assembly.contigs import contig_str
from repro.assembly.pipeline import PipelineConfig, assemble
from repro.assembly.simulate import simulate_genome, simulate_reads


def main():
    rng = np.random.default_rng(42)
    genome = simulate_genome(rng, 8_000)
    reads = simulate_reads(genome, depth=12, mean_len=900, std_len=120,
                           error_rate=0.03, seed=1)
    print(f"genome {len(genome)} bp; {reads.n_reads} reads, "
          f"depth {reads.depth:.1f}")

    cfg = PipelineConfig(m_capacity=1 << 15, upper=48, read_capacity=128,
                         overlap_capacity=48, r_capacity=32, band=33,
                         max_steps=2048, align_chunk=8192)
    res = assemble(reads.codes, reads.lengths, cfg)

    print("\npipeline stages (paper Fig. 5-8 layers):")
    for k, v in res.timings.items():
        print(f"  {k:<12} {v:7.2f} s")
    print("\nstatistics (paper Table III analogues):")
    for k in ("c_density", "r_density", "s_density", "tr_iterations",
              "n_contained", "n_branch_cut", "cc_iterations"):
        print(f"  {k:<15} {res.stats[k]}")
    cs = res.stats["contigs"]
    print(f"\ncontigs: {cs['n_contigs']}  N50={cs['n50']}  L50={cs['l50']}  "
          f"mean={cs['mean_length']:.0f}  "
          f"longest={cs['longest']} (genome={len(genome)})")
    longest = max(res.contigs, key=lambda c: c.length)
    print(f"longest contig head: {contig_str(longest)[:60]}...")


if __name__ == "__main__":
    main()
